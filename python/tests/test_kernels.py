"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/strides/seeds; every case asserts allclose.
This is the CORE correctness signal for the compute layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_bias_act, depthwise3x3, avgpool_global, same_pad
from compile.kernels.matmul import vmem_bytes as mm_vmem, mxu_utilization, apply_act
from compile.kernels.depthwise import vmem_bytes as dw_vmem
from compile.kernels.ref import (
    ref_matmul_bias_act,
    ref_depthwise3x3,
    ref_avgpool_global,
)

ACTS = ["none", "relu", "relu6", "sigmoid", "silu"]


def _rand(rng, *shape):
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act", ACTS)
def test_matmul_acts(act):
    rng = np.random.RandomState(0)
    x, w, b = _rand(rng, 64, 32), _rand(rng, 32, 48), _rand(rng, 48)
    got = matmul_bias_act(x, w, b, act)
    want = ref_matmul_bias_act(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 96),
    n=st.integers(1, 300),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_shape_sweep(m, k, n, act, seed):
    rng = np.random.RandomState(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = matmul_bias_act(x, w, b, act)
    want = ref_matmul_bias_act(x, w, b, act)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_tile_boundary_shapes():
    # Exactly at / just around the 128-tile boundaries.
    rng = np.random.RandomState(1)
    for m in (127, 128, 129):
        for n in (127, 128, 129):
            x, w, b = _rand(rng, m, 16), _rand(rng, 16, n), _rand(rng, n)
            np.testing.assert_allclose(
                matmul_bias_act(x, w, b, "relu"),
                ref_matmul_bias_act(x, w, b, "relu"),
                rtol=1e-5,
                atol=1e-5,
            )


def test_matmul_custom_tiles():
    rng = np.random.RandomState(2)
    x, w, b = _rand(rng, 200, 40), _rand(rng, 40, 72), _rand(rng, 72)
    for tm, tn in [(32, 32), (64, 128), (256, 8)]:
        np.testing.assert_allclose(
            matmul_bias_act(x, w, b, "none", tile_m=tm, tile_n=tn),
            ref_matmul_bias_act(x, w, b, "none"),
            rtol=1e-5,
            atol=1e-5,
        )


def test_matmul_rejects_bad_act():
    rng = np.random.RandomState(0)
    with pytest.raises(ValueError):
        matmul_bias_act(_rand(rng, 4, 4), _rand(rng, 4, 4), _rand(rng, 4), "tanh")


def test_apply_act_values():
    x = jnp.asarray([-1.0, 0.0, 3.0, 7.0], jnp.float32)
    np.testing.assert_allclose(apply_act(x, "relu"), [0, 0, 3, 7])
    np.testing.assert_allclose(apply_act(x, "relu6"), [0, 0, 3, 6])
    np.testing.assert_allclose(apply_act(x, "none"), x)


def test_mm_perf_estimators():
    # Analytic estimators used by EXPERIMENTS.md #Perf-L1 are sane.
    assert mm_vmem(4096, 64, 128, tile_m=128, tile_n=128) == 4 * (
        128 * 64 + 64 * 128 + 128 + 128 * 128
    )
    assert 0.0 < mxu_utilization(100, 32, 100) <= 1.0
    assert mxu_utilization(128, 32, 128, tile_m=128, tile_n=128) == 1.0


# ---------------------------------------------------------------------------
# depthwise3x3
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("act", ["none", "relu6", "silu"])
def test_depthwise_basic(stride, act):
    rng = np.random.RandomState(3)
    x, w, b = _rand(rng, 16, 16, 24), _rand(rng, 3, 3, 24), _rand(rng, 24)
    got = depthwise3x3(x, w, b, stride, act)
    want = ref_depthwise3x3(x, w, b, stride, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(3, 40),
    w=st.integers(3, 40),
    c=st.integers(1, 160),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_shape_sweep(h, w, c, stride, seed):
    rng = np.random.RandomState(seed)
    x, wgt, b = _rand(rng, h, w, c), _rand(rng, 3, 3, c), _rand(rng, c)
    got = depthwise3x3(x, wgt, b, stride)
    want = ref_depthwise3x3(x, wgt, b, stride)
    assert got.shape == want.shape == (-(-h // stride), -(-w // stride), c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_depthwise_odd_sizes():
    rng = np.random.RandomState(4)
    for h, w in [(7, 9), (5, 5), (3, 3), (31, 17)]:
        for s in (1, 2):
            x, wgt, b = _rand(rng, h, w, 8), _rand(rng, 3, 3, 8), _rand(rng, 8)
            np.testing.assert_allclose(
                depthwise3x3(x, wgt, b, s),
                ref_depthwise3x3(x, wgt, b, s),
                rtol=1e-5,
                atol=1e-5,
            )


def test_same_pad_semantics():
    # TF SAME semantics: out = ceil(in/stride).
    assert same_pad(64, 3, 1) == (64, 1, 1)
    assert same_pad(64, 3, 2) == (32, 0, 1)
    assert same_pad(7, 3, 2) == (4, 1, 1)
    out, lo, hi = same_pad(5, 3, 1)
    assert out == 5 and lo + hi == 2


def test_dw_perf_estimator():
    assert dw_vmem(16, 16, 8) > 0
    # channel tiling caps the slab at tile_c channels
    assert dw_vmem(16, 16, 512, tile_c=128) < dw_vmem(16, 16, 512, tile_c=512)


# ---------------------------------------------------------------------------
# avgpool_global
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 32),
    w=st.integers(1, 32),
    c=st.integers(1, 512),
    seed=st.integers(0, 2**31 - 1),
)
def test_avgpool_sweep(h, w, c, seed):
    rng = np.random.RandomState(seed)
    x = _rand(rng, h, w, c)
    got = avgpool_global(x)
    assert got.shape == (c,)
    np.testing.assert_allclose(got, ref_avgpool_global(x), rtol=1e-5, atol=1e-6)


def test_avgpool_constant():
    x = jnp.full((4, 4, 3), 2.5, jnp.float32)
    np.testing.assert_allclose(avgpool_global(x), [2.5, 2.5, 2.5], rtol=1e-6)
