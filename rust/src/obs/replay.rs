//! Trace replay & audit: re-derive a full [`SimReport`] from an NDJSON
//! firehose, and diff two traces for determinism debugging.
//!
//! The firehose (PR 6) made every engine event visible; this module makes
//! it *verifiable*. [`FirehoseReader`] streams a trace line-at-a-time
//! through [`Json::parse`] (constant memory — a 10M-request trace never
//! lives in RAM), [`ReplayState`] folds the events into the same ledger
//! sums the live engine keeps, and [`verify`] confronts the reconstruction
//! with the live report: integer counters must match exactly, energy and
//! carbon to float tolerance. A trace that replays clean is an
//! independently audited carbon ledger — the paper's per-gCO2 claims
//! re-derived from raw events rather than trusted from the aggregator.
//!
//! Requirements on the trace: it must carry a `run_meta` header and every
//! event kind (`--trace-filter all`, the default). Replay reconstructs
//! everything except per-node SoC timelines/projections (interior battery
//! state is not on the event stream) and monitor summaries; [`verify`]
//! skips those fields.
//!
//! [`diff`] compares two traces event-by-event and reports the first
//! divergence (line, kind, virtual time, field) — the tool the sharded-
//! engine determinism work needs: two runs that should be identical are
//! localised to the exact event where they stopped agreeing, instead of
//! eyeballing two end-of-run reports.

use std::collections::BTreeMap;
use std::io::{self, BufRead};

use crate::carbon::joules_to_kwh;
use crate::sim::report::{sum_storage, sum_supply, summary_or_zero};
use crate::sim::{ClassUsage, NodeUsage, SimReport, SiteUsage};
use crate::util::json::Json;

use super::EventKind;

/// Relative tolerance for float comparisons in [`verify`]; the engine and
/// the replay sum the same per-event values in the same order, so real
/// agreement is ~1e-15 — 1e-6 is the audit threshold, not the noise floor.
pub const VERIFY_REL_TOL: f64 = 1e-6;
/// Absolute floor for near-zero comparisons in [`verify`].
pub const VERIFY_ABS_TOL: f64 = 1e-9;

/// Streams NDJSON trace lines through [`Json::parse`], one at a time over
/// a reused buffer — no whole-file read, no line vector.
pub struct FirehoseReader<R: io::BufRead> {
    input: R,
    buf: String,
    line: u64,
}

impl<R: io::BufRead> FirehoseReader<R> {
    pub fn new(input: R) -> FirehoseReader<R> {
        FirehoseReader { input, buf: String::new(), line: 0 }
    }

    /// 1-indexed number of the last line handed out.
    pub fn line(&self) -> u64 {
        self.line
    }

    /// Next non-empty line as a parsed [`Json`] event, `None` at EOF.
    pub fn next_event(&mut self) -> Result<Option<Json>, String> {
        loop {
            self.buf.clear();
            let n = self
                .input
                .read_line(&mut self.buf)
                .map_err(|e| format!("trace read error after line {}: {e}", self.line))?;
            if n == 0 {
                return Ok(None);
            }
            self.line += 1;
            let text = self.buf.trim();
            if text.is_empty() {
                continue;
            }
            return Json::parse(text)
                .map(Some)
                .map_err(|e| format!("trace line {}: {e}", self.line));
        }
    }
}

/// The run header, from the trace's `run_meta` event.
struct Meta {
    scenario: String,
    scheduler: String,
    seed: u64,
    requests_declared: u64,
    node_names: Vec<String>,
    node_microgrid: Vec<bool>,
    node_index: BTreeMap<String, usize>,
    class_names: Vec<String>,
    class_slo_s: Vec<f64>,
    site_names: Vec<String>,
    site_of: Vec<usize>,
    site_index: BTreeMap<String, usize>,
    router: String,
}

/// Per-node replay ledger, mirroring the engine's per-node accumulators.
#[derive(Default, Clone)]
struct NodeAcc {
    tasks: u64,
    busy_ms: f64,
    energy_dynamic_kwh: f64,
    carbon_dynamic_g: f64,
    uptime_s: f64,
    idle_energy_j: f64,
    idle_carbon_g: f64,
    pv_j: f64,
    battery_j: f64,
    grid_j: f64,
    grid_charge_j: f64,
    charged_g: f64,
    battery_g: f64,
    /// Stored embodied carbon after the node's *latest* settlement slice —
    /// the last slice in the trace ends at the horizon, so this finishes
    /// as the report's `carbon_stored_g`.
    stored_g: f64,
    queue_delay_ms: Vec<f64>,
}

/// Per-class replay ledger. `arrived` feeds the per-class conservation
/// identity: a request's class never changes after arrival, so the class's
/// rejected count is `arrived − completed` — the same identity the fleet
/// level uses.
#[derive(Default, Clone)]
struct ClassAcc {
    arrived: u64,
    completed: u64,
    slo_missed: u64,
    batches: u64,
    latency_ms: Vec<f64>,
    energy_j: f64,
    carbon_g: f64,
}

/// Per-site replay ledger: the WAN side of a site's row. Member-node
/// energy/carbon come from the per-node ledgers via the meta's `site_of`
/// map; only the cross-site transfer sums need their own accumulators.
#[derive(Default, Clone)]
struct SiteAcc {
    shipped_out: u64,
    shipped_in: u64,
    wan_energy_j: f64,
    wan_carbon_g: f64,
}

/// Folds trace events into the same sums the live engine keeps, then
/// produces a [`SimReport`] via [`ReplayState::finish`]. Counter
/// identities, per event kind:
///
/// - `arrival` → `requests`; `defer_release` → `deferred`; `completion` →
///   `completed` (+ per-node/per-class ledgers, latency, makespan);
///   `rejected` falls out of conservation (`requests − completed` — every
///   arrival terminates as exactly one of the two once the heap drains).
/// - `decision` with `ctx == "migration"` and an `assign` verdict →
///   `migrated`.
/// - `mg_slice` → supply splits, idle/dynamic carbon shares, the
///   stored-carbon ledger; `idle_slice` → uptime and the grid-only idle
///   floor; `batch_formed` → per-class batch counts.
/// - `wan_hop` → per-site shipped counts and transfer energy/carbon,
///   billed at the origin site exactly as the engine attributes them;
///   arrivals also carry their class, so per-class `rejected` falls out of
///   the same conservation identity (`arrived − completed`).
pub struct ReplayState {
    meta: Option<Meta>,
    events: u64,
    requests: u64,
    completed: u64,
    deferred: u64,
    migrated: u64,
    deadline_missed: u64,
    makespan_s: f64,
    energy_total_j: f64,
    carbon_dynamic_g: f64,
    latency_ms: Vec<f64>,
    wait_ms: Vec<f64>,
    nodes: Vec<NodeAcc>,
    classes: Vec<ClassAcc>,
    sites: Vec<SiteAcc>,
}

impl Default for ReplayState {
    fn default() -> Self {
        ReplayState::new()
    }
}

fn num(ev: &Json, key: &str) -> Result<f64, String> {
    ev.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing number field {key:?}"))
}

fn text<'j>(ev: &'j Json, key: &str) -> Result<&'j str, String> {
    ev.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("missing string field {key:?}"))
}

fn flag(ev: &Json, key: &str) -> Result<bool, String> {
    ev.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| format!("missing bool field {key:?}"))
}

impl ReplayState {
    pub fn new() -> ReplayState {
        ReplayState {
            meta: None,
            events: 0,
            requests: 0,
            completed: 0,
            deferred: 0,
            migrated: 0,
            deadline_missed: 0,
            makespan_s: 0.0,
            energy_total_j: 0.0,
            carbon_dynamic_g: 0.0,
            latency_ms: Vec::new(),
            wait_ms: Vec::new(),
            nodes: Vec::new(),
            classes: Vec::new(),
            sites: Vec::new(),
        }
    }

    /// Events folded so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Fold one parsed trace event into the ledgers.
    pub fn apply(&mut self, ev: &Json) -> Result<(), String> {
        let label = text(ev, "kind")?;
        let kind = EventKind::parse(label)
            .ok_or_else(|| format!("unknown event kind {label:?}"))?;
        self.events += 1;
        if kind == EventKind::RunMeta {
            return self.apply_meta(ev);
        }
        if self.meta.is_none() {
            return Err(format!(
                "event {label:?} before the run_meta header — replay needs a full trace \
                 (--trace-filter all)"
            ));
        }
        match kind {
            EventKind::Arrival => {
                self.requests += 1;
                // Legacy traces carry no class on arrivals; class 0
                // absorbs them, mirroring the engine's default class.
                let class = ev.get("class").and_then(Json::as_usize).unwrap_or(0);
                if class >= self.classes.len() {
                    return Err(format!(
                        "arrival class {class} out of range ({} declared in run_meta)",
                        self.classes.len()
                    ));
                }
                self.classes[class].arrived += 1;
            }
            EventKind::Decision => {
                if text(ev, "ctx")? == "migration" && text(ev, "verdict")? == "assign" {
                    self.migrated += 1;
                }
            }
            EventKind::Dispatch => {
                let g = self.node_idx(text(ev, "node")?)?;
                let qd = num(ev, "queue_delay_est_ms")?;
                self.nodes[g].queue_delay_ms.push(qd);
            }
            EventKind::DeferRelease => self.deferred += 1,
            EventKind::Completion => self.apply_completion(ev)?,
            EventKind::Churn | EventKind::Alert => {}
            EventKind::MicrogridSlice => self.apply_mg_slice(ev)?,
            EventKind::IdleSlice => {
                let g = self.node_idx(text(ev, "node")?)?;
                let dt = num(ev, "t1_s")? - num(ev, "t0_s")?;
                let n = &mut self.nodes[g];
                n.uptime_s += dt;
                n.idle_energy_j += num(ev, "energy_j")?;
                n.idle_carbon_g += num(ev, "carbon_g")?;
            }
            EventKind::BatchFormed => {
                let class = self.class_idx(ev)?;
                self.classes[class].batches += 1;
            }
            EventKind::WanHop => {
                let from = self.site_idx(text(ev, "from")?)?;
                let to = self.site_idx(text(ev, "to")?)?;
                self.sites[from].shipped_out += 1;
                self.sites[to].shipped_in += 1;
                // Transfer energy/carbon bill at the origin, exactly as
                // the engine attributes them to the shipping site's row.
                self.sites[from].wan_energy_j += num(ev, "energy_j")?;
                self.sites[from].wan_carbon_g += num(ev, "carbon_g")?;
            }
            EventKind::RunMeta => unreachable!("handled above"),
        }
        Ok(())
    }

    fn apply_meta(&mut self, ev: &Json) -> Result<(), String> {
        if self.meta.is_some() {
            return Err("second run_meta header — one trace per file".into());
        }
        // Geographic metadata is optional: flat fleets carry no sites
        // array, no router, and no per-node site tags.
        let mut site_names = Vec::new();
        let mut site_index = BTreeMap::new();
        if let Some(sites) = ev.get("sites").and_then(Json::as_arr) {
            for s in sites {
                let name =
                    s.as_str().ok_or("run_meta sites must be an array of strings")?;
                site_index.insert(name.to_string(), site_names.len());
                site_names.push(name.to_string());
            }
        }
        let router =
            ev.get("router").and_then(Json::as_str).unwrap_or_default().to_string();
        let nodes = ev.get("nodes").and_then(Json::as_arr).ok_or("run_meta missing nodes")?;
        let mut node_names = Vec::with_capacity(nodes.len());
        let mut node_microgrid = Vec::with_capacity(nodes.len());
        let mut node_index = BTreeMap::new();
        let mut site_of = Vec::with_capacity(nodes.len());
        for n in nodes {
            let name = text(n, "node")?;
            node_index.insert(name.to_string(), node_names.len());
            node_names.push(name.to_string());
            node_microgrid.push(flag(n, "microgrid")?);
            let site = n.get("site").and_then(Json::as_usize).unwrap_or(0);
            if !site_names.is_empty() && site >= site_names.len() {
                return Err(format!(
                    "node {name:?} site {site} out of range ({} declared)",
                    site_names.len()
                ));
            }
            site_of.push(site);
        }
        let classes =
            ev.get("classes").and_then(Json::as_arr).ok_or("run_meta missing classes")?;
        let mut class_names = Vec::with_capacity(classes.len());
        let mut class_slo_s = Vec::with_capacity(classes.len());
        for c in classes {
            class_names.push(text(c, "class")?.to_string());
            // Infinite SLOs serialise as null (fnum convention).
            class_slo_s.push(c.get("slo_s").and_then(Json::as_f64).unwrap_or(f64::INFINITY));
        }
        self.nodes = vec![NodeAcc::default(); node_names.len()];
        // Class ledgers exist even for legacy single-class runs (class 0
        // absorbs everything), mirroring the engine; reported only when
        // the meta declared a mix.
        self.classes = vec![ClassAcc::default(); class_names.len().max(1)];
        self.sites = vec![SiteAcc::default(); site_names.len()];
        self.meta = Some(Meta {
            scenario: text(ev, "scenario")?.to_string(),
            scheduler: text(ev, "scheduler")?.to_string(),
            seed: num(ev, "seed")? as u64,
            requests_declared: num(ev, "requests")? as u64,
            node_names,
            node_microgrid,
            node_index,
            class_names,
            class_slo_s,
            site_names,
            site_of,
            site_index,
            router,
        });
        Ok(())
    }

    fn apply_completion(&mut self, ev: &Json) -> Result<(), String> {
        let g = self.node_idx(text(ev, "node")?)?;
        let class = self.class_idx(ev)?;
        let t_s = num(ev, "t_s")?;
        let service_ms = num(ev, "service_ms")?;
        let latency_ms = num(ev, "latency_ms")?;
        let energy_j = num(ev, "energy_j")?;
        let carbon_g = num(ev, "carbon_g")?;
        let n = &mut self.nodes[g];
        n.tasks += 1;
        n.busy_ms += service_ms;
        // Per-completion kWh conversion, exactly as the engine's node
        // ledger does it (the fleet total converts the joule sum once).
        n.energy_dynamic_kwh += joules_to_kwh(energy_j);
        n.carbon_dynamic_g += carbon_g;
        self.energy_total_j += energy_j;
        self.carbon_dynamic_g += carbon_g;
        self.completed += 1;
        self.latency_ms.push(latency_ms);
        // The engine samples wait at service start: latency − service.
        self.wait_ms.push(latency_ms - service_ms);
        if flag(ev, "missed")? {
            self.deadline_missed += 1;
        }
        let c = &mut self.classes[class];
        c.completed += 1;
        c.latency_ms.push(latency_ms);
        c.energy_j += energy_j;
        c.carbon_g += carbon_g;
        if flag(ev, "slo_missed")? {
            c.slo_missed += 1;
        }
        self.makespan_s = self.makespan_s.max(t_s);
        Ok(())
    }

    fn apply_mg_slice(&mut self, ev: &Json) -> Result<(), String> {
        let g = self.node_idx(text(ev, "node")?)?;
        let carbon_g = num(ev, "carbon_g")?;
        let idle_g = num(ev, "idle_g")?;
        let n = &mut self.nodes[g];
        n.pv_j += num(ev, "pv_j")?;
        n.battery_j += num(ev, "battery_j")?;
        n.grid_j += num(ev, "grid_j")?;
        n.grid_charge_j += num(ev, "grid_charge_j")?;
        n.charged_g += num(ev, "charge_g")?;
        n.battery_g += num(ev, "battery_g")?;
        n.stored_g = num(ev, "stored_g")?;
        // The slice's carbon splits idle/dynamic exactly as the engine
        // attributed it.
        n.idle_carbon_g += idle_g;
        let dyn_g = carbon_g - idle_g;
        n.carbon_dynamic_g += dyn_g;
        self.carbon_dynamic_g += dyn_g;
        Ok(())
    }

    fn node_idx(&self, name: &str) -> Result<usize, String> {
        self.meta
            .as_ref()
            .and_then(|m| m.node_index.get(name).copied())
            .ok_or_else(|| format!("node {name:?} not in the run_meta roster"))
    }

    fn site_idx(&self, name: &str) -> Result<usize, String> {
        self.meta
            .as_ref()
            .and_then(|m| m.site_index.get(name).copied())
            .ok_or_else(|| format!("site {name:?} not in the run_meta roster"))
    }

    fn class_idx(&self, ev: &Json) -> Result<usize, String> {
        let class = ev
            .get("class")
            .and_then(Json::as_usize)
            .ok_or("missing non-negative integer field \"class\"")?;
        if class >= self.classes.len() {
            return Err(format!(
                "class {class} out of range ({} declared in run_meta)",
                self.classes.len()
            ));
        }
        Ok(class)
    }

    /// Assemble the reconstructed [`SimReport`]. SoC timelines/projections
    /// and monitor summaries are not reconstructible from the stream and
    /// stay empty ([`verify`] skips them).
    pub fn finish(self) -> Result<SimReport, String> {
        let meta = self.meta.ok_or("trace has no run_meta header (--trace-filter all)")?;
        if self.completed > self.requests {
            return Err(format!(
                "{} completions for {} arrivals — trace is truncated or mixed",
                self.completed, self.requests
            ));
        }
        let nodes: Vec<NodeUsage> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| {
                let idle_kwh = joules_to_kwh(n.idle_energy_j);
                let microgrid = meta.node_microgrid[i];
                let (pv, battery, grid) = if microgrid {
                    (joules_to_kwh(n.pv_j), joules_to_kwh(n.battery_j), joules_to_kwh(n.grid_j))
                } else {
                    (0.0, 0.0, n.energy_dynamic_kwh + idle_kwh)
                };
                let qd = summary_or_zero(&n.queue_delay_ms);
                NodeUsage {
                    name: meta.node_names[i].clone(),
                    tasks: n.tasks,
                    busy_ms: n.busy_ms,
                    uptime_s: n.uptime_s,
                    queue_delay_ms_p50: qd.p50,
                    queue_delay_ms_p99: qd.p99,
                    queue_delay_ms_max: qd.max,
                    energy_dynamic_kwh: n.energy_dynamic_kwh,
                    energy_idle_kwh: idle_kwh,
                    carbon_dynamic_g: n.carbon_dynamic_g,
                    carbon_idle_g: n.idle_carbon_g,
                    microgrid,
                    energy_pv_kwh: pv,
                    energy_battery_kwh: battery,
                    energy_grid_kwh: grid,
                    energy_grid_charge_kwh: joules_to_kwh(n.grid_charge_j),
                    carbon_charged_g: n.charged_g,
                    carbon_battery_g: n.battery_g,
                    carbon_stored_g: n.stored_g,
                    soc_timeline: Vec::new(),
                    soc_projection: Vec::new(),
                }
            })
            .collect();
        let (energy_pv_kwh_total, energy_battery_kwh_total, energy_grid_kwh_total) =
            sum_supply(&nodes);
        let (
            energy_grid_charge_kwh_total,
            carbon_charged_g_total,
            carbon_battery_g_total,
            carbon_stored_g_total,
        ) = sum_storage(&nodes);
        let classes: Vec<ClassUsage> = meta
            .class_names
            .iter()
            .enumerate()
            .map(|(c, name)| {
                let acc = &self.classes[c];
                ClassUsage {
                    name: name.clone(),
                    completed: acc.completed,
                    // Per-class conservation: class membership is fixed at
                    // arrival, so sheds + scheduler rejects are whatever
                    // of the class's arrivals never completed.
                    rejected: acc.arrived.saturating_sub(acc.completed),
                    slo_s: meta.class_slo_s[c],
                    slo_missed: acc.slo_missed,
                    batches: acc.batches,
                    latency_ms: summary_or_zero(&acc.latency_ms),
                    energy_dynamic_kwh: joules_to_kwh(acc.energy_j),
                    carbon_dynamic_g: acc.carbon_g,
                    carbon_per_req_g: if acc.completed > 0 {
                        acc.carbon_g / acc.completed as f64
                    } else {
                        0.0
                    },
                }
            })
            .collect();
        let energy_idle_kwh_total =
            joules_to_kwh(self.nodes.iter().map(|n| n.idle_energy_j).sum::<f64>());
        let carbon_idle_g_total: f64 = self.nodes.iter().map(|n| n.idle_carbon_g).sum();
        let energy_dynamic_kwh_total = joules_to_kwh(self.energy_total_j);
        // Per-site rows re-derive the engine's partition: member nodes'
        // dynamic + idle sums from the node ledgers, WAN transfer from the
        // wan_hop ledger billed at the origin site.
        let sites: Vec<SiteUsage> = meta
            .site_names
            .iter()
            .enumerate()
            .map(|(s, name)| {
                let members: Vec<usize> =
                    (0..self.nodes.len()).filter(|&g| meta.site_of[g] == s).collect();
                let completed: u64 = members.iter().map(|&g| self.nodes[g].tasks).sum();
                let dyn_kwh: f64 =
                    members.iter().map(|&g| self.nodes[g].energy_dynamic_kwh).sum();
                let idle_kwh = joules_to_kwh(
                    members.iter().map(|&g| self.nodes[g].idle_energy_j).sum::<f64>(),
                );
                let dyn_g: f64 = members.iter().map(|&g| self.nodes[g].carbon_dynamic_g).sum();
                let idle_g: f64 = members.iter().map(|&g| self.nodes[g].idle_carbon_g).sum();
                let acc = &self.sites[s];
                let wan_kwh = joules_to_kwh(acc.wan_energy_j);
                let wan_g = acc.wan_carbon_g;
                let carbon_g = dyn_g + idle_g + wan_g;
                SiteUsage {
                    name: name.clone(),
                    nodes: members.len(),
                    completed,
                    shipped_out: acc.shipped_out,
                    shipped_in: acc.shipped_in,
                    energy_kwh: dyn_kwh + idle_kwh,
                    energy_wan_kwh: wan_kwh,
                    carbon_g,
                    carbon_wan_g: wan_g,
                    carbon_per_req_g: if completed > 0 {
                        carbon_g / completed as f64
                    } else {
                        0.0
                    },
                }
            })
            .collect();
        let energy_wan_kwh_total: f64 = sites.iter().map(|r| r.energy_wan_kwh).sum();
        let carbon_wan_g_total: f64 = sites.iter().map(|r| r.carbon_wan_g).sum();
        Ok(SimReport {
            scenario: meta.scenario,
            scheduler: meta.scheduler,
            seed: meta.seed,
            requests: self.requests,
            completed: self.completed,
            // Conservation: the heap drains fully, so every arrival ends
            // as exactly one completion or rejection.
            rejected: self.requests - self.completed,
            migrated: self.migrated,
            deferred: self.deferred,
            deadline_missed: self.deadline_missed,
            makespan_s: self.makespan_s,
            throughput_rps: if self.makespan_s > 0.0 {
                self.completed as f64 / self.makespan_s
            } else {
                0.0
            },
            latency_ms: summary_or_zero(&self.latency_ms),
            wait_ms: summary_or_zero(&self.wait_ms),
            energy_kwh_total: energy_dynamic_kwh_total
                + energy_idle_kwh_total
                + energy_wan_kwh_total,
            energy_dynamic_kwh_total,
            energy_idle_kwh_total,
            energy_wan_kwh_total,
            energy_pv_kwh_total,
            energy_battery_kwh_total,
            energy_grid_kwh_total,
            energy_grid_charge_kwh_total,
            carbon_charged_g_total,
            carbon_battery_g_total,
            carbon_stored_g_total,
            carbon_g_total: self.carbon_dynamic_g + carbon_idle_g_total + carbon_wan_g_total,
            carbon_dynamic_g_total: self.carbon_dynamic_g,
            carbon_idle_g_total,
            carbon_wan_g_total,
            carbon_per_req_g: if self.completed > 0 {
                (self.carbon_dynamic_g + carbon_idle_g_total + carbon_wan_g_total)
                    / self.completed as f64
            } else {
                0.0
            },
            router: meta.router,
            wan_shipped: self.sites.iter().map(|s| s.shipped_out).sum(),
            classes,
            sites,
            nodes,
            monitors: Vec::new(),
        })
    }
}

/// Replay an entire trace from `input` to a reconstructed [`SimReport`]
/// plus the event count folded.
pub fn replay_report<R: BufRead>(input: R) -> Result<(SimReport, u64), String> {
    let mut reader = FirehoseReader::new(input);
    let mut state = ReplayState::new();
    while let Some(ev) = reader.next_event()? {
        state.apply(&ev).map_err(|e| format!("trace line {}: {e}", reader.line()))?;
    }
    let events = state.events();
    Ok((state.finish()?, events))
}

// -- verification -----------------------------------------------------------

fn close(a: f64, b: f64) -> bool {
    let d = (a - b).abs();
    d <= VERIFY_ABS_TOL || d <= VERIFY_REL_TOL * a.abs().max(b.abs())
}

struct Verifier {
    mismatches: Vec<String>,
}

impl Verifier {
    fn int(&mut self, field: &str, replayed: u64, live: u64) {
        if replayed != live {
            self.mismatches.push(format!("{field}: replayed {replayed} != live {live}"));
        }
    }

    fn float(&mut self, field: &str, replayed: f64, live: f64) {
        if !close(replayed, live) && !(replayed.is_nan() && live.is_nan()) {
            self.mismatches.push(format!("{field}: replayed {replayed} != live {live}"));
        }
    }

    fn str(&mut self, field: &str, replayed: &str, live: &str) {
        if replayed != live {
            self.mismatches
                .push(format!("{field}: replayed {replayed:?} != live {live:?}"));
        }
    }

    fn summary(
        &mut self,
        field: &str,
        replayed: &crate::util::stats::Summary,
        live: &crate::util::stats::Summary,
    ) {
        self.int(&format!("{field}.n"), replayed.n as u64, live.n as u64);
        self.float(&format!("{field}.mean"), replayed.mean, live.mean);
        self.float(&format!("{field}.p50"), replayed.p50, live.p50);
        self.float(&format!("{field}.p95"), replayed.p95, live.p95);
        self.float(&format!("{field}.p99"), replayed.p99, live.p99);
        self.float(&format!("{field}.max"), replayed.max, live.max);
    }
}

/// Confront a replayed report with the live one: integer counters exactly,
/// floats within [`VERIFY_REL_TOL`]/[`VERIFY_ABS_TOL`]. Returns one line
/// per mismatching field — empty means the trace audits clean. SoC
/// timelines/projections and monitor summaries are live-only (not on the
/// event stream) and are skipped.
pub fn verify(replayed: &SimReport, live: &SimReport) -> Vec<String> {
    let mut v = Verifier { mismatches: Vec::new() };
    v.str("scenario", &replayed.scenario, &live.scenario);
    v.str("scheduler", &replayed.scheduler, &live.scheduler);
    v.int("seed", replayed.seed, live.seed);
    v.int("requests", replayed.requests, live.requests);
    v.int("completed", replayed.completed, live.completed);
    v.int("rejected", replayed.rejected, live.rejected);
    v.int("migrated", replayed.migrated, live.migrated);
    v.int("deferred", replayed.deferred, live.deferred);
    v.int("deadline_missed", replayed.deadline_missed, live.deadline_missed);
    v.float("makespan_s", replayed.makespan_s, live.makespan_s);
    v.float("throughput_rps", replayed.throughput_rps, live.throughput_rps);
    v.summary("latency_ms", &replayed.latency_ms, &live.latency_ms);
    v.summary("wait_ms", &replayed.wait_ms, &live.wait_ms);
    v.float("energy_kwh_total", replayed.energy_kwh_total, live.energy_kwh_total);
    v.float(
        "energy_dynamic_kwh_total",
        replayed.energy_dynamic_kwh_total,
        live.energy_dynamic_kwh_total,
    );
    v.float("energy_idle_kwh_total", replayed.energy_idle_kwh_total, live.energy_idle_kwh_total);
    v.float("energy_wan_kwh_total", replayed.energy_wan_kwh_total, live.energy_wan_kwh_total);
    v.float("energy_pv_kwh_total", replayed.energy_pv_kwh_total, live.energy_pv_kwh_total);
    v.float(
        "energy_battery_kwh_total",
        replayed.energy_battery_kwh_total,
        live.energy_battery_kwh_total,
    );
    v.float("energy_grid_kwh_total", replayed.energy_grid_kwh_total, live.energy_grid_kwh_total);
    v.float(
        "energy_grid_charge_kwh_total",
        replayed.energy_grid_charge_kwh_total,
        live.energy_grid_charge_kwh_total,
    );
    v.float("carbon_charged_g_total", replayed.carbon_charged_g_total, live.carbon_charged_g_total);
    v.float("carbon_battery_g_total", replayed.carbon_battery_g_total, live.carbon_battery_g_total);
    v.float("carbon_stored_g_total", replayed.carbon_stored_g_total, live.carbon_stored_g_total);
    v.float("carbon_g_total", replayed.carbon_g_total, live.carbon_g_total);
    v.float("carbon_dynamic_g_total", replayed.carbon_dynamic_g_total, live.carbon_dynamic_g_total);
    v.float("carbon_idle_g_total", replayed.carbon_idle_g_total, live.carbon_idle_g_total);
    v.float("carbon_wan_g_total", replayed.carbon_wan_g_total, live.carbon_wan_g_total);
    v.float("carbon_per_req_g", replayed.carbon_per_req_g, live.carbon_per_req_g);
    v.str("router", &replayed.router, &live.router);
    v.int("wan_shipped", replayed.wan_shipped, live.wan_shipped);
    v.int("sites.len", replayed.sites.len() as u64, live.sites.len() as u64);
    for (r, l) in replayed.sites.iter().zip(&live.sites) {
        let p = format!("site[{}]", l.name);
        v.str(&format!("{p}.name"), &r.name, &l.name);
        v.int(&format!("{p}.nodes"), r.nodes as u64, l.nodes as u64);
        v.int(&format!("{p}.completed"), r.completed, l.completed);
        v.int(&format!("{p}.shipped_out"), r.shipped_out, l.shipped_out);
        v.int(&format!("{p}.shipped_in"), r.shipped_in, l.shipped_in);
        v.float(&format!("{p}.energy_kwh"), r.energy_kwh, l.energy_kwh);
        v.float(&format!("{p}.energy_wan_kwh"), r.energy_wan_kwh, l.energy_wan_kwh);
        v.float(&format!("{p}.carbon_g"), r.carbon_g, l.carbon_g);
        v.float(&format!("{p}.carbon_wan_g"), r.carbon_wan_g, l.carbon_wan_g);
        v.float(&format!("{p}.carbon_per_req_g"), r.carbon_per_req_g, l.carbon_per_req_g);
    }
    v.int("nodes.len", replayed.nodes.len() as u64, live.nodes.len() as u64);
    for (r, l) in replayed.nodes.iter().zip(&live.nodes) {
        let p = format!("node[{}]", l.name);
        v.str(&format!("{p}.name"), &r.name, &l.name);
        v.int(&format!("{p}.tasks"), r.tasks, l.tasks);
        v.float(&format!("{p}.busy_ms"), r.busy_ms, l.busy_ms);
        v.float(&format!("{p}.uptime_s"), r.uptime_s, l.uptime_s);
        v.float(&format!("{p}.queue_delay_ms_p50"), r.queue_delay_ms_p50, l.queue_delay_ms_p50);
        v.float(&format!("{p}.queue_delay_ms_p99"), r.queue_delay_ms_p99, l.queue_delay_ms_p99);
        v.float(&format!("{p}.queue_delay_ms_max"), r.queue_delay_ms_max, l.queue_delay_ms_max);
        v.float(&format!("{p}.energy_dynamic_kwh"), r.energy_dynamic_kwh, l.energy_dynamic_kwh);
        v.float(&format!("{p}.energy_idle_kwh"), r.energy_idle_kwh, l.energy_idle_kwh);
        v.float(&format!("{p}.carbon_dynamic_g"), r.carbon_dynamic_g, l.carbon_dynamic_g);
        v.float(&format!("{p}.carbon_idle_g"), r.carbon_idle_g, l.carbon_idle_g);
        v.int(&format!("{p}.microgrid"), r.microgrid as u64, l.microgrid as u64);
        v.float(&format!("{p}.energy_pv_kwh"), r.energy_pv_kwh, l.energy_pv_kwh);
        v.float(&format!("{p}.energy_battery_kwh"), r.energy_battery_kwh, l.energy_battery_kwh);
        v.float(&format!("{p}.energy_grid_kwh"), r.energy_grid_kwh, l.energy_grid_kwh);
        v.float(
            &format!("{p}.energy_grid_charge_kwh"),
            r.energy_grid_charge_kwh,
            l.energy_grid_charge_kwh,
        );
        v.float(&format!("{p}.carbon_charged_g"), r.carbon_charged_g, l.carbon_charged_g);
        v.float(&format!("{p}.carbon_battery_g"), r.carbon_battery_g, l.carbon_battery_g);
        v.float(&format!("{p}.carbon_stored_g"), r.carbon_stored_g, l.carbon_stored_g);
    }
    v.int("classes.len", replayed.classes.len() as u64, live.classes.len() as u64);
    for (r, l) in replayed.classes.iter().zip(&live.classes) {
        let p = format!("class[{}]", l.name);
        v.str(&format!("{p}.name"), &r.name, &l.name);
        v.int(&format!("{p}.completed"), r.completed, l.completed);
        v.int(&format!("{p}.rejected"), r.rejected, l.rejected);
        v.int(&format!("{p}.slo_missed"), r.slo_missed, l.slo_missed);
        v.int(&format!("{p}.batches"), r.batches, l.batches);
        if r.slo_s.is_finite() || l.slo_s.is_finite() {
            v.float(&format!("{p}.slo_s"), r.slo_s, l.slo_s);
        }
        v.summary(&format!("{p}.latency_ms"), &r.latency_ms, &l.latency_ms);
        v.float(&format!("{p}.energy_dynamic_kwh"), r.energy_dynamic_kwh, l.energy_dynamic_kwh);
        v.float(&format!("{p}.carbon_dynamic_g"), r.carbon_dynamic_g, l.carbon_dynamic_g);
        v.float(&format!("{p}.carbon_per_req_g"), r.carbon_per_req_g, l.carbon_per_req_g);
    }
    v.mismatches
}

// -- trace diff -------------------------------------------------------------

/// The first point where two traces stop agreeing.
#[derive(Debug, Clone, PartialEq)]
pub struct Divergence {
    /// 1-indexed line number (same in both traces — diff stops at the
    /// first divergent line).
    pub line: u64,
    /// Event kind at the divergence (trace A's, or B's if A ended first).
    pub kind: String,
    /// Virtual time of the divergent event (`t_s`/`t0_s`; 0 for the
    /// run_meta header).
    pub t_s: f64,
    /// Dotted path of the first differing field, in sorted-key order —
    /// `"<end-of-trace>"` when one trace is a prefix of the other.
    pub field: String,
    /// The two values at `field`, rendered as JSON (`"<missing>"` /
    /// `"<end-of-trace>"` when absent).
    pub a: String,
    pub b: String,
}

impl Divergence {
    /// One-line rendering: `line 84371: completion @ t=53211.4s diverges
    /// at energy_j: 10.2 != 10.9`.
    pub fn render(&self) -> String {
        format!(
            "line {}: {} @ t={}s diverges at {}: {} != {}",
            self.line, self.kind, self.t_s, self.field, self.a, self.b
        )
    }
}

fn event_kind(ev: &Json) -> String {
    ev.get("kind").and_then(Json::as_str).unwrap_or("?").to_string()
}

fn event_t(ev: &Json) -> f64 {
    ev.get("t_s").or_else(|| ev.get("t0_s")).and_then(Json::as_f64).unwrap_or(0.0)
}

/// First differing field between two JSON values, as `(path, a, b)`.
/// Objects walk keys in sorted order (BTreeMap) and arrays by index, so
/// the answer is order-stable: the same pair of traces always names the
/// same field.
fn first_field_diff(path: &str, a: &Json, b: &Json) -> Option<(String, String, String)> {
    match (a, b) {
        (Json::Obj(oa), Json::Obj(ob)) => {
            for (k, va) in oa {
                let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                match ob.get(k) {
                    Some(vb) => {
                        if let Some(d) = first_field_diff(&sub, va, vb) {
                            return Some(d);
                        }
                    }
                    None => return Some((sub, va.to_string(), "<missing>".into())),
                }
            }
            for (k, vb) in ob {
                if !oa.contains_key(k) {
                    let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                    return Some((sub, "<missing>".into(), vb.to_string()));
                }
            }
            None
        }
        (Json::Arr(xa), Json::Arr(xb)) => {
            for (i, (va, vb)) in xa.iter().zip(xb).enumerate() {
                if let Some(d) = first_field_diff(&format!("{path}[{i}]"), va, vb) {
                    return Some(d);
                }
            }
            if xa.len() != xb.len() {
                return Some((
                    format!("{path}.len"),
                    xa.len().to_string(),
                    xb.len().to_string(),
                ));
            }
            None
        }
        _ if a == b => None,
        _ => Some((path.to_string(), a.to_string(), b.to_string())),
    }
}

/// Walk two traces in lockstep and report the first divergent event, or
/// `None` when they match line for line. Order-stable by construction:
/// lines in file order, fields in sorted-key order.
pub fn diff<A: BufRead, B: BufRead>(a: A, b: B) -> Result<Option<Divergence>, String> {
    let mut ra = FirehoseReader::new(a);
    let mut rb = FirehoseReader::new(b);
    loop {
        let ea = ra.next_event().map_err(|e| format!("trace A: {e}"))?;
        let eb = rb.next_event().map_err(|e| format!("trace B: {e}"))?;
        match (ea, eb) {
            (None, None) => return Ok(None),
            (Some(ev), None) => {
                return Ok(Some(Divergence {
                    line: ra.line(),
                    kind: event_kind(&ev),
                    t_s: event_t(&ev),
                    field: "<end-of-trace>".into(),
                    a: event_kind(&ev),
                    b: "<end-of-trace>".into(),
                }))
            }
            (None, Some(ev)) => {
                return Ok(Some(Divergence {
                    line: rb.line(),
                    kind: event_kind(&ev),
                    t_s: event_t(&ev),
                    field: "<end-of-trace>".into(),
                    a: "<end-of-trace>".into(),
                    b: event_kind(&ev),
                }))
            }
            (Some(ev_a), Some(ev_b)) => {
                if let Some((field, va, vb)) = first_field_diff("", &ev_a, &ev_b) {
                    return Ok(Some(Divergence {
                        line: ra.line(),
                        kind: event_kind(&ev_a),
                        t_s: event_t(&ev_a),
                        field,
                        a: va,
                        b: vb,
                    }));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{"kind":"run_meta","scenario":"unit","scheduler":"green","seed":7,"requests":2,"nodes":[{"node":"a","microgrid":false}],"classes":[]}"#;

    fn trace(lines: &[&str]) -> String {
        let mut s = String::new();
        for l in lines {
            s.push_str(l);
            s.push('\n');
        }
        s
    }

    #[test]
    fn reader_streams_and_skips_blank_lines() {
        let text = trace(&[META, "", r#"{"kind":"arrival","t_s":1,"deadline_s":null}"#]);
        let mut r = FirehoseReader::new(text.as_bytes());
        assert_eq!(r.next_event().unwrap().unwrap().get("kind").unwrap().as_str(), Some("run_meta"));
        assert_eq!(r.next_event().unwrap().unwrap().get("kind").unwrap().as_str(), Some("arrival"));
        assert_eq!(r.line(), 3);
        assert!(r.next_event().unwrap().is_none());
        let mut bad = FirehoseReader::new("not json\n".as_bytes());
        assert!(bad.next_event().unwrap_err().contains("line 1"));
    }

    #[test]
    fn replay_folds_a_tiny_trace_into_a_report() {
        let text = trace(&[
            META,
            r#"{"kind":"arrival","t_s":0.5,"deadline_s":null}"#,
            r#"{"kind":"dispatch","t_s":0.5,"arrival_s":0.5,"node":"a","queue_delay_est_ms":4}"#,
            r#"{"kind":"completion","t_s":0.7,"arrival_s":0.5,"node":"a","class":0,"service_ms":200,"latency_ms":200,"energy_j":9,"carbon_g":0.02,"missed":false,"slo_missed":false}"#,
            r#"{"kind":"arrival","t_s":1.0,"deadline_s":null}"#,
            r#"{"kind":"idle_slice","t0_s":0,"t1_s":0.7,"node":"a","energy_j":3.5,"carbon_g":0.001}"#,
        ]);
        let (report, events) = replay_report(text.as_bytes()).unwrap();
        assert_eq!(events, 6);
        assert_eq!(report.scenario, "unit");
        assert_eq!(report.scheduler, "green");
        assert_eq!(report.seed, 7);
        assert_eq!(report.requests, 2);
        assert_eq!(report.completed, 1);
        assert_eq!(report.rejected, 1, "conservation: the unfinished arrival was rejected");
        assert_eq!(report.makespan_s, 0.7);
        assert!(report.classes.is_empty(), "no mix declared, no class rows");
        let a = report.node("a").unwrap();
        assert_eq!(a.tasks, 1);
        assert_eq!(a.busy_ms, 200.0);
        assert!((a.uptime_s - 0.7).abs() < 1e-12);
        assert!((a.energy_dynamic_kwh - 9.0 / 3.6e6).abs() < 1e-18);
        assert!((report.energy_idle_kwh_total - 3.5 / 3.6e6).abs() < 1e-18);
        assert!((report.carbon_g_total - 0.021).abs() < 1e-12);
        assert_eq!(a.queue_delay_ms_max, 4.0);
        // Grid-only supply identity: everything came from the grid.
        assert!((a.energy_grid_kwh - (a.energy_dynamic_kwh + a.energy_idle_kwh)).abs() < 1e-18);
    }

    #[test]
    fn replay_folds_wan_hops_into_site_rows() {
        let meta = r#"{"kind":"run_meta","scenario":"unit","scheduler":"green","seed":7,"requests":1,"nodes":[{"node":"a","microgrid":false,"site":0},{"node":"b","microgrid":false,"site":1}],"classes":[],"sites":["eu","us"],"router":"deadline"}"#;
        let text = trace(&[
            meta,
            r#"{"kind":"arrival","t_s":0.5,"deadline_s":null,"class":0}"#,
            r#"{"kind":"wan_hop","t_s":0.5,"from":"eu","to":"us","latency_ms":120,"energy_j":0.008,"carbon_g":0.001}"#,
            r#"{"kind":"completion","t_s":0.7,"arrival_s":0.5,"node":"b","class":0,"service_ms":200,"latency_ms":200,"energy_j":9,"carbon_g":0.02,"missed":false,"slo_missed":false}"#,
        ]);
        let (report, events) = replay_report(text.as_bytes()).unwrap();
        assert_eq!(events, 4);
        assert_eq!(report.router, "deadline");
        assert_eq!(report.wan_shipped, 1);
        assert_eq!(report.sites.len(), 2);
        let eu = &report.sites[0];
        assert_eq!((eu.shipped_out, eu.shipped_in), (1, 0));
        assert!((eu.energy_wan_kwh - 0.008 / 3.6e6).abs() < 1e-18);
        assert!((eu.carbon_wan_g - 0.001).abs() < 1e-15);
        let us = &report.sites[1];
        assert_eq!((us.shipped_out, us.shipped_in), (0, 1));
        assert_eq!(us.completed, 1);
        // The transfer joins the fleet totals through the origin row.
        assert!((report.carbon_g_total - 0.021).abs() < 1e-12);
        assert!((report.energy_kwh_total - (9.0 + 0.008) / 3.6e6).abs() < 1e-18);
        assert!(verify(&report, &report).is_empty());
        let mut drifted = report.clone();
        drifted.sites[0].shipped_out = 9;
        drifted.wan_shipped = 9;
        let problems = verify(&report, &drifted);
        assert!(problems.iter().any(|p| p.starts_with("site[eu].shipped_out")), "{problems:?}");
        assert!(problems.iter().any(|p| p.starts_with("wan_shipped")), "{problems:?}");
    }

    #[test]
    fn replay_requires_the_header() {
        let text = trace(&[r#"{"kind":"arrival","t_s":1,"deadline_s":null}"#]);
        let err = replay_report(text.as_bytes()).unwrap_err();
        assert!(err.contains("run_meta"), "{err}");
        // And an empty trace fails at finish.
        assert!(replay_report("".as_bytes()).unwrap_err().contains("run_meta"));
    }

    #[test]
    fn verify_reports_nothing_for_identical_reports_and_names_drift() {
        let (report, _) = replay_report(trace(&[META]).as_bytes()).unwrap();
        assert!(verify(&report, &report).is_empty());
        let mut drifted = report.clone();
        drifted.completed = 5;
        drifted.carbon_g_total += 1.0;
        let problems = verify(&report, &drifted);
        assert!(problems.iter().any(|p| p.starts_with("completed:")), "{problems:?}");
        assert!(problems.iter().any(|p| p.starts_with("carbon_g_total:")), "{problems:?}");
    }

    #[test]
    fn verify_tolerates_float_noise_but_not_integer_drift() {
        let (report, _) = replay_report(trace(&[META]).as_bytes()).unwrap();
        let mut noisy = report.clone();
        noisy.makespan_s += 1e-12;
        noisy.carbon_g_total *= 1.0 + 1e-9;
        assert!(verify(&report, &noisy).is_empty(), "sub-tolerance float noise must pass");
        let mut off = report.clone();
        off.requests += 1;
        assert_eq!(verify(&report, &off).len(), 2, "requests and the rejected identity drift");
    }

    #[test]
    fn diff_finds_nothing_between_identical_traces() {
        let text = trace(&[META, r#"{"kind":"arrival","t_s":1,"deadline_s":null}"#]);
        assert_eq!(diff(text.as_bytes(), text.as_bytes()).unwrap(), None);
    }

    #[test]
    fn diff_names_the_first_divergent_field_and_is_order_stable() {
        let a = trace(&[
            META,
            r#"{"kind":"arrival","t_s":1,"deadline_s":null}"#,
            r#"{"kind":"completion","t_s":2,"arrival_s":1,"node":"a","class":0,"service_ms":100,"latency_ms":1000,"energy_j":5,"carbon_g":0.4,"missed":false,"slo_missed":false}"#,
        ]);
        let b = trace(&[
            META,
            r#"{"kind":"arrival","t_s":1,"deadline_s":null}"#,
            r#"{"kind":"completion","t_s":2,"arrival_s":1,"node":"a","class":0,"service_ms":100,"latency_ms":1000,"energy_j":5.5,"carbon_g":0.5,"missed":false,"slo_missed":false}"#,
        ]);
        let d = diff(a.as_bytes(), b.as_bytes()).unwrap().expect("must diverge");
        assert_eq!(d.line, 3);
        assert_eq!(d.kind, "completion");
        assert_eq!(d.t_s, 2.0);
        // carbon_g sorts before energy_j: sorted-key order is the stable tie-break.
        assert_eq!(d.field, "carbon_g");
        assert_eq!((d.a.as_str(), d.b.as_str()), ("0.4", "0.5"));
        // Symmetric inputs produce the same location.
        let d2 = diff(b.as_bytes(), a.as_bytes()).unwrap().expect("must diverge");
        assert_eq!((d2.line, d2.field.as_str()), (3, "carbon_g"));
        assert!(d.render().contains("line 3: completion @ t=2s"), "{}", d.render());
    }

    #[test]
    fn diff_detects_truncation() {
        let a = trace(&[META, r#"{"kind":"arrival","t_s":1,"deadline_s":null}"#]);
        let b = trace(&[META]);
        let d = diff(a.as_bytes(), b.as_bytes()).unwrap().expect("must diverge");
        assert_eq!(d.line, 2);
        assert_eq!(d.field, "<end-of-trace>");
        assert_eq!(d.kind, "arrival");
    }
}
