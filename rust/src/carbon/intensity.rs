//! Grid carbon-intensity data: named regional scenarios (the paper's static
//! setup, Sec. IV-A1) and temporal traces (the paper's future-work
//! extension: "real-time carbon intensity integration").

use super::GramsPerKwh;

/// A named grid region with a representative static intensity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Region {
    pub name: &'static str,
    pub intensity: GramsPerKwh,
}

/// Representative regional intensities cited by the paper (Sec. II-E,
/// IV-A1): coal-heavy grids >800, China average ~530, hydro-rich <200,
/// renewable areas <100 gCO₂/kWh; plus the paper's three node scenarios.
pub const REGIONS: &[Region] = &[
    Region { name: "coal-north-china", intensity: 820.0 },
    Region { name: "node-high-scenario", intensity: 620.0 },
    Region { name: "china-average", intensity: 530.0 },
    Region { name: "global-average", intensity: 475.0 },
    Region { name: "node-green-scenario", intensity: 380.0 },
    Region { name: "yunnan-hydro", intensity: 180.0 },
    Region { name: "renewable-zone", intensity: 90.0 },
    Region { name: "nordic-hydro", intensity: 45.0 },
];

/// Look up a named region.
pub fn region(name: &str) -> Option<Region> {
    REGIONS.iter().copied().find(|r| r.name == name)
}

/// Time-varying carbon intensity. The paper uses `Static`; `Diurnal` and
/// `Trace` implement its future-work extension so schedulers can be
/// evaluated against temporal variation too (bench `ablation`).
#[derive(Debug, Clone)]
pub enum IntensityTrace {
    /// Constant intensity (the paper's experimental setting).
    Static(GramsPerKwh),
    /// Sinusoidal day curve: `mean + amp * sin(2π (t - phase)/period)`.
    /// Approximates solar-driven grids (low at noon, high at night).
    Diurnal { mean: GramsPerKwh, amplitude: f64, period_s: f64, phase_s: f64 },
    /// Piecewise-constant samples `(t_seconds, intensity)`, step-held.
    /// `at`/`integral` rely on the samples being time-sorted; build through
    /// [`IntensityTrace::from_samples`] (which normalizes) unless the data
    /// is sorted by construction.
    Trace(Vec<(f64, GramsPerKwh)>),
}

impl IntensityTrace {
    /// Validating `Trace` constructor: rejects non-finite times and
    /// non-finite or negative intensities, and sorts the samples by time
    /// (stable, so equal-time duplicates keep their input order and the
    /// last one wins under step-hold). `Trace::at` binary-searches and
    /// therefore silently mis-reads unsorted data — every external source
    /// (the CSV loader in particular) must come through here.
    pub fn from_samples(
        mut points: Vec<(f64, GramsPerKwh)>,
    ) -> Result<IntensityTrace, String> {
        for &(t, v) in &points {
            if !t.is_finite() {
                return Err(format!("non-finite sample time {t}"));
            }
            if !v.is_finite() || v < 0.0 {
                return Err(format!("bad intensity {v} at t = {t} (must be finite and >= 0)"));
            }
        }
        points.sort_by(|a, b| a.0.total_cmp(&b.0));
        Ok(IntensityTrace::Trace(points))
    }

    /// Parse a single-zone ElectricityMaps-style CSV (see
    /// [`zone_traces_from_csv`] for the format). Errors if the file holds
    /// more than one zone.
    pub fn from_csv(text: &str) -> Result<IntensityTrace, String> {
        let mut zones = zone_traces_from_csv(text)?;
        if zones.len() != 1 {
            return Err(format!(
                "expected a single zone, found {} — use zone_traces_from_csv",
                zones.len()
            ));
        }
        Ok(zones.remove(0).1)
    }

    /// Intensity at time `t` seconds from experiment start.
    pub fn at(&self, t: f64) -> GramsPerKwh {
        match self {
            IntensityTrace::Static(v) => *v,
            IntensityTrace::Diurnal { mean, amplitude, period_s, phase_s } => {
                let x = 2.0 * std::f64::consts::PI * (t - phase_s) / period_s;
                (mean + amplitude * x.sin()).max(0.0)
            }
            IntensityTrace::Trace(points) => {
                if points.is_empty() {
                    return 0.0;
                }
                // Step-hold: last sample with time <= t (or the first
                // sample when t precedes the trace). Samples are
                // time-sorted, so a binary search replaces the old O(n)
                // scan — this sits on the simulator's per-completion path.
                let idx = points.partition_point(|&(ts, _)| ts <= t);
                if idx == 0 {
                    points[0].1
                } else {
                    points[idx - 1].1
                }
            }
        }
    }

    /// Mean over `[0, horizon]` by midpoint sampling (reporting helper).
    pub fn mean(&self, horizon: f64, samples: usize) -> GramsPerKwh {
        debug_assert!(samples > 0);
        (0..samples)
            .map(|i| self.at((i as f64 + 0.5) * horizon / samples as f64))
            .sum::<f64>()
            / samples as f64
    }

    /// `∫ I(t) dt` over `[t0, t1]`, in (gCO₂/kWh)·s — the piecewise
    /// intensity-time integral the simulator prices idle-floor energy
    /// against (a single-instant sample would mis-charge any interval that
    /// spans a grid swing). Exact for `Static`, `Trace` (piecewise
    /// constant) and unclamped `Diurnal`; clamped diurnals (amplitude >
    /// mean) fall back to midpoint sampling at ~period/1024 resolution.
    pub fn integral(&self, t0: f64, t1: f64) -> f64 {
        // Demoted: the engine settles slices along a monotone virtual clock.
        debug_assert!(t1 >= t0, "integral bounds reversed: [{t0}, {t1}]");
        match self {
            IntensityTrace::Static(v) => v * (t1 - t0),
            IntensityTrace::Diurnal { mean, amplitude, period_s, phase_s } => {
                if amplitude.abs() <= *mean {
                    // Never clamps: exact antiderivative of mean + a·sin(ω(t−φ)).
                    let w = 2.0 * std::f64::consts::PI / period_s;
                    let prim = |t: f64| mean * t - amplitude / w * (w * (t - phase_s)).cos();
                    prim(t1) - prim(t0)
                } else {
                    let steps =
                        (((t1 - t0) / (period_s / 1024.0)).ceil() as usize).clamp(1, 1 << 22);
                    let h = (t1 - t0) / steps as f64;
                    (0..steps).map(|i| self.at(t0 + (i as f64 + 0.5) * h)).sum::<f64>() * h
                }
            }
            IntensityTrace::Trace(points) => {
                if points.is_empty() {
                    return 0.0;
                }
                // Walk the step-held segments overlapping [t0, t1].
                let mut total = 0.0;
                let mut t = t0;
                let mut idx = points.partition_point(|&(ts, _)| ts <= t0);
                loop {
                    let v = if idx == 0 { points[0].1 } else { points[idx - 1].1 };
                    let next = if idx < points.len() { points[idx].0.min(t1) } else { t1 };
                    total += v * (next - t);
                    if next >= t1 {
                        break;
                    }
                    t = next;
                    idx += 1;
                }
                total
            }
        }
    }
}

/// Parse an ElectricityMaps-style CSV export into per-zone intensity
/// traces, sorted by zone name. Accepted layouts (comma-separated, one
/// optional header row, `#` comments and blank lines ignored):
///
/// * `timestamp,intensity` — a single anonymous zone (named `"trace"`);
/// * `timestamp,zone,intensity` — multiple zones in one file.
///
/// `timestamp` is either plain seconds (used verbatim) or an ISO-8601 UTC
/// datetime `YYYY-MM-DDTHH:MM[:SS][Z]` (space separator also accepted);
/// datetime files are normalized so the earliest sample across all zones
/// sits at `t = 0`, keeping multi-zone traces mutually aligned. Rows may
/// arrive in any order — each zone goes through the validating
/// [`IntensityTrace::from_samples`] constructor.
pub fn zone_traces_from_csv(text: &str) -> Result<Vec<(String, IntensityTrace)>, String> {
    let mut zones: std::collections::BTreeMap<String, Vec<(f64, f64)>> = Default::default();
    let mut saw_datetime = false;
    let mut saw_numeric = false;
    let mut header_skipped = false;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let (ts_raw, zone, value_raw) = match fields.as_slice() {
            [t, v] => (*t, "trace", *v),
            [t, z, v] => (*t, *z, *v),
            _ => {
                return Err(format!(
                    "line {}: expected 2 or 3 columns, got {}",
                    lineno + 1,
                    fields.len()
                ))
            }
        };
        let t = if let Ok(secs) = ts_raw.parse::<f64>() {
            saw_numeric = true;
            secs
        } else if let Some(secs) = parse_datetime_s(ts_raw) {
            saw_datetime = true;
            secs
        } else if !header_skipped && zones.is_empty() && value_raw.parse::<f64>().is_err() {
            // A header row has a non-parsable timestamp AND a non-numeric
            // value column; a malformed first data row (bad timestamp,
            // numeric intensity) must be an error, not a silent skip.
            header_skipped = true;
            continue;
        } else {
            return Err(format!("line {}: bad timestamp {ts_raw:?}", lineno + 1));
        };
        if saw_numeric && saw_datetime {
            // Numeric stamps are kept verbatim while datetimes get
            // normalized to the file's earliest sample — mixing the two
            // would silently leave the datetime rows at epoch scale.
            return Err(format!(
                "line {}: mixing numeric-seconds and datetime timestamps",
                lineno + 1
            ));
        }
        let v: f64 = value_raw
            .parse()
            .map_err(|_| format!("line {}: bad intensity {value_raw:?}", lineno + 1))?;
        zones.entry(zone.to_string()).or_default().push((t, v));
    }
    if zones.is_empty() {
        return Err("no samples in CSV".into());
    }
    if saw_datetime {
        let t0 = zones.values().flatten().map(|&(t, _)| t).fold(f64::MAX, f64::min);
        for pts in zones.values_mut() {
            for p in pts.iter_mut() {
                p.0 -= t0;
            }
        }
    }
    zones
        .into_iter()
        .map(|(name, pts)| match IntensityTrace::from_samples(pts) {
            Ok(tr) => Ok((name, tr)),
            Err(e) => Err(format!("zone {name:?}: {e}")),
        })
        .collect()
}

/// `YYYY-MM-DDTHH:MM[:SS][Z]` (or with a space separator) → seconds since
/// the Unix epoch, UTC. Returns `None` on anything malformed.
fn parse_datetime_s(s: &str) -> Option<f64> {
    let s = s.trim().trim_end_matches('Z');
    let (date, time) = s.split_once(|c| c == 'T' || c == ' ')?;
    let mut dp = date.split('-');
    let y: i64 = dp.next()?.parse().ok()?;
    let m: u32 = dp.next()?.parse().ok()?;
    let d: u32 = dp.next()?.parse().ok()?;
    if dp.next().is_some() || !(1..=12).contains(&m) || !(1..=days_in_month(y, m)).contains(&d) {
        return None;
    }
    let mut tp = time.split(':');
    let hh: u32 = tp.next()?.parse().ok()?;
    let mm: u32 = tp.next()?.parse().ok()?;
    let ss: f64 = match tp.next() {
        Some(x) => x.parse().ok()?,
        None => 0.0,
    };
    if tp.next().is_some() || hh >= 24 || mm >= 60 || !(0.0..60.0).contains(&ss) {
        return None;
    }
    Some(days_from_civil(y, m, d) as f64 * 86_400.0
        + hh as f64 * 3_600.0
        + mm as f64 * 60.0
        + ss)
}

fn days_in_month(y: i64, m: u32) -> u32 {
    match m {
        1 | 3 | 5 | 7 | 8 | 10 | 12 => 31,
        4 | 6 | 9 | 11 => 30,
        _ => {
            if (y % 4 == 0 && y % 100 != 0) || y % 400 == 0 {
                29
            } else {
                28
            }
        }
    }
}

/// Days since 1970-01-01 for a proleptic-Gregorian civil date (Howard
/// Hinnant's `days_from_civil` algorithm — exact for all i64-range years).
fn days_from_civil(y: i64, m: u32, d: u32) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = y - era * 400; // [0, 399]
    let mp = (m as i64 + 9) % 12; // March-based month, [0, 11]
    let doy = (153 * mp + 2) / 5 + d as i64 - 1; // [0, 365]
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy; // [0, 146096]
    era * 146_097 + doe - 719_468
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_cover_paper_scenarios() {
        assert_eq!(region("node-high-scenario").unwrap().intensity, 620.0);
        assert_eq!(region("china-average").unwrap().intensity, 530.0);
        assert_eq!(region("node-green-scenario").unwrap().intensity, 380.0);
        assert!(region("atlantis").is_none());
        // ordering: coal-heavy above renewable
        assert!(region("coal-north-china").unwrap().intensity > 800.0);
        assert!(region("renewable-zone").unwrap().intensity < 100.0);
    }

    #[test]
    fn static_trace_constant() {
        let t = IntensityTrace::Static(530.0);
        assert_eq!(t.at(0.0), 530.0);
        assert_eq!(t.at(1e6), 530.0);
        assert_eq!(t.mean(100.0, 10), 530.0);
    }

    #[test]
    fn diurnal_oscillates_and_clamps() {
        let t = IntensityTrace::Diurnal {
            mean: 100.0,
            amplitude: 150.0,
            period_s: 86400.0,
            phase_s: 0.0,
        };
        // peak at period/4
        assert!((t.at(21600.0) - 250.0).abs() < 1.0);
        // trough clamps at zero (mean-amp < 0)
        assert_eq!(t.at(64800.0), 0.0);
        // mean over a full period is >= 0 and <= mean+amp
        let m = t.mean(86400.0, 1000);
        assert!(m > 0.0 && m < 250.0);
    }

    #[test]
    fn trace_step_holds() {
        let t = IntensityTrace::Trace(vec![(0.0, 500.0), (10.0, 300.0), (20.0, 700.0)]);
        assert_eq!(t.at(0.0), 500.0);
        assert_eq!(t.at(9.9), 500.0);
        assert_eq!(t.at(10.0), 300.0);
        assert_eq!(t.at(25.0), 700.0);
        // before first sample: first value
        assert_eq!(IntensityTrace::Trace(vec![(5.0, 42.0)]).at(0.0), 42.0);
        assert_eq!(IntensityTrace::Trace(vec![]).at(1.0), 0.0);
    }

    #[test]
    fn from_samples_sorts_and_validates() {
        // Unsorted input is normalized, not mis-read.
        let t = IntensityTrace::from_samples(vec![(20.0, 700.0), (0.0, 500.0), (10.0, 300.0)])
            .unwrap();
        assert_eq!(t.at(5.0), 500.0);
        assert_eq!(t.at(15.0), 300.0);
        assert_eq!(t.at(25.0), 700.0);
        // Bad values are rejected outright.
        assert!(IntensityTrace::from_samples(vec![(f64::NAN, 1.0)]).is_err());
        assert!(IntensityTrace::from_samples(vec![(0.0, -5.0)]).is_err());
        assert!(IntensityTrace::from_samples(vec![(0.0, f64::INFINITY)]).is_err());
        // Empty is a valid (all-zero) trace, matching Trace(vec![]).
        assert!(IntensityTrace::from_samples(Vec::new()).is_ok());
    }

    #[test]
    fn prop_from_samples_normalizes_unsorted_input() {
        crate::util::proptest::check(
            "from_samples(shuffled) reads identically to the sorted trace",
            300,
            |rng| {
                let n = rng.below(10);
                let mut ts = rng.range(-5.0, 5.0);
                let mut sorted = Vec::with_capacity(n);
                for _ in 0..n {
                    ts += rng.range(0.1, 10.0);
                    sorted.push((ts, rng.range(0.0, 900.0)));
                }
                let mut shuffled = sorted.clone();
                rng.shuffle(&mut shuffled);
                let queries: Vec<f64> = (0..8).map(|_| rng.range(-20.0, 120.0)).collect();
                (sorted, shuffled, queries)
            },
            |(sorted, shuffled, queries)| {
                let reference = IntensityTrace::Trace(sorted.clone());
                let built = IntensityTrace::from_samples(shuffled.clone())
                    .map_err(|e| format!("valid input rejected: {e}"))?;
                for &q in queries {
                    if built.at(q) != reference.at(q) {
                        return Err(format!(
                            "at({q}) = {} after normalization, want {}",
                            built.at(q),
                            reference.at(q)
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn integral_static_and_trace_exact() {
        assert_eq!(IntensityTrace::Static(530.0).integral(10.0, 20.0), 5300.0);
        assert_eq!(IntensityTrace::Static(530.0).integral(5.0, 5.0), 0.0);
        let t = IntensityTrace::Trace(vec![(0.0, 500.0), (10.0, 300.0), (20.0, 700.0)]);
        // Spanning all three segments: 5s@500 + 10s@300 + 5s@700.
        assert!((t.integral(5.0, 25.0) - (2500.0 + 3000.0 + 3500.0)).abs() < 1e-9);
        // Entirely before the first sample: step-hold extends backwards.
        assert!((t.integral(-10.0, -5.0) - 2500.0).abs() < 1e-9);
        // Entirely past the last sample.
        assert!((t.integral(30.0, 40.0) - 7000.0).abs() < 1e-9);
        // Inside one segment.
        assert!((t.integral(12.0, 14.0) - 600.0).abs() < 1e-9);
        assert_eq!(IntensityTrace::Trace(vec![]).integral(0.0, 100.0), 0.0);
    }

    #[test]
    fn integral_diurnal_matches_midpoint_sampling() {
        let t = IntensityTrace::Diurnal {
            mean: 530.0,
            amplitude: 180.0,
            period_s: 86_400.0,
            phase_s: 3_600.0,
        };
        // Full period: the sinusoid integrates away, leaving mean·period.
        assert!((t.integral(0.0, 86_400.0) - 530.0 * 86_400.0).abs() < 1e-4);
        // Partial window: analytic result vs a fine midpoint reference.
        let (t0, t1) = (10_000.0, 47_000.0);
        let steps = 400_000;
        let h = (t1 - t0) / steps as f64;
        let numeric: f64 =
            (0..steps).map(|i| t.at(t0 + (i as f64 + 0.5) * h)).sum::<f64>() * h;
        let analytic = t.integral(t0, t1);
        assert!(
            (analytic - numeric).abs() / numeric.abs() < 1e-6,
            "analytic {analytic} vs numeric {numeric}"
        );
        // Clamped curve (amplitude > mean) stays non-negative and finite.
        let c = IntensityTrace::Diurnal {
            mean: 100.0,
            amplitude: 150.0,
            period_s: 86_400.0,
            phase_s: 0.0,
        };
        let v = c.integral(0.0, 86_400.0);
        assert!(v > 0.0 && v < 250.0 * 86_400.0, "{v}");
    }

    #[test]
    fn prop_integral_matches_fine_riemann_sum() {
        // The microgrid supply settlement and the idle-floor pricing both
        // lean on `integral`: check it against a 200k-step midpoint
        // Riemann sum of `at` across all three variants, with slice
        // bounds that regularly straddle (or sit exactly on) trace
        // samples. Tolerance is 0.1% of the max-value × window scale —
        // generous enough for the reference sum's own discretization
        // error at step-held jumps and clamped-diurnal kinks, far below
        // any mispriced segment.
        crate::util::proptest::check(
            "integral == fine midpoint Riemann sum",
            60,
            |rng| {
                let trace = match rng.below(3) {
                    0 => IntensityTrace::Static(rng.range(0.0, 900.0)),
                    1 => IntensityTrace::Diurnal {
                        mean: rng.range(50.0, 600.0),
                        // May exceed the mean: exercises the clamped
                        // (midpoint-sampled) fallback path too.
                        amplitude: rng.range(0.0, 700.0),
                        period_s: rng.range(1_000.0, 50_000.0),
                        phase_s: rng.range(-25_000.0, 25_000.0),
                    },
                    _ => {
                        let n = 1 + rng.below(8);
                        let mut t = rng.range(-50.0, 50.0);
                        let mut pts = Vec::with_capacity(n);
                        for _ in 0..n {
                            t += rng.range(1.0, 200.0);
                            pts.push((t, rng.range(0.0, 900.0)));
                        }
                        IntensityTrace::Trace(pts)
                    }
                };
                let mut t0 = rng.range(-100.0, 500.0);
                let mut t1 = t0 + rng.range(0.0, 2_000.0);
                // Every third case pins a bound to an exact sample time:
                // the boundary-inclusivity cases the settlement hits when
                // a slice ends on a trace step.
                if let IntensityTrace::Trace(pts) = &trace {
                    match rng.below(3) {
                        0 => {
                            t0 = pts[rng.below(pts.len())].0;
                            t1 = t1.max(t0);
                        }
                        1 => t1 = t0.max(pts[rng.below(pts.len())].0),
                        _ => {}
                    }
                }
                (trace, t0, t1)
            },
            |(trace, t0, t1)| {
                let dt = t1 - t0;
                let steps = 200_000;
                let h = dt / steps as f64;
                let riemann: f64 = if dt == 0.0 {
                    0.0
                } else {
                    (0..steps).map(|i| trace.at(t0 + (i as f64 + 0.5) * h)).sum::<f64>() * h
                };
                let got = trace.integral(*t0, *t1);
                let tol = 1.5 * dt + 1e-9;
                if (got - riemann).abs() > tol {
                    return Err(format!(
                        "integral({t0}, {t1}) = {got}, Riemann = {riemann} (tol {tol})"
                    ));
                }
                if got < -1e-12 {
                    return Err(format!("negative integral {got} of a non-negative trace"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn csv_single_zone_numeric_seconds() {
        let csv = "timestamp,intensity\n0,500\n10,300\n20,700\n";
        let t = IntensityTrace::from_csv(csv).unwrap();
        assert_eq!(t.at(5.0), 500.0);
        assert_eq!(t.at(15.0), 300.0);
        // Unsorted rows are normalized by the validating constructor.
        let t2 = IntensityTrace::from_csv("20,700\n0,500\n10,300\n").unwrap();
        assert_eq!(t2.at(5.0), 500.0);
        assert_eq!(t2.at(25.0), 700.0);
    }

    #[test]
    fn csv_multi_zone_datetimes_normalized_and_aligned() {
        let csv = "\
datetime,zone,carbon_intensity_gco2eq_per_kwh
2024-06-01T00:00:00Z,DE,420
2024-06-01T01:00:00Z,DE,410
# a comment
2024-06-01T00:00:00Z,DK,180
2024-06-01T01:00:00Z,DK,175
";
        let zones = zone_traces_from_csv(csv).unwrap();
        assert_eq!(zones.len(), 2);
        assert_eq!(zones[0].0, "DE"); // BTreeMap order: sorted by name
        assert_eq!(zones[1].0, "DK");
        // Earliest sample normalized to t = 0; the next hour at t = 3600.
        assert_eq!(zones[0].1.at(0.0), 420.0);
        assert_eq!(zones[0].1.at(3_599.0), 420.0);
        assert_eq!(zones[0].1.at(3_600.0), 410.0);
        assert_eq!(zones[1].1.at(3_600.0), 175.0);
        // Multi-zone file through the single-zone entrypoint is an error.
        assert!(IntensityTrace::from_csv(csv).is_err());
    }

    #[test]
    fn csv_rejects_malformed_rows() {
        assert!(zone_traces_from_csv("").is_err());
        assert!(zone_traces_from_csv("just,one,header,row\n").is_err()); // 4 columns
        assert!(zone_traces_from_csv("0,abc\n").is_err()); // bad intensity
        assert!(zone_traces_from_csv("0,100\nnot-a-time,200\n").is_err()); // 2nd bad stamp
        assert!(zone_traces_from_csv("0,-10\n").is_err()); // negative intensity
        // A malformed FIRST data row (bad timestamp, numeric intensity) is
        // an error, not a silent header skip — hour 25 does not exist.
        assert!(zone_traces_from_csv("2024-06-01T25:00:00Z,DE,420\n").is_err());
        // Mixing numeric-seconds and datetime stamps would leave the
        // datetime rows at epoch scale after normalization: reject it.
        assert!(zone_traces_from_csv("0,500\n2024-06-01T00:00:00Z,300\n").is_err());
        assert!(zone_traces_from_csv("2024-06-01T00:00:00Z,300\n3600,500\n").is_err());
    }

    #[test]
    fn datetime_parsing_civil_arithmetic() {
        assert_eq!(days_from_civil(1970, 1, 1), 0);
        assert_eq!(days_from_civil(1970, 1, 2), 1);
        assert_eq!(days_from_civil(1969, 12, 31), -1);
        // Leap handling: 2024 is a leap year, 2023 is not.
        assert_eq!(days_from_civil(2024, 3, 1) - days_from_civil(2024, 2, 28), 2);
        assert_eq!(days_from_civil(2023, 3, 1) - days_from_civil(2023, 2, 28), 1);
        assert_eq!(days_from_civil(2000, 3, 1) - days_from_civil(2000, 2, 29), 1);
        // Datetime → epoch seconds, with and without seconds/Z.
        assert_eq!(parse_datetime_s("1970-01-01T00:00:00Z"), Some(0.0));
        assert_eq!(parse_datetime_s("1970-01-02 06:30"), Some(86_400.0 + 6.5 * 3_600.0));
        assert_eq!(parse_datetime_s("1970-01-01T00:00:30"), Some(30.0));
        assert_eq!(parse_datetime_s("garbage"), None);
        assert_eq!(parse_datetime_s("1970-13-01T00:00"), None);
        assert_eq!(parse_datetime_s("1970-01-01T25:00"), None);
        // Nonexistent civil dates are rejected, not wrapped into the next
        // month; real leap days parse.
        assert_eq!(parse_datetime_s("2024-02-30T00:00"), None);
        assert_eq!(parse_datetime_s("2023-02-29T00:00"), None);
        assert_eq!(parse_datetime_s("2024-04-31T00:00"), None);
        assert!(parse_datetime_s("2024-02-29T00:00").is_some());
        assert!(parse_datetime_s("2000-02-29T00:00").is_some());
    }

    #[test]
    fn prop_trace_binary_search_matches_linear_scan() {
        // The pre-optimization reference implementation.
        fn linear(points: &[(f64, f64)], t: f64) -> f64 {
            if points.is_empty() {
                return 0.0;
            }
            let mut current = points[0].1;
            for &(ts, v) in points {
                if ts <= t {
                    current = v;
                } else {
                    break;
                }
            }
            current
        }
        crate::util::proptest::check(
            "partition_point lookup == step-hold linear scan",
            500,
            |rng| {
                // 0..8 samples (0 = the empty case) at strictly increasing
                // times that may start negative; queries range from well
                // before the first sample to well past the last.
                let n = rng.below(8);
                let mut ts = rng.range(-5.0, 5.0);
                let mut points = Vec::with_capacity(n);
                for _ in 0..n {
                    ts += rng.range(0.1, 10.0);
                    points.push((ts, rng.range(0.0, 900.0)));
                }
                let queries: Vec<f64> = (0..8).map(|_| rng.range(-20.0, 90.0)).collect();
                (points, queries)
            },
            |(points, queries)| {
                let trace = IntensityTrace::Trace(points.clone());
                for &q in queries {
                    let fast = trace.at(q);
                    let slow = linear(points, q);
                    if fast != slow {
                        return Err(format!("at({q}) = {fast}, linear scan = {slow}"));
                    }
                }
                // Exact sample times must also agree (boundary inclusivity).
                for &(ts, _) in points {
                    if trace.at(ts) != linear(points, ts) {
                        return Err(format!("boundary mismatch at t = {ts}"));
                    }
                }
                Ok(())
            },
        );
    }
}
