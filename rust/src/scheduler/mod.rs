//! The Carbon-Aware Scheduler — the paper's primary contribution
//! (Sec. III-C/D): weighted node scoring (Eq. 3), the carbon-efficiency
//! score S_C (Eq. 4), the three operational modes (Table I), the node
//! selection algorithm (Algorithm 1), and the non-carbon-aware baselines
//! (AMP4EC NSA, round-robin, random, least-loaded).
//!
//! # The `decide` API
//!
//! Scheduling is a single joint verdict: [`Scheduler::decide`] takes a
//! [`FleetView`] — a per-arrival immutable snapshot carrying, for every
//! candidate node, the Algorithm-1 score inputs, a queue-delay estimate,
//! the *blended* (microgrid-aware) effective carbon intensity, and an
//! optional short intensity forecast out to the task's latest viable
//! release slot — and answers [`SchedulingDecision`]: `Assign(i)` (where),
//! `Defer { until_s }` (when), or `Reject` (neither). The paper's
//! Algorithm 1 only ever answered "which node"; deferral ran as a separate
//! route-then-defer pass in the simulator. Folding both into one verdict
//! lets policies trade *where* against *when* jointly:
//! [`RouteThenDefer`] reproduces the legacy two-pass shape as an adapter,
//! and [`DeferAwareGreenScheduler`] answers jointly (and spreads releases
//! across the forecast plateau so parked work doesn't stampede the
//! cleanest node).
//!
//! Real-time callers with no forecast context snapshot the fleet with
//! [`FleetView::observe`] and read the verdict via
//! [`SchedulingDecision::assigned`].

mod baselines;
mod defer;
mod modes;
mod normalized;
mod nsa;
mod score;
mod view;

pub use baselines::{Amp4ecScheduler, LeastLoadedScheduler, RandomScheduler, RoundRobinScheduler};
pub use defer::{DeferAwareGreenScheduler, RouteThenDefer, DEFAULT_JOIN_TOL, DEFAULT_PLATEAU_TOL};
pub use modes::{Mode, Weights};
pub use nsa::{CarbonAwareScheduler, SelectionTrace, LOAD_CUTOFF};
pub use normalized::{ConstrainedGreenScheduler, NormalizedScheduler};
pub use score::{carbon_score, score_breakdown, score_breakdown_view, ScoreBreakdown, TaskDemand};
pub use view::{
    CandidateExplain, ClassNodeView, DecisionExplain, FleetView, NodeView, RejectReason,
    SchedulingDecision,
};

/// Scheduling interface shared by the carbon-aware scheduler and all
/// baselines: one [`SchedulingDecision`] per task over a [`FleetView`]
/// snapshot. `Assign` indexes into `fleet.nodes`; `Reject` is Algorithm 1
/// line 18 (`n* = null`); `Defer` parks the task for a cleaner forecast
/// slot — only meaningful when the view carries forecast context, and only
/// returned by schedulers whose [`Scheduler::defers`] is true.
pub trait Scheduler: Send {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision;

    /// Human-readable name for reports.
    fn name(&self) -> &str;

    /// `decide` with an explanation: fill `explain` with the per-candidate
    /// scores and rationale behind the verdict, for the observability
    /// firehose ([`crate::obs`]). Must return the *identical* verdict (and
    /// perform the identical internal state transitions) as `decide` on the
    /// same inputs — tracing never perturbs the simulation. The default
    /// records the baseline view of every candidate; policies with richer
    /// internals (scores, defer slots) override it.
    fn decide_explained(
        &mut self,
        task: &TaskDemand,
        fleet: &FleetView,
        explain: &mut DecisionExplain,
    ) -> SchedulingDecision {
        explain.all_from_fleet(fleet, task);
        self.decide(task, fleet)
    }

    /// Whether `decide` already weighs deferral jointly (may return
    /// `Defer` verdicts itself). The simulator wraps schedulers that
    /// don't in the legacy [`RouteThenDefer`] gate when a scenario
    /// configures deferral, so baselines keep their historical
    /// route-then-defer behaviour without knowing forecasts exist.
    fn defers(&self) -> bool {
        false
    }
}

impl<T: Scheduler + ?Sized> Scheduler for &mut T {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        (**self).decide(task, fleet)
    }
    fn decide_explained(
        &mut self,
        task: &TaskDemand,
        fleet: &FleetView,
        explain: &mut DecisionExplain,
    ) -> SchedulingDecision {
        (**self).decide_explained(task, fleet, explain)
    }
    fn name(&self) -> &str {
        (**self).name()
    }
    fn defers(&self) -> bool {
        (**self).defers()
    }
}

impl<T: Scheduler + ?Sized> Scheduler for Box<T> {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        (**self).decide(task, fleet)
    }
    fn decide_explained(
        &mut self,
        task: &TaskDemand,
        fleet: &FleetView,
        explain: &mut DecisionExplain,
    ) -> SchedulingDecision {
        (**self).decide_explained(task, fleet, explain)
    }
    fn name(&self) -> &str {
        (**self).name()
    }
    fn defers(&self) -> bool {
        (**self).defers()
    }
}
