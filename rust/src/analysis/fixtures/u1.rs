//! Known-bad fixture: U1 — a milliseconds value assigned from seconds.
//! The WAN ledger mixes _s/_ms/_wh/_kwh; conversions must be explicit.

/// Copy a WAN latency budget across layers — dropping the unit on the
/// floor.
pub fn carry_over(window_s: f64) -> f64 {
    let mut window_ms = 0.0;
    window_ms = window_s;
    window_ms
}
