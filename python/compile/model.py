"""L2 public entrypoint: the CarbonEdge model zoo forward pass.

Thin re-export kept at the path the repo layout mandates; the actual model
definitions (which call the L1 Pallas kernels) live in ``models.py`` and
``layers.py``.
"""

from .models import ZOO, Model, Stage, build, make_divisible  # noqa: F401
from .layers import LayerMeta  # noqa: F401


def forward(name: str, x, **kwargs):
    """Run a zoo model forward: ``x (H,W,3) -> logits (num_classes,)``."""
    return build(name, **kwargs).forward(x)
