//! Configuration system: typed experiment/serving config with JSON file
//! loading (`configs/*.json`) and programmatic defaults matching the
//! paper's setup (Sec. IV-A).

use anyhow::{Context, Result};

use crate::energy::{CpuRapl, GpuSim, HostPowerModel, RamPower};
use crate::node::NodeSpec;
use crate::util::json::Json;

/// Host power model calibrated to the paper's testbed scale (DESIGN.md §3):
/// a DGX SPARK-class desktop host. Full-load ≈ 142 W, so a 255 ms
/// monolithic inference consumes ≈ 36 J ⇒ 0.0053 gCO₂ at 530 g/kWh —
/// exactly the paper's Table II monolithic datum.
pub fn default_host_power() -> HostPowerModel {
    HostPowerModel {
        cpu: CpuRapl { idle_w: 30.0, peak_w: 80.0 },
        gpu: GpuSim { idle_w: 12.0, peak_w: 50.0 },
        ram: RamPower::new(32.0),
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Artifact directory (manifest.json + HLO + weights).
    pub artifacts_dir: String,
    /// Node fleet.
    pub nodes: Vec<NodeSpec>,
    /// Host power model (energy accounting).
    pub host: HostPowerModel,
    /// PUE (paper default 1.0 for edge).
    pub pue: f64,
    /// Grid intensity used for host-local (monolithic) execution — the
    /// paper's "average scenario" (530 gCO₂/kWh).
    pub host_intensity: f64,
    /// Inferences per experiment configuration (paper: 50).
    pub iterations: usize,
    /// Repetitions per configuration (paper: 3).
    pub repetitions: usize,
    /// Upload weights as device-resident buffers (§Perf hot path).
    pub resident_weights: bool,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            artifacts_dir: "artifacts".into(),
            nodes: NodeSpec::paper_nodes(),
            host: default_host_power(),
            pue: crate::carbon::DEFAULT_PUE,
            host_intensity: 530.0,
            iterations: 50,
            repetitions: 3,
            resident_weights: true,
        }
    }
}

impl Config {
    /// Load from a JSON config file; missing fields fall back to defaults.
    pub fn from_file(path: &str) -> Result<Config> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing config {path}"))?;
        Config::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Config> {
        let mut c = Config::default();
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = j.get("pue").and_then(Json::as_f64) {
            c.pue = v;
        }
        if let Some(v) = j.get("host_intensity").and_then(Json::as_f64) {
            c.host_intensity = v;
        }
        if let Some(v) = j.get("iterations").and_then(Json::as_usize) {
            c.iterations = v;
        }
        if let Some(v) = j.get("repetitions").and_then(Json::as_usize) {
            c.repetitions = v;
        }
        if let Some(v) = j.get("resident_weights").and_then(Json::as_bool) {
            c.resident_weights = v;
        }
        if let Some(h) = j.get("host") {
            c.host = HostPowerModel {
                cpu: CpuRapl {
                    idle_w: h.req_f64("cpu_idle_w")?,
                    peak_w: h.req_f64("cpu_peak_w")?,
                },
                gpu: GpuSim {
                    idle_w: h.req_f64("gpu_idle_w")?,
                    peak_w: h.req_f64("gpu_peak_w")?,
                },
                ram: RamPower::new(h.req_f64("ram_gb")?),
            };
        }
        if let Some(ns) = j.get("nodes").and_then(Json::as_arr) {
            c.nodes = ns.iter().map(node_from_json).collect::<Result<Vec<_>>>()?;
        }
        Ok(c)
    }
}

fn node_from_json(j: &Json) -> Result<NodeSpec> {
    Ok(NodeSpec {
        name: j.req_str("name")?.to_string(),
        cpu_quota: j.req_f64("cpu_quota")?,
        mem_mb: j.req_usize("mem_mb")?,
        intensity: j.req_f64("intensity")?,
        rated_power_w: j.req_f64("rated_power_w")?,
        idle_w: j.get("idle_w").and_then(Json::as_f64).unwrap_or(0.0),
        prior_ms: j.req_f64("prior_ms")?,
        alpha: j.get("alpha").and_then(Json::as_f64).unwrap_or(0.005),
        overhead_ms: j.get("overhead_ms").and_then(Json::as_f64).unwrap_or(8.0),
        time_scale: j.get("time_scale").and_then(Json::as_f64).unwrap_or(20.6),
        adaptive: j.get("adaptive").and_then(Json::as_bool).unwrap_or(false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = Config::default();
        assert_eq!(c.iterations, 50);
        assert_eq!(c.repetitions, 3);
        assert_eq!(c.pue, 1.0);
        assert_eq!(c.host_intensity, 530.0);
        assert_eq!(c.nodes.len(), 3);
        // full-load host power ≈ 142 W (paper-scale energy; DESIGN.md §3)
        assert!((c.host.power_watts(1.0, 1.0) - 142.0).abs() < 1e-9);
    }

    #[test]
    fn json_overrides() {
        let j = Json::parse(
            r#"{
              "iterations": 10, "pue": 1.2, "host_intensity": 475.0,
              "resident_weights": false,
              "nodes": [
                {"name": "n0", "cpu_quota": 0.5, "mem_mb": 256, "intensity": 100.0,
                 "rated_power_w": 40.0, "prior_ms": 100.0}
              ]
            }"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.iterations, 10);
        assert_eq!(c.pue, 1.2);
        assert!(!c.resident_weights);
        assert_eq!(c.nodes.len(), 1);
        assert_eq!(c.nodes[0].name, "n0");
        assert_eq!(c.nodes[0].alpha, 0.005); // default
        assert_eq!(c.nodes[0].time_scale, 20.6); // default
        // untouched fields keep defaults
        assert_eq!(c.repetitions, 3);
    }

    #[test]
    fn host_override() {
        let j = Json::parse(
            r#"{"host": {"cpu_idle_w": 1, "cpu_peak_w": 2, "gpu_idle_w": 3,
                          "gpu_peak_w": 4, "ram_gb": 8}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.host.power_watts(1.0, 1.0), 2.0 + 4.0 + 3.0);
    }

    #[test]
    fn bad_node_rejected() {
        let j = Json::parse(r#"{"nodes": [{"name": "x"}]}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
    }
}
