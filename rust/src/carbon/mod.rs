//! Carbon accounting: grid carbon-intensity data and the paper's emission
//! model (Eq. 2), plus temporal intensity traces (the paper's stated
//! future-work extension, implemented here behind the same interface).

mod budget;
mod deferral;
mod intensity;

pub use budget::{Admission, BudgetBook, CarbonBudget};
pub use deferral::{DeferDecision, DeferralPolicy};
pub use intensity::{region, zone_traces_from_csv, IntensityTrace, Region, REGIONS};

/// Grid carbon intensity in gCO₂/kWh.
pub type GramsPerKwh = f64;

/// Power Usage Effectiveness. The paper defaults to 1.0 for edge devices.
pub const DEFAULT_PUE: f64 = 1.0;

/// Paper Eq. 2: `C = E_total * I_carbon * PUE`.
///
/// `energy_kwh` in kWh, `intensity` in gCO₂/kWh; result in grams of CO₂.
pub fn emissions_g(energy_kwh: f64, intensity: GramsPerKwh, pue: f64) -> f64 {
    // Demoted to debug_assert: this sits on the per-completion hot path and
    // every caller's inputs are validated once at scenario/config build.
    debug_assert!(energy_kwh >= 0.0, "negative energy");
    debug_assert!(intensity >= 0.0, "negative intensity");
    debug_assert!(pue >= 1.0, "PUE < 1 is unphysical");
    energy_kwh * intensity * pue
}

/// Joules -> kWh (1 kWh = 3.6e6 J).
pub fn joules_to_kwh(j: f64) -> f64 {
    j / 3.6e6
}

/// Watts sustained for `ms` milliseconds -> kWh.
/// This is the paper's `E = P * T / 3_600_000` (with T in ms) conversion
/// used inside the carbon-efficiency score (Eq. 4).
pub fn watts_ms_to_kwh(watts: f64, ms: f64) -> f64 {
    watts * ms / 3.6e9
}

/// Carbon efficiency metric reported in Fig. 2: inferences per gram CO₂.
pub fn carbon_efficiency(inferences: u64, grams: f64) -> f64 {
    if grams <= 0.0 {
        return f64::INFINITY;
    }
    inferences as f64 / grams
}

/// A carbon "ledger" accumulating emissions per label (node / experiment).
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    entries: std::collections::BTreeMap<String, LedgerEntry>,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct LedgerEntry {
    pub energy_kwh: f64,
    pub carbon_g: f64,
    pub tasks: u64,
}

impl Ledger {
    pub fn charge(&mut self, label: &str, energy_kwh: f64, intensity: GramsPerKwh, pue: f64) {
        let e = self.entries.entry(label.to_string()).or_default();
        e.energy_kwh += energy_kwh;
        e.carbon_g += emissions_g(energy_kwh, intensity, pue);
        e.tasks += 1;
    }

    pub fn get(&self, label: &str) -> LedgerEntry {
        self.entries.get(label).copied().unwrap_or_default()
    }

    pub fn total(&self) -> LedgerEntry {
        let mut t = LedgerEntry::default();
        for e in self.entries.values() {
            t.energy_kwh += e.energy_kwh;
            t.carbon_g += e.carbon_g;
            t.tasks += e.tasks;
        }
        t
    }

    pub fn labels(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_exact() {
        // 1 kWh at 530 g/kWh, PUE 1.0 -> 530 g.
        assert_eq!(emissions_g(1.0, 530.0, 1.0), 530.0);
        // PUE scales linearly.
        assert_eq!(emissions_g(2.0, 100.0, 1.5), 300.0);
        assert_eq!(emissions_g(0.0, 620.0, 1.0), 0.0);
    }

    #[test]
    #[should_panic]
    fn pue_below_one_rejected() {
        emissions_g(1.0, 100.0, 0.5);
    }

    #[test]
    fn unit_conversions() {
        assert!((joules_to_kwh(3.6e6) - 1.0).abs() < 1e-12);
        // 500 W for 255 ms = 0.03542 Wh = 3.542e-5 kWh
        let kwh = watts_ms_to_kwh(500.0, 255.0);
        assert!((kwh - 500.0 * 0.255 / 3.6e6).abs() < 1e-12);
    }

    #[test]
    fn paper_scale_sanity() {
        // The paper's monolithic MobileNetV2 datum: 0.0053 gCO2/inference at
        // 530 g/kWh implies exactly 1e-5 kWh (36 J) per inference.
        let kwh = 0.0053 / 530.0;
        assert!((emissions_g(kwh, 530.0, DEFAULT_PUE) - 0.0053).abs() < 1e-12);
        assert!((joules_to_kwh(36.0) - kwh).abs() < 1e-8);
    }

    #[test]
    fn efficiency_metric() {
        // Fig. 2: 50 inferences at 0.0041 g/inf -> 243.9 inf/g.
        let eff = carbon_efficiency(50, 50.0 * 0.0041);
        assert!((eff - 1.0 / 0.0041).abs() < 1e-9);
        assert!(carbon_efficiency(5, 0.0).is_infinite());
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = Ledger::default();
        l.charge("node-green", 0.001, 380.0, 1.0);
        l.charge("node-green", 0.001, 380.0, 1.0);
        l.charge("node-high", 0.001, 620.0, 1.0);
        let g = l.get("node-green");
        assert_eq!(g.tasks, 2);
        assert!((g.carbon_g - 0.76).abs() < 1e-12);
        let t = l.total();
        assert_eq!(t.tasks, 3);
        assert!((t.carbon_g - (0.76 + 0.62)).abs() < 1e-12);
        assert_eq!(l.labels(), vec!["node-green", "node-high"]);
    }
}
