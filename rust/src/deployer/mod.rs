//! The Model Deployer (paper Sec. III-A component D): registers model
//! programs with the executor and binds them to nodes as containers.
//!
//! Two deployment shapes:
//! * **task-level routing** (the paper's evaluated mode): every node gets
//!   the full stage chain; the scheduler picks one node per inference;
//! * **cross-node pipeline** (the paper's future-work extension): stages
//!   are partitioned across nodes (Green Partitioning) and one inference
//!   flows through all of them.

use std::sync::Arc;

use anyhow::Result;

use crate::config::Config;
use crate::model::LoadedModel;
use crate::node::{Container, EdgeNode};
use crate::partitioner::Partition;
use crate::runtime::ExecHandle;

/// Registers the monolithic program. Key: `"<model>/monolithic"`.
/// Runs one warm-up inference so first-request latency is not polluted by
/// lazy one-time initialization (standard serving practice).
pub fn register_monolithic(exec: &ExecHandle, model: &LoadedModel, cfg: &Config) -> Result<String> {
    let key = format!("{}/monolithic", model.entry.name);
    exec.register(&key, &model.monolithic_path(), model.all_weights(), cfg.resident_weights)?;
    exec.execute(&key, crate::runtime::Tensor::zeros(model.entry.input_shape.clone()))?;
    Ok(key)
}

/// Registers every stage program (with warm-up). Keys: `"<model>/stage<i>"`.
pub fn register_stages(
    exec: &ExecHandle,
    model: &LoadedModel,
    cfg: &Config,
) -> Result<Vec<String>> {
    let mut keys = Vec::with_capacity(model.entry.stages.len());
    for (i, stage) in model.entry.stages.iter().enumerate() {
        let key = format!("{}/stage{}", model.entry.name, i);
        let weights = model.stage_weights[i].clone();
        exec.register(&key, &model.stage_path(i), weights, cfg.resident_weights)?;
        exec.execute(&key, crate::runtime::Tensor::zeros(stage.in_shape.clone()))?;
        keys.push(key);
    }
    Ok(keys)
}

/// Task-level deployment: every node can run the full stage chain.
pub fn deploy_task_level(
    exec: &ExecHandle,
    model: &LoadedModel,
    nodes: &[Arc<EdgeNode>],
    cfg: &Config,
) -> Result<Vec<Container>> {
    let keys = register_stages(exec, model, cfg)?;
    Ok(nodes
        .iter()
        .map(|n| Container::new(Arc::clone(n), exec.clone(), cfg.host, cfg.pue, keys.clone()))
        .collect())
}

/// Pipeline deployment: contiguous stage groups per node (skipping nodes
/// whose group is empty). Returns containers in pipeline order.
pub fn deploy_pipeline(
    exec: &ExecHandle,
    model: &LoadedModel,
    nodes: &[Arc<EdgeNode>],
    partition: &Partition,
    cfg: &Config,
) -> Result<Vec<Container>> {
    anyhow::ensure!(partition.is_valid(), "invalid partition");
    anyhow::ensure!(
        partition.n_stages == model.entry.stages.len(),
        "partition over {} stages, model has {}",
        partition.n_stages,
        model.entry.stages.len()
    );
    anyhow::ensure!(partition.n_groups() == nodes.len(), "one group per node required");
    let keys = register_stages(exec, model, cfg)?;
    let mut out = Vec::new();
    for (node, (s, e)) in nodes.iter().zip(partition.ranges()) {
        if s == e {
            continue; // node receives no stage
        }
        out.push(Container::new(
            Arc::clone(node),
            exec.clone(),
            cfg.host,
            cfg.pue,
            keys[s..e].to_vec(),
        ));
    }
    anyhow::ensure!(!out.is_empty(), "empty pipeline");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use crate::node::NodeRegistry;
    use crate::partitioner::balanced_partition;

    #[test]
    fn pipeline_partition_shape_checks() {
        // Validation-only checks that don't need a live executor: the
        // partition must match stage count and node count.
        let r = NodeRegistry::paper_setup();
        let p = balanced_partition(&[1, 1], 3);
        // 2 stages into 3 nodes -> p has at most 2 groups after clamping,
        // so deploy must reject the group/node mismatch.
        assert!(p.n_groups() != r.len() || p.is_valid());
    }
}
