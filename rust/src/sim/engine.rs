//! The discrete-event engine: a binary-heap event queue over virtual time
//! driving per-node FIFO queues with bounded concurrency.
//!
//! Determinism: every event carries a monotone sequence number that breaks
//! timestamp ties, all randomness flows from two seeded [`Rng`] streams
//! (arrivals and service jitter), and per-node accounting is an index-
//! addressed ledger table — identical seeds therefore yield identical
//! [`SimReport`]s.
//!
//! Energy is a **two-part model**: every powered-on node accrues its
//! [`crate::node::NodeSpec::idle_w`] floor over virtual uptime (priced by
//! piecewise integration of its [`IntensityTrace`], not at a single
//! instant), and each task adds `dynamic_power_w × service` on top, priced
//! at completion-time intensity (Eq. 2).
//!
//! Scheduling is **verdict-driven**: every admission builds a
//! [`FleetView`] snapshot — per-node state, queue-delay estimate, blended
//! effective intensity, and (for slack-carrying arrivals) a forecast of
//! that effective intensity out to the latest viable release slot — and
//! the engine obeys the scheduler's [`SchedulingDecision`]: `Assign`
//! dispatches, `Defer { until_s }` parks the task as an
//! [`EventKind::DeferredRelease`], `Reject` counts it rejected. Schedulers
//! that don't defer on their own ([`crate::scheduler::Scheduler::defers`]
//! = false) are wrapped in the legacy [`RouteThenDefer`] gate when the
//! scenario configures a [`DeferralSpec`], reproducing the historical
//! route-then-defer behaviour — now against the *blended* forecast, so a
//! charged battery or midday PV rightly suppresses a defer the raw grid
//! curve would have taken.
//!
//! Nodes with an attached [`crate::microgrid::MicrogridSpec`] route both
//! parts of their draw (idle floor + per-task dynamic power) through the
//! microgrid instead: every change of a node's draw settles the elapsed
//! slice PV-first, then battery, then grid ([`Simulation::settle_microgrid`]
//! via [`crate::microgrid::Microgrid::settle`]). Grid joules bear carbon at
//! the slice-mean grid intensity; battery joules bear the store's
//! *embodied* intensity (grid-charged arbitrage imports price their
//! carbon into the stored ledger at charge time and release it pro rata
//! on discharge — never laundered to zero); both are split between the
//! idle and dynamic ledgers by draw share. The scheduler-visible
//! intensity override carries the *marginal* effective intensity — what
//! the next task's watts would actually pay after the standing draw
//! claims local supply.
//!
//! A microgrid node's forecast is a **simulated SoC trajectory**
//! ([`crate::microgrid::Microgrid::project`]): the settlement arithmetic
//! rolled forward at the node's standing draw, charge policy included, so
//! `DeferAwareGreenScheduler` and the `RouteThenDefer` gate price release
//! slots against the battery the node will actually have. The forecast is
//! *draw*-frozen (the engine cannot know future dispatch), no longer
//! *charge*-frozen; `SimConfig::charge_frozen_forecasts` restores the
//! legacy PR-4 frozen average-blend forecast for A/B twins.
//!
//! Observability is **opt-in and zero-overhead when off**:
//! [`Simulation::try_run_observed`] attaches a [`crate::obs::EventSink`]
//! and a [`crate::obs::Telemetry`] registry, and the hot paths
//! then emit a [`crate::obs::TraceEvent`] at every arrival, scheduling
//! verdict (timed, with the [`crate::scheduler::DecisionExplain`]
//! rationale when the sink keeps decision events), dispatch, deferred
//! release, completion, churn transition and microgrid settlement slice.
//! On the default `run`/`try_run` paths the sink is `None` and every
//! emission site is a dead branch — no event is constructed, no clock
//! read, and the [`SimReport`] stays bit-identical either way.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use crate::carbon::{emissions_g, joules_to_kwh, DeferralPolicy, IntensityTrace, LedgerEntry};
use crate::microgrid::Microgrid;
use crate::node::EdgeNode;
use crate::obs::{EventKind as TraceKind, EventSink, MonitorSet, Telemetry, TraceEvent};
use crate::scheduler::{
    ClassNodeView, DecisionExplain, FleetView, NodeView, RejectReason, RouteThenDefer, Scheduler,
    SchedulingDecision, TaskDemand,
};
use crate::site::{Router, SiteView};
use crate::util::rng::Rng;
use crate::workload::WorkloadMix;

use super::report::SimReport;
use super::scenarios::Scenario;

/// Longest single microgrid settlement slice (virtual seconds): intervals
/// between events are covered in chunks of at most this, so PV generation
/// and grid intensity are resolved to a bounded granularity even across
/// sparse-event gaps (15 min ≪ the diurnal timescales of both curves).
pub const MG_SETTLE_MAX_SLICE_S: f64 = 900.0;

/// In-engine temporal deferral: arrivals get `slack_s` of slack, and the
/// policy may park them until a cleaner forecast slot. The policy is only
/// consulted up to `deadline − headroom_s` so a released task still has
/// room to queue and execute before its deadline.
#[derive(Debug, Clone)]
pub struct DeferralSpec {
    /// Slack granted to every arrival: `deadline = arrival + slack_s`.
    pub slack_s: f64,
    /// Safety margin kept between the latest considered release slot and
    /// the deadline (covers queueing + service after release).
    pub headroom_s: f64,
    /// The forecast-scanning policy (resolution + minimum gain).
    pub policy: DeferralPolicy,
}

impl Default for DeferralSpec {
    fn default() -> DeferralSpec {
        DeferralSpec { slack_s: 3_600.0, headroom_s: 900.0, policy: DeferralPolicy::default() }
    }
}

impl DeferralSpec {
    /// Invariant check, run once per simulation at
    /// [`super::scenarios::Scenario::validate`] time (the forecast walk
    /// itself only debug-asserts on the hot path).
    pub fn validate(&self) -> Result<(), String> {
        if !self.slack_s.is_finite() || self.slack_s < 0.0 {
            return Err(format!("deferral slack must be finite and >= 0, got {}", self.slack_s));
        }
        if !self.headroom_s.is_finite() || self.headroom_s < 0.0 {
            return Err(format!(
                "deferral headroom must be finite and >= 0, got {}",
                self.headroom_s
            ));
        }
        self.policy.validate()
    }
}

/// Batch-formation service model (TensorFlow-Serving style): same-class
/// tasks dispatched to a node accumulate in a per-`(node, class)` queue
/// until the fill target is reached or the oldest member has waited out
/// the formation window, then execute as **one batch in one service
/// slot** on the node's sub-linear batch curves
/// ([`crate::node::NodeSpec::batch_latency_ms`] /
/// [`crate::node::NodeSpec::batch_dynamic_power_w`]). Batch energy is
/// settled once and apportioned equally across members. `window_ms: 0`
/// with `max_batch: 1` reproduces the one-task-per-slot model bit for
/// bit (`tests/sim.rs` asserts report equality per scenario).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchSpec {
    /// Longest time (ms) the oldest queued task waits before its batch
    /// seals regardless of fill. Zero seals every batch immediately.
    pub window_ms: f64,
    /// Fill target: a batch seals as soon as this many same-class tasks
    /// are queued, and never carries more.
    pub max_batch: usize,
}

impl Default for BatchSpec {
    fn default() -> BatchSpec {
        BatchSpec { window_ms: 200.0, max_batch: 8 }
    }
}

impl BatchSpec {
    /// Invariant check, run once per simulation at
    /// [`super::scenarios::Scenario::validate`] time.
    pub fn validate(&self) -> Result<(), String> {
        if !self.window_ms.is_finite() || self.window_ms < 0.0 {
            return Err(format!(
                "batch window must be finite and >= 0 ms, got {}",
                self.window_ms
            ));
        }
        if self.max_batch == 0 {
            return Err("batch fill target must be >= 1".into());
        }
        Ok(())
    }
}

/// Class-aware admission control for sustained overload: a fresh arrival
/// is shed — rejected before the scheduler runs — when even the
/// *least-loaded* visible node's queue-delay estimate exceeds the class's
/// tolerance `shed_queue_s × (1 + priority)`. Low-priority (0) classes
/// shed first; each priority step buys one extra multiple of the base
/// tolerance, so under a sustained overload the reject counts order
/// strictly by priority. Deferred releases and churn migrations are never
/// shed (their requests were already admitted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionSpec {
    /// Base queue-pressure tolerance (virtual seconds) for a
    /// priority-0 class.
    pub shed_queue_s: f64,
}

impl Default for AdmissionSpec {
    fn default() -> AdmissionSpec {
        AdmissionSpec { shed_queue_s: 10.0 }
    }
}

impl AdmissionSpec {
    /// Invariant check, run once per simulation at
    /// [`super::scenarios::Scenario::validate`] time.
    pub fn validate(&self) -> Result<(), String> {
        if !self.shed_queue_s.is_finite() || self.shed_queue_s <= 0.0 {
            return Err(format!(
                "admission shed_queue_s must be finite and > 0, got {}",
                self.shed_queue_s
            ));
        }
        Ok(())
    }
}

/// Engine knobs shared by every scenario.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Master seed: arrival and service streams are derived from it.
    pub seed: u64,
    /// Mean *real-executor* time per request (ms) fed into the node latency
    /// model — the paper's MobileNetV2 runs ≈ 9.6 ms of PJRT time.
    pub base_exec_ms: f64,
    /// Lognormal service jitter σ (0 = deterministic service times). The
    /// multiplier `exp(σ·N(0,1) − σ²/2)` is mean-preserving.
    pub jitter_sigma: f64,
    /// Power usage effectiveness for Eq. 2.
    pub pue: f64,
    /// Resource demand presented to the scheduler for every request.
    pub demand: TaskDemand,
    /// How often (virtual seconds) time-varying intensities are pushed into
    /// the scheduler-visible node state. Static traces are never refreshed.
    pub intensity_refresh_s: f64,
    /// Carbon-aware temporal deferral; `None` (the default) runs every
    /// arrival immediately, the pre-deferral behaviour.
    pub deferral: Option<DeferralSpec>,
    /// A/B twin switch: `true` rebuilds microgrid forecasts the legacy
    /// PR-4 way ([`crate::microgrid::Microgrid::frozen_intensity`] — the
    /// decision-time state of charge held constant, average-blend
    /// pricing) instead of simulating the SoC trajectory. Default
    /// `false`; only the `charge_frozen_twin` comparisons flip it.
    pub charge_frozen_forecasts: bool,
    /// Multi-tenant workload-class registry
    /// ([`crate::workload::WorkloadMix`]): per-class demand, SLO tier,
    /// model scale and arrival-mix weights, sampled per arrival from a
    /// dedicated seeded stream woven into the Poisson/MMPP generators.
    /// `None` (the default) runs the single-class legacy model: every
    /// request presents `demand` and class index 0.
    pub workload: Option<WorkloadMix>,
    /// Batched service model: when set, dispatch pushes tasks into
    /// per-`(node, class)` batch-formation queues instead of the plain
    /// FIFO, and sealed batches occupy one service slot each at the
    /// sub-linear batch latency/power point. `None` (the default) is
    /// the exact legacy one-task-per-slot path.
    pub batching: Option<BatchSpec>,
    /// Class-aware overload shedding ([`AdmissionSpec`]): reject fresh
    /// arrivals, lowest priority first, once queue pressure exceeds the
    /// class's tolerance. `None` (the default) admits everything and
    /// lets the scheduler decide — the legacy behaviour.
    pub admission: Option<AdmissionSpec>,
    /// Fold queued-but-unstarted work into the *projected* standing
    /// draw that prices microgrid effective intensities and SoC
    /// forecasts: a backlog will occupy the free service slots for the
    /// whole pricing window, so it counts toward the standing draw (up
    /// to capacity). Default `false` keeps the legacy in-service-only
    /// projection; accounting (microgrid settlement) always uses the
    /// actual draw either way.
    pub demand_aware_projections: bool,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            seed: 42,
            base_exec_ms: 9.6,
            jitter_sigma: 0.08,
            pue: crate::carbon::DEFAULT_PUE,
            demand: TaskDemand::default(),
            intensity_refresh_s: 60.0,
            deferral: None,
            charge_frozen_forecasts: false,
            workload: None,
            batching: None,
            admission: None,
            demand_aware_projections: false,
        }
    }
}

impl SimConfig {
    /// Invariant check for everything the engine's hot paths only
    /// debug-assert ([`super::scenarios::Scenario::validate`] calls it).
    pub fn validate(&self) -> Result<(), String> {
        if !self.base_exec_ms.is_finite() || self.base_exec_ms <= 0.0 {
            return Err(format!("base_exec_ms must be > 0, got {}", self.base_exec_ms));
        }
        if !self.jitter_sigma.is_finite() || self.jitter_sigma < 0.0 {
            return Err(format!("jitter_sigma must be >= 0, got {}", self.jitter_sigma));
        }
        if !self.pue.is_finite() || self.pue < 1.0 {
            return Err(format!("pue must be >= 1, got {}", self.pue));
        }
        if !self.intensity_refresh_s.is_finite() || self.intensity_refresh_s <= 0.0 {
            return Err(format!(
                "intensity_refresh_s must be > 0, got {}",
                self.intensity_refresh_s
            ));
        }
        if let Some(d) = &self.deferral {
            d.validate()?;
        }
        if let Some(b) = &self.batching {
            b.validate()?;
        }
        if let Some(w) = &self.workload {
            w.validate()?;
        }
        if let Some(a) = &self.admission {
            a.validate()?;
        }
        Ok(())
    }
}

/// Open-loop request arrival process in virtual time.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Equally spaced arrivals at `rate_hz`.
    Uniform { rate_hz: f64 },
    /// Poisson arrivals at `rate_hz`.
    Poisson { rate_hz: f64 },
    /// Two-state Markov-modulated Poisson process: dwell times are
    /// exponential with mean `mean_dwell_s`, arrivals are Poisson at the
    /// current state's rate. Models bursty edge traffic.
    Mmpp { rate_low_hz: f64, rate_high_hz: f64, mean_dwell_s: f64 },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate (Hz).
    pub fn mean_rate_hz(&self) -> f64 {
        match self {
            ArrivalProcess::Uniform { rate_hz } | ArrivalProcess::Poisson { rate_hz } => *rate_hz,
            // Equal mean dwell in both states -> equal time share.
            ArrivalProcess::Mmpp { rate_low_hz, rate_high_hz, .. } => {
                (rate_low_hz + rate_high_hz) / 2.0
            }
        }
    }
}

/// Stateful gap generator for one run.
struct ArrivalGen {
    process: ArrivalProcess,
    rng: Rng,
    /// MMPP state: currently in the high-rate burst state?
    high: bool,
    /// MMPP: virtual seconds left in the current state.
    dwell_left_s: f64,
}

impl ArrivalGen {
    fn new(process: ArrivalProcess, seed: u64) -> ArrivalGen {
        let mut rng = Rng::new(seed);
        let dwell_left_s = match &process {
            ArrivalProcess::Mmpp { mean_dwell_s, .. } => {
                debug_assert!(*mean_dwell_s > 0.0, "MMPP dwell must be positive");
                rng.exp(1.0 / mean_dwell_s)
            }
            _ => 0.0,
        };
        ArrivalGen { process, rng, high: false, dwell_left_s }
    }

    fn next_gap_s(&mut self) -> f64 {
        match self.process {
            ArrivalProcess::Uniform { rate_hz } => {
                // Demoted: Scenario::validate rejects non-positive rates.
                debug_assert!(rate_hz > 0.0);
                1.0 / rate_hz
            }
            ArrivalProcess::Poisson { rate_hz } => self.rng.exp(rate_hz),
            ArrivalProcess::Mmpp { rate_low_hz, rate_high_hz, mean_dwell_s } => {
                let mut elapsed = 0.0;
                loop {
                    let rate = if self.high { rate_high_hz } else { rate_low_hz };
                    let gap = self.rng.exp(rate);
                    if gap <= self.dwell_left_s {
                        self.dwell_left_s -= gap;
                        return elapsed + gap;
                    }
                    // Advance to the state switch and resample (memoryless).
                    elapsed += self.dwell_left_s;
                    self.dwell_left_s = self.rng.exp(1.0 / mean_dwell_s);
                    self.high = !self.high;
                }
            }
        }
    }
}

/// A node joining or leaving the fleet at a virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    pub at_s: f64,
    pub node: usize,
    pub up: bool,
}

enum EventKind {
    Arrival,
    /// A deferred request released at the slot the scheduler's verdict
    /// chose: re-decided against fresh intensities with *no forecast
    /// context*, so no scheduler can re-defer it (a parked task can never
    /// livelock). The release re-runs routing, so a task parked for one
    /// node's trough may land elsewhere if the fleet shifted meanwhile —
    /// the min-gain threshold is enforced at decision time, not at
    /// execution.
    DeferredRelease { arrival_s: f64, deadline_s: f64, class: usize, site: usize },
    /// A WAN-shipped request landing at its target site after the link
    /// latency: admitted there with its *original* arrival timestamp, so
    /// the hop sits inside end-to-end latency (transfer energy/carbon
    /// were already paid at the origin when the hop was emitted).
    WanArrival { site: usize, arrival_s: f64, deadline_s: f64, class: usize },
    Completion {
        node: usize,
        class: usize,
        arrival_s: f64,
        deadline_s: f64,
        service_ms: f64,
        energy_j: f64,
    },
    /// Batch-formation window expiry for `(node, class)`. `gen` guards
    /// staleness: sealing a batch bumps the generation, so a timer
    /// scheduled for an already-dispatched batch is a no-op.
    BatchTimer { node: usize, class: usize, gen: u64 },
    /// A sealed batch finishing service: the slot frees, `dyn_w` leaves
    /// the node's active draw, and each `(arrival_s, deadline_s)` member
    /// settles an equal share of the batch energy.
    BatchComplete {
        node: usize,
        class: usize,
        service_ms: f64,
        dyn_w: f64,
        tasks: Vec<(f64, f64)>,
    },
    Churn { node: usize, up: bool },
}

/// One task waiting in a batch-formation queue (batched path only).
struct BatchTask {
    arrival_s: f64,
    deadline_s: f64,
    /// When the task entered this node's formation queue — later than
    /// `arrival_s` for deferred or migrated work. The formation-window
    /// clock runs from the *head* member's enqueue time.
    enqueue_s: f64,
}

struct Event {
    t_s: f64,
    seq: u64,
    kind: EventKind,
}

// BinaryHeap is a max-heap; compare reversed on (time, seq) so the earliest
// event pops first and ties resolve in insertion order — the total order
// that makes the simulation deterministic.
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.t_s.total_cmp(&self.t_s).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Event {}

/// Per-site aggregates behind [`Simulation::site_views`], maintained so
/// the router sees O(sites) summaries instead of an O(total-nodes)
/// snapshot per arrival: membership-derived terms are rebuilt only on
/// churn, the intensity sum piggybacks on the throttled refresh.
#[derive(Debug, Clone, Copy, Default)]
struct SiteAgg {
    /// Active (powered-on) nodes at the site.
    active: usize,
    /// Aggregate service slots across active nodes.
    slots: usize,
    /// Mean single-task service estimate across active nodes (s).
    est_service_s: f64,
    /// Mean task dynamic energy across active nodes (J).
    task_energy_j: f64,
    /// Sum of scheduler-visible effective intensities over active nodes.
    intensity_sum: f64,
}

/// One simulation run over a [`Scenario`].
pub struct Simulation<'a> {
    sc: &'a Scenario,
    nodes: Vec<Arc<EdgeNode>>,
    active: Vec<bool>,
    /// Active-node cache: fleet-view position → global node index
    /// (rebuilt only on churn, so the per-request hot path never rescans
    /// the `active` table). `SchedulingDecision::Assign` indexes map back
    /// through it.
    cache_idx: Vec<usize>,
    /// Per-node FIFO of waiting requests (legacy one-task-per-slot
    /// path): `(arrival_s, deadline_s, class)`.
    queues: Vec<VecDeque<(f64, f64, usize)>>,
    /// Batch-formation queues, `[node][class]` — only populated when
    /// `SimConfig::batching` is set (the two queue families are
    /// mutually exclusive per run).
    bqueues: Vec<Vec<VecDeque<BatchTask>>>,
    /// Formation-timer generation per `[node][class]`, bumped on every
    /// seal so stale [`EventKind::BatchTimer`]s no-op.
    bt_gen: Vec<Vec<u64>>,
    /// Whether a formation timer is outstanding per `[node][class]`.
    bt_sched: Vec<Vec<bool>>,
    /// Slots in service, not tasks: a sealed batch of any fill occupies
    /// exactly one.
    in_service: Vec<usize>,
    /// Sum of the per-batch dynamic power points currently in service
    /// per node (W) — the actual draw the microgrid settlement bills on
    /// the batched path. The legacy path derives its draw as
    /// `in_service × dynamic_power_w` exactly as before.
    active_dyn_w: Vec<f64>,
    heap: BinaryHeap<Event>,
    seq: u64,
    service_rng: Rng,
    /// Workload-class sampling stream, drawn once per arrival — and only
    /// when a [`WorkloadMix`] is configured, so legacy runs consume
    /// nothing from it.
    class_rng: Rng,
    /// Per-class constants resolved once from the mix (single-element
    /// defaults for legacy runs: scale 1, SLO ∞, priority 0).
    n_classes: usize,
    class_exec_scale: Vec<f64>,
    class_slo_s: Vec<f64>,
    class_priority: Vec<u8>,
    /// Per-class accounting, indexed by class. Maintained on every run
    /// (class 0 absorbs everything without a mix) but reported only
    /// when a mix is configured.
    class_completed: Vec<u64>,
    class_rejected: Vec<u64>,
    class_slo_missed: Vec<u64>,
    class_batches: Vec<u64>,
    class_latency_ms: Vec<Vec<f64>>,
    class_energy_j: Vec<f64>,
    class_carbon_g: Vec<f64>,
    /// Per-node *dynamic* energy/carbon/task totals, indexed by node id —
    /// the per-completion hot path must not pay a string-keyed map lookup.
    node_ledger: Vec<LedgerEntry>,
    /// Idle-floor accounting: when the node last powered on (None = down),
    /// plus accumulated uptime / idle energy / idle carbon.
    up_since: Vec<Option<f64>>,
    uptime_s: Vec<f64>,
    idle_energy_j: Vec<f64>,
    idle_carbon_g: Vec<f64>,
    /// Per-node microgrid runtime state (`None` = grid-only node).
    microgrids: Vec<Option<Microgrid>>,
    /// Virtual time each node's microgrid supply ledger is settled to.
    mg_settled_s: Vec<f64>,
    /// Per-node supply splits (J): PV consumed directly, battery
    /// discharge, and grid import. Grid-only nodes never touch these.
    pv_energy_j: Vec<f64>,
    battery_energy_j: Vec<f64>,
    grid_energy_j: Vec<f64>,
    /// Grid energy imported *into the battery* per node (J, input side) —
    /// the arbitrage flow, outside the supply-conservation identity.
    grid_charge_energy_j: Vec<f64>,
    /// Embodied carbon bought into each node's store over the run
    /// (grams, PUE applied).
    charge_carbon_g: Vec<f64>,
    /// Embodied carbon released by battery discharge per node (grams, PUE
    /// applied) — a labelled subset of the idle/dynamic carbon ledgers,
    /// kept so the stored-carbon balance `charged == released + stored`
    /// is checkable from the report.
    battery_carbon_g: Vec<f64>,
    /// `(t, state-of-charge fraction)` samples per microgrid node, taken
    /// at every intensity refresh plus the horizon.
    soc_timeline: Vec<Vec<(f64, f64)>>,
    /// `(t, projected soc)` one-refresh-ahead projections per microgrid
    /// node (recorded when deferral is on and forecasts are trajectory-
    /// based) — the projected-vs-actual diagnostic in the report/JSON.
    soc_projection: Vec<Vec<(f64, f64)>>,
    /// Queue-delay estimates (ms) sampled per node at every dispatch — the
    /// value the fleet view advertised for the chosen node at decision
    /// time (backlog × mean service ÷ service slots).
    queue_delay_ms: Vec<Vec<f64>>,
    latency_ms: Vec<f64>,
    wait_ms: Vec<f64>,
    energy_total_j: f64,
    carbon_total_g: f64,
    arrived: u64,
    completed: u64,
    rejected: u64,
    migrated: u64,
    deferred: u64,
    deadline_missed: u64,
    makespan_s: f64,
    /// Timestamp of the last event processed — the horizon idle-floor
    /// accrual runs to (events pop in time order, so this is monotone).
    t_last: f64,
    last_refresh_s: f64,
    /// Observability ([`crate::obs`]): trace sink and telemetry registry,
    /// both present only on the [`Simulation::try_run_observed`] path.
    /// Every emission site branches on `observing()` first, so the
    /// unobserved hot paths construct nothing and read no clock.
    sink: Option<&'a mut dyn EventSink>,
    telem: Option<Telemetry>,
    /// In-sim monitor rules ([`Simulation::try_run_monitored`]): every
    /// emitted event is folded into sliding virtual-time windows and
    /// threshold crossings fire [`TraceEvent::Alert`]s back into the
    /// firehose. `None` on every other path — no window, no rule, nothing
    /// constructed.
    monitors: Option<MonitorSet>,
    /// Geographic layer ([`crate::site`]) runtime state. All of it is
    /// empty/`None` on flat fleets, so every `site_caches.is_empty()`
    /// guard below is a dead branch and legacy runs stay bit-identical.
    /// Node → site index (scenario [`crate::site::SiteLayer::site_of`]).
    site_of: Vec<usize>,
    /// Per-site active-node caches: the site-scoped analogue of
    /// `cache_idx`, rebuilt beside it on churn.
    site_caches: Vec<Vec<usize>>,
    /// The cross-site router instance, built from the scenario's
    /// [`crate::site::RouterSpec`].
    router: Option<Box<dyn Router>>,
    /// Home-site sampling stream — its own seed derivation, drawn once
    /// per arrival and only when sites are configured, so the legacy
    /// arrival/service/class streams never shift.
    home_rng: Rng,
    /// Scheduler-visible effective intensity per node, mirrored on every
    /// refresh so site means never re-observe nodes.
    node_eff: Vec<f64>,
    /// Static per-node single-task service estimate (s) at the
    /// scenario's base exec time.
    node_est_service_s: Vec<f64>,
    site_agg: Vec<SiteAgg>,
    /// Tasks dispatched and not yet completed per site (queued + forming
    /// + in service) — the router's queue-pressure input.
    site_outstanding: Vec<usize>,
    /// WAN ledgers, indexed by site. Transfer energy/carbon are
    /// attributed to the *origin* site (its grid powers the egress) and
    /// live outside the per-node ledgers.
    site_shipped_out: Vec<u64>,
    site_shipped_in: Vec<u64>,
    site_wan_energy_j: Vec<f64>,
    site_wan_carbon_g: Vec<f64>,
}

impl<'a> Simulation<'a> {
    /// Run `scenario` under `scheduler` and return the aggregated report.
    /// Node state is built fresh from the scenario specs, so identical
    /// (scenario, seed, fresh scheduler) triples produce identical reports.
    ///
    /// When the scenario configures a [`DeferralSpec`] and the scheduler
    /// does not defer on its own, it is wrapped in the legacy
    /// [`RouteThenDefer`] gate (route first, then park for the chosen
    /// node's cleanest forecast slot) — the report keeps the inner
    /// scheduler's name, so historical runs stay comparable.
    pub fn run(scenario: &'a Scenario, scheduler: &mut dyn Scheduler) -> SimReport {
        match Simulation::try_run(scenario, scheduler) {
            Ok(report) => report,
            Err(e) => panic!("invalid scenario {:?}: {e}", scenario.name),
        }
    }

    /// Like [`Simulation::run`], but surfaces invalid scenarios as an
    /// `Err` instead of panicking: every invariant the engine's hot paths
    /// only debug-assert ([`Scenario::validate`]) is checked once here,
    /// before any event is processed. The CLI routes through this so bad
    /// input is a clean error, never a mid-simulation panic.
    pub fn try_run(
        scenario: &'a Scenario,
        scheduler: &mut dyn Scheduler,
    ) -> Result<SimReport, String> {
        scenario.validate()?;
        let name = scheduler.name().to_string();
        let (report, _) = match &scenario.config.deferral {
            Some(d) if !scheduler.defers() => {
                let mut gate = RouteThenDefer::new(scheduler, d.policy.clone());
                Simulation::run_inner(scenario, &mut gate, &name, None, None)
            }
            _ => Simulation::run_inner(scenario, scheduler, &name, None, None),
        };
        Ok(report)
    }

    /// Like [`Simulation::try_run`], but with observability attached: every
    /// arrival, scheduling verdict, dispatch, deferred release, completion,
    /// churn transition and microgrid settlement slice is emitted to `sink`
    /// as a [`TraceEvent`], and an in-process [`Telemetry`] registry
    /// (event counters, queue-delay / latency / per-decision-overhead
    /// histograms) is returned beside the report. Scheduler calls route
    /// through [`Scheduler::decide_explained`] when the sink keeps
    /// decision events, so firehose lines carry the per-candidate
    /// rationale. Tracing never perturbs the run: the report is
    /// bit-identical to what [`Simulation::try_run`] produces.
    pub fn try_run_observed(
        scenario: &'a Scenario,
        scheduler: &mut dyn Scheduler,
        sink: &'a mut dyn EventSink,
    ) -> Result<(SimReport, Telemetry), String> {
        scenario.validate()?;
        let name = scheduler.name().to_string();
        let (report, telem) = match &scenario.config.deferral {
            Some(d) if !scheduler.defers() => {
                let mut gate = RouteThenDefer::new(scheduler, d.policy.clone());
                Simulation::run_inner(scenario, &mut gate, &name, Some(sink), None)
            }
            _ => Simulation::run_inner(scenario, scheduler, &name, Some(sink), None),
        };
        // lint: allow(P1 run_inner always collects telemetry when a sink is passed)
        Ok((report, telem.expect("observed run always collects telemetry")))
    }

    /// Like [`Simulation::try_run_observed`], but with an in-sim
    /// [`MonitorSet`] evaluated on every emitted event: sliding
    /// virtual-time windows track carbon burn-rate, per-class SLO-miss
    /// burn and reject/defer rate, threshold crossings fire
    /// [`TraceEvent::Alert`] events into the sink, and the per-rule
    /// summaries land in both the returned [`Telemetry`] and the report's
    /// `monitors` field. Monitoring is deterministic — rules read virtual
    /// time only — so every other report field stays bit-identical to the
    /// unmonitored run.
    pub fn try_run_monitored(
        scenario: &'a Scenario,
        scheduler: &mut dyn Scheduler,
        sink: &'a mut dyn EventSink,
        monitors: MonitorSet,
    ) -> Result<(SimReport, Telemetry), String> {
        scenario.validate()?;
        let name = scheduler.name().to_string();
        let (report, telem) = match &scenario.config.deferral {
            Some(d) if !scheduler.defers() => {
                let mut gate = RouteThenDefer::new(scheduler, d.policy.clone());
                Simulation::run_inner(scenario, &mut gate, &name, Some(sink), Some(monitors))
            }
            _ => {
                Simulation::run_inner(scenario, scheduler, &name, Some(sink), Some(monitors))
            }
        };
        // lint: allow(P1 run_inner always collects telemetry when a sink is passed)
        Ok((report, telem.expect("observed run always collects telemetry")))
    }

    fn run_inner(
        scenario: &'a Scenario,
        scheduler: &mut dyn Scheduler,
        scheduler_name: &str,
        sink: Option<&'a mut dyn EventSink>,
        monitors: Option<MonitorSet>,
    ) -> (SimReport, Option<Telemetry>) {
        let n = scenario.specs.len();
        debug_assert!(scenario.validate().is_ok());
        let microgrids: Vec<Option<Microgrid>> = if scenario.microgrids.is_empty() {
            (0..n).map(|_| None).collect()
        } else {
            scenario.microgrids.iter().map(|m| m.clone().map(Microgrid::new)).collect()
        };
        let soc_timeline = microgrids
            .iter()
            .map(|m| match m {
                Some(mg) => vec![(0.0, mg.soc_frac())],
                None => Vec::new(),
            })
            .collect();

        let (class_exec_scale, class_slo_s, class_priority): (Vec<f64>, Vec<f64>, Vec<u8>) =
            match &scenario.config.workload {
                Some(mix) => (
                    mix.classes.iter().map(|c| c.exec_scale).collect(),
                    mix.classes.iter().map(|c| c.slo_s).collect(),
                    mix.classes.iter().map(|c| c.priority).collect(),
                ),
                None => (vec![1.0], vec![f64::INFINITY], vec![0]),
            };
        let n_classes = class_exec_scale.len();
        let n_sites = scenario.sites.as_ref().map(|l| l.sites.len()).unwrap_or(0);

        let mut sim = Simulation {
            sc: scenario,
            nodes: scenario.specs.iter().cloned().map(EdgeNode::new).collect(),
            active: vec![true; n],
            cache_idx: Vec::new(),
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            bqueues: (0..n).map(|_| (0..n_classes).map(|_| VecDeque::new()).collect()).collect(),
            bt_gen: vec![vec![0; n_classes]; n],
            bt_sched: vec![vec![false; n_classes]; n],
            in_service: vec![0; n],
            active_dyn_w: vec![0.0; n],
            heap: BinaryHeap::new(),
            seq: 0,
            service_rng: Rng::new(scenario.config.seed ^ 0x5DEECE66D),
            class_rng: Rng::new(scenario.config.seed ^ 0xC1A55),
            n_classes,
            class_exec_scale,
            class_slo_s,
            class_priority,
            class_completed: vec![0; n_classes],
            class_rejected: vec![0; n_classes],
            class_slo_missed: vec![0; n_classes],
            class_batches: vec![0; n_classes],
            class_latency_ms: (0..n_classes).map(|_| Vec::new()).collect(),
            class_energy_j: vec![0.0; n_classes],
            class_carbon_g: vec![0.0; n_classes],
            node_ledger: vec![LedgerEntry::default(); n],
            up_since: vec![Some(0.0); n],
            uptime_s: vec![0.0; n],
            idle_energy_j: vec![0.0; n],
            idle_carbon_g: vec![0.0; n],
            microgrids,
            mg_settled_s: vec![0.0; n],
            pv_energy_j: vec![0.0; n],
            battery_energy_j: vec![0.0; n],
            grid_energy_j: vec![0.0; n],
            grid_charge_energy_j: vec![0.0; n],
            charge_carbon_g: vec![0.0; n],
            battery_carbon_g: vec![0.0; n],
            soc_timeline,
            soc_projection: (0..n).map(|_| Vec::new()).collect(),
            queue_delay_ms: (0..n).map(|_| Vec::new()).collect(),
            latency_ms: Vec::with_capacity(scenario.requests),
            wait_ms: Vec::with_capacity(scenario.requests),
            energy_total_j: 0.0,
            carbon_total_g: 0.0,
            arrived: 0,
            completed: 0,
            rejected: 0,
            migrated: 0,
            deferred: 0,
            deadline_missed: 0,
            makespan_s: 0.0,
            t_last: 0.0,
            last_refresh_s: f64::NEG_INFINITY,
            telem: sink.as_ref().map(|_| Telemetry::new()),
            sink,
            monitors,
            site_of: scenario.sites.as_ref().map(|l| l.site_of.clone()).unwrap_or_default(),
            site_caches: vec![Vec::new(); n_sites],
            router: scenario.sites.as_ref().map(|l| l.router.build()),
            home_rng: Rng::new(scenario.config.seed ^ 0x517E5),
            node_eff: scenario.specs.iter().map(|s| s.intensity).collect(),
            node_est_service_s: scenario
                .specs
                .iter()
                .map(|s| s.simulate_latency_ms(scenario.config.base_exec_ms) / 1e3)
                .collect(),
            site_agg: vec![SiteAgg::default(); n_sites],
            site_outstanding: vec![0; n_sites],
            site_shipped_out: vec![0; n_sites],
            site_shipped_in: vec![0; n_sites],
            site_wan_energy_j: vec![0.0; n_sites],
            site_wan_carbon_g: vec![0.0; n_sites],
        };
        sim.rebuild_cache();
        if sim.observing() {
            // Run header first on the stream: everything a replay needs
            // that the event flow itself cannot carry (node/class rosters,
            // seed, declared request count). Built purely from the
            // scenario so no engine state is borrowed.
            let node_meta: Vec<(&str, bool)> = scenario
                .specs
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    (s.name.as_str(), scenario.microgrids.get(i).is_some_and(|m| m.is_some()))
                })
                .collect();
            let class_meta: Vec<(&str, f64)> = match &scenario.config.workload {
                Some(mix) => {
                    mix.classes.iter().map(|c| (c.name.as_str(), c.slo_s)).collect()
                }
                None => Vec::new(),
            };
            let site_meta: Vec<&str> = match &scenario.sites {
                Some(layer) => layer.sites.iter().map(|s| s.name.as_str()).collect(),
                None => Vec::new(),
            };
            let site_of_meta: &[usize] = match &scenario.sites {
                Some(layer) => &layer.site_of,
                None => &[],
            };
            let router_meta =
                scenario.sites.as_ref().map(|l| l.router.name()).unwrap_or("");
            sim.emit(&TraceEvent::RunMeta {
                scenario: &scenario.name,
                scheduler: scheduler_name,
                seed: scenario.config.seed,
                requests: scenario.requests as u64,
                nodes: &node_meta,
                classes: &class_meta,
                sites: &site_meta,
                site_of: site_of_meta,
                router: router_meta,
            });
        }

        for ev in &scenario.churn {
            debug_assert!(ev.node < n, "churn event names node {} of {}", ev.node, n);
            sim.push(ev.at_s, EventKind::Churn { node: ev.node, up: ev.up });
        }

        let mut arrivals = ArrivalGen::new(scenario.arrivals.clone(), scenario.config.seed);
        if scenario.requests > 0 {
            let first = arrivals.next_gap_s();
            sim.push(first, EventKind::Arrival);
        }

        while let Some(ev) = sim.heap.pop() {
            let t = ev.t_s;
            sim.t_last = sim.t_last.max(t);
            match ev.kind {
                EventKind::Arrival => {
                    sim.arrived += 1;
                    sim.refresh_intensities(t);
                    // The class draw happens only under a configured mix,
                    // so legacy runs consume nothing from the stream.
                    let class = match &scenario.config.workload {
                        Some(mix) => mix.sample(sim.class_rng.f64()),
                        None => 0,
                    };
                    let deadline = match &sim.sc.config.deferral {
                        Some(d) => t + d.slack_s,
                        None => f64::INFINITY,
                    };
                    if sim.observing() {
                        sim.emit(&TraceEvent::Arrival { t_s: t, deadline_s: deadline, class });
                    }
                    sim.route_and_admit(t, deadline, class, scheduler);
                    if sim.arrived < scenario.requests as u64 {
                        let gap = arrivals.next_gap_s();
                        sim.push(t + gap, EventKind::Arrival);
                    }
                }
                EventKind::DeferredRelease { arrival_s, deadline_s, class, site } => {
                    sim.refresh_intensities(t);
                    if sim.observing() {
                        sim.emit(&TraceEvent::DeferRelease { t_s: t, arrival_s, deadline_s });
                    }
                    sim.admit(arrival_s, t, deadline_s, false, class, site, scheduler);
                }
                EventKind::WanArrival { site, arrival_s, deadline_s, class } => {
                    sim.refresh_intensities(t);
                    sim.admit(arrival_s, t, deadline_s, true, class, site, scheduler);
                }
                EventKind::Completion {
                    node,
                    class,
                    arrival_s,
                    deadline_s,
                    service_ms,
                    energy_j,
                } => {
                    sim.complete(node, class, t, arrival_s, deadline_s, service_ms, energy_j);
                }
                EventKind::BatchTimer { node, class, gen } => {
                    // A stale generation means the batch this timer was
                    // armed for already sealed (fill or churn): no-op.
                    if sim.bt_gen[node][class] == gen {
                        sim.bt_sched[node][class] = false;
                        sim.try_dispatch_batches(node, t);
                    }
                }
                EventKind::BatchComplete { node, class, service_ms, dyn_w, tasks } => {
                    sim.complete_batch(node, class, t, service_ms, dyn_w, tasks);
                }
                EventKind::Churn { node, up } => {
                    sim.churn(node, up, t, scheduler);
                }
            }
        }

        sim.close_horizon();
        let summaries = sim.monitors.take().map(|m| m.summaries()).unwrap_or_default();
        if let Some(t) = sim.telem.as_mut() {
            t.monitors = summaries.clone();
        }
        let telem = sim.telem.take();
        let mut report = sim.into_report(scheduler_name);
        report.monitors = summaries;
        (report, telem)
    }

    /// Whether this run has an observer attached — the single branch every
    /// emission site pays on the unobserved path.
    #[inline]
    fn observing(&self) -> bool {
        self.sink.is_some()
    }

    /// Count `ev` in the telemetry registry (pre-filter, so conservation
    /// checks see every event), fold it into any attached monitor rules,
    /// and hand it to the sink. Threshold crossings the fold produced are
    /// drained afterwards as [`TraceEvent::Alert`]s — counted and
    /// recorded like any event, but never fed back into the monitors, so
    /// alerting cannot recurse. Call only behind an `observing()` check
    /// so the unobserved path constructs nothing.
    fn emit(&mut self, ev: &TraceEvent<'_>) {
        if let Some(t) = self.telem.as_mut() {
            t.count(ev.kind());
        }
        if let Some(m) = self.monitors.as_mut() {
            m.observe(ev);
        }
        if let Some(sink) = self.sink.as_deref_mut() {
            sink.record(ev);
        }
        while let Some(fire) = self.monitors.as_mut().and_then(|m| m.pop_fire()) {
            let alert = TraceEvent::Alert {
                t_s: fire.t_s,
                rule: fire.rule,
                value: fire.value,
                threshold: fire.threshold,
                window_s: fire.window_s,
                class: fire.class,
            };
            if let Some(t) = self.telem.as_mut() {
                t.count(TraceKind::Alert);
            }
            if let Some(sink) = self.sink.as_deref_mut() {
                sink.record(&alert);
            }
        }
    }

    fn push(&mut self, t_s: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { t_s, seq, kind });
    }

    fn rebuild_cache(&mut self) {
        self.cache_idx.clear();
        for i in 0..self.nodes.len() {
            if self.active[i] {
                self.cache_idx.push(i);
            }
        }
        if !self.site_caches.is_empty() {
            for cache in self.site_caches.iter_mut() {
                cache.clear();
            }
            for (g, &s) in self.site_of.iter().enumerate() {
                if self.active[g] {
                    self.site_caches[s].push(g);
                }
            }
            self.rebuild_site_aggs();
        }
    }

    /// Recompute the per-site aggregates behind [`Simulation::site_views`]
    /// from scratch — O(total nodes), paid only at init and on churn.
    fn rebuild_site_aggs(&mut self) {
        for s in 0..self.site_caches.len() {
            let members = &self.site_caches[s];
            let active = members.len();
            let mut slots = 0usize;
            let mut est_sum = 0.0;
            let mut task_w_sum = 0.0;
            let mut intensity_sum = 0.0;
            for &g in members {
                slots += self.sc.capacity[g];
                est_sum += self.node_est_service_s[g];
                task_w_sum += self.sc.specs[g].dynamic_power_w();
                intensity_sum += self.node_eff[g];
            }
            let est_service_s = if active > 0 { est_sum / active as f64 } else { 0.0 };
            let task_w = if active > 0 { task_w_sum / active as f64 } else { 0.0 };
            self.site_agg[s] = SiteAgg {
                active,
                slots,
                est_service_s,
                task_energy_j: task_w * est_service_s,
                intensity_sum,
            };
        }
    }

    /// Push time-varying intensities into scheduler-visible node state,
    /// throttled to `intensity_refresh_s` of virtual time. Static traces
    /// never need a refresh (the spec value already applies).
    fn refresh_intensities(&mut self, t_s: f64) {
        if t_s - self.last_refresh_s < self.sc.config.intensity_refresh_s {
            return;
        }
        self.force_refresh_intensities(t_s);
    }

    /// Unthrottled refresh — used where stale intensities would silently
    /// misroute a *batch* of work (churn migration re-dispatch). Microgrid
    /// nodes refresh even on static grids (their effective intensity moves
    /// with sunlight and state of charge, not just the grid), get their
    /// supply ledger settled to `t_s` first so the SoC is current, and
    /// record an SoC timeline sample (plus, when trajectory forecasts are
    /// on, a one-refresh-ahead SoC projection for the projected-vs-actual
    /// diagnostic).
    fn force_refresh_intensities(&mut self, t_s: f64) {
        self.last_refresh_s = t_s;
        // Advertising window for the battery term of the marginal
        // intensity: the scheduler acts on this price until the next
        // refresh, so the battery may only advertise power its charge can
        // sustain that long.
        let sustain_s = self.sc.config.intensity_refresh_s.max(1.0);
        let sc = self.sc;
        let project_soc =
            sc.config.deferral.is_some() && !sc.config.charge_frozen_forecasts;
        for g in 0..sc.specs.len() {
            self.settle_microgrid(g, t_s);
            let draw = self.node_draw(g);
            if let Some(mg) = &mut self.microgrids[g] {
                let eff = mg.advertised_intensity(&sc.traces[g], t_s, draw, sustain_s);
                self.nodes[g].set_intensity(eff);
                self.node_eff[g] = eff;
                self.soc_timeline[g].push((t_s, mg.soc_frac()));
                if project_soc {
                    // One settlement step ahead at the standing draw: the
                    // engine's own forecast of the next timeline sample.
                    let target = t_s + sc.config.intensity_refresh_s;
                    let proj = mg.project(
                        t_s,
                        target,
                        draw,
                        &sc.traces[g],
                        sc.config.intensity_refresh_s,
                        sustain_s,
                    );
                    if let Some(&(pt, _, soc)) = proj.last() {
                        self.soc_projection[g].push((pt, soc));
                    }
                }
            } else if !matches!(sc.traces[g], IntensityTrace::Static(_)) {
                let eff = sc.traces[g].at(t_s);
                self.nodes[g].set_intensity(eff);
                self.node_eff[g] = eff;
            }
        }
        // Fold the fresh intensities into the per-site means — O(active)
        // inside an already-O(n) throttled walk.
        if !self.site_caches.is_empty() {
            for s in 0..self.site_caches.len() {
                self.site_agg[s].intensity_sum =
                    self.site_caches[s].iter().map(|&g| self.node_eff[g]).sum();
            }
        }
    }

    /// The draw profile node `g` is priced at right now: local supply
    /// serves the standing draw (idle floor while powered on + work in
    /// service) first, and the marginal price is what the next task's
    /// dynamic watts would pay. With `demand_aware_projections` the
    /// queued backlog counts toward the standing draw too (it will
    /// occupy the free service slots for the whole pricing window, up
    /// to capacity); the batched path otherwise prices its actual
    /// per-batch power sum. Projection only — settlement bills the
    /// actual draw regardless.
    fn node_draw(&self, g: usize) -> crate::microgrid::NodeDraw {
        let spec = &self.sc.specs[g];
        let idle_w = if self.up_since[g].is_some() { spec.idle_w } else { 0.0 };
        let dyn_standing_w = if self.sc.config.demand_aware_projections {
            let queued: usize = if self.sc.config.batching.is_some() {
                self.bqueues[g].iter().map(|q| q.len()).sum()
            } else {
                self.queues[g].len()
            };
            (self.in_service[g] + queued).min(self.sc.capacity[g]) as f64
                * spec.dynamic_power_w()
        } else if self.sc.config.batching.is_some() {
            self.active_dyn_w[g]
        } else {
            self.in_service[g] as f64 * spec.dynamic_power_w()
        };
        crate::microgrid::NodeDraw {
            standing_w: idle_w + dyn_standing_w,
            task_w: spec.dynamic_power_w(),
            rated_w: spec.rated_power_w,
        }
    }

    /// Advance node `g`'s microgrid supply ledger to `until_s` at the
    /// node's *current* draw (idle floor while powered on + per-task
    /// dynamic power), covering it PV-first, then battery, then grid.
    /// Grid-supplied joules are priced at the slice-mean grid intensity
    /// and attributed to the idle / dynamic carbon ledgers in proportion
    /// to their share of the slice draw. Must run *before* any change to
    /// `in_service[g]` or the node's power state, so every slice is billed
    /// at the draw that actually applied.
    ///
    /// The interval is covered in chunks of at most
    /// [`MG_SETTLE_MAX_SLICE_S`]: `cover` nets PV against demand uniformly
    /// within one slice, so an unbounded slice across a sparse-event gap
    /// would let PV generated after sunrise retroactively supply pre-dawn
    /// draw (and price grid import at a mean over hours of grid swing).
    /// The draw is constant across the whole interval by the call
    /// discipline above, so chunking changes only the supply/pricing
    /// resolution, never the demand.
    fn settle_microgrid(&mut self, g: usize, until_s: f64) {
        if self.microgrids[g].is_none() {
            return;
        }
        if until_s - self.mg_settled_s[g] <= 0.0 {
            return;
        }
        let sc = self.sc;
        let idle_w = if self.up_since[g].is_some() { sc.specs[g].idle_w } else { 0.0 };
        // Actual draw, never the projection: per-batch power sums on the
        // batched path, slot count × per-task power on the legacy one.
        let dyn_w = if sc.config.batching.is_some() {
            self.active_dyn_w[g]
        } else {
            self.in_service[g] as f64 * sc.specs[g].dynamic_power_w()
        };
        let draw_w = idle_w + dyn_w;
        let idle_share = if draw_w > 0.0 { idle_w / draw_w } else { 0.0 };
        while self.mg_settled_s[g] < until_s {
            let t0 = self.mg_settled_s[g];
            let t1 = (t0 + MG_SETTLE_MAX_SLICE_S).min(until_s);
            self.mg_settled_s[g] = t1;
            // lint: allow(P1 settle_microgrid early-returns when the node has no microgrid)
            let mg = self.microgrids[g].as_mut().unwrap();
            let flow = mg.settle(t0, t1, draw_w, &sc.traces[g]);
            self.pv_energy_j[g] += flow.pv_j;
            self.battery_energy_j[g] += flow.battery_j;
            self.grid_energy_j[g] += flow.grid_j;
            self.grid_charge_energy_j[g] += flow.grid_charge_j;
            // Embodied carbon bought into the store (priced at the slice
            // mean inside settle): tracked, but billed only on discharge.
            self.charge_carbon_g[g] += sc.config.pue * flow.charge_carbon_g;
            // Direct grid supply bears the slice-mean grid intensity;
            // battery discharge bears the store's embodied intensity.
            // Both split idle/dynamic by draw share.
            let mut carbon = 0.0;
            if flow.grid_j > 0.0 {
                let mean_intensity = sc.traces[g].integral(t0, t1) / (t1 - t0);
                carbon += sc.config.pue * joules_to_kwh(flow.grid_j) * mean_intensity;
            }
            if flow.battery_carbon_g > 0.0 {
                let released = sc.config.pue * flow.battery_carbon_g;
                self.battery_carbon_g[g] += released;
                carbon += released;
            }
            if carbon > 0.0 {
                self.idle_carbon_g[g] += carbon * idle_share;
                let dyn_carbon = carbon * (1.0 - idle_share);
                self.node_ledger[g].carbon_g += dyn_carbon;
                self.carbon_total_g += dyn_carbon;
            }
            if self.observing() {
                // lint: allow(P1 settle_microgrid early-returns when the node has no microgrid)
                let mg = self.microgrids[g].as_ref().unwrap();
                let soc = mg.soc_frac();
                let stored_g = sc.config.pue * mg.stored_carbon_g();
                self.emit(&TraceEvent::MicrogridSlice {
                    t0_s: t0,
                    t1_s: t1,
                    node: &sc.specs[g].name,
                    pv_j: flow.pv_j,
                    battery_j: flow.battery_j,
                    grid_j: flow.grid_j,
                    grid_charge_j: flow.grid_charge_j,
                    carbon_g: carbon,
                    idle_g: carbon * idle_share,
                    charge_g: sc.config.pue * flow.charge_carbon_g,
                    battery_g: sc.config.pue * flow.battery_carbon_g,
                    stored_g,
                    soc,
                });
            }
        }
    }

    /// Snapshot the schedulable fleet for one decision at `now_s`. With
    /// `allow_defer` (and a finite deadline under a configured
    /// [`DeferralSpec`]), each node view additionally carries a forecast
    /// of its *effective* intensity — the raw trace for grid-only nodes,
    /// a simulated SoC trajectory ([`Microgrid::project`]: the settlement
    /// rolled forward at the standing draw, charge policy included) for
    /// microgrid nodes — sampled on the policy's walk out to
    /// `deadline − headroom`, plus the projected SoC per slot
    /// (`NodeView::soc_forecast`). Under the charge-frozen twin the
    /// legacy PR-4 frozen average blend is rebuilt instead. Released and
    /// migrated tasks get no forecast, so no scheduler can defer them (no
    /// re-deferral livelock).
    fn fleet_view(&self, now_s: f64, deadline_s: f64, allow_defer: bool, site: usize) -> FleetView {
        let sc = self.sc;
        let deferral = if allow_defer && deadline_s.is_finite() {
            sc.config.deferral.as_ref()
        } else {
            None
        };
        // Advertising window for the battery term of a forecast sample —
        // the same window the refresh path prices with.
        let sustain_s = sc.config.intensity_refresh_s.max(1.0);
        let nodes = self
            .scoped_cache(site)
            .iter()
            .map(|&g| {
                let mut view = NodeView::observe(&self.nodes[g], sc.capacity[g]);
                if let Some(b) = &sc.config.batching {
                    // Per-class batching context: open-batch fill, the
                    // predicted dispatch instant (window expiry, or now
                    // when already full / empty), and a class-resolved
                    // queue-delay estimate = blended estimate + the
                    // formation wait still ahead of a joining task.
                    let window_s = b.window_ms / 1e3;
                    let blended_qd_s = view.queue_delay_s;
                    view.class_state = self.bqueues[g]
                        .iter()
                        .map(|q| {
                            let predicted_dispatch_s = match q.front() {
                                Some(_) if q.len() >= b.max_batch => now_s,
                                Some(head) => (head.enqueue_s + window_s).max(now_s),
                                None => now_s,
                            };
                            ClassNodeView {
                                queued: q.len(),
                                predicted_dispatch_s,
                                queue_delay_s: blended_qd_s
                                    + (predicted_dispatch_s - now_s),
                            }
                        })
                        .collect();
                }
                if let Some(d) = deferral {
                    let horizon = (deadline_s - d.headroom_s).max(now_s);
                    let trace = &sc.traces[g];
                    view.forecast = match &self.microgrids[g] {
                        Some(mg) => {
                            let draw = self.node_draw(g);
                            if sc.config.charge_frozen_forecasts {
                                d.policy.forecast(
                                    |t| mg.frozen_intensity(t, draw, trace.at(t), sustain_s),
                                    now_s,
                                    horizon,
                                )
                            } else {
                                let proj = mg.project(
                                    now_s,
                                    horizon,
                                    draw,
                                    trace,
                                    d.policy.resolution_s,
                                    sustain_s,
                                );
                                view.soc_forecast =
                                    proj.iter().map(|&(t, _, soc)| (t, soc)).collect();
                                proj.into_iter().map(|(t, eff, _)| (t, eff)).collect()
                            }
                        }
                        None => d.policy.forecast(|t| trace.at(t), now_s, horizon),
                    };
                }
                view
            })
            .collect();
        FleetView { nodes, now_s, deadline_s: deadline_s.is_finite().then_some(deadline_s) }
    }

    /// The active-node cache one decision sees: the site's own slice on
    /// geographic fleets, the flat fleet-wide cache otherwise (where
    /// `site` is a dummy 0). `Assign` verdicts index back through it.
    #[inline]
    fn scoped_cache(&self, site: usize) -> &[usize] {
        if self.site_caches.is_empty() {
            &self.cache_idx
        } else {
            &self.site_caches[site]
        }
    }

    /// O(sites) router summaries from the maintained aggregates — the
    /// arrival hot path never scans nodes to route.
    fn site_views(&self) -> Vec<SiteView> {
        self.site_agg
            .iter()
            .enumerate()
            .map(|(s, a)| {
                let (intensity, queue_delay_s) = if a.active > 0 {
                    (
                        a.intensity_sum / a.active as f64,
                        self.site_outstanding[s] as f64 * a.est_service_s
                            / a.slots.max(1) as f64,
                    )
                } else {
                    (f64::INFINITY, f64::INFINITY)
                };
                SiteView {
                    index: s,
                    intensity,
                    queue_delay_s,
                    active_nodes: a.active,
                    slots: a.slots,
                    est_service_s: a.est_service_s,
                    task_energy_j: a.task_energy_j,
                }
            })
            .collect()
    }

    /// Pick the serving site for one fresh arrival and admit it there. On
    /// a flat fleet this is a straight pass-through to
    /// [`Simulation::admit`]. With a site layer, the request lands at a
    /// uniformly-drawn home site (its own seeded stream, so flat runs
    /// never shift), the router decides over [`Simulation::site_views`]
    /// summaries — timed into the same per-decision overhead histogram
    /// the scheduler pays into — and a remote verdict ships the request:
    /// transfer energy is billed at the origin's effective intensity
    /// immediately (the origin grid powers the egress), a
    /// [`TraceEvent::WanHop`] hits the firehose, and the request
    /// re-enters the event flow at the target one link latency later
    /// with its original arrival timestamp.
    fn route_and_admit(
        &mut self,
        t_s: f64,
        deadline_s: f64,
        class: usize,
        scheduler: &mut dyn Scheduler,
    ) {
        let sc = self.sc;
        let Some(layer) = sc.sites.as_ref() else {
            self.admit(t_s, t_s, deadline_s, true, class, 0, scheduler);
            return;
        };
        let home = self.home_rng.below(layer.sites.len());
        let views = self.site_views();
        // lint: allow(D2 real ns-overhead telemetry only; virtual time never reads it)
        let t0 = self.telem.as_ref().map(|_| Instant::now());
        let target = self
            .router
            .as_mut()
            .expect("site layer always builds a router") // lint: allow(P1 router built with the site layer)
            .route(
                home,
                t_s,
                deadline_s.is_finite().then_some(deadline_s),
                &views,
                &layer.topology,
            );
        if let (Some(t0), Some(telem)) = (t0, self.telem.as_mut()) {
            telem.decide_ns.record(t0.elapsed().as_nanos() as f64);
        }
        debug_assert!(target < layer.sites.len(), "router returned site {target}");
        if target == home {
            self.admit(t_s, t_s, deadline_s, true, class, home, scheduler);
            return;
        }
        let link = layer.topology.link(home, target);
        let origin_i = views[home].intensity;
        let wan_g = if origin_i.is_finite() {
            sc.config.pue * joules_to_kwh(link.energy_j) * origin_i
        } else {
            0.0
        };
        self.site_shipped_out[home] += 1;
        self.site_shipped_in[target] += 1;
        self.site_wan_energy_j[home] += link.energy_j;
        self.site_wan_carbon_g[home] += wan_g;
        if self.observing() {
            self.emit(&TraceEvent::WanHop {
                t_s,
                from: layer.sites[home].name.as_str(),
                to: layer.sites[target].name.as_str(),
                latency_ms: link.latency_ms,
                energy_j: link.energy_j,
                carbon_g: wan_g,
            });
        }
        self.push(
            t_s + link.latency_ms / 1e3,
            EventKind::WanArrival { site: target, arrival_s: t_s, deadline_s, class },
        );
    }

    /// Route one request through the scheduler's verdict: `Assign`
    /// dispatches onto the chosen node, `Defer` parks the request as a
    /// [`EventKind::DeferredRelease`] at the scheduler's slot, `Reject`
    /// counts it rejected. A defer verdict the engine cannot honour (no
    /// slack context, a non-future slot, or one past the deadline) is a
    /// rejection — in-tree schedulers never produce one, because they only
    /// defer toward slots of the view's own forecast.
    #[allow(clippy::too_many_arguments)]
    fn admit(
        &mut self,
        arrival_s: f64,
        now_s: f64,
        deadline_s: f64,
        allow_defer: bool,
        class: usize,
        site: usize,
        scheduler: &mut dyn Scheduler,
    ) {
        let view = self.fleet_view(now_s, deadline_s, allow_defer, site);
        let demand = self.demand_of(class);
        if allow_defer {
            if let Some(shed_s) = self.sc.config.admission.as_ref().map(|a| a.shed_queue_s) {
                let pressure =
                    view.nodes.iter().map(|nv| nv.queue_delay_s).fold(f64::INFINITY, f64::min);
                if pressure > shed_s * (1.0 + f64::from(self.class_priority[class])) {
                    self.rejected += 1;
                    self.class_rejected[class] += 1;
                    if self.observing() {
                        let empty = DecisionExplain::default();
                        self.emit(&TraceEvent::Decision {
                            t_s: now_s,
                            arrival_s,
                            ctx: "admission",
                            verdict: SchedulingDecision::Reject { reason: RejectReason::Overload },
                            node: None,
                            explain: &empty,
                            decide_ns: 0,
                        });
                    }
                    return;
                }
            }
        }
        let decision = if self.observing() {
            let ctx = if allow_defer { "arrival" } else { "release" };
            self.decide_observed(scheduler, &demand, &view, arrival_s, now_s, ctx)
        } else {
            scheduler.decide(&demand, &view)
        };
        match decision {
            SchedulingDecision::Assign(ci) => {
                let g = self.scoped_cache(site)[ci];
                let qd_ms = view.nodes[ci].queue_delay_s * 1e3;
                self.dispatch(g, qd_ms, arrival_s, now_s, deadline_s, class);
            }
            SchedulingDecision::Defer { until_s }
                if allow_defer && until_s > now_s && until_s <= deadline_s =>
            {
                self.deferred += 1;
                self.push(
                    until_s,
                    EventKind::DeferredRelease { arrival_s, deadline_s, class, site },
                );
            }
            SchedulingDecision::Defer { .. } | SchedulingDecision::Reject { .. } => {
                self.rejected += 1;
                self.class_rejected[class] += 1;
            }
        }
    }

    /// The scheduler-facing demand for one request: the class's
    /// registered demand (class index stamped) under a configured mix,
    /// else the scenario-wide default.
    fn demand_of(&self, class: usize) -> TaskDemand {
        match &self.sc.config.workload {
            Some(mix) => mix.demand_of(class),
            None => self.sc.config.demand,
        }
    }

    /// One scheduler call under observation: wall-clock the decision into
    /// the telemetry overhead histogram, and — when the sink keeps
    /// decision events — route through [`Scheduler::decide_explained`] so
    /// the emitted event carries the per-candidate rationale. The explain
    /// payload is skipped entirely when nobody reads it; the verdict is
    /// identical either way (the `decide_explained` contract).
    fn decide_observed(
        &mut self,
        scheduler: &mut dyn Scheduler,
        demand: &TaskDemand,
        view: &FleetView,
        arrival_s: f64,
        now_s: f64,
        ctx: &'static str,
    ) -> SchedulingDecision {
        let want_explain = match self.sink.as_ref() {
            Some(s) => s.wants(TraceKind::Decision),
            None => false,
        };
        // lint: allow(D2 measures real decide-ns against the paper's 0.03 ms envelope)
        let t0 = Instant::now();
        let (decision, explain) = if want_explain {
            let mut e = DecisionExplain::default();
            let d = scheduler.decide_explained(demand, view, &mut e);
            (d, Some(e))
        } else {
            (scheduler.decide(demand, view), None)
        };
        let decide_ns = t0.elapsed().as_nanos() as u64;
        if let Some(t) = self.telem.as_mut() {
            t.decide_ns.record(decide_ns as f64);
        }
        if explain.is_some() || self.monitors.is_some() {
            // Monitors read decision verdicts (reject/defer rate) even
            // when the sink filters decision events out; an empty explain
            // stands in so the event can still be constructed cheaply.
            let empty = DecisionExplain::default();
            let node = decision.assigned().map(|ci| view.nodes[ci].node.spec.name.as_str());
            self.emit(&TraceEvent::Decision {
                t_s: now_s,
                arrival_s,
                ctx,
                verdict: decision,
                node,
                explain: explain.as_ref().unwrap_or(&empty),
                decide_ns,
            });
        } else if let Some(t) = self.telem.as_mut() {
            // The sink filtered decision events out; still count it.
            t.count(TraceKind::Decision);
        }
        decision
    }

    /// Assign a request (original arrival time `arrival_s`) to node `g` at
    /// virtual time `now`. `begin_task` here — before service starts — so
    /// schedulers observe backlog (queued + executing) as `inflight`.
    /// `queue_delay_est_ms` is the estimate the decision's [`FleetView`]
    /// advertised for this node; it is recorded verbatim so the report's
    /// per-node p50/max are exactly what the scheduler saw.
    fn dispatch(
        &mut self,
        g: usize,
        queue_delay_est_ms: f64,
        arrival_s: f64,
        now_s: f64,
        deadline_s: f64,
        class: usize,
    ) {
        debug_assert!(self.active[g], "dispatch onto inactive node {g}");
        if !self.site_caches.is_empty() {
            self.site_outstanding[self.site_of[g]] += 1;
        }
        self.queue_delay_ms[g].push(queue_delay_est_ms);
        if self.observing() {
            if let Some(t) = self.telem.as_mut() {
                t.queue_delay_ms.record(queue_delay_est_ms);
            }
            let sc = self.sc;
            self.emit(&TraceEvent::Dispatch {
                t_s: now_s,
                arrival_s,
                node: &sc.specs[g].name,
                queue_delay_est_ms,
            });
        }
        self.nodes[g].begin_task();
        if self.sc.config.batching.is_some() {
            self.bqueues[g][class].push_back(BatchTask {
                arrival_s,
                deadline_s,
                enqueue_s: now_s,
            });
            self.try_dispatch_batches(g, now_s);
        } else {
            self.queues[g].push_back((arrival_s, deadline_s, class));
            self.try_start(g, now_s);
        }
    }

    fn try_start(&mut self, g: usize, now_s: f64) {
        // Starting work changes the node's draw: settle the elapsed
        // microgrid slice at the old draw first.
        self.settle_microgrid(g, now_s);
        while self.in_service[g] < self.sc.capacity[g] {
            let Some((arrival_s, deadline_s, class)) = self.queues[g].pop_front() else { break };
            let sigma = self.sc.config.jitter_sigma;
            let jitter = if sigma > 0.0 {
                (sigma * self.service_rng.normal() - 0.5 * sigma * sigma).exp()
            } else {
                1.0
            };
            let exec_ms = self.sc.config.base_exec_ms * jitter * self.class_exec_scale[class];
            let service_ms = self.sc.specs[g].simulate_latency_ms(exec_ms);
            // Dynamic (above-idle) energy only: the idle floor is accrued
            // over uptime, so a saturated node draws exactly rated power.
            let energy_j = self.sc.specs[g].dynamic_power_w() * service_ms / 1e3;
            self.wait_ms.push((now_s - arrival_s) * 1e3);
            self.in_service[g] += 1;
            self.push(
                now_s + service_ms / 1e3,
                EventKind::Completion {
                    node: g,
                    class,
                    arrival_s,
                    deadline_s,
                    service_ms,
                    energy_j,
                },
            );
        }
    }

    /// Dispatch-time batch formation (batched path only): while a
    /// service slot is free, seal the best *sealable* class — a batch is
    /// sealable when its queue reached the fill target or its head has
    /// waited out the formation window (a zero window seals on sight).
    /// Among sealable classes the highest priority wins, ties to the
    /// longest-waiting head, then the lowest class index. Classes still
    /// forming get a generation-guarded window timer so a partial batch
    /// is never stranded.
    fn try_dispatch_batches(&mut self, g: usize, now_s: f64) {
        let Some(spec) = self.sc.config.batching else { return };
        let window_s = spec.window_ms / 1e3;
        while self.in_service[g] < self.sc.capacity[g] {
            // (class, priority, head enqueue) of the best sealable class.
            let mut best: Option<(usize, u8, f64)> = None;
            for c in 0..self.n_classes {
                let q = &self.bqueues[g][c];
                let Some(head) = q.front() else { continue };
                let sealable = q.len() >= spec.max_batch
                    || window_s <= 0.0
                    || now_s - head.enqueue_s >= window_s;
                if !sealable {
                    continue;
                }
                let cand = (c, self.class_priority[c], head.enqueue_s);
                best = match best {
                    // Keep the incumbent on higher priority, or on equal
                    // priority with an earlier-or-equal head (ascending
                    // scan, so full ties stay with the lower index).
                    Some(b) if b.1 > cand.1 || (b.1 == cand.1 && b.2 <= cand.2) => Some(b),
                    _ => Some(cand),
                };
            }
            let Some((c, _, _)) = best else { break };
            self.seal_batch(g, c, now_s, spec.max_batch);
        }
        if window_s > 0.0 {
            for c in 0..self.n_classes {
                self.ensure_batch_timer(g, c, now_s, window_s);
            }
        }
    }

    /// Arm a formation-window timer for `(g, c)`'s open batch if none is
    /// outstanding and the window has not already expired — an expired
    /// window means only capacity blocks the seal, and the next batch
    /// completion on this node re-runs formation anyway (re-arming would
    /// spin a same-instant timer loop).
    fn ensure_batch_timer(&mut self, g: usize, c: usize, now_s: f64, window_s: f64) {
        if self.bt_sched[g][c] {
            return;
        }
        let Some(head) = self.bqueues[g][c].front() else { return };
        let due_s = head.enqueue_s + window_s;
        if due_s <= now_s {
            return;
        }
        let gen = self.bt_gen[g][c];
        self.bt_sched[g][c] = true;
        self.push(due_s, EventKind::BatchTimer { node: g, class: c, gen });
    }

    /// Seal the open batch of `class` on node `g`: take up to
    /// `fill_target` members, draw one service-jitter multiplier for the
    /// whole batch, and enter it into service as a single slot at the
    /// sub-linear batch latency/power point.
    fn seal_batch(&mut self, g: usize, class: usize, now_s: f64, fill_target: usize) {
        // The batch entering service changes the node's draw: settle the
        // elapsed microgrid slice at the old draw first.
        self.settle_microgrid(g, now_s);
        let q = &mut self.bqueues[g][class];
        let k = q.len().min(fill_target);
        debug_assert!(k > 0, "sealing an empty batch on node {g}");
        // lint: allow(P1 seal_batch callers guarantee a non-empty queue, k > 0 above)
        let head_wait_ms = (now_s - q.front().unwrap().enqueue_s) * 1e3;
        let mut tasks = Vec::with_capacity(k);
        for _ in 0..k {
            // lint: allow(P1 the loop pops exactly k <= q.len() tasks)
            let task = q.pop_front().unwrap();
            tasks.push((task.arrival_s, task.deadline_s));
        }
        // Any outstanding formation timer now refers to a sealed batch.
        self.bt_gen[g][class] += 1;
        self.bt_sched[g][class] = false;
        for &(arrival_s, _) in &tasks {
            self.wait_ms.push((now_s - arrival_s) * 1e3);
        }
        let sigma = self.sc.config.jitter_sigma;
        let jitter = if sigma > 0.0 {
            (sigma * self.service_rng.normal() - 0.5 * sigma * sigma).exp()
        } else {
            1.0
        };
        let exec_ms = self.sc.config.base_exec_ms * jitter * self.class_exec_scale[class];
        let service_ms = self.sc.specs[g].batch_latency_ms(exec_ms, k);
        let dyn_w = self.sc.specs[g].batch_dynamic_power_w(k);
        self.in_service[g] += 1;
        self.active_dyn_w[g] += dyn_w;
        self.class_batches[class] += 1;
        if self.observing() {
            let sc = self.sc;
            self.emit(&TraceEvent::BatchFormed {
                t_s: now_s,
                node: &sc.specs[g].name,
                class,
                fill: k,
                head_wait_ms,
            });
        }
        self.push(
            now_s + service_ms / 1e3,
            EventKind::BatchComplete { node: g, class, service_ms, dyn_w, tasks },
        );
    }

    fn complete(
        &mut self,
        g: usize,
        class: usize,
        t_s: f64,
        arrival_s: f64,
        deadline_s: f64,
        service_ms: f64,
        energy_j: f64,
    ) {
        // The draw drops when this task leaves service: settle the
        // microgrid slice (which includes this task's power) first.
        self.settle_microgrid(g, t_s);
        self.in_service[g] -= 1;
        // Emissions price the *completion-time* grid intensity (Eq. 2) —
        // this is where Diurnal/Trace bite on the accounting path. A
        // microgrid node's carbon is instead accrued slice-by-slice in
        // settle_microgrid (only its grid-supplied share bears carbon).
        let carbon_g = if self.microgrids[g].is_some() {
            0.0
        } else {
            emissions_g(joules_to_kwh(energy_j), self.sc.traces[g].at(t_s), self.sc.config.pue)
        };
        self.account_completion(
            g, class, t_s, arrival_s, deadline_s, service_ms, energy_j, carbon_g,
        );
        // A churned-down node keeps its power floor while in-service work
        // drains; the last drain completion finally powers it off.
        if !self.active[g] && self.in_service[g] == 0 && self.up_since[g].is_some() {
            self.accrue_idle(g, t_s);
            self.up_since[g] = None;
        }
        self.try_start(g, t_s);
    }

    /// One sealed batch leaving service: free the slot, remove the
    /// batch's power point from the node's active draw, and settle each
    /// member with an equal share of the batch energy (and, on grid-only
    /// nodes, the completion-time carbon on that share).
    fn complete_batch(
        &mut self,
        g: usize,
        class: usize,
        t_s: f64,
        service_ms: f64,
        dyn_w: f64,
        tasks: Vec<(f64, f64)>,
    ) {
        // The batch's draw stops now: settle the elapsed slice first.
        self.settle_microgrid(g, t_s);
        self.in_service[g] -= 1;
        self.active_dyn_w[g] -= dyn_w;
        let energy_j = dyn_w * service_ms / 1e3;
        let task_energy_j = energy_j / tasks.len() as f64;
        let task_carbon_g = if self.microgrids[g].is_some() {
            0.0
        } else {
            emissions_g(
                joules_to_kwh(task_energy_j),
                self.sc.traces[g].at(t_s),
                self.sc.config.pue,
            )
        };
        for (arrival_s, deadline_s) in tasks {
            self.account_completion(
                g,
                class,
                t_s,
                arrival_s,
                deadline_s,
                service_ms,
                task_energy_j,
                task_carbon_g,
            );
        }
        // A churned-down node keeps its power floor while in-service work
        // drains; the last drain completion finally powers it off.
        if !self.active[g] && self.in_service[g] == 0 && self.up_since[g].is_some() {
            self.accrue_idle(g, t_s);
            self.up_since[g] = None;
        }
        self.try_dispatch_batches(g, t_s);
    }

    /// Per-task completion accounting shared by the one-task and batched
    /// service paths: node ledger + fleet totals, latency, legacy
    /// deadline bookkeeping, per-class SLO bookkeeping (a class's SLO
    /// clock runs from arrival, independent of deferral slack), and the
    /// Completion trace event.
    #[allow(clippy::too_many_arguments)]
    fn account_completion(
        &mut self,
        g: usize,
        class: usize,
        t_s: f64,
        arrival_s: f64,
        deadline_s: f64,
        service_ms: f64,
        energy_j: f64,
        carbon_g: f64,
    ) {
        let kwh = joules_to_kwh(energy_j);
        if !self.site_caches.is_empty() {
            self.site_outstanding[self.site_of[g]] -= 1;
        }
        self.nodes[g].finish_task(service_ms, energy_j, carbon_g);
        let entry = &mut self.node_ledger[g];
        entry.energy_kwh += kwh;
        entry.carbon_g += carbon_g;
        entry.tasks += 1;
        self.energy_total_j += energy_j;
        self.carbon_total_g += carbon_g;
        let latency_ms = (t_s - arrival_s) * 1e3;
        self.latency_ms.push(latency_ms);
        self.completed += 1;
        if t_s > deadline_s {
            self.deadline_missed += 1;
        }
        self.class_completed[class] += 1;
        self.class_latency_ms[class].push(latency_ms);
        self.class_energy_j[class] += energy_j;
        self.class_carbon_g[class] += carbon_g;
        let slo_missed = t_s > arrival_s + self.class_slo_s[class];
        if slo_missed {
            self.class_slo_missed[class] += 1;
        }
        if self.observing() {
            if let Some(t) = self.telem.as_mut() {
                t.latency_ms.record(latency_ms);
            }
            let sc = self.sc;
            self.emit(&TraceEvent::Completion {
                t_s,
                arrival_s,
                node: &sc.specs[g].name,
                class,
                service_ms,
                latency_ms,
                energy_j,
                carbon_g,
                missed: t_s > deadline_s,
                slo_missed,
            });
        }
        self.makespan_s = self.makespan_s.max(t_s);
    }

    /// Close the node's open uptime interval at `until_s`, charging the
    /// idle floor for it: energy is `idle_w × Δt`, carbon integrates the
    /// intensity trace piecewise across the interval (a single-instant
    /// price would mis-charge any interval spanning a grid swing).
    fn accrue_idle(&mut self, g: usize, until_s: f64) {
        let Some(since) = self.up_since[g] else { return };
        let dt = until_s - since;
        if dt > 0.0 {
            self.uptime_s[g] += dt;
            let idle_w = self.sc.specs[g].idle_w;
            let mut energy_j = 0.0;
            let mut carbon_g = 0.0;
            if idle_w > 0.0 {
                energy_j = idle_w * dt;
                self.idle_energy_j[g] += energy_j;
                // A microgrid node's idle carbon is accrued in
                // settle_microgrid (only the grid-supplied share bears
                // carbon); grid-only nodes price the full floor here.
                if self.microgrids[g].is_none() {
                    let intensity_dt = self.sc.traces[g].integral(since, until_s);
                    // idle_w·∫I dt is W·(g/kWh)·s; /3.6e6 converts W·s → kWh.
                    carbon_g = self.sc.config.pue * idle_w * intensity_dt / 3.6e6;
                    self.idle_carbon_g[g] += carbon_g;
                }
            }
            if self.observing() {
                // Emitted even at idle_w == 0 — the interval itself is
                // what replays uptime.
                let sc = self.sc;
                self.emit(&TraceEvent::IdleSlice {
                    t0_s: since,
                    t1_s: until_s,
                    node: &sc.specs[g].name,
                    energy_j,
                    carbon_g,
                });
            }
        }
        self.up_since[g] = Some(until_s);
    }

    fn churn(&mut self, g: usize, up: bool, t_s: f64, scheduler: &mut dyn Scheduler) {
        if self.observing() {
            let sc = self.sc;
            self.emit(&TraceEvent::Churn { t_s, node: &sc.specs[g].name, up });
        }
        if up {
            if !self.active[g] {
                self.active[g] = true;
                // A node rejoining while still draining never powered off:
                // its uptime interval is still open and stays continuous.
                if self.up_since[g].is_none() {
                    // Close the powered-off slice (draw 0, PV kept charging
                    // the battery) before the idle floor returns.
                    self.settle_microgrid(g, t_s);
                    self.up_since[g] = Some(t_s);
                }
                self.rebuild_cache();
            }
            return;
        }
        if !self.active[g] {
            return;
        }
        self.active[g] = false;
        // Power off now only if nothing is executing; otherwise the floor
        // keeps burning until the last in-service task drains (complete()
        // closes the interval) — a box cannot finish work while drawing
        // only above-idle power.
        if self.in_service[g] == 0 {
            // Settle while the idle floor still applies, then cut the draw.
            self.settle_microgrid(g, t_s);
            self.accrue_idle(g, t_s);
            self.up_since[g] = None;
        }
        self.rebuild_cache();
        // Tasks already in service drain gracefully (their completion events
        // stand); queued work migrates through the scheduler to the
        // remaining fleet, keeping its original arrival timestamps. Refresh
        // intensities first (unthrottled): the whole backlog re-routes in
        // one batch, and placing it against grids up to intensity_refresh_s
        // stale would systematically misroute it.
        if !self.queues[g].is_empty() || self.bqueues[g].iter().any(|q| !q.is_empty()) {
            self.force_refresh_intensities(t_s);
        }
        // Batch-formation queues drain too: flatten every class in
        // enqueue order (stable sort keeps within-class FIFO and breaks
        // cross-class ties by class index) and invalidate their timers.
        let mut forming: Vec<(f64, f64, f64, usize)> = Vec::new();
        for c in 0..self.n_classes {
            if !self.bqueues[g][c].is_empty() {
                self.bt_gen[g][c] += 1;
                self.bt_sched[g][c] = false;
                for task in self.bqueues[g][c].drain(..) {
                    forming.push((task.enqueue_s, task.arrival_s, task.deadline_s, c));
                }
            }
        }
        forming.sort_by(|a, b| a.0.total_cmp(&b.0));
        let pending: Vec<(f64, f64, usize)> = self
            .queues[g]
            .drain(..)
            .chain(forming.into_iter().map(|(_, a, d, c)| (a, d, c)))
            .collect();
        // Migration stays within the churned node's own site on
        // geographic fleets — cross-site movement is the router's call at
        // arrival time, never a side effect of churn.
        let site = if self.site_caches.is_empty() { 0 } else { self.site_of[g] };
        for (arrival_s, deadline_s, class) in pending {
            self.nodes[g].cancel_task();
            if !self.site_caches.is_empty() {
                // The task leaves the site's outstanding set; a successful
                // re-dispatch below re-counts it.
                self.site_outstanding[site] -= 1;
            }
            // One fresh view per migrated task: each dispatch changes the
            // backlog the next decision must see. Migration never defers
            // (no forecast in the view), matching the release path.
            let view = self.fleet_view(t_s, deadline_s, false, site);
            let demand = self.demand_of(class);
            let decision = if self.observing() {
                self.decide_observed(scheduler, &demand, &view, arrival_s, t_s, "migration")
            } else {
                scheduler.decide(&demand, &view)
            };
            match decision {
                SchedulingDecision::Assign(ci) => {
                    let ng = self.scoped_cache(site)[ci];
                    let qd_ms = view.nodes[ci].queue_delay_s * 1e3;
                    self.migrated += 1;
                    self.dispatch(ng, qd_ms, arrival_s, t_s, deadline_s, class);
                }
                _ => {
                    self.rejected += 1;
                    self.class_rejected[class] += 1;
                }
            }
        }
    }

    /// Close every node still powered on at the simulation horizon, and
    /// settle every microgrid to it (a powered-off node's PV keeps
    /// charging its battery right up to the horizon). Runs before the
    /// telemetry registry is detached, so the horizon settlement slices
    /// still reach the sink *and* the counters.
    fn close_horizon(&mut self) {
        let horizon = self.t_last;
        for g in 0..self.sc.specs.len() {
            self.settle_microgrid(g, horizon);
            self.accrue_idle(g, horizon);
            if let Some(mg) = &self.microgrids[g] {
                self.soc_timeline[g].push((horizon, mg.soc_frac()));
            }
        }
    }

    fn into_report(mut self, scheduler_name: &str) -> SimReport {
        let energy_idle_kwh_total = joules_to_kwh(self.idle_energy_j.iter().sum::<f64>());
        let carbon_idle_g_total: f64 = self.idle_carbon_g.iter().sum();
        let energy_dynamic_kwh_total = joules_to_kwh(self.energy_total_j);
        let mut soc_timelines = std::mem::take(&mut self.soc_timeline);
        let mut soc_projections = std::mem::take(&mut self.soc_projection);
        let nodes: Vec<super::report::NodeUsage> = self
            .sc
            .specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let e = self.node_ledger[i];
                let idle_kwh = joules_to_kwh(self.idle_energy_j[i]);
                // Supply-side split: microgrid nodes report what the
                // settlement ledger routed through PV / battery / grid;
                // grid-only nodes drew everything from the grid.
                let (pv, battery, grid) = if self.microgrids[i].is_some() {
                    (
                        joules_to_kwh(self.pv_energy_j[i]),
                        joules_to_kwh(self.battery_energy_j[i]),
                        joules_to_kwh(self.grid_energy_j[i]),
                    )
                } else {
                    (0.0, 0.0, e.energy_kwh + idle_kwh)
                };
                let qd = super::report::summary_or_zero(&self.queue_delay_ms[i]);
                super::report::NodeUsage {
                    name: spec.name.clone(),
                    tasks: e.tasks,
                    busy_ms: self.nodes[i].state().busy_ms,
                    uptime_s: self.uptime_s[i],
                    queue_delay_ms_p50: qd.p50,
                    queue_delay_ms_p99: qd.p99,
                    queue_delay_ms_max: qd.max,
                    energy_dynamic_kwh: e.energy_kwh,
                    energy_idle_kwh: idle_kwh,
                    carbon_dynamic_g: e.carbon_g,
                    carbon_idle_g: self.idle_carbon_g[i],
                    microgrid: self.microgrids[i].is_some(),
                    energy_pv_kwh: pv,
                    energy_battery_kwh: battery,
                    energy_grid_kwh: grid,
                    energy_grid_charge_kwh: joules_to_kwh(self.grid_charge_energy_j[i]),
                    carbon_charged_g: self.charge_carbon_g[i],
                    carbon_battery_g: self.battery_carbon_g[i],
                    carbon_stored_g: self.microgrids[i]
                        .as_ref()
                        .map(|mg| self.sc.config.pue * mg.stored_carbon_g())
                        .unwrap_or(0.0),
                    soc_timeline: std::mem::take(&mut soc_timelines[i]),
                    soc_projection: std::mem::take(&mut soc_projections[i]),
                }
            })
            .collect();
        let (energy_pv_kwh_total, energy_battery_kwh_total, energy_grid_kwh_total) =
            super::report::sum_supply(&nodes);
        let (
            energy_grid_charge_kwh_total,
            carbon_charged_g_total,
            carbon_battery_g_total,
            carbon_stored_g_total,
        ) = super::report::sum_storage(&nodes);
        // Per-class rows only when a mix is configured: legacy reports
        // keep an empty vec, so their PartialEq equality is untouched.
        let classes: Vec<super::report::ClassUsage> = match &self.sc.config.workload {
            Some(mix) => mix
                .classes
                .iter()
                .enumerate()
                .map(|(c, wc)| super::report::ClassUsage {
                    name: wc.name.clone(),
                    completed: self.class_completed[c],
                    rejected: self.class_rejected[c],
                    slo_s: wc.slo_s,
                    slo_missed: self.class_slo_missed[c],
                    batches: self.class_batches[c],
                    latency_ms: super::report::summary_or_zero(&self.class_latency_ms[c]),
                    energy_dynamic_kwh: joules_to_kwh(self.class_energy_j[c]),
                    carbon_dynamic_g: self.class_carbon_g[c],
                    carbon_per_req_g: if self.class_completed[c] > 0 {
                        self.class_carbon_g[c] / self.class_completed[c] as f64
                    } else {
                        0.0
                    },
                })
                .collect(),
            None => Vec::new(),
        };
        // Per-site rows only on geographic fleets: flat reports keep the
        // empty vec / zero totals, so their PartialEq equality is
        // untouched. Site energy/carbon are a strict partition of the
        // fleet totals: every node belongs to exactly one site, and WAN
        // transfer joins the totals through the origin site's row.
        let sites: Vec<super::report::SiteUsage> = match self.sc.sites.as_ref() {
            Some(layer) => layer
                .sites
                .iter()
                .enumerate()
                .map(|(s, site)| {
                    let members: Vec<usize> = (0..self.sc.specs.len())
                        .filter(|&g| layer.site_of[g] == s)
                        .collect();
                    let completed: u64 =
                        members.iter().map(|&g| self.node_ledger[g].tasks).sum();
                    let dyn_kwh: f64 =
                        members.iter().map(|&g| self.node_ledger[g].energy_kwh).sum();
                    let idle_kwh = joules_to_kwh(
                        members.iter().map(|&g| self.idle_energy_j[g]).sum::<f64>(),
                    );
                    let dyn_g: f64 =
                        members.iter().map(|&g| self.node_ledger[g].carbon_g).sum();
                    let idle_g: f64 =
                        members.iter().map(|&g| self.idle_carbon_g[g]).sum();
                    let wan_kwh = joules_to_kwh(self.site_wan_energy_j[s]);
                    let wan_g = self.site_wan_carbon_g[s];
                    let carbon_g = dyn_g + idle_g + wan_g;
                    super::report::SiteUsage {
                        name: site.name.clone(),
                        nodes: members.len(),
                        completed,
                        shipped_out: self.site_shipped_out[s],
                        shipped_in: self.site_shipped_in[s],
                        energy_kwh: dyn_kwh + idle_kwh,
                        energy_wan_kwh: wan_kwh,
                        carbon_g,
                        carbon_wan_g: wan_g,
                        carbon_per_req_g: if completed > 0 {
                            carbon_g / completed as f64
                        } else {
                            0.0
                        },
                    }
                })
                .collect(),
            None => Vec::new(),
        };
        let energy_wan_kwh_total: f64 = sites.iter().map(|r| r.energy_wan_kwh).sum();
        let carbon_wan_g_total: f64 = sites.iter().map(|r| r.carbon_wan_g).sum();
        SimReport {
            scenario: self.sc.name.clone(),
            scheduler: scheduler_name.to_string(),
            seed: self.sc.config.seed,
            requests: self.arrived,
            completed: self.completed,
            rejected: self.rejected,
            migrated: self.migrated,
            deferred: self.deferred,
            deadline_missed: self.deadline_missed,
            makespan_s: self.makespan_s,
            throughput_rps: if self.makespan_s > 0.0 {
                self.completed as f64 / self.makespan_s
            } else {
                0.0
            },
            latency_ms: super::report::summary_or_zero(&self.latency_ms),
            wait_ms: super::report::summary_or_zero(&self.wait_ms),
            energy_kwh_total: energy_dynamic_kwh_total
                + energy_idle_kwh_total
                + energy_wan_kwh_total,
            energy_dynamic_kwh_total,
            energy_idle_kwh_total,
            energy_wan_kwh_total,
            energy_pv_kwh_total,
            energy_battery_kwh_total,
            energy_grid_kwh_total,
            energy_grid_charge_kwh_total,
            carbon_charged_g_total,
            carbon_battery_g_total,
            carbon_stored_g_total,
            carbon_g_total: self.carbon_total_g + carbon_idle_g_total + carbon_wan_g_total,
            carbon_dynamic_g_total: self.carbon_total_g,
            carbon_idle_g_total,
            carbon_wan_g_total,
            carbon_per_req_g: if self.completed > 0 {
                (self.carbon_total_g + carbon_idle_g_total + carbon_wan_g_total)
                    / self.completed as f64
            } else {
                0.0
            },
            router: self
                .sc
                .sites
                .as_ref()
                .map(|l| l.router.name().to_string())
                .unwrap_or_default(),
            wan_shipped: self.site_shipped_out.iter().sum(),
            classes,
            sites,
            nodes,
            // Filled by run_inner after the take(); into_report itself
            // never sees the monitor set.
            monitors: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeSpec;
    use crate::scheduler::{CarbonAwareScheduler, Mode, RoundRobinScheduler};
    use crate::sim::scenarios;

    fn one_node_scenario(requests: usize, rate_hz: f64, capacity: usize) -> Scenario {
        let specs = vec![NodeSpec::paper_nodes().remove(0)];
        Scenario {
            name: "one-node".into(),
            traces: vec![IntensityTrace::Static(specs[0].intensity)],
            capacity: vec![capacity],
            specs,
            arrivals: ArrivalProcess::Uniform { rate_hz },
            requests,
            churn: Vec::new(),
            microgrids: Vec::new(),
            sites: None,
            config: SimConfig { jitter_sigma: 0.0, ..SimConfig::default() },
        }
    }

    #[test]
    fn virtual_clock_and_fifo_order() {
        // Uniform arrivals slower than service: zero queueing, latency ==
        // service time, makespan == last arrival + service.
        let sc = one_node_scenario(10, 1.0, 1);
        let service_ms = sc.specs[0].simulate_latency_ms(sc.config.base_exec_ms);
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 10);
        assert_eq!(r.rejected, 0);
        assert!((r.latency_ms.mean - service_ms).abs() < 1e-9, "{}", r.latency_ms.mean);
        assert!(r.wait_ms.max.abs() < 1e-9);
        assert!((r.makespan_s - (10.0 + service_ms / 1e3)).abs() < 1e-9);
    }

    #[test]
    fn saturation_builds_fifo_queue() {
        // Arrivals 10× faster than service: waits grow linearly; FIFO means
        // later arrivals wait longer (p95 >> p50).
        let sc = one_node_scenario(200, 50.0, 1);
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 200);
        assert!(r.wait_ms.p95 > r.wait_ms.p50 * 1.5, "{:?}", r.wait_ms);
        assert!(r.latency_ms.mean > r.wait_ms.mean);
    }

    #[test]
    fn capacity_bounds_concurrency() {
        // Doubling capacity halves the backlog for an overloaded node.
        let mut rr = RoundRobinScheduler::new();
        let slow = Simulation::run(&one_node_scenario(200, 50.0, 1), &mut rr);
        let fast = Simulation::run(&one_node_scenario(200, 50.0, 2), &mut rr);
        assert!(fast.wait_ms.mean < slow.wait_ms.mean * 0.6);
        assert!(fast.makespan_s < slow.makespan_s);
    }

    #[test]
    fn mmpp_gaps_positive_and_deterministic() {
        let p = ArrivalProcess::Mmpp { rate_low_hz: 2.0, rate_high_hz: 40.0, mean_dwell_s: 5.0 };
        let mut a = ArrivalGen::new(p.clone(), 7);
        let mut b = ArrivalGen::new(p.clone(), 7);
        let mut total = 0.0;
        for _ in 0..5_000 {
            let ga = a.next_gap_s();
            assert_eq!(ga, b.next_gap_s());
            assert!(ga > 0.0);
            total += ga;
        }
        // 5k arrivals at mean rate 21 Hz ≈ 238 s of virtual time.
        let mean_rate = 5_000.0 / total;
        assert!((mean_rate - p.mean_rate_hz()).abs() / p.mean_rate_hz() < 0.25, "{mean_rate}");
    }

    #[test]
    fn event_order_breaks_ties_by_sequence() {
        let a = Event { t_s: 1.0, seq: 0, kind: EventKind::Arrival };
        let b = Event { t_s: 1.0, seq: 1, kind: EventKind::Arrival };
        let c = Event { t_s: 0.5, seq: 2, kind: EventKind::Arrival };
        let mut h = BinaryHeap::new();
        h.push(b);
        h.push(a);
        h.push(c);
        assert_eq!(h.pop().unwrap().seq, 2); // earliest time first
        assert_eq!(h.pop().unwrap().seq, 0); // then insertion order
        assert_eq!(h.pop().unwrap().seq, 1);
    }

    #[test]
    fn identical_seeds_identical_reports() {
        let sc = scenarios::build("paper-3-node", 0, 2_000, 9).unwrap();
        let run = || {
            let mut s = CarbonAwareScheduler::new("green", Mode::Green.weights());
            Simulation::run(&sc, &mut s)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn idle_floor_accrues_over_uptime() {
        // One idle-capable node, light load: idle energy = idle_w × horizon,
        // dynamic energy = (rated − idle) × busy time.
        let mut sc = one_node_scenario(10, 1.0, 1);
        sc.specs[0].idle_w = 40.0;
        let service_ms = sc.specs[0].simulate_latency_ms(sc.config.base_exec_ms);
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        let horizon = 10.0 + service_ms / 1e3; // last completion = last event
        let n = &r.nodes[0];
        assert!((n.uptime_s - horizon).abs() < 1e-9, "uptime {}", n.uptime_s);
        let want_idle_kwh = 40.0 * horizon / 3.6e6;
        assert!((n.energy_idle_kwh - want_idle_kwh).abs() < 1e-15);
        let want_dyn_kwh = (170.0 - 40.0) * (10.0 * service_ms / 1e3) / 3.6e6;
        assert!(
            (n.energy_dynamic_kwh - want_dyn_kwh).abs() < 1e-12,
            "dyn {} want {}",
            n.energy_dynamic_kwh,
            want_dyn_kwh
        );
        // Static trace: idle carbon = idle energy × intensity.
        assert!((n.carbon_idle_g - want_idle_kwh * 620.0).abs() < 1e-12);
        assert!((r.energy_kwh_total - (n.energy_idle_kwh + n.energy_dynamic_kwh)).abs() < 1e-15);
        // With idle_w = 0 the idle side vanishes and dynamic equals the old
        // single-part accounting.
        let r0 = Simulation::run(&one_node_scenario(10, 1.0, 1), &mut s);
        assert_eq!(r0.energy_idle_kwh_total, 0.0);
        assert!(r0.nodes[0].energy_dynamic_kwh > want_dyn_kwh); // full 170 W
    }

    #[test]
    fn churned_down_node_stops_accruing_idle() {
        let mut sc = one_node_scenario(5, 1.0, 1);
        sc.specs.push(sc.specs[0].clone());
        sc.specs[1].name = "idle-bystander".into();
        sc.specs[1].idle_w = 100.0;
        sc.traces.push(IntensityTrace::Static(500.0));
        sc.capacity.push(1);
        // The bystander powers off at t = 2 and returns at t = 4.
        sc.churn = vec![
            ChurnEvent { at_s: 2.0, node: 1, up: false },
            ChurnEvent { at_s: 4.0, node: 1, up: true },
        ];
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        // Node 0 never churns: its uptime is the whole horizon. The
        // bystander's uptime is exactly two powered-off seconds shorter.
        let by = r.node("idle-bystander").unwrap();
        assert!(by.uptime_s > 0.0);
        assert!(
            (r.nodes[0].uptime_s - by.uptime_s - 2.0).abs() < 1e-9,
            "node0 up {} vs bystander up {}",
            r.nodes[0].uptime_s,
            by.uptime_s
        );
        let want_idle_kwh = 100.0 * by.uptime_s / 3.6e6;
        assert!((by.energy_idle_kwh - want_idle_kwh).abs() < 1e-15);
        assert!((by.carbon_idle_g - want_idle_kwh * 500.0).abs() < 1e-12);
    }

    #[test]
    fn draining_node_keeps_its_idle_floor_until_work_finishes() {
        // One node, one ~10 s task started before a churn-down at t = 1:
        // the box cannot power off mid-execution, so the idle floor runs
        // until the completion at ~10.5 s, not until the churn instant.
        let mut sc = one_node_scenario(1, 2.0, 1);
        sc.specs[0].idle_w = 40.0;
        sc.config.base_exec_ms = 485.0; // service = 485·20.6 + 8 ≈ 9999 ms
        sc.churn = vec![ChurnEvent { at_s: 1.0, node: 0, up: false }];
        let service_s = sc.specs[0].simulate_latency_ms(485.0) / 1e3;
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 1);
        let n = &r.nodes[0];
        let want_uptime = 0.5 + service_s; // arrival at 0.5, drains to completion
        assert!(
            (n.uptime_s - want_uptime).abs() < 1e-9,
            "uptime {} want {want_uptime} (churn-time cutoff would give 1.0)",
            n.uptime_s
        );
        assert!((n.energy_idle_kwh - 40.0 * want_uptime / 3.6e6).abs() < 1e-15);
    }

    #[test]
    fn deferral_parks_work_until_cleaner_slot() {
        // Single node on a stepped trace: dirty for the first 100 s, clean
        // afterwards. Every arrival lands in the dirty window with enough
        // slack to reach the clean one.
        let mut sc = one_node_scenario(10, 1.0, 1);
        sc.traces = vec![
            IntensityTrace::from_samples(vec![(0.0, 800.0), (100.0, 100.0)]).unwrap(),
        ];
        sc.config.deferral = Some(DeferralSpec {
            slack_s: 200.0,
            headroom_s: 10.0,
            policy: DeferralPolicy { resolution_s: 5.0, min_gain: 0.05 },
        });
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 10);
        assert_eq!(r.deferred, 10, "every dirty-window arrival should park");
        assert_eq!(r.deadline_missed, 0);
        // All work executed in the clean window: carbon priced at 100, and
        // the effective intensity of dynamic energy says so.
        let eff = r.carbon_dynamic_g_total / r.energy_dynamic_kwh_total;
        assert!((eff - 100.0).abs() < 1e-6, "effective intensity {eff}");
        // The no-deferral twin burns the same energy at 8× the intensity.
        let mut twin = sc.clone();
        twin.config.deferral = None;
        let rt = Simulation::run(&twin, &mut s);
        assert_eq!(rt.deferred, 0);
        assert!(rt.carbon_dynamic_g_total > 7.0 * r.carbon_dynamic_g_total);
        // Parked time shows up as wait, not as lost requests.
        assert!(r.wait_ms.mean > 60_000.0, "parked wait {}", r.wait_ms.mean);
    }

    #[test]
    fn deadline_misses_are_counted() {
        // Deferral with zero headroom and a ~50 s service time: arrivals at
        // 10/20/30 s (deadlines 110/120/130) all defer into the clean
        // window at ~100 s, then serialize on the single node — every
        // completion lands past its deadline.
        let mut sc = one_node_scenario(3, 0.1, 1);
        sc.config.base_exec_ms = 2_427.0; // ≈ 50 s of service
        sc.traces = vec![
            IntensityTrace::from_samples(vec![(0.0, 800.0), (100.0, 100.0)]).unwrap(),
        ];
        sc.config.deferral = Some(DeferralSpec {
            slack_s: 100.0,
            headroom_s: 0.0,
            policy: DeferralPolicy { resolution_s: 7.0, min_gain: 0.05 },
        });
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 3);
        assert_eq!(r.deferred, 3);
        assert_eq!(r.deadline_missed, 3, "all completions land past their deadlines");
        // The same setup with generous headroom never defers past what the
        // deadline can absorb — zero misses is reachable by configuration.
        let mut safe = sc.clone();
        safe.config.base_exec_ms = SimConfig::default().base_exec_ms;
        let rs = Simulation::run(&safe, &mut s);
        assert_eq!(rs.deadline_missed, 0, "short service leaves the deadline intact");
    }

    #[test]
    fn full_battery_suppresses_raw_grid_deferral() {
        use crate::microgrid::{
            BatterySpec, ChargePolicy, DischargePolicy, MicrogridSpec, PvProfile,
        };
        // ROADMAP-flagged bugfix pin: a stepped dirty→clean grid that the
        // raw curve would park everything for, behind a full battery. The
        // node's *blended* effective intensity is ~0 right now (the battery
        // covers the marginal draw carbon-free), so no future slot can
        // clear the min-gain bar — deferring would only delay work the
        // battery serves cleanly today. The old engine consulted the raw
        // grid trace and parked all of it.
        let mut sc = one_node_scenario(10, 1.0, 1);
        sc.traces =
            vec![IntensityTrace::from_samples(vec![(0.0, 800.0), (100.0, 100.0)]).unwrap()];
        sc.config.deferral = Some(DeferralSpec {
            slack_s: 200.0,
            headroom_s: 10.0,
            policy: DeferralPolicy { resolution_s: 5.0, min_gain: 0.05 },
        });
        sc.microgrids = vec![Some(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec::simple(5_000.0, 1.0, 1.0),
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        })];
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 10);
        assert_eq!(r.deferred, 0, "charged battery must suppress the grid-curve defer");
        assert_eq!(r.deadline_missed, 0);
        assert_eq!(r.carbon_g_total, 0.0, "the battery supplies every joule");
        assert!(r.energy_battery_kwh_total > 0.0);
        // The identical grid-only twin still parks everything — exactly
        // the defer the blended forecast suppressed.
        let mut twin = sc.clone();
        twin.microgrids = Vec::new();
        let rt = Simulation::run(&twin, &mut s);
        assert_eq!(rt.deferred, 10);
        assert!(rt.carbon_g_total > 0.0);
    }

    #[test]
    fn queue_delay_estimates_surface_in_the_report() {
        // Saturated single node: backlog builds, so dispatch-time
        // queue-delay estimates grow past zero; the report carries their
        // p50/max per node.
        let sc = one_node_scenario(200, 50.0, 1);
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        let n = &r.nodes[0];
        assert!(n.queue_delay_ms_p50 > 0.0, "saturation must show up: {n:?}");
        assert!(n.queue_delay_ms_max >= n.queue_delay_ms_p50);
        // The estimate is backlog × service: with ~200 queued tasks at
        // ~206 ms service the max sits in the tens of seconds.
        assert!(n.queue_delay_ms_max > 10_000.0, "max {}", n.queue_delay_ms_max);
        // An unsaturated run never queues: every estimate is zero.
        let r0 = Simulation::run(&one_node_scenario(10, 1.0, 1), &mut s);
        assert_eq!(r0.nodes[0].queue_delay_ms_p50, 0.0);
        assert_eq!(r0.nodes[0].queue_delay_ms_max, 0.0);
    }

    #[test]
    fn pv_covers_daytime_draw_before_grid() {
        use crate::microgrid::{
            BatterySpec, ChargePolicy, DischargePolicy, MicrogridSpec, PvProfile,
        };
        // One node, no battery, 1 kW of PV shining over the whole short
        // run (sunrise shifted 6 h back puts solar noon at t = 0): every
        // dynamic joule is PV-supplied and the run is carbon-free.
        let mut sc = one_node_scenario(10, 1.0, 1);
        sc.microgrids = vec![Some(MicrogridSpec {
            pv: PvProfile::diurnal_with_sunrise(1_000.0, -21_600.0),
            battery: BatterySpec::none(),
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        })];
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 10);
        let n = &r.nodes[0];
        assert!(n.microgrid);
        assert!(n.energy_pv_kwh > 0.0);
        assert_eq!(n.energy_battery_kwh, 0.0);
        assert!(n.energy_grid_kwh.abs() < 1e-15, "grid used: {}", n.energy_grid_kwh);
        assert_eq!(r.carbon_g_total, 0.0);
        assert_eq!(r.carbon_per_req_g, 0.0);
        // Supply conservation: pv covers exactly idle + dynamic.
        let demand = n.energy_dynamic_kwh + n.energy_idle_kwh;
        assert!((n.energy_pv_kwh - demand).abs() <= 1e-9 * demand.max(1e-30));
        assert!((r.energy_pv_kwh_total - n.energy_pv_kwh).abs() < 1e-18);
        // The identical grid-only run prices every joule at 620 g/kWh.
        let plain = Simulation::run(&one_node_scenario(10, 1.0, 1), &mut s);
        assert!(plain.carbon_g_total > 0.0);
        assert_eq!(plain.nodes[0].energy_pv_kwh, 0.0);
        assert!(
            (plain.nodes[0].energy_grid_kwh - demand).abs() <= 1e-9 * demand,
            "grid-only node draws everything from the grid"
        );
    }

    #[test]
    fn battery_bridges_then_grid_takes_over() {
        use crate::microgrid::{
            BatterySpec, ChargePolicy, DischargePolicy, MicrogridSpec, PvProfile,
        };
        // No PV (midnight), a tiny fully-charged battery: the first task's
        // energy drains it, the rest imports grid power. 10 tasks × ~35 J
        // of dynamic energy each vs 36 J stored.
        let mut sc = one_node_scenario(10, 1.0, 1);
        sc.microgrids = vec![Some(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 0.01, // 36 J
                max_charge_w: 500.0,
                max_discharge_w: 500.0,
                rt_efficiency: 1.0,
                initial_soc: 1.0,
            },
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        })];
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 10);
        let n = &r.nodes[0];
        // The battery is fully drained...
        assert!((n.energy_battery_kwh - 36.0 / 3.6e6).abs() < 1e-15);
        assert_eq!(n.soc_timeline.last().unwrap().1, 0.0);
        // ...the rest comes from the grid, and the split conserves.
        let demand = n.energy_dynamic_kwh + n.energy_idle_kwh;
        assert!(n.energy_grid_kwh > 0.0);
        assert!(
            (n.energy_pv_kwh + n.energy_battery_kwh + n.energy_grid_kwh - demand).abs()
                <= 1e-9 * demand
        );
        // Carbon: exactly the grid share at the static intensity.
        let want_g = n.energy_grid_kwh * 620.0;
        assert!((r.carbon_g_total - want_g).abs() < 1e-12, "{} vs {want_g}", r.carbon_g_total);
        // The battery saved carbon vs the grid-only twin.
        let plain = Simulation::run(&one_node_scenario(10, 1.0, 1), &mut s);
        assert!(r.carbon_g_total < plain.carbon_g_total);
    }

    #[test]
    fn scheduler_follows_charged_battery_via_effective_intensity() {
        use crate::microgrid::{
            BatterySpec, ChargePolicy, DischargePolicy, MicrogridSpec, PvProfile,
        };
        // Two identical nodes on the same dirty grid; only one has a
        // charged battery. Green mode reads the blended effective
        // intensity through the override and routes everything there.
        let mut sc = one_node_scenario(50, 1.0, 1);
        sc.specs.push(sc.specs[0].clone());
        sc.specs[1].name = "solar".into();
        sc.traces.push(IntensityTrace::Static(620.0));
        sc.capacity.push(1);
        sc.microgrids = vec![
            None,
            Some(MicrogridSpec {
                pv: PvProfile::none(),
                battery: BatterySpec::simple(1_000.0, 0.9, 1.0),
                charge: ChargePolicy::Off,
                discharge: DischargePolicy::Greedy,
            }),
        ];
        let mut s = CarbonAwareScheduler::new("green", Mode::Green.weights());
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 50);
        assert_eq!(r.node("solar").unwrap().tasks, 50, "charge should attract every task");
        assert_eq!(r.nodes[0].tasks, 0);
        // All dynamic energy came out of the battery: a zero-carbon run.
        assert_eq!(r.carbon_g_total, 0.0);
        assert!(r.energy_battery_kwh_total > 0.0);
        let solar = r.node("solar").unwrap();
        assert!(
            (solar.energy_battery_kwh - solar.energy_dynamic_kwh).abs()
                <= 1e-9 * solar.energy_dynamic_kwh
        );
        // SoC timeline is monotone non-increasing (discharge only, no PV).
        let socs: Vec<f64> = solar.soc_timeline.iter().map(|&(_, s)| s).collect();
        assert!(socs.len() >= 2);
        assert!(socs.windows(2).all(|w| w[1] <= w[0] + 1e-12), "{socs:?}");
        assert!(socs[0] > *socs.last().unwrap(), "battery should drain");
    }

    #[test]
    fn grid_charge_arbitrage_settles_into_the_stored_ledger() {
        use crate::microgrid::{
            BatterySpec, ChargePolicy, DischargePolicy, MicrogridSpec, PvProfile,
        };
        // Clean first 100 s (100 g), dirty afterwards (800 g): the policy
        // imports during the clean window and the report carries the
        // charge-source split and a balanced stored-carbon ledger.
        let mut sc = one_node_scenario(20, 0.1, 1); // arrivals to t = 200
        sc.traces =
            vec![IntensityTrace::from_samples(vec![(0.0, 100.0), (100.0, 800.0)]).unwrap()];
        sc.microgrids = vec![Some(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 50.0,
                max_charge_w: 400.0,
                max_discharge_w: 400.0,
                rt_efficiency: 0.8,
                initial_soc: 0.0,
            },
            charge: ChargePolicy::Threshold { percentile: 0.25, window_s: 200.0 },
            discharge: DischargePolicy::Greedy,
        })];
        let mut s = RoundRobinScheduler::new();
        let r = Simulation::run(&sc, &mut s);
        assert_eq!(r.completed, 20);
        let n = &r.nodes[0];
        assert!(n.energy_grid_charge_kwh > 0.0, "clean window must import: {n:?}");
        assert!(n.carbon_charged_g > 0.0);
        // Ledger balance: everything bought is either released or stored.
        assert!(
            (n.carbon_charged_g - n.carbon_battery_g - n.carbon_stored_g).abs()
                <= 1e-9 * n.carbon_charged_g,
            "stored-carbon ledger unbalanced: {n:?}"
        );
        // Discharged joules billed their embodied carbon into the node
        // ledgers — arbitrage is not laundering.
        assert!(n.carbon_battery_g > 0.0, "dirty window should discharge: {n:?}");
        assert!(r.carbon_g_total >= n.carbon_battery_g);
        // Supply conservation is untouched by the charge flow: grid-charge
        // joules are battery input, not node supply.
        let supply = n.energy_pv_kwh + n.energy_battery_kwh + n.energy_grid_kwh;
        let demand = n.energy_dynamic_kwh + n.energy_idle_kwh;
        assert!((supply - demand).abs() <= 1e-6 * demand.max(1e-30), "{supply} vs {demand}");
        // Totals mirror the node rows.
        assert!((r.energy_grid_charge_kwh_total - n.energy_grid_charge_kwh).abs() < 1e-15);
        assert!((r.carbon_stored_g_total - n.carbon_stored_g).abs() < 1e-15);
        // The charge-policy-free twin never imports.
        let mut twin = sc.clone();
        if let Some(Some(mg)) = twin.microgrids.first_mut().map(|m| m.as_mut()) {
            mg.charge = ChargePolicy::Off;
        }
        let rt = Simulation::run(&twin, &mut s);
        assert_eq!(rt.energy_grid_charge_kwh_total, 0.0);
        assert_eq!(rt.carbon_charged_g_total, 0.0);
        assert_eq!(rt.carbon_stored_g_total, 0.0);
    }

    #[test]
    fn frozen_twin_is_identical_without_microgrid_deferral_overlap() {
        // The charge-frozen flag only touches microgrid forecast
        // construction: a deferral scenario with no microgrids replays
        // bit-for-bit under either mode.
        let mut sc = one_node_scenario(10, 1.0, 1);
        sc.traces =
            vec![IntensityTrace::from_samples(vec![(0.0, 800.0), (100.0, 100.0)]).unwrap()];
        sc.config.deferral = Some(DeferralSpec {
            slack_s: 200.0,
            headroom_s: 10.0,
            policy: DeferralPolicy { resolution_s: 5.0, min_gain: 0.05 },
        });
        let mut s = RoundRobinScheduler::new();
        let a = Simulation::run(&sc, &mut s);
        let mut frozen = sc.clone();
        frozen.config.charge_frozen_forecasts = true;
        let b = Simulation::run(&frozen, &mut s);
        assert_eq!(a, b, "frozen flag leaked into a microgrid-free run");
    }

    #[test]
    fn try_run_surfaces_invalid_scenarios_as_errors() {
        let mut sc = one_node_scenario(10, 1.0, 1);
        sc.config.deferral = Some(DeferralSpec {
            slack_s: 200.0,
            headroom_s: 10.0,
            policy: DeferralPolicy { resolution_s: 0.0, min_gain: 0.05 },
        });
        let mut s = RoundRobinScheduler::new();
        let err = Simulation::try_run(&sc, &mut s).unwrap_err();
        assert!(err.contains("resolution"), "unhelpful error: {err}");
        // Capacity and shape problems surface the same way.
        let mut bad = one_node_scenario(10, 1.0, 1);
        bad.capacity = vec![0];
        assert!(Simulation::try_run(&bad, &mut s).is_err());
        let mut shape = one_node_scenario(10, 1.0, 1);
        shape.traces.clear();
        assert!(Simulation::try_run(&shape, &mut s).is_err());
        // A valid scenario still runs.
        assert!(Simulation::try_run(&one_node_scenario(10, 1.0, 1), &mut s).is_ok());
    }
}
