"""L1 Pallas kernel: tiled matmul + bias + activation.

This is the compute hot-spot of the CarbonEdge model zoo: every pointwise
(1x1) convolution, the im2col-ed stem/head convolutions, the squeeze-excite
MLP and the classifier head all lower to this kernel.

TPU mapping (DESIGN.md #Hardware-Adaptation): the grid tiles M and N for the
MXU systolic array; K is kept VMEM-resident so each output tile is produced
in a single pass (no partial-accumulator HBM traffic). Bias add and the
activation are fused into the epilogue so the activation never makes an
extra HBM round-trip. On this image the kernel runs under ``interpret=True``
(CPU PJRT cannot execute Mosaic custom-calls); the lowering is identical in
structure, and TPU efficiency is estimated analytically in EXPERIMENTS.md
#Perf-L1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes. M/N tiles of 128 match the 128x128
# systolic array; they are clamped (and inputs zero-padded) for small layers.
# Overridable via env for the #Perf-L1 tile sweep (EXPERIMENTS.md).
import os

TILE_M = int(os.environ.get("CE_TILE_M", "512"))
TILE_N = int(os.environ.get("CE_TILE_N", "128"))

_ACTS = ("none", "relu", "relu6", "sigmoid", "silu")


def apply_act(x, act: str):
    """Apply a named activation (shared by kernels and the jnp oracle)."""
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    if act == "silu":
        return x * jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {act!r}")


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    # One (TILE_M, TILE_N) output tile per program. K is resident: a single
    # MXU-shaped dot produces the full tile, then the epilogue fuses
    # bias + activation before the tile is written back.
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    o_ref[...] = apply_act(acc, act).astype(o_ref.dtype)


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("act", "tile_m", "tile_n"))
def matmul_bias_act(x, w, b, act: str = "none", *, tile_m: int = TILE_M, tile_n: int = TILE_N):
    """``act(x @ w + b)`` via the tiled Pallas kernel.

    Args:
      x: ``(M, K)`` float array.
      w: ``(K, N)`` float array.
      b: ``(N,)`` bias.
      act: one of ``none|relu|relu6|sigmoid|silu`` (fused epilogue).

    Returns:
      ``(M, N)`` float32 array.
    """
    if act not in _ACTS:
        raise ValueError(f"unknown activation {act!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)

    bm = min(tile_m, _pad_to(m, 8))
    bn = min(tile_n, _pad_to(n, 8))
    mp, np_ = _pad_to(m, bm), _pad_to(n, bn)

    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, 0)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, np_ - n)))
    bp = jnp.pad(b.astype(jnp.float32), ((0, np_ - n),))

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_mm_kernel, act=act),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(m: int, k: int, n: int, tile_m: int = TILE_M, tile_n: int = TILE_N) -> int:
    """Analytic VMEM footprint of one program instance (float32).

    Used by the #Perf-L1 roofline estimate: x-tile + w-tile + bias + out-tile.
    """
    bm, bn = min(tile_m, m), min(tile_n, n)
    return 4 * (bm * k + k * bn + bn + bm * bn)


def mxu_utilization(m: int, k: int, n: int, tile_m: int = TILE_M, tile_n: int = TILE_N) -> float:
    """Fraction of MXU lanes doing useful work for this shape (padding waste)."""
    bm, bn = min(tile_m, _pad_to(m, 8)), min(tile_n, _pad_to(n, 8))
    mp, np_ = _pad_to(m, bm), _pad_to(n, bn)
    return (m * n * k) / float(mp * np_ * k)
