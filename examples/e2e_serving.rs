//! End-to-end serving driver (the repo's headline validation run):
//! loads a real (AOT-compiled) model, serves Poisson-arrival batched
//! requests across the simulated heterogeneous fleet in every scheduling
//! mode, and reports latency / throughput / carbon — recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```sh
//! cargo run --release --example e2e_serving -- [--requests 50] [--rate 8]
//! ```

use carbonedge::config::Config;
use carbonedge::coordinator::{Coordinator, ServingLoop};
use carbonedge::deployer;
use carbonedge::scheduler::{Amp4ecScheduler, CarbonAwareScheduler, Mode, Scheduler};
use carbonedge::util::cli::Args;
use carbonedge::util::table::{f2, f4, Table};
use carbonedge::workload::{Arrivals, RequestStream};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let requests = args.parse_or("requests", 50usize)?;
    let rate = args.parse_or("rate", 8.0f64)?;
    let model_name = args.str_or("model", "mobilenet_v2");

    let coord = Coordinator::new(Config::default())?;
    let model = coord.load_model(&model_name)?;
    println!(
        "e2e: serving {requests} Poisson requests @ {rate} req/s on {model_name} ({:.2}M params)",
        model.entry.params as f64 / 1e6
    );

    let mut table = Table::new(
        "End-to-end serving (Poisson arrivals, simulated 3-node edge fleet)",
        &[
            "Scheduler",
            "p50 (ms)",
            "p95 (ms)",
            "req/s",
            "gCO2/inf",
            "inf/gCO2",
            "queue (ms)",
            "sched (ms)",
        ],
    );

    let mut scheds: Vec<Box<dyn Scheduler>> = vec![
        Box::new(Amp4ecScheduler::new()),
        Box::new(CarbonAwareScheduler::new("performance", Mode::Performance.weights())),
        Box::new(CarbonAwareScheduler::new("balanced", Mode::Balanced.weights())),
        Box::new(CarbonAwareScheduler::new("green", Mode::Green.weights())),
    ];

    for sched in scheds.iter_mut() {
        let registry = coord.calibrated_registry(&model)?;
        let containers =
            deployer::deploy_task_level(&coord.exec(), &model, registry.nodes(), &coord.cfg)?;
        let stream = RequestStream {
            image_size: coord.manifest.image_size,
            arrivals: Arrivals::Poisson { count: requests, rate_hz: rate, seed: 42 },
            seed: 7,
        };
        let loop_ = ServingLoop::new(&registry, &containers);
        let name = sched.name().to_string();
        let out = loop_.serve(&stream, sched.as_mut(), &name)?;
        let r = &out.report;
        table.row(vec![
            name,
            f2(r.latency_ms.p50),
            f2(r.latency_ms.p95),
            f2(r.throughput_rps),
            f4(r.carbon_per_inf_g),
            f2(r.carbon_efficiency),
            f2(out.queue_ms_mean),
            format!("{:.4}", out.sched_ms_mean),
        ]);
        let usage: Vec<String> =
            r.node_usage.iter().map(|(n, c)| format!("{n}:{c}")).collect();
        println!("  {} -> {}", r.label, usage.join(" "));
    }
    println!("\n{}", table.render());
    Ok(())
}
