//! Aggregated results of one simulation run. `SimReport` is `PartialEq` so
//! determinism is testable as plain equality: identical (scenario, seed,
//! fresh scheduler) runs must produce identical reports, bit for bit.

use crate::obs::MonitorSummary;
use crate::util::stats::Summary;
use crate::util::table::{f2, f5, Table};

/// Per-node slice of the fleet ledger under the two-part energy model:
/// dynamic (task-attributed) and idle-floor energy/carbon are kept apart so
/// consolidation effects are visible per node. Conservation: `idle +
/// dynamic` across rows sums to the report totals — `tests/sim.rs` asserts
/// it for every scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeUsage {
    pub name: String,
    pub tasks: u64,
    pub busy_ms: f64,
    /// Virtual seconds this node was powered on — churn-down intervals
    /// excluded, except that a departing node stays powered (and keeps
    /// accruing its floor) until its in-service work finishes draining.
    /// This is what the idle floor is integrated over.
    pub uptime_s: f64,
    /// Median queue-delay estimate (ms) across this node's dispatches —
    /// the backlog × mean-service ÷ slots figure the `FleetView`
    /// advertised to the scheduler at each decision. Zero when the node
    /// never queued (or never ran work).
    pub queue_delay_ms_p50: f64,
    /// 99th-percentile queue-delay estimate (ms) across this node's
    /// dispatches — the tail the mean and median hide.
    pub queue_delay_ms_p99: f64,
    /// Worst queue-delay estimate (ms) across this node's dispatches.
    pub queue_delay_ms_max: f64,
    /// Task-attributed energy: `dynamic_power_w × busy time`.
    pub energy_dynamic_kwh: f64,
    /// Idle-floor energy: `idle_w × uptime`.
    pub energy_idle_kwh: f64,
    /// Emissions of the dynamic energy, priced at completion-time intensity
    /// (microgrid nodes: the dynamic share of their grid-supplied carbon,
    /// accrued slice-by-slice).
    pub carbon_dynamic_g: f64,
    /// Emissions of the idle energy, integrated piecewise against the
    /// node's intensity trace over its uptime (microgrid nodes: the idle
    /// share of their grid-supplied carbon).
    pub carbon_idle_g: f64,
    /// Whether this node sits behind a PV + battery microgrid.
    pub microgrid: bool,
    /// Supply-side split of the node's draw: PV consumed directly...
    pub energy_pv_kwh: f64,
    /// ...battery discharge...
    pub energy_battery_kwh: f64,
    /// ...and grid import (for grid-only nodes this is simply idle +
    /// dynamic). Conservation: `pv + battery + grid == idle + dynamic` per
    /// node — `tests/sim.rs` asserts it for every scenario.
    pub energy_grid_kwh: f64,
    /// Grid energy imported *into the battery* (kWh, input side before
    /// round-trip losses) under a [`crate::microgrid::ChargePolicy`] —
    /// the arbitrage flow, deliberately outside the supply identity
    /// above (it is battery input, not node supply).
    pub energy_grid_charge_kwh: f64,
    /// Embodied carbon bought into the store by grid charging (grams,
    /// PUE applied, priced at charge-time slice-mean intensity).
    pub carbon_charged_g: f64,
    /// Embodied carbon released by battery discharge (grams, PUE
    /// applied) — a labelled subset of `carbon_dynamic_g +
    /// carbon_idle_g`. Stored-carbon balance, asserted per scenario by
    /// `tests/sim.rs`: `carbon_charged_g == carbon_battery_g +
    /// carbon_stored_g`.
    pub carbon_battery_g: f64,
    /// Embodied carbon still sitting in the store at the horizon (grams,
    /// PUE applied): bought but not yet billed to any task or idle
    /// ledger.
    pub carbon_stored_g: f64,
    /// `(virtual seconds, state-of-charge fraction)` samples over the run
    /// (empty for grid-only nodes).
    pub soc_timeline: Vec<(f64, f64)>,
    /// One-refresh-ahead SoC projections `(target t, projected soc)` from
    /// the engine's own trajectory forecasts — compare against
    /// `soc_timeline` for the projected-vs-actual diagnostic. Empty for
    /// grid-only nodes, runs without deferral, and charge-frozen twins.
    pub soc_projection: Vec<(f64, f64)>,
}

impl NodeUsage {
    /// Total energy (idle + dynamic) attributed to this node.
    pub fn energy_kwh(&self) -> f64 {
        self.energy_dynamic_kwh + self.energy_idle_kwh
    }

    /// Total emissions (idle + dynamic) attributed to this node.
    pub fn carbon_g(&self) -> f64 {
        self.carbon_dynamic_g + self.carbon_idle_g
    }
}

/// Per-workload-class slice of a multi-tenant run
/// ([`crate::workload::WorkloadClass`]): completions, SLO compliance
/// against the class's own latency budget (clocked from arrival,
/// independent of deferral slack), latency distribution, and the
/// *dynamic* energy/carbon attributed to the class's tasks (the idle
/// floor has no per-class owner). `batches` counts sealed batches on
/// the batched service path (0 when batching is off), so `completed /
/// batches` is the realized mean fill.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassUsage {
    pub name: String,
    pub completed: u64,
    /// Requests of this class turned away — scheduler `Reject` verdicts
    /// plus admission-control sheds under sustained overload
    /// ([`crate::sim::AdmissionSpec`]). Conservation: sums to the
    /// report-level `rejected` whenever `classes` is non-empty.
    pub rejected: u64,
    /// The class's latency SLO (seconds) — copied from the mix so the
    /// report is self-describing.
    pub slo_s: f64,
    /// Completions that landed later than `arrival + slo_s`.
    pub slo_missed: u64,
    /// Batches sealed for this class (batched service path only).
    pub batches: u64,
    /// End-to-end latency (formation wait + batch service), ms.
    pub latency_ms: Summary,
    /// Task-attributed (dynamic) energy for this class's completions.
    pub energy_dynamic_kwh: f64,
    /// Emissions of that dynamic energy.
    pub carbon_dynamic_g: f64,
    /// Dynamic gCO₂ per completed request of this class.
    pub carbon_per_req_g: f64,
}

impl ClassUsage {
    /// Realized mean batch fill (tasks per sealed batch); 0 when the
    /// run never batched this class.
    pub fn mean_fill(&self) -> f64 {
        if self.batches > 0 {
            self.completed as f64 / self.batches as f64
        } else {
            0.0
        }
    }
}

/// Per-site slice of a geographic run ([`crate::site::SiteLayer`]): how
/// much work each region's grid ate, how much of it arrived over the WAN,
/// and what the cross-site hops themselves cost. `carbon_g` already
/// includes `carbon_wan_g` (transfer emissions are billed to the
/// *origin* site that chose to ship). Conservation: site rows partition
/// the fleet — energy/carbon sums match the report totals at 1e-6, and
/// `tests/sim.rs` asserts it for the multi-site scenarios.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteUsage {
    pub name: String,
    /// Nodes homed at this site.
    pub nodes: usize,
    /// Requests completed on this site's nodes (wherever they arrived).
    pub completed: u64,
    /// Requests that arrived here but were routed to another site.
    pub shipped_out: u64,
    /// Requests routed here from another site.
    pub shipped_in: u64,
    /// Node energy (idle + dynamic) of this site's members, WAN excluded.
    pub energy_kwh: f64,
    /// WAN transfer energy paid by requests shipped *out* of this site.
    pub energy_wan_kwh: f64,
    /// Total emissions attributed to this site: member idle + dynamic
    /// carbon plus `carbon_wan_g`.
    pub carbon_g: f64,
    /// Emissions of the WAN transfer energy, priced at the origin grid's
    /// ship-time intensity (zero when the origin runs carbon-free).
    pub carbon_wan_g: f64,
    /// `carbon_g` per completion landed on this site (0 when idle).
    pub carbon_per_req_g: f64,
}

/// Everything one simulation run produced.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    pub scenario: String,
    pub scheduler: String,
    pub seed: u64,
    /// Requests that arrived (generated by the arrival process).
    pub requests: u64,
    pub completed: u64,
    /// Requests no feasible node would take (scheduler returned `None`).
    pub rejected: u64,
    /// Queued requests re-routed when their node departed (churn).
    pub migrated: u64,
    /// Requests parked by the in-engine deferral policy for a cleaner
    /// forecast slot (each is later released and counted as completed or
    /// rejected like any other request).
    pub deferred: u64,
    /// Requests that completed after their deadline (only possible when the
    /// scenario configures deferral slack).
    pub deadline_missed: u64,
    /// Virtual time of the last completion (s).
    pub makespan_s: f64,
    pub throughput_rps: f64,
    /// End-to-end latency (queue wait + deferral parking + service), ms.
    pub latency_ms: Summary,
    /// Queue wait (including deferral parking) alone, ms.
    pub wait_ms: Summary,
    /// Total energy: dynamic + idle (+ WAN transfer on multi-site runs).
    pub energy_kwh_total: f64,
    pub energy_dynamic_kwh_total: f64,
    pub energy_idle_kwh_total: f64,
    /// WAN transfer energy across all cross-site hops — *on top of* the
    /// idle + dynamic node split, and included in `energy_kwh_total`.
    /// Zero (and absent from render/JSON) on flat fleets.
    pub energy_wan_kwh_total: f64,
    /// Supply-side totals: PV + battery + grid == total energy (grid-only
    /// nodes contribute their whole draw to the grid term).
    pub energy_pv_kwh_total: f64,
    pub energy_battery_kwh_total: f64,
    pub energy_grid_kwh_total: f64,
    /// Arbitrage totals: grid energy imported into batteries, the
    /// embodied carbon bought with it, the share released by discharge
    /// (already inside the carbon totals) and the share still stored at
    /// the horizon (not billed anywhere yet).
    pub energy_grid_charge_kwh_total: f64,
    pub carbon_charged_g_total: f64,
    pub carbon_battery_g_total: f64,
    pub carbon_stored_g_total: f64,
    /// Total emissions: dynamic + idle (+ WAN transfer on multi-site
    /// runs).
    pub carbon_g_total: f64,
    pub carbon_dynamic_g_total: f64,
    pub carbon_idle_g_total: f64,
    /// Emissions of the WAN transfer energy — included in
    /// `carbon_g_total` and the per-request figure.
    pub carbon_wan_g_total: f64,
    /// Total emissions (idle included) per completed request.
    pub carbon_per_req_g: f64,
    /// Per-workload-class rows — empty unless the scenario configures a
    /// [`crate::workload::WorkloadMix`] (legacy single-class reports
    /// stay bit-identical).
    pub classes: Vec<ClassUsage>,
    /// Name of the cross-site [`crate::site::Router`] in effect — empty
    /// string on flat (siteless) fleets.
    pub router: String,
    /// Requests the router shipped to a non-home site over the WAN.
    pub wan_shipped: u64,
    /// Per-site rows — empty unless the scenario configures a
    /// [`crate::site::SiteLayer`] (flat reports stay bit-identical).
    pub sites: Vec<SiteUsage>,
    pub nodes: Vec<NodeUsage>,
    /// Per-rule monitor summaries — empty unless a
    /// [`crate::obs::MonitorSet`] was attached
    /// ([`crate::sim::Simulation::try_run_monitored`]). Deterministic:
    /// rules evaluate over virtual time only, so identical seeds still
    /// produce identical reports with monitors on.
    pub monitors: Vec<MonitorSummary>,
}

/// Sum the supply split over node rows: `(pv kWh, battery kWh, grid kWh)`.
/// Single source of truth for the report totals (the engine builds them
/// from this at report time) and [`SimReport::node_sums_supply`].
pub(crate) fn sum_supply(nodes: &[NodeUsage]) -> (f64, f64, f64) {
    nodes.iter().fold((0.0, 0.0, 0.0), |(p, b, g), n| {
        (p + n.energy_pv_kwh, b + n.energy_battery_kwh, g + n.energy_grid_kwh)
    })
}

/// Sum the storage/arbitrage ledger over node rows: `(grid-charge kWh,
/// charged g, discharged g, stored g)`. Single source for the report
/// totals and [`SimReport::node_sums_storage`].
pub(crate) fn sum_storage(nodes: &[NodeUsage]) -> (f64, f64, f64, f64) {
    nodes.iter().fold((0.0, 0.0, 0.0, 0.0), |(e, c, b, s), n| {
        (
            e + n.energy_grid_charge_kwh,
            c + n.carbon_charged_g,
            b + n.carbon_battery_g,
            s + n.carbon_stored_g,
        )
    })
}

/// `Summary::of` panics on empty samples; a run where every request was
/// rejected still deserves a report.
pub(crate) fn summary_or_zero(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        Summary::of(&[0.0])
    } else {
        Summary::of(xs)
    }
}

impl SimReport {
    /// Per-node row by name.
    pub fn node(&self, name: &str) -> Option<&NodeUsage> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Per-class row by name (multi-tenant runs only).
    pub fn class(&self, name: &str) -> Option<&ClassUsage> {
        self.classes.iter().find(|c| c.name == name)
    }

    /// Per-site row by name (multi-site runs only).
    pub fn site(&self, name: &str) -> Option<&SiteUsage> {
        self.sites.iter().find(|s| s.name == name)
    }

    /// Sum of the per-site rows: `(completed, shipped out, total energy
    /// kWh, total carbon g, wan kWh, wan g)` — the conservation
    /// counterpart to the fleet totals (site rows partition the fleet;
    /// `tests/sim.rs` asserts it at 1e-6 for the multi-site scenarios).
    pub fn site_sums(&self) -> (u64, u64, f64, f64, f64, f64) {
        self.sites.iter().fold((0, 0, 0.0, 0.0, 0.0, 0.0), |(n, o, e, c, we, wc), s| {
            (
                n + s.completed,
                o + s.shipped_out,
                e + s.energy_kwh + s.energy_wan_kwh,
                c + s.carbon_g,
                we + s.energy_wan_kwh,
                wc + s.carbon_wan_g,
            )
        })
    }

    /// Sum of the per-class completion counters — the conservation
    /// counterpart to `completed` (equal whenever `classes` is
    /// non-empty; `tests/sim.rs` asserts it).
    pub fn class_sums(&self) -> (u64, u64, f64, f64) {
        self.classes.iter().fold((0, 0, 0.0, 0.0), |(n, m, e, c), cl| {
            (
                n + cl.completed,
                m + cl.slo_missed,
                e + cl.energy_dynamic_kwh,
                c + cl.carbon_dynamic_g,
            )
        })
    }

    /// Sum of the per-node ledger rows (tasks, total energy, total carbon)
    /// — the conservation counterpart to the streamed totals.
    pub fn node_sums(&self) -> (u64, f64, f64) {
        self.nodes.iter().fold((0, 0.0, 0.0), |(t, e, c), n| {
            (t + n.tasks, e + n.energy_kwh(), c + n.carbon_g())
        })
    }

    /// Per-part sums of the node rows: `(dynamic kWh, idle kWh, dynamic g,
    /// idle g)` — conservation of the two-part split itself.
    pub fn node_sums_split(&self) -> (f64, f64, f64, f64) {
        self.nodes.iter().fold((0.0, 0.0, 0.0, 0.0), |(ed, ei, cd, ci), n| {
            (
                ed + n.energy_dynamic_kwh,
                ei + n.energy_idle_kwh,
                cd + n.carbon_dynamic_g,
                ci + n.carbon_idle_g,
            )
        })
    }

    /// Supply-side sums of the node rows: `(pv kWh, battery kWh, grid
    /// kWh)` — the conservation counterpart of the supply totals.
    pub fn node_sums_supply(&self) -> (f64, f64, f64) {
        sum_supply(&self.nodes)
    }

    /// Storage-ledger sums of the node rows: `(grid-charge kWh, charged
    /// g, discharged g, stored g)` — the conservation counterpart of the
    /// arbitrage totals.
    pub fn node_sums_storage(&self) -> (f64, f64, f64, f64) {
        sum_storage(&self.nodes)
    }

    /// One-block human-readable report (CLI / examples).
    pub fn render(&self) -> String {
        let mut out = format!(
            "scenario {} · scheduler {} · seed {}\n\
             {} arrived / {} completed / {} rejected / {} migrated / {} deferred ({} missed deadline)\n\
             over {:.1} virtual s ({:.1} req/s)\n\
             latency {:.2} ms mean (p95 {:.2}, p99 {:.2}), wait {:.2} ms mean (p99 {:.2})\n\
             energy {:.6} kWh ({:.6} dynamic + {:.6} idle), carbon {:.4} g ({:.6} g/req)\n",
            self.scenario,
            self.scheduler,
            self.seed,
            self.requests,
            self.completed,
            self.rejected,
            self.migrated,
            self.deferred,
            self.deadline_missed,
            self.makespan_s,
            self.throughput_rps,
            self.latency_ms.mean,
            self.latency_ms.p95,
            self.latency_ms.p99,
            self.wait_ms.mean,
            self.wait_ms.p99,
            self.energy_kwh_total,
            self.energy_dynamic_kwh_total,
            self.energy_idle_kwh_total,
            self.carbon_g_total,
            self.carbon_per_req_g,
        );
        let microgrids = self.nodes.iter().any(|n| n.microgrid);
        if microgrids {
            out.push_str(&format!(
                "supply {:.6} pv + {:.6} battery + {:.6} grid kWh\n",
                self.energy_pv_kwh_total,
                self.energy_battery_kwh_total,
                self.energy_grid_kwh_total,
            ));
        }
        if self.energy_grid_charge_kwh_total > 0.0 {
            out.push_str(&format!(
                "arbitrage {:.6} kWh grid-charged ({:.4} g embodied: {:.4} g discharged + {:.4} g stored)\n",
                self.energy_grid_charge_kwh_total,
                self.carbon_charged_g_total,
                self.carbon_battery_g_total,
                self.carbon_stored_g_total,
            ));
        }
        if !self.sites.is_empty() {
            out.push_str(&format!(
                "router {} · {} shipped cross-site · wan {:.6} kWh / {:.4} g\n",
                self.router,
                self.wan_shipped,
                self.energy_wan_kwh_total,
                self.carbon_wan_g_total,
            ));
            let mut st = Table::new(
                "",
                &[
                    "site",
                    "nodes",
                    "done",
                    "out",
                    "in",
                    "energy (kWh)",
                    "wan (kWh)",
                    "carbon (g)",
                    "g/req",
                ],
            );
            for s in &self.sites {
                st.row(vec![
                    s.name.clone(),
                    s.nodes.to_string(),
                    s.completed.to_string(),
                    s.shipped_out.to_string(),
                    s.shipped_in.to_string(),
                    format!("{:.6}", s.energy_kwh),
                    format!("{:.6}", s.energy_wan_kwh),
                    f5(s.carbon_g),
                    f5(s.carbon_per_req_g),
                ]);
            }
            out.push_str(&st.render());
        }
        if !self.classes.is_empty() {
            let mut ct = Table::new(
                "",
                &[
                    "class",
                    "done",
                    "rej",
                    "slo (s)",
                    "missed",
                    "batches",
                    "fill",
                    "p50 (ms)",
                    "p99 (ms)",
                    "dyn (kWh)",
                    "g/req",
                ],
            );
            for c in &self.classes {
                ct.row(vec![
                    c.name.clone(),
                    c.completed.to_string(),
                    c.rejected.to_string(),
                    if c.slo_s.is_finite() { f2(c.slo_s) } else { "-".into() },
                    c.slo_missed.to_string(),
                    c.batches.to_string(),
                    if c.batches > 0 { f2(c.mean_fill()) } else { "-".into() },
                    f2(c.latency_ms.p50),
                    f2(c.latency_ms.p99),
                    format!("{:.6}", c.energy_dynamic_kwh),
                    f5(c.carbon_per_req_g),
                ]);
            }
            out.push_str(&ct.render());
        }
        let mut t = if microgrids {
            Table::new(
                "",
                &[
                    "node",
                    "tasks",
                    "busy (s)",
                    "up (s)",
                    "qd50 (ms)",
                    "qd99 (ms)",
                    "dyn (kWh)",
                    "idle (kWh)",
                    "pv (kWh)",
                    "batt (kWh)",
                    "grid (kWh)",
                    "soc",
                    "carbon (g)",
                ],
            )
        } else {
            Table::new(
                "",
                &[
                    "node",
                    "tasks",
                    "busy (s)",
                    "up (s)",
                    "qd50 (ms)",
                    "qd99 (ms)",
                    "dyn (kWh)",
                    "idle (kWh)",
                    "carbon (g)",
                ],
            )
        };
        if !self.monitors.is_empty() {
            let mut mt = Table::new(
                "",
                &["monitor", "threshold", "window (s)", "alerts", "first (s)", "peak"],
            );
            for m in &self.monitors {
                mt.row(vec![
                    m.rule.clone(),
                    f5(m.threshold),
                    f2(m.window_s),
                    m.alerts.to_string(),
                    m.first_alert_s.map(f2).unwrap_or_else(|| "-".into()),
                    f5(m.peak),
                ]);
            }
            out.push_str(&mt.render());
        }
        for n in &self.nodes {
            let mut row = vec![
                n.name.clone(),
                n.tasks.to_string(),
                f2(n.busy_ms / 1e3),
                f2(n.uptime_s),
                f2(n.queue_delay_ms_p50),
                f2(n.queue_delay_ms_p99),
                format!("{:.6}", n.energy_dynamic_kwh),
                format!("{:.6}", n.energy_idle_kwh),
            ];
            if microgrids {
                row.push(format!("{:.6}", n.energy_pv_kwh));
                row.push(format!("{:.6}", n.energy_battery_kwh));
                row.push(format!("{:.6}", n.energy_grid_kwh));
                row.push(match n.soc_timeline.last() {
                    Some(&(_, soc)) if n.microgrid => format!("{:.0}%", soc * 100.0),
                    _ => "-".into(),
                });
            }
            row.push(f5(n.carbon_g()));
            t.row(row);
        }
        out.push_str(&t.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> SimReport {
        SimReport {
            scenario: "unit".into(),
            scheduler: "green".into(),
            seed: 1,
            requests: 3,
            completed: 2,
            rejected: 1,
            migrated: 0,
            deferred: 1,
            deadline_missed: 0,
            makespan_s: 4.0,
            throughput_rps: 0.5,
            latency_ms: Summary::of(&[100.0, 200.0]),
            wait_ms: Summary::of(&[0.0, 10.0]),
            energy_kwh_total: 4e-5,
            energy_dynamic_kwh_total: 3e-5,
            energy_idle_kwh_total: 1e-5,
            energy_wan_kwh_total: 0.0,
            energy_pv_kwh_total: 0.5e-5,
            energy_battery_kwh_total: 0.5e-5,
            energy_grid_kwh_total: 3e-5,
            energy_grid_charge_kwh_total: 1e-5,
            carbon_charged_g_total: 0.006,
            carbon_battery_g_total: 0.004,
            carbon_stored_g_total: 0.002,
            carbon_g_total: 0.017,
            carbon_dynamic_g_total: 0.012,
            carbon_idle_g_total: 0.005,
            carbon_wan_g_total: 0.0,
            carbon_per_req_g: 0.0085,
            classes: Vec::new(),
            router: String::new(),
            wan_shipped: 0,
            sites: Vec::new(),
            nodes: vec![
                NodeUsage {
                    name: "a".into(),
                    tasks: 1,
                    busy_ms: 100.0,
                    uptime_s: 4.0,
                    queue_delay_ms_p50: 125.0,
                    queue_delay_ms_p99: 240.0,
                    queue_delay_ms_max: 250.0,
                    energy_dynamic_kwh: 1e-5,
                    energy_idle_kwh: 1e-5,
                    carbon_dynamic_g: 0.004,
                    carbon_idle_g: 0.005,
                    microgrid: true,
                    energy_pv_kwh: 0.5e-5,
                    energy_battery_kwh: 0.5e-5,
                    energy_grid_kwh: 1e-5,
                    energy_grid_charge_kwh: 1e-5,
                    carbon_charged_g: 0.006,
                    carbon_battery_g: 0.004,
                    carbon_stored_g: 0.002,
                    soc_timeline: vec![(0.0, 0.3), (4.0, 0.8)],
                    soc_projection: vec![(4.0, 0.78)],
                },
                NodeUsage {
                    name: "b".into(),
                    tasks: 1,
                    busy_ms: 200.0,
                    uptime_s: 4.0,
                    queue_delay_ms_p50: 0.0,
                    queue_delay_ms_p99: 0.0,
                    queue_delay_ms_max: 0.0,
                    energy_dynamic_kwh: 2e-5,
                    energy_idle_kwh: 0.0,
                    carbon_dynamic_g: 0.008,
                    carbon_idle_g: 0.0,
                    microgrid: false,
                    energy_pv_kwh: 0.0,
                    energy_battery_kwh: 0.0,
                    energy_grid_kwh: 2e-5,
                    energy_grid_charge_kwh: 0.0,
                    carbon_charged_g: 0.0,
                    carbon_battery_g: 0.0,
                    carbon_stored_g: 0.0,
                    soc_timeline: Vec::new(),
                    soc_projection: Vec::new(),
                },
            ],
            monitors: Vec::new(),
        }
    }

    #[test]
    fn node_lookup_and_sums() {
        let r = report();
        assert_eq!(r.node("b").unwrap().tasks, 1);
        assert!(r.node("zzz").is_none());
        let (tasks, energy, carbon) = r.node_sums();
        assert_eq!(tasks, 2);
        assert!((energy - 4e-5).abs() < 1e-15);
        assert!((carbon - 0.017).abs() < 1e-15);
        let (ed, ei, cd, ci) = r.node_sums_split();
        assert!((ed - 3e-5).abs() < 1e-15);
        assert!((ei - 1e-5).abs() < 1e-15);
        assert!((cd - 0.012).abs() < 1e-15);
        assert!((ci - 0.005).abs() < 1e-15);
        // Supply-side conservation: pv + battery + grid == idle + dynamic.
        let (pv, batt, grid) = r.node_sums_supply();
        assert!((pv - 0.5e-5).abs() < 1e-15);
        assert!((batt - 0.5e-5).abs() < 1e-15);
        assert!((grid - 3e-5).abs() < 1e-15);
        assert!((pv + batt + grid - r.energy_kwh_total).abs() < 1e-15);
        // Storage-ledger sums mirror the totals and balance.
        let (gc, charged, spent, stored) = r.node_sums_storage();
        assert!((gc - r.energy_grid_charge_kwh_total).abs() < 1e-15);
        assert!((charged - r.carbon_charged_g_total).abs() < 1e-15);
        assert!((spent - r.carbon_battery_g_total).abs() < 1e-15);
        assert!((stored - r.carbon_stored_g_total).abs() < 1e-15);
        assert!((charged - spent - stored).abs() < 1e-15);
    }

    #[test]
    fn usage_combines_idle_and_dynamic() {
        let r = report();
        let a = r.node("a").unwrap();
        assert!((a.energy_kwh() - 2e-5).abs() < 1e-15);
        assert!((a.carbon_g() - 0.009).abs() < 1e-15);
    }

    #[test]
    fn render_mentions_everything_load_bearing() {
        let s = report().render();
        assert!(s.contains("unit"));
        assert!(s.contains("green"));
        assert!(s.contains("2 completed"));
        assert!(s.contains("1 deferred"));
        assert!(s.contains("| a"));
        assert!(s.contains("| b"));
        assert!(s.contains("idle (kWh)"));
        // Queue-delay estimates render per node (p50 + p99 columns), and
        // the header line carries the latency tail.
        assert!(s.contains("qd50 (ms)"));
        assert!(s.contains("qd99 (ms)"));
        assert!(s.contains("125.00"));
        assert!(s.contains("240.00"));
        assert!(s.contains("p99 200.00"), "{s}");
        // The fixture has a microgrid node: the supply split shows up, and
        // node a's final state of charge renders while grid-only b dashes.
        assert!(s.contains("supply"));
        assert!(s.contains("pv (kWh)"));
        assert!(s.contains("80%"));
        assert!(s.contains("| -"));
        // A fleet without microgrids keeps the compact table.
        let mut plain = report();
        for n in &mut plain.nodes {
            n.microgrid = false;
            n.soc_timeline.clear();
        }
        let sp = plain.render();
        assert!(!sp.contains("supply"));
        assert!(!sp.contains("pv (kWh)"));
    }

    #[test]
    fn render_shows_arbitrage_line_only_when_grid_charging() {
        let s = report().render();
        assert!(s.contains("arbitrage"), "{s}");
        assert!(s.contains("stored"), "{s}");
        let mut off = report();
        off.energy_grid_charge_kwh_total = 0.0;
        assert!(!off.render().contains("arbitrage"));
    }

    #[test]
    fn monitor_table_renders_only_when_rules_attached() {
        let plain = report();
        assert!(!plain.render().contains("monitor"), "no rules, no table");
        let mut monitored = report();
        monitored.monitors = vec![
            MonitorSummary {
                rule: "carbon-budget".into(),
                threshold: 0.5,
                window_s: 600.0,
                alerts: 2,
                first_alert_s: Some(42.5),
                peak: 0.9,
            },
            MonitorSummary {
                rule: "slo-burn".into(),
                threshold: 10.0,
                window_s: 600.0,
                alerts: 0,
                first_alert_s: None,
                peak: 1.5,
            },
        ];
        let s = monitored.render();
        assert!(s.contains("| carbon-budget"), "{s}");
        assert!(s.contains("42.50"), "{s}");
        assert!(s.contains("| slo-burn"), "{s}");
        assert!(s.contains("| -"), "never-fired rule dashes first-alert: {s}");
    }

    #[test]
    fn site_table_renders_only_for_multi_site_runs() {
        // Flat (siteless) reports carry no site rows and no router line.
        let plain = report();
        assert!(plain.sites.is_empty());
        assert!(!plain.render().contains("router"));
        // A geographic run renders the router line plus one row per
        // site, and the lookup/sums helpers agree with the totals.
        let mut geo = report();
        geo.router = "deadline".into();
        geo.wan_shipped = 1;
        geo.energy_wan_kwh_total = 1e-7;
        geo.carbon_wan_g_total = 0.0001;
        geo.sites = vec![
            SiteUsage {
                name: "eu-west".into(),
                nodes: 1,
                completed: 1,
                shipped_out: 1,
                shipped_in: 0,
                energy_kwh: 2e-5,
                energy_wan_kwh: 1e-7,
                carbon_g: 0.0091,
                carbon_wan_g: 0.0001,
                carbon_per_req_g: 0.0091,
            },
            SiteUsage {
                name: "us-west".into(),
                nodes: 1,
                completed: 1,
                shipped_out: 0,
                shipped_in: 1,
                energy_kwh: 2e-5,
                energy_wan_kwh: 0.0,
                carbon_g: 0.008,
                carbon_wan_g: 0.0,
                carbon_per_req_g: 0.008,
            },
        ];
        let s = geo.render();
        assert!(s.contains("router deadline"), "{s}");
        assert!(s.contains("1 shipped cross-site"), "{s}");
        assert!(s.contains("| eu-west"), "{s}");
        assert!(s.contains("| us-west"), "{s}");
        assert!(s.contains("wan (kWh)"), "{s}");
        assert_eq!(geo.site("us-west").unwrap().shipped_in, 1);
        assert!(geo.site("zzz").is_none());
        let (done, out, energy, carbon, wan_e, wan_g) = geo.site_sums();
        assert_eq!((done, out), (2, 1));
        assert!((energy - (4e-5 + 1e-7)).abs() < 1e-15);
        assert!((carbon - 0.0171).abs() < 1e-15);
        assert!((wan_e - 1e-7).abs() < 1e-15);
        assert!((wan_g - 0.0001).abs() < 1e-15);
    }

    #[test]
    fn empty_sample_guard() {
        assert_eq!(summary_or_zero(&[]).mean, 0.0);
        assert_eq!(summary_or_zero(&[5.0]).mean, 5.0);
    }

    #[test]
    fn class_table_renders_only_for_multi_tenant_runs() {
        // Single-class (legacy) reports carry no class rows and render
        // no class table.
        let plain = report();
        assert!(plain.classes.is_empty());
        assert!(!plain.render().contains("slo (s)"));
        // A multi-tenant run renders one row per class with fill and
        // SLO-miss columns, and the lookup/sums helpers agree.
        let mut multi = report();
        multi.classes = vec![
            ClassUsage {
                name: "interactive".into(),
                completed: 120,
                rejected: 4,
                slo_s: 3.0,
                slo_missed: 2,
                batches: 40,
                latency_ms: Summary::of(&[80.0, 120.0]),
                energy_dynamic_kwh: 2e-5,
                carbon_dynamic_g: 0.01,
                carbon_per_req_g: 0.01 / 120.0,
            },
            ClassUsage {
                name: "background".into(),
                completed: 30,
                rejected: 0,
                slo_s: f64::INFINITY,
                slo_missed: 0,
                batches: 0,
                latency_ms: Summary::of(&[900.0]),
                energy_dynamic_kwh: 1e-5,
                carbon_dynamic_g: 0.02,
                carbon_per_req_g: 0.02 / 30.0,
            },
        ];
        let s = multi.render();
        assert!(s.contains("| interactive"), "{s}");
        assert!(s.contains("| background"), "{s}");
        assert!(s.contains("slo (s)"));
        assert!(s.contains("rej"), "admission sheds render per class: {s}");
        assert!(s.contains("3.00"), "finite SLOs render in seconds: {s}");
        let interactive = multi.class("interactive").unwrap();
        assert!((interactive.mean_fill() - 3.0).abs() < 1e-12);
        assert_eq!(multi.class("background").unwrap().mean_fill(), 0.0);
        assert!(multi.class("zzz").is_none());
        let (done, missed, energy, carbon) = multi.class_sums();
        assert_eq!((done, missed), (150, 2));
        assert!((energy - 3e-5).abs() < 1e-15);
        assert!((carbon - 0.03).abs() < 1e-15);
    }
}
