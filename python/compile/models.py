"""L2 model zoo: MobileNetV2 / MobileNetV4-style / EfficientNet-B0-style.

The paper evaluates torchvision MobileNetV2, MobileNetV4 and EfficientNet-B0
at 224x224. We rebuild the same architectures in JAX on top of the L1 Pallas
kernels, width-scaled and at a configurable (default 64x64) input size so the
AOT artifacts compile and execute quickly on this CPU-only image
(substitution table: DESIGN.md section 7). Weights are deterministic
(seeded He-normal with folded-BN biases) and are exported as packed binary
sidecars; the lowered HLO takes them as *arguments* (like a real serving
runtime: weights are loaded at deploy time, not baked into the program).

Each model is exposed as an ordered list of **stages** (stem / block groups /
head). `aot.py` exports one HLO per stage plus a monolithic HLO; the Rust
partitioner (Eq. 5 cost model) groups contiguous stages onto edge nodes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax.numpy as jnp

from . import layers as L
from .kernels import depthwise3x3, avgpool_global, same_pad


def make_divisible(v: float, divisor: int = 8) -> int:
    """Standard MobileNet channel rounding."""
    new_v = max(divisor, int(v + divisor / 2) // divisor * divisor)
    if new_v < 0.9 * v:
        new_v += divisor
    return new_v


@dataclasses.dataclass
class Stage:
    """A contiguous chunk of the model: unit of distribution across nodes.

    ``fn(weights, x)`` where ``weights`` is the per-stage list of parameter
    arrays (HLO arguments, in order) and ``x`` the activation.
    """

    name: str
    fn: Callable
    in_shape: tuple
    out_shape: tuple
    layers: List[L.LayerMeta]
    weights: List[jnp.ndarray]

    @property
    def params(self) -> int:
        return sum(m.params for m in self.layers)

    @property
    def flops(self) -> int:
        return sum(m.flops for m in self.layers)

    @property
    def cost(self) -> int:
        return sum(m.cost for m in self.layers)


@dataclasses.dataclass
class Model:
    name: str
    input_shape: tuple  # (H, W, 3)
    num_classes: int
    stages: List[Stage]

    def forward(self, x):
        """Full forward pass using the stored weights (testing convenience)."""
        for s in self.stages:
            x = s.fn(s.weights, x)
        return x

    def monolithic_fn(self):
        """``fn(all_weights, x)`` suitable for AOT lowering as one program."""
        stages = self.stages
        sizes = [len(s.weights) for s in stages]

        def fn(ws, x):
            off = 0
            for s, n in zip(stages, sizes):
                x = s.fn(ws[off : off + n], x)
                off += n
            return x

        return fn

    @property
    def all_weights(self) -> List[jnp.ndarray]:
        return [w for s in self.stages for w in s.weights]

    @property
    def params(self) -> int:
        return sum(s.params for s in self.stages)

    @property
    def flops(self) -> int:
        return sum(s.flops for s in self.stages)

    @property
    def layers(self) -> List[L.LayerMeta]:
        return [m for s in self.stages for m in s.layers]


class _Builder:
    """Tracks the running activation shape while blocks are appended.

    Ops have signature ``op(ws, x)`` where ``ws`` is the *stage-local*
    weight list; weights are referenced by index so they can be lowered as
    HLO arguments instead of baked constants.
    """

    def __init__(self, init: L.Initializer, in_shape):
        self.init = init
        self.shape = tuple(in_shape)
        self.ops: List[Callable] = []
        self.metas: List[L.LayerMeta] = []
        self.weights: List[jnp.ndarray] = []
        self._stages: List[Stage] = []
        self._stage_start_shape = self.shape
        self._n = 0

    def _name(self, base):
        self._n += 1
        return f"{base}_{self._n}"

    def _add_w(self, *arrays) -> int:
        idx = len(self.weights)
        self.weights.extend(arrays)
        return idx

    # -- primitive layers ---------------------------------------------------

    def conv(self, k, cout, stride=1, act="relu6"):
        h, w, cin = self.shape
        wgt, b = self.init.conv(k, k, cin, cout)
        i = self._add_w(wgt, b)
        ho, _, _ = same_pad(h, k, stride)
        wo, _, _ = same_pad(w, k, stride)
        out_shape = (ho, wo, cout)
        self.ops.append(lambda ws, x, i=i, stride=stride, act=act: L.conv2d(x, ws[i], ws[i + 1], stride, act))
        self.metas.append(L.conv_meta(self._name(f"conv{k}x{k}"), k, cin, cout, self.shape, out_shape))
        self.shape = out_shape

    def dw(self, stride=1, act="relu6"):
        h, w, c = self.shape
        wgt, b = self.init.dw(c)
        i = self._add_w(wgt, b)
        ho, _, _ = same_pad(h, 3, stride)
        wo, _, _ = same_pad(w, 3, stride)
        out_shape = (ho, wo, c)
        self.ops.append(lambda ws, x, i=i, stride=stride, act=act: depthwise3x3(x, ws[i], ws[i + 1], stride, act))
        self.metas.append(L.dw_meta(self._name("dw3x3"), c, self.shape, out_shape))
        self.shape = out_shape

    def gap(self):
        h, w, c = self.shape
        self.ops.append(lambda ws, x: avgpool_global(x))
        self.metas.append(L.misc_meta(self._name("gap"), "pool", 0, self.shape, (c,), flops=h * w * c))
        self.shape = (c,)

    def classifier(self, num_classes):
        (nin,) = self.shape
        wgt, b = self.init.dense(nin, num_classes)
        i = self._add_w(wgt, b)
        self.ops.append(lambda ws, x, i=i: L.dense(x, ws[i], ws[i + 1], "none"))
        self.metas.append(L.linear_meta(self._name("classifier"), nin, num_classes))
        self.shape = (num_classes,)

    # -- composite blocks ---------------------------------------------------

    def inverted_residual(self, t, cout, stride, act="relu6", start_dw=False, se_ratio=0.0):
        """MNv2 inverted residual / MNv4 UIB / EffNet MBConv (by flags)."""
        h, w, cin = self.shape
        residual = stride == 1 and cin == cout
        start = len(self.ops)

        if start_dw:  # UIB extra-DW variant (MobileNetV4)
            self.dw(stride=1, act="none")
        hidden = make_divisible(cin * t)
        if t != 1:
            self.conv(1, hidden, 1, act)
        self.dw(stride=stride, act=act)
        if se_ratio > 0.0:  # EfficientNet squeeze-excite
            c = self.shape[2]
            reduced = max(8, make_divisible(cin * se_ratio))
            w1, b1 = self.init.dense(c, reduced)
            w2, b2 = self.init.dense(reduced, c)
            i = self._add_w(w1, b1, w2, b2)
            self.ops.append(
                lambda ws, x, i=i: L.squeeze_excite(x, ws[i], ws[i + 1], ws[i + 2], ws[i + 3])
            )
            se_params = c * reduced + reduced + reduced * c + c
            self.metas.append(
                L.misc_meta(self._name("se"), "scale", se_params, self.shape, self.shape,
                            flops=2 * (c * reduced * 2) + self.shape[0] * self.shape[1] * c)
            )
        self.conv(1, cout, 1, "none")

        if residual:
            body = self.ops[start:]
            del self.ops[start:]

            def block(ws, x, body=tuple(body)):
                y = x
                for op in body:
                    y = op(ws, y)
                return x + y

            self.ops.append(block)
            hh, ww, cc = self.shape
            self.metas.append(L.misc_meta(self._name("add"), "add", 0, self.shape, self.shape, flops=hh * ww * cc))

    # -- stage management ----------------------------------------------------

    def end_stage(self, name):
        ops = list(self.ops)
        metas = list(self.metas)
        weights = list(self.weights)
        self.ops, self.metas, self.weights = [], [], []

        def stage_fn(ws, x, ops=tuple(ops)):
            for op in ops:
                x = op(ws, x)
            return x

        self._stages.append(Stage(name, stage_fn, self._stage_start_shape, self.shape, metas, weights))
        self._stage_start_shape = self.shape

    def finish(self, name, input_shape, num_classes) -> Model:
        assert not self.ops, "un-ended stage"
        return Model(name, tuple(input_shape), num_classes, self._stages)


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


def mobilenet_v2(image_size=64, width=0.5, num_classes=1000, seed=42) -> Model:
    """MobileNetV2 (Sandler et al., CVPR'18): inverted residuals, ReLU6."""
    cfg = [  # (t, c, n, s) — the paper's Table 2
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ]
    b = _Builder(L.Initializer(seed), (image_size, image_size, 3))
    b.conv(3, make_divisible(32 * width), stride=2, act="relu6")
    stage_after = {1: "stage0_stem_g1", 3: "stage1", 5: "stage2"}  # group idx -> stage cut
    for gi, (t, c, n, s) in enumerate(cfg):
        cout = make_divisible(c * width)
        for i in range(n):
            b.inverted_residual(t, cout, s if i == 0 else 1, act="relu6")
        if gi in stage_after:
            b.end_stage(stage_after[gi])
    head = max(1024, make_divisible(1280 * width))
    b.conv(1, head, 1, act="relu6")
    b.gap()
    b.classifier(num_classes)
    b.end_stage("stage3_head")
    return b.finish("mobilenet_v2", (image_size, image_size, 3), num_classes)


def mobilenet_v4(image_size=64, width=0.5, num_classes=1000, seed=43) -> Model:
    """MobileNetV4-style (Qin et al., ECCV'24): UIB blocks (extra-DW variant).

    A conv-small-like configuration; the UIB "ExtraDW" block (leading
    stride-1 depthwise before the expansion) is the architecture's signature.
    """
    cfg = [  # (t, c, n, s, extra_dw)
        (1, 32, 1, 2, False),
        (4, 48, 2, 2, True),
        (4, 64, 3, 2, True),
        (4, 96, 3, 1, False),
        (6, 128, 2, 2, True),
    ]
    b = _Builder(L.Initializer(seed), (image_size, image_size, 3))
    b.conv(3, make_divisible(32 * width), stride=2, act="relu6")
    stage_after = {0: "stage0_stem_g1", 2: "stage1", 3: "stage2"}
    for gi, (t, c, n, s, xdw) in enumerate(cfg):
        cout = make_divisible(c * width)
        for i in range(n):
            b.inverted_residual(t, cout, s if i == 0 else 1, act="relu6", start_dw=xdw)
        if gi in stage_after:
            b.end_stage(stage_after[gi])
    head = max(960, make_divisible(1280 * width))
    b.conv(1, head, 1, act="relu6")
    b.gap()
    b.classifier(num_classes)
    b.end_stage("stage3_head")
    return b.finish("mobilenet_v4", (image_size, image_size, 3), num_classes)


def efficientnet_b0(image_size=64, width=0.5, num_classes=1000, seed=44) -> Model:
    """EfficientNet-B0-style (Tan & Le, ICML'19): MBConv + squeeze-excite, SiLU."""
    cfg = [  # (t, c, n, s)
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 40, 2, 2),
        (6, 80, 3, 2),
        (6, 112, 3, 1),
        (6, 192, 4, 2),
        (6, 320, 1, 1),
    ]
    b = _Builder(L.Initializer(seed), (image_size, image_size, 3))
    b.conv(3, make_divisible(32 * width), stride=2, act="silu")
    stage_after = {1: "stage0_stem_g1", 3: "stage1", 5: "stage2"}
    for gi, (t, c, n, s) in enumerate(cfg):
        cout = make_divisible(c * width)
        for i in range(n):
            b.inverted_residual(t, cout, s if i == 0 else 1, act="silu", se_ratio=0.25)
        if gi in stage_after:
            b.end_stage(stage_after[gi])
    head = max(1024, make_divisible(1280 * width))
    b.conv(1, head, 1, act="silu")
    b.gap()
    b.classifier(num_classes)
    b.end_stage("stage3_head")
    return b.finish("efficientnet_b0", (image_size, image_size, 3), num_classes)


ZOO = {
    "mobilenet_v2": mobilenet_v2,
    "mobilenet_v4": mobilenet_v4,
    "efficientnet_b0": efficientnet_b0,
}


def build(name: str, image_size=64, width=0.5, num_classes=1000) -> Model:
    if name not in ZOO:
        raise KeyError(f"unknown model {name!r}; options: {sorted(ZOO)}")
    return ZOO[name](image_size=image_size, width=width, num_classes=num_classes)
