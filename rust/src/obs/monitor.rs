//! In-sim sliding-window monitors: early-warning rules evaluated on each
//! emitted [`TraceEvent`] over *virtual* time, inside the engine's
//! observation path.
//!
//! End-of-run reports tell you a run blew its carbon budget; a monitor
//! tells you *when* — the virtual instant the trailing-window burn rate
//! crossed the line — which is what a sustainability controller (Ecomap)
//! or an operator replaying an incident actually needs. Three rules:
//!
//! - **carbon-budget** (gCO2/s): operational carbon deposited by
//!   completions, microgrid settlement slices and idle-floor accruals
//!   over the trailing window, divided by the window length, against a
//!   [`CarbonBudget`] rate.
//! - **slo-burn** (%): per-class fraction of completions that missed
//!   their class SLO inside the window.
//! - **reject-defer** (%): fraction of scheduling verdicts inside the
//!   window that did not assign (rejects + defers).
//!
//! Rules are **edge-triggered**: a rule fires once when its value crosses
//! the threshold from below and re-arms only after the value falls back
//! to or under it — a sustained breach is one alert, not one per event.
//! Every firing becomes an [`EventKind::Alert`] in the firehose, and each
//! rule leaves a deterministic [`MonitorSummary`] (virtual-time only; no
//! wall clock) in [`super::Telemetry`] and the sim report.
//!
//! A run with no [`MonitorSet`] attached constructs nothing — the
//! zero-overhead-when-off guarantee of the observation layer holds.

use std::collections::VecDeque;

use super::{EventKind, TraceEvent};
use crate::scheduler::SchedulingDecision;

/// Carbon burn-rate budget for the `carbon-budget` rule, in grams of CO2
/// per *virtual* second across the whole fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CarbonBudget {
    pub g_per_s: f64,
}

/// Rate rules (slo-burn, reject-defer) stay silent until their window
/// holds this many samples — a 100% miss rate over two completions is
/// noise, not a burn.
pub const MIN_RATE_SAMPLES: usize = 16;

const RULE_CARBON: &str = "carbon-budget";
const RULE_SLO: &str = "slo-burn";
const RULE_REJECT: &str = "reject-defer";

/// One monitor firing, queued inside the [`MonitorSet`] until the engine
/// drains it into an [`EventKind::Alert`] event.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertFire {
    pub rule: &'static str,
    pub t_s: f64,
    pub value: f64,
    pub threshold: f64,
    pub window_s: f64,
    /// Class index for per-class rules (slo-burn), else `None`.
    pub class: Option<usize>,
}

/// Deterministic end-of-run summary of one rule: how often it fired, when
/// it first fired, and the peak value its window ever reached. Built from
/// virtual time only, so attaching monitors cannot perturb report
/// equality checks.
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorSummary {
    pub rule: String,
    pub threshold: f64,
    pub window_s: f64,
    pub alerts: u64,
    pub first_alert_s: Option<f64>,
    pub peak: f64,
}

/// One sliding window of `(t_s, value)` samples with a running sum and an
/// edge-trigger arm.
#[derive(Debug, Clone)]
struct Window {
    samples: VecDeque<(f64, f64)>,
    sum: f64,
    armed: bool,
}

impl Window {
    fn new() -> Window {
        Window { samples: VecDeque::new(), sum: 0.0, armed: true }
    }

    /// Append a sample and evict everything older than `t_s − window_s`.
    fn push(&mut self, t_s: f64, value: f64, window_s: f64) {
        self.samples.push_back((t_s, value));
        self.sum += value;
        while let Some(&(t0, v0)) = self.samples.front() {
            if t0 >= t_s - window_s {
                break;
            }
            self.samples.pop_front();
            self.sum -= v0;
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum RuleKind {
    CarbonBudget,
    SloBurn,
    RejectDefer,
}

#[derive(Debug, Clone)]
struct Rule {
    kind: RuleKind,
    threshold: f64,
    /// Per-class windows for slo-burn (grown on demand); a single window
    /// at index 0 otherwise.
    windows: Vec<Window>,
    alerts: u64,
    first_alert_s: Option<f64>,
    peak: f64,
}

impl Rule {
    fn new(kind: RuleKind, threshold: f64) -> Rule {
        Rule { kind, threshold, windows: Vec::new(), alerts: 0, first_alert_s: None, peak: 0.0 }
    }

    fn name(&self) -> &'static str {
        match self.kind {
            RuleKind::CarbonBudget => RULE_CARBON,
            RuleKind::SloBurn => RULE_SLO,
            RuleKind::RejectDefer => RULE_REJECT,
        }
    }
}

/// A set of sliding-window rules sharing one window length. Feed it every
/// emitted event via [`MonitorSet::observe`], drain firings with
/// [`MonitorSet::pop_fire`], and collect per-rule [`MonitorSummary`] rows
/// at the end with [`MonitorSet::summaries`].
#[derive(Debug, Clone)]
pub struct MonitorSet {
    window_s: f64,
    rules: Vec<Rule>,
    fired: Vec<AlertFire>,
}

impl MonitorSet {
    /// An empty set evaluating over a trailing `window_s` of virtual time.
    pub fn new(window_s: f64) -> MonitorSet {
        MonitorSet { window_s, rules: Vec::new(), fired: Vec::new() }
    }

    /// Default window: one virtual hour.
    pub const DEFAULT_WINDOW_S: f64 = 3_600.0;

    /// Add a fleet-wide carbon burn-rate rule (gCO2 per virtual second).
    pub fn carbon_budget(mut self, budget: CarbonBudget) -> MonitorSet {
        self.rules.push(Rule::new(RuleKind::CarbonBudget, budget.g_per_s));
        self
    }

    /// Add a per-class SLO-miss burn-rate rule (percent of windowed
    /// completions missing their class SLO).
    pub fn slo_burn_pct(mut self, pct: f64) -> MonitorSet {
        self.rules.push(Rule::new(RuleKind::SloBurn, pct));
        self
    }

    /// Add a reject/defer-rate rule (percent of windowed verdicts that
    /// did not assign).
    pub fn reject_defer_pct(mut self, pct: f64) -> MonitorSet {
        self.rules.push(Rule::new(RuleKind::RejectDefer, pct));
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Parse the CLI spec: a comma list of `carbon-budget=G` (gCO2/s),
    /// `slo-burn=PCT`, `reject-defer=PCT` and an optional shared
    /// `window=SECONDS` (default one hour). At least one rule is required.
    ///
    /// `carbon-budget=0.5,slo-burn=5,window=1800`
    pub fn parse(spec: &str) -> Result<MonitorSet, String> {
        let mut window_s = MonitorSet::DEFAULT_WINDOW_S;
        let mut rules: Vec<(RuleKind, f64)> = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| format!("monitor term {tok:?} is not key=value"))?;
            let v: f64 = val
                .trim()
                .parse()
                .map_err(|_| format!("monitor term {tok:?}: {val:?} is not a number"))?;
            if !v.is_finite() || v < 0.0 {
                return Err(format!("monitor term {tok:?} must be finite and >= 0"));
            }
            match key.trim() {
                "window" => {
                    if v <= 0.0 {
                        return Err("monitor window must be > 0 seconds".into());
                    }
                    window_s = v;
                }
                RULE_CARBON => rules.push((RuleKind::CarbonBudget, v)),
                RULE_SLO => rules.push((RuleKind::SloBurn, v)),
                RULE_REJECT => rules.push((RuleKind::RejectDefer, v)),
                other => {
                    return Err(format!(
                        "unknown monitor rule {other:?}; expected {RULE_CARBON}, {RULE_SLO}, \
                         {RULE_REJECT} or window"
                    ))
                }
            }
        }
        if rules.is_empty() {
            return Err(format!(
                "empty monitor spec; expected a comma list like {RULE_CARBON}=0.5,window=1800"
            ));
        }
        let mut set = MonitorSet::new(window_s);
        for (kind, threshold) in rules {
            set.rules.push(Rule::new(kind, threshold));
        }
        Ok(set)
    }

    /// Evaluate every rule against one emitted event, queueing an
    /// [`AlertFire`] per below→above threshold crossing. Alert events
    /// themselves are ignored (a monitor never feeds on its own output).
    pub fn observe(&mut self, ev: &TraceEvent<'_>) {
        match *ev {
            TraceEvent::Completion { t_s, carbon_g, class, slo_missed, .. } => {
                self.deposit_carbon(t_s, carbon_g);
                self.record_slo(t_s, class, slo_missed);
            }
            TraceEvent::MicrogridSlice { t1_s, carbon_g, .. } => {
                self.deposit_carbon(t1_s, carbon_g);
            }
            TraceEvent::IdleSlice { t1_s, carbon_g, .. } => {
                self.deposit_carbon(t1_s, carbon_g);
            }
            TraceEvent::Decision { t_s, verdict, .. } => {
                let non_assign = !matches!(verdict, SchedulingDecision::Assign(_));
                self.record_verdict(t_s, non_assign);
            }
            _ => {}
        }
    }

    /// Drain the next queued firing (FIFO), if any.
    pub fn pop_fire(&mut self) -> Option<AlertFire> {
        if self.fired.is_empty() {
            None
        } else {
            Some(self.fired.remove(0))
        }
    }

    /// Deterministic per-rule summaries, in rule-registration order.
    pub fn summaries(&self) -> Vec<MonitorSummary> {
        self.rules
            .iter()
            .map(|r| MonitorSummary {
                rule: r.name().to_string(),
                threshold: r.threshold,
                window_s: self.window_s,
                alerts: r.alerts,
                first_alert_s: r.first_alert_s,
                peak: r.peak,
            })
            .collect()
    }

    fn deposit_carbon(&mut self, t_s: f64, carbon_g: f64) {
        let window_s = self.window_s;
        for r in self.rules.iter_mut().filter(|r| r.kind == RuleKind::CarbonBudget) {
            if r.windows.is_empty() {
                r.windows.push(Window::new());
            }
            r.windows[0].push(t_s, carbon_g, window_s);
            let value = r.windows[0].sum / window_s;
            Self::trigger(&mut self.fired, r, 0, None, t_s, value, window_s);
        }
    }

    fn record_slo(&mut self, t_s: f64, class: usize, missed: bool) {
        let window_s = self.window_s;
        for r in self.rules.iter_mut().filter(|r| r.kind == RuleKind::SloBurn) {
            while r.windows.len() <= class {
                r.windows.push(Window::new());
            }
            let w = &mut r.windows[class];
            w.push(t_s, if missed { 1.0 } else { 0.0 }, window_s);
            if w.samples.len() < MIN_RATE_SAMPLES {
                continue;
            }
            let value = 100.0 * w.sum / w.samples.len() as f64;
            Self::trigger(&mut self.fired, r, class, Some(class), t_s, value, window_s);
        }
    }

    fn record_verdict(&mut self, t_s: f64, non_assign: bool) {
        let window_s = self.window_s;
        for r in self.rules.iter_mut().filter(|r| r.kind == RuleKind::RejectDefer) {
            if r.windows.is_empty() {
                r.windows.push(Window::new());
            }
            let w = &mut r.windows[0];
            w.push(t_s, if non_assign { 1.0 } else { 0.0 }, window_s);
            if w.samples.len() < MIN_RATE_SAMPLES {
                continue;
            }
            let value = 100.0 * w.sum / w.samples.len() as f64;
            Self::trigger(&mut self.fired, r, 0, None, t_s, value, window_s);
        }
    }

    /// Shared edge-trigger: fire on a below→above crossing of the rule's
    /// threshold, re-arm once the value falls back to or under it.
    fn trigger(
        fired: &mut Vec<AlertFire>,
        rule: &mut Rule,
        widx: usize,
        class: Option<usize>,
        t_s: f64,
        value: f64,
        window_s: f64,
    ) {
        rule.peak = rule.peak.max(value);
        let armed = &mut rule.windows[widx].armed;
        if value > rule.threshold {
            if *armed {
                *armed = false;
                rule.alerts += 1;
                rule.first_alert_s.get_or_insert(t_s);
                fired.push(AlertFire {
                    rule: rule.name(),
                    t_s,
                    value,
                    threshold: rule.threshold,
                    window_s,
                    class,
                });
            }
        } else {
            *armed = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn completion(t_s: f64, carbon_g: f64, class: usize, slo_missed: bool) -> TraceEvent<'static> {
        TraceEvent::Completion {
            t_s,
            arrival_s: t_s - 1.0,
            node: "edge-a",
            class,
            service_ms: 200.0,
            latency_ms: 1_000.0,
            energy_j: 10.0,
            carbon_g,
            missed: false,
            slo_missed,
        }
    }

    #[test]
    fn parse_builds_rules_and_window() {
        let m = MonitorSet::parse("carbon-budget=0.5, slo-burn=5, reject-defer=20, window=1800")
            .unwrap();
        assert_eq!(m.rules.len(), 3);
        assert_eq!(m.window_s(), 1_800.0);
        let s = m.summaries();
        assert_eq!(s[0].rule, "carbon-budget");
        assert_eq!(s[0].threshold, 0.5);
        assert_eq!(s[1].rule, "slo-burn");
        assert_eq!(s[2].rule, "reject-defer");
        assert!(MonitorSet::parse("window=600").is_err(), "window alone is not a rule");
        assert!(MonitorSet::parse("carbon-budget=x").is_err());
        assert!(MonitorSet::parse("bogus=1").is_err());
        assert!(MonitorSet::parse("").is_err());
    }

    #[test]
    fn carbon_budget_fires_once_per_sustained_breach() {
        // 10 s window, budget 1 g/s. Deposits of 6 g at 1 Hz breach at
        // the second deposit (12 g / 10 s) and stay breached — exactly
        // one alert until the stream goes quiet and the window drains.
        let mut m = MonitorSet::new(10.0).carbon_budget(CarbonBudget { g_per_s: 1.0 });
        for i in 0..8 {
            m.observe(&completion(i as f64, 6.0, 0, false));
        }
        let fire = m.pop_fire().expect("budget breach must fire");
        assert_eq!(fire.rule, "carbon-budget");
        assert_eq!(fire.t_s, 1.0);
        assert!(fire.value > 1.0);
        assert!(m.pop_fire().is_none(), "sustained breach is one alert");
        // The window drains below budget, then a fresh breach re-fires.
        m.observe(&completion(100.0, 0.0, 0, false));
        m.observe(&completion(101.0, 6.0, 0, false));
        m.observe(&completion(102.0, 6.0, 0, false));
        let again = m.pop_fire().expect("re-armed rule must fire again");
        assert_eq!(again.t_s, 102.0);
        let s = &m.summaries()[0];
        assert_eq!(s.alerts, 2);
        assert_eq!(s.first_alert_s, Some(1.0));
        assert!(s.peak > 1.0);
    }

    #[test]
    fn slo_burn_is_per_class_and_needs_min_samples() {
        let mut m = MonitorSet::new(1_000.0).slo_burn_pct(25.0);
        // Class 1 misses every completion, class 0 never: only class 1
        // fires, and only once its window holds MIN_RATE_SAMPLES.
        for i in 0..MIN_RATE_SAMPLES {
            m.observe(&completion(i as f64, 0.0, 1, true));
            m.observe(&completion(i as f64, 0.0, 0, false));
            if i + 1 < MIN_RATE_SAMPLES {
                assert!(m.pop_fire().is_none(), "fired below the sample floor at {i}");
            }
        }
        let fire = m.pop_fire().expect("class 1 burns 100%");
        assert_eq!(fire.rule, "slo-burn");
        assert_eq!(fire.class, Some(1));
        assert_eq!(fire.value, 100.0);
        assert!(m.pop_fire().is_none(), "class 0 never burns");
    }

    #[test]
    fn reject_defer_rate_counts_non_assign_verdicts() {
        use crate::scheduler::DecisionExplain;
        let explain = DecisionExplain::default();
        let mut m = MonitorSet::new(1_000.0).reject_defer_pct(50.0);
        for i in 0..(2 * MIN_RATE_SAMPLES) {
            let verdict = if i % 4 == 0 {
                SchedulingDecision::Assign(0)
            } else {
                SchedulingDecision::Defer { until_s: i as f64 + 10.0 }
            };
            m.observe(&TraceEvent::Decision {
                t_s: i as f64,
                arrival_s: i as f64,
                ctx: "arrival",
                verdict,
                node: None,
                explain: &explain,
                decide_ns: 100,
            });
        }
        let fire = m.pop_fire().expect("75% non-assign beats 50%");
        assert_eq!(fire.rule, "reject-defer");
        assert!(fire.value > 50.0, "value {}", fire.value);
        assert_eq!(fire.class, None);
    }

    #[test]
    fn alert_events_do_not_feed_monitors() {
        let mut m = MonitorSet::new(10.0).carbon_budget(CarbonBudget { g_per_s: 0.0 });
        m.observe(&TraceEvent::Alert {
            t_s: 1.0,
            rule: "carbon-budget",
            value: 9.0,
            threshold: 0.0,
            window_s: 10.0,
            class: None,
        });
        assert!(m.pop_fire().is_none());
        assert_eq!(m.summaries()[0].alerts, 0);
    }
}
