//! Sustainability-report export (paper Sec. V-B: "organizations can use
//! the framework to report carbon emissions for sustainability
//! compliance"): serialize run reports to JSON.
//!
//! Simulation reports stream through [`crate::util::json::JsonWriter`]
//! ([`write_sim_report`]): bytes go straight to the output `io::Write`
//! with no intermediate [`Json`] tree, so a 10M-request report (with its
//! per-node SoC timelines) exports to disk in constant memory. The
//! tree-building [`sim_report_to_json`] survives as a thin parse of the
//! streamed text for callers that want to inspect the document.

use std::io;

use crate::util::json::{arr, num, obj, s, Json, JsonWriter};

use super::RunReport;

/// JSON document for one run report.
pub fn report_to_json(r: &RunReport) -> Json {
    obj(vec![
        ("label", s(&r.label)),
        ("inferences", num(r.inferences as f64)),
        (
            "latency_ms",
            obj(vec![
                ("mean", num(r.latency_ms.mean)),
                ("p50", num(r.latency_ms.p50)),
                ("p95", num(r.latency_ms.p95)),
                ("ci95", num(r.latency_ms.ci95())),
            ]),
        ),
        ("throughput_rps", num(r.throughput_rps)),
        ("energy_kwh", num(r.energy_kwh)),
        ("carbon_per_inf_g", num(r.carbon_per_inf_g)),
        ("carbon_total_g", num(r.carbon_total_g)),
        ("carbon_efficiency_inf_per_g", num(r.carbon_efficiency)),
        (
            "node_usage",
            arr(r.node_usage
                .iter()
                .map(|(n, c)| obj(vec![("node", s(n)), ("tasks", num(*c as f64))]))
                .collect()),
        ),
    ])
}

/// One `(t, value)` timeline as a JSON array of pairs, keeping every
/// `stride`-th sample plus the last (so the horizon state always
/// survives downsampling). `stride == 1` keeps everything.
fn write_timeline<W: io::Write>(
    j: &mut JsonWriter<W>,
    key: &str,
    samples: &[(f64, f64)],
    stride: usize,
) -> io::Result<()> {
    let stride = stride.max(1);
    let last = samples.len().saturating_sub(1);
    j.key(key)?;
    j.begin_arr()?;
    for (i, &(t, v)) in samples.iter().enumerate() {
        if i % stride != 0 && i != last {
            continue;
        }
        j.begin_arr()?;
        j.fnum(t)?;
        j.fnum(v)?;
        j.end_arr()?;
    }
    j.end_arr()
}

/// Stream one virtual-time simulation report (the L3.5 counterpart of
/// [`report_to_json`]) as JSON straight onto `out` — same compliance
/// pipeline, fed by the fleet simulator instead of real execution, with
/// no intermediate tree. Derived rates/ratios go through
/// [`JsonWriter::fnum`]: a run where nothing completed serializes them as
/// `0`/`null`, never as bare `NaN` (which is not JSON).
/// `timeline_stride` downsamples the per-node SoC timelines/projections
/// (keep every Nth sample plus the last); pass `1` for the full series.
pub fn write_sim_report<W: io::Write>(
    out: &mut W,
    r: &crate::sim::SimReport,
    timeline_stride: usize,
) -> io::Result<()> {
    let j = &mut JsonWriter::new(&mut *out);
    j.begin_obj()?;
    j.field_str("scenario", &r.scenario)?;
    j.field_str("scheduler", &r.scheduler)?;
    j.field_num("seed", r.seed as f64)?;
    j.field_num("requests", r.requests as f64)?;
    j.field_num("completed", r.completed as f64)?;
    j.field_num("rejected", r.rejected as f64)?;
    j.field_num("migrated", r.migrated as f64)?;
    j.field_num("deferred", r.deferred as f64)?;
    j.field_num("deadline_missed", r.deadline_missed as f64)?;
    j.field_fnum("makespan_s", r.makespan_s)?;
    j.field_fnum("throughput_rps", r.throughput_rps)?;
    j.key("latency_ms")?;
    j.begin_obj()?;
    j.field_fnum("mean", r.latency_ms.mean)?;
    j.field_fnum("p50", r.latency_ms.p50)?;
    j.field_fnum("p95", r.latency_ms.p95)?;
    j.field_fnum("p99", r.latency_ms.p99)?;
    j.field_fnum("max", r.latency_ms.max)?;
    j.end_obj()?;
    j.field_fnum("wait_ms_mean", r.wait_ms.mean)?;
    j.field_fnum("wait_ms_p99", r.wait_ms.p99)?;
    j.field_fnum("energy_kwh", r.energy_kwh_total)?;
    j.field_fnum("energy_dynamic_kwh", r.energy_dynamic_kwh_total)?;
    j.field_fnum("energy_idle_kwh", r.energy_idle_kwh_total)?;
    j.field_fnum("energy_pv_kwh", r.energy_pv_kwh_total)?;
    j.field_fnum("energy_battery_kwh", r.energy_battery_kwh_total)?;
    j.field_fnum("energy_grid_kwh", r.energy_grid_kwh_total)?;
    j.field_fnum("energy_grid_charge_kwh", r.energy_grid_charge_kwh_total)?;
    j.field_fnum("carbon_charged_g", r.carbon_charged_g_total)?;
    j.field_fnum("carbon_battery_g", r.carbon_battery_g_total)?;
    j.field_fnum("carbon_stored_g", r.carbon_stored_g_total)?;
    j.field_fnum("carbon_total_g", r.carbon_g_total)?;
    j.field_fnum("carbon_dynamic_g", r.carbon_dynamic_g_total)?;
    j.field_fnum("carbon_idle_g", r.carbon_idle_g_total)?;
    j.field_fnum("carbon_per_req_g", r.carbon_per_req_g)?;
    // Router + per-site rows (multi-site runs only; absent otherwise so
    // legacy flat-fleet documents are byte-identical).
    if !r.sites.is_empty() {
        j.field_str("router", &r.router)?;
        j.field_num("wan_shipped", r.wan_shipped as f64)?;
        j.field_fnum("energy_wan_kwh", r.energy_wan_kwh_total)?;
        j.field_fnum("carbon_wan_g", r.carbon_wan_g_total)?;
        j.key("sites")?;
        j.begin_arr()?;
        for s in &r.sites {
            j.begin_obj()?;
            j.field_str("site", &s.name)?;
            j.field_num("nodes", s.nodes as f64)?;
            j.field_num("completed", s.completed as f64)?;
            j.field_num("shipped_out", s.shipped_out as f64)?;
            j.field_num("shipped_in", s.shipped_in as f64)?;
            j.field_fnum("energy_kwh", s.energy_kwh)?;
            j.field_fnum("energy_wan_kwh", s.energy_wan_kwh)?;
            j.field_fnum("carbon_g", s.carbon_g)?;
            j.field_fnum("carbon_wan_g", s.carbon_wan_g)?;
            j.field_fnum("carbon_per_req_g", s.carbon_per_req_g)?;
            j.end_obj()?;
        }
        j.end_arr()?;
    }
    // Per-workload-class rows (multi-tenant runs only; empty otherwise).
    if !r.classes.is_empty() {
        j.key("classes")?;
        j.begin_arr()?;
        for c in &r.classes {
            j.begin_obj()?;
            j.field_str("class", &c.name)?;
            j.field_num("completed", c.completed as f64)?;
            j.field_num("rejected", c.rejected as f64)?;
            j.field_fnum("slo_s", c.slo_s)?;
            j.field_num("slo_missed", c.slo_missed as f64)?;
            j.field_num("batches", c.batches as f64)?;
            j.field_fnum("mean_fill", c.mean_fill())?;
            j.field_fnum("latency_ms_p50", c.latency_ms.p50)?;
            j.field_fnum("latency_ms_p99", c.latency_ms.p99)?;
            j.field_fnum("energy_dynamic_kwh", c.energy_dynamic_kwh)?;
            j.field_fnum("carbon_dynamic_g", c.carbon_dynamic_g)?;
            j.field_fnum("carbon_per_req_g", c.carbon_per_req_g)?;
            j.end_obj()?;
        }
        j.end_arr()?;
    }
    // Per-rule monitor summaries (monitored runs only; empty otherwise).
    if !r.monitors.is_empty() {
        j.key("monitors")?;
        j.begin_arr()?;
        for m in &r.monitors {
            j.begin_obj()?;
            j.field_str("rule", &m.rule)?;
            j.field_fnum("threshold", m.threshold)?;
            j.field_num("window_s", m.window_s)?;
            j.field_num("alerts", m.alerts as f64)?;
            match m.first_alert_s {
                Some(t) => j.field_num("first_alert_s", t)?,
                None => {
                    j.key("first_alert_s")?;
                    j.null()?;
                }
            }
            j.field_fnum("peak", m.peak)?;
            j.end_obj()?;
        }
        j.end_arr()?;
    }
    j.key("nodes")?;
    j.begin_arr()?;
    for n in &r.nodes {
        j.begin_obj()?;
        j.field_str("node", &n.name)?;
        j.field_num("tasks", n.tasks as f64)?;
        j.field_fnum("busy_ms", n.busy_ms)?;
        j.field_fnum("uptime_s", n.uptime_s)?;
        j.field_fnum("queue_delay_ms_p50", n.queue_delay_ms_p50)?;
        j.field_fnum("queue_delay_ms_p99", n.queue_delay_ms_p99)?;
        j.field_fnum("queue_delay_ms_max", n.queue_delay_ms_max)?;
        j.field_fnum("energy_kwh", n.energy_kwh())?;
        j.field_fnum("energy_dynamic_kwh", n.energy_dynamic_kwh)?;
        j.field_fnum("energy_idle_kwh", n.energy_idle_kwh)?;
        j.field_fnum("carbon_g", n.carbon_g())?;
        j.field_fnum("carbon_dynamic_g", n.carbon_dynamic_g)?;
        j.field_fnum("carbon_idle_g", n.carbon_idle_g)?;
        j.field_bool("microgrid", n.microgrid)?;
        j.field_fnum("energy_pv_kwh", n.energy_pv_kwh)?;
        j.field_fnum("energy_battery_kwh", n.energy_battery_kwh)?;
        j.field_fnum("energy_grid_kwh", n.energy_grid_kwh)?;
        j.field_fnum("energy_grid_charge_kwh", n.energy_grid_charge_kwh)?;
        j.field_fnum("carbon_charged_g", n.carbon_charged_g)?;
        j.field_fnum("carbon_battery_g", n.carbon_battery_g)?;
        j.field_fnum("carbon_stored_g", n.carbon_stored_g)?;
        write_timeline(j, "soc_timeline", &n.soc_timeline, timeline_stride)?;
        write_timeline(j, "soc_projection", &n.soc_projection, timeline_stride)?;
        j.end_obj()?;
    }
    j.end_arr()?;
    j.end_obj()
}

/// [`write_sim_report`] into a `String` (full timelines, stride 1).
pub fn sim_report_json_string(r: &crate::sim::SimReport) -> String {
    sim_report_json_string_strided(r, 1)
}

/// [`write_sim_report`] into a `String` with a timeline stride.
pub fn sim_report_json_string_strided(r: &crate::sim::SimReport, stride: usize) -> String {
    let mut buf = Vec::new();
    // lint: allow(P1 io::Write on Vec<u8> is infallible)
    write_sim_report(&mut buf, r, stride).expect("write to Vec<u8> cannot fail");
    // lint: allow(P1 JsonWriter escapes everything it emits to ASCII)
    String::from_utf8(buf).expect("JsonWriter emits UTF-8")
}

/// The simulation report as a parsed [`Json`] tree — a thin parse of the
/// streamed [`write_sim_report`] text, for callers that want to inspect
/// or embed the document rather than write it out.
pub fn sim_report_to_json(r: &crate::sim::SimReport) -> Json {
    // lint: allow(P1 round-trips text this module's own writer just produced)
    Json::parse(&sim_report_json_string(r)).expect("streamed report is valid JSON")
}

/// A compliance document over several runs (e.g. one per mode).
pub fn compliance_document(title: &str, reports: &[RunReport]) -> Json {
    obj(vec![
        ("title", s(title)),
        ("framework", s("CarbonEdge")),
        ("runs", arr(reports.iter().map(report_to_json).collect())),
        (
            "total_carbon_g",
            num(reports.iter().map(|r| r.carbon_total_g).sum()),
        ),
        (
            "total_inferences",
            num(reports.iter().map(|r| r.inferences).sum::<u64>() as f64),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::ExecutionRecord;
    use crate::runtime::Tensor;

    fn report() -> RunReport {
        let recs: Vec<ExecutionRecord> = (0..3)
            .map(|_| ExecutionRecord {
                node: "node-green".into(),
                exec_ms: 9.0,
                latency_ms: 200.0,
                energy_j: 30.0,
                carbon_g: 0.003,
                output: Tensor::zeros(vec![1]),
            })
            .collect();
        RunReport::from_records("test", &recs).unwrap()
    }

    #[test]
    fn roundtrips_through_parser() {
        let j = report_to_json(&report());
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_str("label").unwrap(), "test");
        assert_eq!(back.req_usize("inferences").unwrap(), 3);
        assert!((back.req_f64("carbon_per_inf_g").unwrap() - 0.003).abs() < 1e-12);
        assert_eq!(back.path(&["latency_ms"]).unwrap().req_f64("mean").unwrap(), 200.0);
    }

    #[test]
    fn sim_report_roundtrips_through_parser() {
        let sc = crate::sim::scenarios::build("paper-3-node", 0, 20, 1).unwrap();
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let back = Json::parse(&sim_report_to_json(&r).to_string()).unwrap();
        assert_eq!(back.req_str("scenario").unwrap(), "paper-3-node");
        assert_eq!(back.req_str("scheduler").unwrap(), "green");
        assert_eq!(back.req_usize("requests").unwrap(), 20);
        assert_eq!(back.req_arr("nodes").unwrap().len(), 3);
        assert!(back.req_f64("carbon_total_g").unwrap() > 0.0);
        // Two-part energy split + deferral counters survive the roundtrip.
        assert_eq!(back.req_usize("deferred").unwrap(), 0);
        assert_eq!(back.req_usize("deadline_missed").unwrap(), 0);
        assert_eq!(back.req_f64("energy_idle_kwh").unwrap(), 0.0); // paper nodes: no floor
        let total = back.req_f64("energy_kwh").unwrap();
        let dynamic = back.req_f64("energy_dynamic_kwh").unwrap();
        assert!((total - dynamic).abs() < 1e-15);
        let node0 = &back.req_arr("nodes").unwrap()[0];
        assert!(node0.req_f64("uptime_s").unwrap() > 0.0);
        assert!(node0.req_f64("carbon_idle_g").unwrap() == 0.0);
        // Queue-delay estimates ride along per node.
        assert!(node0.req_f64("queue_delay_ms_p50").unwrap() >= 0.0);
        assert!(
            node0.req_f64("queue_delay_ms_max").unwrap()
                >= node0.req_f64("queue_delay_ms_p50").unwrap()
        );
    }

    #[test]
    fn sim_report_json_carries_idle_split() {
        let sc = crate::sim::scenarios::build("consolidation", 3, 50, 2).unwrap();
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let back = Json::parse(&sim_report_to_json(&r).to_string()).unwrap();
        let idle = back.req_f64("energy_idle_kwh").unwrap();
        let dynamic = back.req_f64("energy_dynamic_kwh").unwrap();
        let total = back.req_f64("energy_kwh").unwrap();
        assert!(idle > 0.0, "consolidation nodes carry an idle floor");
        assert!((idle + dynamic - total).abs() <= 1e-12 * total);
        assert!(back.req_f64("carbon_idle_g").unwrap() > 0.0);
    }

    #[test]
    fn sim_report_json_carries_microgrid_supply_split() {
        let sc = crate::sim::scenarios::build("solar-battery", 2, 60, 3).unwrap();
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let text = sim_report_to_json(&r).to_string();
        let back = Json::parse(&text).unwrap();
        let pv = back.req_f64("energy_pv_kwh").unwrap();
        let batt = back.req_f64("energy_battery_kwh").unwrap();
        let grid = back.req_f64("energy_grid_kwh").unwrap();
        let total = back.req_f64("energy_kwh").unwrap();
        assert!(pv > 0.0, "a day of solar-battery must use PV");
        assert!((pv + batt + grid - total).abs() <= 1e-9 * total);
        let node0 = &back.req_arr("nodes").unwrap()[0];
        assert_eq!(node0.get("microgrid").unwrap().as_bool(), Some(true));
        let soc = node0.req_arr("soc_timeline").unwrap();
        assert!(soc.len() >= 2, "SoC timeline missing");
        for sample in soc {
            let pair = sample.as_arr().unwrap();
            let frac = pair[1].as_f64().unwrap();
            assert!((0.0..=1.0 + 1e-9).contains(&frac), "SoC {frac} out of range");
        }
    }

    #[test]
    fn sim_report_json_carries_stored_carbon_ledger() {
        // The arbitrage scenario grid-charges overnight: the export must
        // carry the charge-source split and a balanced stored ledger.
        let sc = crate::sim::scenarios::build("arbitrage", 2, 600, 3).unwrap();
        let mut sched = crate::scheduler::DeferAwareGreenScheduler::new(0.05);
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let back = Json::parse(&sim_report_to_json(&r).to_string()).unwrap();
        let charged = back.req_f64("carbon_charged_g").unwrap();
        let spent = back.req_f64("carbon_battery_g").unwrap();
        let stored = back.req_f64("carbon_stored_g").unwrap();
        assert!(back.req_f64("energy_grid_charge_kwh").unwrap() > 0.0);
        assert!(charged > 0.0, "overnight window must import");
        assert!(
            (charged - spent - stored).abs() <= 1e-6 * charged,
            "ledger unbalanced: {charged} vs {spent} + {stored}"
        );
        let node0 = &back.req_arr("nodes").unwrap()[0];
        assert!(node0.req_f64("carbon_charged_g").unwrap() >= 0.0);
        // Projected-vs-actual SoC rides along (trajectory forecasts on).
        assert!(!node0.req_arr("soc_projection").unwrap().is_empty());
        assert!(!node0.req_arr("soc_timeline").unwrap().is_empty());
    }

    #[test]
    fn sim_report_json_zero_completions_never_emits_nan() {
        // A demand no node can fit: every request is rejected, all the
        // derived rates hit their zero-completion guards, and the export
        // stays valid JSON (0/null, never NaN).
        let mut sc = crate::sim::scenarios::build("paper-3-node", 0, 50, 1).unwrap();
        sc.config.demand = crate::scheduler::TaskDemand {
            cpu: 64.0,
            mem_mb: 1 << 20,
            latency_threshold_ms: 5_000.0,
            class: 0,
        };
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        assert_eq!(r.completed, 0);
        assert_eq!(r.rejected, 50);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.carbon_per_req_g, 0.0);
        let text = sim_report_to_json(&r).to_string();
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_usize("completed").unwrap(), 0);
        assert_eq!(back.req_f64("carbon_per_req_g").unwrap(), 0.0);
    }

    #[test]
    fn timeline_stride_keeps_first_and_last() {
        let samples: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, i as f64 / 10.0)).collect();
        let mut buf = Vec::new();
        {
            let j = &mut JsonWriter::new(&mut buf);
            j.begin_obj().unwrap();
            write_timeline(j, "tl", &samples, 4).unwrap();
            j.end_obj().unwrap();
        }
        let v = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
        let tl = v.req_arr("tl").unwrap();
        // Indices 0, 4, 8 plus the final sample (9).
        assert_eq!(tl.len(), 4);
        assert_eq!(tl[0].as_arr().unwrap()[0].as_f64(), Some(0.0));
        assert_eq!(tl[3].as_arr().unwrap()[0].as_f64(), Some(9.0));
        // Stride 1 (and 0, clamped) keeps everything.
        for stride in [0, 1] {
            let mut buf = Vec::new();
            let j = &mut JsonWriter::new(&mut buf);
            j.begin_obj().unwrap();
            write_timeline(j, "tl", &samples, stride).unwrap();
            j.end_obj().unwrap();
            let v = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
            assert_eq!(v.req_arr("tl").unwrap().len(), samples.len());
        }
    }

    #[test]
    fn streamed_sim_report_carries_tail_percentiles_and_strides() {
        let sc = crate::sim::scenarios::build("solar-battery", 2, 60, 3).unwrap();
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let back = Json::parse(&sim_report_json_string(&r)).unwrap();
        // Tail percentiles ride along in the streamed document.
        let p50 = back.path(&["latency_ms", "p50"]).unwrap().as_f64().unwrap();
        let p99 = back.path(&["latency_ms", "p99"]).unwrap().as_f64().unwrap();
        let max = back.path(&["latency_ms", "max"]).unwrap().as_f64().unwrap();
        assert!(p50 <= p99 && p99 <= max, "{p50} / {p99} / {max}");
        assert!(back.req_f64("wait_ms_p99").unwrap() >= 0.0);
        let node0 = &back.req_arr("nodes").unwrap()[0];
        assert!(
            node0.req_f64("queue_delay_ms_p99").unwrap()
                <= node0.req_f64("queue_delay_ms_max").unwrap() + 1e-12
        );
        // Downsampled timelines keep both endpoints.
        let orig = node0.req_arr("soc_timeline").unwrap();
        let strided = Json::parse(&sim_report_json_string_strided(&r, 10)).unwrap();
        let tl = strided.req_arr("nodes").unwrap()[0].req_arr("soc_timeline").unwrap();
        assert!(tl.len() <= orig.len());
        assert_eq!(tl.first(), orig.first());
        assert_eq!(tl.last(), orig.last());
    }

    #[test]
    fn sim_report_json_carries_monitor_summaries() {
        let sc = crate::sim::scenarios::build("paper-3-node", 0, 20, 1).unwrap();
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let mut r = crate::sim::Simulation::run(&sc, &mut sched);
        assert!(
            !sim_report_json_string(&r).contains("\"monitors\""),
            "no monitors attached, no key"
        );
        r.monitors.push(crate::obs::MonitorSummary {
            rule: "carbon-budget".into(),
            threshold: 1e-3,
            window_s: 600.0,
            alerts: 4,
            first_alert_s: None,
            peak: 2e-3,
        });
        let back = Json::parse(&sim_report_json_string(&r)).unwrap();
        let ms = back.req_arr("monitors").unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].req_str("rule").unwrap(), "carbon-budget");
        assert_eq!(ms[0].req_usize("alerts").unwrap(), 4);
        assert_eq!(ms[0].get("first_alert_s"), Some(&Json::Null));
    }

    #[test]
    fn sim_report_json_carries_site_rows() {
        // Flat fleets carry no site keys; a multi-site run exports the
        // router, WAN totals and a partitioning per-site array.
        let flat = crate::sim::scenarios::build("paper-3-node", 0, 20, 1).unwrap();
        let mut sched = crate::scheduler::DeferAwareGreenScheduler::new(0.05);
        let r = crate::sim::Simulation::run(&flat, &mut sched);
        let text = sim_report_json_string(&r);
        assert!(!text.contains("\"sites\""), "no site layer, no key");
        assert!(!text.contains("\"router\""), "no site layer, no router");
        let sc = crate::sim::scenarios::build("multi-site", 0, 400, 7).unwrap();
        let mut sched = crate::scheduler::DeferAwareGreenScheduler::new(0.05);
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let back = Json::parse(&sim_report_json_string(&r)).unwrap();
        assert_eq!(back.req_str("router").unwrap(), "deadline");
        let sites = back.req_arr("sites").unwrap();
        assert_eq!(sites.len(), 3);
        let done: f64 = sites.iter().map(|s| s.req_f64("completed").unwrap()).sum();
        assert_eq!(done as u64, r.completed);
        let energy: f64 = sites
            .iter()
            .map(|s| s.req_f64("energy_kwh").unwrap() + s.req_f64("energy_wan_kwh").unwrap())
            .sum();
        let total = back.req_f64("energy_kwh").unwrap();
        assert!((energy - total).abs() <= 1e-6 * total.max(1e-12), "{energy} vs {total}");
    }

    #[test]
    fn compliance_totals() {
        let doc = compliance_document("Q3", &[report(), report()]);
        assert_eq!(doc.req_usize("total_inferences").unwrap(), 6);
        assert!((doc.req_f64("total_carbon_g").unwrap() - 0.018).abs() < 1e-12);
        assert_eq!(doc.req_arr("runs").unwrap().len(), 2);
    }
}
