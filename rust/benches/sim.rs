//! Simulator throughput bench: how many virtual requests per wall-clock
//! second the discrete-event engine sustains. Target (ISSUE 1 / ROADMAP
//! L3.5): ≥ 1M simulated requests/s on the paper-3-node scenario.
//!
//! Needs no artifacts — run with `cargo bench --bench sim`.

use std::time::Instant;

use carbonedge::node::EdgeNode;
use carbonedge::scheduler::{CarbonAwareScheduler, DeferAwareGreenScheduler, FleetView, Mode};
use carbonedge::sim::{scenarios, Simulation};

fn throughput(name: &str, nodes: usize, requests: usize, runs: usize) -> f64 {
    let sc = scenarios::build(name, nodes, requests, 42).expect("known scenario");
    let mut best = f64::MAX;
    for _ in 0..runs {
        let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
        let t0 = Instant::now();
        let r = Simulation::run(&sc, &mut sched);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(r.completed + r.rejected, requests as u64);
        best = best.min(dt);
    }
    requests as f64 / best
}

fn main() {
    println!("simulator throughput (best of 3, CE-Green)");
    let rps = throughput("paper-3-node", 0, 1_000_000, 3);
    let verdict = if rps >= 1e6 { "meets the 1M target" } else { "BELOW the 1M target" };
    println!("  paper-3-node     1M requests   {:>8.2}M sim-req/s  ({verdict})", rps / 1e6);

    let rps = throughput("fleet-100", 100, 200_000, 3);
    println!("  fleet-100      200k requests   {:>8.2}M sim-req/s", rps / 1e6);

    let rps = throughput("bursty", 0, 500_000, 3);
    println!("  bursty         500k requests   {:>8.2}M sim-req/s", rps / 1e6);

    let rps = throughput("churn", 0, 200_000, 3);
    println!("  churn          200k requests   {:>8.2}M sim-req/s", rps / 1e6);

    // Deferral + CSV-trace lookups on the hot path (every arrival consults
    // the forecast, every parked task re-enters the heap).
    let rps = throughput("real-trace", 0, 200_000, 3);
    println!("  real-trace     200k requests   {:>8.2}M sim-req/s  (deferral on)", rps / 1e6);

    // Idle-floor accrual + piecewise intensity integration at report time.
    let rps = throughput("consolidation", 0, 200_000, 3);
    println!("  consolidation  200k requests   {:>8.2}M sim-req/s  (idle floors)", rps / 1e6);

    // Microgrid settlement on the hot path: every draw change covers a
    // slice PV-first/battery/grid, every refresh re-blends the effective
    // intensity and samples the SoC timeline.
    let rps = throughput("solar-battery", 0, 200_000, 3);
    println!("  solar-battery  200k requests   {:>8.2}M sim-req/s  (pv+battery)", rps / 1e6);

    let rps = throughput("microgrid-fleet", 0, 200_000, 3);
    println!("  microgrid-flt  200k requests   {:>8.2}M sim-req/s  (mixed supply)", rps / 1e6);

    // Grid-charge arbitrage + SoC-trajectory forecasts: every settlement
    // slice consults the charge threshold, every slack-carrying arrival
    // rolls a per-node SoC projection over its defer window. Smaller
    // request count: the scenario's pinned arrival rate means requests
    // buy virtual days, not density.
    let rps = throughput("arbitrage", 0, 50_000, 3);
    println!("  arbitrage       50k requests   {:>8.2}M sim-req/s  (SoC projection)", rps / 1e6);

    // Joint defer+route: per-arrival fleet-wide forecasts plus the plateau
    // spread in DeferAwareGreenScheduler (the route-then-defer gate path is
    // covered by real-trace above).
    let sc = scenarios::build("deferral-routing", 0, 200_000, 42).unwrap();
    let mut best = f64::MAX;
    for _ in 0..3 {
        let mut sched = DeferAwareGreenScheduler::new(0.05);
        let t0 = Instant::now();
        let r = Simulation::run(&sc, &mut sched);
        assert_eq!(r.completed + r.rejected, 200_000);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    println!(
        "  defer-routing  200k requests   {:>8.2}M sim-req/s  (joint defer+route)",
        200_000.0 / best / 1e6
    );

    // FleetView snapshot cost: the fixed per-arrival price of the decide
    // API. The paper budgets 0.03 ms/task of scheduling overhead
    // (Sec. IV-F); the snapshot must stay a small fraction of it.
    for (label, n) in [("3-node", 3usize), ("100-node", 100)] {
        let specs: Vec<_> = (0..n)
            .map(|i| {
                let mut spec = carbonedge::node::NodeSpec::paper_nodes()[i % 3].clone();
                spec.name = format!("n{i}");
                spec
            })
            .collect();
        let nodes: Vec<_> = specs.into_iter().map(EdgeNode::new).collect();
        let iters = 200_000usize;
        let t0 = Instant::now();
        let mut sink = 0usize;
        for _ in 0..iters {
            sink += FleetView::observe(&nodes).nodes.len();
        }
        let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        assert_eq!(sink, n * iters);
        let verdict = if ns < 30_000.0 {
            "within the 0.03 ms/task envelope"
        } else {
            "OVER the 0.03 ms/task envelope"
        };
        println!("  FleetView::observe {label:>9}   {ns:>8.0} ns/snapshot  ({verdict})");
    }
}
