//! Carbon budgets — the paper's "multi-tenant optimization with carbon
//! budgets" future-work item (Sec. V-A): per-tenant emission allowances
//! with admission control and periodic refill.

use std::collections::BTreeMap;

/// Admission decision for a task under a budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Enough budget: run now.
    Admit,
    /// Budget exhausted but the task may run later (deferral).
    Defer,
    /// Task alone exceeds the whole period budget: reject outright.
    Reject,
}

/// A per-tenant carbon allowance over a refill period.
#[derive(Debug, Clone)]
pub struct CarbonBudget {
    /// Grams of CO₂ allowed per period.
    pub per_period_g: f64,
    /// Remaining grams in the current period.
    remaining_g: f64,
    /// Period length (seconds).
    pub period_s: f64,
    /// Start of the current period (experiment clock, seconds).
    period_start: f64,
}

impl CarbonBudget {
    pub fn new(per_period_g: f64, period_s: f64) -> CarbonBudget {
        // lint: allow(P2 one-shot constructor guard)
        assert!(per_period_g > 0.0 && period_s > 0.0);
        CarbonBudget { per_period_g, remaining_g: per_period_g, period_s, period_start: 0.0 }
    }

    pub fn remaining_g(&self) -> f64 {
        self.remaining_g
    }

    /// Advance the experiment clock, refilling at period boundaries.
    pub fn tick(&mut self, now_s: f64) {
        while now_s - self.period_start >= self.period_s {
            self.period_start += self.period_s;
            self.remaining_g = self.per_period_g;
        }
    }

    /// Admission control for a task expected to emit `est_g`.
    pub fn admit(&self, est_g: f64) -> Admission {
        debug_assert!(est_g >= 0.0);
        if est_g > self.per_period_g {
            Admission::Reject
        } else if est_g > self.remaining_g {
            Admission::Defer
        } else {
            Admission::Admit
        }
    }

    /// Charge actual emissions after execution (may overdraw slightly when
    /// the estimate was low; the debt carries into the period).
    pub fn charge(&mut self, actual_g: f64) {
        debug_assert!(actual_g >= 0.0);
        self.remaining_g -= actual_g;
    }
}

/// Multi-tenant budget book.
#[derive(Debug, Default)]
pub struct BudgetBook {
    tenants: BTreeMap<String, CarbonBudget>,
}

impl BudgetBook {
    pub fn register(&mut self, tenant: &str, budget: CarbonBudget) {
        self.tenants.insert(tenant.to_string(), budget);
    }

    pub fn get(&self, tenant: &str) -> Option<&CarbonBudget> {
        self.tenants.get(tenant)
    }

    pub fn tick_all(&mut self, now_s: f64) {
        for b in self.tenants.values_mut() {
            b.tick(now_s);
        }
    }

    /// Admission for a tenant's task; unknown tenants are admitted
    /// (no budget configured).
    pub fn admit(&self, tenant: &str, est_g: f64) -> Admission {
        self.tenants.get(tenant).map(|b| b.admit(est_g)).unwrap_or(Admission::Admit)
    }

    pub fn charge(&mut self, tenant: &str, actual_g: f64) {
        if let Some(b) = self.tenants.get_mut(tenant) {
            b.charge(actual_g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_defer_reject() {
        let b = CarbonBudget::new(1.0, 60.0);
        assert_eq!(b.admit(0.4), Admission::Admit);
        assert_eq!(b.admit(1.5), Admission::Reject);
        let mut b = b;
        b.charge(0.9);
        assert_eq!(b.admit(0.4), Admission::Defer); // remaining 0.1 < 0.4
        assert_eq!(b.admit(0.05), Admission::Admit);
    }

    #[test]
    fn refill_at_period_boundary() {
        let mut b = CarbonBudget::new(1.0, 60.0);
        b.charge(1.0);
        assert!(b.remaining_g() <= 0.0 + 1e-12);
        b.tick(59.9);
        assert!(b.remaining_g() <= 0.0 + 1e-12); // not yet
        b.tick(60.0);
        assert_eq!(b.remaining_g(), 1.0);
        // multiple periods elapse at once
        b.charge(1.0);
        b.tick(400.0);
        assert_eq!(b.remaining_g(), 1.0);
    }

    #[test]
    fn overdraw_carries_debt() {
        let mut b = CarbonBudget::new(1.0, 60.0);
        b.charge(1.3); // actual exceeded estimate
        assert!((b.remaining_g() + 0.3).abs() < 1e-12);
        assert_eq!(b.admit(0.1), Admission::Defer);
    }

    #[test]
    fn multi_tenant_isolation() {
        let mut book = BudgetBook::default();
        book.register("team-a", CarbonBudget::new(0.5, 60.0));
        book.register("team-b", CarbonBudget::new(2.0, 60.0));
        book.charge("team-a", 0.5);
        assert_eq!(book.admit("team-a", 0.1), Admission::Defer);
        assert_eq!(book.admit("team-b", 0.1), Admission::Admit);
        // unknown tenant: no budget -> admitted
        assert_eq!(book.admit("team-c", 99.0), Admission::Admit);
        book.tick_all(61.0);
        assert_eq!(book.admit("team-a", 0.1), Admission::Admit);
    }
}
