//! The scheduling verdict and the fleet snapshot it is decided over.
//!
//! [`SchedulingDecision`] widens the old "which node" answer into a joint
//! *where-or-when* verdict: assign to a node, defer to a cleaner forecast
//! slot, or reject. [`FleetView`] is the per-arrival immutable snapshot a
//! [`super::Scheduler`] decides against: one [`NodeView`] per candidate
//! node carrying the Algorithm-1 score inputs (a [`NodeState`] snapshot),
//! a queue-delay estimate, the *blended* effective carbon intensity
//! (microgrid-aware, via `EdgeNode::intensity_override`), and — when the
//! task carries deadline slack — a short forecast of that effective
//! intensity out to the latest viable release slot. Decisions therefore
//! see load, time and supply in one place instead of re-reading live node
//! state mid-decision.

use std::sync::Arc;

use crate::node::{EdgeNode, NodeState};

use super::{TaskDemand, LOAD_CUTOFF};

/// Why a task could not be placed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// No node passes the Algorithm-1 feasibility filters (load cutoff,
    /// latency threshold, resource fit) — line 18's `n* = null`.
    NoFeasibleNode,
    /// Shed by admission control before the scheduler ran: sustained
    /// overload pushed queue pressure past the class's priority-scaled
    /// tolerance ([`crate::sim::AdmissionSpec`]).
    Overload,
}

/// One scheduling verdict: *where* to run, *when* to run, or neither.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedulingDecision {
    /// Run now on `FleetView::nodes[i]`.
    Assign(usize),
    /// Park the task and re-decide at `until_s` (virtual/experiment clock;
    /// must be strictly after the view's `now_s` and inside the task's
    /// deadline). Only meaningful when the view carried forecast context —
    /// the engine treats an unhonourable defer as a rejection.
    Defer { until_s: f64 },
    /// No placement exists.
    Reject { reason: RejectReason },
}

impl SchedulingDecision {
    /// The standard rejection.
    pub fn reject() -> SchedulingDecision {
        SchedulingDecision::Reject { reason: RejectReason::NoFeasibleNode }
    }

    /// Lift the legacy `Option<usize>` selection into a verdict.
    pub fn from_choice(choice: Option<usize>) -> SchedulingDecision {
        match choice {
            Some(i) => SchedulingDecision::Assign(i),
            None => SchedulingDecision::reject(),
        }
    }

    /// The assigned node index, if this verdict places the task now.
    pub fn assigned(&self) -> Option<usize> {
        match self {
            SchedulingDecision::Assign(i) => Some(*i),
            _ => None,
        }
    }
}

/// Per-candidate detail recorded by [`super::Scheduler::decide_explained`]:
/// the score inputs one node contributed to a verdict. Feeds the decision
/// lines of the observability firehose ([`crate::obs`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CandidateExplain {
    /// Node name (`EdgeNode::spec.name`).
    pub node: String,
    /// Passed the Algorithm-1 feasibility filters (load cutoff, latency
    /// threshold, resource fit).
    pub feasible: bool,
    /// Routing score when the scheduler scored this node (the Eq. 3
    /// weighted total for Algorithm-1 policies — higher wins); `None` for
    /// filtered-out candidates and unscored policies.
    pub score: Option<f64>,
    /// Effective carbon intensity at decision time (gCO₂/kWh).
    pub intensity: f64,
    /// Queue-delay estimate at decision time (ms).
    pub queue_delay_ms: f64,
    /// Best forecast release slot a defer-aware policy considered for this
    /// node, with the intensity it would pay there.
    pub best_slot: Option<(f64, f64)>,
}

impl CandidateExplain {
    /// Baseline detail straight off a [`NodeView`] (no score, no slot).
    pub fn from_view(v: &NodeView, task: &TaskDemand) -> CandidateExplain {
        CandidateExplain {
            node: v.node.spec.name.clone(),
            feasible: v.feasible(task),
            score: None,
            intensity: v.intensity,
            queue_delay_ms: v.queue_delay_s * 1e3,
            best_slot: None,
        }
    }
}

/// Why a verdict came out the way it did: per-candidate scores plus a free
/// note from the deciding policy. Filled by `decide_explained` only when a
/// trace sink asked for decision events — the plain `decide` path never
/// allocates any of this.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DecisionExplain {
    /// One entry per scheduler-considered candidate (fleet-view order for
    /// policies that scan all nodes; may be sparse for early-exit policies).
    pub candidates: Vec<CandidateExplain>,
    /// Policy-specific rationale, e.g. the winning slot of a defer verdict
    /// or the gate that suppressed one.
    pub note: Option<String>,
}

impl DecisionExplain {
    /// Fill `candidates` with the baseline view of every fleet node.
    pub fn all_from_fleet(&mut self, fleet: &FleetView, task: &TaskDemand) {
        self.candidates = fleet.nodes.iter().map(|v| CandidateExplain::from_view(v, task)).collect();
    }
}

/// Per-workload-class batching state of one node at decision time: what a
/// class-aware scheduler needs to price "join the batch forming here" vs
/// "open a new one there" vs defer. Built by the simulator only for runs
/// with a [`crate::workload::WorkloadMix`] configured; empty otherwise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassNodeView {
    /// Tasks of this class waiting in the node's batch-formation queue
    /// (the open batch's current fill).
    pub queued: usize,
    /// When the open batch is predicted to dispatch (virtual seconds):
    /// the earlier of window expiry and now-if-full. Equal to the view's
    /// `now_s` when nothing is queued (a new batch would open and could
    /// go immediately once a slot frees).
    pub predicted_dispatch_s: f64,
    /// Class-resolved queue-delay estimate (seconds): backlog × *this
    /// class's* measured mean service time ÷ service slots, falling back
    /// to the node's blended mean where the class has no history yet.
    pub queue_delay_s: f64,
}

/// Immutable snapshot of one candidate node at decision time.
#[derive(Debug, Clone)]
pub struct NodeView {
    /// The live node (spec + accessors) this view snapshots.
    pub node: Arc<EdgeNode>,
    /// Scheduler-visible state, captured once — deciding from the snapshot
    /// instead of the live accessors keeps every score component coherent
    /// (and saves the per-component mutex traffic).
    pub state: NodeState,
    /// Estimated wait before a task handed to this node starts executing
    /// (seconds): backlog × mean service time ÷ concurrent service slots.
    pub queue_delay_s: f64,
    /// Effective carbon intensity the node would serve at right now
    /// (gCO₂/kWh): the dynamic override when installed — the simulator
    /// pushes the microgrid-*blended* value through it — else the static
    /// spec scenario.
    pub intensity: f64,
    /// Short forecast of that effective intensity: `(t_s, gCO₂/kWh)`
    /// samples from `now` (first entry) to the latest viable release slot,
    /// at the deferral policy's resolution. Empty when the task carries no
    /// usable slack (no deferral configured, a released/migrated task, or
    /// an infinite deadline) — schedulers must not defer then. For
    /// microgrid nodes the samples come from a simulated SoC trajectory
    /// ([`crate::microgrid::Microgrid::project`]), not a charge-frozen
    /// blend: release slots are priced against the battery the node will
    /// actually have.
    pub forecast: Vec<(f64, f64)>,
    /// Projected state-of-charge fraction at each forecast slot
    /// (`(t_s, soc)`, same slot grid as `forecast`). Empty for grid-only
    /// nodes, for tasks without forecast context, and under the
    /// charge-frozen twin (`SimConfig::charge_frozen_forecasts`).
    /// Report/JSON diagnostics ride on it; schedulers may ignore it.
    pub soc_forecast: Vec<(f64, f64)>,
    /// Per-workload-class batching state, indexed by
    /// [`TaskDemand::class`]. Empty for single-class runs and every
    /// non-simulated path — schedulers must treat empty as "no batching
    /// context" and fall back to the blended `queue_delay_s`.
    pub class_state: Vec<ClassNodeView>,
}

impl NodeView {
    /// Snapshot `node`. `service_slots` is the node's concurrent service
    /// capacity (1 for plain serving paths); it divides the queue-delay
    /// estimate.
    pub fn observe(node: &Arc<EdgeNode>, service_slots: usize) -> NodeView {
        let state = node.state();
        let queue_delay_s =
            state.queue_delay_ms(node.spec.prior_ms) / service_slots.max(1) as f64 / 1e3;
        let intensity = state.intensity_override.unwrap_or(node.spec.intensity);
        NodeView {
            node: Arc::clone(node),
            state,
            queue_delay_s,
            intensity,
            forecast: Vec::new(),
            soc_forecast: Vec::new(),
            class_state: Vec::new(),
        }
    }

    /// Queue-delay estimate for `class` (seconds): the class-resolved
    /// figure when the view carries batching context, else the blended
    /// node-level estimate.
    pub fn class_queue_delay_s(&self, class: usize) -> f64 {
        match self.class_state.get(class) {
            Some(c) => c.queue_delay_s,
            None => self.queue_delay_s,
        }
    }

    /// The scheduler's T_avg (Eq. 4), from the snapshot: measured history
    /// when the node is `adaptive`, else the static capability prior.
    pub fn score_ms(&self) -> f64 {
        if self.node.spec.adaptive {
            self.state.avg_ms.unwrap_or(self.node.spec.prior_ms)
        } else {
            self.node.spec.prior_ms
        }
    }

    /// Resource check (Algorithm 1 `has_sufficient_resources`).
    pub fn fits(&self, task: &TaskDemand) -> bool {
        self.node.fits(task.mem_mb, task.cpu)
    }

    /// The full Algorithm-1 line-3/6 feasibility filter: under the load
    /// cutoff, inside the latency threshold, and resource-fitting.
    pub fn feasible(&self, task: &TaskDemand) -> bool {
        self.state.load <= LOAD_CUTOFF
            && self.score_ms() <= task.latency_threshold_ms
            && self.fits(task)
    }
}

/// Per-arrival snapshot of the schedulable fleet.
#[derive(Debug, Clone)]
pub struct FleetView {
    /// One view per candidate node; [`SchedulingDecision::Assign`] indexes
    /// into this list.
    pub nodes: Vec<NodeView>,
    /// Decision time on the virtual/experiment clock (0 for real-time
    /// serving paths, which decide "now" by definition).
    pub now_s: f64,
    /// Absolute deadline when the task carries slack (`None` = run
    /// whenever): `now_s`..`deadline_s` is the defer window, and each
    /// node's forecast already stops at the policy's headroom before it.
    pub deadline_s: Option<f64>,
}

impl FleetView {
    /// Snapshot a live fleet for an immediate (real-time) decision: no
    /// virtual clock, no deadline slack, no forecasts, one service slot
    /// per node. The serving and experiment paths decide through this; the
    /// simulator builds richer views itself.
    pub fn observe(nodes: &[Arc<EdgeNode>]) -> FleetView {
        FleetView {
            nodes: nodes.iter().map(|n| NodeView::observe(n, 1)).collect(),
            now_s: 0.0,
            deadline_s: None,
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::{NodeRegistry, NodeSpec};

    #[test]
    fn decision_helpers() {
        assert_eq!(SchedulingDecision::from_choice(Some(2)), SchedulingDecision::Assign(2));
        assert_eq!(SchedulingDecision::from_choice(None), SchedulingDecision::reject());
        assert_eq!(SchedulingDecision::Assign(1).assigned(), Some(1));
        assert_eq!(SchedulingDecision::Defer { until_s: 9.0 }.assigned(), None);
        assert_eq!(SchedulingDecision::reject().assigned(), None);
        assert_eq!(
            SchedulingDecision::reject(),
            SchedulingDecision::Reject { reason: RejectReason::NoFeasibleNode }
        );
    }

    #[test]
    fn observe_snapshots_state_and_intensity() {
        let r = NodeRegistry::paper_setup();
        let v = NodeView::observe(r.get(0), 1);
        assert_eq!(v.state.inflight, 0);
        assert_eq!(v.queue_delay_s, 0.0);
        assert_eq!(v.intensity, 620.0); // static spec scenario
        assert!(v.forecast.is_empty());
        assert!(v.soc_forecast.is_empty());
        assert!(v.class_state.is_empty());
        // The override flows into the snapshot.
        r.get(0).set_intensity(42.0);
        assert_eq!(NodeView::observe(r.get(0), 1).intensity, 42.0);
        // The view is a snapshot: later node mutations don't reach it.
        r.get(0).begin_task();
        assert_eq!(v.state.inflight, 0);
    }

    #[test]
    fn queue_delay_scales_with_backlog_and_slots() {
        let r = NodeRegistry::paper_setup();
        let n = r.get(0); // prior 250 ms
        n.begin_task();
        n.begin_task();
        // No history yet: estimate = backlog × prior.
        let v = NodeView::observe(n, 1);
        assert!((v.queue_delay_s - 2.0 * 0.250).abs() < 1e-12);
        // Two service slots halve it.
        let v2 = NodeView::observe(n, 2);
        assert!((v2.queue_delay_s - 0.250).abs() < 1e-12);
        // Measured history replaces the prior.
        n.finish_task(100.0, 0.0, 0.0);
        let v3 = NodeView::observe(n, 1);
        assert!((v3.queue_delay_s - 0.100).abs() < 1e-12, "{}", v3.queue_delay_s);
    }

    #[test]
    fn class_queue_delay_falls_back_to_blended() {
        let r = NodeRegistry::paper_setup();
        let mut v = NodeView::observe(r.get(0), 1);
        v.queue_delay_s = 0.4;
        // No batching context: every class sees the blended estimate.
        assert_eq!(v.class_queue_delay_s(0), 0.4);
        assert_eq!(v.class_queue_delay_s(7), 0.4);
        // With context, the class-resolved figure wins — and out-of-range
        // classes still fall back.
        v.class_state = vec![
            ClassNodeView { queued: 2, predicted_dispatch_s: 1.0, queue_delay_s: 0.9 },
            ClassNodeView { queued: 0, predicted_dispatch_s: 0.0, queue_delay_s: 0.1 },
        ];
        assert_eq!(v.class_queue_delay_s(0), 0.9);
        assert_eq!(v.class_queue_delay_s(1), 0.1);
        assert_eq!(v.class_queue_delay_s(5), 0.4);
    }

    #[test]
    fn feasibility_mirrors_algorithm_1_filters() {
        let r = NodeRegistry::paper_setup();
        let task = TaskDemand::default();
        let v = NodeView::observe(r.get(0), 1);
        assert!(v.feasible(&task));
        // Resource filter: 2 GB fits nothing.
        let big = TaskDemand { mem_mb: 2048, ..task };
        assert!(!v.feasible(&big));
        // Latency filter: node-green's 625 ms prior exceeds 300 ms.
        let tight = TaskDemand { latency_threshold_ms: 300.0, ..task };
        assert!(!NodeView::observe(r.get(2), 1).feasible(&tight));
        // Load filter: saturate past the cutoff.
        let n = r.get(1);
        for _ in 0..200 {
            n.begin_task();
            n.finish_task(10.0, 0.0, 0.0);
            n.begin_task();
        }
        assert!(n.state().load > LOAD_CUTOFF);
        assert!(!NodeView::observe(n, 1).feasible(&task));
    }

    #[test]
    fn fleet_observe_covers_every_node() {
        let r = NodeRegistry::paper_setup();
        let f = FleetView::observe(r.nodes());
        assert_eq!(f.len(), 3);
        assert!(!f.is_empty());
        assert_eq!(f.now_s, 0.0);
        assert_eq!(f.deadline_s, None);
        assert_eq!(f.nodes[2].node.spec.name, "node-green");
    }

    #[test]
    fn score_ms_follows_adaptive_flag() {
        let mut spec = NodeSpec::paper_nodes().remove(0);
        spec.adaptive = true;
        let n = EdgeNode::new(spec);
        assert_eq!(NodeView::observe(&n, 1).score_ms(), 250.0); // prior cold-start
        n.begin_task();
        n.finish_task(90.0, 0.0, 0.0);
        assert_eq!(NodeView::observe(&n, 1).score_ms(), 90.0); // measured
        let fixed = EdgeNode::new(NodeSpec::paper_nodes().remove(0));
        fixed.begin_task();
        fixed.finish_task(90.0, 0.0, 0.0);
        assert_eq!(NodeView::observe(&fixed, 1).score_ms(), 250.0); // prior
    }
}
