//! Open-loop serving: a request queue fed by an arrival process, drained by
//! the router (scheduler) into node containers. Demonstrates the framework
//! as an online service rather than a batch experiment (examples/e2e).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::RunReport;
use crate::node::{Container, ExecutionRecord, NodeRegistry};
use crate::scheduler::{FleetView, Scheduler, TaskDemand};
use crate::util::stats::mean_or_zero;
use crate::workload::{Arrivals, RequestStream};

/// Result of a serving session.
pub struct ServeOutcome {
    pub report: RunReport,
    /// Mean time requests spent queued before dispatch (ms).
    pub queue_ms_mean: f64,
    /// Mean scheduling decision time (ms).
    pub sched_ms_mean: f64,
}

/// The serving loop: owns the request queue and drives dispatch.
pub struct ServingLoop<'a> {
    pub registry: &'a NodeRegistry,
    pub containers: &'a [Container],
    pub demand: TaskDemand,
}

impl<'a> ServingLoop<'a> {
    pub fn new(registry: &'a NodeRegistry, containers: &'a [Container]) -> ServingLoop<'a> {
        assert_eq!(registry.len(), containers.len(), "one container per node");
        ServingLoop { registry, containers, demand: TaskDemand::default() }
    }

    /// Serve a request stream. For `Poisson` arrivals, request issue times
    /// follow the generated gaps in *real time*; the queue drains in FIFO
    /// order (the executor serializes device work, as one accelerator
    /// would).
    pub fn serve(
        &self,
        stream: &RequestStream,
        scheduler: &mut dyn Scheduler,
        label: &str,
    ) -> Result<ServeOutcome> {
        let inputs = stream.inputs();
        let gaps = stream.arrivals.gaps();
        let mut queue: VecDeque<(usize, Instant)> = VecDeque::new();
        let mut records: Vec<ExecutionRecord> = Vec::with_capacity(inputs.len());
        let mut queue_ms = Vec::with_capacity(inputs.len());
        let mut sched_ms: Vec<f64> = Vec::with_capacity(inputs.len());

        match &stream.arrivals {
            Arrivals::ClosedLoop { .. } => {
                for x in &inputs {
                    // lint: allow(D2 L3 real-execution latency measurement)
                    let t0 = Instant::now();
                    let fleet = FleetView::observe(self.registry.nodes());
                    let pick = scheduler.decide(&self.demand, &fleet).assigned();
                    sched_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                    let idx = pick.ok_or_else(|| anyhow::anyhow!("no feasible node"))?;
                    records.push(self.containers[idx].infer(x.clone())?);
                    queue_ms.push(0.0);
                }
            }
            Arrivals::Poisson { .. } => {
                // lint: allow(D2 open-loop arrivals are issued on the real clock)
                let start = Instant::now();
                let mut issue_at: Vec<Duration> = Vec::with_capacity(inputs.len());
                let mut acc = Duration::ZERO;
                for g in &gaps {
                    acc += Duration::from_secs_f64(*g);
                    issue_at.push(acc);
                }
                let mut next = 0usize;
                while records.len() < inputs.len() {
                    // enqueue everything whose issue time has passed
                    while next < inputs.len() && start.elapsed() >= issue_at[next] {
                        // lint: allow(D2 real enqueue timestamp for queue-delay measurement)
                        queue.push_back((next, Instant::now()));
                        next += 1;
                    }
                    if let Some((i, enq)) = queue.pop_front() {
                        queue_ms.push(enq.elapsed().as_secs_f64() * 1e3);
                        // lint: allow(D2 L3 real-execution latency measurement)
                        let t0 = Instant::now();
                        let fleet = FleetView::observe(self.registry.nodes());
                        let pick = scheduler.decide(&self.demand, &fleet).assigned();
                        sched_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                        let idx = pick.ok_or_else(|| anyhow::anyhow!("no feasible node"))?;
                        records.push(self.containers[idx].infer(inputs[i].clone())?);
                    } else if next < inputs.len() {
                        let wait = issue_at[next].saturating_sub(start.elapsed());
                        std::thread::sleep(wait.min(Duration::from_millis(2)));
                    }
                }
            }
        }

        let report = RunReport::from_records(label, &records)?;
        Ok(ServeOutcome {
            report,
            queue_ms_mean: mean_or_zero(&queue_ms),
            sched_ms_mean: mean_or_zero(&sched_ms),
        })
    }
}
