//! # `carbonedge lint` — determinism & ledger-safety static analysis
//!
//! The repo's headline guarantees are *equalities*: a traced run is
//! bit-identical to an untraced one, a replayed firehose reconstructs the
//! live report field-by-field, and the energy/carbon ledgers conserve to
//! rounding. Those guarantees are enforced at runtime by the test
//! suites — this module enforces their *preconditions* statically, so a
//! careless edit fails CI before it can produce a plausible-but-wrong
//! simulation. It is a self-contained, no-external-deps analyzer in the
//! same hand-rolled style as [`crate::util::json`]: a sanitizing lexer
//! ([`lexer`]) blanks comments/strings and tracks test regions, and a
//! small rule engine ([`rules`]) runs line-oriented checks over the
//! result.
//!
//! ## Rule catalogue
//!
//! | id | family | fires on |
//! |----|--------|----------|
//! | D1 | determinism | iteration over `HashMap`/`HashSet` in simulator modules — iteration order is randomized per process, so any fold feeding a report or replay breaks determinism-by-equality; use `BTreeMap` or collect-and-sort |
//! | D2 | determinism | `Instant::now` / `SystemTime::now` / `thread_rng` / `rand::random` outside `util/bench.rs` — virtual time comes from the event queue, randomness from seeded [`crate::util::rng`] streams |
//! | D3 | determinism | an f64 `.sum()`/`.fold()`/`.product()` chained onto an unordered-container iteration — float addition does not commute, so even value-identical runs diverge in the last ulp |
//! | P1 | panic-safety | `.unwrap()` / `.expect(` in simulator/metrics non-test code — a panic mid-run poisons a multi-minute fleet sweep; propagate or waive with the invariant that makes it unreachable |
//! | P2 | panic-safety | `assert!`-family (not `debug_assert!`) outside `validate*` functions — release-mode asserts on hot paths re-check invariants `validate()` already guaranteed once |
//! | U1 | unit-hygiene | a direct flow (`=`, `+=`, comparison, `.max(`/`.min(`) between identifiers whose unit suffixes disagree within one family (`_s`/`_ms`/`_ns`, `_w`/`_kw`, `_j`/`_wh`/`_kwh`, `_g`/`_kg`) — the WAN/battery ledgers mix all of these |
//!
//! ## Scoping
//!
//! D1/D3 and P2 apply to the deterministic simulator modules
//! ([`DET_MODULES`]); P1 additionally covers `metrics` (the export
//! writers sit on the report path); D2 applies everywhere except
//! `util/bench.rs` (the bench harness is *supposed* to read the wall
//! clock); U1 applies everywhere. Test code (`#[cfg(test)]` / `#[test]`)
//! is always exempt: tests may unwrap and assert freely.
//!
//! ## Waivers
//!
//! Legitimate exceptions carry an inline waiver on the same or the
//! preceding line:
//!
//! ```text
//! let t0 = Instant::now(); // lint: allow(D2 real ns-per-decision telemetry, never virtual state)
//! ```
//!
//! Waivers are counted and reported; `carbonedge lint --deny` exits
//! nonzero only on *unwaived* findings. The reason is mandatory by
//! convention — a waiver documents the invariant that makes the hazard
//! safe, and reviewers treat a bare waiver as a finding.

pub mod lexer;
pub mod rules;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Modules whose code runs under the virtual clock and feeds the
/// deterministic reports (D1/D3/P2 scope, plus P1).
pub const DET_MODULES: [&str; 7] =
    ["sim", "scheduler", "site", "obs", "microgrid", "carbon", "workload"];

/// Additional modules in P1 (unwrap/expect) scope: the metrics export
/// writers serialize the report ledger, so a panic there loses the run.
pub const PANIC_MODULES: [&str; 1] = ["metrics"];

/// Rule identifiers. See the module docs for the catalogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    D1,
    D2,
    D3,
    P1,
    P2,
    U1,
}

impl Rule {
    pub fn id(&self) -> &'static str {
        match self {
            Rule::D1 => "D1",
            Rule::D2 => "D2",
            Rule::D3 => "D3",
            Rule::P1 => "P1",
            Rule::P2 => "P2",
            Rule::U1 => "U1",
        }
    }

    /// One-line fix hint attached to every finding.
    pub fn hint(&self) -> &'static str {
        match self {
            Rule::D1 => "HashMap/HashSet iteration order is nondeterministic; use BTreeMap",
            Rule::D2 => "wall-clock/randomness breaks replay; use virtual time or util::rng",
            Rule::D3 => "f64 fold over an unordered container; sort keys before accumulating",
            Rule::P1 => "unwrap/expect can poison a fleet sweep; propagate or waive",
            Rule::P2 => "release assert outside validate(); demote to debug_assert!",
            Rule::U1 => "unit suffixes disagree (_s/_ms, _wh/_kwh, ...); convert explicitly",
        }
    }
}

/// One lint finding: where, what, and an excerpt of the offending line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub excerpt: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: {} `{}`\n    hint: {}",
            self.path,
            self.line,
            self.rule.id(),
            self.excerpt,
            self.rule.hint()
        )
    }
}

/// Lint result for one file (or one tree): unwaived findings plus the
/// count of findings suppressed by inline waivers.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub waived: usize,
    pub files: usize,
}

/// The module a path belongs to for scoping: the first directory under
/// `src/`, or the file stem for `src/`-level files (`lib.rs` → `lib`).
pub fn module_of(path: &str) -> String {
    let parts: Vec<&str> = path.split(['/', '\\']).collect();
    if let Some(i) = parts.iter().position(|&p| p == "src") {
        let rest = &parts[i + 1..];
        if rest.len() >= 2 {
            return rest[0].to_string();
        }
        if let Some(f) = rest.first() {
            return f.trim_end_matches(".rs").to_string();
        }
    }
    if parts.len() >= 2 {
        return parts[parts.len() - 2].to_string();
    }
    String::new()
}

/// Lint one file's source text. `path` determines module scoping only —
/// the text itself is taken from `src`, so callers may lint fixtures or
/// unsaved buffers under any synthetic path.
pub fn lint_source(path: &str, src: &str) -> LintReport {
    let model = lexer::SourceModel::new(src);
    let mut raw = Vec::new();
    rules::run(path, &model, &mut raw);
    let mut report = LintReport {
        files: 1,
        ..LintReport::default()
    };
    for f in raw {
        if model.waived(f.line, f.rule.id()) {
            report.waived += 1;
        } else {
            report.findings.push(f);
        }
    }
    report
}

/// Lint files and directory trees. Directories are walked recursively in
/// sorted order (deterministic output); only `.rs` files are linted, and
/// any path component named `fixtures` is skipped — the fixture corpus
/// under `analysis/fixtures/` is *intentionally* dirty.
pub fn lint_paths<S: AsRef<str>>(paths: &[S]) -> Result<LintReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        collect(Path::new(p.as_ref()), &mut files)?;
    }
    files.sort();
    let mut report = LintReport::default();
    for path in files {
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let one = lint_source(&path.to_string_lossy(), &src);
        report.findings.extend(one.findings);
        report.waived += one.waived;
        report.files += 1;
    }
    Ok(report)
}

fn collect(path: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if path.file_name().is_some_and(|n| n == "fixtures") {
        return Ok(());
    }
    let meta = std::fs::metadata(path).with_context(|| format!("stat {}", path.display()))?;
    if meta.is_dir() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(path)
            .with_context(|| format!("reading dir {}", path.display()))?
            .collect::<std::io::Result<Vec<_>>>()?
            .into_iter()
            .map(|e| e.path())
            .collect();
        entries.sort();
        for e in entries {
            collect(&e, out)?;
        }
    } else if path.extension().is_some_and(|e| e == "rs") {
        out.push(path.to_path_buf());
    }
    Ok(())
}

/// The known-bad fixture corpus: one snippet per rule, each tripping
/// exactly its own rule once, plus a waived variant. Embedded so the
/// test suite (and `lint --self-check` style uses) need no filesystem
/// layout assumptions. The paths are synthetic — they place each fixture
/// in the module scope its rule targets.
pub mod fixtures {
    pub const D1: &str = include_str!("fixtures/d1.rs");
    pub const D1_PATH: &str = "rust/src/sim/fixtures/d1.rs";
    pub const D2: &str = include_str!("fixtures/d2.rs");
    pub const D2_PATH: &str = "rust/src/sim/fixtures/d2.rs";
    pub const D3: &str = include_str!("fixtures/d3.rs");
    pub const D3_PATH: &str = "rust/src/sim/fixtures/d3.rs";
    pub const P1: &str = include_str!("fixtures/p1.rs");
    pub const P1_PATH: &str = "rust/src/scheduler/fixtures/p1.rs";
    pub const P2: &str = include_str!("fixtures/p2.rs");
    pub const P2_PATH: &str = "rust/src/carbon/fixtures/p2.rs";
    pub const U1: &str = include_str!("fixtures/u1.rs");
    pub const U1_PATH: &str = "rust/src/site/fixtures/u1.rs";
    pub const WAIVED: &str = include_str!("fixtures/waived.rs");
    pub const WAIVED_PATH: &str = "rust/src/scheduler/fixtures/waived.rs";

    /// `(rule id, expected line, path, source)` for every fixture that
    /// must fire.
    pub const ALL_BAD: [(&str, usize, &str, &str); 6] = [
        ("D1", 9, D1_PATH, D1),
        ("D2", 7, D2_PATH, D2),
        ("D3", 7, D3_PATH, D3),
        ("P1", 7, P1_PATH, P1),
        ("P2", 7, P2_PATH, P2),
        ("U1", 8, U1_PATH, U1),
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_scoping() {
        assert_eq!(module_of("rust/src/sim/engine.rs"), "sim");
        assert_eq!(module_of("rust/src/util/json.rs"), "util");
        assert_eq!(module_of("rust/src/lib.rs"), "lib");
        assert_eq!(module_of("/abs/repo/rust/src/obs/replay.rs"), "obs");
    }

    #[test]
    fn waived_findings_count_but_do_not_fail() {
        let src = "fn f(x: Option<f64>) -> f64 {\n    // lint: allow(P1 caller checked is_some)\n    x.unwrap()\n}\n";
        let r = lint_source("rust/src/sim/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.waived, 1);
    }

    #[test]
    fn every_fixture_trips_exactly_its_own_rule() {
        for (rule, line, path, src) in fixtures::ALL_BAD {
            let r = lint_source(path, src);
            assert_eq!(r.findings.len(), 1, "{rule}: {:?}", r.findings);
            assert_eq!(r.findings[0].rule.id(), rule);
            assert_eq!(r.findings[0].line, line, "{rule} fired on the wrong line");
            assert_eq!(r.waived, 0);
        }
    }
}
