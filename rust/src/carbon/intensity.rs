//! Grid carbon-intensity data: named regional scenarios (the paper's static
//! setup, Sec. IV-A1) and temporal traces (the paper's future-work
//! extension: "real-time carbon intensity integration").

use super::GramsPerKwh;

/// A named grid region with a representative static intensity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Region {
    pub name: &'static str,
    pub intensity: GramsPerKwh,
}

/// Representative regional intensities cited by the paper (Sec. II-E,
/// IV-A1): coal-heavy grids >800, China average ~530, hydro-rich <200,
/// renewable areas <100 gCO₂/kWh; plus the paper's three node scenarios.
pub const REGIONS: &[Region] = &[
    Region { name: "coal-north-china", intensity: 820.0 },
    Region { name: "node-high-scenario", intensity: 620.0 },
    Region { name: "china-average", intensity: 530.0 },
    Region { name: "global-average", intensity: 475.0 },
    Region { name: "node-green-scenario", intensity: 380.0 },
    Region { name: "yunnan-hydro", intensity: 180.0 },
    Region { name: "renewable-zone", intensity: 90.0 },
    Region { name: "nordic-hydro", intensity: 45.0 },
];

/// Look up a named region.
pub fn region(name: &str) -> Option<Region> {
    REGIONS.iter().copied().find(|r| r.name == name)
}

/// Time-varying carbon intensity. The paper uses `Static`; `Diurnal` and
/// `Trace` implement its future-work extension so schedulers can be
/// evaluated against temporal variation too (bench `ablation`).
#[derive(Debug, Clone)]
pub enum IntensityTrace {
    /// Constant intensity (the paper's experimental setting).
    Static(GramsPerKwh),
    /// Sinusoidal day curve: `mean + amp * sin(2π (t - phase)/period)`.
    /// Approximates solar-driven grids (low at noon, high at night).
    Diurnal { mean: GramsPerKwh, amplitude: f64, period_s: f64, phase_s: f64 },
    /// Piecewise-constant samples `(t_seconds, intensity)`, step-held.
    Trace(Vec<(f64, GramsPerKwh)>),
}

impl IntensityTrace {
    /// Intensity at time `t` seconds from experiment start.
    pub fn at(&self, t: f64) -> GramsPerKwh {
        match self {
            IntensityTrace::Static(v) => *v,
            IntensityTrace::Diurnal { mean, amplitude, period_s, phase_s } => {
                let x = 2.0 * std::f64::consts::PI * (t - phase_s) / period_s;
                (mean + amplitude * x.sin()).max(0.0)
            }
            IntensityTrace::Trace(points) => {
                if points.is_empty() {
                    return 0.0;
                }
                // Step-hold: last sample with time <= t (or the first
                // sample when t precedes the trace). Samples are
                // time-sorted, so a binary search replaces the old O(n)
                // scan — this sits on the simulator's per-completion path.
                let idx = points.partition_point(|&(ts, _)| ts <= t);
                if idx == 0 {
                    points[0].1
                } else {
                    points[idx - 1].1
                }
            }
        }
    }

    /// Mean over `[0, horizon]` by midpoint sampling (reporting helper).
    pub fn mean(&self, horizon: f64, samples: usize) -> GramsPerKwh {
        assert!(samples > 0);
        (0..samples)
            .map(|i| self.at((i as f64 + 0.5) * horizon / samples as f64))
            .sum::<f64>()
            / samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_cover_paper_scenarios() {
        assert_eq!(region("node-high-scenario").unwrap().intensity, 620.0);
        assert_eq!(region("china-average").unwrap().intensity, 530.0);
        assert_eq!(region("node-green-scenario").unwrap().intensity, 380.0);
        assert!(region("atlantis").is_none());
        // ordering: coal-heavy above renewable
        assert!(region("coal-north-china").unwrap().intensity > 800.0);
        assert!(region("renewable-zone").unwrap().intensity < 100.0);
    }

    #[test]
    fn static_trace_constant() {
        let t = IntensityTrace::Static(530.0);
        assert_eq!(t.at(0.0), 530.0);
        assert_eq!(t.at(1e6), 530.0);
        assert_eq!(t.mean(100.0, 10), 530.0);
    }

    #[test]
    fn diurnal_oscillates_and_clamps() {
        let t = IntensityTrace::Diurnal {
            mean: 100.0,
            amplitude: 150.0,
            period_s: 86400.0,
            phase_s: 0.0,
        };
        // peak at period/4
        assert!((t.at(21600.0) - 250.0).abs() < 1.0);
        // trough clamps at zero (mean-amp < 0)
        assert_eq!(t.at(64800.0), 0.0);
        // mean over a full period is >= 0 and <= mean+amp
        let m = t.mean(86400.0, 1000);
        assert!(m > 0.0 && m < 250.0);
    }

    #[test]
    fn trace_step_holds() {
        let t = IntensityTrace::Trace(vec![(0.0, 500.0), (10.0, 300.0), (20.0, 700.0)]);
        assert_eq!(t.at(0.0), 500.0);
        assert_eq!(t.at(9.9), 500.0);
        assert_eq!(t.at(10.0), 300.0);
        assert_eq!(t.at(25.0), 700.0);
        // before first sample: first value
        assert_eq!(IntensityTrace::Trace(vec![(5.0, 42.0)]).at(0.0), 42.0);
        assert_eq!(IntensityTrace::Trace(vec![]).at(1.0), 0.0);
    }

    #[test]
    fn prop_trace_binary_search_matches_linear_scan() {
        // The pre-optimization reference implementation.
        fn linear(points: &[(f64, f64)], t: f64) -> f64 {
            if points.is_empty() {
                return 0.0;
            }
            let mut current = points[0].1;
            for &(ts, v) in points {
                if ts <= t {
                    current = v;
                } else {
                    break;
                }
            }
            current
        }
        crate::util::proptest::check(
            "partition_point lookup == step-hold linear scan",
            500,
            |rng| {
                // 0..8 samples (0 = the empty case) at strictly increasing
                // times that may start negative; queries range from well
                // before the first sample to well past the last.
                let n = rng.below(8);
                let mut ts = rng.range(-5.0, 5.0);
                let mut points = Vec::with_capacity(n);
                for _ in 0..n {
                    ts += rng.range(0.1, 10.0);
                    points.push((ts, rng.range(0.0, 900.0)));
                }
                let queries: Vec<f64> = (0..8).map(|_| rng.range(-20.0, 90.0)).collect();
                (points, queries)
            },
            |(points, queries)| {
                let trace = IntensityTrace::Trace(points.clone());
                for &q in queries {
                    let fast = trace.at(q);
                    let slow = linear(points, q);
                    if fast != slow {
                        return Err(format!("at({q}) = {fast}, linear scan = {slow}"));
                    }
                }
                // Exact sample times must also agree (boundary inclusivity).
                for &(ts, _) in points {
                    if trace.at(ts) != linear(points, ts) {
                        return Err(format!("boundary mismatch at t = {ts}"));
                    }
                }
                Ok(())
            },
        );
    }
}
