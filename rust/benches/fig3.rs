//! Bench: regenerate paper Fig. 3 (w_C sweep: carbon-latency trade-off,
//! transition threshold at w_C >= 0.50).

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let iters: usize =
        std::env::var("CE_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    let coord = Coordinator::new(cfg)?;
    let mono = exp::run_strategy(&coord, "mobilenet_v2", exp::Strategy::Monolithic, iters, 1)?;
    let points = exp::fig3_sweep(&coord, "mobilenet_v2", iters, 0.05)?;
    println!("{}", exp::fig3_render(&points, &mono));
    println!("paper Fig. 3 shape: transition at w_C >= 0.50, ~22.9% reduction beyond it");
    Ok(())
}
