//! Quickstart: load a model, run a few carbon-aware inferences, print the
//! carbon report.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::metrics::RunReport;
use carbonedge::scheduler::{CarbonAwareScheduler, Mode};
use carbonedge::workload::RequestStream;

fn main() -> anyhow::Result<()> {
    // 1. Start the coordinator (PJRT executor + artifact manifest).
    let coord = Coordinator::new(Config::default())?;
    println!("loaded manifest with {} models", coord.manifest.models.len());

    // 2. Load MobileNetV2 and verify numerics against the golden record.
    let model = coord.load_model("mobilenet_v2")?;
    let err = coord.golden_check(&model)?;
    println!("golden check OK (max logit error {err:.2e})");

    // 3. Run 10 inferences in Green mode across the simulated edge fleet.
    let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
    let stream = RequestStream {
        image_size: coord.manifest.image_size,
        arrivals: carbonedge::workload::Arrivals::ClosedLoop { count: 10 },
        seed: 0,
    };
    let run = coord.run_scheduled(&model, &mut sched, &stream.inputs())?;
    let report = RunReport::from_records("quickstart-green", &run.records);

    // 4. Print the carbon report.
    println!("\n== {} ==", report.label);
    println!("inferences:        {}", report.inferences);
    println!("mean latency:      {:.2} ms", report.latency_ms.mean);
    println!("throughput:        {:.2} req/s", report.throughput_rps);
    println!("energy:            {:.6} kWh", report.energy_kwh);
    println!("carbon/inference:  {:.5} gCO2", report.carbon_per_inf_g);
    println!("carbon efficiency: {:.1} inf/gCO2", report.carbon_efficiency);
    println!("scheduling:        {:.4} ms/task", run.mean_sched_ms());
    for (node, tasks) in &report.node_usage {
        println!("  routed {tasks} tasks -> {node}");
    }
    Ok(())
}
