//! Typed view of `artifacts/manifest.json`.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Top-level manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub image_size: usize,
    pub width: f64,
    pub num_classes: usize,
    pub models: BTreeMap<String, ModelEntry>,
}

/// One model's artifact set.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub params: usize,
    pub flops: usize,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub monolithic: String,
    pub weights_file: String,
    pub weights_total: usize,
    pub input_file: String,
    pub golden: GoldenRecord,
    pub stages: Vec<StageEntry>,
    pub weights: Vec<WeightEntry>,
    pub layers: Vec<LayerEntry>,
}

/// One distributable stage.
#[derive(Debug, Clone)]
pub struct StageEntry {
    pub name: String,
    pub artifact: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub params: usize,
    pub flops: usize,
    /// Eq. 5 cost of the stage (sum over its layers).
    pub cost: usize,
    pub num_weights: usize,
}

impl StageEntry {
    /// Activation elements crossing the stage boundary (communication cost).
    pub fn boundary_elems(&self) -> usize {
        self.out_shape.iter().product()
    }
}

/// One packed weight tensor.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub stage: usize,
    pub shape: Vec<usize>,
    pub offset: usize,
}

/// Per-layer record (paper Eq. 5 inputs).
#[derive(Debug, Clone)]
pub struct LayerEntry {
    pub name: String,
    pub kind: String,
    pub stage: usize,
    pub params: usize,
    pub cost: usize,
    pub flops: usize,
}

/// Golden check exported by aot.py.
#[derive(Debug, Clone)]
pub struct GoldenRecord {
    pub seed: usize,
    pub logits8: Vec<f64>,
    pub argmax: usize,
    pub logit_sum: f64,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path}; run `make artifacts` first"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        Manifest::from_json(&json)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        for (name, entry) in j.req_obj("models")? {
            models.insert(name.clone(), ModelEntry::from_json(name, entry)?);
        }
        Ok(Manifest {
            version: j.req_usize("version")?,
            image_size: j.req_usize("image_size")?,
            width: j.req_f64("width").unwrap_or(1.0),
            num_classes: j.req_usize("num_classes")?,
            models,
        })
    }
}

impl ModelEntry {
    fn from_json(name: &str, j: &Json) -> Result<ModelEntry> {
        let stages = j
            .req_arr("stages")?
            .iter()
            .map(StageEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        let weights = j
            .req_arr("weights")?
            .iter()
            .map(WeightEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        let layers = j
            .req_arr("layers")?
            .iter()
            .map(LayerEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        let g = j.get("golden").ok_or_else(|| anyhow::anyhow!("missing golden"))?;
        Ok(ModelEntry {
            name: name.to_string(),
            params: j.req_usize("params")?,
            flops: j.req_usize("flops")?,
            num_classes: j.req_usize("num_classes")?,
            input_shape: j
                .get("input_shape")
                .and_then(Json::usize_vec)
                .ok_or_else(|| anyhow::anyhow!("missing input_shape"))?,
            monolithic: j.req_str("monolithic")?.to_string(),
            weights_file: j.req_str("weights_file")?.to_string(),
            weights_total: j.req_usize("weights_total")?,
            input_file: j.req_str("input_file")?.to_string(),
            golden: GoldenRecord {
                seed: g.req_usize("seed")?,
                logits8: g
                    .req_arr("logits8")?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(f64::NAN))
                    .collect(),
                argmax: g.req_usize("argmax")?,
                logit_sum: g.req_f64("logit_sum")?,
            },
            stages,
            weights,
            layers,
        })
    }

    /// Total Eq. 5 cost of the model.
    pub fn total_cost(&self) -> usize {
        self.stages.iter().map(|s| s.cost).sum()
    }
}

impl StageEntry {
    fn from_json(j: &Json) -> Result<StageEntry> {
        Ok(StageEntry {
            name: j.req_str("name")?.to_string(),
            artifact: j.req_str("artifact")?.to_string(),
            in_shape: j
                .get("in_shape")
                .and_then(Json::usize_vec)
                .ok_or_else(|| anyhow::anyhow!("missing in_shape"))?,
            out_shape: j
                .get("out_shape")
                .and_then(Json::usize_vec)
                .ok_or_else(|| anyhow::anyhow!("missing out_shape"))?,
            params: j.req_usize("params")?,
            flops: j.req_usize("flops")?,
            cost: j.req_usize("cost")?,
            num_weights: j.req_usize("num_weights")?,
        })
    }
}

impl WeightEntry {
    fn from_json(j: &Json) -> Result<WeightEntry> {
        Ok(WeightEntry {
            stage: j.req_usize("stage")?,
            shape: j
                .get("shape")
                .and_then(Json::usize_vec)
                .ok_or_else(|| anyhow::anyhow!("missing weight shape"))?,
            offset: j.req_usize("offset")?,
        })
    }
}

impl LayerEntry {
    fn from_json(j: &Json) -> Result<LayerEntry> {
        Ok(LayerEntry {
            name: j.req_str("name")?.to_string(),
            kind: j.req_str("kind")?.to_string(),
            stage: j.req_usize("stage")?,
            params: j.req_usize("params")?,
            cost: j.req_usize("cost")?,
            flops: j.req_usize("flops")?,
        })
    }
}
