//! Bench: regenerate paper Table III (comparison with related carbon-aware
//! systems; our row carries the measured reduction).

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let iters: usize =
        std::env::var("CE_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(15);
    let coord = Coordinator::new(cfg)?;
    // Table III only needs the Green-vs-Mono reduction: run those two.
    let mono = exp::run_strategy(&coord, "mobilenet_v2", exp::Strategy::Monolithic, iters, 1)?;
    let green = exp::run_strategy(
        &coord,
        "mobilenet_v2",
        exp::Strategy::CarbonEdge(carbonedge::scheduler::Mode::Green),
        iters,
        1,
    )?;
    println!("{}", exp::table3_render(green.reduction_vs(&mono)));
    Ok(())
}
