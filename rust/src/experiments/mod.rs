//! Experiment harness: one function per paper table/figure (DESIGN.md §4).
//! Shared by `carbonedge reproduce`, the benches, and the examples.

use anyhow::Result;

use crate::coordinator::Coordinator;
use crate::metrics::{average_reports, RunReport};
use crate::scheduler::{Amp4ecScheduler, CarbonAwareScheduler, Mode, Weights};
use crate::util::stats::Summary;
use crate::util::table::{f2, f4, f5, pct, Table};
use crate::workload::RequestStream;

/// The experiment configurations (Table II's five, plus sweep points).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    Monolithic,
    Amp4ec,
    CarbonEdge(Mode),
    /// Fig. 3 sweep point: custom carbon weight.
    Sweep(f64),
}

impl Strategy {
    pub fn label(&self) -> String {
        match self {
            Strategy::Monolithic => "Monolithic".into(),
            Strategy::Amp4ec => "AMP4EC".into(),
            Strategy::CarbonEdge(m) => format!(
                "CE-{}",
                match m {
                    Mode::Performance => "Performance",
                    Mode::Green => "Green",
                    Mode::Balanced => "Balanced",
                }
            ),
            Strategy::Sweep(w) => format!("w_C={w:.2}"),
        }
    }

    pub fn table2_order() -> [Strategy; 5] {
        [
            Strategy::Monolithic,
            Strategy::Amp4ec,
            Strategy::CarbonEdge(Mode::Performance),
            Strategy::CarbonEdge(Mode::Balanced),
            Strategy::CarbonEdge(Mode::Green),
        ]
    }
}

/// One live configuration during an interleaved run.
struct Runner {
    label: String,
    kind: RunnerKind,
    records: Vec<crate::node::ExecutionRecord>,
    sched_ns: Vec<u64>,
}

enum RunnerKind {
    Mono { container: crate::node::Container },
    Sched {
        sched: Box<dyn crate::scheduler::Scheduler>,
        registry: crate::node::NodeRegistry,
        containers: Vec<crate::node::Container>,
    },
}

impl Runner {
    fn build(
        coord: &Coordinator,
        model: &crate::model::LoadedModel,
        s: Strategy,
    ) -> Result<Runner> {
        let kind = match s {
            Strategy::Monolithic => {
                let key = crate::deployer::register_monolithic(&coord.exec(), model, &coord.cfg)?;
                let c = crate::node::Container::new(
                    coord.host_node(),
                    coord.exec(),
                    coord.cfg.host,
                    coord.cfg.pue,
                    vec![key],
                );
                RunnerKind::Mono { container: c }
            }
            _ => {
                let sched: Box<dyn crate::scheduler::Scheduler> = match s {
                    Strategy::Amp4ec => Box::new(Amp4ecScheduler::new()),
                    Strategy::CarbonEdge(mode) => {
                        Box::new(CarbonAwareScheduler::new(mode.name(), mode.weights()))
                    }
                    Strategy::Sweep(w) => {
                        Box::new(CarbonAwareScheduler::new("sweep", Weights::sweep(w)))
                    }
                    Strategy::Monolithic => unreachable!(),
                };
                let registry = coord.calibrated_registry(model)?;
                let containers = crate::deployer::deploy_task_level(
                    &coord.exec(),
                    model,
                    registry.nodes(),
                    &coord.cfg,
                )?;
                RunnerKind::Sched { sched, registry, containers }
            }
        };
        Ok(Runner { label: Strategy::label(&s), kind, records: Vec::new(), sched_ns: Vec::new() })
    }

    fn step(&mut self, input: &crate::runtime::Tensor) -> Result<()> {
        match &mut self.kind {
            RunnerKind::Mono { container } => {
                self.records.push(container.infer(input.clone())?);
            }
            RunnerKind::Sched { sched, registry, containers } => {
                let task = crate::scheduler::TaskDemand::default();
                // The snapshot is part of the decision cost (it does the
                // state reads the old select did internally), so it stays
                // inside the timed region.
                // lint: allow(D2 L3 measures real scheduling overhead on the wall clock)
                let t0 = std::time::Instant::now();
                let fleet = crate::scheduler::FleetView::observe(registry.nodes());
                let pick = sched.decide(&task, &fleet).assigned();
                self.sched_ns.push(t0.elapsed().as_nanos() as u64);
                let i = pick.ok_or_else(|| anyhow::anyhow!("no feasible node"))?;
                self.records.push(containers[i].infer(input.clone())?);
            }
        }
        Ok(())
    }
}

/// Run several configurations **interleaved per inference** (the paper runs
/// configurations back-to-back on a dedicated DGX; on this shared 1-core
/// host, interleaving cancels slow host-performance drift so cross-config
/// ratios — the quantities every table reports — stay stable).
pub fn run_interleaved(
    coord: &Coordinator,
    model_name: &str,
    strategies: &[Strategy],
    iterations: usize,
    repetitions: usize,
) -> Result<Vec<RunReport>> {
    let model = coord.load_model(model_name)?;
    let mut all_reports: Vec<Vec<RunReport>> = vec![Vec::new(); strategies.len()];
    for rep in 0..repetitions {
        let stream = RequestStream {
            image_size: coord.manifest.image_size,
            arrivals: crate::workload::Arrivals::ClosedLoop { count: iterations },
            seed: rep as u64 * 1000,
        };
        let inputs = stream.inputs();
        let mut runners = strategies
            .iter()
            .map(|s| Runner::build(coord, &model, *s))
            .collect::<Result<Vec<_>>>()?;
        for input in &inputs {
            for r in runners.iter_mut() {
                r.step(input)?;
            }
        }
        for (i, r) in runners.into_iter().enumerate() {
            all_reports[i].push(RunReport::from_records(&r.label, &r.records)?);
        }
    }
    all_reports.iter().map(|reps| average_reports(reps)).collect()
}

/// Run one configuration (`repetitions` × `iterations`, averaged) —
/// the paper's experimental protocol (Sec. IV-A4).
pub fn run_strategy(
    coord: &Coordinator,
    model_name: &str,
    strategy: Strategy,
    iterations: usize,
    repetitions: usize,
) -> Result<RunReport> {
    Ok(run_interleaved(coord, model_name, &[strategy], iterations, repetitions)?.remove(0))
}

// ---------------------------------------------------------------------------
// Table II — carbon footprint comparison (MobileNetV2)
// ---------------------------------------------------------------------------

pub struct Table2 {
    pub reports: Vec<RunReport>,
}

pub fn table2(coord: &Coordinator, model: &str, iters: usize, reps: usize) -> Result<Table2> {
    let reports = run_interleaved(coord, model, &Strategy::table2_order(), iters, reps)?;
    Ok(Table2 { reports })
}

impl Table2 {
    pub fn render(&self) -> String {
        let base = &self.reports[0];
        let mut t = Table::new(
            "Table II — Carbon footprint comparison (MobileNetV2)",
            &[
                "Configuration",
                "Latency (ms)",
                "Throughput (req/s)",
                "Carbon (gCO2/inf)",
                "Reduction vs Mono",
            ],
        );
        for r in &self.reports {
            let red = if std::ptr::eq(r, base) {
                "-".to_string()
            } else {
                pct(r.reduction_vs(base))
            };
            t.row(vec![
                r.label.clone(),
                f2(r.latency_ms.mean),
                f2(r.throughput_rps),
                f4(r.carbon_per_inf_g),
                red,
            ]);
        }
        t.render()
    }

    pub fn green_reduction(&self) -> f64 {
        self.reports[4].reduction_vs(&self.reports[0])
    }
}

// ---------------------------------------------------------------------------
// Fig. 2 — latency vs carbon-efficiency trade-off
// ---------------------------------------------------------------------------

pub fn fig2_render(t2: &Table2) -> String {
    let mut t = Table::new(
        "Fig. 2 — Latency vs carbon efficiency (series data)",
        &["Configuration", "Latency (ms)", "Carbon efficiency (inf/gCO2)"],
    );
    for r in &t2.reports {
        t.row(vec![r.label.clone(), f2(r.latency_ms.mean), f2(r.carbon_efficiency)]);
    }
    let mut out = t.render();
    out.push_str(&ascii_scatter(
        &t2.reports
            .iter()
            .map(|r| (r.label.clone(), r.latency_ms.mean, r.carbon_efficiency))
            .collect::<Vec<_>>(),
    ));
    out
}

/// Minimal ASCII scatter so the "figure" exists as a figure.
fn ascii_scatter(points: &[(String, f64, f64)]) -> String {
    if points.is_empty() {
        return String::new();
    }
    let (w, h) = (60usize, 14usize);
    let xmin = points.iter().map(|p| p.1).fold(f64::MAX, f64::min);
    let xmax = points.iter().map(|p| p.1).fold(f64::MIN, f64::max);
    let ymin = points.iter().map(|p| p.2).fold(f64::MAX, f64::min);
    let ymax = points.iter().map(|p| p.2).fold(f64::MIN, f64::max);
    let xr = (xmax - xmin).max(1e-9);
    let yr = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![b' '; w]; h];
    for (i, (_, x, y)) in points.iter().enumerate() {
        let cx = (((x - xmin) / xr) * (w - 1) as f64).round() as usize;
        let cy = (((y - ymin) / yr) * (h - 1) as f64).round() as usize;
        grid[h - 1 - cy][cx] = b'A' + (i as u8);
    }
    let mut s = String::new();
    s.push_str(&format!(
        "  carbon efficiency (inf/g): {ymin:.0}..{ymax:.0} (y) vs latency (ms): {xmin:.0}..{xmax:.0} (x)\n"
    ));
    for row in grid {
        s.push_str("  |");
        s.push_str(std::str::from_utf8(&row).unwrap());
        s.push('\n');
    }
    s.push_str("  +");
    s.push_str(&"-".repeat(60));
    s.push('\n');
    for (i, (label, ..)) in points.iter().enumerate() {
        s.push_str(&format!("  {} = {}\n", (b'A' + i as u8) as char, label));
    }
    s
}

// ---------------------------------------------------------------------------
// Table III — comparison with related carbon-aware systems
// ---------------------------------------------------------------------------

pub fn table3_render(green_reduction: f64) -> String {
    let mut t = Table::new(
        "Table III — Comparison with related carbon-aware systems",
        &["System", "Target", "Carbon Reduction"],
    );
    t.row(vec!["GreenScale [35]".into(), "Edge-Cloud".into(), "10-30%".into()]);
    t.row(vec!["DRL Scheduler [17]".into(), "Kubernetes".into(), "up to 24%".into()]);
    t.row(vec!["LLM Edge [16]".into(), "Edge Clusters".into(), "up to 35%".into()]);
    t.row(vec![
        "CarbonEdge (ours)".into(),
        "Edge DL Inference".into(),
        format!("{:.1}% (measured)", green_reduction * 100.0),
    ]);
    t.render()
}

// ---------------------------------------------------------------------------
// Table IV — multi-model comparison
// ---------------------------------------------------------------------------

pub struct Table4Row {
    pub model: String,
    pub mono: RunReport,
    pub green: RunReport,
}

pub fn table4(
    coord: &Coordinator,
    models: &[&str],
    iters: usize,
    reps: usize,
) -> Result<Vec<Table4Row>> {
    models
        .iter()
        .map(|m| {
            let mut rs = run_interleaved(
                coord,
                m,
                &[Strategy::Monolithic, Strategy::CarbonEdge(Mode::Green)],
                iters,
                reps,
            )?;
            let green = rs.pop().unwrap();
            let mono = rs.pop().unwrap();
            Ok(Table4Row { model: m.to_string(), mono, green })
        })
        .collect()
}

pub fn table4_render(rows: &[Table4Row]) -> String {
    let mut t = Table::new(
        "Table IV — Multi-model carbon footprint comparison",
        &["Model", "Mode", "Latency (ms)", "Carbon (gCO2/inf)", "Reduction"],
    );
    for r in rows {
        t.row(vec![
            r.model.clone(),
            "Monolithic".into(),
            f2(r.mono.latency_ms.mean),
            f5(r.mono.carbon_per_inf_g),
            "-".into(),
        ]);
        t.row(vec![
            r.model.clone(),
            "CE-Green".into(),
            f2(r.green.latency_ms.mean),
            f5(r.green.carbon_per_inf_g),
            pct(r.green.reduction_vs(&r.mono)),
        ]);
    }
    t.render()
}

// ---------------------------------------------------------------------------
// Table V — node usage distribution per mode
// ---------------------------------------------------------------------------

pub struct Table5 {
    /// (mode, usage % per node in registry order)
    pub rows: Vec<(String, Vec<f64>)>,
    pub node_names: Vec<String>,
}

pub fn table5(coord: &Coordinator, model: &str, iters: usize) -> Result<Table5> {
    let node_names: Vec<String> = coord.cfg.nodes.iter().map(|n| n.name.clone()).collect();
    let mut rows = Vec::new();
    for mode in Mode::all() {
        let r = run_strategy(coord, model, Strategy::CarbonEdge(mode), iters, 1)?;
        let names: Vec<&str> = node_names.iter().map(String::as_str).collect();
        rows.push((mode.name().to_string(), r.usage_pct(&names)));
    }
    Ok(Table5 { rows, node_names })
}

pub fn table5_render(t5: &Table5) -> String {
    let mut header: Vec<&str> = vec!["Mode"];
    header.extend(t5.node_names.iter().map(String::as_str));
    let mut t = Table::new("Table V — Node usage distribution (% of tasks)", &header);
    for (mode, pcts) in &t5.rows {
        let mut row = vec![mode.clone()];
        row.extend(pcts.iter().map(|p| format!("{p:.0}%")));
        t.row(row);
    }
    t.render()
}

// ---------------------------------------------------------------------------
// Fig. 3 — weight sweep: carbon-latency trade-off, transition at w_C >= 0.5
// ---------------------------------------------------------------------------

pub struct SweepPoint {
    pub w_c: f64,
    pub report: RunReport,
}

pub fn fig3_sweep(
    coord: &Coordinator,
    model_name: &str,
    iters: usize,
    step: f64,
) -> Result<Vec<SweepPoint>> {
    let mut ws = Vec::new();
    let mut w_c: f64 = 0.0;
    while w_c <= 1.0 + 1e-9 {
        ws.push(w_c.min(1.0));
        w_c += step;
    }
    let strategies: Vec<Strategy> = ws.iter().map(|&w| Strategy::Sweep(w)).collect();
    let reports = run_interleaved(coord, model_name, &strategies, iters, 1)?;
    Ok(ws
        .into_iter()
        .zip(reports)
        .map(|(w_c, report)| SweepPoint { w_c, report })
        .collect())
}

pub fn fig3_render(points: &[SweepPoint], mono: &RunReport) -> String {
    let mut t = Table::new(
        "Fig. 3 — Weight sweep: carbon-latency trade-off",
        &["w_C", "Latency (ms)", "Carbon (gCO2/inf)", "Reduction vs Mono", "Dominant node"],
    );
    let mut transition = None;
    for p in points {
        let dominant = p
            .report
            .node_usage
            .iter()
            .max_by_key(|(_, c)| *c)
            .map(|(n, _)| n.clone())
            .unwrap_or_default();
        let red = p.report.reduction_vs(mono);
        if transition.is_none() && red > 0.10 {
            transition = Some(p.w_c);
        }
        t.row(vec![
            format!("{:.2}", p.w_c),
            f2(p.report.latency_ms.mean),
            f4(p.report.carbon_per_inf_g),
            pct(red),
            dominant,
        ]);
    }
    let mut out = t.render();
    if let Some(w) = transition {
        out.push_str(&format!("Transition to green routing at w_C >= {w:.2}\n"));
    }
    out
}

// ---------------------------------------------------------------------------
// Scheduling overhead (Sec. IV-F: 0.03 ms per task)
// ---------------------------------------------------------------------------

pub fn scheduling_overhead(coord: &Coordinator, model: &str, iters: usize) -> Result<Summary> {
    let m = coord.load_model(model)?;
    let mut s = CarbonAwareScheduler::new("green", Mode::Green.weights());
    let stream = RequestStream {
        image_size: coord.manifest.image_size,
        arrivals: crate::workload::Arrivals::ClosedLoop { count: iters },
        seed: 0,
    };
    let run = coord.run_scheduled(&m, &mut s, &stream.inputs())?;
    let ms: Vec<f64> = run.sched_ns.iter().map(|&ns| ns as f64 / 1e6).collect();
    Ok(Summary::of(&ms))
}

// ---------------------------------------------------------------------------
// Virtual-time experiments (the L3.5 simulator — no artifacts required)
// ---------------------------------------------------------------------------

use crate::scheduler::{DeferAwareGreenScheduler, RoundRobinScheduler};
use crate::sim::{scenarios, Scenario, SimReport, Simulation};
use crate::site::RouterSpec;

/// Relative reduction of `new` vs `base` rendered as a percentage — `-`
/// when the base is zero or not finite (a run where nothing completed, or
/// a fully PV/battery-supplied fleet), so comparison tables never print
/// NaN.
fn reduction_pct(new: f64, base: f64) -> String {
    if base > 0.0 && base.is_finite() && new.is_finite() {
        pct(1.0 - new / base)
    } else {
        "-".to_string()
    }
}

/// Run one scheduling mode over a scenario in virtual time.
pub fn sim_run_mode(sc: &Scenario, mode: Mode) -> SimReport {
    let mut s = CarbonAwareScheduler::new(mode.name(), mode.weights());
    Simulation::run(sc, &mut s)
}

/// The Table-II cast at fleet scale: monolithic single-host baseline plus
/// the three CE modes, all over the same arrival process and seed.
pub fn sim_mode_comparison(sc: &Scenario) -> Vec<SimReport> {
    let mono_sc = scenarios::monolithic_of(sc);
    // Round-robin over one node = plain FIFO host execution; no load cutoff,
    // so the baseline completes every request no matter the backlog.
    let mut mono_sched = RoundRobinScheduler::new();
    let mut out = vec![Simulation::run(&mono_sc, &mut mono_sched)];
    for mode in Mode::all() {
        out.push(sim_run_mode(sc, mode));
    }
    out
}

pub fn sim_comparison_render(reports: &[SimReport]) -> String {
    let mut t = Table::new(
        "Virtual fleet — mode comparison",
        &["Scheduler", "Latency (ms)", "p95 (ms)", "Throughput (req/s)", "gCO2/req", "Reduction"],
    );
    let base = reports[0].carbon_per_req_g;
    for (i, r) in reports.iter().enumerate() {
        let red = if i == 0 { "-".to_string() } else { reduction_pct(r.carbon_per_req_g, base) };
        t.row(vec![
            r.scheduler.clone(),
            f2(r.latency_ms.mean),
            f2(r.latency_ms.p95),
            f2(r.throughput_rps),
            format!("{:.6}", r.carbon_per_req_g),
            red,
        ]);
    }
    t.render()
}

/// One point of the virtual weight sweep.
pub struct SimSweepPoint {
    pub w_c: f64,
    pub report: SimReport,
}

/// Fig. 3 transplanted to virtual time: sweep w_C ∈ {0, step, …, 1} over a
/// scenario at fleet scale. Each point reuses the scenario (same arrivals,
/// same seed) with a fresh scheduler.
pub fn sim_weight_sweep(sc: &Scenario, step: f64) -> Vec<SimSweepPoint> {
    assert!(step > 0.0 && step <= 1.0);
    let mut points = Vec::new();
    let mut w_c: f64 = 0.0;
    while w_c <= 1.0 + 1e-9 {
        let w = w_c.min(1.0);
        let mut s = CarbonAwareScheduler::new("sweep", Weights::sweep(w));
        points.push(SimSweepPoint { w_c: w, report: Simulation::run(sc, &mut s) });
        w_c += step;
    }
    points
}

/// In-engine deferral A/B: run `sc` (which should carry a
/// `config.deferral`) in Green mode against an otherwise-identical twin
/// with deferral disabled. Returns `(deferred_run, baseline_run)` — same
/// arrivals, same seed, same fleet; the only difference is whether slack
/// is spent chasing cleaner forecast slots.
pub fn sim_deferral_comparison(sc: &Scenario) -> (SimReport, SimReport) {
    let mut twin = sc.clone();
    twin.name = format!("{}-no-defer", sc.name);
    twin.config.deferral = None;
    (sim_run_mode(sc, Mode::Green), sim_run_mode(&twin, Mode::Green))
}

pub fn sim_deferral_render(deferred: &SimReport, baseline: &SimReport) -> String {
    let mut t = Table::new(
        "In-engine carbon deferral — A/B on the same workload",
        &["Run", "gCO2/req", "Deferred", "Missed", "Latency p95 (ms)", "Makespan (s)"],
    );
    for r in [baseline, deferred] {
        t.row(vec![
            r.scenario.clone(),
            format!("{:.6}", r.carbon_per_req_g),
            r.deferred.to_string(),
            r.deadline_missed.to_string(),
            f2(r.latency_ms.p95),
            f2(r.makespan_s),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "deferral cuts gCO2/req by {}\n",
        reduction_pct(deferred.carbon_per_req_g, baseline.carbon_per_req_g)
    ));
    out
}

/// Joint defer+route vs the legacy route-*then*-defer shape, on the same
/// deferral-carrying scenario: a fresh [`DeferAwareGreenScheduler`] (its
/// verdicts weigh every node's blended forecast and spread releases
/// across the trough plateau) against plain Green mode, which the engine
/// wraps in the [`crate::scheduler::RouteThenDefer`] gate. Same arrivals,
/// same seed, same fleet. Returns `(joint_run, route_then_defer_run)`.
pub fn sim_deferral_routing_comparison(sc: &Scenario) -> (SimReport, SimReport) {
    let d = sc.config.deferral.as_ref().expect("scenario carries no deferral");
    let mut joint = DeferAwareGreenScheduler::new(d.policy.min_gain);
    (Simulation::run(sc, &mut joint), sim_run_mode(sc, Mode::Green))
}

pub fn sim_deferral_routing_render(joint: &SimReport, rtd: &SimReport) -> String {
    let mut t = Table::new(
        "Joint defer+route vs route-then-defer — same workload",
        &["Scheduler", "gCO2/req", "Deferred", "Rejected", "Missed", "Wait p95 (ms)"],
    );
    for r in [rtd, joint] {
        t.row(vec![
            r.scheduler.clone(),
            format!("{:.6}", r.carbon_per_req_g),
            r.deferred.to_string(),
            r.rejected.to_string(),
            r.deadline_missed.to_string(),
            f2(r.wait_ms.p95),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "deciding where+when jointly cuts gCO2/req by {} vs route-then-defer\n",
        reduction_pct(joint.carbon_per_req_g, rtd.carbon_per_req_g),
    ));
    out
}

/// The consolidation experiment idle accounting unlocks: replay the *same*
/// workload (same arrival process, same seed — the `consolidation`
/// scenario derives its rate from a fixed 3-node reference) against a
/// small fleet and a large one, in Green mode. Dynamic energy is nearly
/// identical; every extra node adds an idle floor, so the small fleet
/// emits less. Returns `(small_run, large_run)`.
pub fn sim_consolidation(
    n_small: usize,
    n_large: usize,
    requests: usize,
    seed: u64,
) -> (SimReport, SimReport) {
    assert!(n_small >= 1 && n_large > n_small);
    let small = scenarios::build("consolidation", n_small, requests, seed).unwrap();
    let large = scenarios::build("consolidation", n_large, requests, seed).unwrap();
    (sim_run_mode(&small, Mode::Green), sim_run_mode(&large, Mode::Green))
}

pub fn sim_consolidation_render(small: &SimReport, large: &SimReport) -> String {
    let mut t = Table::new(
        "Consolidation — idle floors vs fleet size (same workload)",
        &["Fleet", "Nodes", "gCO2/req", "Idle kWh", "Dynamic kWh", "Latency p95 (ms)"],
    );
    for r in [small, large] {
        t.row(vec![
            r.scenario.clone(),
            r.nodes.len().to_string(),
            format!("{:.6}", r.carbon_per_req_g),
            format!("{:.6}", r.energy_idle_kwh_total),
            format!("{:.6}", r.energy_dynamic_kwh_total),
            f2(r.latency_ms.p95),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "consolidating onto {} nodes cuts gCO2/req by {} vs {} nodes\n",
        small.nodes.len(),
        reduction_pct(small.carbon_per_req_g, large.carbon_per_req_g),
        large.nodes.len(),
    ));
    out
}

// ---------------------------------------------------------------------------
// Microgrids: PV + battery supply vs grid-only (the L3.5 supply-side A/B)
// ---------------------------------------------------------------------------

/// The experiment local supply unlocks: run `sc` (which should carry
/// microgrids) in Green mode, the identical fleet with every microgrid
/// stripped in Green mode, and the microgrid fleet under carbon-agnostic
/// round-robin. Returns `(mg_green, plain_green, mg_round_robin)` — same
/// arrivals, same seed; the deltas isolate (a) what the local supply is
/// worth and (b) what carbon-aware routing adds on top of it.
pub fn sim_microgrid_comparison(sc: &Scenario) -> (SimReport, SimReport, SimReport) {
    assert!(!sc.microgrids.is_empty(), "scenario carries no microgrids");
    let plain = scenarios::microgrid_disabled_twin(sc);
    let mut rr = RoundRobinScheduler::new();
    (sim_run_mode(sc, Mode::Green), sim_run_mode(&plain, Mode::Green), Simulation::run(sc, &mut rr))
}

/// [`sim_microgrid_comparison`] over the `solar-battery` scenario —
/// `carbonedge sim --scenario solar-battery --compare-microgrid` and
/// `examples/fleet_sim.rs` both land here.
pub fn sim_microgrid(
    nodes: usize,
    requests: usize,
    seed: u64,
) -> (SimReport, SimReport, SimReport) {
    let sc = scenarios::build("solar-battery", nodes, requests, seed).unwrap();
    sim_microgrid_comparison(&sc)
}

pub fn sim_microgrid_render(
    mg_green: &SimReport,
    plain_green: &SimReport,
    mg_rr: &SimReport,
) -> String {
    let mut t = Table::new(
        "Microgrid — PV + battery supply vs grid-only (same workload)",
        &["Run", "Scheduler", "gCO2/req", "PV kWh", "Battery kWh", "Grid kWh", "Latency p95 (ms)"],
    );
    for r in [plain_green, mg_rr, mg_green] {
        t.row(vec![
            r.scenario.clone(),
            r.scheduler.clone(),
            format!("{:.6}", r.carbon_per_req_g),
            format!("{:.6}", r.energy_pv_kwh_total),
            format!("{:.6}", r.energy_battery_kwh_total),
            format!("{:.6}", r.energy_grid_kwh_total),
            f2(r.latency_ms.p95),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "microgrids cut gCO2/req by {} (green mode); carbon-aware routing adds {} over round-robin\n",
        reduction_pct(mg_green.carbon_per_req_g, plain_green.carbon_per_req_g),
        reduction_pct(mg_green.carbon_per_req_g, mg_rr.carbon_per_req_g),
    ));
    out
}

// ---------------------------------------------------------------------------
// Grid-charge arbitrage + SoC-trajectory forecasts (the supply-side A/B/C)
// ---------------------------------------------------------------------------

/// The experiment grid-charge arbitrage and SoC-trajectory forecasting
/// unlock, on an arbitrage-carrying scenario under the joint
/// [`DeferAwareGreenScheduler`]: the scenario as built (charge policy on,
/// trajectory forecasts), the same fleet with grid charging off, and the
/// same fleet with the legacy charge-frozen forecasts. Same arrivals,
/// same seed. Returns `(arbitrage, charge_off, charge_frozen)` — the
/// first margin prices what buying clean night energy is worth, the
/// second what truthful SoC forecasts add on top.
pub fn sim_arbitrage_comparison(sc: &Scenario) -> (SimReport, SimReport, SimReport) {
    assert!(!sc.microgrids.is_empty(), "scenario carries no microgrids");
    let d = sc.config.deferral.as_ref().expect("scenario carries no deferral");
    let min_gain = d.policy.min_gain;
    let off = scenarios::charge_disabled_twin(sc);
    let frozen = scenarios::charge_frozen_twin(sc);
    let run = |s: &Scenario| {
        let mut sched = DeferAwareGreenScheduler::new(min_gain);
        Simulation::run(s, &mut sched)
    };
    (run(sc), run(&off), run(&frozen))
}

/// [`sim_arbitrage_comparison`] over the `arbitrage` scenario —
/// `carbonedge sim --scenario arbitrage --compare-arbitrage` and
/// `examples/fleet_sim.rs` both land here.
pub fn sim_arbitrage(
    nodes: usize,
    requests: usize,
    seed: u64,
) -> (SimReport, SimReport, SimReport) {
    let sc = scenarios::build("arbitrage", nodes, requests, seed).unwrap();
    sim_arbitrage_comparison(&sc)
}

pub fn sim_arbitrage_render(
    arb: &SimReport,
    off: &SimReport,
    frozen: &SimReport,
) -> String {
    let mut t = Table::new(
        "Grid-charge arbitrage + SoC-trajectory forecasts — same workload",
        &[
            "Run",
            "gCO2/req",
            "Grid-charge kWh",
            "Embodied g",
            "Discharged g",
            "Stored g",
            "Deferred",
            "Missed",
        ],
    );
    for r in [off, frozen, arb] {
        t.row(vec![
            r.scenario.clone(),
            format!("{:.6}", r.carbon_per_req_g),
            format!("{:.6}", r.energy_grid_charge_kwh_total),
            f2(r.carbon_charged_g_total),
            f2(r.carbon_battery_g_total),
            f2(r.carbon_stored_g_total),
            r.deferred.to_string(),
            r.deadline_missed.to_string(),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "grid-charge arbitrage cuts gCO2/req by {} vs charge-off; \
         SoC-trajectory forecasts cut {} vs charge-frozen\n",
        reduction_pct(arb.carbon_per_req_g, off.carbon_per_req_g),
        reduction_pct(arb.carbon_per_req_g, frozen.carbon_per_req_g),
    ));
    out
}

// ---------------------------------------------------------------------------
// Batched multi-tenant serving vs one-task-per-slot (the service-model A/B)
// ---------------------------------------------------------------------------

/// The experiment batch formation unlocks, on a batching-carrying
/// scenario in Green mode: the scenario as built (per-`(node, class)`
/// batch queues at the chassis's sub-linear latency/power point) against
/// its [`scenarios::batching_disabled_twin`] (same tenant mix, same
/// arrivals, same seed, one task per service slot). Returns
/// `(batched, unbatched)` — under overload the margin shows up twice,
/// as gCO₂/req *and* as tail latency.
pub fn sim_batching_comparison(sc: &Scenario) -> (SimReport, SimReport) {
    assert!(sc.config.batching.is_some(), "scenario carries no batch spec");
    let twin = scenarios::batching_disabled_twin(sc);
    (sim_run_mode(sc, Mode::Green), sim_run_mode(&twin, Mode::Green))
}

/// [`sim_batching_comparison`] over the `batch-serving` scenario —
/// `carbonedge sim --scenario batch-serving --compare-batching` and
/// `examples/fleet_sim.rs` both land here.
pub fn sim_batching(nodes: usize, requests: usize, seed: u64) -> (SimReport, SimReport) {
    let sc = scenarios::build("batch-serving", nodes, requests, seed).unwrap();
    sim_batching_comparison(&sc)
}

pub fn sim_batching_render(batched: &SimReport, unbatched: &SimReport) -> String {
    let mut t = Table::new(
        "Batched serving vs one-task-per-slot — same tenant mix",
        &["Run", "gCO2/req", "Dynamic kWh", "Batches", "Mean fill", "p99 (ms)", "SLO missed"],
    );
    for r in [unbatched, batched] {
        let (_, slo_missed, _, _) = r.class_sums();
        let batches: u64 = r.classes.iter().map(|c| c.batches).sum();
        let fill = if batches > 0 {
            format!("{:.2}", r.completed as f64 / batches as f64)
        } else {
            "-".into()
        };
        t.row(vec![
            r.scenario.clone(),
            format!("{:.6}", r.carbon_per_req_g),
            format!("{:.6}", r.energy_dynamic_kwh_total),
            batches.to_string(),
            fill,
            f2(r.latency_ms.p99),
            slo_missed.to_string(),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "batch formation cuts gCO2/req by {} vs one-task-per-slot at p99 {} vs {} ms\n",
        reduction_pct(batched.carbon_per_req_g, unbatched.carbon_per_req_g),
        f2(batched.latency_ms.p99),
        f2(unbatched.latency_ms.p99),
    ));
    out
}

// ---------------------------------------------------------------------------
// Cross-site routing: nearest vs carbon-greedy vs deadline-feasible (A/B/C)
// ---------------------------------------------------------------------------

/// The experiment the site layer unlocks, on a geographic scenario under
/// the scheduler it configures (joint defer+route when the scenario
/// carries deferral, Green otherwise): the same fleet, arrivals and seed
/// under each [`crate::site::RouterSpec`] — locality-only `nearest`,
/// `carbon`-greedy, and the `deadline`-feasible carbon router. The first
/// margin prices what cross-site shifting is worth at all; the second,
/// what the feasibility guard saves in missed deadlines while keeping
/// most of the carbon win. Reports come back in that order, each tagged
/// with its router name.
pub fn sim_router_comparison(sc: &Scenario) -> Vec<SimReport> {
    assert!(sc.sites.is_some(), "scenario carries no site layer");
    let run = |s: &Scenario| match &s.config.deferral {
        Some(d) => {
            let mut sched = DeferAwareGreenScheduler::new(d.policy.min_gain);
            Simulation::run(s, &mut sched)
        }
        None => sim_run_mode(s, Mode::Green),
    };
    [RouterSpec::Nearest, RouterSpec::Carbon, RouterSpec::default()]
        .into_iter()
        .map(|spec| {
            let mut twin = sc.clone();
            twin.sites.as_mut().expect("checked above").router = spec;
            run(&twin)
        })
        .collect()
}

/// [`sim_router_comparison`] over the `follow-the-sun` scenario —
/// `carbonedge sim --scenario follow-the-sun --compare-routers` and
/// `examples/fleet_sim.rs` both land here.
pub fn sim_routers(nodes: usize, requests: usize, seed: u64) -> Vec<SimReport> {
    let sc = scenarios::build("follow-the-sun", nodes, requests, seed).unwrap();
    sim_router_comparison(&sc)
}

pub fn sim_router_render(reports: &[SimReport]) -> String {
    let mut t = Table::new(
        "Cross-site routing — same fleet, arrivals and seed",
        &["Router", "gCO2/req", "Shipped", "WAN kWh", "Missed", "Latency p95 (ms)"],
    );
    for r in reports {
        t.row(vec![
            r.router.clone(),
            format!("{:.6}", r.carbon_per_req_g),
            r.wan_shipped.to_string(),
            format!("{:.6}", r.energy_wan_kwh_total),
            r.deadline_missed.to_string(),
            f2(r.latency_ms.p95),
        ]);
    }
    let mut out = t.render();
    if let [nearest, carbon, deadline] = reports {
        out.push_str(&format!(
            "deadline-feasible routing cuts gCO2/req by {} vs nearest \
             and misses {} deadlines vs carbon-greedy's {}\n",
            reduction_pct(deadline.carbon_per_req_g, nearest.carbon_per_req_g),
            deadline.deadline_missed,
            carbon.deadline_missed,
        ));
    }
    out
}

pub fn sim_sweep_render(points: &[SimSweepPoint]) -> String {
    let mut t = Table::new(
        "Virtual weight sweep — carbon/latency trade-off at fleet scale",
        &["w_C", "Latency (ms)", "p95 (ms)", "gCO2/req", "Dominant node"],
    );
    for p in points {
        let dominant = p
            .report
            .nodes
            .iter()
            .max_by_key(|n| n.tasks)
            .map(|n| n.name.clone())
            .unwrap_or_default();
        t.row(vec![
            format!("{:.2}", p.w_c),
            f2(p.report.latency_ms.mean),
            f2(p.report.latency_ms.p95),
            format!("{:.6}", p.report.carbon_per_req_g),
            dominant,
        ]);
    }
    t.render()
}
