//! Simulated container runtime: executes a registered program on behalf of
//! a node, applying the node's latency model and producing the energy /
//! carbon attribution for the task (the role Docker + CodeCarbon play in
//! the paper's testbed).

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::carbon;
use crate::energy::HostPowerModel;
use crate::runtime::{ExecHandle, Tensor};

use super::EdgeNode;

/// Outcome of one task execution on a node.
#[derive(Debug, Clone)]
pub struct ExecutionRecord {
    pub node: String,
    /// Real PJRT execution time.
    pub exec_ms: f64,
    /// Simulated container latency (quota-scaled + overhead).
    pub latency_ms: f64,
    /// Host energy consumed during the task window (J) — CodeCarbon
    /// machine-mode equivalent: full host power over the task duration.
    pub energy_j: f64,
    /// Emissions charged at the node's grid intensity (Eq. 2).
    pub carbon_g: f64,
    pub output: Tensor,
}

/// A container bound to a node: runs programs via the shared executor.
pub struct Container {
    node: Arc<EdgeNode>,
    exec: ExecHandle,
    host: HostPowerModel,
    pue: f64,
    /// Program keys this container runs, in pipeline order
    /// (a single key for monolithic; the stage chain for partitioned).
    programs: Vec<String>,
}

impl Container {
    pub fn new(
        node: Arc<EdgeNode>,
        exec: ExecHandle,
        host: HostPowerModel,
        pue: f64,
        programs: Vec<String>,
    ) -> Container {
        assert!(!programs.is_empty(), "container needs at least one program");
        Container { node, exec, host, pue, programs }
    }

    pub fn node(&self) -> &Arc<EdgeNode> {
        &self.node
    }

    pub fn programs(&self) -> &[String] {
        &self.programs
    }

    /// Run one inference through this container's program chain.
    ///
    /// Energy accounting (DESIGN.md §3): the host runs at full utilization
    /// for the duration of the (simulated) task latency; the task is charged
    /// the full host energy over that window at the node's grid intensity —
    /// this is what CodeCarbon machine-mode measures when configurations are
    /// run one at a time, and it reproduces the paper's Table II magnitudes.
    pub fn infer(&self, input: Tensor) -> Result<ExecutionRecord> {
        self.node.begin_task();
        let result = self.infer_inner(input);
        match &result {
            Ok(rec) => self.node.finish_task(rec.latency_ms, rec.energy_j, rec.carbon_g),
            Err(_) => self.node.finish_task(0.0, 0.0, 0.0),
        }
        result
    }

    fn infer_inner(&self, mut x: Tensor) -> Result<ExecutionRecord> {
        let mut exec = Duration::ZERO;
        for key in &self.programs {
            let (out, dt) = self.exec.execute(key, x)?;
            x = out;
            exec += dt;
        }
        let exec_ms = exec.as_secs_f64() * 1e3;
        let latency_ms = self.node.spec.simulate_latency_ms(exec_ms);
        let energy_j = self.host.power_watts(1.0, 1.0) * latency_ms / 1e3;
        let carbon_g = carbon::emissions_g(
            carbon::joules_to_kwh(energy_j),
            self.node.spec.intensity,
            self.pue,
        );
        Ok(ExecutionRecord {
            node: self.node.spec.name.clone(),
            exec_ms,
            latency_ms,
            energy_j,
            carbon_g,
            output: x,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeSpec;

    #[test]
    fn energy_carbon_formula() {
        // No executor needed: validate the pure accounting math the
        // container applies, using the same formulas.
        let spec = &NodeSpec::paper_nodes()[2]; // node-green, 380 g/kWh
        let host = crate::config::default_host_power();
        // ~9.6 ms of real executor time -> ~266 ms simulated container time.
        let latency_ms = spec.simulate_latency_ms(9.6);
        let energy_j = host.power_watts(1.0, 1.0) * latency_ms / 1e3;
        let carbon_g =
            carbon::emissions_g(carbon::joules_to_kwh(energy_j), spec.intensity, 1.0);
        // ~142W * ~0.27s at 380 g/kWh ≈ 0.004 g — the paper's CE-Green scale.
        assert!(carbon_g > 0.002 && carbon_g < 0.008, "carbon {carbon_g}");
    }
}
