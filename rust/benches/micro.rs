//! Micro-benchmarks of the L3 hot-path building blocks (§Perf-L3 profile):
//! score computation, JSON parsing, partition DP, image synthesis, and the
//! end-to-end per-inference cost split (executor vs bookkeeping).

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::node::NodeRegistry;
use carbonedge::partitioner::balanced_partition;
use carbonedge::scheduler::{score_breakdown, Mode, TaskDemand};
use carbonedge::util::bench::{black_box, Bencher};
use carbonedge::util::json::Json;
use carbonedge::workload::synthetic_image;

fn main() -> anyhow::Result<()> {
    let b = Bencher::default();

    // score computation (Eq. 3 full breakdown, one node)
    let reg = NodeRegistry::paper_setup();
    let task = TaskDemand::default();
    let w = Mode::Green.weights();
    let r = b.run_batched("score_breakdown", 1000, || {
        black_box(score_breakdown(reg.get(0), &task, &w));
    });
    println!("{}", r.report());

    // JSON parse of a manifest-sized document
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        let r = b.run("json_parse_manifest", || {
            black_box(Json::parse(&text).unwrap());
        });
        println!("{}", r.report());
    }

    // partition DP (12 stages into 3 groups)
    let costs: Vec<u64> = (1..=12).map(|i| (i * 37) % 101 + 1).collect();
    let r = b.run_batched("balanced_partition_12x3", 100, || {
        black_box(balanced_partition(&costs, 3));
    });
    println!("{}", r.report());

    // input synthesis (64x64 image)
    let r = b.run("synthetic_image_64", || {
        black_box(synthetic_image(64, 1));
    });
    println!("{}", r.report());

    // end-to-end per-inference split: executor time vs total, and the
    // §Perf-L3 A/B — device-resident weight buffers (hot path) vs
    // literal-per-call re-upload (naive baseline).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let coord = Coordinator::new(Config::default())?;
        let model = coord.load_model("mobilenet_v2")?;
        let exec = coord.exec();
        let input = synthetic_image(coord.manifest.image_size, 0);
        let quick = Bencher::quick();

        exec.register(
            "perf/resident",
            &model.monolithic_path(),
            model.all_weights(),
            true,
        )?;
        exec.execute("perf/resident", input.clone())?; // warmup
        let resident = quick.run("pjrt_execute/resident-weights", || {
            black_box(exec.execute("perf/resident", input.clone()).unwrap());
        });
        println!("{}", resident.report());

        exec.register(
            "perf/literals",
            &model.monolithic_path(),
            model.all_weights(),
            false,
        )?;
        exec.execute("perf/literals", input.clone())?; // warmup
        let literals = quick.run("pjrt_execute/literal-per-call", || {
            black_box(exec.execute("perf/literals", input.clone()).unwrap());
        });
        println!("{}", literals.report());
        println!(
            "resident-weights speedup: {:.2}x (before {:.2} ms -> after {:.2} ms)",
            literals.per_iter.mean / resident.per_iter.mean,
            literals.per_iter.mean * 1e3,
            resident.per_iter.mean * 1e3,
        );

        let stats = exec.stats()?;
        println!(
            "executor stats: {} executions, {:.1} ms device total, {:.1} ms upload total",
            stats.executions,
            stats.exec_time.as_secs_f64() * 1e3,
            stats.upload_time.as_secs_f64() * 1e3,
        );
    }
    Ok(())
}
