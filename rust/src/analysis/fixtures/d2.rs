//! Known-bad fixture: D2 — wall-clock read inside simulator code.
//! Virtual time comes from the event queue, never the host clock.
use std::time::Instant;

/// Timestamp an event with host time (wrong: breaks replay).
pub fn stamp() -> Instant {
    Instant::now()
}
