"""L1 Pallas kernel: global average pool (feeds the classifier head / SE)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_C = 256


def _gap_kernel(x_ref, o_ref):
    o_ref[...] = jnp.mean(x_ref[...], axis=(0, 1))


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("tile_c",))
def avgpool_global(x, *, tile_c: int = TILE_C):
    """Global average pool ``(H, W, C) -> (C,)``."""
    h, w, c = x.shape
    bc = min(tile_c, _pad_to(c, 8))
    cp = _pad_to(c, bc)
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, 0), (0, cp - c)))
    out = pl.pallas_call(
        _gap_kernel,
        out_shape=jax.ShapeDtypeStruct((cp,), jnp.float32),
        grid=(cp // bc,),
        in_specs=[pl.BlockSpec((h, w, bc), lambda k: (0, 0, k))],
        out_specs=pl.BlockSpec((bc,), lambda k: (k,)),
        interpret=True,
    )(xp)
    return out[:c]
