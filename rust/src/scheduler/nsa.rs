//! Algorithm 1: Carbon-Aware Node Selection, behind the `decide` verdict.

use super::{
    score_breakdown_view, CandidateExplain, DecisionExplain, FleetView, Scheduler,
    SchedulingDecision, ScoreBreakdown, TaskDemand, Weights,
};

/// Algorithm 1 line 3: skip nodes with load above this cutoff.
pub const LOAD_CUTOFF: f64 = 0.8;

/// Record of one selection decision (scheduling-behaviour analysis,
/// Table V / Fig. 3).
#[derive(Debug, Clone)]
pub struct SelectionTrace {
    pub chosen: Option<usize>,
    pub breakdowns: Vec<Option<ScoreBreakdown>>,
}

/// The paper's carbon-aware scheduler.
#[derive(Debug, Clone)]
pub struct CarbonAwareScheduler {
    pub weights: Weights,
    name: String,
    /// Keep per-decision traces (used by the behaviour analysis benches;
    /// disabled on the hot path).
    pub trace: bool,
    pub traces: Vec<SelectionTrace>,
}

impl CarbonAwareScheduler {
    pub fn new(name: &str, weights: Weights) -> CarbonAwareScheduler {
        CarbonAwareScheduler { weights, name: name.to_string(), trace: false, traces: Vec::new() }
    }

    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    /// Algorithm 1, lines 1–18, over the fleet snapshot.
    pub fn decide_traced(&self, task: &TaskDemand, fleet: &FleetView) -> SelectionTrace {
        let mut best_score = 0.0;
        let mut best: Option<usize> = None;
        let mut breakdowns = vec![None; fleet.nodes.len()];
        for (i, view) in fleet.nodes.iter().enumerate() {
            // lines 3 + 6: overload / latency / resource filters
            if !view.feasible(task) {
                continue;
            }
            // lines 7–12: component scores + weighted total
            let b = score_breakdown_view(view, task, &self.weights);
            breakdowns[i] = Some(b);
            // lines 13–15: argmax
            if b.total > best_score {
                best_score = b.total;
                best = Some(i);
            }
        }
        SelectionTrace { chosen: best, breakdowns }
    }
}

impl Scheduler for CarbonAwareScheduler {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        let t = self.decide_traced(task, fleet);
        let chosen = t.chosen;
        if self.trace {
            self.traces.push(t);
        }
        SchedulingDecision::from_choice(chosen)
    }

    fn decide_explained(
        &mut self,
        task: &TaskDemand,
        fleet: &FleetView,
        explain: &mut DecisionExplain,
    ) -> SchedulingDecision {
        let t = self.decide_traced(task, fleet);
        explain.candidates = fleet
            .nodes
            .iter()
            .zip(&t.breakdowns)
            .map(|(v, b)| {
                let mut c = CandidateExplain::from_view(v, task);
                c.score = b.as_ref().map(|b| b.total);
                c
            })
            .collect();
        let chosen = t.chosen;
        if self.trace {
            self.traces.push(t);
        }
        SchedulingDecision::from_choice(chosen)
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::{EdgeNode, NodeRegistry, NodeSpec};
    use crate::scheduler::{score_breakdown, Mode};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn reg() -> NodeRegistry {
        NodeRegistry::paper_setup()
    }

    fn sched(mode: Mode) -> CarbonAwareScheduler {
        CarbonAwareScheduler::new(mode.name(), mode.weights())
    }

    /// Decide over a live fleet the way real-time callers do.
    fn pick(
        s: &mut CarbonAwareScheduler,
        task: &TaskDemand,
        nodes: &[Arc<EdgeNode>],
    ) -> Option<usize> {
        s.decide(task, &FleetView::observe(nodes)).assigned()
    }

    #[test]
    fn performance_mode_picks_node_high() {
        let r = reg();
        let mut s = sched(Mode::Performance);
        let i = pick(&mut s, &TaskDemand::default(), r.nodes()).unwrap();
        assert_eq!(r.get(i).spec.name, "node-high");
    }

    #[test]
    fn balanced_mode_behaves_like_performance() {
        // Table V: Balanced also routes to node-high because S_C has
        // limited differentiation vs S_P (Sec. IV-F).
        let r = reg();
        let mut s = sched(Mode::Balanced);
        let i = pick(&mut s, &TaskDemand::default(), r.nodes()).unwrap();
        assert_eq!(r.get(i).spec.name, "node-high");
    }

    #[test]
    fn green_mode_picks_node_green() {
        let r = reg();
        let mut s = sched(Mode::Green);
        let i = pick(&mut s, &TaskDemand::default(), r.nodes()).unwrap();
        assert_eq!(r.get(i).spec.name, "node-green");
    }

    #[test]
    fn selection_sticky_over_repeated_tasks() {
        // Table V: 100% concentration per mode across 50 sequential tasks.
        for (mode, expect) in [
            (Mode::Performance, "node-high"),
            (Mode::Balanced, "node-high"),
            (Mode::Green, "node-green"),
        ] {
            let r = reg();
            let mut s = sched(mode);
            for step in 0..50 {
                let i = pick(&mut s, &TaskDemand::default(), r.nodes()).unwrap();
                let n = r.get(i);
                assert_eq!(n.spec.name, expect, "{mode:?} step {step}");
                // simulate sequential execution: measured latency from the
                // node's latency model over a ~9.6 ms real execution
                // (≈ 265 ms simulated, the paper's regime)
                n.begin_task();
                let lat = n.spec.simulate_latency_ms(9.6);
                n.finish_task(lat, 36.0, 0.005);
            }
        }
    }

    #[test]
    fn overloaded_node_filtered() {
        let r = reg();
        // Saturate node-high's load beyond the 0.8 cutoff.
        {
            let n = r.get(0);
            for _ in 0..200 {
                n.begin_task();
            }
            for _ in 0..200 {
                n.finish_task(10.0, 0.0, 0.0);
                n.begin_task();
            }
        }
        assert!(r.get(0).state().load > LOAD_CUTOFF);
        let mut s = sched(Mode::Performance);
        let i = pick(&mut s, &TaskDemand::default(), r.nodes()).unwrap();
        assert_ne!(r.get(i).spec.name, "node-high");
    }

    #[test]
    fn latency_threshold_filters() {
        let r = reg();
        let task = TaskDemand { latency_threshold_ms: 300.0, ..TaskDemand::default() };
        // priors: high 250 (ok), medium 417, green 625 (filtered)
        let mut s = sched(Mode::Green);
        let i = pick(&mut s, &task, r.nodes()).unwrap();
        assert_eq!(r.get(i).spec.name, "node-high");
    }

    #[test]
    fn insufficient_resources_rejected() {
        let r = reg();
        // 800 MB fits only node-high (1024 MB).
        let task = TaskDemand { mem_mb: 800, ..TaskDemand::default() };
        let mut s = sched(Mode::Green);
        let i = pick(&mut s, &task, r.nodes()).unwrap();
        assert_eq!(r.get(i).spec.name, "node-high");
        // 2 GB fits nothing: an explicit Reject verdict, not a panic.
        let task = TaskDemand { mem_mb: 2048, ..TaskDemand::default() };
        assert_eq!(
            s.decide(&task, &FleetView::observe(r.nodes())),
            SchedulingDecision::reject()
        );
        assert!(!s.defers(), "plain NSA never defers");
    }

    #[test]
    fn trace_records_breakdowns() {
        let r = reg();
        let mut s = sched(Mode::Green).with_trace();
        s.decide(&TaskDemand::default(), &FleetView::observe(r.nodes()));
        assert_eq!(s.traces.len(), 1);
        let t = &s.traces[0];
        assert!(t.breakdowns.iter().all(Option::is_some));
        assert_eq!(t.chosen, Some(2));
    }

    // ---------------- property tests (DESIGN.md §5) ----------------

    fn random_nodes(rng: &mut Rng) -> Vec<Arc<EdgeNode>> {
        let n = 1 + rng.below(6);
        (0..n)
            .map(|i| {
                EdgeNode::new(NodeSpec {
                    name: format!("n{i}"),
                    cpu_quota: rng.range(0.1, 2.0),
                    mem_mb: 128 + rng.below(2048),
                    intensity: rng.range(40.0, 900.0),
                    rated_power_w: rng.range(5.0, 400.0),
                    idle_w: 0.0,
                    prior_ms: rng.range(10.0, 2000.0),
                    alpha: rng.range(0.0, 1.0),
                    overhead_ms: rng.range(0.0, 10.0),
                    time_scale: rng.range(1.0, 30.0),
                    adaptive: rng.f64() < 0.5,
                })
            })
            .collect()
    }

    #[test]
    fn prop_chosen_node_is_feasible() {
        check(
            "chosen node satisfies the Algorithm-1 filters",
            300,
            |rng| {
                let nodes = random_nodes(rng);
                let task = TaskDemand {
                    cpu: rng.range(0.05, 1.0),
                    mem_mb: 64 + rng.below(1024),
                    latency_threshold_ms: rng.range(100.0, 3000.0),
                    class: 0,
                };
                (nodes, task)
            },
            |(nodes, task)| {
                let mut s = CarbonAwareScheduler::new("t", Mode::Green.weights());
                if let Some(i) = pick(&mut s, task, nodes) {
                    let n = &nodes[i];
                    if !n.fits(task.mem_mb, task.cpu) {
                        return Err("chose node without resources".into());
                    }
                    if n.avg_ms() > task.latency_threshold_ms {
                        return Err("chose node above latency threshold".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_greener_node_wins_at_full_carbon_weight() {
        // With w = (0,0,0,0,1) and all else equal, strictly lower intensity
        // must win (Eq. 4 monotonicity).
        check(
            "w_C=1 prefers lower intensity, ceteris paribus",
            200,
            |rng| {
                let i1 = rng.range(50.0, 800.0);
                let i2 = rng.range(50.0, 800.0);
                (i1, i2)
            },
            |&(i1, i2)| {
                if (i1 - i2).abs() < 1.0 {
                    return Ok(());
                }
                let mk = |name: &str, intensity: f64| {
                    EdgeNode::new(NodeSpec {
                        name: name.into(),
                        cpu_quota: 1.0,
                        mem_mb: 1024,
                        intensity,
                        rated_power_w: 100.0,
                        idle_w: 0.0,
                        prior_ms: 300.0,
                        alpha: 0.0,
                        overhead_ms: 0.0,
                        time_scale: 1.0,
                        adaptive: false,
                    })
                };
                let nodes = vec![mk("a", i1), mk("b", i2)];
                let w = Weights { r: 0.0, l: 0.0, p: 0.0, b: 0.0, c: 1.0 };
                let mut s = CarbonAwareScheduler::new("t", w);
                let chosen = pick(&mut s, &TaskDemand::default(), &nodes).unwrap();
                let want = if i1 < i2 { 0 } else { 1 };
                if chosen == want {
                    Ok(())
                } else {
                    Err(format!("chose {chosen}, wanted {want} (i1={i1}, i2={i2})"))
                }
            },
        );
    }

    #[test]
    fn prop_node_order_irrelevant() {
        // Shuffling the node list must not change *which node* wins
        // (identity, not index).
        check(
            "permutation stability",
            100,
            |rng| {
                let nodes = random_nodes(rng);
                let seed = rng.next_u64();
                (nodes, seed)
            },
            |(nodes, seed)| {
                let task = TaskDemand::default();
                let mut s = CarbonAwareScheduler::new("t", Mode::Balanced.weights());
                let a = pick(&mut s, &task, nodes).map(|i| nodes[i].spec.name.clone());
                let mut shuffled: Vec<_> = nodes.clone();
                Rng::new(*seed).shuffle(&mut shuffled);
                let b = pick(&mut s, &task, &shuffled).map(|i| shuffled[i].spec.name.clone());
                // Ties may break differently; accept equal-score swaps by
                // comparing scores instead of names when names differ.
                if a == b {
                    return Ok(());
                }
                let score = |name: &Option<String>, list: &[Arc<EdgeNode>]| {
                    name.as_ref().and_then(|nm| {
                        list.iter()
                            .find(|n| &n.spec.name == nm)
                            .map(|n| score_breakdown(n, &task, &Mode::Balanced.weights()).total)
                    })
                };
                let sa = score(&a, nodes);
                let sb = score(&b, nodes);
                match (sa, sb) {
                    (Some(x), Some(y)) if (x - y).abs() < 1e-12 => Ok(()),
                    _ => Err(format!("order changed winner: {a:?} vs {b:?}")),
                }
            },
        );
    }
}
