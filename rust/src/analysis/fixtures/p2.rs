//! Known-bad fixture: P2 — release assert outside validate().
//! The invariant was already guaranteed by a validate() one-shot.

/// Price energy, re-checking an invariant on every call.
pub fn price(energy_kwh: f64, intensity: f64) -> f64 {
    let rate = intensity;
    assert!(energy_kwh >= 0.0);
    energy_kwh * rate
}
