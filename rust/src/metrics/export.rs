//! Sustainability-report export (paper Sec. V-B: "organizations can use
//! the framework to report carbon emissions for sustainability
//! compliance"): serialize run reports to JSON.

use crate::util::json::{arr, num, obj, s, Json};

use super::RunReport;

/// JSON document for one run report.
pub fn report_to_json(r: &RunReport) -> Json {
    obj(vec![
        ("label", s(&r.label)),
        ("inferences", num(r.inferences as f64)),
        (
            "latency_ms",
            obj(vec![
                ("mean", num(r.latency_ms.mean)),
                ("p50", num(r.latency_ms.p50)),
                ("p95", num(r.latency_ms.p95)),
                ("ci95", num(r.latency_ms.ci95())),
            ]),
        ),
        ("throughput_rps", num(r.throughput_rps)),
        ("energy_kwh", num(r.energy_kwh)),
        ("carbon_per_inf_g", num(r.carbon_per_inf_g)),
        ("carbon_total_g", num(r.carbon_total_g)),
        ("carbon_efficiency_inf_per_g", num(r.carbon_efficiency)),
        (
            "node_usage",
            arr(r.node_usage
                .iter()
                .map(|(n, c)| obj(vec![("node", s(n)), ("tasks", num(*c as f64))]))
                .collect()),
        ),
    ])
}

/// Finite number → `Json::Num`, anything else (NaN/±inf from a degenerate
/// run — zero completions, zero-carbon denominators) → `Json::Null`, so
/// the export is always valid RFC 8259 JSON.
fn fnum(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// JSON document for one virtual-time simulation report (the L3.5
/// counterpart of [`report_to_json`]) — same compliance pipeline, fed by
/// the fleet simulator instead of real execution. Derived rates/ratios go
/// through [`fnum`]: a run where nothing completed serializes them as
/// `0`/`null`, never as bare `NaN` (which is not JSON).
pub fn sim_report_to_json(r: &crate::sim::SimReport) -> Json {
    obj(vec![
        ("scenario", s(&r.scenario)),
        ("scheduler", s(&r.scheduler)),
        ("seed", num(r.seed as f64)),
        ("requests", num(r.requests as f64)),
        ("completed", num(r.completed as f64)),
        ("rejected", num(r.rejected as f64)),
        ("migrated", num(r.migrated as f64)),
        ("deferred", num(r.deferred as f64)),
        ("deadline_missed", num(r.deadline_missed as f64)),
        ("makespan_s", fnum(r.makespan_s)),
        ("throughput_rps", fnum(r.throughput_rps)),
        (
            "latency_ms",
            obj(vec![
                ("mean", fnum(r.latency_ms.mean)),
                ("p50", fnum(r.latency_ms.p50)),
                ("p95", fnum(r.latency_ms.p95)),
            ]),
        ),
        ("wait_ms_mean", fnum(r.wait_ms.mean)),
        ("energy_kwh", fnum(r.energy_kwh_total)),
        ("energy_dynamic_kwh", fnum(r.energy_dynamic_kwh_total)),
        ("energy_idle_kwh", fnum(r.energy_idle_kwh_total)),
        ("energy_pv_kwh", fnum(r.energy_pv_kwh_total)),
        ("energy_battery_kwh", fnum(r.energy_battery_kwh_total)),
        ("energy_grid_kwh", fnum(r.energy_grid_kwh_total)),
        ("energy_grid_charge_kwh", fnum(r.energy_grid_charge_kwh_total)),
        ("carbon_charged_g", fnum(r.carbon_charged_g_total)),
        ("carbon_battery_g", fnum(r.carbon_battery_g_total)),
        ("carbon_stored_g", fnum(r.carbon_stored_g_total)),
        ("carbon_total_g", fnum(r.carbon_g_total)),
        ("carbon_dynamic_g", fnum(r.carbon_dynamic_g_total)),
        ("carbon_idle_g", fnum(r.carbon_idle_g_total)),
        ("carbon_per_req_g", fnum(r.carbon_per_req_g)),
        (
            "nodes",
            arr(r.nodes
                .iter()
                .map(|n| {
                    obj(vec![
                        ("node", s(&n.name)),
                        ("tasks", num(n.tasks as f64)),
                        ("busy_ms", fnum(n.busy_ms)),
                        ("uptime_s", fnum(n.uptime_s)),
                        ("queue_delay_ms_p50", fnum(n.queue_delay_ms_p50)),
                        ("queue_delay_ms_max", fnum(n.queue_delay_ms_max)),
                        ("energy_kwh", fnum(n.energy_kwh())),
                        ("energy_dynamic_kwh", fnum(n.energy_dynamic_kwh)),
                        ("energy_idle_kwh", fnum(n.energy_idle_kwh)),
                        ("carbon_g", fnum(n.carbon_g())),
                        ("carbon_dynamic_g", fnum(n.carbon_dynamic_g)),
                        ("carbon_idle_g", fnum(n.carbon_idle_g)),
                        ("microgrid", Json::Bool(n.microgrid)),
                        ("energy_pv_kwh", fnum(n.energy_pv_kwh)),
                        ("energy_battery_kwh", fnum(n.energy_battery_kwh)),
                        ("energy_grid_kwh", fnum(n.energy_grid_kwh)),
                        ("energy_grid_charge_kwh", fnum(n.energy_grid_charge_kwh)),
                        ("carbon_charged_g", fnum(n.carbon_charged_g)),
                        ("carbon_battery_g", fnum(n.carbon_battery_g)),
                        ("carbon_stored_g", fnum(n.carbon_stored_g)),
                        (
                            "soc_timeline",
                            arr(n.soc_timeline
                                .iter()
                                .map(|&(t, soc)| arr(vec![fnum(t), fnum(soc)]))
                                .collect()),
                        ),
                        (
                            "soc_projection",
                            arr(n.soc_projection
                                .iter()
                                .map(|&(t, soc)| arr(vec![fnum(t), fnum(soc)]))
                                .collect()),
                        ),
                    ])
                })
                .collect()),
        ),
    ])
}

/// A compliance document over several runs (e.g. one per mode).
pub fn compliance_document(title: &str, reports: &[RunReport]) -> Json {
    obj(vec![
        ("title", s(title)),
        ("framework", s("CarbonEdge")),
        ("runs", arr(reports.iter().map(report_to_json).collect())),
        (
            "total_carbon_g",
            num(reports.iter().map(|r| r.carbon_total_g).sum()),
        ),
        (
            "total_inferences",
            num(reports.iter().map(|r| r.inferences).sum::<u64>() as f64),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::ExecutionRecord;
    use crate::runtime::Tensor;

    fn report() -> RunReport {
        let recs: Vec<ExecutionRecord> = (0..3)
            .map(|_| ExecutionRecord {
                node: "node-green".into(),
                exec_ms: 9.0,
                latency_ms: 200.0,
                energy_j: 30.0,
                carbon_g: 0.003,
                output: Tensor::zeros(vec![1]),
            })
            .collect();
        RunReport::from_records("test", &recs)
    }

    #[test]
    fn roundtrips_through_parser() {
        let j = report_to_json(&report());
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_str("label").unwrap(), "test");
        assert_eq!(back.req_usize("inferences").unwrap(), 3);
        assert!((back.req_f64("carbon_per_inf_g").unwrap() - 0.003).abs() < 1e-12);
        assert_eq!(back.path(&["latency_ms"]).unwrap().req_f64("mean").unwrap(), 200.0);
    }

    #[test]
    fn sim_report_roundtrips_through_parser() {
        let sc = crate::sim::scenarios::build("paper-3-node", 0, 20, 1).unwrap();
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let back = Json::parse(&sim_report_to_json(&r).to_string()).unwrap();
        assert_eq!(back.req_str("scenario").unwrap(), "paper-3-node");
        assert_eq!(back.req_str("scheduler").unwrap(), "green");
        assert_eq!(back.req_usize("requests").unwrap(), 20);
        assert_eq!(back.req_arr("nodes").unwrap().len(), 3);
        assert!(back.req_f64("carbon_total_g").unwrap() > 0.0);
        // Two-part energy split + deferral counters survive the roundtrip.
        assert_eq!(back.req_usize("deferred").unwrap(), 0);
        assert_eq!(back.req_usize("deadline_missed").unwrap(), 0);
        assert_eq!(back.req_f64("energy_idle_kwh").unwrap(), 0.0); // paper nodes: no floor
        let total = back.req_f64("energy_kwh").unwrap();
        let dynamic = back.req_f64("energy_dynamic_kwh").unwrap();
        assert!((total - dynamic).abs() < 1e-15);
        let node0 = &back.req_arr("nodes").unwrap()[0];
        assert!(node0.req_f64("uptime_s").unwrap() > 0.0);
        assert!(node0.req_f64("carbon_idle_g").unwrap() == 0.0);
        // Queue-delay estimates ride along per node.
        assert!(node0.req_f64("queue_delay_ms_p50").unwrap() >= 0.0);
        assert!(
            node0.req_f64("queue_delay_ms_max").unwrap()
                >= node0.req_f64("queue_delay_ms_p50").unwrap()
        );
    }

    #[test]
    fn sim_report_json_carries_idle_split() {
        let sc = crate::sim::scenarios::build("consolidation", 3, 50, 2).unwrap();
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let back = Json::parse(&sim_report_to_json(&r).to_string()).unwrap();
        let idle = back.req_f64("energy_idle_kwh").unwrap();
        let dynamic = back.req_f64("energy_dynamic_kwh").unwrap();
        let total = back.req_f64("energy_kwh").unwrap();
        assert!(idle > 0.0, "consolidation nodes carry an idle floor");
        assert!((idle + dynamic - total).abs() <= 1e-12 * total);
        assert!(back.req_f64("carbon_idle_g").unwrap() > 0.0);
    }

    #[test]
    fn sim_report_json_carries_microgrid_supply_split() {
        let sc = crate::sim::scenarios::build("solar-battery", 2, 60, 3).unwrap();
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let text = sim_report_to_json(&r).to_string();
        let back = Json::parse(&text).unwrap();
        let pv = back.req_f64("energy_pv_kwh").unwrap();
        let batt = back.req_f64("energy_battery_kwh").unwrap();
        let grid = back.req_f64("energy_grid_kwh").unwrap();
        let total = back.req_f64("energy_kwh").unwrap();
        assert!(pv > 0.0, "a day of solar-battery must use PV");
        assert!((pv + batt + grid - total).abs() <= 1e-9 * total);
        let node0 = &back.req_arr("nodes").unwrap()[0];
        assert_eq!(node0.get("microgrid").unwrap().as_bool(), Some(true));
        let soc = node0.req_arr("soc_timeline").unwrap();
        assert!(soc.len() >= 2, "SoC timeline missing");
        for sample in soc {
            let pair = sample.as_arr().unwrap();
            let frac = pair[1].as_f64().unwrap();
            assert!((0.0..=1.0 + 1e-9).contains(&frac), "SoC {frac} out of range");
        }
    }

    #[test]
    fn sim_report_json_carries_stored_carbon_ledger() {
        // The arbitrage scenario grid-charges overnight: the export must
        // carry the charge-source split and a balanced stored ledger.
        let sc = crate::sim::scenarios::build("arbitrage", 2, 600, 3).unwrap();
        let mut sched = crate::scheduler::DeferAwareGreenScheduler::new(0.05);
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        let back = Json::parse(&sim_report_to_json(&r).to_string()).unwrap();
        let charged = back.req_f64("carbon_charged_g").unwrap();
        let spent = back.req_f64("carbon_battery_g").unwrap();
        let stored = back.req_f64("carbon_stored_g").unwrap();
        assert!(back.req_f64("energy_grid_charge_kwh").unwrap() > 0.0);
        assert!(charged > 0.0, "overnight window must import");
        assert!(
            (charged - spent - stored).abs() <= 1e-6 * charged,
            "ledger unbalanced: {charged} vs {spent} + {stored}"
        );
        let node0 = &back.req_arr("nodes").unwrap()[0];
        assert!(node0.req_f64("carbon_charged_g").unwrap() >= 0.0);
        // Projected-vs-actual SoC rides along (trajectory forecasts on).
        assert!(!node0.req_arr("soc_projection").unwrap().is_empty());
        assert!(!node0.req_arr("soc_timeline").unwrap().is_empty());
    }

    #[test]
    fn sim_report_json_zero_completions_never_emits_nan() {
        // A demand no node can fit: every request is rejected, all the
        // derived rates hit their zero-completion guards, and the export
        // stays valid JSON (0/null, never NaN).
        let mut sc = crate::sim::scenarios::build("paper-3-node", 0, 50, 1).unwrap();
        sc.config.demand = crate::scheduler::TaskDemand {
            cpu: 64.0,
            mem_mb: 1 << 20,
            latency_threshold_ms: 5_000.0,
        };
        let mut sched = crate::scheduler::CarbonAwareScheduler::new(
            "green",
            crate::scheduler::Mode::Green.weights(),
        );
        let r = crate::sim::Simulation::run(&sc, &mut sched);
        assert_eq!(r.completed, 0);
        assert_eq!(r.rejected, 50);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.carbon_per_req_g, 0.0);
        let text = sim_report_to_json(&r).to_string();
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_usize("completed").unwrap(), 0);
        assert_eq!(back.req_f64("carbon_per_req_g").unwrap(), 0.0);
    }

    #[test]
    fn compliance_totals() {
        let doc = compliance_document("Q3", &[report(), report()]);
        assert_eq!(doc.req_usize("total_inferences").unwrap(), 6);
        assert!((doc.req_f64("total_carbon_g").unwrap() - 0.018).abs() < 1e-12);
        assert_eq!(doc.req_arr("runs").unwrap().len(), 2);
    }
}
