//! Ablation benches for the design choices DESIGN.md calls out:
//!  1. scheduler baselines (AMP4EC / round-robin / random / least-loaded
//!     vs CE-Green) — what carbon awareness alone buys;
//!  2. energy apportioning mode (quota-proportional vs active-attribution);
//!  3. temporal intensity traces (diurnal grid) vs the paper's static
//!     scenarios — the future-work extension;
//!  4. task-level routing vs cross-node green pipeline.

use carbonedge::carbon::IntensityTrace;
use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::energy::{ApportionMode, Apportioner};
use carbonedge::metrics::RunReport;
use carbonedge::scheduler::{
    Amp4ecScheduler, CarbonAwareScheduler, ConstrainedGreenScheduler, LeastLoadedScheduler, Mode,
    NormalizedScheduler, RandomScheduler, RoundRobinScheduler, Scheduler,
};
use carbonedge::util::table::{f2, f4, Table};
use carbonedge::workload::RequestStream;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("run `make artifacts` first");
        return Ok(());
    }
    let coord = Coordinator::new(Config::default())?;
    let model = coord.load_model("mobilenet_v2")?;
    let stream = RequestStream {
        image_size: coord.manifest.image_size,
        arrivals: carbonedge::workload::Arrivals::ClosedLoop { count: 25 },
        seed: 0,
    };
    let inputs = stream.inputs();

    // --- 1. scheduler ablation -------------------------------------------
    let mut t = Table::new(
        "Ablation 1 — scheduler policies (25 inferences, MobileNetV2)",
        &["Scheduler", "Latency (ms)", "gCO2/inf", "inf/gCO2", "node mix"],
    );
    let mut scheds: Vec<Box<dyn Scheduler>> = vec![
        Box::new(Amp4ecScheduler::new()),
        Box::new(RoundRobinScheduler::new()),
        Box::new(RandomScheduler::new(11)),
        Box::new(LeastLoadedScheduler),
        Box::new(CarbonAwareScheduler::new("ce-green", Mode::Green.weights())),
        // Sec. V-A future-work variants: min-max normalized Balanced
        // (does differentiate on carbon) and constraint-based green.
        Box::new(NormalizedScheduler::new("balanced-normalized", Mode::Balanced.weights())),
        Box::new(ConstrainedGreenScheduler::new(1.15)),
    ];
    for s in scheds.iter_mut() {
        let run = coord.run_scheduled(&model, s.as_mut(), &inputs)?;
        let r = RunReport::from_records(s.name(), &run.records)?;
        let mix: Vec<String> = r.node_usage.iter().map(|(n, c)| format!("{n}:{c}")).collect();
        t.row(vec![
            r.label.clone(),
            f2(r.latency_ms.mean),
            f4(r.carbon_per_inf_g),
            f2(r.carbon_efficiency),
            mix.join(" "),
        ]);
    }
    println!("{}", t.render());

    // --- 2. apportioning mode ---------------------------------------------
    let quotas: Vec<(&str, f64)> = coord
        .cfg
        .nodes
        .iter()
        .map(|n| (n.name.as_str(), n.cpu_quota))
        .collect();
    let mut t = Table::new(
        "Ablation 2 — host-energy apportioning (100 J idle + 50 J dynamic window, node-green active)",
        &["Mode", "node-high (J)", "node-medium (J)", "node-green (J)"],
    );
    for mode in [ApportionMode::QuotaProportional, ApportionMode::ActiveAttribution] {
        let a = Apportioner::new(mode, &quotas);
        let out = a.attribute(100.0, 50.0, Some("node-green"));
        t.row(vec![
            format!("{mode:?}"),
            f2(out["node-high"]),
            f2(out["node-medium"]),
            f2(out["node-green"]),
        ]);
    }
    println!("{}", t.render());

    // --- 3. temporal intensity (future-work extension) ---------------------
    let diurnal =
        IntensityTrace::Diurnal { mean: 530.0, amplitude: 180.0, period_s: 86_400.0, phase_s: 0.0 };
    let mut t = Table::new(
        "Ablation 3 — static vs diurnal grid intensity (carbon of a 36 J inference at different times)",
        &["time of day", "intensity (g/kWh)", "gCO2/inf (static 530)", "gCO2/inf (diurnal)"],
    );
    for (label, tsec) in
        [("00:00", 0.0), ("06:00", 21_600.0), ("12:00", 43_200.0), ("18:00", 64_800.0)]
    {
        let kwh = carbonedge::carbon::joules_to_kwh(36.0);
        t.row(vec![
            label.to_string(),
            f2(diurnal.at(tsec)),
            f4(carbonedge::carbon::emissions_g(kwh, 530.0, 1.0)),
            f4(carbonedge::carbon::emissions_g(kwh, diurnal.at(tsec), 1.0)),
        ]);
    }
    println!("{}", t.render());

    // --- 4. task-level vs pipeline ------------------------------------------
    let mut t = Table::new(
        "Ablation 4 — task-level routing vs cross-node green pipeline",
        &["Execution", "Latency (ms)", "gCO2/inf", "route"],
    );
    let mut green = CarbonAwareScheduler::new("green", Mode::Green.weights());
    let run = coord.run_scheduled(&model, &mut green, &inputs)?;
    let r = RunReport::from_records("task-level (CE-Green)", &run.records)?;
    t.row(vec![
        r.label.clone(),
        f2(r.latency_ms.mean),
        f4(r.carbon_per_inf_g),
        "single node".into(),
    ]);
    let recs = coord.run_pipeline(&model, 0.5, &inputs, 4.0)?;
    let rp = RunReport::from_records("green pipeline (w=0.5)", &recs)?;
    t.row(vec![
        rp.label.clone(),
        f2(rp.latency_ms.mean),
        f4(rp.carbon_per_inf_g),
        recs[0].node.clone(),
    ]);
    println!("{}", t.render());
    Ok(())
}
