//! Eq. 5 layer cost model.
//!
//! ```text
//! Cost(l) = kh·kw·Cin·Cout   Conv2D
//!         | Nin·Nout          Linear
//!         | params_count      others
//! ```
//!
//! The Python side already materializes these per layer into the manifest;
//! this module recomputes them from layer descriptors (so Rust owns the
//! model-analysis path too) and cross-checks against the manifest in the
//! integration tests.

use crate::model::LayerEntry;

/// Eq. 5 over a manifest layer record.
///
/// For `conv2d` and `linear` layers aot.py stores the Eq. 5 value in
/// `cost`; for every other kind the cost is the parameter count. This
/// function re-derives the "others" branch so a manifest with a missing /
/// stale cost field still partitions correctly.
pub fn layer_cost(layer: &LayerEntry) -> usize {
    match layer.kind.as_str() {
        "conv2d" | "linear" => layer.cost,
        _ => layer.params,
    }
}

/// Aggregated per-stage cost view of a model.
#[derive(Debug, Clone)]
pub struct CostProfile {
    /// Eq. 5 cost per stage.
    pub stage_costs: Vec<u64>,
    /// Activation elements leaving each stage (communication cost proxy).
    pub boundary_elems: Vec<u64>,
    pub total: u64,
}

/// Build the stage-level cost profile the partitioner consumes.
pub fn model_cost_profile(entry: &crate::model::ModelEntry) -> CostProfile {
    let mut stage_costs = vec![0u64; entry.stages.len()];
    for l in &entry.layers {
        stage_costs[l.stage] += layer_cost(l) as u64;
    }
    let boundary_elems =
        entry.stages.iter().map(|s| s.boundary_elems() as u64).collect::<Vec<_>>();
    let total = stage_costs.iter().sum();
    CostProfile { stage_costs, boundary_elems, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerEntry;

    fn layer(kind: &str, cost: usize, params: usize, stage: usize) -> LayerEntry {
        LayerEntry {
            name: format!("{kind}_{stage}"),
            kind: kind.into(),
            stage,
            params,
            cost,
            flops: 0,
        }
    }

    #[test]
    fn eq5_branches() {
        // conv2d / linear use the declared Eq. 5 cost...
        assert_eq!(layer_cost(&layer("conv2d", 1152, 1168, 0)), 1152);
        assert_eq!(layer_cost(&layer("linear", 1000, 2000, 0)), 1000);
        // ...everything else falls back to params_count.
        assert_eq!(layer_cost(&layer("depthwise", 0, 80, 0)), 80);
        assert_eq!(layer_cost(&layer("pool", 77, 0, 0)), 0);
        assert_eq!(layer_cost(&layer("add", 99, 0, 0)), 0);
    }
}
