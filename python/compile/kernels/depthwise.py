"""L1 Pallas kernel: depthwise 3x3 convolution (SAME padding, stride 1/2).

The second hot op of the MobileNet-family models. TPU mapping: the grid
tiles the channel axis; each program holds a (Hp, Wp, bc) spatial slab in
VMEM and produces the full output plane for its channel block as nine
shifted multiply-accumulates — a vector (VPU) op, not an MXU op, exactly as
a depthwise conv maps on TPU. Bias + activation are fused in the epilogue.

Runs under ``interpret=True`` on this image (see matmul.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import apply_act

TILE_C = 128


def same_pad(size: int, k: int, stride: int) -> tuple[int, int, int]:
    """TF-style SAME padding. Returns (out_size, pad_lo, pad_hi)."""
    out = -(-size // stride)  # ceil
    total = max((out - 1) * stride + k - size, 0)
    lo = total // 2
    return out, lo, total - lo


def _dw_kernel(x_ref, w_ref, b_ref, o_ref, *, stride: int, act: str, ho: int, wo: int):
    x = x_ref[...]
    w = w_ref[...]
    c = x.shape[-1]
    acc = jnp.zeros((ho, wo, c), jnp.float32)
    # Nine shifted MACs over the VMEM-resident slab; strided slices express
    # the stride without gather traffic.
    for di in range(3):
        for dj in range(3):
            xs = jax.lax.slice(
                x,
                (di, dj, 0),
                (di + (ho - 1) * stride + 1, dj + (wo - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            acc = acc + xs * w[di, dj][None, None, :]
    acc = acc + b_ref[...][None, None, :]
    o_ref[...] = apply_act(acc, act).astype(o_ref.dtype)


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("stride", "act", "tile_c"))
def depthwise3x3(x, w, b, stride: int = 1, act: str = "none", *, tile_c: int = TILE_C):
    """Depthwise 3x3 conv, SAME padding.

    Args:
      x: ``(H, W, C)``.
      w: ``(3, 3, C)`` per-channel filters.
      b: ``(C,)`` bias.
      stride: 1 or 2.

    Returns:
      ``(Ho, Wo, C)`` float32, ``Ho = ceil(H/stride)``.
    """
    assert stride in (1, 2), stride
    h, wdt, c = x.shape
    assert w.shape == (3, 3, c), (w.shape, c)
    assert b.shape == (c,), (b.shape, c)

    ho, plo_h, phi_h = same_pad(h, 3, stride)
    wo, plo_w, phi_w = same_pad(wdt, 3, stride)

    bc = min(tile_c, _pad_to(c, 8))
    cp = _pad_to(c, bc)

    xp = jnp.pad(x.astype(jnp.float32), ((plo_h, phi_h), (plo_w, phi_w), (0, cp - c)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, 0), (0, cp - c)))
    bp = jnp.pad(b.astype(jnp.float32), ((0, cp - c),))
    hp, wp_ = xp.shape[0], xp.shape[1]

    out = pl.pallas_call(
        functools.partial(_dw_kernel, stride=stride, act=act, ho=ho, wo=wo),
        out_shape=jax.ShapeDtypeStruct((ho, wo, cp), jnp.float32),
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((hp, wp_, bc), lambda k: (0, 0, k)),
            pl.BlockSpec((3, 3, bc), lambda k: (0, 0, k)),
            pl.BlockSpec((bc,), lambda k: (k,)),
        ],
        out_specs=pl.BlockSpec((ho, wo, bc), lambda k: (0, 0, k)),
        interpret=True,
    )(xp, wp, bp)
    return out[:, :, :c]


def vmem_bytes(h: int, w: int, c: int, stride: int = 1, tile_c: int = TILE_C) -> int:
    """Analytic VMEM footprint of one program instance (float32)."""
    ho, plo_h, phi_h = same_pad(h, 3, stride)
    wo, plo_w, phi_w = same_pad(w, 3, stride)
    bc = min(tile_c, c)
    slab = (h + plo_h + phi_h) * (w + plo_w + phi_w) * bc
    return 4 * (slab + 9 * bc + bc + ho * wo * bc)
