//! Local microgrids: PV + battery behind an edge node, making the node's
//! *effective* carbon intensity depend on sunlight and state of charge.
//!
//! The paper prices every joule at the grid's intensity; real edge sites
//! increasingly sit behind local solar and storage (the renewable-
//! availability effect GreenScale shows dominates edge carbon). This
//! module models that supply side:
//!
//! * [`PvProfile`] — photovoltaic generation in watts over virtual time,
//!   backed by the same [`IntensityTrace`] machinery the grid curves use
//!   (`Static`/`Diurnal`/`Trace` variants, CSV ingestion), so the
//!   `at`/`integral` semantics are shared with the carbon accounting path;
//! * [`BatterySpec`] — capacity, charge/discharge rate limits, round-trip
//!   efficiency (applied on the charge side) and initial state of charge;
//! * [`Microgrid`] — the runtime state: over any virtual-time slice, node
//!   draw is covered **PV-first, then battery, then grid**
//!   ([`Microgrid::cover`]), and excess PV charges the battery (anything
//!   beyond the charger rate or the headroom is curtailed). Only charging
//!   from local PV is modelled — the battery never charges from the grid,
//!   so stored energy is always zero-carbon.
//!
//! The fleet simulator ([`crate::sim`]) attaches an optional
//! [`MicrogridSpec`] per node, settles every change of node draw through
//! [`Microgrid::cover`], and pushes [`Microgrid::effective_intensity`]
//! into `EdgeNode::intensity_override` — so every existing
//! [`crate::scheduler::Scheduler`] transparently follows the sun and the
//! charge without knowing microgrids exist.

use crate::carbon::{GramsPerKwh, IntensityTrace};

/// Seconds per hour — the Wh ↔ J conversion used throughout.
const WH_TO_J: f64 = 3_600.0;

/// Photovoltaic generation profile: watts as a function of virtual time,
/// reusing [`IntensityTrace`] (value = watts, not gCO₂/kWh).
#[derive(Debug, Clone)]
pub struct PvProfile {
    trace: IntensityTrace,
}

impl PvProfile {
    /// No local generation (0 W at all times).
    pub fn none() -> PvProfile {
        PvProfile { trace: IntensityTrace::Static(0.0) }
    }

    /// Clamped half-sine day curve peaking at `peak_w`: sunrise at 06:00,
    /// solar noon at 12:00, sunset at 18:00, zero overnight (the negative
    /// half of the sinusoid clamps to zero).
    pub fn diurnal(peak_w: f64) -> PvProfile {
        PvProfile::diurnal_with_sunrise(peak_w, 21_600.0)
    }

    /// Like [`PvProfile::diurnal`] with the sunrise moved to `sunrise_s`
    /// (virtual seconds): generation is positive over
    /// `(sunrise, sunrise + 12 h)` of every day. Lets a fleet stagger its
    /// sites across "longitudes".
    pub fn diurnal_with_sunrise(peak_w: f64, sunrise_s: f64) -> PvProfile {
        assert!(peak_w.is_finite() && peak_w >= 0.0, "bad PV peak {peak_w}");
        PvProfile {
            trace: IntensityTrace::Diurnal {
                mean: 0.0,
                amplitude: peak_w,
                period_s: 86_400.0,
                phase_s: sunrise_s,
            },
        }
    }

    /// Generation trace from explicit `(t_seconds, watts)` samples
    /// (step-held, validated and time-sorted).
    pub fn from_samples(points: Vec<(f64, f64)>) -> Result<PvProfile, String> {
        IntensityTrace::from_samples(points).map(|trace| PvProfile { trace })
    }

    /// Generation trace from a single-zone CSV (`timestamp,watts`) — the
    /// same format [`IntensityTrace::from_csv`] accepts for grid curves.
    pub fn from_csv(text: &str) -> Result<PvProfile, String> {
        IntensityTrace::from_csv(text).map(|trace| PvProfile { trace })
    }

    /// Instantaneous generation at `t` (W).
    pub fn power_w(&self, t: f64) -> f64 {
        self.trace.at(t).max(0.0)
    }

    /// Energy generated over `[t0, t1]` (J = W·s), via the trace's exact
    /// piecewise/analytic integral.
    pub fn energy_j(&self, t0: f64, t1: f64) -> f64 {
        self.trace.integral(t0, t1).max(0.0)
    }
}

/// Battery parameters. Rates are symmetric power limits; the round-trip
/// efficiency is applied entirely on the charge side (storing `x` joules
/// of PV yields `rt_efficiency · x` joules of usable charge), which keeps
/// discharge accounting exact.
#[derive(Debug, Clone)]
pub struct BatterySpec {
    pub capacity_wh: f64,
    pub max_charge_w: f64,
    pub max_discharge_w: f64,
    /// Round-trip efficiency in `(0, 1]`.
    pub rt_efficiency: f64,
    /// Initial state of charge as a fraction of capacity, in `[0, 1]`.
    pub initial_soc: f64,
}

impl BatterySpec {
    /// No storage: zero capacity, zero rates.
    pub fn none() -> BatterySpec {
        BatterySpec {
            capacity_wh: 0.0,
            max_charge_w: 0.0,
            max_discharge_w: 0.0,
            rt_efficiency: 1.0,
            initial_soc: 0.0,
        }
    }

    /// A `capacity_wh` battery with 1C symmetric rate limits (a 600 Wh
    /// battery charges/discharges at up to 600 W).
    pub fn simple(capacity_wh: f64, rt_efficiency: f64, initial_soc: f64) -> BatterySpec {
        BatterySpec {
            capacity_wh,
            max_charge_w: capacity_wh,
            max_discharge_w: capacity_wh,
            rt_efficiency,
            initial_soc,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("capacity_wh", self.capacity_wh),
            ("max_charge_w", self.max_charge_w),
            ("max_discharge_w", self.max_discharge_w),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("battery {name} must be finite and >= 0, got {v}"));
            }
        }
        let eff = self.rt_efficiency;
        if !eff.is_finite() || !(eff > 0.0 && eff <= 1.0) {
            return Err(format!("battery rt_efficiency must be in (0, 1], got {eff}"));
        }
        if !self.initial_soc.is_finite() || !(0.0..=1.0).contains(&self.initial_soc) {
            return Err(format!("battery initial_soc must be in [0, 1], got {}", self.initial_soc));
        }
        Ok(())
    }
}

/// Immutable per-node microgrid configuration a scenario carries; the
/// simulator builds a fresh [`Microgrid`] runtime state from it per run,
/// keeping runs deterministic.
#[derive(Debug, Clone)]
pub struct MicrogridSpec {
    pub pv: PvProfile,
    pub battery: BatterySpec,
}

impl MicrogridSpec {
    /// Convenience: a diurnal PV array peaking at `pv_peak_w` plus a 1C
    /// battery of `battery_wh` starting at `initial_soc`.
    pub fn solar(
        pv_peak_w: f64,
        battery_wh: f64,
        rt_efficiency: f64,
        initial_soc: f64,
    ) -> MicrogridSpec {
        MicrogridSpec {
            pv: PvProfile::diurnal(pv_peak_w),
            battery: BatterySpec::simple(battery_wh, rt_efficiency, initial_soc),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        self.battery.validate()
    }
}

/// How one virtual-time slice of node demand was supplied (all in joules).
/// Invariant: `pv_j + battery_j + grid_j == draw_w · Δt` — the simulator's
/// energy-conservation tests lean on it.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SliceFlow {
    /// PV generation consumed directly by the node.
    pub pv_j: f64,
    /// Battery discharge consumed by the node.
    pub battery_j: f64,
    /// Grid import consumed by the node (the only carbon-bearing term).
    pub grid_j: f64,
    /// Excess PV routed into the battery (input side, before losses).
    pub charged_j: f64,
    /// Excess PV neither consumed nor storable (rate/headroom limits).
    pub curtailed_j: f64,
}

/// Runtime microgrid state: spec + current stored energy.
#[derive(Debug, Clone)]
pub struct Microgrid {
    pub spec: MicrogridSpec,
    /// Stored energy (J), always in `[0, capacity]`.
    soc_j: f64,
}

impl Microgrid {
    pub fn new(spec: MicrogridSpec) -> Microgrid {
        if let Err(e) = spec.validate() {
            panic!("invalid microgrid spec: {e}");
        }
        let soc_j = spec.battery.initial_soc * spec.battery.capacity_wh * WH_TO_J;
        Microgrid { spec, soc_j }
    }

    /// State of charge as a fraction of capacity (0 for a zero-capacity
    /// battery).
    pub fn soc_frac(&self) -> f64 {
        let cap_j = self.spec.battery.capacity_wh * WH_TO_J;
        if cap_j > 0.0 {
            self.soc_j / cap_j
        } else {
            0.0
        }
    }

    /// Stored energy in Wh.
    pub fn soc_wh(&self) -> f64 {
        self.soc_j / WH_TO_J
    }

    /// Cover a constant draw of `draw_w` watts over `[t0, t1]`: PV first,
    /// then battery (rate- and charge-limited), then grid; excess PV
    /// charges the battery up to the charger rate and the headroom
    /// (efficiency-adjusted), the rest is curtailed. Returns the supply
    /// split; mutates the state of charge.
    pub fn cover(&mut self, t0: f64, t1: f64, draw_w: f64) -> SliceFlow {
        let dt = t1 - t0;
        assert!(dt >= 0.0, "cover slice reversed: [{t0}, {t1}]");
        if dt == 0.0 {
            return SliceFlow::default();
        }
        let b = &self.spec.battery;
        let cap_j = b.capacity_wh * WH_TO_J;
        let demand_j = (draw_w * dt).max(0.0);
        let pv_avail_j = self.spec.pv.energy_j(t0, t1);
        let pv_j = demand_j.min(pv_avail_j);
        let mut residual_j = demand_j - pv_j;
        let battery_j = residual_j.min(b.max_discharge_w * dt).min(self.soc_j).max(0.0);
        self.soc_j = (self.soc_j - battery_j).max(0.0);
        residual_j -= battery_j;
        let grid_j = residual_j.max(0.0);
        let excess_j = (pv_avail_j - pv_j).max(0.0);
        let headroom_in_j = (cap_j - self.soc_j).max(0.0) / b.rt_efficiency;
        let charged_j = excess_j.min(b.max_charge_w * dt).min(headroom_in_j);
        self.soc_j = (self.soc_j + charged_j * b.rt_efficiency).min(cap_j);
        SliceFlow { pv_j, battery_j, grid_j, charged_j, curtailed_j: excess_j - charged_j }
    }

    /// Blended effective carbon intensity (gCO₂/kWh) of serving `draw_w`
    /// at instant `t` against a grid currently at `grid_intensity`: the
    /// grid-supplied fraction of the draw (after instantaneous PV and the
    /// battery) scales the grid intensity. PV and battery joules are
    /// zero-carbon, so a sunlit or charged node reads as clean to every
    /// scheduler scoring `EdgeNode::intensity()`.
    ///
    /// The battery term is capped at the power the *current charge* can
    /// sustain for `sustain_s` seconds (the advertising window — the
    /// simulator passes its intensity-refresh interval), not just the
    /// discharge rate limit: a near-empty battery must not advertise its
    /// full rate and have the scheduler pile a whole refresh window of
    /// load onto joules that drain in the first instant.
    pub fn effective_intensity(
        &self,
        t: f64,
        draw_w: f64,
        grid_intensity: GramsPerKwh,
        sustain_s: f64,
    ) -> GramsPerKwh {
        assert!(sustain_s > 0.0, "sustain window must be positive");
        let pv_w = self.spec.pv.power_w(t);
        let batt_w = self.spec.battery.max_discharge_w.min(self.soc_j / sustain_s);
        if draw_w <= 0.0 {
            // Marginal view for a zero-draw node: the first watt would be
            // local whenever any local supply exists.
            return if pv_w > 0.0 || batt_w > 0.0 { 0.0 } else { grid_intensity };
        }
        let residual_w = (draw_w - pv_w - batt_w).max(0.0);
        grid_intensity * residual_w / draw_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pv_diurnal_shape() {
        let pv = PvProfile::diurnal(400.0);
        assert_eq!(pv.power_w(0.0), 0.0); // midnight
        assert_eq!(pv.power_w(10_000.0), 0.0); // pre-dawn
        assert!((pv.power_w(43_200.0) - 400.0).abs() < 1e-9); // solar noon
        assert!(pv.power_w(30_000.0) > 0.0 && pv.power_w(30_000.0) < 400.0);
        assert_eq!(pv.power_w(70_000.0), 0.0); // night
        // Daily yield of a clamped half-sine: peak · (2/π) · 12 h.
        let day_j = pv.energy_j(0.0, 86_400.0);
        let want = 400.0 * (2.0 / std::f64::consts::PI) * 43_200.0;
        assert!((day_j - want).abs() / want < 1e-3, "day {day_j} want {want}");
        // Staggered sunrise shifts the window.
        let east = PvProfile::diurnal_with_sunrise(400.0, 0.0);
        assert!(east.power_w(10_000.0) > 0.0);
        assert_eq!(east.power_w(50_000.0), 0.0);
        assert_eq!(PvProfile::none().power_w(43_200.0), 0.0);
        assert_eq!(PvProfile::none().energy_j(0.0, 86_400.0), 0.0);
    }

    #[test]
    fn pv_from_samples_and_csv() {
        let pv = PvProfile::from_samples(vec![(0.0, 0.0), (100.0, 250.0), (200.0, 0.0)]).unwrap();
        assert_eq!(pv.power_w(150.0), 250.0);
        assert!((pv.energy_j(0.0, 300.0) - 250.0 * 100.0).abs() < 1e-9);
        assert!(PvProfile::from_samples(vec![(0.0, -1.0)]).is_err());
        let csv = PvProfile::from_csv("0,0\n100,250\n200,0\n").unwrap();
        assert_eq!(csv.power_w(150.0), 250.0);
        assert!(PvProfile::from_csv("garbage").is_err());
    }

    #[test]
    fn battery_validation() {
        assert!(BatterySpec::none().validate().is_ok());
        assert!(BatterySpec::simple(600.0, 0.9, 0.5).validate().is_ok());
        assert!(BatterySpec::simple(-1.0, 0.9, 0.5).validate().is_err());
        assert!(BatterySpec::simple(600.0, 0.0, 0.5).validate().is_err());
        assert!(BatterySpec::simple(600.0, 1.1, 0.5).validate().is_err());
        assert!(BatterySpec::simple(600.0, 0.9, 1.5).validate().is_err());
        assert!(BatterySpec::simple(f64::NAN, 0.9, 0.5).validate().is_err());
        // 1C convention
        let b = BatterySpec::simple(600.0, 0.9, 0.5);
        assert_eq!(b.max_charge_w, 600.0);
        assert_eq!(b.max_discharge_w, 600.0);
    }

    #[test]
    #[should_panic(expected = "invalid microgrid spec")]
    fn microgrid_rejects_bad_spec() {
        Microgrid::new(MicrogridSpec::solar(100.0, 100.0, 2.0, 0.5));
    }

    #[test]
    fn cover_pv_first_then_battery_then_grid() {
        // Constant 500 W PV, 1000 Wh battery at 50%.
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 500.0)]).unwrap(),
            battery: BatterySpec::simple(1_000.0, 1.0, 0.5),
        });
        // Draw under PV: all PV, battery untouched (and charging from excess).
        let f = mg.cover(0.0, 10.0, 300.0);
        assert!((f.pv_j - 3_000.0).abs() < 1e-9);
        assert_eq!(f.battery_j, 0.0);
        assert_eq!(f.grid_j, 0.0);
        assert!((f.charged_j - 2_000.0).abs() < 1e-9); // 200 W excess × 10 s
        assert!((f.pv_j + f.battery_j + f.grid_j - 3_000.0).abs() < 1e-9);
        // Draw over PV but within battery rate: PV + battery, no grid.
        let f = mg.cover(10.0, 20.0, 900.0);
        assert!((f.pv_j - 5_000.0).abs() < 1e-9);
        assert!((f.battery_j - 4_000.0).abs() < 1e-9);
        assert_eq!(f.grid_j, 0.0);
        // Draw over PV + battery rate (1C = 1000 W): grid takes the rest.
        let f = mg.cover(20.0, 30.0, 2_000.0);
        assert!((f.pv_j - 5_000.0).abs() < 1e-9);
        assert!((f.battery_j - 10_000.0).abs() < 1e-9); // rate-capped
        assert!((f.grid_j - 5_000.0).abs() < 1e-9);
        assert!((f.pv_j + f.battery_j + f.grid_j - 20_000.0).abs() < 1e-9);
    }

    #[test]
    fn battery_never_exceeds_bounds() {
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 1_000.0)]).unwrap(),
            battery: BatterySpec::simple(10.0, 1.0, 0.9), // 10 Wh = 36 kJ
        });
        // Massive excess: SoC caps at capacity.
        mg.cover(0.0, 3_600.0, 0.0);
        assert!((mg.soc_frac() - 1.0).abs() < 1e-12);
        assert!((mg.soc_wh() - 10.0).abs() < 1e-12);
        // Massive draw with no PV window left: SoC floors at zero, grid
        // absorbs everything beyond the stored energy.
        let mut dark = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec::simple(10.0, 1.0, 1.0),
        });
        let f = dark.cover(0.0, 3_600.0, 100.0); // 360 kJ demand vs 36 kJ stored
        assert!(dark.soc_frac().abs() < 1e-12);
        assert!((f.battery_j - 36_000.0).abs() < 1e-9);
        assert!((f.grid_j - (360_000.0 - 36_000.0)).abs() < 1e-9);
    }

    #[test]
    fn charge_respects_rate_efficiency_and_headroom() {
        // 1000 W of excess PV into a 100 W charger: input rate-capped.
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 1_000.0)]).unwrap(),
            battery: BatterySpec {
                capacity_wh: 1_000.0,
                max_charge_w: 100.0,
                max_discharge_w: 100.0,
                rt_efficiency: 0.8,
                initial_soc: 0.0,
            },
        });
        let f = mg.cover(0.0, 10.0, 0.0);
        assert!((f.charged_j - 1_000.0).abs() < 1e-9); // 100 W × 10 s input
        assert!((f.curtailed_j - 9_000.0).abs() < 1e-9);
        // Only 80% of the input lands as stored charge.
        assert!((mg.soc_wh() - 1_000.0 * 0.8 / 3_600.0).abs() < 1e-12);
        // Near-full battery: charging stops at the headroom, not past it.
        let mut full = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 1_000.0)]).unwrap(),
            battery: BatterySpec {
                capacity_wh: 1.0, // 3600 J
                max_charge_w: 1_000.0,
                max_discharge_w: 1_000.0,
                rt_efficiency: 0.5,
                initial_soc: 0.5,
            },
        });
        let f = full.cover(0.0, 100.0, 0.0); // 100 kJ excess vs 1800 J headroom
        assert!((f.charged_j - 1_800.0 / 0.5).abs() < 1e-9); // input = headroom/η
        assert!((full.soc_frac() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cover_conserves_demand_exactly() {
        let mut mg = Microgrid::new(MicrogridSpec::solar(400.0, 600.0, 0.9, 0.3));
        let mut t = 0.0;
        for (dt, draw) in [(500.0, 54.0), (10_000.0, 142.0), (40_000.0, 0.0), (20_000.0, 300.0)] {
            let f = mg.cover(t, t + dt, draw);
            let demand = draw * dt;
            assert!(
                (f.pv_j + f.battery_j + f.grid_j - demand).abs() <= 1e-9 * demand.max(1.0),
                "slice at t={t}: {f:?} vs demand {demand}"
            );
            assert!((0.0..=1.0 + 1e-12).contains(&mg.soc_frac()));
            t += dt;
        }
        // Zero-length slices are exact no-ops.
        let before = mg.soc_frac();
        assert_eq!(mg.cover(t, t, 1_000.0), SliceFlow::default());
        assert_eq!(mg.soc_frac(), before);
    }

    #[test]
    fn effective_intensity_blends_supply() {
        const WINDOW: f64 = 60.0;
        // PV 300 W at noon, charged 1C-600 battery, grid at 500 g/kWh.
        let mg = Microgrid::new(MicrogridSpec::solar(300.0, 600.0, 0.9, 1.0));
        let noon = 43_200.0;
        // 200 W draw fully PV-covered: effectively zero-carbon.
        assert_eq!(mg.effective_intensity(noon, 200.0, 500.0, WINDOW), 0.0);
        // 1500 W draw at noon: 300 PV + 600 battery + 600 grid -> 40% grid.
        let eff = mg.effective_intensity(noon, 1_500.0, 500.0, WINDOW);
        assert!((eff - 500.0 * 600.0 / 1_500.0).abs() < 1e-9);
        // Midnight, battery charged: discharge rate still covers 600 W.
        assert_eq!(mg.effective_intensity(0.0, 600.0, 500.0, WINDOW), 0.0);
        let eff = mg.effective_intensity(0.0, 1_200.0, 500.0, WINDOW);
        assert!((eff - 250.0).abs() < 1e-9);
        // Depleted battery at midnight: pure grid.
        let empty = Microgrid::new(MicrogridSpec::solar(300.0, 600.0, 0.9, 0.0));
        assert_eq!(empty.effective_intensity(0.0, 100.0, 500.0, WINDOW), 500.0);
        // Zero draw: marginal watt is local iff any local supply exists.
        assert_eq!(mg.effective_intensity(0.0, 0.0, 500.0, WINDOW), 0.0);
        assert_eq!(empty.effective_intensity(0.0, 0.0, 500.0, WINDOW), 500.0);
        assert_eq!(empty.effective_intensity(noon, 0.0, 500.0, WINDOW), 0.0); // sun is up
    }

    #[test]
    fn effective_intensity_caps_battery_at_sustainable_power() {
        // 1800 J of charge over a 60 s advertising window sustains 30 W —
        // a near-empty battery must not advertise its full 500 W rate (the
        // SoC→0 cliff would misroute a whole refresh window of load onto
        // joules that drain in the first instant).
        let low = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 10.0, // 36 kJ
                max_charge_w: 500.0,
                max_discharge_w: 500.0,
                rt_efficiency: 1.0,
                initial_soc: 0.05, // 1800 J
            },
        });
        let eff = low.effective_intensity(0.0, 100.0, 500.0, 60.0);
        assert!((eff - 500.0 * (100.0 - 30.0) / 100.0).abs() < 1e-9, "eff {eff}");
        // A longer window sustains even less; a shorter one more.
        let eff_long = low.effective_intensity(0.0, 100.0, 500.0, 600.0);
        assert!(eff_long > eff);
        let eff_short = low.effective_intensity(0.0, 100.0, 500.0, 3.0);
        assert!(eff_short < eff);
        // Fully charged, the rate limit (not the charge) is what binds.
        let full = Microgrid::new(MicrogridSpec::solar(0.0, 10.0, 1.0, 1.0));
        let eff = full.effective_intensity(0.0, 100.0, 500.0, 60.0);
        // 1C on 10 Wh = 10 W rate, though 36 kJ / 60 s could push 600 W.
        assert!((eff - 500.0 * (100.0 - 10.0) / 100.0).abs() < 1e-9, "eff {eff}");
    }
}
