//! Weight sweep (the paper's Fig. 3 scenario as an application): explore
//! the performance–carbon trade-off by sweeping the carbon weight `w_C`
//! from 0 to 1 and report where routing flips to the green node.
//!
//! ```sh
//! cargo run --release --example weight_sweep -- [--step 0.1] [--iters 10]
//! ```

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;
use carbonedge::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let step = args.parse_or("step", 0.1f64)?;
    let iters = args.parse_or("iters", 10usize)?;
    let model = args.str_or("model", "mobilenet_v2");

    let coord = Coordinator::new(Config::default())?;
    let mono = exp::run_strategy(&coord, &model, exp::Strategy::Monolithic, iters, 1)?;
    let points = exp::fig3_sweep(&coord, &model, iters, step)?;
    println!("{}", exp::fig3_render(&points, &mono));

    // Narrative summary: carbon saved at each end of the sweep.
    let first = &points.first().unwrap().report;
    let last = &points.last().unwrap().report;
    println!(
        "w_C=0.0: {:.5} g/inf on {:?} | w_C=1.0: {:.5} g/inf on {:?}",
        first.carbon_per_inf_g,
        first.node_usage.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        last.carbon_per_inf_g,
        last.node_usage.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
    );
    Ok(())
}
