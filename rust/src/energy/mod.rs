//! Energy accounting substrate: simulated power sources (the paper's
//! CodeCarbon + RAPL + nvidia-smi stack, Sec. III-B), an integrating host
//! meter (Eq. 1), and the cgroup-quota apportioner (Sec. IV-A1).

mod apportion;
mod power;

pub use apportion::{ApportionMode, Apportioner};
pub use power::{CpuRapl, GpuSim, HostPowerModel, PowerModel, RamPower, RAM_WATTS_PER_GB};

use std::time::Duration;

/// Integrating host energy meter: the paper's Eq. 1
/// `E_total = ∫ (P_GPU + P_CPU + P_RAM) dt`, discretized over samples
/// (CodeCarbon's `measure_power_secs` behaviour).
#[derive(Debug, Clone)]
pub struct HostMeter {
    model: HostPowerModel,
    energy_j: f64,
    elapsed: Duration,
    samples: u64,
}

impl HostMeter {
    pub fn new(model: HostPowerModel) -> HostMeter {
        HostMeter { model, energy_j: 0.0, elapsed: Duration::ZERO, samples: 0 }
    }

    /// Record one sample period: utilizations in `[0,1]` held for `dt`.
    pub fn sample(&mut self, dt: Duration, cpu_util: f64, gpu_util: f64) {
        let p = self.model.power_watts(cpu_util, gpu_util);
        self.energy_j += p * dt.as_secs_f64();
        self.elapsed += dt;
        self.samples += 1;
    }

    /// Total energy in joules (Eq. 1 integral so far).
    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    pub fn energy_kwh(&self) -> f64 {
        crate::carbon::joules_to_kwh(self.energy_j)
    }

    pub fn elapsed(&self) -> Duration {
        self.elapsed
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Average power over the metered window.
    pub fn avg_power_w(&self) -> f64 {
        let s = self.elapsed.as_secs_f64();
        if s == 0.0 {
            0.0
        } else {
            self.energy_j / s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn host() -> HostPowerModel {
        HostPowerModel {
            cpu: CpuRapl { idle_w: 40.0, peak_w: 240.0 },
            gpu: GpuSim { idle_w: 60.0, peak_w: 400.0 },
            ram: RamPower::new(64.0),
        }
    }

    #[test]
    fn eq1_integration() {
        let mut m = HostMeter::new(host());
        // idle for 1s: 40 + 60 + 24 = 124 W
        m.sample(Duration::from_secs(1), 0.0, 0.0);
        assert!((m.energy_j() - 124.0).abs() < 1e-9);
        // full load 1s: 240 + 400 + 24 = 664 W
        m.sample(Duration::from_secs(1), 1.0, 1.0);
        assert!((m.energy_j() - (124.0 + 664.0)).abs() < 1e-9);
        assert_eq!(m.samples(), 2);
        assert!((m.avg_power_w() - 394.0).abs() < 1e-9);
    }

    #[test]
    fn kwh_conversion() {
        let mut m = HostMeter::new(host());
        m.sample(Duration::from_secs(3600), 0.0, 0.0);
        // 124 W for 1 h = 0.124 kWh
        assert!((m.energy_kwh() - 0.124).abs() < 1e-9);
    }

    #[test]
    fn fractional_util_interpolates() {
        let mut m = HostMeter::new(host());
        m.sample(Duration::from_secs(1), 0.5, 0.0);
        // cpu: 40 + 0.5*200 = 140; gpu 60; ram 24 => 224
        assert!((m.energy_j() - 224.0).abs() < 1e-9);
    }
}
