//! Substrate utilities built from scratch for the offline environment
//! (DESIGN.md §7): JSON, CLI parsing, PRNG, statistics, table rendering,
//! thread pool, and a micro-benchmark harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
