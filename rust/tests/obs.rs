//! Integration tests for the observability subsystem: the NDJSON event
//! firehose, the in-process telemetry registry, and the guarantee that
//! tracing never perturbs the simulation. Everything runs on the virtual
//! clock — no artifacts needed.

use std::collections::BTreeMap;

use carbonedge::obs::{
    EventKind, FirehoseSink, NullSink, Telemetry, TraceFilter, OVERHEAD_ENVELOPE_NS,
};
use carbonedge::scheduler::{CarbonAwareScheduler, DeferAwareGreenScheduler, Mode, Scheduler};
use carbonedge::sim::{scenarios, SimReport, Simulation};
use carbonedge::util::json::Json;

fn green() -> CarbonAwareScheduler {
    CarbonAwareScheduler::new("green", Mode::Green.weights())
}

/// Run a scenario with a full firehose attached — `defer-green` when the
/// scenario configures deferral (its intended scheduler), plain green
/// otherwise; return the report, telemetry, and the NDJSON the sink wrote.
fn observed(name: &str, requests: usize, seed: u64) -> (SimReport, Telemetry, String) {
    let sc = scenarios::build(name, 0, requests, seed).unwrap();
    let mut sched: Box<dyn Scheduler> = match &sc.config.deferral {
        Some(d) => Box::new(DeferAwareGreenScheduler::new(d.policy.min_gain)),
        None => Box::new(green()),
    };
    let mut sink = FirehoseSink::new(Vec::new());
    let (report, telem) =
        Simulation::try_run_observed(&sc, sched.as_mut(), &mut sink).unwrap();
    let text = String::from_utf8(sink.finish().unwrap()).unwrap();
    (report, telem, text)
}

/// Every firehose line parses back through `util::json`, event counts are
/// conserved against both the report and the telemetry counters, and
/// replaying completion + microgrid-slice carbon reproduces the report's
/// carbon total. `paper-3-node` covers the plain grid path, `arbitrage`
/// the deferral + microgrid settlement path (both fleets are zero-idle,
/// so the event stream carries *all* the carbon).
#[test]
fn firehose_round_trip_conserves_events_and_replays_carbon() {
    for name in ["paper-3-node", "arbitrage"] {
        let (report, telem, text) = observed(name, 4_000, 7);
        let mut counts: BTreeMap<String, u64> = BTreeMap::new();
        let mut completion_carbon = 0.0;
        let mut slice_carbon = 0.0;
        let mut missed = 0u64;
        let mut lines = 0u64;
        for line in text.lines() {
            lines += 1;
            let v = Json::parse(line)
                .unwrap_or_else(|e| panic!("{name}: invalid NDJSON line ({e}): {line}"));
            let kind = v.req_str("kind").unwrap().to_string();
            *counts.entry(kind.clone()).or_insert(0) += 1;
            match kind.as_str() {
                "completion" => {
                    completion_carbon += v.req_f64("carbon_g").unwrap();
                    if v.get("missed").unwrap().as_bool() == Some(true) {
                        missed += 1;
                    }
                }
                "mg_slice" => slice_carbon += v.req_f64("carbon_g").unwrap(),
                "decision" => {
                    // Decision traces carry the per-candidate rationale.
                    assert!(
                        !v.req_arr("candidates").unwrap().is_empty(),
                        "{name}: decision line without candidates: {line}"
                    );
                }
                _ => {}
            }
        }
        // One line per event, and the post-filter stream (filter = all)
        // matches the pre-filter telemetry counters kind by kind.
        assert_eq!(lines, telem.total_events(), "{name}: line count vs telemetry");
        for k in EventKind::ALL {
            assert_eq!(
                counts.get(k.label()).copied().unwrap_or(0),
                telem.events_of(k),
                "{name}: {} count mismatch",
                k.label()
            );
        }
        // Event-count conservation against the report.
        assert_eq!(counts["arrival"], report.requests, "{name}: arrivals");
        assert_eq!(counts["completion"], report.completed, "{name}: completions");
        assert_eq!(report.completed + report.rejected, report.requests, "{name}: leaked");
        assert_eq!(missed, report.deadline_missed, "{name}: missed-deadline replay");
        // Carbon replay: completions carry grid-attributed carbon,
        // microgrid slices carry settled carbon; together they reproduce
        // the run total.
        let replayed = completion_carbon + slice_carbon;
        assert!(
            (replayed - report.carbon_g_total).abs() <= 1e-6 * report.carbon_g_total.max(1e-12),
            "{name}: replayed carbon {replayed} != total {}",
            report.carbon_g_total
        );
        if name == "arbitrage" {
            // The interesting paths actually fired.
            assert!(counts.get("mg_slice").copied().unwrap_or(0) > 0, "no settlement slices");
            assert!(counts.get("defer_release").copied().unwrap_or(0) > 0, "no defer releases");
        }
    }
}

/// Tracing must never perturb the run: with the full firehose attached —
/// and with the counters-only `NullSink` — the `SimReport` is bit-identical
/// (`PartialEq` over every field) to the untraced run, across the whole
/// scenario library.
#[test]
fn traced_run_report_is_bit_identical_to_untraced() {
    for name in scenarios::SCENARIO_NAMES {
        let sc = scenarios::build(name, 0, 1_500, 7).unwrap();
        let untraced = Simulation::try_run(&sc, &mut green()).unwrap();

        let mut sink = FirehoseSink::new(Vec::new());
        let (traced, telem) = Simulation::try_run_observed(&sc, &mut green(), &mut sink).unwrap();
        assert_eq!(untraced, traced, "{name}: firehose tracing perturbed the simulation");
        assert_eq!(telem.events_of(EventKind::Completion), traced.completed, "{name}");

        let mut null = NullSink;
        let (counted, _) = Simulation::try_run_observed(&sc, &mut green(), &mut null).unwrap();
        assert_eq!(untraced, counted, "{name}: NullSink observation perturbed the simulation");
    }
}

/// The paper's 0.03 ms scheduling-overhead envelope, measured in-process:
/// per-decision wall-clock cost through the counters-only observation path
/// stays within [`OVERHEAD_ENVELOPE_NS`] (relaxed 10x in debug builds,
/// which is what `cargo test` runs).
#[test]
fn decision_overhead_stays_within_the_paper_envelope() {
    let sc = scenarios::build("paper-3-node", 0, 5_000, 42).unwrap();
    let mut null = NullSink;
    let (report, telem) = Simulation::try_run_observed(&sc, &mut green(), &mut null).unwrap();
    assert!(telem.decide_ns.count >= report.requests, "every arrival was timed");
    let envelope = if cfg!(debug_assertions) {
        OVERHEAD_ENVELOPE_NS * 10.0
    } else {
        OVERHEAD_ENVELOPE_NS
    };
    let mean = telem.decide_ns.mean();
    assert!(
        mean <= envelope,
        "mean decide overhead {mean:.0} ns exceeds the envelope {envelope:.0} ns"
    );
}

/// `--trace-filter decision`: the firehose drops every other kind, but the
/// telemetry counters (pre-filter by design) still see the whole run.
#[test]
fn filtered_firehose_drops_lines_but_telemetry_counts_everything() {
    let sc = scenarios::build("paper-3-node", 0, 2_000, 7).unwrap();
    let filter = TraceFilter::parse("decision").unwrap();
    let mut sink = FirehoseSink::with_filter(Vec::new(), filter);
    let (report, telem) = Simulation::try_run_observed(&sc, &mut green(), &mut sink).unwrap();
    let written = sink.events_written();
    let text = String::from_utf8(sink.finish().unwrap()).unwrap();
    assert_eq!(text.lines().count() as u64, written);
    assert!(written > 0, "no decision lines written");
    for line in text.lines() {
        let v = Json::parse(line).unwrap();
        assert_eq!(v.req_str("kind").unwrap(), "decision");
    }
    assert_eq!(telem.events_of(EventKind::Arrival), report.requests);
    assert_eq!(telem.events_of(EventKind::Dispatch), report.completed);
    assert_eq!(telem.events_of(EventKind::Decision), written);
}

/// The batched service path through the firehose: one `batch_formed`
/// line per sealed batch whose fills sum to exactly the completions, a
/// per-class seal count that matches the report's `ClassUsage` rows, and
/// (a grid-only fleet) a completion-carbon replay of the dynamic total.
#[test]
fn batch_serving_firehose_conserves_fills_and_replays_dynamic_carbon() {
    let (report, telem, text) = observed("batch-serving", 3_000, 7);
    let mut fills = 0u64;
    let mut seals_per_class: BTreeMap<i64, u64> = BTreeMap::new();
    let mut batch_lines = 0u64;
    let mut completion_carbon = 0.0;
    for line in text.lines() {
        let v = Json::parse(line).unwrap();
        match v.req_str("kind").unwrap() {
            "batch_formed" => {
                batch_lines += 1;
                let fill = v.get("fill").unwrap().as_i64().unwrap();
                assert!(fill >= 1, "empty batch sealed: {line}");
                fills += fill as u64;
                *seals_per_class
                    .entry(v.get("class").unwrap().as_i64().unwrap())
                    .or_insert(0) += 1;
                assert!(v.req_f64("head_wait_ms").unwrap() >= 0.0, "{line}");
            }
            "completion" => completion_carbon += v.req_f64("carbon_g").unwrap(),
            _ => {}
        }
    }
    assert_eq!(batch_lines, telem.events_of(EventKind::BatchFormed));
    assert_eq!(fills, report.completed, "batch fills must sum to completions");
    assert_eq!(report.classes.len(), 3);
    for (c, class) in report.classes.iter().enumerate() {
        assert_eq!(
            seals_per_class.get(&(c as i64)).copied().unwrap_or(0),
            class.batches,
            "{}: sealed-batch count mismatch",
            class.name
        );
    }
    assert!(
        (completion_carbon - report.carbon_dynamic_g_total).abs()
            <= 1e-6 * report.carbon_dynamic_g_total.max(1e-12),
        "completion carbon {completion_carbon} != dynamic total {}",
        report.carbon_dynamic_g_total
    );
}
