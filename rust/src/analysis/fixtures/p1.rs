//! Known-bad fixture: P1 — unwrap on a hot decision path.
//! A panic here poisons an entire fleet sweep.

/// Pick the first candidate, panicking on an empty slate.
pub fn first_choice(candidates: &[usize]) -> usize {
    let head = candidates.first();
    *head.unwrap()
}
