"""AOT compilation: lower the model zoo to HLO text + weight/manifest sidecars.

This is the only place Python touches the serving stack. For every model we
emit:

  artifacts/<model>.hlo.txt            monolithic program  f(weights..., x)
  artifacts/<model>.stage<i>.hlo.txt   one program per stage
  artifacts/<model>.weights.bin        all weights, packed f32 little-endian
  artifacts/<model>.input.bin          golden input image (f32, H*W*3)
  artifacts/manifest.json              shapes, layer cost tables (Eq. 5),
                                       weight offsets, golden logits

Interchange format is **HLO text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .models import ZOO, build

# ImageNet preprocessing constants used by the paper (Sec. IV-A2).
IMAGENET_MEAN = np.array([0.485, 0.456, 0.406], np.float32)
IMAGENET_STD = np.array([0.229, 0.224, 0.225], np.float32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def golden_image(image_size: int, seed: int = 0) -> np.ndarray:
    """Deterministic synthetic "photo": smooth gradients + noise, then the
    paper's ImageNet normalization. Shared with the Rust workload generator
    (same formula, same seed) so golden logits match end-to-end."""
    rng = np.random.RandomState(seed)
    yy, xx = np.mgrid[0:image_size, 0:image_size].astype(np.float32) / image_size
    base = np.stack([yy, xx, 0.5 * (xx + yy)], axis=-1)
    img = np.clip(base + 0.1 * rng.randn(image_size, image_size, 3).astype(np.float32), 0.0, 1.0)
    return (img - IMAGENET_MEAN) / IMAGENET_STD


def lower_model(model, image_size: int):
    """Lower monolithic + per-stage programs; return dict name -> hlo text."""
    x_spec = jax.ShapeDtypeStruct((image_size, image_size, 3), jnp.float32)
    out = {}

    w_specs = [jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in model.all_weights]
    mono = jax.jit(lambda ws, x: (model.monolithic_fn()(ws, x),))
    out["monolithic"] = to_hlo_text(mono.lower(w_specs, x_spec))

    for i, s in enumerate(model.stages):
        s_in = jax.ShapeDtypeStruct(tuple(s.in_shape), jnp.float32)
        s_specs = [jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in s.weights]
        fn = jax.jit(lambda ws, x, s=s: (s.fn(ws, x),))
        out[f"stage{i}"] = to_hlo_text(fn.lower(s_specs, s_in))
    return out


def export_model(model, out_dir: str, image_size: int) -> dict:
    """Write all artifacts for one model; return its manifest entry."""
    hlos = lower_model(model, image_size)
    files = {}
    for key, text in hlos.items():
        fname = f"{model.name}.hlo.txt" if key == "monolithic" else f"{model.name}.{key}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[key] = fname

    # Packed weights + offset table (per-tensor, element offsets into the bin).
    weights_meta = []
    offset = 0
    chunks = []
    for si, s in enumerate(model.stages):
        for w in s.weights:
            arr = np.asarray(w, np.float32)
            weights_meta.append({"stage": si, "shape": list(arr.shape), "offset": offset})
            offset += arr.size
            chunks.append(arr.ravel())
    packed = np.concatenate(chunks) if chunks else np.zeros(0, np.float32)
    wfile = f"{model.name}.weights.bin"
    packed.tofile(os.path.join(out_dir, wfile))

    # Golden input + logits (monolithic forward with the real weights).
    img = golden_image(image_size)
    ifile = f"{model.name}.input.bin"
    img.astype("<f4").tofile(os.path.join(out_dir, ifile))
    logits = np.asarray(model.forward(jnp.asarray(img)))
    golden = {
        "seed": 0,
        "logits8": [float(v) for v in logits[:8]],
        "argmax": int(np.argmax(logits)),
        "logit_sum": float(logits.sum()),
    }

    return {
        "params": int(model.params),
        "flops": int(model.flops),
        "input_shape": [image_size, image_size, 3],
        "num_classes": model.num_classes,
        "monolithic": files["monolithic"],
        "weights_file": wfile,
        "weights_total": int(packed.size),
        "input_file": ifile,
        "golden": golden,
        "stages": [
            {
                "name": s.name,
                "artifact": files[f"stage{i}"],
                "in_shape": list(s.in_shape),
                "out_shape": list(s.out_shape),
                "params": int(s.params),
                "flops": int(s.flops),
                "cost": int(s.cost),
                "num_weights": len(s.weights),
            }
            for i, s in enumerate(model.stages)
        ],
        "weights": weights_meta,
        "layers": [dict(m.to_json(), stage=si) for si, s in enumerate(model.stages) for m in s.layers],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="CarbonEdge AOT pipeline")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=sorted(ZOO))
    ap.add_argument("--image-size", type=int, default=64)
    ap.add_argument("--width", type=float, default=0.5)
    ap.add_argument("--classes", type=int, default=1000)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "image_size": args.image_size,
        "width": args.width,
        "num_classes": args.classes,
        "models": {},
    }
    for name in args.models:
        print(f"[aot] building {name} ...", flush=True)
        model = build(name, image_size=args.image_size, width=args.width, num_classes=args.classes)
        manifest["models"][name] = export_model(model, args.out_dir, args.image_size)
        print(
            f"[aot]   {name}: {model.params/1e6:.2f}M params, {model.flops/1e6:.1f}M flops, "
            f"{len(model.stages)} stages",
            flush=True,
        )

    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    digest = hashlib.sha256(open(path, "rb").read()).hexdigest()[:12]
    print(f"[aot] wrote {path} (sha256 {digest})")


if __name__ == "__main__":
    main()
