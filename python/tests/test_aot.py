"""AOT pipeline: manifest schema, golden reproducibility, HLO text validity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import export_model, golden_image, lower_model, to_hlo_text
from compile.models import build

SMALL = dict(image_size=32, width=0.25, num_classes=10)


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    model = build("mobilenet_v2", **SMALL)
    entry = export_model(model, str(out), SMALL["image_size"])
    return model, entry, str(out)


def test_golden_image_deterministic():
    a, b = golden_image(32), golden_image(32)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (32, 32, 3)
    # normalized: not all positive (mean removed)
    assert float(a.min()) < 0 < float(a.max())


def test_hlo_text_is_parseable_hlo(exported):
    _, entry, out = exported
    text = open(os.path.join(out, entry["monolithic"])).read()
    assert text.startswith("HloModule"), text[:50]
    assert "ENTRY" in text


def test_manifest_entry_schema(exported):
    model, entry, _ = exported
    assert entry["params"] == model.params
    assert entry["flops"] == model.flops
    assert len(entry["stages"]) == len(model.stages)
    assert entry["weights_total"] == sum(
        int(np.prod(w["shape"])) for w in entry["weights"]
    )
    # stage chaining recorded consistently
    for a, b in zip(entry["stages"], entry["stages"][1:]):
        assert a["out_shape"] == b["in_shape"]
    # per-stage weight counts sum to the packed table
    assert sum(s["num_weights"] for s in entry["stages"]) == len(entry["weights"])


def test_weights_bin_roundtrip(exported):
    model, entry, out = exported
    packed = np.fromfile(os.path.join(out, entry["weights_file"]), "<f4")
    assert packed.size == entry["weights_total"]
    # reconstruct tensor 0 and compare to the model weight
    w0 = entry["weights"][0]
    n0 = int(np.prod(w0["shape"]))
    np.testing.assert_array_equal(
        packed[w0["offset"] : w0["offset"] + n0].reshape(w0["shape"]),
        np.asarray(model.all_weights[0]),
    )


def test_golden_logits_reproducible(exported):
    model, entry, out = exported
    img = np.fromfile(os.path.join(out, entry["input_file"]), "<f4").reshape(32, 32, 3)
    logits = np.asarray(model.forward(jnp.asarray(img)))
    np.testing.assert_allclose(logits[:8], entry["golden"]["logits8"], rtol=1e-5)
    assert int(np.argmax(logits)) == entry["golden"]["argmax"]


def test_stage_hlo_executes_like_stage_fn(exported):
    """Compile stage0 HLO back through XLA and compare with the jax fn."""
    model, entry, out = exported
    # jax executes the same lowered computation it produced
    s = model.stages[0]
    x = jnp.asarray(golden_image(32, seed=3))
    want = np.asarray(s.fn(s.weights, x))
    lowered = jax.jit(lambda ws, xx, s=s: (s.fn(ws, xx),)).lower(
        [jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in s.weights],
        jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )
    got = np.asarray(lowered.compile()(s.weights, x)[0])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_lower_model_emits_all_programs():
    model = build("mobilenet_v2", **SMALL)
    hlos = lower_model(model, SMALL["image_size"])
    assert set(hlos) == {"monolithic", "stage0", "stage1", "stage2", "stage3"}
    for text in hlos.values():
        assert text.startswith("HloModule")
