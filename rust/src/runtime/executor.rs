//! The executor thread: sole owner of the PJRT client.
//!
//! Programs (monolithic models or stages) are registered once with their
//! weights; weights are uploaded to device-resident buffers at registration
//! so the per-request hot path uploads only the activation (§Perf-L3
//! optimization — the `resident=false` mode keeps the naive
//! literal-per-call path for before/after comparison).

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::{Runtime, Tensor};

/// Identifies a registered program (e.g. `"mobilenet_v2/stage0"`).
pub type ProgramKey = String;

/// Aggregate executor statistics.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub programs: usize,
    pub executions: u64,
    pub exec_time: Duration,
    pub upload_time: Duration,
}

enum Msg {
    Register {
        key: ProgramKey,
        artifact: String,
        weights: Vec<Tensor>,
        resident: bool,
        reply: mpsc::Sender<Result<()>>,
    },
    Execute {
        key: ProgramKey,
        input: Tensor,
        reply: mpsc::Sender<Result<(Tensor, Duration)>>,
    },
    Stats {
        reply: mpsc::Sender<ExecStats>,
    },
    /// Stop the executor loop even while other handles hold senders.
    Shutdown,
}

struct Program {
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    /// Device-resident weights (hot path).
    buffers: Vec<xla::PjRtBuffer>,
    /// Host literals (naive path, kept for §Perf baseline runs).
    literals: Vec<xla::Literal>,
    resident: bool,
}

/// Executor thread owner; dropping it shuts the thread down.
pub struct ExecServer {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<()>>,
}

/// Cloneable, `Send` handle used by the coordinator and node simulators.
#[derive(Clone)]
pub struct ExecHandle {
    tx: mpsc::Sender<Msg>,
}

impl ExecServer {
    /// Spawn the executor thread (creates the PJRT CPU client inside it).
    pub fn start() -> Result<ExecServer> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new()
            .name("carbonedge-executor".into())
            .spawn(move || {
                let mut rt = match Runtime::cpu() {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut programs: HashMap<ProgramKey, Program> = HashMap::new();
                let mut stats = ExecStats::default();
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Register { key, artifact, weights, resident, reply } => {
                            let r =
                                register(&mut rt, &mut programs, key, &artifact, weights, resident);
                            stats.programs = programs.len();
                            let _ = reply.send(r);
                        }
                        Msg::Execute { key, input, reply } => {
                            let r = execute(&rt, &programs, &key, input, &mut stats);
                            let _ = reply.send(r);
                        }
                        Msg::Stats { reply } => {
                            let _ = reply.send(stats.clone());
                        }
                        Msg::Shutdown => break,
                    }
                }
            })?;
        ready_rx.recv().map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(ExecServer { tx, join: Some(join) })
    }

    pub fn handle(&self) -> ExecHandle {
        ExecHandle { tx: self.tx.clone() }
    }
}

impl Drop for ExecServer {
    fn drop(&mut self) {
        // An explicit shutdown message stops the loop even while cloned
        // ExecHandles still hold senders (closing the channel alone would
        // deadlock the join below).
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn register(
    rt: &mut Runtime,
    programs: &mut HashMap<ProgramKey, Program>,
    key: ProgramKey,
    artifact: &str,
    weights: Vec<Tensor>,
    resident: bool,
) -> Result<()> {
    let exe = rt.load(artifact)?;
    let mut buffers = Vec::new();
    let mut literals = Vec::new();
    if resident {
        for w in &weights {
            buffers.push(rt.upload(w)?);
        }
    } else {
        for w in &weights {
            literals.push(w.to_literal()?);
        }
    }
    programs.insert(key, Program { exe, buffers, literals, resident });
    Ok(())
}

fn execute(
    rt: &Runtime,
    programs: &HashMap<ProgramKey, Program>,
    key: &str,
    input: Tensor,
    stats: &mut ExecStats,
) -> Result<(Tensor, Duration)> {
    let prog = programs.get(key).ok_or_else(|| anyhow!("program {key:?} not registered"))?;
    // lint: allow(D2 PJRT execution is timed on the real clock)
    let t0 = Instant::now();
    let out = if prog.resident {
        let up0 = Instant::now(); // lint: allow(D2 PJRT upload is timed on the real clock)
        let x = rt.upload(&input)?;
        stats.upload_time += up0.elapsed();
        let mut args: Vec<&xla::PjRtBuffer> = prog.buffers.iter().collect();
        args.push(&x);
        rt.execute_buffers(&prog.exe, &args)?
    } else {
        let input_lit = input.to_literal()?;
        let mut args: Vec<&xla::Literal> = prog.literals.iter().collect();
        args.push(&input_lit);
        let outs = prog.exe.execute(&args)?;
        let lit = outs[0][0].to_literal_sync()?.to_tuple1()?;
        Tensor::from_literal(&lit)?
    };
    let dt = t0.elapsed();
    stats.executions += 1;
    stats.exec_time += dt;
    Ok((out, dt))
}

impl ExecHandle {
    /// Register a program (idempotent per key; re-registering replaces it).
    pub fn register(
        &self,
        key: &str,
        artifact: &str,
        weights: Vec<Tensor>,
        resident: bool,
    ) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Register {
                key: key.to_string(),
                artifact: artifact.to_string(),
                weights,
                resident,
                reply,
            })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    /// Execute a registered program; returns output + real device time.
    pub fn execute(&self, key: &str, input: Tensor) -> Result<(Tensor, Duration)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Execute { key: key.to_string(), input, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    pub fn stats(&self) -> Result<ExecStats> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Msg::Stats { reply }).map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))
    }
}
