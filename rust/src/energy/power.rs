//! Simulated power sources standing in for the paper's measurement stack
//! (DESIGN.md §7): RAPL (CPU), nvidia-smi/pynvml (GPU), and the paper's
//! fixed 0.375 W/GB DDR4 RAM estimate (Sec. III-B1).

/// The paper's RAM power constant: 0.375 W per gigabyte (DDR4).
pub const RAM_WATTS_PER_GB: f64 = 0.375;

/// A utilization-driven power source.
pub trait PowerModel {
    /// Power draw in watts at utilization `util` ∈ [0, 1].
    fn power_watts(&self, util: f64) -> f64;
}

/// Simulated RAPL (Running Average Power Limit) CPU package power:
/// linear idle→peak in utilization, the standard first-order model.
#[derive(Debug, Clone, Copy)]
pub struct CpuRapl {
    pub idle_w: f64,
    pub peak_w: f64,
}

impl PowerModel for CpuRapl {
    fn power_watts(&self, util: f64) -> f64 {
        let u = util.clamp(0.0, 1.0);
        self.idle_w + u * (self.peak_w - self.idle_w)
    }
}

/// Simulated GPU power (nvidia-smi / pynvml equivalent).
#[derive(Debug, Clone, Copy)]
pub struct GpuSim {
    pub idle_w: f64,
    pub peak_w: f64,
}

impl PowerModel for GpuSim {
    fn power_watts(&self, util: f64) -> f64 {
        let u = util.clamp(0.0, 1.0);
        self.idle_w + u * (self.peak_w - self.idle_w)
    }
}

/// RAM power: capacity-proportional constant draw (paper Sec. III-B1).
#[derive(Debug, Clone, Copy)]
pub struct RamPower {
    pub gb: f64,
}

impl RamPower {
    pub fn new(gb: f64) -> RamPower {
        assert!(gb >= 0.0);
        RamPower { gb }
    }
}

impl PowerModel for RamPower {
    fn power_watts(&self, _util: f64) -> f64 {
        self.gb * RAM_WATTS_PER_GB
    }
}

/// The full host: CPU + GPU + RAM (the three sources of Eq. 1).
#[derive(Debug, Clone, Copy)]
pub struct HostPowerModel {
    pub cpu: CpuRapl,
    pub gpu: GpuSim,
    pub ram: RamPower,
}

impl HostPowerModel {
    pub fn power_watts(&self, cpu_util: f64, gpu_util: f64) -> f64 {
        self.cpu.power_watts(cpu_util) + self.gpu.power_watts(gpu_util) + self.ram.power_watts(0.0)
    }

    /// Idle floor of the host.
    pub fn idle_watts(&self) -> f64 {
        self.power_watts(0.0, 0.0)
    }

    /// Full-load draw of the host (both sources saturated).
    pub fn rated_watts(&self) -> f64 {
        self.power_watts(1.0, 1.0)
    }

    /// Dynamic (above-idle) power at the given utilizations.
    pub fn dynamic_watts(&self, cpu_util: f64, gpu_util: f64) -> f64 {
        self.power_watts(cpu_util, gpu_util) - self.idle_watts()
    }

    /// Project this host onto the simulator's two-part node model:
    /// `(rated_power_w, idle_w)` for a [`crate::node::NodeSpec`]. The
    /// simulator charges `idle_w` across virtual uptime and
    /// `rated - idle` per busy millisecond, which reproduces this model's
    /// `power_watts` at both utilization extremes.
    pub fn node_power_split(&self) -> (f64, f64) {
        (self.rated_watts(), self.idle_watts())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rapl_linear() {
        let c = CpuRapl { idle_w: 10.0, peak_w: 110.0 };
        assert_eq!(c.power_watts(0.0), 10.0);
        assert_eq!(c.power_watts(1.0), 110.0);
        assert_eq!(c.power_watts(0.25), 35.0);
        // clamping
        assert_eq!(c.power_watts(-1.0), 10.0);
        assert_eq!(c.power_watts(2.0), 110.0);
    }

    #[test]
    fn ram_paper_constant() {
        // 1 GB -> 0.375 W, 512 MB -> 0.1875 W (paper Sec. III-B1)
        assert_eq!(RamPower::new(1.0).power_watts(0.5), 0.375);
        assert_eq!(RamPower::new(0.5).power_watts(0.0), 0.1875);
        assert_eq!(RamPower::new(64.0).power_watts(0.0), 24.0);
    }

    #[test]
    fn host_composition() {
        let h = HostPowerModel {
            cpu: CpuRapl { idle_w: 40.0, peak_w: 240.0 },
            gpu: GpuSim { idle_w: 60.0, peak_w: 400.0 },
            ram: RamPower::new(64.0),
        };
        assert_eq!(h.idle_watts(), 124.0);
        assert_eq!(h.power_watts(1.0, 1.0), 664.0);
        assert_eq!(h.dynamic_watts(1.0, 0.0), 200.0);
        assert_eq!(h.dynamic_watts(0.0, 0.0), 0.0);
        assert_eq!(h.rated_watts(), 664.0);
        assert_eq!(h.node_power_split(), (664.0, 124.0));
    }
}
