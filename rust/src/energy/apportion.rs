//! Per-container energy apportioning.
//!
//! The paper (Sec. IV-A1, V-A) measures energy at the *host* level
//! (CodeCarbon machine mode) and apportions it to Docker containers
//! proportionally to their cgroup resource quotas — "an accounting method,
//! not direct per-container measurement". Both that method and the
//! active-attribution variant (dynamic energy charged to the container
//! that executed the task) are implemented; experiments default to
//! active attribution (DESIGN.md §3) and tests compare the two.

use std::collections::BTreeMap;

/// How host energy is attributed to containers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApportionMode {
    /// The paper's accounting: share = quota_i / Σ quota (idle + dynamic).
    QuotaProportional,
    /// Dynamic energy goes to the active container; idle energy is split
    /// by quota share.
    ActiveAttribution,
}

/// Splits host energy among named containers.
#[derive(Debug, Clone)]
pub struct Apportioner {
    pub mode: ApportionMode,
    /// container -> cpu quota (the Docker `--cpus` value).
    quotas: BTreeMap<String, f64>,
}

impl Apportioner {
    pub fn new(mode: ApportionMode, quotas: &[(&str, f64)]) -> Apportioner {
        let map: BTreeMap<String, f64> =
            quotas.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        assert!(!map.is_empty(), "no containers");
        assert!(map.values().all(|&q| q > 0.0), "quotas must be positive");
        Apportioner { mode, quotas: map }
    }

    pub fn quota(&self, name: &str) -> Option<f64> {
        self.quotas.get(name).copied()
    }

    pub fn total_quota(&self) -> f64 {
        self.quotas.values().sum()
    }

    /// Quota share of a container (the paper's accounting ratio).
    pub fn share(&self, name: &str) -> f64 {
        self.quota(name).map(|q| q / self.total_quota()).unwrap_or(0.0)
    }

    /// Attribute one measurement window.
    ///
    /// * `idle_j`: host idle-floor energy during the window.
    /// * `dynamic_j`: above-idle energy during the window.
    /// * `active`: container that executed work during the window (if any).
    ///
    /// Returns container -> joules. Total is conserved exactly.
    pub fn attribute(
        &self,
        idle_j: f64,
        dynamic_j: f64,
        active: Option<&str>,
    ) -> BTreeMap<String, f64> {
        let mut out: BTreeMap<String, f64> = BTreeMap::new();
        match self.mode {
            ApportionMode::QuotaProportional => {
                for name in self.quotas.keys() {
                    out.insert(name.clone(), (idle_j + dynamic_j) * self.share(name));
                }
            }
            ApportionMode::ActiveAttribution => {
                for name in self.quotas.keys() {
                    out.insert(name.clone(), idle_j * self.share(name));
                }
                match active {
                    Some(name) if self.quotas.contains_key(name) => {
                        *out.get_mut(name).unwrap() += dynamic_j;
                    }
                    _ => {
                        // No active container: dynamic energy falls back to
                        // quota shares so nothing is lost.
                        for name in self.quotas.keys().cloned().collect::<Vec<_>>() {
                            let s = self.share(&name);
                            *out.get_mut(&name).unwrap() += dynamic_j * s;
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes() -> Apportioner {
        // The paper's three nodes: 1.0 / 0.6 / 0.4 CPUs.
        Apportioner::new(
            ApportionMode::QuotaProportional,
            &[("node-high", 1.0), ("node-medium", 0.6), ("node-green", 0.4)],
        )
    }

    #[test]
    fn quota_shares_paper_setup() {
        let a = nodes();
        assert!((a.total_quota() - 2.0).abs() < 1e-12);
        assert!((a.share("node-high") - 0.5).abs() < 1e-12);
        assert!((a.share("node-medium") - 0.3).abs() < 1e-12);
        assert!((a.share("node-green") - 0.2).abs() < 1e-12);
        assert_eq!(a.share("nope"), 0.0);
    }

    #[test]
    fn quota_proportional_conserves() {
        let a = nodes();
        let out = a.attribute(100.0, 50.0, Some("node-green"));
        let total: f64 = out.values().sum();
        assert!((total - 150.0).abs() < 1e-9);
        // active container irrelevant in this mode
        assert!((out["node-high"] - 75.0).abs() < 1e-9);
    }

    #[test]
    fn active_attribution_charges_worker() {
        let mut a = nodes();
        a.mode = ApportionMode::ActiveAttribution;
        let out = a.attribute(100.0, 50.0, Some("node-green"));
        // idle split 50/30/20, green also gets all 50 dynamic
        assert!((out["node-green"] - (20.0 + 50.0)).abs() < 1e-9);
        assert!((out["node-high"] - 50.0).abs() < 1e-9);
        let total: f64 = out.values().sum();
        assert!((total - 150.0).abs() < 1e-9);
    }

    #[test]
    fn active_attribution_without_active_falls_back() {
        let mut a = nodes();
        a.mode = ApportionMode::ActiveAttribution;
        let out = a.attribute(10.0, 20.0, None);
        let total: f64 = out.values().sum();
        assert!((total - 30.0).abs() < 1e-9);
        assert!((out["node-high"] - 15.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn zero_quota_rejected() {
        Apportioner::new(ApportionMode::QuotaProportional, &[("x", 0.0)]);
    }
}
