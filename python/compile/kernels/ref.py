"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the kernels are validated against in
``python/tests/test_kernels.py`` (hypothesis shape sweeps + allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import apply_act
from .depthwise import same_pad


def ref_matmul_bias_act(x, w, b, act: str = "none"):
    return apply_act(
        jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32),
        act,
    )


def ref_depthwise3x3(x, w, b, stride: int = 1, act: str = "none"):
    """Depthwise 3x3 conv via lax.conv_general_dilated (feature groups)."""
    h, wdt, c = x.shape
    _, plo_h, phi_h = same_pad(h, 3, stride)
    _, plo_w, phi_w = same_pad(wdt, 3, stride)
    lhs = x.astype(jnp.float32)[None]  # NHWC
    rhs = w.astype(jnp.float32)[:, :, None, :]  # HWIO with I=1, O=C (grouped)
    out = jax.lax.conv_general_dilated(
        lhs,
        rhs,
        window_strides=(stride, stride),
        padding=((plo_h, phi_h), (plo_w, phi_w)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )[0]
    return apply_act(out + b.astype(jnp.float32)[None, None, :], act)


def ref_avgpool_global(x):
    return jnp.mean(x.astype(jnp.float32), axis=(0, 1))
