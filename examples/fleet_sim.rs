//! Fleet simulation tour (the L3.5 virtual-time layer): replay the paper's
//! 3-node testbed open-loop, sweep the carbon weight at fleet scale, watch
//! a churning fleet migrate its queues, see idle-floor accounting make
//! consolidation visible, park morning-peak work for the midday solar
//! trough with in-engine deferral, put PV + battery microgrids behind
//! the fleet, let the joint defer+route scheduler answer *where and
//! when* in one verdict, watch grid-charge arbitrage buy clean night
//! energy against a duck curve with SoC-trajectory forecasts pricing the
//! release slots truthfully, batch a three-class multi-tenant mix into
//! shared service slots that amortize the idle floor, trace a single
//! defer decision end-to-end through the NDJSON event firehose and fold
//! the trace back into the full report with the replay engine, then
//! follow the sun across three regional sites whose PV windows rotate
//! around the clock — the cross-site deadline router against every
//! single-site green baseline — all in a few wall-clock seconds, no
//! artifacts required.
//!
//! ```sh
//! cargo run --release --example fleet_sim -- [--requests 20000] [--seed 42]
//! ```

use carbonedge::experiments as exp;
use carbonedge::obs::{replay, FirehoseSink};
use carbonedge::scheduler::{CarbonAwareScheduler, Mode};
use carbonedge::sim::{scenarios, Simulation};
use carbonedge::util::cli::Args;
use carbonedge::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let requests = args.parse_or("requests", 20_000usize)?;
    let seed = args.parse_or("seed", 42u64)?;

    // 1. The paper's qualitative result in virtual time: monolithic host
    //    vs the three CE modes under contention (6 req/s open loop).
    let paper = scenarios::build("paper-3-node", 0, requests, seed).unwrap();
    let reports = exp::sim_mode_comparison(&paper);
    println!("{}", exp::sim_comparison_render(&reports));

    // 2. Fig. 3 at fleet scale: w_C sweep over a 50-node heterogeneous
    //    fleet synthesized from the REGIONS table.
    let fleet = scenarios::build("fleet-100", 50, requests, seed).unwrap();
    let points = exp::sim_weight_sweep(&fleet, 0.25);
    println!("{}", exp::sim_sweep_render(&points));

    // 3. Churn: nodes leave mid-run, queued work migrates (against freshly
    //    refreshed grid intensities), nothing lands on a departed node.
    let churn = scenarios::build("churn", 0, requests, seed).unwrap();
    let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
    let r = Simulation::run(&churn, &mut sched);
    println!("{}", r.render());
    println!("churn: {} migrated, {} rejected", r.migrated, r.rejected);

    // 4. Consolidation: the same workload on 3 busy nodes vs 12 mostly-idle
    //    ones — idle floors (HostPowerModel: ~54 W of the ~142 W rated) are
    //    what make "fewer, busier nodes" measurably greener.
    let (small, large) = exp::sim_consolidation(3, 12, requests, seed);
    println!("{}", exp::sim_consolidation_render(&small, &large));

    // 5. In-engine deferral on a real-shape day curve (bundled
    //    ElectricityMaps-style CSV): arrivals get 6 h of slack and the
    //    engine parks dirty-hour work until cleaner forecast slots.
    let day = scenarios::build("real-trace", 0, requests, seed).unwrap();
    let (deferred, baseline) = exp::sim_deferral_comparison(&day);
    println!("{}", exp::sim_deferral_render(&deferred, &baseline));

    // 6. Microgrids: a day on PV + battery-backed nodes (400 W arrays,
    //    600 Wh batteries) vs the identical grid-only fleet, plus what
    //    carbon-aware routing adds over round-robin — the sun covers the
    //    day, the battery bridges the evening, the grid fills pre-dawn.
    let (mg_green, plain_green, mg_rr) = exp::sim_microgrid(0, requests, seed);
    println!("{}", exp::sim_microgrid_render(&mg_green, &plain_green, &mg_rr));

    // 7. Joint defer+route: the deferral-routing scenario (zone fleet,
    //    single service slots, ~1 s tasks) under the DeferAwareGreen
    //    scheduler's one-verdict API vs the legacy route-then-defer gate.
    //    Route-then-defer stampedes the clean zone at its trough and
    //    spills onto dirty grids; the joint verdict parks spill arrivals
    //    for *other* nodes' troughs and spreads releases across the
    //    plateau — fewer gCO2/req, no extra deadline misses.
    let dr = scenarios::build("deferral-routing", 0, requests, seed).unwrap();
    let (joint, rtd) = exp::sim_deferral_routing_comparison(&dr);
    println!("{}", exp::sim_deferral_routing_render(&joint, &rtd));

    // 8. Grid-charge arbitrage + SoC-trajectory forecasts: duck-curve
    //    grid, batteries that buy cheap clean night energy (carried at
    //    its embodied intensity by the stored-carbon ledger — never
    //    laundered to zero) and an A/B/C against the charge-off twin and
    //    the legacy charge-frozen forecasts, which defer evening work
    //    onto batteries that are empty by the release slot. The
    //    trajectory forecasts (Microgrid::project) price release slots
    //    against the battery each node will actually have.
    let (arb, off, frozen) = exp::sim_arbitrage(0, requests.min(8_000), seed);
    println!("{}", exp::sim_arbitrage_render(&arb, &off, &frozen));

    // 9. Batched multi-tenant serving: one hot model, three deadline
    //    tiers (interactive 3 s / standard 10 s / background 60 s), an
    //    idle-heavy accelerator host under 1.3x overload — vs the
    //    identical fleet serving one task per slot. Requests that share
    //    a service slot amortize the ~100 W idle floor and ride the
    //    sub-linear batch power curve (b^0.2), so batch formation cuts
    //    gCO2/req while the faster queue drain holds p99; the report
    //    grows per-class rows (completions, SLO misses, batch fill,
    //    attributed energy/carbon).
    let bs = scenarios::build("batch-serving", 0, requests.min(8_000), seed).unwrap();
    let (batched, unbatched) = exp::sim_batching_comparison(&bs);
    println!("{}", exp::sim_batching_render(&batched, &unbatched));

    // 10. Observability: trace one defer decision end-to-end through the
    //    NDJSON event firehose. Every arrival, verdict (with per-candidate
    //    scores and the forecast slot each node would offer), dispatch,
    //    deferred release and completion streams as one JSON object per
    //    line — into a Vec here, onto disk via
    //    `carbonedge sim --trace-out trace.ndjson` in the CLI. Below: the
    //    first request the route-then-defer gate parks, followed through
    //    its release re-decision, dispatch and completion, then the
    //    telemetry that rode along (event counters plus queue-delay /
    //    latency / decide-overhead histograms vs the paper's 0.03 ms
    //    scheduling budget).
    let day = scenarios::build("real-trace", 0, requests.min(8_000), seed).unwrap();
    let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
    let mut sink = FirehoseSink::new(Vec::new());
    let (live, telem) =
        Simulation::try_run_observed(&day, &mut sched, &mut sink).expect("valid scenario");
    let ndjson = String::from_utf8(sink.finish()?)?;
    println!("one deferred request, end to end (raw firehose lines):");
    let mut tracked = None;
    for line in ndjson.lines() {
        let ev = Json::parse(line).expect("firehose lines are valid JSON");
        let arrival = ev.get("arrival_s").and_then(Json::as_f64);
        match tracked {
            None if ev.req_str("kind")? == "decision"
                && ev.req_str("verdict")? == "defer" =>
            {
                tracked = arrival;
                println!("  {line}");
            }
            Some(a) if arrival == Some(a) => println!("  {line}"),
            _ => {}
        }
    }
    print!("{}", telem.render());

    // The firehose is a verifiable source of truth, not just a log: fold
    // the NDJSON back through the replay state machine and the *entire*
    // report — per-node and per-class counters, idle/dynamic/pv/battery/
    // grid energy splits, Eq. 2 carbon, latency and wait percentiles —
    // reconstructs from events alone, then audits field by field against
    // the live run. From disk the same loop is
    // `carbonedge replay trace.ndjson --verify`.
    let (replayed, events) =
        replay::replay_report(ndjson.as_bytes()).expect("well-formed trace");
    let mismatches = replay::verify(&replayed, &live);
    assert!(mismatches.is_empty(), "replay diverged: {mismatches:?}");
    println!(
        "replayed {events} events -> report matches the live run \
         ({} completed, {:.3} gCO2)",
        replayed.completed, replayed.carbon_g_total
    );

    // And two traces diff in lockstep: a seed-perturbed twin announces
    // itself at the first divergent event — here the run_meta header,
    // which carries the seed. On disk: `carbonedge replay --diff A B`.
    let twin_day = scenarios::build("real-trace", 0, requests.min(8_000), seed + 1).unwrap();
    let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
    let mut twin_sink = FirehoseSink::new(Vec::new());
    Simulation::try_run_observed(&twin_day, &mut sched, &mut twin_sink)
        .expect("valid scenario");
    let twin = String::from_utf8(twin_sink.finish()?)?;
    let d = replay::diff(ndjson.as_bytes(), twin.as_bytes())
        .expect("both traces are well-formed")
        .expect("a perturbed seed must diverge");
    println!("seed-perturbed twin: {}", d.render());

    // 11. Follow the sun: three regional sites 8 h apart, each behind a
    //    3x-rated PV array whose window covers a third of the day, linked
    //    by 60 ms WAN hops whose transfer energy is priced into Eq. 2 at
    //    the origin grid. The cross-site router picks the region whose
    //    grid/PV eats each request *before* the local scheduler places it
    //    within the site: nearest (never ships) pays the home grid all
    //    night, carbon-greedy chases the sun but eats WAN latency
    //    blindly, and the deadline-feasible router ships only when the
    //    hop + remote queue still clear the SLO. Then the honest
    //    baseline: the whole planet's demand forced through each single
    //    region in green mode — the best of those twins is what "just
    //    pick the greenest site" costs, and the router beats it well
    //    under the 0.9x acceptance margin with zero missed deadlines.
    let sun = scenarios::build("follow-the-sun", 0, requests.min(8_000), seed).unwrap();
    let routed = exp::sim_router_comparison(&sun);
    println!("{}", exp::sim_router_render(&routed));
    let layer = sun.sites.as_ref().expect("geographic scenario");
    let best_single = (0..layer.sites.len())
        .map(|s| {
            let twin = scenarios::single_site_twin(&sun, s);
            let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
            Simulation::run(&twin, &mut sched)
        })
        .min_by(|a, b| a.carbon_per_req_g.total_cmp(&b.carbon_per_req_g))
        .expect("at least one site");
    let deadline = &routed[2];
    println!(
        "follow-the-sun: deadline router {:.6} gCO2/req vs best single site \
         {} at {:.6} ({:.2}x, {} missed deadlines)",
        deadline.carbon_per_req_g,
        best_single.scenario,
        best_single.carbon_per_req_g,
        deadline.carbon_per_req_g / best_single.carbon_per_req_g,
        deadline.deadline_missed,
    );
    Ok(())
}
