//! Host-side tensor: flat f32 data + shape, with Literal conversions and
//! binary (de)serialization matching the aot.py sidecar format.

use anyhow::{bail, Context, Result};

/// A dense row-major f32 tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Bytes this tensor occupies (f32).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Convert to an XLA literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::new(dims, data)
    }

    /// Parse a little-endian f32 binary file (aot.py `.bin` sidecars).
    pub fn from_bin_file(path: &str, shape: Vec<usize>) -> Result<Tensor> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        let data = f32_from_le_bytes(&bytes)?;
        Tensor::new(shape, data)
    }

    /// Slice a sub-tensor out of a flat buffer (weight unpacking).
    pub fn from_flat(flat: &[f32], offset: usize, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if offset + n > flat.len() {
            bail!("weight slice {}..{} out of bounds ({})", offset, offset + n, flat.len());
        }
        Tensor::new(shape, flat[offset..offset + n].to_vec())
    }
}

/// Decode little-endian f32s.
pub fn f32_from_le_bytes(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("binary length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn zeros_and_size() {
        let t = Tensor::zeros(vec![4, 2]);
        assert_eq!(t.len(), 8);
        assert_eq!(t.size_bytes(), 32);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_flat_slices() {
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let t = Tensor::from_flat(&flat, 2, vec![2, 2]).unwrap();
        assert_eq!(t.data, vec![2.0, 3.0, 4.0, 5.0]);
        assert!(Tensor::from_flat(&flat, 8, vec![2]).is_ok());
        assert!(Tensor::from_flat(&flat, 8, vec![3]).is_err());
    }

    #[test]
    fn le_bytes_roundtrip() {
        let vals = [1.5f32, -2.25, 0.0, f32::MAX];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(f32_from_le_bytes(&bytes).unwrap(), vals);
        assert!(f32_from_le_bytes(&bytes[..5]).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        // Requires the PJRT-free literal API only.
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }
}
