//! Observability: a zero-overhead-when-off event firehose plus in-process
//! telemetry for the discrete-event simulator.
//!
//! The paper evaluates CarbonEdge end-to-end — carbon per decision, 0.03 ms
//! scheduling overhead, deferral behaviour — but the numbers it reports are
//! aggregates. This module exposes the *per-event* stream behind those
//! aggregates so individual verdicts can be audited: why a task was routed
//! to node X, which forecast slot a defer parked it for, and what each
//! microgrid settlement slice cost.
//!
//! Five pieces:
//!
//! - [`TraceEvent`] / [`EventSink`] — the simulator's hot paths
//!   ([`crate::sim::Simulation::try_run_observed`]) emit borrowed,
//!   enum-dispatched events at every arrival, scheduling decision,
//!   dispatch, deferred release, completion, churn transition, microgrid
//!   settlement slice, idle-floor accrual and monitor alert, plus one
//!   [`TraceEvent::RunMeta`] header per run. With no sink attached (the
//!   default `run`/`try_run` entry points) no event is ever constructed —
//!   the off path is a dead branch, not a null write.
//! - [`FirehoseSink`] — streams one NDJSON object per event through
//!   [`crate::util::json::JsonWriter`]; no intermediate tree, no in-memory
//!   event buffer, so a 10M-request run streams to disk in constant
//!   memory. [`TraceFilter`] drops kinds before serialisation.
//! - [`Telemetry`] — monotonic per-kind counters plus log2 histograms for
//!   queue delay, end-to-end latency, and per-decision wall-clock
//!   overhead, guarded against the paper's 0.03 ms envelope
//!   ([`OVERHEAD_ENVELOPE_NS`]).
//! - [`replay`] — the audit side of the firehose: a streaming
//!   [`replay::FirehoseReader`] feeds a [`replay::ReplayState`] machine
//!   that reconstructs a full [`crate::sim::SimReport`] *purely from
//!   events* (`carbonedge replay trace.ndjson`), and
//!   [`replay::diff`] pinpoints the first divergent event between two
//!   traces for determinism debugging (`carbonedge replay --diff A B`).
//! - [`monitor`] — in-sim sliding-window rules ([`monitor::MonitorSet`])
//!   evaluated on each emitted event over *virtual* time: carbon
//!   burn-rate vs a gCO2/s budget, per-class SLO-miss burn rate, and
//!   reject/defer rate. Crossing a threshold fires an
//!   [`EventKind::Alert`] into the firehose; per-rule summaries land in
//!   [`Telemetry`] and the sim report.
//!
//! Tracing must never perturb the simulation: the engine asserts (in tests)
//! that a fully-traced run produces a bit-identical
//! [`crate::sim::SimReport`] to an untraced one — with or without
//! monitors attached (their summaries live in a separate report field).

pub mod monitor;
pub mod replay;
mod telemetry;

pub use monitor::{AlertFire, CarbonBudget, MonitorSet, MonitorSummary};
pub use replay::{FirehoseReader, ReplayState};
pub use telemetry::{Log2Histogram, Telemetry, OVERHEAD_ENVELOPE_NS};

use std::io;

use crate::scheduler::{DecisionExplain, RejectReason, SchedulingDecision};
use crate::util::json::JsonWriter;

/// The twelve trace event kinds, used for filtering and counting.
/// Discriminants index [`Telemetry::events`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Arrival = 0,
    Decision = 1,
    Dispatch = 2,
    DeferRelease = 3,
    Completion = 4,
    Churn = 5,
    MicrogridSlice = 6,
    BatchFormed = 7,
    /// A [`monitor::MonitorSet`] rule crossed its threshold.
    Alert = 8,
    /// An idle-floor accrual interval closed on a node (power-off or the
    /// simulation horizon) — what makes uptime and idle energy/carbon
    /// reconstructible from the stream.
    IdleSlice = 9,
    /// One per run, first in the stream: scenario/scheduler/seed plus the
    /// node and class rosters, so a replay needs nothing but the trace.
    RunMeta = 10,
    /// A cross-site [`crate::site::Router`] shipped a request to a
    /// non-home site over the WAN: the hop's latency and transfer energy,
    /// and the carbon that energy cost at the origin grid.
    WanHop = 11,
}

impl EventKind {
    pub const COUNT: usize = 12;
    pub const ALL: [EventKind; EventKind::COUNT] = [
        EventKind::Arrival,
        EventKind::Decision,
        EventKind::Dispatch,
        EventKind::DeferRelease,
        EventKind::Completion,
        EventKind::Churn,
        EventKind::MicrogridSlice,
        EventKind::BatchFormed,
        EventKind::Alert,
        EventKind::IdleSlice,
        EventKind::RunMeta,
        EventKind::WanHop,
    ];

    /// Stable label: the `kind` field of every NDJSON line and the token
    /// accepted by `--trace-filter`.
    pub fn label(self) -> &'static str {
        match self {
            EventKind::Arrival => "arrival",
            EventKind::Decision => "decision",
            EventKind::Dispatch => "dispatch",
            EventKind::DeferRelease => "defer_release",
            EventKind::Completion => "completion",
            EventKind::Churn => "churn",
            EventKind::MicrogridSlice => "mg_slice",
            EventKind::BatchFormed => "batch_formed",
            EventKind::Alert => "alert",
            EventKind::IdleSlice => "idle_slice",
            EventKind::RunMeta => "run_meta",
            EventKind::WanHop => "wan_hop",
        }
    }

    pub fn parse(s: &str) -> Option<EventKind> {
        match s {
            "arrival" => Some(EventKind::Arrival),
            "decision" => Some(EventKind::Decision),
            "dispatch" => Some(EventKind::Dispatch),
            "defer_release" | "defer" => Some(EventKind::DeferRelease),
            "completion" => Some(EventKind::Completion),
            "churn" => Some(EventKind::Churn),
            "mg_slice" | "microgrid" => Some(EventKind::MicrogridSlice),
            "batch_formed" | "batch" => Some(EventKind::BatchFormed),
            "alert" => Some(EventKind::Alert),
            "idle_slice" | "idle" => Some(EventKind::IdleSlice),
            "run_meta" | "meta" => Some(EventKind::RunMeta),
            "wan_hop" | "wan" => Some(EventKind::WanHop),
            _ => None,
        }
    }

    fn bit(self) -> u16 {
        1 << (self as u16)
    }
}

/// Bitmask over [`EventKind`]s a sink cares about. `u16` leaves headroom
/// past the current eleven kinds (the original `u8` saturated at eight).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceFilter(u16);

impl TraceFilter {
    pub fn all() -> TraceFilter {
        TraceFilter((1 << EventKind::COUNT as u16) - 1)
    }

    pub fn none() -> TraceFilter {
        TraceFilter(0)
    }

    pub fn contains(&self, kind: EventKind) -> bool {
        self.0 & kind.bit() != 0
    }

    pub fn with(mut self, kind: EventKind) -> TraceFilter {
        self.0 |= kind.bit();
        self
    }

    /// Parse a comma-separated kind list (`"decision,completion"`), or
    /// `"all"`. Unknown tokens are an error listing the valid labels.
    pub fn parse(spec: &str) -> Result<TraceFilter, String> {
        if spec.trim() == "all" {
            return Ok(TraceFilter::all());
        }
        let mut f = TraceFilter::none();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            match EventKind::parse(tok) {
                Some(k) => f = f.with(k),
                None => {
                    let valid: Vec<&str> = EventKind::ALL.iter().map(|k| k.label()).collect();
                    return Err(format!(
                        "unknown trace kind {tok:?}; expected \"all\" or a comma list of {}",
                        valid.join(", ")
                    ));
                }
            }
        }
        if f == TraceFilter::none() {
            return Err("empty trace filter; expected \"all\" or a comma list of kinds".into());
        }
        Ok(f)
    }
}

/// One simulator event, borrowed from engine state — sinks serialise or
/// aggregate in place, the engine never allocates to emit. Times are
/// virtual (experiment-clock seconds) except `decide_ns`, which is
/// wall-clock.
#[derive(Debug)]
pub enum TraceEvent<'a> {
    /// A request entered the system. `deadline_s` is `f64::INFINITY` when
    /// the scenario has no deferral window (serialised as `null`);
    /// `class` is the workload-class draw (0 without a mix). Classes
    /// never change after arrival, so per-class reject counts fall out
    /// of replay conservation just like the fleet-level one.
    Arrival { t_s: f64, deadline_s: f64, class: usize },
    /// A scheduling verdict, with the per-candidate rationale gathered by
    /// [`crate::scheduler::Scheduler::decide_explained`]. `ctx` says what
    /// triggered the decision: `"arrival"`, `"release"` (a deferred task
    /// re-deciding), or `"migration"` (churn-down drain).
    Decision {
        t_s: f64,
        arrival_s: f64,
        ctx: &'static str,
        verdict: SchedulingDecision,
        /// Assigned node's name, when the verdict is `Assign`.
        node: Option<&'a str>,
        explain: &'a DecisionExplain,
        /// Wall-clock cost of this `decide` call.
        decide_ns: u64,
    },
    /// A task was handed to a node's queue.
    Dispatch { t_s: f64, arrival_s: f64, node: &'a str, queue_delay_est_ms: f64 },
    /// A deferred task woke up for its re-decision.
    DeferRelease { t_s: f64, arrival_s: f64, deadline_s: f64 },
    /// A task finished. `carbon_g` is the grid-attributed operational
    /// carbon; microgrid-backed nodes settle carbon in `MicrogridSlice`
    /// events instead and report `0.0` here. `missed` is the legacy
    /// deadline check, `slo_missed` the per-class SLO check (arrival +
    /// class SLO budget, independent of deferral slack).
    Completion {
        t_s: f64,
        arrival_s: f64,
        node: &'a str,
        class: usize,
        service_ms: f64,
        latency_ms: f64,
        energy_j: f64,
        carbon_g: f64,
        missed: bool,
        slo_missed: bool,
    },
    /// A node went up or down.
    Churn { t_s: f64, node: &'a str, up: bool },
    /// One microgrid settlement slice: the energy flows and carbon accrued
    /// on `node` over `[t0_s, t1_s]`, and the battery state of charge
    /// after the slice. Summing `carbon_g` over these plus `Completion`
    /// carbon replays the run's carbon total (for zero-idle fleets);
    /// `idle_g` is the idle-floor share of `carbon_g` (the rest is
    /// dynamic), and `charge_g` / `battery_g` / `stored_g` carry the
    /// stored-carbon ledger: embodied carbon bought this slice, embodied
    /// carbon released by discharge this slice, and the total still
    /// stored after the slice.
    MicrogridSlice {
        t0_s: f64,
        t1_s: f64,
        node: &'a str,
        pv_j: f64,
        battery_j: f64,
        grid_j: f64,
        grid_charge_j: f64,
        carbon_g: f64,
        idle_g: f64,
        charge_g: f64,
        battery_g: f64,
        stored_g: f64,
        soc: f64,
    },
    /// A batch was sealed and entered service on `node`
    /// ([`crate::sim::BatchSpec`]): `fill` same-class tasks dispatched as
    /// one unit, `head_wait_ms` the time the oldest member spent waiting
    /// for the batch to form (0 for a full-on-arrival seal).
    BatchFormed { t_s: f64, node: &'a str, class: usize, fill: usize, head_wait_ms: f64 },
    /// A monitor rule crossed its threshold ([`monitor::MonitorSet`]):
    /// `value` is the windowed rate that breached `threshold` over the
    /// trailing `window_s` of virtual time. `class` is set for per-class
    /// rules (SLO burn).
    Alert {
        t_s: f64,
        rule: &'static str,
        value: f64,
        threshold: f64,
        window_s: f64,
        class: Option<usize>,
    },
    /// An idle-floor accrual interval closed on `node`: `energy_j` is
    /// `idle_w × (t1_s − t0_s)`, `carbon_g` the piecewise trace-integrated
    /// idle carbon (0 on microgrid nodes, whose idle carbon settles in
    /// `MicrogridSlice` events). Summing `t1_s − t0_s` replays uptime.
    IdleSlice { t0_s: f64, t1_s: f64, node: &'a str, energy_j: f64, carbon_g: f64 },
    /// Run header, emitted once before any other event: everything a
    /// replay needs that is not derivable from the stream itself —
    /// scenario/scheduler/seed/request count plus the node roster (name,
    /// microgrid-backed?) and class roster (name, SLO seconds; empty
    /// without a workload mix).
    RunMeta {
        scenario: &'a str,
        scheduler: &'a str,
        seed: u64,
        requests: u64,
        nodes: &'a [(&'a str, bool)],
        classes: &'a [(&'a str, f64)],
        /// Site roster (multi-site runs; empty — and absent from the
        /// NDJSON line — on flat fleets).
        sites: &'a [&'a str],
        /// Home site index per node, parallel to `nodes` (empty on flat
        /// fleets).
        site_of: &'a [usize],
        /// Cross-site router name (`""` on flat fleets).
        router: &'a str,
    },
    /// A cross-site router shipped a request from its home site over the
    /// WAN: `energy_j` is the transfer energy (billed on top of the node
    /// split), `carbon_g` that energy priced at the origin grid's
    /// ship-time effective intensity. The request re-enters the target
    /// site's queue `latency_ms` later with its original arrival time.
    WanHop {
        t_s: f64,
        from: &'a str,
        to: &'a str,
        latency_ms: f64,
        energy_j: f64,
        carbon_g: f64,
    },
}

impl TraceEvent<'_> {
    pub fn kind(&self) -> EventKind {
        match self {
            TraceEvent::Arrival { .. } => EventKind::Arrival,
            TraceEvent::Decision { .. } => EventKind::Decision,
            TraceEvent::Dispatch { .. } => EventKind::Dispatch,
            TraceEvent::DeferRelease { .. } => EventKind::DeferRelease,
            TraceEvent::Completion { .. } => EventKind::Completion,
            TraceEvent::Churn { .. } => EventKind::Churn,
            TraceEvent::MicrogridSlice { .. } => EventKind::MicrogridSlice,
            TraceEvent::BatchFormed { .. } => EventKind::BatchFormed,
            TraceEvent::Alert { .. } => EventKind::Alert,
            TraceEvent::IdleSlice { .. } => EventKind::IdleSlice,
            TraceEvent::RunMeta { .. } => EventKind::RunMeta,
            TraceEvent::WanHop { .. } => EventKind::WanHop,
        }
    }
}

/// Where trace events go. The engine calls [`EventSink::wants`] before
/// building expensive payloads (decision explains), and [`EventSink::record`]
/// with every event it constructs.
pub trait EventSink {
    fn record(&mut self, ev: &TraceEvent<'_>);

    /// Whether this sink will keep events of `kind`. Used by the engine to
    /// skip building the [`DecisionExplain`] payload when nobody reads it.
    fn wants(&self, kind: EventKind) -> bool {
        let _ = kind;
        true
    }
}

/// Discards everything; `wants` is always false so the engine skips all
/// payload construction. Telemetry is still collected — this is the
/// "counters-only" observation mode.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl EventSink for NullSink {
    #[inline]
    fn record(&mut self, _ev: &TraceEvent<'_>) {}

    #[inline]
    fn wants(&self, _kind: EventKind) -> bool {
        false
    }
}

/// Streams events as NDJSON — one compact JSON object per line — straight
/// through [`JsonWriter`] onto any `io::Write` (typically a
/// `BufWriter<File>`). No event is ever buffered in memory. I/O errors are
/// latched and surfaced by [`FirehoseSink::finish`], so `record` stays
/// infallible on the hot path.
pub struct FirehoseSink<W: io::Write> {
    out: W,
    filter: TraceFilter,
    events_written: u64,
    io_error: Option<io::Error>,
}

impl<W: io::Write> FirehoseSink<W> {
    pub fn new(out: W) -> FirehoseSink<W> {
        FirehoseSink::with_filter(out, TraceFilter::all())
    }

    pub fn with_filter(out: W, filter: TraceFilter) -> FirehoseSink<W> {
        FirehoseSink { out, filter, events_written: 0, io_error: None }
    }

    /// Lines written so far (post-filter).
    pub fn events_written(&self) -> u64 {
        self.events_written
    }

    /// Surface any latched I/O error and hand back the writer (unflushed).
    pub fn finish(self) -> io::Result<W> {
        match self.io_error {
            Some(e) => Err(e),
            None => Ok(self.out),
        }
    }

    fn write_event(&mut self, ev: &TraceEvent<'_>) -> io::Result<()> {
        let j = &mut JsonWriter::new(&mut self.out);
        j.begin_obj()?;
        j.field_str("kind", ev.kind().label())?;
        match *ev {
            TraceEvent::Arrival { t_s, deadline_s, class } => {
                j.field_num("t_s", t_s)?;
                j.field_fnum("deadline_s", deadline_s)?;
                j.field_num("class", class as f64)?;
            }
            TraceEvent::Decision { t_s, arrival_s, ctx, verdict, node, explain, decide_ns } => {
                j.field_num("t_s", t_s)?;
                j.field_num("arrival_s", arrival_s)?;
                j.field_str("ctx", ctx)?;
                match verdict {
                    SchedulingDecision::Assign(_) => {
                        j.field_str("verdict", "assign")?;
                        match node {
                            Some(n) => j.field_str("node", n)?,
                            None => j.field_null("node")?,
                        }
                    }
                    SchedulingDecision::Defer { until_s } => {
                        j.field_str("verdict", "defer")?;
                        j.field_num("until_s", until_s)?;
                    }
                    SchedulingDecision::Reject { reason } => {
                        j.field_str("verdict", "reject")?;
                        let r = match reason {
                            RejectReason::NoFeasibleNode => "no-feasible-node",
                            RejectReason::Overload => "overload",
                        };
                        j.field_str("reason", r)?;
                    }
                }
                j.field_num("decide_ns", decide_ns as f64)?;
                j.key("candidates")?;
                j.begin_arr()?;
                for c in &explain.candidates {
                    j.begin_obj()?;
                    j.field_str("node", &c.node)?;
                    j.field_bool("feasible", c.feasible)?;
                    match c.score {
                        Some(s) => j.field_fnum("score", s)?,
                        None => j.field_null("score")?,
                    }
                    j.field_fnum("intensity", c.intensity)?;
                    j.field_fnum("queue_delay_ms", c.queue_delay_ms)?;
                    match c.best_slot {
                        Some((slot_s, slot_i)) => {
                            j.field_num("slot_s", slot_s)?;
                            j.field_fnum("slot_intensity", slot_i)?;
                        }
                        None => {
                            j.field_null("slot_s")?;
                            j.field_null("slot_intensity")?;
                        }
                    }
                    j.end_obj()?;
                }
                j.end_arr()?;
                match &explain.note {
                    Some(n) => j.field_str("note", n)?,
                    None => j.field_null("note")?,
                }
            }
            TraceEvent::Dispatch { t_s, arrival_s, node, queue_delay_est_ms } => {
                j.field_num("t_s", t_s)?;
                j.field_num("arrival_s", arrival_s)?;
                j.field_str("node", node)?;
                j.field_fnum("queue_delay_est_ms", queue_delay_est_ms)?;
            }
            TraceEvent::DeferRelease { t_s, arrival_s, deadline_s } => {
                j.field_num("t_s", t_s)?;
                j.field_num("arrival_s", arrival_s)?;
                j.field_fnum("deadline_s", deadline_s)?;
            }
            TraceEvent::Completion {
                t_s,
                arrival_s,
                node,
                class,
                service_ms,
                latency_ms,
                energy_j,
                carbon_g,
                missed,
                slo_missed,
            } => {
                j.field_num("t_s", t_s)?;
                j.field_num("arrival_s", arrival_s)?;
                j.field_str("node", node)?;
                j.field_num("class", class as f64)?;
                j.field_fnum("service_ms", service_ms)?;
                j.field_fnum("latency_ms", latency_ms)?;
                j.field_fnum("energy_j", energy_j)?;
                j.field_fnum("carbon_g", carbon_g)?;
                j.field_bool("missed", missed)?;
                j.field_bool("slo_missed", slo_missed)?;
            }
            TraceEvent::Churn { t_s, node, up } => {
                j.field_num("t_s", t_s)?;
                j.field_str("node", node)?;
                j.field_bool("up", up)?;
            }
            TraceEvent::MicrogridSlice {
                t0_s,
                t1_s,
                node,
                pv_j,
                battery_j,
                grid_j,
                grid_charge_j,
                carbon_g,
                idle_g,
                charge_g,
                battery_g,
                stored_g,
                soc,
            } => {
                j.field_num("t0_s", t0_s)?;
                j.field_num("t1_s", t1_s)?;
                j.field_str("node", node)?;
                j.field_fnum("pv_j", pv_j)?;
                j.field_fnum("battery_j", battery_j)?;
                j.field_fnum("grid_j", grid_j)?;
                j.field_fnum("grid_charge_j", grid_charge_j)?;
                j.field_fnum("carbon_g", carbon_g)?;
                j.field_fnum("idle_g", idle_g)?;
                j.field_fnum("charge_g", charge_g)?;
                j.field_fnum("battery_g", battery_g)?;
                j.field_fnum("stored_g", stored_g)?;
                j.field_fnum("soc", soc)?;
            }
            TraceEvent::BatchFormed { t_s, node, class, fill, head_wait_ms } => {
                j.field_num("t_s", t_s)?;
                j.field_str("node", node)?;
                j.field_num("class", class as f64)?;
                j.field_num("fill", fill as f64)?;
                j.field_fnum("head_wait_ms", head_wait_ms)?;
            }
            TraceEvent::Alert { t_s, rule, value, threshold, window_s, class } => {
                j.field_num("t_s", t_s)?;
                j.field_str("rule", rule)?;
                j.field_fnum("value", value)?;
                j.field_fnum("threshold", threshold)?;
                j.field_fnum("window_s", window_s)?;
                match class {
                    Some(c) => j.field_num("class", c as f64)?,
                    None => j.field_null("class")?,
                }
            }
            TraceEvent::IdleSlice { t0_s, t1_s, node, energy_j, carbon_g } => {
                j.field_num("t0_s", t0_s)?;
                j.field_num("t1_s", t1_s)?;
                j.field_str("node", node)?;
                j.field_fnum("energy_j", energy_j)?;
                j.field_fnum("carbon_g", carbon_g)?;
            }
            TraceEvent::RunMeta {
                scenario,
                scheduler,
                seed,
                requests,
                nodes,
                classes,
                sites,
                site_of,
                router,
            } => {
                j.field_str("scenario", scenario)?;
                j.field_str("scheduler", scheduler)?;
                j.field_num("seed", seed as f64)?;
                j.field_num("requests", requests as f64)?;
                j.key("nodes")?;
                j.begin_arr()?;
                for (i, &(name, microgrid)) in nodes.iter().enumerate() {
                    j.begin_obj()?;
                    j.field_str("node", name)?;
                    j.field_bool("microgrid", microgrid)?;
                    if let Some(&s) = site_of.get(i) {
                        j.field_num("site", s as f64)?;
                    }
                    j.end_obj()?;
                }
                j.end_arr()?;
                j.key("classes")?;
                j.begin_arr()?;
                for &(name, slo_s) in classes {
                    j.begin_obj()?;
                    j.field_str("class", name)?;
                    j.field_fnum("slo_s", slo_s)?;
                    j.end_obj()?;
                }
                j.end_arr()?;
                // Site roster + router only on multi-site runs, so flat
                // traces stay byte-identical to pre-site builds.
                if !sites.is_empty() {
                    j.field_str("router", router)?;
                    j.key("sites")?;
                    j.begin_arr()?;
                    for &name in sites {
                        j.string(name)?;
                    }
                    j.end_arr()?;
                }
            }
            TraceEvent::WanHop { t_s, from, to, latency_ms, energy_j, carbon_g } => {
                j.field_num("t_s", t_s)?;
                j.field_str("from", from)?;
                j.field_str("to", to)?;
                j.field_fnum("latency_ms", latency_ms)?;
                j.field_fnum("energy_j", energy_j)?;
                j.field_fnum("carbon_g", carbon_g)?;
            }
        }
        j.end_obj()?;
        self.out.write_all(b"\n")
    }
}

impl<W: io::Write> EventSink for FirehoseSink<W> {
    fn record(&mut self, ev: &TraceEvent<'_>) {
        if self.io_error.is_some() || !self.filter.contains(ev.kind()) {
            return;
        }
        match self.write_event(ev) {
            Ok(()) => self.events_written += 1,
            Err(e) => self.io_error = Some(e),
        }
    }

    fn wants(&self, kind: EventKind) -> bool {
        self.io_error.is_none() && self.filter.contains(kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::CandidateExplain;
    use crate::util::json::Json;

    #[test]
    fn filter_parses_lists_and_all() {
        let f = TraceFilter::parse("all").unwrap();
        for k in EventKind::ALL {
            assert!(f.contains(k));
        }
        let f = TraceFilter::parse("decision, completion").unwrap();
        assert!(f.contains(EventKind::Decision));
        assert!(f.contains(EventKind::Completion));
        assert!(!f.contains(EventKind::Arrival));
        // Aliases.
        let f = TraceFilter::parse("defer,microgrid,batch").unwrap();
        assert!(f.contains(EventKind::DeferRelease));
        assert!(f.contains(EventKind::MicrogridSlice));
        assert!(f.contains(EventKind::BatchFormed));
        assert!(!f.contains(EventKind::Decision));
        assert!(TraceFilter::parse("bogus").is_err());
        assert!(TraceFilter::parse("").is_err());
    }

    #[test]
    fn kind_labels_round_trip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::parse(k.label()), Some(k));
        }
    }

    /// Regression for the `u8` → `u16` filter widening: the mask was
    /// saturated at eight kinds, so `Alert`/`IdleSlice`/`RunMeta` (bits
    /// 8–10) would silently alias without the wider carrier. Every kind
    /// must round-trip through `with`/`contains` *alone* (no cross-kind
    /// bleed) and through `parse` of its own label, and `all()` must
    /// cover exactly the defined kinds.
    #[test]
    fn every_kind_round_trips_through_the_filter() {
        for k in EventKind::ALL {
            let f = TraceFilter::none().with(k);
            assert!(f.contains(k), "{:?} lost by its own filter", k);
            for other in EventKind::ALL {
                if other != k {
                    assert!(!f.contains(other), "{k:?} filter leaked {other:?}");
                }
            }
            let parsed = TraceFilter::parse(k.label()).unwrap();
            assert_eq!(parsed, f, "{:?} label parse != with()", k);
            assert!(TraceFilter::all().contains(k), "all() missing {k:?}");
        }
        // The all-mask carries no bits beyond the defined kinds.
        assert_eq!(TraceFilter::all().0.count_ones() as usize, EventKind::COUNT);
    }

    #[test]
    fn new_kinds_serialise_one_line_each() {
        let mut sink = FirehoseSink::new(Vec::new());
        sink.record(&TraceEvent::Alert {
            t_s: 120.0,
            rule: "carbon-budget",
            value: 0.91,
            threshold: 0.5,
            window_s: 3600.0,
            class: None,
        });
        sink.record(&TraceEvent::IdleSlice {
            t0_s: 0.0,
            t1_s: 480.5,
            node: "edge-a",
            energy_j: 19_220.0,
            carbon_g: 3.25,
        });
        let nodes = [("edge-a", false), ("solar", true)];
        let classes = [("interactive", 3.0)];
        sink.record(&TraceEvent::RunMeta {
            scenario: "paper-3-node",
            scheduler: "green",
            seed: 42,
            requests: 4_000,
            nodes: &nodes,
            classes: &classes,
            sites: &[],
            site_of: &[],
            router: "",
        });
        assert_eq!(sink.events_written(), 3);
        let text = String::from_utf8(sink.finish().unwrap()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let v = Json::parse(lines[0]).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("alert"));
        assert_eq!(v.get("rule").unwrap().as_str(), Some("carbon-budget"));
        assert_eq!(v.get("class"), Some(&Json::Null));
        let v = Json::parse(lines[1]).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("idle_slice"));
        assert_eq!(v.get("t1_s").unwrap().as_f64(), Some(480.5));
        let v = Json::parse(lines[2]).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("run_meta"));
        assert_eq!(v.get("seed").unwrap().as_i64(), Some(42));
        let ns = v.get("nodes").unwrap().as_arr().unwrap();
        assert_eq!(ns.len(), 2);
        assert_eq!(ns[1].get("microgrid").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("classes").unwrap().as_arr().unwrap().len(), 1);
        // Flat fleet: no site keys on the meta line.
        assert!(v.get("sites").is_none());
        assert!(v.get("router").is_none());
        assert!(ns[0].get("site").is_none());
    }

    #[test]
    fn wan_hop_and_site_meta_serialise() {
        let mut sink = FirehoseSink::new(Vec::new());
        let nodes = [("eu-west-00", false), ("us-west-01", false)];
        sink.record(&TraceEvent::RunMeta {
            scenario: "multi-site",
            scheduler: "green",
            seed: 7,
            requests: 100,
            nodes: &nodes,
            classes: &[],
            sites: &["eu-west", "us-west"],
            site_of: &[0, 1],
            router: "deadline",
        });
        sink.record(&TraceEvent::WanHop {
            t_s: 12.5,
            from: "eu-west",
            to: "us-west",
            latency_ms: 60.0,
            energy_j: 6.4e-3,
            carbon_g: 8.4e-7,
        });
        let text = String::from_utf8(sink.finish().unwrap()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let v = Json::parse(lines[0]).unwrap();
        assert_eq!(v.get("router").unwrap().as_str(), Some("deadline"));
        let sites = v.get("sites").unwrap().as_arr().unwrap();
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].as_str(), Some("eu-west"));
        let ns = v.get("nodes").unwrap().as_arr().unwrap();
        assert_eq!(ns[1].get("site").unwrap().as_i64(), Some(1));
        let v = Json::parse(lines[1]).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("wan_hop"));
        assert_eq!(v.get("from").unwrap().as_str(), Some("eu-west"));
        assert_eq!(v.get("to").unwrap().as_str(), Some("us-west"));
        assert_eq!(v.get("latency_ms").unwrap().as_f64(), Some(60.0));
        assert!(v.get("carbon_g").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn firehose_streams_one_parseable_line_per_event() {
        let mut sink = FirehoseSink::new(Vec::new());
        sink.record(&TraceEvent::Arrival { t_s: 0.5, deadline_s: 3600.5, class: 0 });
        sink.record(&TraceEvent::Dispatch {
            t_s: 0.5,
            arrival_s: 0.5,
            node: "edge-a",
            queue_delay_est_ms: 12.25,
        });
        sink.record(&TraceEvent::Churn { t_s: 9.0, node: "edge-b", up: false });
        sink.record(&TraceEvent::BatchFormed {
            t_s: 10.0,
            node: "edge-a",
            class: 2,
            fill: 5,
            head_wait_ms: 37.5,
        });
        assert_eq!(sink.events_written(), 4);
        let buf = sink.finish().unwrap();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        let v = Json::parse(lines[0]).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("arrival"));
        assert_eq!(v.get("deadline_s").unwrap().as_f64(), Some(3600.5));
        let v = Json::parse(lines[1]).unwrap();
        assert_eq!(v.get("node").unwrap().as_str(), Some("edge-a"));
        assert_eq!(v.get("queue_delay_est_ms").unwrap().as_f64(), Some(12.25));
        let v = Json::parse(lines[2]).unwrap();
        assert_eq!(v.get("up").unwrap().as_bool(), Some(false));
        let v = Json::parse(lines[3]).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("batch_formed"));
        assert_eq!(v.get("class").unwrap().as_i64(), Some(2));
        assert_eq!(v.get("fill").unwrap().as_i64(), Some(5));
        assert_eq!(v.get("head_wait_ms").unwrap().as_f64(), Some(37.5));
    }

    #[test]
    fn firehose_serialises_decisions_with_candidates() {
        let explain = DecisionExplain {
            candidates: vec![
                CandidateExplain {
                    node: "edge-a".into(),
                    feasible: true,
                    score: Some(0.82),
                    intensity: 120.0,
                    queue_delay_ms: 4.0,
                    best_slot: Some((7200.0, 80.0)),
                },
                CandidateExplain {
                    node: "edge-b".into(),
                    feasible: false,
                    score: None,
                    intensity: 300.0,
                    queue_delay_ms: 55.0,
                    best_slot: None,
                },
            ],
            note: Some("joint defer: fleet min 80.0".into()),
        };
        let mut sink = FirehoseSink::new(Vec::new());
        sink.record(&TraceEvent::Decision {
            t_s: 10.0,
            arrival_s: 10.0,
            ctx: "arrival",
            verdict: SchedulingDecision::Defer { until_s: 7200.0 },
            node: None,
            explain: &explain,
            decide_ns: 1850,
        });
        let text = String::from_utf8(sink.finish().unwrap()).unwrap();
        let v = Json::parse(text.trim()).unwrap();
        assert_eq!(v.get("verdict").unwrap().as_str(), Some("defer"));
        assert_eq!(v.get("until_s").unwrap().as_f64(), Some(7200.0));
        assert_eq!(v.get("decide_ns").unwrap().as_i64(), Some(1850));
        let cands = v.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[0].get("score").unwrap().as_f64(), Some(0.82));
        assert_eq!(cands[0].get("slot_s").unwrap().as_f64(), Some(7200.0));
        assert_eq!(cands[1].get("score"), Some(&Json::Null));
        assert!(v.get("note").unwrap().as_str().unwrap().starts_with("joint defer"));
    }

    #[test]
    fn firehose_filter_drops_unwanted_kinds() {
        let filter = TraceFilter::parse("completion").unwrap();
        let mut sink = FirehoseSink::with_filter(Vec::new(), filter);
        assert!(sink.wants(EventKind::Completion));
        assert!(!sink.wants(EventKind::Arrival));
        sink.record(&TraceEvent::Arrival { t_s: 1.0, deadline_s: f64::INFINITY, class: 0 });
        sink.record(&TraceEvent::Completion {
            t_s: 2.0,
            arrival_s: 1.0,
            node: "edge-a",
            class: 0,
            service_ms: 100.0,
            latency_ms: 1000.0,
            energy_j: 5.0,
            carbon_g: 0.4,
            missed: false,
            slo_missed: false,
        });
        assert_eq!(sink.events_written(), 1);
        let text = String::from_utf8(sink.finish().unwrap()).unwrap();
        assert_eq!(text.lines().count(), 1);
        let v = Json::parse(text.trim()).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("completion"));
    }

    #[test]
    fn infinite_deadline_serialises_as_null() {
        let mut sink = FirehoseSink::new(Vec::new());
        sink.record(&TraceEvent::Arrival { t_s: 0.0, deadline_s: f64::INFINITY, class: 0 });
        let text = String::from_utf8(sink.finish().unwrap()).unwrap();
        let v = Json::parse(text.trim()).unwrap();
        assert_eq!(v.get("deadline_s"), Some(&Json::Null));
    }

    #[test]
    fn null_sink_wants_nothing() {
        let mut s = NullSink;
        assert!(!s.wants(EventKind::Decision));
        s.record(&TraceEvent::Arrival { t_s: 0.0, deadline_s: 1.0, class: 0 });
    }
}
