//! The CarbonEdge coordinator (L3): owns the executor, the node fleet, the
//! scheduler and the serving loop; exposes the experiment entry points the
//! benches/examples drive.
//!
//! Request path (all Rust, no Python): input tensor -> scheduler (Alg. 1)
//! -> node container -> executor thread (PJRT) -> latency/energy/carbon
//! accounting -> report.

mod serve;

pub use serve::{ServeOutcome, ServingLoop};

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::Config;
use crate::deployer;
use crate::model::{LoadedModel, Manifest};
use crate::node::{Container, EdgeNode, ExecutionRecord, NodeRegistry, NodeSpec};
use crate::partitioner::{model_cost_profile, GreenPartitioner};
use crate::runtime::{ExecHandle, ExecServer, Tensor};
use crate::scheduler::{Scheduler, TaskDemand};

/// The coordinator: executor + manifest + config.
pub struct Coordinator {
    _server: ExecServer,
    exec: ExecHandle,
    pub manifest: Manifest,
    pub cfg: Config,
    /// Per-model calibration factor: median(monolithic exec) /
    /// median(stage-chain exec), measured back-to-back at first deploy.
    /// Normalizes the container time model against compilation-dependent
    /// differences between the monolithic and staged programs, so that
    /// host noise between *configurations* cannot flip the paper's
    /// latency/carbon shape (DESIGN.md §3).
    calib: std::sync::Mutex<std::collections::HashMap<String, f64>>,
}

impl Coordinator {
    /// Start the executor thread and load the artifact manifest.
    pub fn new(cfg: Config) -> Result<Coordinator> {
        let manifest = Manifest::load(&cfg.artifacts_dir)
            .context("loading manifest (run `make artifacts`)")?;
        let server = ExecServer::start()?;
        let exec = server.handle();
        Ok(Coordinator {
            _server: server,
            exec,
            manifest,
            cfg,
            calib: std::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Deploy-time calibration: measure monolithic vs stage-chain execution
    /// back-to-back (medians of `K` alternating runs) and return
    /// `mono/staged`. Memoized per model.
    pub fn calibration(&self, model: &LoadedModel) -> Result<f64> {
        const K: usize = 5;
        if let Some(f) = self.calib.lock().unwrap().get(&model.entry.name) {
            return Ok(*f);
        }
        let mono_key = deployer::register_monolithic(&self.exec, model, &self.cfg)?;
        let stage_keys = deployer::register_stages(&self.exec, model, &self.cfg)?;
        let input = Tensor::zeros(model.entry.input_shape.clone());
        let mut mono_ms = Vec::with_capacity(K);
        let mut staged_ms = Vec::with_capacity(K);
        for _ in 0..K {
            let (_, d) = self.exec.execute(&mono_key, input.clone())?;
            mono_ms.push(d.as_secs_f64() * 1e3);
            let mut x = input.clone();
            let mut total = 0.0;
            for k in &stage_keys {
                let (out, d) = self.exec.execute(k, x)?;
                x = out;
                total += d.as_secs_f64() * 1e3;
            }
            staged_ms.push(total);
        }
        let med = |v: &mut Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let factor = med(&mut mono_ms) / med(&mut staged_ms).max(1e-9);
        self.calib.lock().unwrap().insert(model.entry.name.clone(), factor);
        Ok(factor)
    }

    /// Fleet with the per-model calibration folded into each node's
    /// time_scale.
    pub fn calibrated_registry(&self, model: &LoadedModel) -> Result<NodeRegistry> {
        let factor = self.calibration(model)?;
        let specs = self
            .cfg
            .nodes
            .iter()
            .cloned()
            .map(|mut s| {
                s.time_scale *= factor;
                s
            })
            .collect();
        Ok(NodeRegistry::new(specs))
    }

    pub fn exec(&self) -> ExecHandle {
        self.exec.clone()
    }

    /// Load a model's weights and manifest entry.
    pub fn load_model(&self, name: &str) -> Result<LoadedModel> {
        let entry = self
            .manifest
            .models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name:?} not in manifest"))?;
        LoadedModel::load(&self.cfg.artifacts_dir, entry)
    }

    /// The pseudo-node representing direct host execution (the paper's
    /// Monolithic baseline): full speed, host grid intensity.
    pub fn host_node(&self) -> Arc<EdgeNode> {
        EdgeNode::new(NodeSpec {
            name: "host".into(),
            cpu_quota: 1.0,
            mem_mb: 32 * 1024,
            intensity: self.cfg.host_intensity,
            rated_power_w: self.cfg.host.power_watts(1.0, 1.0),
            idle_w: 0.0,
            prior_ms: 250.0,
            alpha: 0.0,
            overhead_ms: 0.0,
            time_scale: 20.0,
            adaptive: false,
        })
    }

    /// Fresh fleet per experiment configuration (state isolation).
    pub fn fresh_registry(&self) -> NodeRegistry {
        NodeRegistry::new(self.cfg.nodes.clone())
    }

    /// Monolithic baseline: single-program inference on the host node.
    pub fn run_monolithic(
        &self,
        model: &LoadedModel,
        inputs: &[Tensor],
    ) -> Result<Vec<ExecutionRecord>> {
        let key = deployer::register_monolithic(&self.exec, model, &self.cfg)?;
        let host = self.host_node();
        let c = Container::new(host, self.exec.clone(), self.cfg.host, self.cfg.pue, vec![key]);
        inputs.iter().map(|x| c.infer(x.clone())).collect()
    }

    /// Scheduled task-level execution (AMP4EC / CE modes): each inference
    /// is routed to one node by the scheduler and runs the full stage chain
    /// there. Returns per-task records plus per-decision scheduling time.
    pub fn run_scheduled(
        &self,
        model: &LoadedModel,
        scheduler: &mut dyn Scheduler,
        inputs: &[Tensor],
    ) -> Result<ScheduledRun> {
        let registry = self.calibrated_registry(model)?;
        let containers =
            deployer::deploy_task_level(&self.exec, model, registry.nodes(), &self.cfg)?;
        let task = TaskDemand::default();
        let mut records = Vec::with_capacity(inputs.len());
        let mut sched_ns: Vec<u64> = Vec::with_capacity(inputs.len());
        for x in inputs {
            // Snapshot + decide together are the per-task scheduling cost
            // (the snapshot does the state reads select used to do).
            // lint: allow(D2 L3 measures real scheduling overhead on the wall clock)
            let t0 = Instant::now();
            let fleet = crate::scheduler::FleetView::observe(registry.nodes());
            let pick = scheduler.decide(&task, &fleet).assigned();
            sched_ns.push(t0.elapsed().as_nanos() as u64);
            let i = pick.ok_or_else(|| anyhow::anyhow!("no feasible node"))?;
            records.push(containers[i].infer(x.clone())?);
        }
        Ok(ScheduledRun { records, sched_ns, registry })
    }

    /// Cross-node pipeline execution (the paper's future-work extension):
    /// stages split over the fleet by the Green Partitioning Strategy; one
    /// inference flows through every group in order. Inter-node transfer is
    /// charged per boundary activation via `net_ms_per_mb`.
    pub fn run_pipeline(
        &self,
        model: &LoadedModel,
        carbon_weight: f64,
        inputs: &[Tensor],
        net_ms_per_mb: f64,
    ) -> Result<Vec<ExecutionRecord>> {
        let registry = self.calibrated_registry(model)?;
        let profile = model_cost_profile(&model.entry);
        let partition =
            GreenPartitioner::new(carbon_weight).partition(&profile.stage_costs, registry.nodes());
        let containers =
            deployer::deploy_pipeline(&self.exec, model, registry.nodes(), &partition, &self.cfg)?;
        let mut out = Vec::with_capacity(inputs.len());
        for x in inputs {
            let mut cur = x.clone();
            let mut total = ExecutionRecord {
                node: String::new(),
                exec_ms: 0.0,
                latency_ms: 0.0,
                energy_j: 0.0,
                carbon_g: 0.0,
                output: Tensor::zeros(vec![1]),
            };
            let mut names: Vec<String> = Vec::new();
            for (ci, c) in containers.iter().enumerate() {
                let rec = c.infer(cur)?;
                cur = rec.output.clone();
                total.exec_ms += rec.exec_ms;
                total.latency_ms += rec.latency_ms;
                total.energy_j += rec.energy_j;
                total.carbon_g += rec.carbon_g;
                names.push(c.node().spec.name.clone());
                // network hop (except after the last group)
                if ci + 1 < containers.len() {
                    let mb = rec.output.size_bytes() as f64 / 1e6;
                    total.latency_ms += mb * net_ms_per_mb;
                }
            }
            total.node = names.join("+");
            total.output = cur;
            out.push(total);
        }
        Ok(out)
    }

    /// Golden check: run the monolithic program on the exported input and
    /// compare against the manifest logits (the end-to-end numerics gate).
    pub fn golden_check(&self, model: &LoadedModel) -> Result<f64> {
        let key = deployer::register_monolithic(&self.exec, model, &self.cfg)?;
        let input = model.golden_input()?;
        let (out, _) = self.exec.execute(&key, input)?;
        let g = &model.entry.golden;
        anyhow::ensure!(out.len() == model.entry.num_classes, "logit count");
        let mut max_err = 0f64;
        for (i, want) in g.logits8.iter().enumerate() {
            max_err = max_err.max((out.data[i] as f64 - want).abs());
        }
        let argmax = out
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        anyhow::ensure!(argmax == g.argmax, "argmax {} != golden {}", argmax, g.argmax);
        Ok(max_err)
    }
}

/// Output of a scheduled run.
pub struct ScheduledRun {
    pub records: Vec<ExecutionRecord>,
    /// Per-decision scheduling time (ns) — the paper's 0.03 ms/task claim.
    pub sched_ns: Vec<u64>,
    pub registry: NodeRegistry,
}

impl ScheduledRun {
    pub fn mean_sched_ms(&self) -> f64 {
        if self.sched_ns.is_empty() {
            return 0.0;
        }
        self.sched_ns.iter().sum::<u64>() as f64 / self.sched_ns.len() as f64 / 1e6
    }
}
