//! Deferral-aware scheduling over the [`FleetView`] forecast context.
//!
//! Two policies live here:
//!
//! * [`RouteThenDefer`] — the legacy two-pass shape as an adapter: route
//!   first (any inner scheduler), then ask the [`DeferralPolicy`] whether
//!   the *chosen node's* forecast holds a slot worth parking for. The
//!   simulator wraps non-deferring schedulers in this gate when a scenario
//!   configures deferral, which reproduces the old engine behaviour
//!   bit-for-bit — except that the forecast it reads is the blended
//!   microgrid-aware one, fixing the ROADMAP-flagged raw-grid bug.
//! * [`DeferAwareGreenScheduler`] — the joint *where-or-when* policy the
//!   `Decision` API unlocks: green-mode routing, but the defer question is
//!   asked against the best `(node, slot)` pair across the whole feasible
//!   fleet, not just the chosen node's own curve. A spill onto a dirty
//!   node whose curve is flat no longer runs immediately when another
//!   node's trough is within the deadline. Release slots are additionally
//!   *spread* across the near-optimal plateau of the forecast (round-robin
//!   over slots within [`DeferAwareGreenScheduler::plateau_tol`] of the
//!   minimum), so parked work does not release as one thundering herd that
//!   saturates the cleanest node and spills back onto dirty ones — the
//!   queue-delay failure mode of route-then-defer under load. The defer
//!   question is also *batch-aware*: joining a forming batch is credited
//!   at its marginal energy `(E(k+1) − E(k))/E(1)`, so a request that
//!   would ride an almost-free batch slot runs now unless the forecast
//!   trough is deeper than that discount.

use crate::carbon::{DeferDecision, DeferralPolicy};

use super::{
    CarbonAwareScheduler, DecisionExplain, FleetView, Mode, Scheduler, SchedulingDecision,
    TaskDemand,
};

/// Legacy route-*then*-defer as a [`Scheduler`] adapter: the inner
/// scheduler picks a node, then the policy may park the task for a cleaner
/// slot on that node's forecast. Reports under the inner scheduler's name
/// so wrapped runs stay comparable with historical reports.
pub struct RouteThenDefer<S> {
    inner: S,
    policy: DeferralPolicy,
}

impl<S: Scheduler> RouteThenDefer<S> {
    pub fn new(inner: S, policy: DeferralPolicy) -> RouteThenDefer<S> {
        RouteThenDefer { inner, policy }
    }
}

impl<S: Scheduler> RouteThenDefer<S> {
    /// One body for the plain and explained paths: the verdict (and the
    /// inner scheduler's state transitions) is identical either way;
    /// `explain` only adds detail on the side.
    fn decide_impl(
        &mut self,
        task: &TaskDemand,
        fleet: &FleetView,
        mut explain: Option<&mut DecisionExplain>,
    ) -> SchedulingDecision {
        let routed = match explain.as_deref_mut() {
            Some(e) => self.inner.decide_explained(task, fleet, e),
            None => self.inner.decide(task, fleet),
        };
        match routed {
            SchedulingDecision::Assign(i) => {
                match self.policy.decide_samples(&fleet.nodes[i].forecast) {
                    DeferDecision::Defer { at_s, .. } if at_s > fleet.now_s => {
                        if let Some(e) = explain {
                            let slot_v = fleet.nodes[i]
                                .forecast
                                .iter()
                                .find(|s| s.0 == at_s)
                                .map(|s| s.1);
                            if let Some(c) = e.candidates.get_mut(i) {
                                c.best_slot = slot_v.map(|v| (at_s, v));
                            }
                            e.note = Some(format!(
                                "route-then-defer: routed to {}, parked for its slot at {at_s:.0}s",
                                fleet.nodes[i].node.spec.name
                            ));
                        }
                        SchedulingDecision::Defer { until_s: at_s }
                    }
                    _ => SchedulingDecision::Assign(i),
                }
            }
            other => other,
        }
    }
}

impl<S: Scheduler> Scheduler for RouteThenDefer<S> {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        self.decide_impl(task, fleet, None)
    }

    fn decide_explained(
        &mut self,
        task: &TaskDemand,
        fleet: &FleetView,
        explain: &mut DecisionExplain,
    ) -> SchedulingDecision {
        self.decide_impl(task, fleet, Some(explain))
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn defers(&self) -> bool {
        true
    }
}

/// Joint defer+route green scheduling: route with Green-mode weights, then
/// defer only when the best forecast slot across the *whole feasible
/// fleet* beats the chosen node's current effective intensity by at least
/// `defer_min_gain`. Deferred releases are spread round-robin across the
/// near-optimal forecast plateau (slots within `plateau_tol` of the
/// minimum) instead of all targeting the single argmin slot.
pub struct DeferAwareGreenScheduler {
    inner: CarbonAwareScheduler,
    /// Minimum relative gain of the best fleet-wide forecast slot over the
    /// chosen node's current intensity required to defer (e.g. 0.05 = 5%).
    pub defer_min_gain: f64,
    /// Relative tolerance defining the release plateau: every slot with
    /// intensity ≤ `min × (1 + plateau_tol)` is an acceptable release
    /// target, and successive deferrals rotate across them.
    pub plateau_tol: f64,
    /// `Defer` verdicts issued so far — the plateau rotation counter.
    /// It advances **only** when a task is actually parked: the old
    /// any-forecast-bearing-decision convention made two fleets that
    /// differed only in assign-traffic release their deferred work on
    /// different slots (an unrelated `Assign` between two `Defer`s
    /// shifted the rotation), which broke twin comparisons.
    defers_issued: u64,
    /// Batch-join intensity tolerance: when the routed node has no open
    /// batch for the task's class but another feasible node within
    /// `join_tol` relative effective intensity does, the task joins the
    /// forming batch there — amortizing the per-batch overhead instead of
    /// opening a fresh batch on a marginally cleaner node. Only active
    /// when the fleet view carries per-class batching context
    /// ([`super::NodeView::class_state`]); single-class runs are
    /// untouched.
    pub join_tol: f64,
}

/// Default release-plateau tolerance: slots within 2% of the forecast
/// minimum are treated as equally clean and shared round-robin.
pub const DEFAULT_PLATEAU_TOL: f64 = 0.02;

/// Default batch-join intensity tolerance: a forming batch on a node up
/// to 5% dirtier than the routed choice is still worth joining — the
/// amortized per-batch overhead typically buys back more than 5% energy
/// per request ([`crate::node::NodeSpec::batch_latency_ms`]).
pub const DEFAULT_JOIN_TOL: f64 = 0.05;

impl DeferAwareGreenScheduler {
    pub fn new(defer_min_gain: f64) -> DeferAwareGreenScheduler {
        // lint: allow(P2 one-shot constructor guard, pinned by a should_panic test)
        assert!(
            defer_min_gain.is_finite() && (0.0..=1.0).contains(&defer_min_gain),
            "defer_min_gain must be in [0, 1], got {defer_min_gain}"
        );
        DeferAwareGreenScheduler {
            inner: CarbonAwareScheduler::new("defer-green", Mode::Green.weights()),
            defer_min_gain,
            plateau_tol: DEFAULT_PLATEAU_TOL,
            defers_issued: 0,
            join_tol: DEFAULT_JOIN_TOL,
        }
    }

    /// Class-aware batch join: keep the routed node when it already has a
    /// forming batch for this class (or the view carries no batching
    /// context); otherwise move to the feasible node with the fullest open
    /// batch among those within `join_tol` relative intensity of the
    /// routed choice. Deterministic: ties keep the lowest index.
    fn join_refine(&self, task: &TaskDemand, fleet: &FleetView, chosen: usize) -> usize {
        let Some(own) = fleet.nodes[chosen].class_state.get(task.class) else {
            return chosen;
        };
        if own.queued > 0 {
            return chosen;
        }
        let limit = fleet.nodes[chosen].intensity * (1.0 + self.join_tol);
        let mut best = chosen;
        let mut best_fill = 0usize;
        for (i, v) in fleet.nodes.iter().enumerate() {
            if i == chosen || v.intensity > limit || !v.feasible(task) {
                continue;
            }
            if let Some(cv) = v.class_state.get(task.class) {
                if cv.queued > best_fill {
                    best_fill = cv.queued;
                    best = i;
                }
            }
        }
        best
    }

    /// Marginal-energy credit for joining the chosen node's forming batch:
    /// `(E(k+1) − E(k)) / E(1)`, where `E(b)` is the slot energy of a
    /// `b`-deep batch ([`crate::node::NodeSpec::batch_dynamic_power_w`] ×
    /// [`crate::node::NodeSpec::batch_latency_ms`] at the spec's prior
    /// service estimate) and `k` the batch's current fill. Returns 1.0 (no
    /// credit) when the view carries no batching context or no batch is
    /// forming — an opening request pays full freight.
    fn marginal_batch_ratio(&self, task: &TaskDemand, chosen: &super::NodeView) -> f64 {
        let k = match chosen.class_state.get(task.class) {
            Some(cv) if cv.queued > 0 => cv.queued,
            _ => return 1.0,
        };
        let spec = &chosen.node.spec;
        let e =
            |b: usize| spec.batch_dynamic_power_w(b) * spec.batch_latency_ms(spec.prior_ms, b);
        let e1 = e(1);
        if !e1.is_finite() || e1 <= 0.0 {
            return 1.0;
        }
        ((e(k + 1) - e(k)) / e1).clamp(0.0, 1.0)
    }
}

impl DeferAwareGreenScheduler {
    /// Shared body for the plain and explained paths — the verdict and the
    /// `defers_issued` rotation advance identically whether or not a trace
    /// sink is listening.
    fn decide_impl(
        &mut self,
        task: &TaskDemand,
        fleet: &FleetView,
        mut explain: Option<&mut DecisionExplain>,
    ) -> SchedulingDecision {
        let routed = match explain.as_deref_mut() {
            Some(e) => self.inner.decide_explained(task, fleet, e),
            None => self.inner.decide(task, fleet),
        };
        let SchedulingDecision::Assign(routed_to) = routed else { return routed };
        // Batch-aware placement refinement (no-op without class_state).
        let chosen = self.join_refine(task, fleet, routed_to);
        if chosen != routed_to {
            if let Some(e) = explain.as_deref_mut() {
                e.note = Some(format!(
                    "batch join: moved class {} from {} to {}'s forming batch (fill {})",
                    task.class,
                    fleet.nodes[routed_to].node.spec.name,
                    fleet.nodes[chosen].node.spec.name,
                    fleet.nodes[chosen].class_state[task.class].queued
                ));
            }
        }
        let now_fc = &fleet.nodes[chosen].forecast;
        // No forecast context (no slack, or a released task): run now.
        let Some(&(_, now_i)) = now_fc.first() else {
            return SchedulingDecision::Assign(chosen);
        };
        // Per-slot minimum across the feasible fleet. Engine-built
        // forecasts share one sampling walk, so slot j lines up across
        // nodes; the min length guards hand-built views.
        let feasible: Vec<&super::NodeView> = fleet
            .nodes
            .iter()
            .filter(|v| v.feasible(task) && !v.forecast.is_empty())
            .collect();
        let slots = feasible.iter().map(|v| v.forecast.len()).min().unwrap_or(0);
        let mut mins: Vec<(f64, f64)> = Vec::with_capacity(slots);
        let mut best = f64::INFINITY;
        for j in 0..slots {
            let t = feasible[0].forecast[j].0;
            let v = feasible.iter().map(|nv| nv.forecast[j].1).fold(f64::INFINITY, f64::min);
            if t > fleet.now_s && v < best {
                best = v;
            }
            mins.push((t, v));
        }
        // Decision trace: each candidate's own best future slot, so the
        // firehose shows which curves competed for the release.
        if let Some(e) = explain.as_deref_mut() {
            for (k, v) in fleet.nodes.iter().enumerate() {
                let own_best = v
                    .forecast
                    .iter()
                    .filter(|s| s.0 > fleet.now_s)
                    .fold(None::<(f64, f64)>, |acc, &(t, i)| match acc {
                        Some((_, bi)) if bi <= i => acc,
                        _ => Some((t, i)),
                    });
                if let Some(c) = e.candidates.get_mut(k) {
                    c.best_slot = own_best;
                }
            }
        }
        // Joint verdict: defer only when somewhere in the fleet, sometime
        // inside the deadline, beats running on the routed node right now.
        // A forming batch discounts the now-price to its *marginal* energy:
        // request k+1 adds only E(k+1) − E(k) ≪ E(1) of slot energy, so
        // the trough must be deeper than that discount to justify parking
        // instead of joining.
        let marginal = self.marginal_batch_ratio(task, &fleet.nodes[chosen]);
        if best >= now_i * marginal * (1.0 - self.defer_min_gain) {
            if let Some(e) = explain {
                e.note = Some(format!(
                    "ran now on {}: best fleet slot {best:.1} g/kWh does not clear \
                     {:.1} (now {now_i:.1} g/kWh, min gain {}, batch marginal {marginal:.2})",
                    fleet.nodes[chosen].node.spec.name,
                    now_i * marginal * (1.0 - self.defer_min_gain),
                    self.defer_min_gain
                ));
            }
            return SchedulingDecision::Assign(chosen);
        }
        let plateau = best * (1.0 + self.plateau_tol);
        let candidates: Vec<f64> = mins
            .iter()
            .filter(|&&(t, v)| t > fleet.now_s && v <= plateau)
            .map(|&(t, _)| t)
            .collect();
        // With non-negative intensities and plateau_tol ≥ 0 the argmin slot
        // always qualifies; guard anyway (plateau_tol is a pub knob) rather
        // than panic on an empty plateau.
        let Some(&until_s) =
            candidates.get((self.defers_issued % candidates.len().max(1) as u64) as usize)
        else {
            return SchedulingDecision::Assign(chosen);
        };
        self.defers_issued += 1;
        if let Some(e) = explain {
            e.note = Some(format!(
                "joint defer: fleet min {best:.1} g/kWh beats {now_i:.1} now on {}; \
                 released at {until_s:.0}s ({} plateau slots, defer #{})",
                fleet.nodes[chosen].node.spec.name,
                candidates.len(),
                self.defers_issued
            ));
        }
        SchedulingDecision::Defer { until_s }
    }
}

impl Scheduler for DeferAwareGreenScheduler {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        self.decide_impl(task, fleet, None)
    }

    fn decide_explained(
        &mut self,
        task: &TaskDemand,
        fleet: &FleetView,
        explain: &mut DecisionExplain,
    ) -> SchedulingDecision {
        self.decide_impl(task, fleet, Some(explain))
    }

    fn name(&self) -> &str {
        "defer-green"
    }

    fn defers(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeRegistry;
    use crate::scheduler::RoundRobinScheduler;

    /// Paper fleet snapshot with per-node forecasts installed.
    fn fleet_with_forecasts(forecasts: Vec<Vec<(f64, f64)>>) -> FleetView {
        let r = NodeRegistry::paper_setup();
        let mut f = FleetView::observe(r.nodes());
        for (v, fc) in f.nodes.iter_mut().zip(forecasts) {
            v.forecast = fc;
        }
        f
    }

    #[test]
    fn gate_defers_on_the_chosen_nodes_forecast() {
        // Fresh round-robin always picks node 0 first; its forecast has a
        // 50% cleaner slot.
        let gate = || {
            RouteThenDefer::new(
                RoundRobinScheduler::new(),
                DeferralPolicy { resolution_s: 300.0, min_gain: 0.05 },
            )
        };
        assert!(gate().defers());
        assert_eq!(gate().name(), "round-robin");
        let f = fleet_with_forecasts(vec![
            vec![(0.0, 600.0), (300.0, 300.0)],
            vec![(0.0, 100.0), (300.0, 100.0)],
            vec![(0.0, 100.0), (300.0, 100.0)],
        ]);
        let task = TaskDemand::default();
        assert_eq!(gate().decide(&task, &f), SchedulingDecision::Defer { until_s: 300.0 });
        // Without forecast context the gate passes the assignment through.
        let bare = fleet_with_forecasts(vec![Vec::new(), Vec::new(), Vec::new()]);
        assert_eq!(gate().decide(&task, &bare), SchedulingDecision::Assign(0));
        // A flat forecast (gain below the threshold) runs now too.
        let flat = fleet_with_forecasts(vec![
            vec![(0.0, 600.0), (300.0, 590.0)],
            Vec::new(),
            Vec::new(),
        ]);
        assert_eq!(gate().decide(&task, &flat), SchedulingDecision::Assign(0));
        // Rejections pass through untouched.
        let task_big = TaskDemand { mem_mb: 1 << 20, ..task };
        assert_eq!(gate().decide(&task_big, &f), SchedulingDecision::reject());
    }

    #[test]
    fn joint_defers_toward_another_nodes_trough() {
        // Green routes to node-green (index 2, 380 g). Its own curve is
        // flat — route-then-defer would run now — but node 0's forecast
        // holds a deep trough: the joint verdict parks for it.
        let mut s = DeferAwareGreenScheduler::new(0.05);
        assert!(s.defers());
        assert_eq!(s.name(), "defer-green");
        let f = fleet_with_forecasts(vec![
            vec![(0.0, 620.0), (300.0, 620.0), (600.0, 40.0)],
            vec![(0.0, 530.0), (300.0, 530.0), (600.0, 530.0)],
            vec![(0.0, 380.0), (300.0, 380.0), (600.0, 380.0)],
        ]);
        let task = TaskDemand::default();
        assert_eq!(s.decide(&task, &f), SchedulingDecision::Defer { until_s: 600.0 });
        // The legacy gate on the same view runs now (chosen curve is flat).
        let mut gate = RouteThenDefer::new(
            CarbonAwareScheduler::new("green", Mode::Green.weights()),
            DeferralPolicy::default(),
        );
        assert_eq!(gate.decide(&task, &f), SchedulingDecision::Assign(2));
    }

    #[test]
    fn joint_runs_now_without_sufficient_gain_or_forecast() {
        let mut s = DeferAwareGreenScheduler::new(0.05);
        let task = TaskDemand::default();
        // Empty forecasts (a released task): assign, never defer.
        let bare = fleet_with_forecasts(vec![Vec::new(), Vec::new(), Vec::new()]);
        assert_eq!(s.decide(&task, &bare), SchedulingDecision::Assign(2));
        // Future slots all within 5% of now: run now.
        let flat = fleet_with_forecasts(vec![
            vec![(0.0, 620.0), (300.0, 615.0)],
            vec![(0.0, 530.0), (300.0, 528.0)],
            vec![(0.0, 380.0), (300.0, 370.0)],
        ]);
        assert_eq!(s.decide(&task, &flat), SchedulingDecision::Assign(2));
        // Nothing feasible: reject.
        let task_big = TaskDemand { mem_mb: 1 << 20, ..task };
        assert_eq!(s.decide(&task_big, &flat), SchedulingDecision::reject());
    }

    #[test]
    fn plateau_spreads_release_slots_round_robin() {
        // Three equally-clean future slots on the routed node: successive
        // deferrals must rotate across all of them, not pile onto one.
        let mut s = DeferAwareGreenScheduler::new(0.05);
        let task = TaskDemand::default();
        let fc = vec![(0.0, 380.0), (300.0, 100.0), (600.0, 100.0), (900.0, 101.0)];
        let walk = |v: f64| vec![(0.0, v), (300.0, v), (600.0, v), (900.0, v)];
        let mk = || fleet_with_forecasts(vec![walk(620.0), walk(530.0), fc.clone()]);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..6 {
            match s.decide(&task, &mk()) {
                SchedulingDecision::Defer { until_s } => {
                    seen.insert(until_s as i64);
                }
                other => panic!("expected defer, got {other:?}"),
            }
        }
        // 101 is within 2% of 100: all three slots share the plateau.
        assert_eq!(seen.into_iter().collect::<Vec<_>>(), vec![300, 600, 900]);
    }

    #[test]
    fn rotation_advances_only_on_defer_verdicts() {
        // Twin pin (ISSUE 5 satellite): interleaving forecast-bearing
        // *assign* decisions between two defers must not shift which
        // plateau slot the second defer targets — otherwise two fleets
        // differing only in assign-traffic release deferred work on
        // different slots.
        let task = TaskDemand::default();
        let deep = || {
            fleet_with_forecasts(vec![
                vec![(0.0, 620.0), (300.0, 620.0), (600.0, 620.0)],
                vec![(0.0, 530.0), (300.0, 530.0), (600.0, 530.0)],
                // Routed node: two equally-clean future slots (plateau).
                vec![(0.0, 380.0), (300.0, 100.0), (600.0, 100.0)],
            ])
        };
        // Flat forecasts: a forecast-bearing decision that assigns.
        let flat = || {
            fleet_with_forecasts(vec![
                vec![(0.0, 620.0), (300.0, 620.0)],
                vec![(0.0, 530.0), (300.0, 530.0)],
                vec![(0.0, 380.0), (300.0, 380.0)],
            ])
        };
        let defers_of = |decisions: &[&dyn Fn() -> FleetView]| {
            let mut s = DeferAwareGreenScheduler::new(0.05);
            decisions
                .iter()
                .filter_map(|mk| match s.decide(&task, &mk()) {
                    SchedulingDecision::Defer { until_s } => Some(until_s),
                    _ => None,
                })
                .collect::<Vec<f64>>()
        };
        let plain = defers_of(&[&deep, &deep]);
        // The same two defers with assign-traffic interleaved: identical
        // release slots. Under the old any-decision counter the middle
        // assigns advanced the rotation and shifted the second slot.
        let interleaved = defers_of(&[&deep, &flat, &flat, &deep]);
        assert_eq!(plain, interleaved, "assign traffic shifted the release rotation");
        assert_eq!(plain, vec![300.0, 600.0], "successive defers still rotate the plateau");
    }

    #[test]
    fn batch_join_moves_to_forming_batch_within_tolerance() {
        use crate::scheduler::ClassNodeView;
        let task = TaskDemand::default(); // class 0
        let cs = |queued: usize| {
            vec![ClassNodeView { queued, predicted_dispatch_s: 0.1, queue_delay_s: 0.0 }]
        };
        // node-medium overridden to 380 g/kWh wins green routing (its S_P
        // edge over node-green dominates a near-tie on intensity); the
        // join question is whether node-green's forming batch pulls the
        // task over anyway.
        let mk = |green_i: f64, fill_medium: usize, fill_green: usize| {
            let r = NodeRegistry::paper_setup();
            r.get(1).set_intensity(380.0);
            r.get(2).set_intensity(green_i);
            let mut f = FleetView::observe(r.nodes());
            f.nodes[0].class_state = cs(0);
            f.nodes[1].class_state = cs(fill_medium);
            f.nodes[2].class_state = cs(fill_green);
            f
        };
        let mut s = DeferAwareGreenScheduler::new(0.05);
        // Sanity: with no open batches the route is node-medium.
        assert_eq!(s.decide(&task, &mk(390.0, 0, 0)), SchedulingDecision::Assign(1));
        // node-green at 390 g (within 5% of 380) holds a 3-deep forming
        // batch: join it instead of opening a fresh batch on node-medium.
        assert_eq!(s.decide(&task, &mk(390.0, 0, 3)), SchedulingDecision::Assign(2));
        // The routed node's own forming batch wins outright…
        assert_eq!(s.decide(&task, &mk(390.0, 2, 3)), SchedulingDecision::Assign(1));
        // …and a batch on a node past the tolerance is not worth chasing
        // (450 g vs the 380·1.05 = 399 g limit).
        assert_eq!(s.decide(&task, &mk(450.0, 0, 3)), SchedulingDecision::Assign(1));
        // Without batching context the verdict is the plain green route.
        let r = NodeRegistry::paper_setup();
        let f = FleetView::observe(r.nodes());
        assert_eq!(s.decide(&task, &f), SchedulingDecision::Assign(2));
    }

    #[test]
    fn forming_batch_flips_defer_to_join() {
        use crate::scheduler::ClassNodeView;
        // The marginal-energy credit in action: a trough deep enough to
        // park a batch-OPENING request is not deep enough to beat joining
        // an already-forming batch on the same node, so the identical
        // fleet snapshot flips from Defer to Assign once a batch forms.
        let reg = NodeRegistry::paper_setup();
        let spec = &reg.get(2).spec; // node-green: green routing's pick
        let e =
            |b: usize| spec.batch_dynamic_power_w(b) * spec.batch_latency_ms(spec.prior_ms, b);
        let ratio = (e(2) - e(1)) / e(1);
        assert!(ratio > 0.0 && ratio < 1.0, "paper nodes must amortize, got {ratio}");
        let gain = 0.05;
        let now_i = 380.0;
        // Halfway between the two thresholds: clears the full-freight bar,
        // misses the marginal-credit bar.
        let trough = now_i * (1.0 - gain) * (1.0 + ratio) / 2.0;
        let mk = |queued: usize| {
            let r = NodeRegistry::paper_setup();
            let mut f = FleetView::observe(r.nodes());
            f.nodes[0].forecast = vec![(0.0, 620.0), (300.0, 620.0)];
            f.nodes[1].forecast = vec![(0.0, 530.0), (300.0, 530.0)];
            f.nodes[2].forecast = vec![(0.0, now_i), (300.0, trough)];
            for (i, v) in f.nodes.iter_mut().enumerate() {
                v.class_state = vec![ClassNodeView {
                    queued: if i == 2 { queued } else { 0 },
                    predicted_dispatch_s: 0.1,
                    queue_delay_s: 0.0,
                }];
            }
            f
        };
        let task = TaskDemand::default();
        let mut s = DeferAwareGreenScheduler::new(gain);
        // No batch forming: the trough wins and the task parks.
        assert_eq!(s.decide(&task, &mk(0)), SchedulingDecision::Defer { until_s: 300.0 });
        // A 1-deep forming batch on the routed node: joining costs only
        // the marginal slot energy, so the same trough no longer pays.
        assert_eq!(s.decide(&task, &mk(1)), SchedulingDecision::Assign(2));
    }

    #[test]
    #[should_panic(expected = "defer_min_gain")]
    fn bad_min_gain_rejected() {
        DeferAwareGreenScheduler::new(1.5);
    }
}
