//! Scheduling modes and weight configurations (paper Table I).

/// Weight vector for Eq. 3: `S = w_R·S_R + w_L·S_L + w_P·S_P + w_B·S_B + w_C·S_C`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Weights {
    pub r: f64,
    pub l: f64,
    pub p: f64,
    pub b: f64,
    pub c: f64,
}

impl Weights {
    pub fn sum(&self) -> f64 {
        self.r + self.l + self.p + self.b + self.c
    }

    /// Normalize to sum 1 (weights from sweeps/configs may not add up).
    pub fn normalized(&self) -> Weights {
        let s = self.sum();
        // lint: allow(P2 config-time guard, pinned by a should_panic test)
        assert!(s > 0.0, "zero weight vector");
        Weights { r: self.r / s, l: self.l / s, p: self.p / s, b: self.b / s, c: self.c / s }
    }

    /// Custom sweep point (Fig. 3): carbon weight `w_c`, the remaining mass
    /// distributed over R/L/P/B in Performance mode's proportions.
    pub fn sweep(w_c: f64) -> Weights {
        // lint: allow(P2 sweep points are built once per experiment, keep the guard loud)
        assert!((0.0..=1.0).contains(&w_c));
        let base = Mode::Performance.weights();
        let rest = base.r + base.l + base.p + base.b; // 0.95
        let k = (1.0 - w_c) / rest;
        Weights { r: base.r * k, l: base.l * k, p: base.p * k, b: base.b * k, c: w_c }
    }
}

/// The paper's operational modes (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    Performance,
    Green,
    Balanced,
}

impl Mode {
    /// Exact Table I weight configurations.
    pub fn weights(self) -> Weights {
        match self {
            Mode::Performance => Weights { r: 0.25, l: 0.25, p: 0.30, b: 0.15, c: 0.05 },
            Mode::Green => Weights { r: 0.15, l: 0.15, p: 0.10, b: 0.10, c: 0.50 },
            Mode::Balanced => Weights { r: 0.20, l: 0.20, p: 0.15, b: 0.15, c: 0.30 },
        }
    }

    pub fn all() -> [Mode; 3] {
        [Mode::Performance, Mode::Balanced, Mode::Green]
    }

    pub fn name(self) -> &'static str {
        match self {
            Mode::Performance => "performance",
            Mode::Green => "green",
            Mode::Balanced => "balanced",
        }
    }

    pub fn parse(s: &str) -> Option<Mode> {
        match s.to_ascii_lowercase().as_str() {
            "performance" | "perf" => Some(Mode::Performance),
            "green" => Some(Mode::Green),
            "balanced" => Some(Mode::Balanced),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_exact_values() {
        let p = Mode::Performance.weights();
        assert_eq!((p.r, p.l, p.p, p.b, p.c), (0.25, 0.25, 0.30, 0.15, 0.05));
        let g = Mode::Green.weights();
        assert_eq!((g.r, g.l, g.p, g.b, g.c), (0.15, 0.15, 0.10, 0.10, 0.50));
        let b = Mode::Balanced.weights();
        assert_eq!((b.r, b.l, b.p, b.b, b.c), (0.20, 0.20, 0.15, 0.15, 0.30));
    }

    #[test]
    fn table1_rows_sum_to_one() {
        for m in Mode::all() {
            assert!((m.weights().sum() - 1.0).abs() < 1e-12, "{m:?}");
        }
    }

    #[test]
    fn normalization() {
        let w = Weights { r: 2.0, l: 2.0, p: 2.0, b: 2.0, c: 2.0 }.normalized();
        assert!((w.sum() - 1.0).abs() < 1e-12);
        assert!((w.c - 0.2).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn zero_weights_panic() {
        Weights { r: 0.0, l: 0.0, p: 0.0, b: 0.0, c: 0.0 }.normalized();
    }

    #[test]
    fn sweep_endpoints_and_interior() {
        let w0 = Weights::sweep(0.0);
        assert!((w0.sum() - 1.0).abs() < 1e-12);
        assert_eq!(w0.c, 0.0);
        // At w_c = 0.05 the sweep reproduces Performance mode exactly.
        let w05 = Weights::sweep(0.05);
        let p = Mode::Performance.weights();
        assert!((w05.r - p.r).abs() < 1e-12);
        assert!((w05.p - p.p).abs() < 1e-12);
        // w_c = 1: everything on carbon.
        let w1 = Weights::sweep(1.0);
        assert!((w1.c - 1.0).abs() < 1e-12);
        assert!(w1.r.abs() < 1e-12);
    }

    #[test]
    fn mode_parse() {
        assert_eq!(Mode::parse("green"), Some(Mode::Green));
        assert_eq!(Mode::parse("PERF"), Some(Mode::Performance));
        assert_eq!(Mode::parse("Balanced"), Some(Mode::Balanced));
        assert_eq!(Mode::parse("eco"), None);
    }
}
