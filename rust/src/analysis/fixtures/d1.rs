//! Known-bad fixture: D1 — HashMap iteration in a deterministic module.
//! Iteration order is randomized per process, so any report fold fed
//! from this loop breaks determinism-by-equality.
use std::collections::HashMap;

/// Collect node names — in whatever order the hasher feels like today.
pub fn node_names(index: &HashMap<String, usize>) -> Vec<String> {
    let mut names = Vec::new();
    for name in index.keys() {
        names.push(name.clone());
    }
    names
}
