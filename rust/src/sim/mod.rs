//! # L3.5 — the discrete-event fleet simulator
//!
//! The real `ServingLoop` executes one request at a time against PJRT and
//! sleeps on the wall clock — high fidelity, but physically incapable of the
//! regimes where carbon-aware policies actually differentiate: load
//! contention, temporal intensity variation, and fleet heterogeneity
//! (GreenScale, Ecomap). This module trades the real executor for the
//! calibrated per-node models the repo already has and runs everything on a
//! **virtual clock**:
//!
//! * a deterministic binary-heap event queue over virtual seconds;
//! * per-node FIFO queues with bounded concurrency;
//! * service times from the `NodeSpec` latency model
//!   (`t_exec·(1 + α·(1/quota − 1)) + overhead`) with seeded lognormal
//!   jitter via [`crate::util::rng`];
//! * a **two-part energy model**: every powered-on node accrues its
//!   `NodeSpec::idle_w` floor across virtual uptime (integrated piecewise
//!   against its intensity trace), and each task adds
//!   `dynamic_power_w × service` on top, priced via
//!   [`crate::carbon::emissions_g`] at the **completion-time** value of the
//!   time-varying [`crate::carbon::IntensityTrace`] — so both consolidation
//!   effects (fewer busy nodes beat many idle ones) and `Diurnal`/`Trace`
//!   grids sit on the accounting path;
//! * **verdict-driven carbon deferral** ([`DeferralSpec`]): arrivals
//!   carrying slack get per-node *effective-intensity forecasts* built
//!   into their [`crate::scheduler::FleetView`], and the scheduler's own
//!   [`crate::scheduler::SchedulingDecision`] says run-here or
//!   park-until-then (`deferred`/`deadline_missed` counters in the
//!   report). Non-deferring schedulers are wrapped in the legacy
//!   [`crate::scheduler::RouteThenDefer`] gate;
//!   [`crate::scheduler::DeferAwareGreenScheduler`] decides *where and
//!   when* jointly. `real-trace` exercises the gate against an
//!   ElectricityMaps-style CSV day curve, `deferral-routing` the joint
//!   policy under contention;
//! * **per-node microgrids** ([`crate::microgrid`]): a node may sit behind
//!   a PV array + battery; both parts of its draw are then covered
//!   PV-first, then battery, then grid (settled slice-by-slice along the
//!   virtual clock), grid joules bear carbon at the slice-mean intensity,
//!   battery joules bear their *embodied* (stored-carbon) intensity, and
//!   the report splits supply into pv/battery/grid per node with SoC
//!   timelines. The *marginal* effective intensity — what the next task's
//!   watts would actually pay after the standing draw claims local
//!   supply — feeds `EdgeNode::intensity_override`, so carbon-aware modes
//!   follow the sun and the charge (`solar-battery`, `microgrid-fleet`
//!   scenarios; [`crate::experiments::sim_microgrid`]);
//! * **grid-charge arbitrage + SoC-trajectory forecasts**: a
//!   [`crate::microgrid::ChargePolicy`] lets batteries import grid power
//!   during the cleanest fraction of the day-ahead window, carried at its
//!   embodied intensity by a stored-carbon ledger (`charged == discharged
//!   + stored`, never laundered to zero), and microgrid forecasts are
//!   simulated SoC trajectories ([`crate::microgrid::Microgrid::project`])
//!   instead of charge-frozen blends — deferral verdicts price release
//!   slots against the battery the node will actually have (`arbitrage`
//!   scenario, [`crate::experiments::sim_arbitrage_comparison`],
//!   `--compare-arbitrage`);
//! * a **batched multi-tenant service model** ([`BatchSpec`] +
//!   [`crate::workload::WorkloadMix`]): arrivals sample a workload class
//!   (per-class demand, SLO tier, model `exec_scale`, priority) from a
//!   dedicated seeded stream, dispatch lands in per-`(node, class)`
//!   batch-formation queues, and same-class tasks accumulate until the
//!   fill target or the formation window seals the batch — which then
//!   occupies **one service slot** at the node's sub-linear batch
//!   latency/power point ([`crate::node::NodeSpec::batch_latency_ms`]),
//!   its energy settled once and apportioned equally across members.
//!   `window 0 × max_batch 1` reproduces the one-task-per-slot model
//!   bit for bit; the report gains per-class rows ([`ClassUsage`]:
//!   completions, SLO misses against the class's own budget, realized
//!   mean fill, gCO₂/req). `batch-serving` and `multi-tenant` exercise
//!   it; [`crate::experiments::sim_batching_comparison`] and
//!   `--compare-batching` A/B it against the unbatched twin;
//! * scheduling through the [`crate::scheduler::Scheduler`] `decide` API:
//!   every admission snapshots a [`crate::scheduler::FleetView`] — per-node
//!   state (queue depth + in-flight as `inflight`), a queue-delay estimate
//!   (backlog × mean service ÷ service slots, reported per node as
//!   p50/max), the current virtual-time grid (or blended microgrid)
//!   intensity, and forecast context for slack-carrying arrivals — and the
//!   engine obeys the returned verdict;
//! * **opt-in observability** ([`crate::obs`]): the
//!   [`Simulation::try_run_observed`] entry point threads an
//!   [`crate::obs::EventSink`] through every hot path — arrivals,
//!   scheduling verdicts (with the per-candidate rationale from
//!   [`crate::scheduler::Scheduler::decide_explained`]), dispatches,
//!   deferred releases, completions, churn transitions and microgrid
//!   settlement slices — and returns an in-process
//!   [`crate::obs::Telemetry`] registry (event counters, queue-delay /
//!   latency / per-decision-overhead histograms) beside the report. The
//!   NDJSON [`crate::obs::FirehoseSink`] streams one event per line to
//!   disk (`carbonedge sim --trace-out`); with no sink attached nothing
//!   is ever constructed, and a traced run's [`SimReport`] is
//!   bit-identical to an untraced one (`tests/obs.rs`);
//! * **trace replay & audit** ([`crate::obs::ReplayState`]): an
//!   `all`-filter firehose is a complete ledger — `carbonedge replay`
//!   streams it back through [`crate::obs::FirehoseReader`] and
//!   reconstructs the full [`SimReport`] (counters exactly, energy/carbon
//!   to 1e-6) purely from events, and `carbonedge replay --diff A B`
//!   names the first divergent event between two traces for determinism
//!   debugging;
//! * **hierarchical multi-site fleets** ([`crate::site`], `Scenario::sites`):
//!   nodes group into named sites, each with its own grid trace, microgrid
//!   posture and timezone; a [`crate::site::SiteTopology`] prices every
//!   cross-site hop in WAN latency *and* transfer energy (billed at the
//!   origin site's intensity, Eq. 2 style), and a [`crate::site::Router`]
//!   (`nearest` / `carbon` / `deadline`) picks the serving site per arrival
//!   from O(sites) [`crate::site::SiteView`] summaries before the
//!   intra-site scheduler sees the request. Shipped requests re-enter the
//!   event heap after the WAN delay, emit `wan_hop` firehose events, and
//!   the report gains per-site rows ([`SiteUsage`]: completions, shipped
//!   in/out, member vs WAN energy, gCO₂/req) that partition the fleet
//!   totals exactly. `multi-site` staggers three regional grids;
//!   `follow-the-sun` rotates PV peaks across timezones so cross-region
//!   shifting beats any single-site green policy
//!   ([`crate::experiments::sim_router_comparison`], `--compare-routers`);
//! * **class-aware admission control** ([`AdmissionSpec`], satellite of the
//!   site layer): under sustained overload the engine sheds fresh arrivals
//!   *before* the scheduler decides, lowest priority first — a class at
//!   priority `p` tolerates `shed_queue_s × (1 + p)` of estimated queue
//!   delay — and per-class `rejected` counts land in [`ClassUsage`];
//! * **in-sim monitors** ([`crate::obs::MonitorSet`],
//!   [`Simulation::try_run_monitored`], `sim --monitor`): sliding
//!   virtual-time windows over the event stream — carbon burn-rate vs a
//!   gCO₂/s budget, per-class SLO-miss burn rate, reject/defer rate —
//!   fire `alert` events into the firehose and per-rule summaries into
//!   both [`crate::obs::Telemetry`] and the report.
//!
//! Identical seeds produce identical [`SimReport`]s; millions of simulated
//! requests run in seconds (`benches/sim.rs`). The scenario library lives
//! in [`scenarios`]; fleet synthesis in [`fleet`].
//!
//! # Invariants & lint
//!
//! Determinism-by-equality is a *source-level* discipline, enforced
//! statically by [`crate::analysis`] (`carbonedge lint --deny rust/src`,
//! run as its own CI job):
//!
//! * no `HashMap`/`HashSet` iteration in simulator modules (D1), and
//!   never an f64 fold over one (D3) — hasher order varies per process,
//!   float addition does not commute, and one unordered fold feeding a
//!   [`SimReport`] silently breaks traced==untraced and replay==live
//!   bit-identity; keyed state uses `BTreeMap` or sorted collects;
//! * no `Instant::now`/`SystemTime::now`/ambient randomness (D2) —
//!   virtual time comes from the event queue, randomness from the seeded
//!   [`crate::util::rng`] streams (the engine's real-clock reads for
//!   decide-ns telemetry carry waivers: they measure overhead, they
//!   never feed virtual state);
//! * no unwaived `unwrap`/`expect` (P1) and no release `assert!` outside
//!   `validate*` one-shots (P2) — [`Scenario::validate`] is the single
//!   loud gate at run start, hot paths use `debug_assert!`;
//! * unit suffixes (`_s`/`_ms`, `_wh`/`_kwh`, …) never flow across a
//!   direct assignment/comparison without an explicit conversion (U1).
//!
//! Exceptions are inline `// lint: allow(RULE reason)` waivers naming
//! the invariant that makes them safe; `rust/tests/lint.rs` pins the
//! tree at zero unwaived findings.

mod engine;
pub mod fleet;
pub(crate) mod report;
pub mod scenarios;

pub use engine::{
    AdmissionSpec, ArrivalProcess, BatchSpec, ChurnEvent, DeferralSpec, SimConfig, Simulation,
};
pub use report::{ClassUsage, NodeUsage, SimReport, SiteUsage};
pub use scenarios::{Scenario, SCENARIO_NAMES};
