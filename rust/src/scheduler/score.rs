//! Score components of Eq. 3 and the carbon-efficiency score of Eq. 4.
//!
//! All components are normalized to [0, 1] (Sec. III-C). Formulas follow
//! Algorithm 1 lines 7–11 exactly:
//!
//! * `S_R` — resource availability relative to the task's demand;
//! * `S_L = 1 − load`;
//! * `S_P = 1 / (1 + avg_time)` with time in **seconds** (the paper's
//!   reported S_P range of 0.166 across ~250–600 ms nodes pins the unit);
//! * `S_B = 1 / (1 + 2·task_count)` with `task_count` = in-flight tasks;
//! * `S_C = 1 / (1 + I_carbon · E_est)`, `E_est = P_node · T_avg / 3.6e6`
//!   (the paper's W × ms conversion, Sec. III-C1).

use std::sync::Arc;

use crate::node::EdgeNode;

use super::{NodeView, Weights};

/// Resource demand of an inference task (Algorithm 1's `t`).
#[derive(Debug, Clone, Copy)]
pub struct TaskDemand {
    /// CPU cores needed.
    pub cpu: f64,
    /// Memory needed (MB).
    pub mem_mb: usize,
    /// Latency threshold for the Algorithm 1 line-3 filter (ms).
    pub latency_threshold_ms: f64,
    /// Workload-class index into the run's
    /// [`crate::workload::WorkloadMix`] — same-class tasks share a model
    /// and may be served in one batch. Single-class runs (and the paper's
    /// testbed) use class 0 throughout; the index keys the per-class
    /// batch-fill state in [`super::NodeView::class_state`].
    pub class: usize,
}

impl Default for TaskDemand {
    fn default() -> Self {
        // A lightweight CNN inference: fits every paper node.
        TaskDemand { cpu: 0.2, mem_mb: 256, latency_threshold_ms: 5_000.0, class: 0 }
    }
}

/// All five components plus the weighted total.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoreBreakdown {
    pub s_r: f64,
    pub s_l: f64,
    pub s_p: f64,
    pub s_b: f64,
    pub s_c: f64,
    pub total: f64,
}

/// `S_R`: how comfortably the node's free resources cover the demand,
/// averaged over CPU and memory and clamped to [0, 1].
pub fn resource_score(node: &EdgeNode, task: &TaskDemand) -> f64 {
    resource_score_from(&node.state(), node, task)
}

fn resource_score_from(st: &crate::node::NodeState, node: &EdgeNode, task: &TaskDemand) -> f64 {
    let free_cpu = node.spec.cpu_quota * (1.0 - st.load);
    let cpu_ratio = (free_cpu / task.cpu.max(1e-9)).min(1.0);
    // Memory mirrors the CPU term: the quota minus what in-flight tasks
    // already hold. Charging the full quota as free would keep S_R's
    // memory term at 1.0 no matter the load. In-flight reservations are
    // estimated as `inflight × task.mem_mb` — exact in this testbed and
    // the simulator, where every request in a run presents the same
    // `TaskDemand`; heterogeneous demands would need per-node reserved-
    // memory tracking in `NodeState`.
    let held_mb = st.inflight as f64 * task.mem_mb as f64;
    let free_mem = (node.spec.mem_mb as f64 - held_mb).max(0.0);
    let mem_ratio = (free_mem / task.mem_mb.max(1) as f64).min(1.0);
    ((cpu_ratio + mem_ratio) / 2.0).clamp(0.0, 1.0)
}

/// `S_C` (Eq. 4) from raw quantities.
pub fn carbon_score(intensity: f64, power_w: f64, avg_time_ms: f64) -> f64 {
    let e_est = power_w * avg_time_ms / 3.6e6; // the paper's conversion
    1.0 / (1.0 + intensity * e_est)
}

/// Full Eq. 3 breakdown from a [`NodeView`] snapshot.
///
/// Every component derives from the view's single [`crate::node::NodeState`]
/// snapshot — this sits on the simulator's scheduling hot path (one call
/// per node per arrival), so re-reading through the locking node accessors
/// per component would triple the mutex traffic. The carbon component
/// prices the view's *effective* intensity, which carries the simulator's
/// virtual-time (and microgrid-blended) override when one is installed.
pub fn score_breakdown_view(view: &NodeView, task: &TaskDemand, w: &Weights) -> ScoreBreakdown {
    let node = &view.node;
    let st = &view.state;
    let s_r = resource_score_from(st, node, task);
    let s_l = (1.0 - st.load).clamp(0.0, 1.0);
    // The T_avg rule of NodeView::score_ms, from the snapshot in hand.
    let avg_ms = view.score_ms();
    let s_p = 1.0 / (1.0 + avg_ms / 1e3); // seconds
    let s_b = 1.0 / (1.0 + 2.0 * st.inflight as f64);
    let s_c = carbon_score(view.intensity, node.spec.rated_power_w, avg_ms);
    let total = w.r * s_r + w.l * s_l + w.p * s_p + w.b * s_b + w.c * s_c;
    ScoreBreakdown { s_r, s_l, s_p, s_b, s_c, total }
}

/// Full Eq. 3 breakdown for one live node (snapshots it first).
pub fn score_breakdown(node: &Arc<EdgeNode>, task: &TaskDemand, w: &Weights) -> ScoreBreakdown {
    score_breakdown_view(&NodeView::observe(node, 1), task, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeSpec;
    use crate::scheduler::Mode;

    fn nodes() -> Vec<Arc<EdgeNode>> {
        NodeSpec::paper_nodes().into_iter().map(EdgeNode::new).collect()
    }

    #[test]
    fn eq4_hand_computed() {
        // S_C = 1 / (1 + I * P*T/3.6e6)
        let s = carbon_score(620.0, 170.0, 250.0);
        let e = 170.0 * 250.0 / 3.6e6;
        assert!((s - 1.0 / (1.0 + 620.0 * e)).abs() < 1e-12);
        // monotone: lower intensity -> higher score
        assert!(carbon_score(380.0, 170.0, 250.0) > s);
        // monotone: lower power -> higher score
        assert!(carbon_score(620.0, 68.0, 250.0) > s);
        // zero energy estimate -> perfect score
        assert_eq!(carbon_score(620.0, 0.0, 250.0), 1.0);
    }

    #[test]
    fn components_in_unit_range() {
        let task = TaskDemand::default();
        let w = Mode::Green.weights();
        for n in nodes() {
            let b = score_breakdown(&n, &task, &w);
            for v in [b.s_r, b.s_l, b.s_p, b.s_b, b.s_c] {
                assert!((0.0..=1.0).contains(&v), "{b:?}");
            }
            assert!(b.total <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn calibrated_ranges_match_paper() {
        // DESIGN.md §3: the cold-start score ranges reproduce the paper's
        // reported differentiation: range(S_C) ≈ 0.054, range(S_P) ≈ 0.166.
        let task = TaskDemand::default();
        let w = Mode::Balanced.weights();
        let bs: Vec<ScoreBreakdown> =
            nodes().iter().map(|n| score_breakdown(n, &task, &w)).collect();
        let range = |f: fn(&ScoreBreakdown) -> f64| {
            let vals: Vec<f64> = bs.iter().map(f).collect();
            vals.iter().cloned().fold(f64::MIN, f64::max)
                - vals.iter().cloned().fold(f64::MAX, f64::min)
        };
        let rc = range(|b| b.s_c);
        let rp = range(|b| b.s_p);
        assert!((rc - 0.054).abs() < 0.02, "range(S_C) = {rc}");
        assert!((rp - 0.166).abs() < 0.04, "range(S_P) = {rp}");
        // S_C must differentiate less than S_P (the paper's Balanced-mode
        // explanation, Sec. IV-F).
        assert!(rc < rp);
    }

    #[test]
    fn idle_nodes_equal_load_and_balance() {
        let task = TaskDemand::default();
        let w = Mode::Performance.weights();
        let bs: Vec<ScoreBreakdown> =
            nodes().iter().map(|n| score_breakdown(n, &task, &w)).collect();
        for b in &bs {
            assert_eq!(b.s_l, 1.0);
            assert_eq!(b.s_b, 1.0);
            assert_eq!(b.s_r, 1.0); // demand fits every node comfortably
        }
    }

    #[test]
    fn inflight_lowers_balance_score() {
        let ns = nodes();
        let task = TaskDemand::default();
        let w = Mode::Performance.weights();
        ns[0].begin_task();
        let b = score_breakdown(&ns[0], &task, &w);
        assert!((b.s_b - 1.0 / 3.0).abs() < 1e-12); // 1/(1+2*1)
        ns[0].begin_task();
        let b2 = score_breakdown(&ns[0], &task, &w);
        assert!((b2.s_b - 0.2).abs() < 1e-12); // 1/(1+2*2)
    }

    #[test]
    fn inflight_demand_depletes_resource_memory_term() {
        // node-green: 512 MB quota against the default 256 MB demand.
        let n = EdgeNode::new(NodeSpec::paper_nodes().remove(2));
        let task = TaskDemand::default();
        let w = Mode::Green.weights();
        assert_eq!(score_breakdown(&n, &task, &w).s_r, 1.0);
        // One task in flight: 256 MB still free — exactly one demand fits.
        n.begin_task();
        assert_eq!(score_breakdown(&n, &task, &w).s_r, 1.0);
        // Two in flight: memory exhausted, the term collapses to 0 and S_R
        // to the CPU half (load is still 0, so cpu_ratio = 1).
        n.begin_task();
        let b = score_breakdown(&n, &task, &w);
        assert!((b.s_r - 0.5).abs() < 1e-12, "s_r = {}", b.s_r);
        // Partial depletion: a 128 MB demand sees 256/128 -> ratio capped
        // at 1; a 384 MB demand sees 512-2*384 < 0 clamped to 0.
        let big = TaskDemand { mem_mb: 384, ..task };
        let bb = score_breakdown(&n, &big, &w);
        assert!((bb.s_r - 0.5).abs() < 1e-12, "s_r = {}", bb.s_r);
    }

    #[test]
    fn sp_uses_seconds() {
        let ns = nodes();
        // node-high prior 250 ms -> S_P = 1/1.25 = 0.8
        let b = score_breakdown(&ns[0], &TaskDemand::default(), &Mode::Green.weights());
        assert!((b.s_p - 0.8).abs() < 1e-9);
    }

    #[test]
    fn dynamic_intensity_flows_into_s_c() {
        let ns = nodes();
        let task = TaskDemand::default();
        let w = Mode::Green.weights();
        let before = score_breakdown(&ns[0], &task, &w);
        // node-high (620) told its grid just went hydro-clean: S_C must rise
        // to exactly the carbon_score at the overridden intensity.
        ns[0].set_intensity(45.0);
        let after = score_breakdown(&ns[0], &task, &w);
        assert!(after.s_c > before.s_c);
        let want = carbon_score(45.0, ns[0].spec.rated_power_w, ns[0].score_ms());
        assert!((after.s_c - want).abs() < 1e-12);
    }

    #[test]
    fn weighted_total_formula() {
        let ns = nodes();
        let task = TaskDemand::default();
        let w = Weights { r: 0.1, l: 0.2, p: 0.3, b: 0.15, c: 0.25 };
        let b = score_breakdown(&ns[1], &task, &w);
        let expect = 0.1 * b.s_r + 0.2 * b.s_l + 0.3 * b.s_p + 0.15 * b.s_b + 0.25 * b.s_c;
        assert!((b.total - expect).abs() < 1e-12);
    }
}
