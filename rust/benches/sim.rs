//! Simulator throughput bench: how many virtual requests per wall-clock
//! second the discrete-event engine sustains. Target (ISSUE 1 / ROADMAP
//! L3.5): ≥ 1M simulated requests/s on the paper-3-node scenario.
//!
//! Each scenario also gets a counters-only observed run (`NullSink`) to
//! measure per-decision scheduling overhead in nanoseconds against the
//! paper's 0.03 ms envelope (Sec. IV-F), and the whole result set is
//! emitted as `BENCH_sim.json` (sim-req/s + ns/decision per scenario) so
//! CI can archive machine-readable numbers.
//!
//! Needs no artifacts — run with `cargo bench --bench sim`.

use std::time::Instant;

use carbonedge::node::EdgeNode;
use carbonedge::obs::{NullSink, OVERHEAD_ENVELOPE_NS};
use carbonedge::scheduler::{
    CarbonAwareScheduler, DeferAwareGreenScheduler, FleetView, Mode, Scheduler,
};
use carbonedge::sim::{scenarios, Simulation};
use carbonedge::util::json::JsonWriter;

struct Row {
    scenario: &'static str,
    requests: usize,
    sim_rps: f64,
    decide_ns_mean: f64,
    decide_ns_p99: f64,
}

fn green() -> Box<dyn Scheduler> {
    Box::new(CarbonAwareScheduler::new("green", Mode::Green.weights()))
}

/// Best-of-`runs` untraced throughput, plus one counters-only observed run
/// for the per-decision overhead histogram. The observed run never enters
/// the timing: tracing is benched as overhead-per-decision, not folded
/// into sim-req/s.
fn bench(
    name: &'static str,
    nodes: usize,
    requests: usize,
    runs: usize,
    mk: &dyn Fn() -> Box<dyn Scheduler>,
) -> Row {
    let sc = scenarios::build(name, nodes, requests, 42).expect("known scenario");
    let mut best = f64::MAX;
    for _ in 0..runs {
        let mut sched = mk();
        let t0 = Instant::now();
        let r = Simulation::run(&sc, sched.as_mut());
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(r.completed + r.rejected, requests as u64);
        best = best.min(dt);
    }
    let mut sched = mk();
    let mut null = NullSink;
    let (_, telem) =
        Simulation::try_run_observed(&sc, sched.as_mut(), &mut null).expect("valid scenario");
    Row {
        scenario: name,
        requests,
        sim_rps: requests as f64 / best,
        decide_ns_mean: telem.decide_ns.mean(),
        decide_ns_p99: telem.decide_ns.quantile(0.99),
    }
}

fn main() {
    let g: &dyn Fn() -> Box<dyn Scheduler> = &green;
    let dg: &dyn Fn() -> Box<dyn Scheduler> = &|| Box::new(DeferAwareGreenScheduler::new(0.05));
    let mut rows = Vec::new();

    println!("simulator throughput (best of 3, CE-Green)");
    let r = bench("paper-3-node", 0, 1_000_000, 3, g);
    let verdict =
        if r.sim_rps >= 1e6 { "meets the 1M target" } else { "BELOW the 1M target" };
    println!(
        "  paper-3-node     1M requests   {:>8.2}M sim-req/s  ({verdict})",
        r.sim_rps / 1e6
    );
    rows.push(r);

    let r = bench("fleet-100", 100, 200_000, 3, g);
    println!("  fleet-100      200k requests   {:>8.2}M sim-req/s", r.sim_rps / 1e6);
    rows.push(r);

    let r = bench("bursty", 0, 500_000, 3, g);
    println!("  bursty         500k requests   {:>8.2}M sim-req/s", r.sim_rps / 1e6);
    rows.push(r);

    let r = bench("churn", 0, 200_000, 3, g);
    println!("  churn          200k requests   {:>8.2}M sim-req/s", r.sim_rps / 1e6);
    rows.push(r);

    // Deferral + CSV-trace lookups on the hot path (every arrival consults
    // the forecast, every parked task re-enters the heap).
    let r = bench("real-trace", 0, 200_000, 3, g);
    println!(
        "  real-trace     200k requests   {:>8.2}M sim-req/s  (deferral on)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // Idle-floor accrual + piecewise intensity integration at report time.
    let r = bench("consolidation", 0, 200_000, 3, g);
    println!(
        "  consolidation  200k requests   {:>8.2}M sim-req/s  (idle floors)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // Microgrid settlement on the hot path: every draw change covers a
    // slice PV-first/battery/grid, every refresh re-blends the effective
    // intensity and samples the SoC timeline.
    let r = bench("solar-battery", 0, 200_000, 3, g);
    println!(
        "  solar-battery  200k requests   {:>8.2}M sim-req/s  (pv+battery)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    let r = bench("microgrid-fleet", 0, 200_000, 3, g);
    println!(
        "  microgrid-flt  200k requests   {:>8.2}M sim-req/s  (mixed supply)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // Grid-charge arbitrage + SoC-trajectory forecasts: every settlement
    // slice consults the charge threshold, every slack-carrying arrival
    // rolls a per-node SoC projection over its defer window. Smaller
    // request count: the scenario's pinned arrival rate means requests
    // buy virtual days, not density.
    let r = bench("arbitrage", 0, 50_000, 3, g);
    println!(
        "  arbitrage       50k requests   {:>8.2}M sim-req/s  (SoC projection)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // Joint defer+route: per-arrival fleet-wide forecasts plus the plateau
    // spread in DeferAwareGreenScheduler (the route-then-defer gate path is
    // covered by real-trace above).
    let r = bench("deferral-routing", 0, 200_000, 3, dg);
    println!(
        "  defer-routing  200k requests   {:>8.2}M sim-req/s  (joint defer+route)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // Batch-formation queues on the hot path: every arrival draws a
    // workload class, every dispatch joins or seals a forming batch, and
    // every seal apportions energy across members. The decide-ns row
    // below is the batching baseline for the 10k-node perf item.
    let r = bench("batch-serving", 0, 200_000, 3, g);
    println!(
        "  batch-serving  200k requests   {:>8.2}M sim-req/s  (batch queues)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // Classes + batching + microgrid settlement + demand-aware SoC
    // projections together — the full multi-tenant service model.
    let r = bench("multi-tenant", 0, 200_000, 3, g);
    println!(
        "  multi-tenant   200k requests   {:>8.2}M sim-req/s  (classes+mixed supply)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // The site layer on the hot path: every arrival draws a home site,
    // the router scores O(sites) summaries (timed into the same decide-ns
    // histogram as the scheduler), and shipped requests re-enter the heap
    // after the WAN delay. decide-ns here is the decide+route overhead
    // the 0.03 ms envelope verdict below holds to account.
    let r = bench("multi-site", 0, 200_000, 3, g);
    println!(
        "  multi-site     200k requests   {:>8.2}M sim-req/s  (site routing)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // Follow-the-sun: site routing plus per-arrival PV-aware forecasts
    // and microgrid settlement slices — the full geographic model.
    let r = bench("follow-the-sun", 0, 100_000, 3, dg);
    println!(
        "  follow-sun     100k requests   {:>8.2}M sim-req/s  (routing+pv+defer)",
        r.sim_rps / 1e6
    );
    rows.push(r);

    // Monitor evaluation on the observation path: every emitted event rolls
    // three sliding windows and every decision is timed. Both the
    // throughput and the decide-ns histogram here carry the full monitor
    // cost, which must stay inside the 0.03 ms envelope.
    {
        use carbonedge::obs::{CarbonBudget, MonitorSet};
        let requests = 1_000_000usize;
        let sc = scenarios::build("paper-3-node", 0, requests, 42).expect("known scenario");
        let mut best = f64::MAX;
        let mut last_telem = None;
        for _ in 0..3 {
            let monitors = MonitorSet::new(1_800.0)
                .carbon_budget(CarbonBudget { g_per_s: 0.05 })
                .slo_burn_pct(5.0)
                .reject_defer_pct(20.0);
            let mut sched = green();
            let mut null = NullSink;
            let t0 = Instant::now();
            let (r, telem) =
                Simulation::try_run_monitored(&sc, sched.as_mut(), &mut null, monitors)
                    .expect("valid scenario");
            let dt = t0.elapsed().as_secs_f64();
            assert_eq!(r.completed + r.rejected, requests as u64);
            assert_eq!(r.monitors.len(), 3, "one summary row per rule");
            best = best.min(dt);
            last_telem = Some(telem);
        }
        let telem = last_telem.unwrap();
        let r = Row {
            scenario: "paper-3-node+monitors",
            requests,
            sim_rps: requests as f64 / best,
            decide_ns_mean: telem.decide_ns.mean(),
            decide_ns_p99: telem.decide_ns.quantile(0.99),
        };
        println!(
            "  +3 monitors      1M requests   {:>8.2}M sim-req/s  (monitored run)",
            r.sim_rps / 1e6
        );
        rows.push(r);
    }

    // Per-decision scheduling overhead through the counters-only observed
    // path (NullSink: telemetry on, no serialisation) vs the paper's
    // 0.03 ms/task budget.
    println!("per-decision scheduling overhead (NullSink observed run)");
    for r in &rows {
        let verdict = if r.decide_ns_p99 <= OVERHEAD_ENVELOPE_NS {
            "within the 0.03 ms envelope"
        } else {
            "OVER the 0.03 ms envelope"
        };
        println!(
            "  {:<16} mean {:>7.0} ns  p99 <= {:>7.0} ns  ({verdict})",
            r.scenario, r.decide_ns_mean, r.decide_ns_p99
        );
    }

    // FleetView snapshot cost: the fixed per-arrival price of the decide
    // API. The paper budgets 0.03 ms/task of scheduling overhead
    // (Sec. IV-F); the snapshot must stay a small fraction of it.
    for (label, n) in [("3-node", 3usize), ("100-node", 100)] {
        let specs: Vec<_> = (0..n)
            .map(|i| {
                let mut spec = carbonedge::node::NodeSpec::paper_nodes()[i % 3].clone();
                spec.name = format!("n{i}");
                spec
            })
            .collect();
        let nodes: Vec<_> = specs.into_iter().map(EdgeNode::new).collect();
        let iters = 200_000usize;
        let t0 = Instant::now();
        let mut sink = 0usize;
        for _ in 0..iters {
            sink += FleetView::observe(&nodes).nodes.len();
        }
        let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        assert_eq!(sink, n * iters);
        let verdict = if ns < OVERHEAD_ENVELOPE_NS {
            "within the 0.03 ms/task envelope"
        } else {
            "OVER the 0.03 ms/task envelope"
        };
        println!("  FleetView::observe {label:>9}   {ns:>8.0} ns/snapshot  ({verdict})");
    }

    // Machine-readable results for CI archiving.
    let mut j = JsonWriter::new(Vec::new());
    j.begin_obj().unwrap();
    j.field_num("envelope_ns", OVERHEAD_ENVELOPE_NS).unwrap();
    j.key("scenarios").unwrap();
    j.begin_arr().unwrap();
    for r in &rows {
        j.begin_obj().unwrap();
        j.field_str("scenario", r.scenario).unwrap();
        j.field_num("requests", r.requests as f64).unwrap();
        j.field_fnum("sim_rps", r.sim_rps).unwrap();
        j.field_fnum("decide_ns_mean", r.decide_ns_mean).unwrap();
        j.field_fnum("decide_ns_p99", r.decide_ns_p99).unwrap();
        j.end_obj().unwrap();
    }
    j.end_arr().unwrap();
    j.end_obj().unwrap();
    let mut out = j.into_inner();
    out.push(b'\n');
    std::fs::write("BENCH_sim.json", &out).expect("writing BENCH_sim.json");
    println!("wrote BENCH_sim.json ({} scenarios)", rows.len());
}
