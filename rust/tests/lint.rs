//! The lint gate's own test suite: the fixture corpus must trip exactly
//! one rule each, waivers must suppress-and-count, and — the meta-test —
//! the real `rust/src/` tree must be clean, so `carbonedge lint --deny`
//! stays a zero-finding invariant of the repo just like
//! determinism-by-equality is for the simulator.

use carbonedge::analysis::{self, fixtures};

#[test]
fn each_fixture_trips_exactly_its_own_rule() {
    for (rule, line, path, src) in fixtures::ALL_BAD {
        let r = analysis::lint_source(path, src);
        assert_eq!(
            r.findings.len(),
            1,
            "fixture {rule} must produce exactly one finding, got {:?}",
            r.findings
        );
        let f = &r.findings[0];
        assert_eq!(f.rule.id(), rule, "fixture {rule} fired the wrong rule: {f}");
        assert_eq!(f.line, line, "fixture {rule} fired on the wrong line: {f}");
        assert_eq!(f.path, path);
        assert_eq!(r.waived, 0, "fixture {rule} should carry no waivers");
    }
}

#[test]
fn fixture_rules_are_scoped() {
    // The same D1 hazard outside the deterministic modules is not a
    // finding — util code may use HashMap freely.
    let r = analysis::lint_source("rust/src/util/fixtures/d1.rs", fixtures::D1);
    assert!(r.findings.is_empty(), "D1 must be scoped to det modules: {:?}", r.findings);
    // D2 is global except for the bench harness.
    let r = analysis::lint_source("rust/src/util/bench.rs", fixtures::D2);
    assert!(r.findings.is_empty(), "bench harness may read the wall clock");
}

#[test]
fn waiver_suppresses_and_counts() {
    let r = analysis::lint_source(fixtures::WAIVED_PATH, fixtures::WAIVED);
    assert!(r.findings.is_empty(), "waived fixture must not fire: {:?}", r.findings);
    assert_eq!(r.waived, 1, "the suppressed finding must still be counted");
    // A waiver for the wrong rule does not suppress.
    let wrong = fixtures::WAIVED.replace("allow(P1", "allow(D1");
    let r = analysis::lint_source(fixtures::WAIVED_PATH, &wrong);
    assert_eq!(r.findings.len(), 1, "mismatched waiver must not suppress");
    assert_eq!(r.findings[0].rule.id(), "P1");
    assert_eq!(r.waived, 0);
}

#[test]
fn test_code_is_exempt() {
    let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let x: Option<f64> = Some(1.0);\n        assert!(x.unwrap() > 0.0);\n    }\n}\n";
    let r = analysis::lint_source("rust/src/sim/x.rs", src);
    assert!(r.findings.is_empty(), "tests may unwrap and assert: {:?}", r.findings);
}

/// The meta-test: `lint --deny rust/src` over the real tree reports zero
/// unwaived findings. Every hazard in the simulator source is either
/// fixed or carries an inline waiver naming its invariant — a new
/// unwrap/assert/wall-clock read in scoped code fails this test (and the
/// CI lint job) until it is justified.
#[test]
fn repo_tree_is_lint_clean() {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src");
    let r = analysis::lint_paths(&[root]).expect("walking rust/src");
    assert!(
        r.findings.is_empty(),
        "unwaived lint findings in the tree:\n{}",
        r.findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
    // The sweep left a documented waiver trail; losing it all at once
    // would mean the scoping silently broke.
    assert!(r.waived >= 20, "expected the documented waiver trail, saw {}", r.waived);
    assert!(r.files >= 40, "walked suspiciously few files: {}", r.files);
}
