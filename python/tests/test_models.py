"""L2 correctness: model zoo structure, shapes, stage composition."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile.aot import golden_image
from compile.models import ZOO, build, make_divisible

SMALL = dict(image_size=32, width=0.25, num_classes=10)


@pytest.fixture(scope="module", params=sorted(ZOO))
def small_model(request):
    return build(request.param, **SMALL)


def test_make_divisible():
    assert make_divisible(16) == 16
    assert make_divisible(8.0) == 8
    assert make_divisible(1) == 8  # floor at divisor
    for v in (13, 27, 100, 255):
        assert make_divisible(v) % 8 == 0
        assert make_divisible(v) >= 0.9 * v


def test_stage_shapes_chain(small_model):
    # Stage i out_shape must equal stage i+1 in_shape.
    stages = small_model.stages
    assert stages[0].in_shape == (32, 32, 3)
    for a, b in zip(stages, stages[1:]):
        assert tuple(a.out_shape) == tuple(b.in_shape)
    assert tuple(stages[-1].out_shape) == (10,)


def test_forward_shape_and_finite(small_model):
    x = jnp.asarray(np.random.RandomState(0).randn(32, 32, 3), jnp.float32)
    y = small_model.forward(x)
    assert y.shape == (10,)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_stage_composition_equals_monolithic(small_model):
    """Stage-chained execution must be bit-identical to the monolithic fn."""
    x = jnp.asarray(golden_image(32, seed=7))
    chained = x
    for s in small_model.stages:
        chained = s.fn(s.weights, chained)
    mono = small_model.monolithic_fn()(small_model.all_weights, x)
    np.testing.assert_allclose(np.asarray(chained), np.asarray(mono), rtol=0, atol=0)


def test_deterministic_weights(small_model):
    """Rebuilding the model reproduces identical weights (seeded init)."""
    again = build(small_model.name, **SMALL)
    assert len(again.all_weights) == len(small_model.all_weights)
    for a, b in zip(again.all_weights, small_model.all_weights):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_metadata_consistency(small_model):
    # params metadata matches actual weight element counts.
    meta_params = small_model.params
    actual = sum(int(np.prod(w.shape)) for w in small_model.all_weights)
    assert meta_params == actual
    # Eq. 5 costs are positive for conv/linear layers.
    for m in small_model.layers:
        if m.kind in ("conv2d", "linear"):
            assert m.cost > 0
        assert m.flops >= 0


def test_eq5_cost_model_branches():
    # Paper Eq. 5 exact values per layer kind.
    cm = L.conv_meta("c", 3, 8, 16, (10, 10, 8), (10, 10, 16))
    assert cm.cost == 3 * 3 * 8 * 16
    lm = L.linear_meta("l", 100, 10)
    assert lm.cost == 100 * 10
    dm = L.dw_meta("d", 8, (10, 10, 8), (10, 10, 8))
    assert dm.cost == dm.params  # "others" branch


def test_paper_scale_models():
    """At paper-ish settings the three models keep their relative ordering
    (EfficientNet-B0 > MobileNetV4 > MobileNetV2 in params, as in Sec. IV-A3)."""
    ms = {n: build(n, image_size=64, width=0.5, num_classes=1000) for n in ZOO}
    p = {n: m.params for n, m in ms.items()}
    assert p["efficientnet_b0"] > p["mobilenet_v2"]
    for m in ms.values():
        assert 0.5e6 < m.params < 6e6
        assert len(m.stages) == 4


def test_stage_weight_partition(small_model):
    """all_weights is exactly the concatenation of per-stage weights."""
    cat = [w for s in small_model.stages for w in s.weights]
    assert len(cat) == len(small_model.all_weights)
    for a, b in zip(cat, small_model.all_weights):
        assert a is b


def test_unknown_model_rejected():
    with pytest.raises(KeyError):
        build("resnet50")
