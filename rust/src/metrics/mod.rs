//! Metrics collection and experiment reporting: latency/throughput/energy/
//! carbon aggregation in the exact units the paper's tables use.

mod export;

pub use export::{
    compliance_document, report_to_json, sim_report_json_string,
    sim_report_json_string_strided, sim_report_to_json, write_sim_report,
};

use anyhow::{ensure, Result};

use crate::carbon;
use crate::node::ExecutionRecord;
use crate::util::stats::Summary;

/// Aggregated results of one experiment configuration
/// (e.g. "CE-Green / MobileNetV2 / 50 inferences").
#[derive(Debug, Clone)]
pub struct RunReport {
    pub label: String,
    pub inferences: u64,
    /// Mean end-to-end latency (ms) — Table II column 1.
    pub latency_ms: Summary,
    /// Throughput (req/s) over the run — Table II column 2.
    pub throughput_rps: f64,
    /// Total energy (kWh) over the run.
    pub energy_kwh: f64,
    /// Carbon per inference (gCO₂/inf) — Table II column 3.
    pub carbon_per_inf_g: f64,
    /// Total carbon (g).
    pub carbon_total_g: f64,
    /// Carbon efficiency (inferences per gram) — Fig. 2 y-axis.
    pub carbon_efficiency: f64,
    /// Node usage distribution: (node, tasks) — Table V.
    pub node_usage: Vec<(String, u64)>,
    /// Mean real PJRT execution time (ms), pre-simulation.
    pub exec_ms_mean: f64,
}

impl RunReport {
    /// Build from per-task execution records (closed-loop run: wall time =
    /// Σ simulated latencies). An empty record set is an `Err` — a run
    /// where every task failed or was filtered out has no aggregates to
    /// report, and callers (the CLI, the coordinator) surface that as a
    /// clean error instead of a panic.
    pub fn from_records(label: &str, records: &[ExecutionRecord]) -> Result<RunReport> {
        ensure!(!records.is_empty(), "run {label:?} produced no execution records to aggregate");
        let lat: Vec<f64> = records.iter().map(|r| r.latency_ms).collect();
        let energy_j: f64 = records.iter().map(|r| r.energy_j).sum();
        let carbon_g: f64 = records.iter().map(|r| r.carbon_g).sum();
        let n = records.len() as u64;
        let wall_s = lat.iter().sum::<f64>() / 1e3;
        let mut usage: std::collections::BTreeMap<String, u64> = Default::default();
        for r in records {
            *usage.entry(r.node.clone()).or_default() += 1;
        }
        Ok(RunReport {
            label: label.to_string(),
            inferences: n,
            latency_ms: Summary::of(&lat),
            throughput_rps: n as f64 / wall_s,
            energy_kwh: carbon::joules_to_kwh(energy_j),
            carbon_per_inf_g: carbon_g / n as f64,
            carbon_total_g: carbon_g,
            carbon_efficiency: carbon::carbon_efficiency(n, carbon_g),
            node_usage: usage.into_iter().collect(),
            exec_ms_mean: records.iter().map(|r| r.exec_ms).sum::<f64>() / n as f64,
        })
    }

    /// Carbon reduction vs a baseline (positive = this run is greener),
    /// the paper's "Reduction vs Mono (%)" column.
    pub fn reduction_vs(&self, baseline: &RunReport) -> f64 {
        1.0 - self.carbon_per_inf_g / baseline.carbon_per_inf_g
    }

    /// Node usage as percentages in registry order (Table V row).
    pub fn usage_pct(&self, node_names: &[&str]) -> Vec<f64> {
        let total: u64 = self.node_usage.iter().map(|(_, c)| c).sum();
        node_names
            .iter()
            .map(|name| {
                let c = self
                    .node_usage
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, c)| *c)
                    .unwrap_or(0);
                if total == 0 {
                    0.0
                } else {
                    100.0 * c as f64 / total as f64
                }
            })
            .collect()
    }
}

/// Average several repetition reports (the paper repeats 3×). An empty
/// slice is an `Err` — there is nothing to average.
pub fn average_reports(reports: &[RunReport]) -> Result<RunReport> {
    ensure!(!reports.is_empty(), "no repetition reports to average");
    let k = reports.len() as f64;
    let mut out = reports[0].clone();
    out.throughput_rps = reports.iter().map(|r| r.throughput_rps).sum::<f64>() / k;
    out.energy_kwh = reports.iter().map(|r| r.energy_kwh).sum::<f64>() / k;
    out.carbon_per_inf_g = reports.iter().map(|r| r.carbon_per_inf_g).sum::<f64>() / k;
    out.carbon_total_g = reports.iter().map(|r| r.carbon_total_g).sum::<f64>() / k;
    out.carbon_efficiency = reports.iter().map(|r| r.carbon_efficiency).sum::<f64>() / k;
    out.exec_ms_mean = reports.iter().map(|r| r.exec_ms_mean).sum::<f64>() / k;
    // latency: pool all means (CI across reps is what the paper reports)
    let means: Vec<f64> = reports.iter().map(|r| r.latency_ms.mean).collect();
    out.latency_ms = Summary::of(&means);
    // node usage: sum counts
    let mut usage: std::collections::BTreeMap<String, u64> = Default::default();
    for r in reports {
        for (n, c) in &r.node_usage {
            *usage.entry(n.clone()).or_default() += c;
        }
    }
    out.node_usage = usage.into_iter().collect();
    out.inferences = reports.iter().map(|r| r.inferences).sum();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;

    fn rec(node: &str, latency_ms: f64, energy_j: f64, carbon_g: f64) -> ExecutionRecord {
        ExecutionRecord {
            node: node.into(),
            exec_ms: latency_ms * 0.9,
            latency_ms,
            energy_j,
            carbon_g,
            output: Tensor::zeros(vec![1]),
        }
    }

    #[test]
    fn report_units_match_paper() {
        // 50 inferences at 254.85 ms, 36 J each at 530 g/kWh.
        let records: Vec<ExecutionRecord> =
            (0..50).map(|_| rec("host", 254.85, 36.11, 0.005316)).collect();
        let r = RunReport::from_records("mono", &records).unwrap();
        assert_eq!(r.inferences, 50);
        assert!((r.latency_ms.mean - 254.85).abs() < 1e-9);
        // throughput = 1/latency for a closed loop: 3.92 req/s
        assert!((r.throughput_rps - 1000.0 / 254.85).abs() < 1e-6);
        assert!((r.carbon_per_inf_g - 0.005316).abs() < 1e-9);
        // efficiency = 1/percarbon ≈ 188 inf/g
        assert!((r.carbon_efficiency - 1.0 / 0.005316).abs() < 1e-6);
    }

    #[test]
    fn reduction_sign_convention() {
        let base = RunReport::from_records("m", &[rec("h", 100.0, 10.0, 0.0053)]).unwrap();
        let green = RunReport::from_records("g", &[rec("g", 107.0, 10.7, 0.0041)]).unwrap();
        let red = green.reduction_vs(&base);
        // (1 - 0.0041/0.0053) = +22.6% — the paper's headline shape.
        assert!(red > 0.2 && red < 0.25, "{red}");
        // a dirtier run has negative reduction
        let perf = RunReport::from_records("p", &[rec("hi", 100.0, 10.0, 0.0067)]).unwrap();
        assert!(perf.reduction_vs(&base) < 0.0);
    }

    #[test]
    fn usage_percentages() {
        let records =
            vec![rec("a", 1.0, 1.0, 0.1), rec("a", 1.0, 1.0, 0.1), rec("b", 1.0, 1.0, 0.1)];
        let r = RunReport::from_records("x", &records).unwrap();
        let pct = r.usage_pct(&["a", "b", "c"]);
        assert!((pct[0] - 66.666).abs() < 0.01);
        assert!((pct[1] - 33.333).abs() < 0.01);
        assert_eq!(pct[2], 0.0);
    }

    #[test]
    fn averaging_reports() {
        let r1 = RunReport::from_records("x", &[rec("a", 100.0, 10.0, 0.004)]).unwrap();
        let r2 = RunReport::from_records("x", &[rec("a", 120.0, 12.0, 0.006)]).unwrap();
        let avg = average_reports(&[r1, r2]).unwrap();
        assert!((avg.latency_ms.mean - 110.0).abs() < 1e-9);
        assert!((avg.carbon_per_inf_g - 0.005).abs() < 1e-12);
        assert_eq!(avg.inferences, 2);
        assert_eq!(avg.node_usage, vec![("a".to_string(), 2)]);
    }

    #[test]
    fn empty_inputs_are_errors_not_panics() {
        let err = RunReport::from_records("empty", &[]).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        assert!(average_reports(&[]).is_err());
    }
}
