//! The Model Partitioner (paper Sec. III-E): layer-wise cost analysis
//! (Eq. 5) and partition-boundary selection, plus the Green Partitioning
//! Strategy that weighs node carbon intensity into the split.

mod cost;
mod green;
mod partition;

pub use cost::{layer_cost, model_cost_profile, CostProfile};
pub use green::{green_shares, GreenPartitioner};
pub use partition::{balanced_partition, partition_by_shares, Partition};
