//! Simulated heterogeneous edge nodes.
//!
//! Stands in for the paper's Docker containers with `--cpus/--memory`
//! quotas on a DGX host (DESIGN.md §3, §7). A node carries:
//!
//! * a **resource spec** (CPU quota, memory, static grid carbon intensity);
//! * a **latency model** `t = t_exec·(1 + α·(1/quota − 1)) + overhead`
//!   mapping real PJRT execution time to container time — the paper's own
//!   numbers imply inference is not quota-saturated (a 0.4-CPU node is only
//!   ~7 % slower end-to-end), hence the quota-sensitivity factor α ≪ 1;
//! * **scheduler-visible state**: load, in-flight count, historical average
//!   execution time (the NSA inputs of Algorithm 1).

mod container;

pub use container::{Container, ExecutionRecord};

use std::sync::{Arc, Mutex};

/// Static description of a simulated edge node (the paper's Table in
/// Sec. IV-A1 plus the scheduler's rated power draw used in Eq. 4).
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    /// Docker `--cpus` equivalent.
    pub cpu_quota: f64,
    /// Docker `--memory` equivalent (MB).
    pub mem_mb: usize,
    /// Static grid carbon intensity scenario (gCO₂/kWh).
    pub intensity: f64,
    /// Node's full-load power draw in watts — the `P_node` of Eq. 4 the
    /// scheduler scores against, and the top of the two-part energy model.
    pub rated_power_w: f64,
    /// Idle-floor power draw in watts (the GreenScale-style base load a
    /// powered-on node burns even with nothing in flight). The simulator's
    /// two-part model charges `idle_w` over the node's entire virtual
    /// uptime and [`NodeSpec::dynamic_power_w`] per busy millisecond, so a
    /// fully-busy node draws exactly `rated_power_w`. Zero (the paper's
    /// Table II calibration, which attributes all power to tasks) disables
    /// the floor.
    pub idle_w: f64,
    /// Prior mean execution time (ms) before any task has run; the
    /// scheduler needs a cold-start estimate for S_P / S_C.
    pub prior_ms: f64,
    /// Fraction of runtime that scales with 1/quota (latency model).
    pub alpha: f64,
    /// Fixed per-task container/network overhead (ms).
    pub overhead_ms: f64,
    /// Simulated-time dilation applied to real executor time. Compensates
    /// the model-size substitution (64²/width-0.5 zoo ≈ 20-30× fewer FLOPs
    /// than the paper's 224² models; DESIGN.md §3/§7) so latencies, scores
    /// and energies land in the paper's regime.
    pub time_scale: f64,
    /// When true the scheduler's T_avg uses measured history (the paper's
    /// literal reading); when false (default) it uses the static
    /// capability prior. The paper measured on a *dedicated* DGX where
    /// history converges to capability; on this shared host measured
    /// history carries machine noise that does not exist in the paper's
    /// testbed and can flip rankings (DESIGN.md §3).
    pub adaptive: bool,
    /// Batch-latency exponent γ: serving a batch of `b` same-model
    /// requests costs `overhead + (t₁ − overhead)·b^γ` where `t₁` is the
    /// single-request latency ([`NodeSpec::batch_latency_ms`]). γ < 1 is
    /// the sub-linear compute amortization real inference servers see
    /// (Ecomap/GreenScale); γ = 1 degenerates to sequential service.
    pub batch_gamma: f64,
    /// Batch-power exponent β: a slot running a batch of `b` draws
    /// `dynamic_power_w·b^β` ([`NodeSpec::batch_dynamic_power_w`]) —
    /// wider batches push the accelerator harder, but sub-linearly.
    /// Keeping β + γ ≤ 1 makes *energy per inference* non-increasing in
    /// batch size (power·latency/b ∝ b^{β+γ−1}), the regime where
    /// batching is a carbon lever at all.
    pub batch_beta: f64,
}

impl NodeSpec {
    /// The paper's three-node setup (Sec. IV-A1), with rated powers and
    /// priors calibrated (DESIGN.md §3) so that the score dynamics
    /// reproduce Table V and the Fig. 3 transition at w_C ≥ 0.5:
    /// range(S_C) ≈ 0.06 and range(S_P) ≈ 0.18 across nodes, matching the
    /// paper's reported ranges (0.054 / 0.166).
    pub fn paper_nodes() -> Vec<NodeSpec> {
        // α = 0.005: the paper's own Table II implies containerized
        // inference is essentially quota-insensitive (a 0.4-CPU node is
        // only ~0.2% slower than CE-Performance on the 1.0-CPU node).
        // time_scale 20.6 vs the host's 20 models the container stack's
        // +3% compute cost; together with the 8 ms per-task overhead the
        // CE modes land ~6-8% above monolithic, the paper's Table II gap.
        // The coordinator additionally normalizes this scale per model
        // against a deploy-time mono/staged calibration measurement
        // (Coordinator::calibration) so host noise cannot flip the shape.
        vec![
            NodeSpec {
                name: "node-high".into(),
                cpu_quota: 1.0,
                mem_mb: 1024,
                intensity: 620.0,
                rated_power_w: 170.0,
                idle_w: 0.0,
                prior_ms: 250.0,
                alpha: 0.005,
                overhead_ms: 8.0,
                time_scale: 20.6,
                adaptive: false,
                batch_gamma: 0.8,
                batch_beta: 0.2,
            },
            NodeSpec {
                name: "node-medium".into(),
                cpu_quota: 0.6,
                mem_mb: 512,
                intensity: 530.0,
                rated_power_w: 102.0,
                idle_w: 0.0,
                prior_ms: 417.0,
                alpha: 0.005,
                overhead_ms: 8.0,
                time_scale: 20.6,
                adaptive: false,
                batch_gamma: 0.8,
                batch_beta: 0.2,
            },
            NodeSpec {
                name: "node-green".into(),
                cpu_quota: 0.4,
                mem_mb: 512,
                intensity: 380.0,
                rated_power_w: 68.0,
                idle_w: 0.0,
                prior_ms: 625.0,
                alpha: 0.005,
                overhead_ms: 8.0,
                time_scale: 20.6,
                adaptive: false,
                batch_gamma: 0.8,
                batch_beta: 0.2,
            },
        ]
    }

    /// Latency model: map real executor time to simulated container time.
    pub fn simulate_latency_ms(&self, exec_ms: f64) -> f64 {
        exec_ms * self.time_scale * (1.0 + self.alpha * (1.0 / self.cpu_quota - 1.0))
            + self.overhead_ms
    }

    /// Above-idle (dynamic) power a running task draws, in watts: the
    /// second part of the two-part energy model. With `idle_w = 0` this is
    /// exactly `rated_power_w`, the pre-idle accounting.
    pub fn dynamic_power_w(&self) -> f64 {
        (self.rated_power_w - self.idle_w).max(0.0)
    }

    /// Batched latency model: one service slot working through a batch of
    /// `b` same-class requests takes `overhead + (t₁ − overhead)·b^γ`
    /// milliseconds, where `t₁ = simulate_latency_ms(exec_ms)`. The
    /// per-batch container/network overhead is paid once — that, plus
    /// γ < 1 compute amortization, is why batching wins on both latency
    /// density and energy. `b = 1` returns `simulate_latency_ms` exactly
    /// (bit-for-bit, no powf on that path).
    pub fn batch_latency_ms(&self, exec_ms: f64, b: usize) -> f64 {
        let single = self.simulate_latency_ms(exec_ms);
        if b <= 1 {
            return single;
        }
        self.overhead_ms + (single - self.overhead_ms) * (b as f64).powf(self.batch_gamma)
    }

    /// Dynamic power of one slot running a batch of `b`:
    /// `dynamic_power_w·b^β`. `b = 1` returns [`NodeSpec::dynamic_power_w`]
    /// exactly. Energy per inference is then
    /// `batch_dynamic_power_w(b)·batch_latency_ms(b)/b ∝ b^{β+γ−1}` for
    /// the compute part — non-increasing whenever β + γ ≤ 1 — while the
    /// once-per-batch overhead term strictly amortizes.
    pub fn batch_dynamic_power_w(&self, b: usize) -> f64 {
        let single = self.dynamic_power_w();
        if b <= 1 {
            return single;
        }
        single * (b as f64).powf(self.batch_beta)
    }
}

/// Mutable scheduler-visible node state.
#[derive(Debug, Clone, Default)]
pub struct NodeState {
    /// Tasks currently executing (S_B's `task_count`; Table V's 100 %
    /// concentration is only consistent with an *in-flight* reading).
    pub inflight: usize,
    /// Completed task count.
    pub completed: u64,
    /// Cumulative mean of *measured* execution latency (ms).
    pub avg_ms: Option<f64>,
    /// Utilization in [0,1]: busy-time EWMA.
    pub load: f64,
    /// Accumulated energy attributed to this node (J).
    pub energy_j: f64,
    /// Accumulated carbon (g).
    pub carbon_g: f64,
    /// Total busy milliseconds.
    pub busy_ms: f64,
    /// Dynamic grid-intensity override (gCO₂/kWh). `None` means the static
    /// spec scenario applies; the fleet simulator sets this from a
    /// time-varying [`crate::carbon::IntensityTrace`] so schedulers score
    /// against the *current* virtual-time intensity.
    pub intensity_override: Option<f64>,
}

impl NodeState {
    /// Queue-delay estimate (ms) from this snapshot: backlog (queued +
    /// executing — the scheduler-visible `inflight`) × the measured mean
    /// service time, falling back to `prior_ms` before any history exists.
    /// The single source of the formula: [`EdgeNode::queue_delay_ms`] and
    /// the scheduler's `NodeView` snapshot both price through it.
    pub fn queue_delay_ms(&self, prior_ms: f64) -> f64 {
        self.inflight as f64 * self.avg_ms.unwrap_or(prior_ms)
    }
}

/// A live node: spec + shared state.
#[derive(Debug)]
pub struct EdgeNode {
    pub spec: NodeSpec,
    state: Mutex<NodeState>,
}

impl EdgeNode {
    pub fn new(spec: NodeSpec) -> Arc<EdgeNode> {
        Arc::new(EdgeNode { spec, state: Mutex::new(NodeState::default()) })
    }

    pub fn state(&self) -> NodeState {
        self.state.lock().unwrap().clone()
    }

    /// Measured mean execution time (ms), falling back to the prior.
    pub fn avg_ms(&self) -> f64 {
        self.state.lock().unwrap().avg_ms.unwrap_or(self.spec.prior_ms)
    }

    /// The scheduler's T_avg (Eq. 4 / Algorithm 1): measured history when
    /// the node is `adaptive`, otherwise the static capability prior.
    pub fn score_ms(&self) -> f64 {
        if self.spec.adaptive {
            self.avg_ms()
        } else {
            self.spec.prior_ms
        }
    }

    /// Queue-delay estimate (ms) of the node's current state
    /// ([`NodeState::queue_delay_ms`] at this node's prior). Callers
    /// spreading work across `k` concurrent service slots divide by `k`
    /// (the simulator's fleet views do, per its capacity table).
    pub fn queue_delay_ms(&self) -> f64 {
        self.state.lock().unwrap().queue_delay_ms(self.spec.prior_ms)
    }

    /// Grid intensity the scheduler should score against right now:
    /// the dynamic override (set by the simulator from a time-varying
    /// trace) when present, otherwise the static spec scenario.
    pub fn intensity(&self) -> f64 {
        self.state.lock().unwrap().intensity_override.unwrap_or(self.spec.intensity)
    }

    /// Install/update the dynamic intensity override (virtual-time grids).
    pub fn set_intensity(&self, grams_per_kwh: f64) {
        self.state.lock().unwrap().intensity_override = Some(grams_per_kwh);
    }

    pub fn begin_task(&self) {
        let mut s = self.state.lock().unwrap();
        s.inflight += 1;
    }

    /// Withdraw a task that was assigned (`begin_task`) but never executed —
    /// the simulator uses this when a node departs with work still queued.
    /// Unlike [`EdgeNode::finish_task`] it leaves the completion count and
    /// latency history untouched.
    pub fn cancel_task(&self) {
        let mut s = self.state.lock().unwrap();
        s.inflight = s.inflight.saturating_sub(1);
    }

    /// Record task completion: latency + attributed energy/carbon.
    pub fn finish_task(&self, latency_ms: f64, energy_j: f64, carbon_g: f64) {
        let mut s = self.state.lock().unwrap();
        s.inflight = s.inflight.saturating_sub(1);
        s.completed += 1;
        let n = s.completed as f64;
        s.avg_ms = Some(match s.avg_ms {
            None => latency_ms,
            Some(m) => m + (latency_ms - m) / n,
        });
        s.busy_ms += latency_ms;
        s.energy_j += energy_j;
        s.carbon_g += carbon_g;
        // Load: EWMA of "busy while another task in flight" — with the
        // paper's sequential batch-1 workload this stays near zero.
        let concurrent = s.inflight as f64;
        s.load = 0.9 * s.load + 0.1 * (concurrent / (concurrent + 1.0));
    }

    /// Memory check for Algorithm 1's `has_sufficient_resources`.
    pub fn fits(&self, mem_demand_mb: usize, cpu_demand: f64) -> bool {
        self.spec.mem_mb >= mem_demand_mb && self.spec.cpu_quota >= cpu_demand
    }
}

/// The node fleet.
#[derive(Debug, Clone)]
pub struct NodeRegistry {
    nodes: Vec<Arc<EdgeNode>>,
}

impl NodeRegistry {
    pub fn new(specs: Vec<NodeSpec>) -> NodeRegistry {
        assert!(!specs.is_empty());
        NodeRegistry { nodes: specs.into_iter().map(EdgeNode::new).collect() }
    }

    pub fn paper_setup() -> NodeRegistry {
        NodeRegistry::new(NodeSpec::paper_nodes())
    }

    pub fn nodes(&self) -> &[Arc<EdgeNode>] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn get(&self, idx: usize) -> &Arc<EdgeNode> {
        &self.nodes[idx]
    }

    pub fn by_name(&self, name: &str) -> Option<&Arc<EdgeNode>> {
        self.nodes.iter().find(|n| n.spec.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_nodes_match_setup() {
        let ns = NodeSpec::paper_nodes();
        assert_eq!(ns.len(), 3);
        assert_eq!(ns[0].name, "node-high");
        assert_eq!(ns[0].cpu_quota, 1.0);
        assert_eq!(ns[0].mem_mb, 1024);
        assert_eq!(ns[0].intensity, 620.0);
        assert_eq!(ns[1].intensity, 530.0);
        assert_eq!(ns[2].intensity, 380.0);
        assert_eq!(ns[2].cpu_quota, 0.4);
        // Table II calibration charges full rated power per task: no floor.
        assert!(ns.iter().all(|n| n.idle_w == 0.0));
        assert_eq!(ns[0].dynamic_power_w(), 170.0);
    }

    #[test]
    fn two_part_power_split() {
        let mut n = NodeSpec::paper_nodes().remove(0);
        n.idle_w = 50.0;
        // idle + dynamic reconstructs the full-load draw…
        assert_eq!(n.dynamic_power_w(), 120.0);
        assert_eq!(n.idle_w + n.dynamic_power_w(), n.rated_power_w);
        // …and an idle floor above rated never goes negative.
        n.idle_w = 500.0;
        assert_eq!(n.dynamic_power_w(), 0.0);
    }

    #[test]
    fn latency_model_mildly_quota_sensitive() {
        let ns = NodeSpec::paper_nodes();
        let high = ns[0].simulate_latency_ms(10.0);
        let green = ns[2].simulate_latency_ms(10.0);
        // time_scale 20.6 + overhead 8: 10 ms exec -> 214 ms on node-high.
        assert!((high - (10.0 * 20.6 + 8.0)).abs() < 1e-9);
        // α=0.005, quota 0.4 -> factor 1.0075: near-identical latency,
        // matching the paper's ~0.2% green-vs-performance gap.
        assert!((green - (10.0 * 20.6 * 1.0075 + 8.0)).abs() < 1e-9);
        assert!(green / high < 1.02);
    }

    #[test]
    fn batch_curves_recover_single_task_exactly() {
        let n = NodeSpec::paper_nodes().remove(0);
        // b = 1 is the pre-batching model, bit-for-bit (early return, no
        // powf): the shim-equivalence guarantee starts here.
        assert_eq!(n.batch_latency_ms(10.0, 1), n.simulate_latency_ms(10.0));
        assert_eq!(n.batch_dynamic_power_w(1), n.dynamic_power_w());
        assert_eq!(n.batch_latency_ms(10.0, 0), n.simulate_latency_ms(10.0));
    }

    #[test]
    fn batch_curves_sublinear_and_energy_amortizing() {
        let n = NodeSpec::paper_nodes().remove(0); // γ=0.8, β=0.2
        let t1 = n.batch_latency_ms(10.0, 1);
        let t8 = n.batch_latency_ms(10.0, 8);
        // 8 requests in one batch finish far sooner than 8 sequential…
        assert!(t8 < 8.0 * t1, "{t8} vs {}", 8.0 * t1);
        // …and match the closed form: overhead + (t1-overhead)·8^0.8.
        assert!((t8 - (8.0 + (t1 - 8.0) * 8f64.powf(0.8))).abs() < 1e-9);
        // Power grows sub-linearly with fill…
        let p8 = n.batch_dynamic_power_w(8);
        assert!(p8 > n.dynamic_power_w() && p8 < 8.0 * n.dynamic_power_w());
        // …so energy per inference is strictly decreasing in batch size
        // (β + γ = 1 keeps the compute part flat; the per-batch overhead
        // amortizes on top).
        let e = |b: usize| n.batch_dynamic_power_w(b) * n.batch_latency_ms(10.0, b) / b as f64;
        assert!(e(2) < e(1) && e(4) < e(2) && e(8) < e(4), "{} {} {} {}", e(1), e(2), e(4), e(8));
    }

    #[test]
    fn avg_ms_prior_then_cumulative_mean() {
        let n = EdgeNode::new(NodeSpec::paper_nodes().remove(0));
        assert_eq!(n.avg_ms(), 250.0); // prior
        n.begin_task();
        n.finish_task(100.0, 1.0, 0.1);
        assert_eq!(n.avg_ms(), 100.0);
        n.begin_task();
        n.finish_task(200.0, 1.0, 0.1);
        assert_eq!(n.avg_ms(), 150.0);
        let s = n.state();
        assert_eq!(s.completed, 2);
        assert_eq!(s.inflight, 0);
        assert!((s.energy_j - 2.0).abs() < 1e-12);
        assert!((s.carbon_g - 0.2).abs() < 1e-12);
    }

    #[test]
    fn inflight_tracking() {
        let n = EdgeNode::new(NodeSpec::paper_nodes().remove(2));
        n.begin_task();
        n.begin_task();
        assert_eq!(n.state().inflight, 2);
        n.finish_task(10.0, 0.0, 0.0);
        assert_eq!(n.state().inflight, 1);
    }

    #[test]
    fn cancel_task_skips_history() {
        let n = EdgeNode::new(NodeSpec::paper_nodes().remove(0));
        n.begin_task();
        n.cancel_task();
        let s = n.state();
        assert_eq!(s.inflight, 0);
        assert_eq!(s.completed, 0);
        assert_eq!(s.avg_ms, None);
        n.cancel_task(); // saturates, never underflows
        assert_eq!(n.state().inflight, 0);
    }

    #[test]
    fn queue_delay_tracks_backlog_and_history() {
        let n = EdgeNode::new(NodeSpec::paper_nodes().remove(0)); // prior 250 ms
        assert_eq!(n.queue_delay_ms(), 0.0);
        n.begin_task();
        n.begin_task();
        assert!((n.queue_delay_ms() - 500.0).abs() < 1e-12); // 2 × prior
        // Measured history replaces the prior in the estimate.
        n.finish_task(100.0, 0.0, 0.0);
        assert!((n.queue_delay_ms() - 100.0).abs() < 1e-12); // 1 × measured
        n.finish_task(300.0, 0.0, 0.0);
        assert_eq!(n.queue_delay_ms(), 0.0); // backlog drained
    }

    #[test]
    fn dynamic_intensity_override() {
        let n = EdgeNode::new(NodeSpec::paper_nodes().remove(0));
        assert_eq!(n.intensity(), 620.0); // static spec scenario
        n.set_intensity(95.0);
        assert_eq!(n.intensity(), 95.0);
        n.set_intensity(700.0);
        assert_eq!(n.intensity(), 700.0);
    }

    #[test]
    fn fits_resources() {
        let n = EdgeNode::new(NodeSpec::paper_nodes().remove(2)); // 0.4 cpu, 512MB
        assert!(n.fits(256, 0.2));
        assert!(!n.fits(1024, 0.2));
        assert!(!n.fits(256, 0.5));
    }

    #[test]
    fn registry_lookup() {
        let r = NodeRegistry::paper_setup();
        assert_eq!(r.len(), 3);
        assert!(r.by_name("node-green").is_some());
        assert!(r.by_name("nope").is_none());
        assert_eq!(r.get(1).spec.name, "node-medium");
    }
}
