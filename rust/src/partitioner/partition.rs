//! Contiguous stage partitioning: the classic linear-partition problem
//! (minimize the maximum group cost), solved exactly by DP, plus
//! share-driven splitting for heterogeneous node speeds.

/// A partition of `n` stages into contiguous groups.
/// `bounds[k]` is the first stage of group k+1; groups are
/// `[0, bounds[0]) [bounds[0], bounds[1]) ... [last, n)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub n_stages: usize,
    pub bounds: Vec<usize>,
}

impl Partition {
    /// Group ranges as `(start, end)` pairs (end exclusive). Empty groups
    /// are allowed (a node that receives no stage).
    pub fn ranges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.bounds.len() + 1);
        let mut start = 0;
        for &b in &self.bounds {
            out.push((start, b));
            start = b;
        }
        out.push((start, self.n_stages));
        out
    }

    pub fn n_groups(&self) -> usize {
        self.bounds.len() + 1
    }

    /// Validity: bounds are sorted and within range, groups cover all
    /// stages exactly once (by construction of `ranges`).
    pub fn is_valid(&self) -> bool {
        let mut prev = 0;
        for &b in &self.bounds {
            if b < prev || b > self.n_stages {
                return false;
            }
            prev = b;
        }
        true
    }

    /// Max group cost under `costs`.
    pub fn bottleneck(&self, costs: &[u64]) -> u64 {
        assert_eq!(costs.len(), self.n_stages);
        self.ranges().iter().map(|&(s, e)| costs[s..e].iter().sum::<u64>()).max().unwrap_or(0)
    }
}

/// Exact DP for the linear partition problem: split `costs` into at most
/// `k` contiguous groups minimizing the maximum group sum.
pub fn balanced_partition(costs: &[u64], k: usize) -> Partition {
    let n = costs.len();
    assert!(k > 0, "need at least one group");
    if n == 0 {
        return Partition { n_stages: 0, bounds: vec![0; k - 1] };
    }
    let k = k.min(n.max(1));
    // prefix sums
    let mut pre = vec![0u64; n + 1];
    for i in 0..n {
        pre[i + 1] = pre[i] + costs[i];
    }
    let seg = |a: usize, b: usize| pre[b] - pre[a]; // cost of [a, b)

    // dp[j][i] = min over first i stages in j groups of max group cost
    let inf = u64::MAX;
    let mut dp = vec![vec![inf; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0;
    for j in 1..=k {
        for i in 1..=n {
            for m in (j - 1)..i {
                if dp[j - 1][m] == inf {
                    continue;
                }
                let cand = dp[j - 1][m].max(seg(m, i));
                if cand < dp[j][i] {
                    dp[j][i] = cand;
                    cut[j][i] = m;
                }
            }
        }
    }
    // backtrack
    let mut bounds = Vec::with_capacity(k - 1);
    let mut i = n;
    for j in (2..=k).rev() {
        let m = cut[j][i];
        bounds.push(m);
        i = m;
    }
    bounds.reverse();
    Partition { n_stages: n, bounds }
}

/// Split stages so group cost tracks the given (positive) shares — used by
/// the Green Partitioning Strategy where node shares mix speed and carbon.
/// Greedy prefix assignment against cumulative share targets.
pub fn partition_by_shares(costs: &[u64], shares: &[f64]) -> Partition {
    let n = costs.len();
    let k = shares.len();
    assert!(k > 0);
    assert!(shares.iter().all(|&s| s >= 0.0));
    let total_share: f64 = shares.iter().sum();
    assert!(total_share > 0.0, "all-zero shares");
    let total_cost: u64 = costs.iter().sum();
    let mut bounds = Vec::with_capacity(k - 1);
    let mut acc_target = 0.0;
    let mut idx = 0usize;
    let mut acc_cost = 0u64;
    for share in shares.iter().take(k - 1) {
        acc_target += share / total_share * total_cost as f64;
        // advance idx while adding the next stage keeps us closer to target
        while idx < n {
            let next = acc_cost + costs[idx];
            let d_now = (acc_cost as f64 - acc_target).abs();
            let d_next = (next as f64 - acc_target).abs();
            if d_next <= d_now {
                acc_cost = next;
                idx += 1;
            } else {
                break;
            }
        }
        bounds.push(idx);
    }
    Partition { n_stages: n, bounds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn dp_optimal_simple() {
        // [1,2,3,4,5] into 2 -> [1,2,3] | [4,5]: bottleneck 9 (optimal)
        let p = balanced_partition(&[1, 2, 3, 4, 5], 2);
        assert_eq!(p.bottleneck(&[1, 2, 3, 4, 5]), 9);
        assert_eq!(p.ranges(), vec![(0, 3), (3, 5)]);
    }

    #[test]
    fn dp_handles_spikes() {
        // a huge middle stage must sit alone
        let costs = [1, 100, 1, 1];
        let p = balanced_partition(&costs, 3);
        assert_eq!(p.bottleneck(&costs), 100);
        assert!(p.is_valid());
    }

    #[test]
    fn k_one_is_whole() {
        let p = balanced_partition(&[5, 5, 5], 1);
        assert_eq!(p.ranges(), vec![(0, 3)]);
    }

    #[test]
    fn k_ge_n_single_stages() {
        let costs = [3, 7, 2];
        let p = balanced_partition(&costs, 5); // clamped to 3 groups
        assert_eq!(p.bottleneck(&costs), 7);
    }

    #[test]
    fn shares_proportional() {
        // equal shares ~ balanced
        let costs = [10, 10, 10, 10];
        let p = partition_by_shares(&costs, &[0.5, 0.5]);
        assert_eq!(p.ranges(), vec![(0, 2), (2, 4)]);
        // skewed shares: first node takes more
        let p = partition_by_shares(&costs, &[0.75, 0.25]);
        assert_eq!(p.ranges(), vec![(0, 3), (3, 4)]);
    }

    #[test]
    fn shares_zero_group_ok() {
        let costs = [10, 10];
        let p = partition_by_shares(&costs, &[0.0, 1.0]);
        assert_eq!(p.ranges(), vec![(0, 0), (0, 2)]);
        assert!(p.is_valid());
    }

    #[test]
    fn prop_dp_no_worse_than_even_split() {
        check(
            "DP bottleneck <= naive even split bottleneck",
            200,
            |rng| {
                let n = 1 + rng.below(12);
                let k = 1 + rng.below(5);
                let costs: Vec<u64> = (0..n).map(|_| rng.below(1000) as u64 + 1).collect();
                (costs, k)
            },
            |(costs, k)| {
                let p = balanced_partition(costs, *k);
                if !p.is_valid() {
                    return Err("invalid partition".into());
                }
                // coverage: ranges concatenate to [0, n)
                let r = p.ranges();
                let mut pos = 0;
                for (s, e) in &r {
                    if *s != pos || e < s {
                        return Err(format!("non-contiguous ranges {r:?}"));
                    }
                    pos = *e;
                }
                if pos != costs.len() {
                    return Err("ranges do not cover all stages".into());
                }
                // optimality vs even split
                let k_eff = (*k).min(costs.len());
                let chunk = costs.len().div_ceil(k_eff);
                let naive = Partition {
                    n_stages: costs.len(),
                    bounds: (1..k_eff).map(|j| (j * chunk).min(costs.len())).collect(),
                };
                if p.bottleneck(costs) > naive.bottleneck(costs) {
                    return Err(format!(
                        "dp {} worse than naive {}",
                        p.bottleneck(costs),
                        naive.bottleneck(costs)
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_shares_cover_exactly() {
        check(
            "share partition covers stages exactly once",
            200,
            |rng| {
                let n = 1 + rng.below(10);
                let k = 1 + rng.below(4);
                let costs: Vec<u64> = (0..n).map(|_| rng.below(500) as u64 + 1).collect();
                let shares: Vec<f64> = (0..k).map(|_| rng.range(0.01, 1.0)).collect();
                (costs, shares)
            },
            |(costs, shares)| {
                let p = partition_by_shares(costs, shares);
                let mut pos = 0;
                for (s, e) in p.ranges() {
                    if s != pos {
                        return Err("gap/overlap".into());
                    }
                    pos = e;
                }
                if pos != costs.len() {
                    return Err("missing tail".into());
                }
                if p.n_groups() != shares.len() {
                    return Err("wrong group count".into());
                }
                Ok(())
            },
        );
    }
}
