//! The Carbon-Aware Scheduler — the paper's primary contribution
//! (Sec. III-C/D): weighted node scoring (Eq. 3), the carbon-efficiency
//! score S_C (Eq. 4), the three operational modes (Table I), the node
//! selection algorithm (Algorithm 1), and the non-carbon-aware baselines
//! (AMP4EC NSA, round-robin, random, least-loaded).

mod baselines;
mod modes;
mod normalized;
mod nsa;
mod score;

pub use baselines::{Amp4ecScheduler, LeastLoadedScheduler, RandomScheduler, RoundRobinScheduler};
pub use modes::{Mode, Weights};
pub use normalized::{ConstrainedGreenScheduler, NormalizedScheduler};
pub use nsa::{CarbonAwareScheduler, SelectionTrace, LOAD_CUTOFF};
pub use score::{carbon_score, score_breakdown, ScoreBreakdown, TaskDemand};

use std::sync::Arc;

use crate::node::EdgeNode;

/// Node-selection interface shared by the carbon-aware scheduler and all
/// baselines. Returns the index of the chosen node (None if no feasible
/// node exists, Algorithm 1 line 18 with `n* = null`).
pub trait Scheduler: Send {
    fn select(&mut self, task: &TaskDemand, nodes: &[Arc<EdgeNode>]) -> Option<usize>;

    /// Human-readable name for reports.
    fn name(&self) -> &str;
}
