//! Cross-node pipeline inference with the Green Partitioning Strategy —
//! the paper's stated future-work extension ("cross-node distributed
//! inference"), implemented end-to-end: stages are split over the fleet by
//! carbon-weighted shares and one inference flows through every node.
//!
//! ```sh
//! cargo run --release --example green_pipeline -- [--requests 10]
//! ```

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::metrics::RunReport;
use carbonedge::partitioner::{green_shares, model_cost_profile};
use carbonedge::util::cli::Args;
use carbonedge::util::table::{f2, f4, Table};
use carbonedge::workload::RequestStream;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let requests = args.parse_or("requests", 10usize)?;
    let model_name = args.str_or("model", "mobilenet_v2");
    let net = args.parse_or("net-ms-per-mb", 4.0f64)?;

    let coord = Coordinator::new(Config::default())?;
    let model = coord.load_model(&model_name)?;
    let profile = model_cost_profile(&model.entry);
    println!(
        "pipeline over stages with Eq.5 costs {:?} (boundaries {:?} elems)",
        profile.stage_costs, profile.boundary_elems
    );

    let registry = coord.fresh_registry();
    let mut table = Table::new(
        "Green pipeline: carbon weight vs latency/carbon (cross-node execution)",
        &["carbon_weight", "shares (high/med/green)", "latency (ms)", "gCO2/inf"],
    );
    let stream = RequestStream {
        image_size: coord.manifest.image_size,
        arrivals: carbonedge::workload::Arrivals::ClosedLoop { count: requests },
        seed: 0,
    };
    let inputs = stream.inputs();
    for w in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let shares = green_shares(registry.nodes(), w);
        let recs = coord.run_pipeline(&model, w, &inputs, net)?;
        let r = RunReport::from_records(&format!("pipeline-{w}"), &recs);
        table.row(vec![
            format!("{w:.2}"),
            shares.iter().map(|s| format!("{s:.2}")).collect::<Vec<_>>().join("/"),
            f2(r.latency_ms.mean),
            f4(r.carbon_per_inf_g),
        ]);
    }
    println!("{}", table.render());
    println!("note: pipeline route example -> {}", {
        let recs = coord.run_pipeline(&model, 0.5, &inputs[..1], net)?;
        recs[0].node.clone()
    });
    Ok(())
}
