//! Minimal JSON parser/serializer substrate.
//!
//! `serde`/`serde_json` are not in the offline crate set (DESIGN.md §7), so
//! the manifest/config plumbing uses this hand-rolled recursive-descent
//! implementation. It supports the full JSON grammar (RFC 8259) minus
//! `\uXXXX` surrogate-pair edge cases beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;
use std::io;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` chained through a path.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
    /// Required-field helpers used by manifest/config loaders.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field {key:?}"))
    }
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing number field {key:?}"))
    }
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing non-negative integer field {key:?}"))
    }
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing array field {key:?}"))
    }
    pub fn req_obj(&self, key: &str) -> anyhow::Result<&BTreeMap<String, Json>> {
        self.get(key)
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("missing object field {key:?}"))
    }
    /// Array of numbers -> `Vec<usize>` (shape fields).
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte {:?}", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.b.len());
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("non-utf8 bytes in number"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// ---------------------------------------------------------------------------
// Streaming writer
// ---------------------------------------------------------------------------

/// Streaming JSON writer: serialize-as-you-go over any [`io::Write`], no
/// intermediate [`Json`] tree. At 10M-request report sizes (per-node SoC
/// timelines, per-event firehose lines) materializing the tree is the memory
/// ceiling; this writer emits bytes as the caller walks the document.
///
/// Output is byte-identical to [`Json`]'s `Display` for the same value
/// sequence — same integral-number formatting (`n.fract() == 0` and
/// `|n| < 1e15` prints as an integer) and the same string-escape set — so
/// everything it produces parses back through [`Json::parse`].
///
/// The caller is responsible for well-formedness ordering (a `key` before
/// every value inside an object); nesting commas are handled internally.
/// Misuse (a value where a key is required) is caught by `debug_assert!`.
pub struct JsonWriter<W: io::Write> {
    w: W,
    /// (is_object, wrote_first_element) per open container.
    stack: Vec<(bool, bool)>,
    /// In an object and a key has been written, value pending.
    key_pending: bool,
}

impl<W: io::Write> JsonWriter<W> {
    pub fn new(w: W) -> JsonWriter<W> {
        JsonWriter { w, stack: Vec::new(), key_pending: false }
    }

    /// Comma bookkeeping before a value (or container open) in the current
    /// context. Inside an object the separator was emitted by `key`.
    fn sep(&mut self) -> io::Result<()> {
        if self.key_pending {
            self.key_pending = false;
            return Ok(());
        }
        if let Some((is_obj, first)) = self.stack.last_mut() {
            debug_assert!(!*is_obj, "value without a key inside an object");
            if *first {
                *first = false;
            } else {
                self.w.write_all(b",")?;
            }
        }
        Ok(())
    }

    /// Object member key (with `:`); must be followed by exactly one value.
    pub fn key(&mut self, k: &str) -> io::Result<()> {
        debug_assert!(!self.key_pending, "two keys in a row");
        match self.stack.last_mut() {
            Some((true, first)) => {
                if *first {
                    *first = false;
                } else {
                    self.w.write_all(b",")?;
                }
            }
            _ => debug_assert!(false, "key outside an object"),
        }
        write_escaped_io(&mut self.w, k)?;
        self.w.write_all(b":")?;
        self.key_pending = true;
        Ok(())
    }

    pub fn begin_obj(&mut self) -> io::Result<()> {
        self.sep()?;
        self.stack.push((true, true));
        self.w.write_all(b"{")
    }

    pub fn end_obj(&mut self) -> io::Result<()> {
        debug_assert!(matches!(self.stack.last(), Some((true, _))), "end_obj outside object");
        self.stack.pop();
        self.w.write_all(b"}")
    }

    pub fn begin_arr(&mut self) -> io::Result<()> {
        self.sep()?;
        self.stack.push((false, true));
        self.w.write_all(b"[")
    }

    pub fn end_arr(&mut self) -> io::Result<()> {
        debug_assert!(matches!(self.stack.last(), Some((false, _))), "end_arr outside array");
        self.stack.pop();
        self.w.write_all(b"]")
    }

    pub fn null(&mut self) -> io::Result<()> {
        self.sep()?;
        self.w.write_all(b"null")
    }

    pub fn boolean(&mut self, b: bool) -> io::Result<()> {
        self.sep()?;
        self.w.write_all(if b { b"true" } else { b"false" })
    }

    pub fn num(&mut self, n: f64) -> io::Result<()> {
        self.sep()?;
        if n.fract() == 0.0 && n.abs() < 1e15 {
            write!(self.w, "{}", n as i64)
        } else {
            write!(self.w, "{n}")
        }
    }

    /// Finite-guarded number: NaN/±inf become `null` (the export convention;
    /// bare `NaN` is not valid JSON).
    pub fn fnum(&mut self, n: f64) -> io::Result<()> {
        if n.is_finite() {
            self.num(n)
        } else {
            self.null()
        }
    }

    pub fn string(&mut self, s: &str) -> io::Result<()> {
        self.sep()?;
        write_escaped_io(&mut self.w, s)
    }

    // Compact `key + value` helpers for flat report/event objects.
    pub fn field_num(&mut self, k: &str, n: f64) -> io::Result<()> {
        self.key(k)?;
        self.num(n)
    }
    pub fn field_fnum(&mut self, k: &str, n: f64) -> io::Result<()> {
        self.key(k)?;
        self.fnum(n)
    }
    pub fn field_str(&mut self, k: &str, s: &str) -> io::Result<()> {
        self.key(k)?;
        self.string(s)
    }
    pub fn field_bool(&mut self, k: &str, b: bool) -> io::Result<()> {
        self.key(k)?;
        self.boolean(b)
    }
    pub fn field_null(&mut self, k: &str) -> io::Result<()> {
        self.key(k)?;
        self.null()
    }

    /// Hand back the underlying writer (all containers must be closed).
    pub fn into_inner(self) -> W {
        debug_assert!(self.stack.is_empty(), "unclosed container");
        self.w
    }
}

/// `write_escaped` for byte sinks: bulk-writes unescaped runs, escapes the
/// same set as the `Display` path (multi-byte UTF-8 passes through raw).
fn write_escaped_io<W: io::Write>(w: &mut W, s: &str) -> io::Result<()> {
    w.write_all(b"\"")?;
    let bytes = s.as_bytes();
    let mut start = 0;
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'"' || b == b'\\' || b < 0x20 {
            w.write_all(&bytes[start..i])?;
            match b {
                b'"' => w.write_all(b"\\\"")?,
                b'\\' => w.write_all(b"\\\\")?,
                b'\n' => w.write_all(b"\\n")?,
                b'\r' => w.write_all(b"\\r")?,
                b'\t' => w.write_all(b"\\t")?,
                _ => write!(w, "\\u{b:04x}")?,
            }
            start = i + 1;
        }
    }
    w.write_all(&bytes[start..])?;
    w.write_all(b"\"")
}

/// Convenience constructors for building JSON output (reports).
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap()[0].as_i64(), Some(1));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A é");
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"n":null,"o":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
        assert_eq!(out, src); // BTreeMap keeps keys sorted; src is sorted
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_arr("a").unwrap().len(), 2);
        assert!(v.req_str("missing").is_err());
        assert_eq!(v.get("a").unwrap().usize_vec().unwrap(), vec![1, 2]);
    }

    #[test]
    fn negative_not_usize() {
        let v = Json::parse("-1").unwrap();
        assert_eq!(v.as_usize(), None);
        assert_eq!(v.as_i64(), Some(-1));
    }

    #[test]
    fn builders_display() {
        let v = obj(vec![("x", num(1.0)), ("y", arr(vec![s("a")]))]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":["a"]}"#);
    }

    #[test]
    fn writer_streams_nested_document() {
        let mut j = JsonWriter::new(Vec::new());
        j.begin_obj().unwrap();
        j.field_str("name", "a\"b\nc").unwrap();
        j.key("vals").unwrap();
        j.begin_arr().unwrap();
        j.num(1.0).unwrap();
        j.num(2.5).unwrap();
        j.fnum(f64::NAN).unwrap();
        j.end_arr().unwrap();
        j.key("inner").unwrap();
        j.begin_obj().unwrap();
        j.field_bool("up", true).unwrap();
        j.field_null("gone").unwrap();
        j.field_fnum("big", 3e18).unwrap();
        j.end_obj().unwrap();
        j.end_obj().unwrap();
        let text = String::from_utf8(j.into_inner()).unwrap();
        assert_eq!(
            text,
            r#"{"name":"a\"b\nc","vals":[1,2.5,null],"inner":{"up":true,"gone":null,"big":3000000000000000000}}"#
        );
        // And it parses back to the tree the builders would have produced.
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.path(&["inner", "up"]), Some(&Json::Bool(true)));
        assert_eq!(v.path(&["inner", "gone"]), Some(&Json::Null));
        assert_eq!(v.get("vals").unwrap().as_arr().unwrap()[2], Json::Null);
    }

    #[test]
    fn writer_matches_tree_display() {
        // The streaming writer and the `Json` Display path must agree on
        // number formatting and escaping, since parse-back tests rely on it.
        let tree = obj(vec![
            ("f", num(0.25)),
            ("i", num(12.0)),
            ("s", s("tab\there")),
            ("xs", arr(vec![num(-7.0), Json::Bool(false), Json::Null])),
        ]);
        let mut j = JsonWriter::new(Vec::new());
        j.begin_obj().unwrap();
        j.field_num("f", 0.25).unwrap();
        j.field_num("i", 12.0).unwrap();
        j.field_str("s", "tab\there").unwrap();
        j.key("xs").unwrap();
        j.begin_arr().unwrap();
        j.num(-7.0).unwrap();
        j.boolean(false).unwrap();
        j.null().unwrap();
        j.end_arr().unwrap();
        j.end_obj().unwrap();
        let streamed = String::from_utf8(j.into_inner()).unwrap();
        assert_eq!(streamed, tree.to_string());
    }

    #[test]
    fn writer_output_reparses_to_the_written_tree() {
        use crate::util::proptest::check;
        use crate::util::rng::Rng;

        // A write plan: what gets pushed through the JsonWriter, paired
        // with the tree Json::parse must hand back. `NanAsNull` exercises
        // the fnum finite guard (NaN is written, null must come back).
        #[derive(Debug, Clone)]
        enum V {
            Null,
            NanAsNull,
            Bool(bool),
            Num(f64),
            Str(String),
            Arr(Vec<V>),
            Obj(Vec<(String, V)>),
        }

        impl V {
            fn expected(&self) -> Json {
                match self {
                    V::Null | V::NanAsNull => Json::Null,
                    V::Bool(b) => Json::Bool(*b),
                    V::Num(n) => Json::Num(*n),
                    V::Str(s) => Json::Str(s.clone()),
                    V::Arr(xs) => Json::Arr(xs.iter().map(V::expected).collect()),
                    V::Obj(fs) => {
                        Json::Obj(fs.iter().map(|(k, v)| (k.clone(), v.expected())).collect())
                    }
                }
            }

            fn write<W: std::io::Write>(&self, j: &mut JsonWriter<W>) -> std::io::Result<()> {
                match self {
                    V::Null => j.null(),
                    V::NanAsNull => j.fnum(f64::NAN),
                    V::Bool(b) => j.boolean(*b),
                    V::Num(n) => j.num(*n),
                    V::Str(s) => j.string(s),
                    V::Arr(xs) => {
                        j.begin_arr()?;
                        for x in xs {
                            x.write(j)?;
                        }
                        j.end_arr()
                    }
                    V::Obj(fs) => {
                        j.begin_obj()?;
                        for (k, v) in fs {
                            j.key(k)?;
                            v.write(j)?;
                        }
                        j.end_obj()
                    }
                }
            }
        }

        // Escape-heavy pool: quotes, backslash, control chars, multi-byte
        // UTF-8 — everything write_escaped_io treats specially.
        fn gen_str(r: &mut Rng) -> String {
            const POOL: &[char] =
                &['a', 'b', '_', '"', '\\', '\n', '\r', '\t', '\u{1}', ' ', 'é', '🌍', '0'];
            (0..r.below(8)).map(|_| *r.choose(POOL)).collect()
        }

        fn gen_num(r: &mut Rng) -> f64 {
            match r.below(5) {
                0 => r.below(1000) as f64 - 500.0,  // the i64 fast path
                1 => r.range(-1.0, 1.0),            // fractional Display path
                2 => 3.0e18 * r.range(0.5, 2.0),    // beyond the |n| < 1e15 shortcut
                3 => r.range(1.0, 9.0) * 1e-300,    // extreme magnitude
                _ => r.normal() * 1e6,
            }
        }

        fn gen_v(r: &mut Rng, depth: usize) -> V {
            // Containers only while depth remains; scalars close the tree.
            let top = if depth == 0 { 5 } else { 7 };
            match r.below(top) {
                0 => V::Null,
                1 => V::NanAsNull,
                2 => V::Bool(r.below(2) == 0),
                3 => V::Num(gen_num(r)),
                4 => V::Str(gen_str(r)),
                5 => V::Arr((0..r.below(4)).map(|_| gen_v(r, depth - 1)).collect()),
                _ => V::Obj(
                    (0..r.below(4)).map(|_| (gen_str(r), gen_v(r, depth - 1))).collect(),
                ),
            }
        }

        check(
            "json_writer_roundtrip",
            200,
            |r| {
                // Root is always a container so empty objects and arrays
                // come up often.
                if r.below(2) == 0 {
                    V::Arr((0..r.below(5)).map(|_| gen_v(r, 3)).collect())
                } else {
                    V::Obj((0..r.below(5)).map(|_| (gen_str(r), gen_v(r, 3))).collect())
                }
            },
            |plan| {
                let mut j = JsonWriter::new(Vec::new());
                plan.write(&mut j).map_err(|e| format!("write failed: {e}"))?;
                let text = String::from_utf8(j.into_inner())
                    .map_err(|e| format!("non-utf8 writer output: {e}"))?;
                let parsed = Json::parse(&text)
                    .map_err(|e| format!("reparse of {text:?} failed: {e}"))?;
                let want = plan.expected();
                if parsed == want {
                    Ok(())
                } else {
                    Err(format!("parsed {parsed:?} != expected {want:?} (text {text:?})"))
                }
            },
        );
    }

    #[test]
    fn manifest_like_document() {
        let doc = r#"{"models":{"m":{"stages":[{"in_shape":[64,64,3],"cost":123}]}}}"#;
        let v = Json::parse(doc).unwrap();
        let stage = &v.path(&["models", "m", "stages"]).unwrap().as_arr().unwrap()[0];
        assert_eq!(stage.get("in_shape").unwrap().usize_vec().unwrap(), vec![64, 64, 3]);
        assert_eq!(stage.req_usize("cost").unwrap(), 123);
    }
}
