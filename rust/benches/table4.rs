//! Bench: regenerate paper Table IV (multi-model carbon footprint:
//! MobileNetV2 / MobileNetV4 / EfficientNet-B0, Monolithic vs CE-Green).

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let iters: usize =
        std::env::var("CE_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(15);
    let reps: usize = std::env::var("CE_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let coord = Coordinator::new(cfg)?;
    let models: Vec<String> = coord.manifest.models.keys().cloned().collect();
    let refs: Vec<&str> = models.iter().map(String::as_str).collect();
    let rows = exp::table4(&coord, &refs, iters, reps)?;
    println!("{}", exp::table4_render(&rows));
    println!("paper Table IV shape: consistent reduction (14.8%-32.2%) across architectures");
    Ok(())
}
