//! Bench: regenerate paper Table II (carbon footprint comparison,
//! MobileNetV2, 50 inferences x 3 repetitions across 5 configurations).

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let iters: usize =
        std::env::var("CE_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(15);
    let reps: usize = std::env::var("CE_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let coord = Coordinator::new(cfg)?;
    let t0 = std::time::Instant::now();
    let t2 = exp::table2(&coord, "mobilenet_v2", iters, reps)?;
    println!("{}", t2.render());
    println!(
        "paper Table II shape: Green +22.9% / Performance -26.7%; measured Green {:+.1}%",
        t2.green_reduction() * 100.0
    );
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
