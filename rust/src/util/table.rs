//! ASCII table rendering for the experiment harness — every paper table is
//! reprinted in this format by `carbonedge reproduce` and `cargo bench`.

/// A simple right-padded ASCII table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = |w: &Vec<usize>| -> String {
            let mut s = String::from("+");
            for w in w {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep(&widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out
    }
}

/// Format helpers shared by the experiment printers.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}
pub fn f4(v: f64) -> String {
    format!("{v:.4}")
}
pub fn f5(v: f64) -> String {
    format!("{v:.5}")
}
pub fn pct(v: f64) -> String {
    format!("{:+.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        let out = t.render();
        assert!(out.contains("| a  | bbbb |"));
        assert!(out.contains("| xx | y    |"));
        assert!(out.starts_with("T\n+----+------+"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        Table::new("", &["a"]).row(vec!["x".into(), "y".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.005), "1.00");
        assert_eq!(f4(0.00414), "0.0041");
        assert_eq!(pct(0.229), "+22.9%");
        assert_eq!(pct(-0.267), "-26.7%");
        assert_eq!(f5(0.001234), "0.00123");
    }
}
