//! The scenario library: named, parameterized fleet/workload setups for
//! `carbonedge sim --scenario <name>`. Every scenario is deterministic in
//! `(nodes, requests, seed)`.
//!
//! * **`paper-3-node`** — the paper's Sec. IV-A1 testbed (node-high /
//!   node-medium / node-green, static grids) replayed open-loop at 6 req/s,
//!   enough pressure that modes genuinely contend for nodes instead of the
//!   closed-loop 100%-concentration regime of Table V.
//! * **`fleet-100`** — an N-node (default 100) heterogeneous fleet
//!   synthesized from the `REGIONS` table ([`crate::sim::fleet`]), Poisson
//!   arrivals at 60% of aggregate service capacity: the scale regime where
//!   carbon-aware scoring has real routing freedom.
//! * **`diurnal-solar`** — N nodes (default 12) whose grids follow
//!   [`IntensityTrace::Diurnal`] (amplitude 40% of the regional mean) over a
//!   six-hour virtual horizon; exercises time-varying intensity on both the
//!   scheduling and the accounting path.
//! * **`bursty`** — the paper's 3 nodes under a two-state MMPP arrival
//!   process (quiet 25% / burst 150% of fleet capacity, 20 s mean dwell):
//!   queueing behaviour under load spikes.
//! * **`churn`** — an N-node fleet (default 10) where one node is down from
//!   t = 0 and a third of the fleet departs mid-run and returns later;
//!   queued work migrates, and nothing may ever be scheduled onto a
//!   departed node.

use crate::carbon::IntensityTrace;
use crate::node::NodeSpec;

use super::engine::{ArrivalProcess, ChurnEvent, SimConfig};
use super::fleet;

/// Names accepted by [`build`] (and `carbonedge sim --scenario`).
pub const SCENARIO_NAMES: &[&str] =
    &["paper-3-node", "fleet-100", "diurnal-solar", "bursty", "churn"];

/// A fully specified simulation setup.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub specs: Vec<NodeSpec>,
    /// Per-node intensity trace (same order as `specs`).
    pub traces: Vec<IntensityTrace>,
    /// Per-node service concurrency bound.
    pub capacity: Vec<usize>,
    pub arrivals: ArrivalProcess,
    /// Number of requests the arrival process generates.
    pub requests: usize,
    pub churn: Vec<ChurnEvent>,
    pub config: SimConfig,
}

/// Build a named scenario. `nodes == 0` and `requests == 0` select
/// per-scenario defaults. Returns `None` for unknown names.
pub fn build(name: &str, nodes: usize, requests: usize, seed: u64) -> Option<Scenario> {
    let requests = if requests == 0 { 20_000 } else { requests };
    match name {
        "paper-3-node" => Some(paper_3_node(requests, seed)),
        "fleet-100" => Some(fleet_n(if nodes == 0 { 100 } else { nodes }, requests, seed)),
        "diurnal-solar" => Some(diurnal_solar(if nodes == 0 { 12 } else { nodes }, requests, seed)),
        "bursty" => Some(bursty(nodes, requests, seed)),
        "churn" => Some(churn(if nodes == 0 { 10 } else { nodes }, requests, seed)),
        _ => None,
    }
}

fn static_traces(specs: &[NodeSpec]) -> Vec<IntensityTrace> {
    specs.iter().map(|s| IntensityTrace::Static(s.intensity)).collect()
}

fn paper_3_node(requests: usize, seed: u64) -> Scenario {
    let specs = NodeSpec::paper_nodes();
    Scenario {
        name: "paper-3-node".into(),
        traces: static_traces(&specs),
        capacity: vec![1; specs.len()],
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz: 6.0 },
        requests,
        churn: Vec::new(),
        config: SimConfig { seed, ..SimConfig::default() },
    }
}

fn fleet_n(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    let specs = fleet::synth_fleet(n, seed);
    let capacity = fleet::capacities(&specs);
    let rate_hz = 0.6 * fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    Scenario {
        name: "fleet-100".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz },
        requests,
        churn: Vec::new(),
        config,
    }
}

/// Virtual horizon the diurnal scenario spreads its arrivals over: the
/// first quarter of the day curve, where solar-driven intensity moves
/// monotonically away from the nightly mean.
pub const DIURNAL_HORIZON_S: f64 = 21_600.0;

fn diurnal_solar(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    let specs = fleet::synth_fleet(n, seed);
    let traces = specs
        .iter()
        .map(|s| IntensityTrace::Diurnal {
            mean: s.intensity,
            amplitude: 0.4 * s.intensity,
            period_s: 86_400.0,
            phase_s: 0.0,
        })
        .collect();
    let capacity = fleet::capacities(&specs);
    Scenario {
        name: "diurnal-solar".into(),
        traces,
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz: requests as f64 / DIURNAL_HORIZON_S },
        requests,
        churn: Vec::new(),
        config,
    }
}

fn bursty(nodes: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    let paper = nodes == 0 || nodes == 3;
    let specs = if paper { NodeSpec::paper_nodes() } else { fleet::synth_fleet(nodes, seed) };
    let capacity = if paper { vec![1; specs.len()] } else { fleet::capacities(&specs) };
    let cap_hz = fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    Scenario {
        name: "bursty".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Mmpp {
            rate_low_hz: 0.25 * cap_hz,
            rate_high_hz: 1.5 * cap_hz,
            mean_dwell_s: 20.0,
        },
        requests,
        churn: Vec::new(),
        config,
    }
}

fn churn(n: usize, requests: usize, seed: u64) -> Scenario {
    assert!(n >= 3, "churn scenario needs at least 3 nodes");
    let config = SimConfig { seed, ..SimConfig::default() };
    let specs = fleet::synth_fleet(n, seed);
    let capacity = fleet::capacities(&specs);
    let rate_hz = 0.5 * fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    let horizon_s = requests as f64 / rate_hz;
    // Node n-1 is dead from the start (must never receive work); the first
    // third of the fleet departs at 30% of the horizon and rejoins at 70%.
    let mut churn = vec![ChurnEvent { at_s: 0.0, node: n - 1, up: false }];
    for i in 0..(n / 3).max(1) {
        churn.push(ChurnEvent { at_s: 0.3 * horizon_s, node: i, up: false });
        churn.push(ChurnEvent { at_s: 0.7 * horizon_s, node: i, up: true });
    }
    Scenario {
        name: "churn".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz },
        requests,
        churn,
        config,
    }
}

/// Single-node monolithic baseline for `sc`: the same arrival process and
/// request budget against one host-class node — full-load host power at the
/// host grid scenario (Config::default's 530 gCO₂/kWh), the paper's
/// "Monolithic" row transplanted into virtual time.
pub fn monolithic_of(sc: &Scenario) -> Scenario {
    let host_w = crate::config::default_host_power().power_watts(1.0, 1.0);
    let spec = NodeSpec {
        name: "host-mono".into(),
        cpu_quota: 1.0,
        mem_mb: 4096,
        intensity: 530.0,
        rated_power_w: host_w,
        prior_ms: 250.0,
        alpha: 0.0,
        overhead_ms: 0.0,
        time_scale: 20.6,
        adaptive: false,
    };
    Scenario {
        name: format!("{}-monolithic", sc.name),
        traces: vec![IntensityTrace::Static(spec.intensity)],
        capacity: vec![1],
        specs: vec![spec],
        arrivals: sc.arrivals.clone(),
        requests: sc.requests,
        churn: Vec::new(),
        config: sc.config.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_name_builds() {
        for name in SCENARIO_NAMES {
            let sc = build(name, 0, 0, 7).unwrap_or_else(|| panic!("{name} did not build"));
            assert_eq!(sc.specs.len(), sc.traces.len());
            assert_eq!(sc.specs.len(), sc.capacity.len());
            assert_eq!(sc.requests, 20_000);
            assert_eq!(sc.config.seed, 7);
            assert!(sc.arrivals.mean_rate_hz() > 0.0, "{name}");
        }
        assert!(build("atlantis", 0, 0, 7).is_none());
    }

    #[test]
    fn defaults_match_docs() {
        assert_eq!(build("paper-3-node", 0, 0, 1).unwrap().specs.len(), 3);
        assert_eq!(build("fleet-100", 0, 0, 1).unwrap().specs.len(), 100);
        assert_eq!(build("diurnal-solar", 0, 0, 1).unwrap().specs.len(), 12);
        assert_eq!(build("bursty", 0, 0, 1).unwrap().specs.len(), 3);
        assert_eq!(build("churn", 0, 0, 1).unwrap().specs.len(), 10);
        // node/request overrides respected
        let sc = build("fleet-100", 25, 500, 1).unwrap();
        assert_eq!(sc.specs.len(), 25);
        assert_eq!(sc.requests, 500);
    }

    #[test]
    fn diurnal_uses_time_varying_traces() {
        let sc = build("diurnal-solar", 0, 0, 1).unwrap();
        for tr in &sc.traces {
            assert!(matches!(tr, IntensityTrace::Diurnal { .. }));
        }
        // Horizon scaling: arrivals spread over the quarter-day window.
        let rate = sc.arrivals.mean_rate_hz();
        assert!((rate - 20_000.0 / DIURNAL_HORIZON_S).abs() < 1e-9);
    }

    #[test]
    fn churn_has_dead_node_and_waves() {
        let sc = build("churn", 9, 0, 3).unwrap();
        assert_eq!(sc.churn[0], ChurnEvent { at_s: 0.0, node: 8, up: false });
        let downs = sc.churn.iter().filter(|e| !e.up).count();
        let ups = sc.churn.iter().filter(|e| e.up).count();
        assert_eq!(downs, 1 + 3); // dead node + n/3 wave
        assert_eq!(ups, 3);
    }

    #[test]
    fn monolithic_baseline_is_single_host() {
        let sc = build("paper-3-node", 0, 0, 5).unwrap();
        let mono = monolithic_of(&sc);
        assert_eq!(mono.specs.len(), 1);
        assert_eq!(mono.specs[0].name, "host-mono");
        assert_eq!(mono.specs[0].intensity, 530.0);
        // ≈142 W full-load host (config::default_host_power calibration)
        assert!((mono.specs[0].rated_power_w - 142.0).abs() < 1e-9);
        assert_eq!(mono.requests, sc.requests);
        assert_eq!(mono.config.seed, sc.config.seed);
    }
}
