//! Thread-pool substrate (tokio is not in the offline crate set).
//!
//! A fixed worker pool over `std::sync::mpsc`, used by the coordinator for
//! concurrent request handling and by the workload driver. Jobs are boxed
//! closures; `join` blocks until the queue drains.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    executed: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let executed = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let executed = Arc::clone(&executed);
                thread::Builder::new()
                    .name(format!("carbonedge-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                executed.fetch_add(1, Ordering::SeqCst);
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                cv.notify_all();
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending, executed }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx.as_ref().expect("pool shut down").send(Box::new(f)).expect("workers alive");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Total jobs executed since creation.
    pub fn executed(&self) -> usize {
        self.executed.load(Ordering::SeqCst)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.executed(), 100);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn results_via_channel() {
        let pool = ThreadPool::new(3);
        let (tx, rx) = mpsc::channel();
        for i in 0..10u64 {
            let tx = tx.clone();
            pool.execute(move || tx.send(i * i).unwrap());
        }
        pool.join();
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        pool.join();
        drop(pool); // must not hang
    }
}
