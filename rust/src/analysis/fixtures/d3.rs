//! Known-bad fixture: D3 — f64 fold over an unordered container.
//! Addition order varies per process; the total drifts in the last ulp.
use std::collections::HashMap;

/// Total carbon across nodes, in hasher order.
pub fn total_g(per_node: &HashMap<String, f64>) -> f64 {
    per_node.values().sum()
}
