//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client.
//!
//! The `xla` crate's handles are `Rc`-based (neither `Send` nor `Sync`), so
//! all PJRT work lives on one **executor thread** that owns the client,
//! the compiled-executable cache and the device-resident weight buffers;
//! the rest of the system talks to it through the cloneable, `Send`
//! [`ExecHandle`]. This mirrors a real deployment where a single accelerator
//! queue serializes kernel launches.

mod executor;
mod tensor;

pub use executor::{ExecHandle, ExecServer, ExecStats, ProgramKey};
pub use tensor::{f32_from_le_bytes, Tensor};

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

/// Single-threaded PJRT wrapper: client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, Rc<xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()?, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an HLO-text artifact, memoized by path.
    ///
    /// HLO *text* is the interchange format: jax>=0.5 emits protos with
    /// 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
    /// parser reassigns ids (see DESIGN.md / aot.py).
    pub fn load(&mut self, path: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(path) {
            return Ok(Rc::clone(exe));
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).with_context(|| format!("compiling {path}"))?);
        self.cache.insert(path.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    pub fn cached_programs(&self) -> usize {
        self.cache.len()
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)?)
    }

    /// Execute with device-resident buffer arguments; returns the flat f32
    /// output of the (1-tuple) result plus its shape.
    pub fn execute_buffers(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Tensor> {
        let outs = exe.execute_b(args)?;
        let lit = outs[0][0].to_literal_sync()?.to_tuple1()?;
        Tensor::from_literal(&lit)
    }

    /// Execute with host literals (upload per call). Used by tests and the
    /// §Perf "before" baseline; the hot path uses [`execute_buffers`].
    pub fn execute_literals(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Tensor> {
        let outs = exe.execute(args)?;
        let lit = outs[0][0].to_literal_sync()?.to_tuple1()?;
        Tensor::from_literal(&lit)
    }
}
