//! Integration tests for the L3.5 discrete-event fleet simulator. Unlike
//! `tests/integration.rs` these need no artifacts: everything runs on the
//! virtual clock.

use carbonedge::carbon::{DeferralPolicy, IntensityTrace};
use carbonedge::experiments as exp;
use carbonedge::microgrid::{BatterySpec, ChargePolicy, DischargePolicy, MicrogridSpec, PvProfile};
use carbonedge::node::NodeSpec;
use carbonedge::scheduler::{
    CarbonAwareScheduler, DeferAwareGreenScheduler, LeastLoadedScheduler, Mode, Weights,
};
use carbonedge::sim::{
    scenarios, AdmissionSpec, ArrivalProcess, BatchSpec, ChurnEvent, DeferralSpec, Scenario,
    SimConfig, Simulation,
};

fn green_run(sc: &Scenario) -> carbonedge::sim::SimReport {
    let mut s = CarbonAwareScheduler::new("green", Mode::Green.weights());
    Simulation::run(sc, &mut s)
}

#[test]
fn deterministic_across_runs_for_every_scenario() {
    for name in scenarios::SCENARIO_NAMES {
        let sc = scenarios::build(name, 0, 2_000, 7).unwrap();
        let a = green_run(&sc);
        let b = green_run(&sc);
        assert_eq!(a, b, "{name} diverged across identical runs");
        // A different seed genuinely changes the run.
        let sc2 = scenarios::build(name, 0, 2_000, 8).unwrap();
        let c = green_run(&sc2);
        assert_ne!(a.latency_ms, c.latency_ms, "{name} ignored the seed");
    }
}

#[test]
fn conservation_per_node_ledger_sums_to_fleet_totals() {
    for name in scenarios::SCENARIO_NAMES {
        let sc = scenarios::build(name, 0, 2_000, 11).unwrap();
        let r = green_run(&sc);
        assert_eq!(r.requests, 2_000, "{name}");
        assert_eq!(r.completed + r.rejected, r.requests, "{name}: requests leaked");
        let (tasks, energy_kwh, carbon_g) = r.node_sums();
        assert_eq!(tasks, r.completed, "{name}: task conservation");
        // Node ledgers cover idle + dynamic; geographic scenarios add WAN
        // transfer on top, carried by the site rows, not any node.
        assert!(
            (energy_kwh + r.energy_wan_kwh_total - r.energy_kwh_total).abs()
                <= 1e-9 * r.energy_kwh_total.max(1e-30),
            "{name}: energy ledger {energy_kwh} != total {}",
            r.energy_kwh_total
        );
        assert!(
            (carbon_g + r.carbon_wan_g_total - r.carbon_g_total).abs()
                <= 1e-9 * r.carbon_g_total.max(1e-30),
            "{name}: carbon ledger {carbon_g} != total {}",
            r.carbon_g_total
        );
        // The two-part split itself conserves: per-node idle + dynamic rows
        // sum to the split totals, and the split totals sum to the grand
        // totals (energy and carbon alike).
        let (ed, ei, cd, ci) = r.node_sums_split();
        assert!(
            (ed - r.energy_dynamic_kwh_total).abs()
                <= 1e-9 * r.energy_dynamic_kwh_total.max(1e-30),
            "{name}: dynamic-energy ledger"
        );
        assert!(
            (ei - r.energy_idle_kwh_total).abs() <= 1e-9 * r.energy_idle_kwh_total.max(1e-30),
            "{name}: idle-energy ledger"
        );
        assert!(
            (cd - r.carbon_dynamic_g_total).abs() <= 1e-9 * r.carbon_dynamic_g_total.max(1e-30),
            "{name}: dynamic-carbon ledger"
        );
        assert!(
            (ci - r.carbon_idle_g_total).abs() <= 1e-9 * r.carbon_idle_g_total.max(1e-30),
            "{name}: idle-carbon ledger"
        );
        assert!(
            (r.energy_dynamic_kwh_total + r.energy_idle_kwh_total + r.energy_wan_kwh_total
                - r.energy_kwh_total)
                .abs()
                <= 1e-12 * r.energy_kwh_total.max(1e-30),
            "{name}: energy split does not sum to total"
        );
        assert!(
            (r.carbon_dynamic_g_total + r.carbon_idle_g_total + r.carbon_wan_g_total
                - r.carbon_g_total)
                .abs()
                <= 1e-12 * r.carbon_g_total.max(1e-30),
            "{name}: carbon split does not sum to total"
        );
        // Supply-side conservation: per node, pv + battery + grid covers
        // exactly idle + dynamic (grid-only nodes trivially, microgrid
        // nodes through the slice-settled ledger), the rows sum to the
        // supply totals, and the supply totals sum to the energy total.
        for n in &r.nodes {
            let supply = n.energy_pv_kwh + n.energy_battery_kwh + n.energy_grid_kwh;
            let demand = n.energy_dynamic_kwh + n.energy_idle_kwh;
            assert!(
                (supply - demand).abs() <= 1e-6 * demand.max(1e-30),
                "{name}/{}: supply {supply} != demand {demand}",
                n.name
            );
            assert!(
                n.energy_pv_kwh >= 0.0 && n.energy_battery_kwh >= 0.0 && n.energy_grid_kwh >= 0.0,
                "{name}/{}: negative supply term",
                n.name
            );
            // Battery bounds: SoC samples stay inside [0, 1] and exist
            // exactly for microgrid nodes.
            assert_eq!(n.soc_timeline.is_empty(), !n.microgrid, "{name}/{}", n.name);
            for &(t, soc) in &n.soc_timeline {
                assert!(t >= 0.0, "{name}/{}: SoC sample before t=0", n.name);
                assert!(
                    (0.0..=1.0 + 1e-9).contains(&soc),
                    "{name}/{}: SoC {soc} out of bounds",
                    n.name
                );
            }
        }
        // Stored-carbon ledger: per node, everything grid-charged into the
        // battery is either released by discharge or still stored; the
        // labelled discharge subset never exceeds the node's total carbon.
        for n in &r.nodes {
            assert!(
                n.energy_grid_charge_kwh >= 0.0
                    && n.carbon_charged_g >= 0.0
                    && n.carbon_battery_g >= 0.0
                    && n.carbon_stored_g >= 0.0,
                "{name}/{}: negative storage-ledger term",
                n.name
            );
            assert!(
                (n.carbon_charged_g - n.carbon_battery_g - n.carbon_stored_g).abs()
                    <= 1e-6 * n.carbon_charged_g.max(1e-30),
                "{name}/{}: stored-carbon ledger unbalanced ({} != {} + {})",
                n.name,
                n.carbon_charged_g,
                n.carbon_battery_g,
                n.carbon_stored_g
            );
            assert!(
                n.carbon_battery_g <= n.carbon_g() + 1e-9 * n.carbon_g().max(1e-30),
                "{name}/{}: released embodied carbon exceeds the node ledger",
                n.name
            );
        }
        let (gc, charged, spent, stored) = r.node_sums_storage();
        assert!(
            (gc - r.energy_grid_charge_kwh_total).abs()
                <= 1e-9 * r.energy_grid_charge_kwh_total.max(1e-30),
            "{name}: grid-charge ledger"
        );
        assert!(
            (charged - spent - stored).abs() <= 1e-6 * charged.max(1e-30),
            "{name}: fleet stored-carbon ledger unbalanced"
        );
        assert!(
            (charged - r.carbon_charged_g_total).abs()
                <= 1e-9 * r.carbon_charged_g_total.max(1e-30),
            "{name}: charged-carbon ledger"
        );
        let (pv, batt, grid) = r.node_sums_supply();
        assert!(
            (pv - r.energy_pv_kwh_total).abs() <= 1e-9 * r.energy_pv_kwh_total.max(1e-30),
            "{name}: pv ledger"
        );
        assert!(
            (batt - r.energy_battery_kwh_total).abs()
                <= 1e-9 * r.energy_battery_kwh_total.max(1e-30),
            "{name}: battery ledger"
        );
        assert!(
            (grid - r.energy_grid_kwh_total).abs() <= 1e-9 * r.energy_grid_kwh_total.max(1e-30),
            "{name}: grid ledger"
        );
        assert!(
            (pv + batt + grid + r.energy_wan_kwh_total - r.energy_kwh_total).abs()
                <= 1e-6 * r.energy_kwh_total.max(1e-30),
            "{name}: supply does not sum to total energy"
        );
        assert!(r.completed > 0, "{name}: nothing completed");
        assert!(r.makespan_s > 0.0 && r.throughput_rps > 0.0, "{name}");
    }
}

#[test]
fn paper_3_node_reproduces_qualitative_result_in_virtual_time() {
    let sc = scenarios::build("paper-3-node", 0, 10_000, 42).unwrap();
    let reports = exp::sim_mode_comparison(&sc);
    let (mono, perf, _balanced, green) = (&reports[0], &reports[1], &reports[2], &reports[3]);
    assert_eq!(green.scheduler, "green");
    assert_eq!(perf.scheduler, "performance");
    // The paper's headline shape, at open-loop fleet scale: Green cuts
    // carbon vs both the monolithic host and Performance mode, while
    // Performance is no greener than monolithic.
    assert!(
        green.carbon_per_req_g < 0.85 * mono.carbon_per_req_g,
        "green {} vs mono {}",
        green.carbon_per_req_g,
        mono.carbon_per_req_g
    );
    assert!(
        green.carbon_per_req_g < 0.85 * perf.carbon_per_req_g,
        "green {} vs perf {}",
        green.carbon_per_req_g,
        perf.carbon_per_req_g
    );
    assert!(perf.carbon_per_req_g > 0.99 * mono.carbon_per_req_g);
    // Under contention Green leans on node-green hardest, Performance on
    // node-high — and the single mono host queues far worse than the fleet.
    let top = |r: &carbonedge::sim::SimReport| {
        r.nodes.iter().max_by_key(|n| n.tasks).unwrap().name.clone()
    };
    assert_eq!(top(green), "node-green");
    assert_eq!(top(perf), "node-high");
    assert!(mono.latency_ms.mean > green.latency_ms.mean);
}

#[test]
fn weight_sweep_trades_carbon_for_latency_monotonically() {
    let sc = scenarios::build("paper-3-node", 0, 8_000, 13).unwrap();
    let points = exp::sim_weight_sweep(&sc, 0.25);
    assert_eq!(points.len(), 5); // w_C ∈ {0, .25, .5, .75, 1}
    let carbons: Vec<f64> = points.iter().map(|p| p.report.carbon_per_req_g).collect();
    // Monotone in trend: each step may wiggle ≤ 2% (service jitter), the
    // ends must differ decisively.
    for w in carbons.windows(2) {
        assert!(w[1] <= w[0] * 1.02, "carbon rose along the sweep: {carbons:?}");
    }
    assert!(
        carbons[4] < 0.8 * carbons[0],
        "sweep ends not decisive: {carbons:?}"
    );
    // The carbon savings are bought with latency: the full-carbon extreme
    // is slower than the full-performance extreme.
    assert!(points[4].report.latency_ms.mean > points[0].report.latency_ms.mean);
}

#[test]
fn churn_scenario_never_uses_departed_nodes() {
    let sc = scenarios::build("churn", 0, 3_000, 21).unwrap();
    let r = green_run(&sc);
    // The node that is down from t = 0 must never see a single task.
    let dead = &sc.specs[sc.specs.len() - 1].name;
    assert_eq!(r.node(dead).unwrap().tasks, 0, "dead node {dead} ran work");
    assert_eq!(r.completed + r.rejected, r.requests);
}

#[test]
fn churn_migrates_queued_work_to_survivors() {
    // Deterministic migration: two identical nodes saturated 4× over
    // capacity, one departs mid-run with a long queue.
    let mk = || NodeSpec {
        name: String::new(),
        cpu_quota: 1.0,
        mem_mb: 1024,
        intensity: 500.0,
        rated_power_w: 100.0,
        idle_w: 0.0,
        prior_ms: 250.0,
        alpha: 0.0,
        overhead_ms: 0.0,
        time_scale: 20.6,
        adaptive: false,
        batch_gamma: 0.8,
        batch_beta: 0.2,
    };
    let mut a = mk();
    a.name = "a".into();
    let mut b = mk();
    b.name = "b".into();
    // service ≈ 198 ms ⇒ 2 nodes sustain ~10 req/s; arrivals at 40 req/s.
    let sc = Scenario {
        name: "mini-churn".into(),
        traces: vec![IntensityTrace::Static(500.0), IntensityTrace::Static(500.0)],
        capacity: vec![1, 1],
        specs: vec![a, b],
        arrivals: ArrivalProcess::Uniform { rate_hz: 40.0 },
        requests: 400,
        churn: vec![ChurnEvent { at_s: 5.0, node: 0, up: false }],
        microgrids: Vec::new(),
        sites: None,
        config: SimConfig { seed: 3, jitter_sigma: 0.0, ..SimConfig::default() },
    };
    let mut sched = LeastLoadedScheduler;
    let r = Simulation::run(&sc, &mut sched);
    assert!(r.migrated > 0, "queued work did not migrate");
    assert_eq!(r.completed, 400); // node b absorbed everything
    // Node a stopped exactly when it departed: it completed only what was
    // in service or already finished, far less than half the run.
    let a_tasks = r.node("a").unwrap().tasks;
    assert!(a_tasks > 0 && a_tasks < 100, "node a ran {a_tasks} tasks");
    assert_eq!(r.node("b").unwrap().tasks + a_tasks, 400);
}

#[test]
fn bursty_arrivals_queue_worse_than_steady_poisson_at_equal_load() {
    let bursty = scenarios::build("bursty", 0, 6_000, 17).unwrap();
    let mut steady = bursty.clone();
    steady.name = "steady-twin".into();
    steady.arrivals = ArrivalProcess::Poisson { rate_hz: bursty.arrivals.mean_rate_hz() };
    let rb = green_run(&bursty);
    let rs = green_run(&steady);
    assert_eq!(rb.completed + rb.rejected, 6_000);
    assert!(
        rb.wait_ms.p95 > 1.2 * rs.wait_ms.p95,
        "bursts should queue worse: mmpp p95 {} vs poisson p95 {}",
        rb.wait_ms.p95,
        rs.wait_ms.p95
    );
}

#[test]
fn diurnal_intensity_prices_emissions_at_completion_time() {
    let sc = scenarios::build("diurnal-solar", 0, 4_000, 5).unwrap();
    // Round-robin so the near-idle fleet still exercises every node's trace.
    let mut sched = carbonedge::scheduler::RoundRobinScheduler::new();
    let r = Simulation::run(&sc, &mut sched);
    // Arrivals spread over the first quarter of the day curve, where the
    // sinusoid sits strictly above its mean — so every node's *effective*
    // intensity (carbon / energy) must exceed its static spec scenario.
    // A static-intensity bug would make them exactly equal.
    let mut checked = 0;
    for (spec, usage) in sc.specs.iter().zip(&r.nodes) {
        if usage.tasks == 0 {
            continue;
        }
        // Dynamic (task-attributed) side only: idle-floor carbon integrates
        // the whole window and would dilute the completion-time signal.
        let effective = usage.carbon_dynamic_g / usage.energy_dynamic_kwh;
        assert!(
            effective > 1.05 * spec.intensity,
            "{}: effective {effective} vs static {}",
            spec.name,
            spec.intensity
        );
        checked += 1;
    }
    assert_eq!(checked, sc.specs.len(), "round-robin should exercise every node");
}

#[test]
fn fleet_scale_spreads_load_across_the_region_table() {
    let sc = scenarios::build("fleet-100", 0, 5_000, 29).unwrap();
    assert_eq!(sc.specs.len(), 100);
    let mut s = CarbonAwareScheduler::new("balanced", Mode::Balanced.weights());
    let r = Simulation::run(&sc, &mut s);
    assert_eq!(r.completed + r.rejected, 5_000);
    let active_nodes = r.nodes.iter().filter(|n| n.tasks > 0).count();
    assert!(active_nodes > 20, "only {active_nodes} of 100 nodes saw work");
    // Heterogeneous grids: the busiest nodes should skew cleaner than the
    // fleet-average intensity under a carbon-weighted mode.
    let fleet_mean =
        sc.specs.iter().map(|sp| sp.intensity).sum::<f64>() / sc.specs.len() as f64;
    let mut by_tasks: Vec<(u64, f64)> =
        r.nodes.iter().zip(&sc.specs).map(|(n, sp)| (n.tasks, sp.intensity)).collect();
    by_tasks.sort_by(|x, y| y.0.cmp(&x.0));
    let busiest_mean =
        by_tasks[..10].iter().map(|(_, i)| i).sum::<f64>() / 10.0;
    assert!(
        busiest_mean < fleet_mean,
        "busiest-10 intensity {busiest_mean} not cleaner than fleet mean {fleet_mean}"
    );
}

#[test]
fn consolidation_fewer_busy_nodes_beat_many_idle_ones() {
    // The experiment idle accounting unlocks: the same Green-mode workload
    // (same arrivals, same seed — the scenario's rate is pinned to a 3-node
    // reference) on 3 busy nodes vs spread across 12 mostly-idle ones.
    let (small, large) = exp::sim_consolidation(3, 12, 10_000, 17);
    assert_eq!(small.completed + small.rejected, 10_000);
    assert_eq!(large.completed + large.rejected, 10_000);
    assert!(small.completed as f64 > 0.95 * 10_000.0, "small fleet drowned");
    // Dynamic energy is workload-bound, so it barely moves with fleet size…
    assert!(
        (small.energy_dynamic_kwh_total - large.energy_dynamic_kwh_total).abs()
            < 0.05 * small.energy_dynamic_kwh_total,
        "dynamic energy should be fleet-size invariant: {} vs {}",
        small.energy_dynamic_kwh_total,
        large.energy_dynamic_kwh_total
    );
    // …while the idle floor scales with the number of powered-on nodes.
    assert!(
        large.energy_idle_kwh_total > 3.0 * small.energy_idle_kwh_total,
        "idle energy should scale with fleet size: {} vs {}",
        small.energy_idle_kwh_total,
        large.energy_idle_kwh_total
    );
    // Net effect: consolidation emits measurably less, total and per
    // request.
    assert!(
        small.carbon_g_total < 0.75 * large.carbon_g_total,
        "small {} g vs large {} g",
        small.carbon_g_total,
        large.carbon_g_total
    );
    assert!(small.carbon_per_req_g < 0.75 * large.carbon_per_req_g);
}

#[test]
fn decide_preserves_legacy_select_semantics_across_the_scenario_library() {
    // Shim-equivalence for the `decide` migration: over every scenario's
    // fleet and a band of synthetic node states, each baseline must
    // `Assign(i)` exactly where the retired `select` contract returned
    // `Some(i)` (same feasibility filters, same argmax/min/cycle), must
    // `Reject` exactly where it returned `None`, and must never `Defer`.
    use carbonedge::node::EdgeNode;
    use carbonedge::scheduler::{
        score_breakdown, Amp4ecScheduler, FleetView, RandomScheduler, RoundRobinScheduler,
        Scheduler, SchedulingDecision, TaskDemand, LOAD_CUTOFF,
    };
    let task = TaskDemand::default();
    let argmax = |nodes: &[std::sync::Arc<EdgeNode>], w: &Weights| -> Option<usize> {
        let mut best = None;
        let mut best_score = 0.0;
        for (i, n) in nodes.iter().enumerate() {
            let st = n.state();
            if st.load > LOAD_CUTOFF
                || n.score_ms() > task.latency_threshold_ms
                || !n.fits(task.mem_mb, task.cpu)
            {
                continue;
            }
            let b = score_breakdown(n, &task, w);
            if b.total > best_score {
                best_score = b.total;
                best = Some(i);
            }
        }
        best
    };
    let amp4ec_w = Weights { r: 0.25, l: 0.25, p: 0.30, b: 0.15, c: 0.0 }.normalized();
    for name in scenarios::SCENARIO_NAMES {
        let sc = scenarios::build(name, 0, 0, 13).unwrap();
        let nodes: Vec<_> = sc.specs.iter().cloned().map(EdgeNode::new).collect();
        for round in 0..4usize {
            // Walk the state space: growing backlog on a rotating subset,
            // plus some completed history so load/avg_ms move too.
            for (i, n) in nodes.iter().enumerate() {
                if round > 0 && i % (round + 1) == 0 {
                    n.begin_task();
                    if round == 3 {
                        n.finish_task(150.0, 1.0, 0.01);
                    }
                }
            }
            let fleet = FleetView::observe(&nodes);
            let ctx = format!("{name} round {round}");

            let mut green = CarbonAwareScheduler::new("green", Mode::Green.weights());
            assert_eq!(
                green.decide(&task, &fleet),
                SchedulingDecision::from_choice(argmax(&nodes, &Mode::Green.weights())),
                "{ctx}: green"
            );
            let mut amp = Amp4ecScheduler::new();
            assert_eq!(
                amp.decide(&task, &fleet),
                SchedulingDecision::from_choice(argmax(&nodes, &amp4ec_w)),
                "{ctx}: amp4ec"
            );
            // Least-loaded: min inflight among resource-fitting nodes.
            let expect_ll = nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| n.fits(task.mem_mb, task.cpu))
                .min_by_key(|(_, n)| n.state().inflight)
                .map(|(i, _)| i);
            assert_eq!(
                LeastLoadedScheduler.decide(&task, &fleet),
                SchedulingDecision::from_choice(expect_ll),
                "{ctx}: least-loaded"
            );
            // Fresh round-robin: first resource-fitting node from index 0.
            let expect_rr = (0..nodes.len()).find(|&i| nodes[i].fits(task.mem_mb, task.cpu));
            assert_eq!(
                RoundRobinScheduler::new().decide(&task, &fleet),
                SchedulingDecision::from_choice(expect_rr),
                "{ctx}: round-robin"
            );
            // Random: seeded determinism + feasibility of the pick.
            let ra = RandomScheduler::new(7).decide(&task, &fleet);
            let rb = RandomScheduler::new(7).decide(&task, &fleet);
            assert_eq!(ra, rb, "{ctx}: random determinism");
            match ra {
                SchedulingDecision::Assign(i) => {
                    assert!(nodes[i].fits(task.mem_mb, task.cpu), "{ctx}: random feasibility")
                }
                SchedulingDecision::Reject { .. } => {
                    assert!(expect_rr.is_none(), "{ctx}: random rejected a feasible fleet")
                }
                SchedulingDecision::Defer { .. } => panic!("{ctx}: baseline deferred"),
            }
        }
    }
}

#[test]
fn deferral_routing_scenario_is_deterministic_under_joint_decisions() {
    // Determinism-by-equality for the new scenario under the new
    // scheduler: identical (scenario, seed, fresh DeferAwareGreen) runs
    // replay bit-for-bit, and the joint policy genuinely defers.
    let sc = scenarios::build("deferral-routing", 0, 2_000, 7).unwrap();
    let run = || {
        let mut s = DeferAwareGreenScheduler::new(0.05);
        Simulation::run(&sc, &mut s)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "deferral-routing diverged across identical joint runs");
    assert_eq!(a.scheduler, "defer-green");
    assert_eq!(a.completed + a.rejected, 2_000);
    assert!(a.deferred > 500, "joint policy should park dirty-hour work: {}", a.deferred);
    assert_eq!(a.deadline_missed, 0);
    // A different seed genuinely changes the run.
    let sc2 = scenarios::build("deferral-routing", 0, 2_000, 8).unwrap();
    let mut s2 = DeferAwareGreenScheduler::new(0.05);
    let c = Simulation::run(&sc2, &mut s2);
    assert_ne!(a.latency_ms, c.latency_ms, "deferral-routing ignored the seed");
}

#[test]
fn joint_defer_routing_beats_route_then_defer_on_real_trace() {
    // The ISSUE 4 acceptance gate: on real-trace (same arrivals, same
    // seed, same fleet), the joint DeferAwareGreen scheduler must cut
    // gCO₂/req to ≤ 0.95× of route-then-defer green — with no additional
    // missed deadlines and nothing rejected. The margin comes from two
    // joint-only behaviours: spill arrivals parked for *another* node's
    // trough (route-then-defer only ever reads the chosen node's curve),
    // and releases spread across the trough plateau instead of
    // stampeding the cleanest node past its load cutoff.
    let sc = scenarios::build("real-trace", 0, 4_000, 11).unwrap();
    let (joint, rtd) = exp::sim_deferral_routing_comparison(&sc);
    assert_eq!(rtd.scheduler, "green", "baseline is the auto-gated green run");
    assert_eq!(joint.scheduler, "defer-green");
    assert_eq!(joint.requests, 4_000);
    assert_eq!(joint.completed, 4_000, "joint run must complete everything");
    assert_eq!(rtd.completed, 4_000);
    assert_eq!(joint.rejected, 0);
    assert!(joint.deferred > 500 && rtd.deferred > 500, "both should defer heavily");
    assert_eq!(joint.deadline_missed, 0, "no additional missed deadlines");
    assert_eq!(rtd.deadline_missed, 0);
    assert!(
        joint.carbon_per_req_g <= 0.95 * rtd.carbon_per_req_g,
        "joint {} g/req vs route-then-defer {} g/req",
        joint.carbon_per_req_g,
        rtd.carbon_per_req_g
    );
    // Deterministic A/B: the comparison replays bit-for-bit.
    let (joint2, rtd2) = exp::sim_deferral_routing_comparison(&sc);
    assert_eq!(joint, joint2);
    assert_eq!(rtd, rtd2);
    // The render never prints NaN and names the win.
    let rendered = exp::sim_deferral_routing_render(&joint, &rtd);
    assert!(!rendered.contains("NaN"), "{rendered}");
    assert!(rendered.contains("jointly cuts gCO2/req"));
}

#[test]
fn deferral_beats_no_deferral_twin_on_real_trace() {
    // Green mode over a real-shape day curve with 6 h of slack vs the
    // identical run with deferral stripped: deferral must cut gCO₂/req
    // while completing everything inside its deadlines.
    let sc = scenarios::build("real-trace", 0, 4_000, 11).unwrap();
    let (defer, twin) = exp::sim_deferral_comparison(&sc);
    assert_eq!(defer.requests, 4_000);
    assert_eq!(defer.completed, 4_000, "deferred work must still complete");
    assert_eq!(defer.rejected, 0);
    assert_eq!(twin.completed, 4_000);
    assert_eq!(twin.deferred, 0, "the twin must not defer");
    assert!(
        defer.deferred > 500,
        "morning-peak arrivals should park: only {} deferred",
        defer.deferred
    );
    assert_eq!(defer.deadline_missed, 0, "slack minus headroom must absorb service");
    assert!(
        defer.carbon_per_req_g < 0.95 * twin.carbon_per_req_g,
        "deferral {} g/req vs twin {} g/req",
        defer.carbon_per_req_g,
        twin.carbon_per_req_g
    );
    // Shifting work costs wall-clock, not correctness: the deferred run
    // finishes later but loses nothing.
    assert!(defer.makespan_s > twin.makespan_s);
    // And it stays deterministic: the A/B replays bit-for-bit.
    let (defer2, twin2) = exp::sim_deferral_comparison(&sc);
    assert_eq!(defer, defer2);
    assert_eq!(twin, twin2);
}

#[test]
fn churn_migration_rescores_against_fresh_intensities() {
    // Regression for the stale-intensity migration bug: a backlogged node
    // departs long after the last scheduler-visible refresh, and its queue
    // must be re-routed against the grids *now*, not the grids at t ≈ 0.
    let chassis = |name: &str| NodeSpec {
        name: name.into(),
        cpu_quota: 1.0,
        mem_mb: 1024,
        intensity: 100.0,
        rated_power_w: 100.0,
        idle_w: 0.0,
        prior_ms: 2_000.0,
        alpha: 0.0,
        overhead_ms: 0.0,
        time_scale: 20.6,
        adaptive: false,
        batch_gamma: 0.8,
        batch_beta: 0.2,
    };
    let sink = chassis("sink");
    let mut a = chassis("a");
    a.intensity = 400.0;
    let mut b = chassis("b");
    b.intensity = 400.0;
    let sc = Scenario {
        name: "diurnal-churn".into(),
        traces: vec![
            // The sink's static 100 g/kWh attracts every arrival.
            IntensityTrace::Static(100.0),
            // a: ~300 at t = 0, 500 at the churn instant (t = 120).
            IntensityTrace::Diurnal {
                mean: 400.0,
                amplitude: 100.0,
                period_s: 240.0,
                phase_s: 60.0,
            },
            // b: the mirror image — 500 at t = 0, 300 at t = 120.
            IntensityTrace::Diurnal {
                mean: 400.0,
                amplitude: 100.0,
                period_s: 240.0,
                phase_s: -60.0,
            },
        ],
        capacity: vec![1, 1, 1],
        specs: vec![sink, a, b],
        arrivals: ArrivalProcess::Uniform { rate_hz: 20.0 },
        requests: 300,
        churn: vec![ChurnEvent { at_s: 120.0, node: 0, up: false }],
        microgrids: Vec::new(),
        sites: None,
        config: SimConfig {
            seed: 1,
            jitter_sigma: 0.0,
            base_exec_ms: 100.0,      // service ≈ 2.06 s: the sink backlogs
            intensity_refresh_s: 1e9, // only the t≈0 refresh ever fires
            ..SimConfig::default()
        },
    };
    // Pure-carbon weights make the routing read directly off intensities.
    let mut sched = CarbonAwareScheduler::new("carbon-only", Weights::sweep(1.0));
    let r = Simulation::run(&sc, &mut sched);
    assert_eq!(r.completed, 300);
    assert!(r.migrated > 200, "the sink's backlog should migrate: {}", r.migrated);
    // With the pre-fix stale view (a = 300, b = 500 from t ≈ 0) the whole
    // backlog lands on `a`. The churn-time truth is the reverse.
    assert_eq!(r.node("a").unwrap().tasks, 0, "migrated onto the stale choice");
    assert!(
        r.node("b").unwrap().tasks > 200,
        "b should absorb the backlog, got {}",
        r.node("b").unwrap().tasks
    );
    // Work finished before the churn stays on the sink's ledger.
    let sink_tasks = r.node("sink").unwrap().tasks;
    assert!(sink_tasks > 0 && sink_tasks < 100, "sink ran {sink_tasks}");
}

#[test]
fn solar_battery_microgrids_beat_grid_only_twin() {
    // The ISSUE 3 acceptance gate: identical fleets and arrivals, green
    // mode — the PV + battery fleet must emit < 0.85× the gCO₂/req of the
    // same fleet with microgrids disabled, deterministically.
    let sc = scenarios::build("solar-battery", 0, 6_000, 19).unwrap();
    let (mg, plain, rr) = exp::sim_microgrid_comparison(&sc);
    assert_eq!(mg.requests, 6_000);
    assert_eq!(mg.completed, 6_000, "microgrid run must complete everything");
    assert_eq!(plain.completed, 6_000);
    assert!(
        mg.carbon_per_req_g < 0.85 * plain.carbon_per_req_g,
        "microgrids {} g/req vs grid-only {} g/req",
        mg.carbon_per_req_g,
        plain.carbon_per_req_g
    );
    // The supply story behind the cut: PV covers the day, the battery
    // bridges the evening, the grid only fills the pre-dawn gap.
    assert!(mg.energy_pv_kwh_total > 0.0, "no PV used over a full day");
    assert!(mg.energy_battery_kwh_total > 0.0, "battery never discharged");
    assert!(mg.energy_grid_kwh_total > 0.0, "pre-dawn hours should import grid power");
    assert!(mg.energy_grid_kwh_total < 0.2 * mg.energy_kwh_total, "grid should be the residual");
    // The twin draws everything from the grid at identical total energy
    // (same fleet, same arrivals, same service times).
    assert_eq!(plain.energy_pv_kwh_total, 0.0);
    assert_eq!(plain.energy_battery_kwh_total, 0.0);
    assert!(
        (plain.energy_grid_kwh_total - plain.energy_kwh_total).abs()
            <= 1e-9 * plain.energy_kwh_total
    );
    assert!(
        (mg.energy_kwh_total - plain.energy_kwh_total).abs() <= 1e-6 * plain.energy_kwh_total,
        "microgrids change supply, not demand: {} vs {}",
        mg.energy_kwh_total,
        plain.energy_kwh_total
    );
    // Per-node energy conservation to 1e-6 relative tolerance.
    for n in &mg.nodes {
        let supply = n.energy_pv_kwh + n.energy_battery_kwh + n.energy_grid_kwh;
        let demand = n.energy_dynamic_kwh + n.energy_idle_kwh;
        assert!(
            (supply - demand).abs() <= 1e-6 * demand.max(1e-30),
            "{}: {supply} vs {demand}",
            n.name
        );
    }
    // Same seed ⇒ identical SimReports, bit for bit.
    let (mg2, plain2, rr2) = exp::sim_microgrid_comparison(&sc);
    assert_eq!(mg, mg2);
    assert_eq!(plain, plain2);
    assert_eq!(rr, rr2);
    // The render never prints NaN, even when a run is (near-)zero-carbon.
    let rendered = exp::sim_microgrid_render(&mg, &plain, &rr);
    assert!(!rendered.contains("NaN"), "{rendered}");
    assert!(rendered.contains("microgrids cut gCO2/req"));
}

#[test]
fn project_matches_instantaneous_pricing_and_degenerates_to_the_trace() {
    // ISSUE 5 satellite proptest: across random PV/battery/draw/trace
    // configurations, Microgrid::project's first sample equals the
    // instantaneous advertised intensity, SoC stays in [0, 1], the slot
    // grid is exactly DeferralPolicy::forecast's walk, and a zero-PV
    // zero-battery projection is bit-equal to the raw grid trace.
    use carbonedge::carbon::{DeferralPolicy, IntensityTrace};
    use carbonedge::microgrid::{
        BatterySpec, ChargePolicy, Microgrid, MicrogridSpec, NodeDraw, PvProfile,
    };
    use carbonedge::util::proptest::check;
    check(
        "project first sample == advert, SoC in [0,1], grid-equal when bare",
        120,
        |rng| {
            let trace = IntensityTrace::from_samples(
                (0..6).map(|i| (i as f64 * 500.0, rng.range(50.0, 900.0))).collect(),
            )
            .unwrap();
            let pv_peak = if rng.f64() < 0.5 { 0.0 } else { rng.range(10.0, 400.0) };
            let batt_wh = if rng.f64() < 0.5 { 0.0 } else { rng.range(1.0, 600.0) };
            let spec = MicrogridSpec {
                pv: PvProfile::diurnal(pv_peak),
                battery: BatterySpec {
                    capacity_wh: batt_wh,
                    max_charge_w: rng.range(10.0, 600.0),
                    max_discharge_w: rng.range(10.0, 600.0),
                    rt_efficiency: rng.range(0.5, 1.0),
                    initial_soc: rng.f64(),
                },
                charge: if rng.f64() < 0.5 {
                    ChargePolicy::Off
                } else {
                    ChargePolicy::Threshold {
                        percentile: rng.range(0.1, 0.9),
                        window_s: rng.range(600.0, 5_000.0),
                    }
                },
            };
            let draw = NodeDraw {
                standing_w: rng.range(0.0, 300.0),
                task_w: rng.range(1.0, 200.0),
                rated_w: 142.0,
            };
            let t0 = rng.range(0.0, 2_000.0);
            let horizon = t0 + rng.range(0.0, 3_000.0);
            let resolution = rng.range(30.0, 600.0);
            (trace, spec, draw, t0, horizon, resolution)
        },
        |(trace, spec, draw, t0, horizon, resolution)| {
            let mg = Microgrid::new(spec.clone());
            let proj = mg.project(*t0, *horizon, *draw, trace, *resolution, 60.0);
            // Slot grid identical to the policy walk.
            let policy = DeferralPolicy { resolution_s: *resolution, min_gain: 0.05 };
            let walk = policy.forecast(|t| trace.at(t), *t0, *horizon);
            if proj.len() != walk.len() {
                return Err(format!("slot grids differ: {} vs {}", proj.len(), walk.len()));
            }
            for (&(tp, eff, soc), &(tw, _)) in proj.iter().zip(&walk) {
                if tp != tw {
                    return Err(format!("slot {tp} vs walk {tw}"));
                }
                if !(0.0..=1.0 + 1e-9).contains(&soc) {
                    return Err(format!("SoC {soc} out of [0, 1] at t={tp}"));
                }
                if !eff.is_finite() || eff < 0.0 {
                    return Err(format!("bad intensity {eff} at t={tp}"));
                }
            }
            // First sample is the instantaneous advertised price.
            let mut advert = mg.clone();
            let want = advert.advertised_intensity(trace, *t0, *draw, 60.0);
            if proj[0].1 != want {
                return Err(format!("first sample {} != advert {want}", proj[0].1));
            }
            // project is pure.
            if mg.soc_frac() != Microgrid::new(spec.clone()).soc_frac() {
                return Err("project mutated the live store".into());
            }
            // Bare microgrid: bit-equal to the raw trace.
            let bare = Microgrid::new(MicrogridSpec {
                pv: PvProfile::none(),
                battery: BatterySpec::none(),
                charge: ChargePolicy::Off,
                discharge: DischargePolicy::Greedy,
            });
            for (t, eff, soc) in bare.project(*t0, *horizon, *draw, trace, *resolution, 60.0) {
                if eff != trace.at(t) || soc != 0.0 {
                    return Err(format!("bare projection diverged at t={t}: {eff}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn frozen_forecasts_change_nothing_without_microgrid_deferral_overlap() {
    // Shim-equivalence extended across the scenario library: the
    // charge-frozen twin replays bit-for-bit unless a scenario has BOTH
    // battery-backed microgrids and deferral (only `arbitrage` today) —
    // the trajectory rewrite is surgical. PV-only microgrids under
    // deferral (`follow-the-sun`) sit in between: frozen forecasts
    // average the standing draw where trajectory samples price the
    // marginal watt, so the twins may or may not coincide depending on
    // load collisions — neither direction is an invariant, skip them.
    for name in scenarios::SCENARIO_NAMES {
        let sc = scenarios::build(name, 0, 1_500, 7).unwrap();
        let has_battery =
            sc.microgrids.iter().flatten().any(|m| m.battery.capacity_wh > 0.0);
        let has_microgrid = sc.microgrids.iter().any(Option::is_some);
        if has_microgrid && !has_battery && sc.config.deferral.is_some() {
            continue;
        }
        let overlap = has_battery && sc.config.deferral.is_some();
        let frozen = scenarios::charge_frozen_twin(&sc);
        let mut a = green_run(&sc);
        let mut b = green_run(&frozen);
        // The twin renames itself, and only the trajectory run records the
        // soc_projection diagnostic: strip both so the comparison (either
        // direction) is about genuine scheduling behaviour.
        b.scenario = a.scenario.clone();
        for n in a.nodes.iter_mut().chain(b.nodes.iter_mut()) {
            n.soc_projection.clear();
        }
        if overlap {
            assert_ne!(a, b, "{name}: frozen twin should genuinely differ");
            assert_ne!(
                a.deferred, b.deferred,
                "{name}: forecast modes should produce different defer verdicts"
            );
        } else {
            a.scenario = String::new();
            b.scenario = String::new();
            assert_eq!(a, b, "{name}: frozen flag leaked into a non-overlap scenario");
        }
    }
}

#[test]
fn arbitrage_beats_charge_off_and_charge_frozen_twins() {
    // The ISSUE 5 acceptance gate, margins validated against the exact
    // xoshiro/splitmix64 engine replica: on the arbitrage scenario under
    // defer-green (4000 requests, seed 7), grid-charge arbitrage plus
    // SoC-trajectory forecasting must complete everything with no missed
    // deadlines, cut gCO₂/req well below the charge-off twin (replica:
    // ≈0.74×) and strictly below the charge-frozen twin (replica:
    // ≈0.98×), with the stored-carbon ledger balancing.
    let sc = scenarios::build("arbitrage", 0, 4_000, 7).unwrap();
    let (arb, off, frozen) = exp::sim_arbitrage_comparison(&sc);
    for r in [&arb, &off, &frozen] {
        assert_eq!(r.scheduler, "defer-green");
        assert_eq!(r.requests, 4_000);
        assert_eq!(r.completed, 4_000, "{}: must complete everything", r.scenario);
        assert_eq!(r.rejected, 0, "{}", r.scenario);
        assert_eq!(r.deadline_missed, 0, "{}: no missed deadlines", r.scenario);
        assert!(r.deferred > 500, "{}: duck curve should park work", r.scenario);
    }
    // Arbitrage buys clean night energy and spends it against the duck
    // evening: a decisive cut vs the charge-off twin.
    assert!(
        arb.carbon_per_req_g < 0.9 * off.carbon_per_req_g,
        "arbitrage {} g/req vs charge-off {} g/req",
        arb.carbon_per_req_g,
        off.carbon_per_req_g
    );
    // SoC-trajectory forecasts stop the frozen view from deferring onto
    // batteries that are empty by the release slot: strictly lower.
    assert!(
        arb.carbon_per_req_g < frozen.carbon_per_req_g,
        "trajectory {} g/req vs charge-frozen {} g/req",
        arb.carbon_per_req_g,
        frozen.carbon_per_req_g
    );
    assert_ne!(arb.deferred, frozen.deferred, "forecast modes must verdict differently");
    // The charge flows are real and honestly accounted.
    assert!(arb.energy_grid_charge_kwh_total > 0.0);
    assert!(arb.carbon_charged_g_total > 0.0);
    assert!(arb.carbon_battery_g_total > 0.0, "evening discharge must bill embodied carbon");
    assert!(
        (arb.carbon_charged_g_total
            - arb.carbon_battery_g_total
            - arb.carbon_stored_g_total)
            .abs()
            <= 1e-6 * arb.carbon_charged_g_total,
        "stored-carbon ledger unbalanced"
    );
    assert_eq!(off.energy_grid_charge_kwh_total, 0.0);
    assert_eq!(off.carbon_charged_g_total, 0.0);
    // Projected-vs-actual SoC diagnostics ride on the trajectory runs.
    assert!(arb.nodes.iter().all(|n| !n.soc_projection.is_empty()));
    assert!(frozen.nodes.iter().all(|n| n.soc_projection.is_empty()));
    // Deterministic A/B/C: the comparison replays bit-for-bit.
    let (arb2, off2, frozen2) = exp::sim_arbitrage_comparison(&sc);
    assert_eq!(arb, arb2);
    assert_eq!(off, off2);
    assert_eq!(frozen, frozen2);
    // The render never prints NaN and names both margins.
    let rendered = exp::sim_arbitrage_render(&arb, &off, &frozen);
    assert!(!rendered.contains("NaN"), "{rendered}");
    assert!(rendered.contains("arbitrage cuts gCO2/req"));
    assert!(rendered.contains("SoC-trajectory forecasts cut"));
}

#[test]
fn trajectory_forecasts_do_not_regress_solar_battery_deferral() {
    // The ISSUE 5 acceptance gate on solar-battery: with deferral enabled
    // (4 h slack) and the green gate, SoC-trajectory forecasting must
    // yield gCO₂/req ≤ the charge-frozen twin (replica: ≈0.99996× — an
    // equality-class outcome; the strict win is pinned on arbitrage) with
    // zero missed deadlines on both sides.
    let mut sc = scenarios::build("solar-battery", 0, 4_000, 19).unwrap();
    sc.config.deferral = Some(carbonedge::sim::DeferralSpec {
        slack_s: 14_400.0,
        headroom_s: 900.0,
        policy: carbonedge::carbon::DeferralPolicy::default(),
    });
    let frozen = scenarios::charge_frozen_twin(&sc);
    let traj = green_run(&sc);
    let froz = green_run(&frozen);
    assert_eq!(traj.completed, 4_000);
    assert_eq!(froz.completed, 4_000);
    assert_eq!(traj.deadline_missed, 0);
    assert_eq!(froz.deadline_missed, 0);
    assert!(traj.deferred > 0, "slack over a PV day should park some work");
    assert!(
        traj.carbon_per_req_g <= froz.carbon_per_req_g * (1.0 + 5e-3),
        "trajectory {} g/req regressed vs frozen {} g/req",
        traj.carbon_per_req_g,
        froz.carbon_per_req_g
    );
}

#[test]
fn carbon_aware_routing_follows_charge_on_microgrid_fleet() {
    // Half the fleet (even indices) sits behind charged batteries +
    // staggered PV: green mode reads their near-zero blended effective
    // intensity through the override and concentrates load there, beating
    // carbon-agnostic round-robin on gCO₂/req.
    let sc = scenarios::build("microgrid-fleet", 0, 6_000, 23).unwrap();
    let green = green_run(&sc);
    let mut rr_sched = carbonedge::scheduler::RoundRobinScheduler::new();
    let rr = Simulation::run(&sc, &mut rr_sched);
    assert_eq!(green.completed + green.rejected, 6_000);
    assert_eq!(rr.completed + rr.rejected, 6_000);
    let mg_share = |r: &carbonedge::sim::SimReport| {
        let mg_tasks: u64 =
            r.nodes.iter().enumerate().filter(|(i, _)| i % 2 == 0).map(|(_, n)| n.tasks).sum();
        mg_tasks as f64 / r.completed.max(1) as f64
    };
    let green_share = mg_share(&green);
    let rr_share = mg_share(&rr);
    assert!(
        green_share > 0.6,
        "green should route toward charged nodes: microgrid share {green_share}"
    );
    assert!(
        green_share > rr_share + 0.05,
        "green {green_share} should concentrate harder than round-robin {rr_share}"
    );
    assert!(
        green.carbon_per_req_g < 0.9 * rr.carbon_per_req_g,
        "green {} g/req vs round-robin {} g/req",
        green.carbon_per_req_g,
        rr.carbon_per_req_g
    );
    // The grid-only twin strips the advantage: green loses its edge there.
    let plain = scenarios::microgrid_disabled_twin(&sc);
    let green_plain = green_run(&plain);
    assert!(
        green.carbon_per_req_g < green_plain.carbon_per_req_g,
        "local supply must lower green's own footprint"
    );
}

#[test]
fn batch1_shim_reproduces_one_per_slot_bit_for_bit() {
    // The refactor's keystone: `window 0 × max_batch 1` routes every
    // request through the batched machinery — formation queues, seals,
    // `BatchComplete`, per-batch energy apportionment — yet replays the
    // legacy one-task-per-slot run bit for bit on every scenario in the
    // library: same RNG draw order, ×1.0/÷1.0 energy arithmetic, and the
    // b = 1 early-returns in the latency/power curves.
    for name in scenarios::SCENARIO_NAMES {
        let mut plain = scenarios::build(name, 0, 2_000, 13).unwrap();
        let mut shim = plain.clone();
        plain.config.batching = None;
        shim.config.batching = Some(BatchSpec { window_ms: 0.0, max_batch: 1 });
        let a = green_run(&plain);
        let b = green_run(&shim);
        assert_eq!(a, b, "{name}: batch=1 shim diverged from one-per-slot service");
    }
}

#[test]
fn per_class_rows_conserve_fleet_totals() {
    for name in scenarios::SCENARIO_NAMES {
        let sc = scenarios::build(name, 0, 2_000, 17).unwrap();
        let r = green_run(&sc);
        if sc.config.workload.is_none() {
            assert!(r.classes.is_empty(), "{name}: class rows without a mix");
            continue;
        }
        assert!(!r.classes.is_empty(), "{name}: mix configured but no class rows");
        let (completed, slo_missed, energy_kwh, carbon_g) = r.class_sums();
        assert_eq!(completed, r.completed, "{name}: class completion conservation");
        assert!(slo_missed <= completed, "{name}: more misses than completions");
        assert!(
            (energy_kwh - r.energy_dynamic_kwh_total).abs()
                <= 1e-9 * r.energy_dynamic_kwh_total.max(1e-30),
            "{name}: class energy {energy_kwh} != dynamic total {}",
            r.energy_dynamic_kwh_total
        );
        // Class carbon is attributed at completion time; a microgrid
        // node's dynamic carbon is instead settled slice-by-slice into
        // the node ledger, so exact equality is a grid-only property.
        if sc.microgrids.iter().all(|m| m.is_none()) {
            assert!(
                (carbon_g - r.carbon_dynamic_g_total).abs()
                    <= 1e-9 * r.carbon_dynamic_g_total.max(1e-30),
                "{name}: class carbon {carbon_g} != dynamic total {}",
                r.carbon_dynamic_g_total
            );
        } else {
            assert!(
                carbon_g <= r.carbon_dynamic_g_total + 1e-9,
                "{name}: class carbon exceeds the fleet's dynamic total"
            );
        }
        let lat_n: usize = r.classes.iter().map(|c| c.latency_ms.n).sum();
        assert_eq!(lat_n as u64, r.completed, "{name}: class latency sample conservation");
        for c in &r.classes {
            assert!(c.slo_missed <= c.completed, "{name}/{}", c.name);
            assert!(c.batches <= c.completed, "{name}/{}: fill below one", c.name);
            if sc.config.batching.is_none() {
                assert_eq!(c.batches, 0, "{name}/{}: batches without batching", c.name);
            }
        }
    }
}

#[test]
fn batched_serving_beats_one_per_slot_on_carbon_and_p99() {
    // The ISSUE 7 acceptance gate: under the same three-tier mix at 1.3×
    // one-per-slot capacity, batched green scheduling must beat the
    // unbatched twin on gCO₂/req at equal-or-better p99 latency
    // (ROADMAP's stated bar), with per-class SLO miss counts reported.
    let sc = scenarios::build("batch-serving", 0, 4_000, 7).unwrap();
    let (batched, unbatched) = exp::sim_batching_comparison(&sc);
    assert_eq!(batched.requests, 4_000);
    assert_eq!(unbatched.requests, 4_000);
    // Per-class rows with SLO miss counts on both sides of the A/B.
    assert_eq!(batched.classes.len(), 3);
    assert_eq!(unbatched.classes.len(), 3);
    for r in [&batched, &unbatched] {
        let (completed, _, _, _) = r.class_sums();
        assert_eq!(completed, r.completed, "{}: class conservation", r.scenario);
        assert!(r.classes.iter().all(|c| c.slo_s.is_finite()));
    }
    // Batching genuinely forms multi-task batches under overload; the
    // twin never seals any.
    let batches: u64 = batched.classes.iter().map(|c| c.batches).sum();
    assert!(batches > 0, "no batches sealed");
    let mean_fill = batched.completed as f64 / batches as f64;
    assert!(mean_fill > 1.25, "mean fill {mean_fill} barely above one-per-slot");
    assert!(unbatched.classes.iter().all(|c| c.batches == 0));
    // The overloaded one-per-slot twin sheds load; batching absorbs more
    // of the same arrival stream.
    assert!(
        batched.completed > unbatched.completed,
        "batched completed {} vs one-per-slot {}",
        batched.completed,
        unbatched.completed
    );
    // gCO₂/req: a strict win with margin — more completions against the
    // same idle floors, sub-linear batch power, amortized overhead.
    assert!(
        batched.carbon_per_req_g < 0.97 * unbatched.carbon_per_req_g,
        "batched {} g/req vs one-per-slot {} g/req",
        batched.carbon_per_req_g,
        unbatched.carbon_per_req_g
    );
    // p99: equal or better — a fill-k slot drains its queue ~k^0.2
    // faster, and the 200 ms window is a fraction of one inference.
    assert!(
        batched.latency_ms.p99 <= unbatched.latency_ms.p99,
        "batched p99 {} ms vs one-per-slot {} ms",
        batched.latency_ms.p99,
        unbatched.latency_ms.p99
    );
    // Determinism by equality: the A/B replays bit for bit.
    let (b2, u2) = exp::sim_batching_comparison(&sc);
    assert_eq!(batched, b2);
    assert_eq!(unbatched, u2);
    // The render names the margin and never prints NaN.
    let rendered = exp::sim_batching_render(&batched, &unbatched);
    assert!(!rendered.contains("NaN"), "{rendered}");
    assert!(rendered.contains("batch formation cuts gCO2/req"), "{rendered}");
}

#[test]
fn deep_forming_queue_flips_defer_under_demand_aware_projections() {
    // Demand-aware projection regression: one battery-backed node whose
    // only service slot sits free behind a forming batch. The legacy
    // projection prices the marginal task against the idle floor alone —
    // the (embodied-zero) battery covers it, effective intensity 0,
    // nothing to defer for. Folding the queued backlog into the standing
    // draw claims the battery, the marginal task lands on the 500 g/kWh
    // grid with a 100 g/kWh slot an affordable wait away, and the
    // verdict flips to defer.
    let build = |aware: bool| Scenario {
        name: "defer-flip".into(),
        specs: vec![NodeSpec {
            name: "mg".into(),
            cpu_quota: 1.0,
            mem_mb: 1024,
            intensity: 500.0,
            rated_power_w: 98.0,
            idle_w: 10.0,
            prior_ms: 250.0,
            alpha: 0.0,
            overhead_ms: 8.0,
            time_scale: 20.6,
            adaptive: false,
            batch_gamma: 0.8,
            batch_beta: 0.2,
        }],
        traces: vec![IntensityTrace::Trace(vec![(0.0, 500.0), (1_200.0, 100.0)])],
        capacity: vec![1],
        arrivals: ArrivalProcess::Uniform { rate_hz: 1.0 },
        requests: 4,
        churn: Vec::new(),
        // 120 Wh at 1C: the 120 W discharge rate covers idle + one task
        // (98 W) but not idle + projected backlog + the marginal task.
        microgrids: vec![Some(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec::simple(120.0, 1.0, 1.0),
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        })],
        sites: None,
        config: SimConfig {
            seed: 5,
            jitter_sigma: 0.0,
            deferral: Some(DeferralSpec {
                slack_s: 1_300.0,
                headroom_s: 60.0,
                policy: DeferralPolicy { resolution_s: 300.0, min_gain: 0.05 },
            }),
            // A wide-open formation window: arrivals 2-4 decide while
            // arrival 1 is still forming (slot free, queue non-empty) —
            // exactly where the two projections diverge.
            batching: Some(BatchSpec { window_ms: 30_000.0, max_batch: 8 }),
            demand_aware_projections: aware,
            ..SimConfig::default()
        },
    };
    let run = |sc: &Scenario| {
        let mut s = DeferAwareGreenScheduler::new(0.05);
        Simulation::run(sc, &mut s)
    };
    let legacy = run(&build(false));
    let aware = run(&build(true));
    assert_eq!(legacy.completed, 4);
    assert_eq!(aware.completed, 4);
    // Legacy projection: the battery covers the marginal watt right now,
    // and nothing in the forecast beats an effective intensity of zero.
    assert_eq!(legacy.deferred, 0, "legacy projection should run everything now");
    // Demand-aware: every arrival that sees the forming backlog parks to
    // the clean slot (the first never sees one, so it runs now).
    assert_eq!(aware.deferred, 3, "deep queue must flip the verdict to defer");
    assert_eq!(aware.deadline_missed, 0);
    assert_eq!(legacy.deadline_missed, 0);
}

#[test]
fn admission_sheds_lowest_priority_first_under_sustained_overload() {
    // ISSUE 9 satellite: class-aware admission control. The three-tenant
    // mix at 5x capacity with a 2 s shed budget: priority p tolerates
    // 2 x (1 + p) seconds of estimated queue delay, so best-effort
    // `generate` (p0) sheds hardest, `embed` (p1) next, and interactive
    // `vision-small` (p2) least.
    let mut sc = scenarios::build("multi-tenant", 0, 3_000, 11).unwrap();
    sc.arrivals = ArrivalProcess::Poisson { rate_hz: 5.0 * sc.arrivals.mean_rate_hz() };
    sc.config.admission = Some(AdmissionSpec { shed_queue_s: 2.0 });
    sc.validate().unwrap();
    let r = green_run(&sc);
    assert!(r.rejected > 0, "sustained overload must shed");
    assert_eq!(r.classes.len(), 3);
    // Per-class rejected rows partition the fleet's rejected counter.
    let shed: u64 = r.classes.iter().map(|c| c.rejected).sum();
    assert_eq!(shed, r.rejected, "class rejected rows must partition the total");
    // Reject *rates* order strictly by priority (arrival weights differ,
    // so raw counts would conflate mix share with shedding).
    let rate = |name: &str| {
        let c = r.classes.iter().find(|c| c.name == name).unwrap();
        c.rejected as f64 / (c.completed + c.rejected).max(1) as f64
    };
    let (generate, embed, vision) = (rate("generate"), rate("embed"), rate("vision-small"));
    assert!(
        generate > embed && embed > vision,
        "shed rates must order by priority: generate {generate:.3} > embed {embed:.3} > \
         vision-small {vision:.3}"
    );
    // Deterministic: the shed pattern replays bit for bit.
    assert_eq!(green_run(&sc), r);
}

#[test]
fn site_rows_partition_fleet_totals_on_geo_scenarios() {
    // ISSUE 9 satellite: per-site energy (member idle + dynamic + WAN
    // transfer) must sum to the fleet totals — sites are a partition, not
    // a sample.
    for name in ["multi-site", "follow-the-sun"] {
        let sc = scenarios::build(name, 0, 2_000, 17).unwrap();
        let r = green_run(&sc);
        assert_eq!(r.sites.len(), 3, "{name}: three regional sites");
        assert!(!r.router.is_empty(), "{name}: router must be named");
        let (completed, shipped_out, energy, carbon, wan_kwh, wan_g) = r.site_sums();
        assert_eq!(completed, r.completed, "{name}: site completion conservation");
        assert_eq!(shipped_out, r.wan_shipped, "{name}: shipped-out conservation");
        assert!(
            (energy - r.energy_kwh_total).abs() <= 1e-6 * r.energy_kwh_total.max(1e-30),
            "{name}: site energy {energy} != fleet {}",
            r.energy_kwh_total
        );
        assert!(
            (carbon - r.carbon_g_total).abs() <= 1e-6 * r.carbon_g_total.max(1e-30),
            "{name}: site carbon {carbon} != fleet {}",
            r.carbon_g_total
        );
        assert!((wan_kwh - r.energy_wan_kwh_total).abs() <= 1e-12, "{name}: wan energy total");
        assert!((wan_g - r.carbon_wan_g_total).abs() <= 1e-12, "{name}: wan carbon total");
    }
    // Flat fleets stay flat: no site rows, no router, no WAN counters.
    let r = green_run(&scenarios::build("paper-3-node", 0, 200, 7).unwrap());
    assert!(r.sites.is_empty());
    assert!(r.router.is_empty());
    assert_eq!(r.wan_shipped, 0);
    assert_eq!(r.energy_wan_kwh_total, 0.0);
}

#[test]
fn follow_the_sun_beats_every_single_site_green_baseline() {
    // The ISSUE 9 acceptance gate: on `follow-the-sun` the deadline
    // router's gCO2/req must come in under 0.9x the best single-site
    // green twin with zero missed deadlines, deterministically.
    let sc = scenarios::build("follow-the-sun", 0, 3_000, 7).unwrap();
    let multi = green_run(&sc);
    assert_eq!(multi.router, "deadline");
    assert!(multi.wan_shipped > 0, "follow-the-sun must ship work across sites");
    assert_eq!(multi.deadline_missed, 0, "cross-site shifting may not cost deadlines");
    assert_eq!(green_run(&sc), multi, "the geo run must replay bit for bit");
    // The best single-region twin: the same demand forced through one
    // site's nodes, PV and grid — green scheduling, same deferral knobs.
    let n_sites = sc.sites.as_ref().unwrap().sites.len();
    let best = (0..n_sites)
        .map(|s| green_run(&scenarios::single_site_twin(&sc, s)).carbon_per_req_g)
        .fold(f64::INFINITY, f64::min);
    assert!(best.is_finite() && best > 0.0);
    assert!(
        multi.carbon_per_req_g < 0.9 * best,
        "follow-the-sun {} g/req must beat 0.9x best single-site {} g/req",
        multi.carbon_per_req_g,
        best
    );
}
