//! Multi-model evaluation (the paper's Table IV scenario): compare
//! Monolithic vs CE-Green across the whole model zoo to demonstrate the
//! framework generalizes across architectures.
//!
//! ```sh
//! cargo run --release --example multi_model -- [--iters 20]
//! ```

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;
use carbonedge::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let iters = args.parse_or("iters", 20usize)?;

    let coord = Coordinator::new(Config::default())?;
    let models: Vec<String> = coord.manifest.models.keys().cloned().collect();
    let refs: Vec<&str> = models.iter().map(String::as_str).collect();
    println!(
        "evaluating {} architectures x (Monolithic, CE-Green), {iters} inferences each",
        refs.len()
    );

    let rows = exp::table4(&coord, &refs, iters, 1)?;
    println!("{}", exp::table4_render(&rows));

    // Generalizability check mirroring the paper's claim (14.8%–32.2%).
    let reductions: Vec<f64> = rows.iter().map(|r| r.green.reduction_vs(&r.mono)).collect();
    let min = reductions.iter().cloned().fold(f64::MAX, f64::min);
    let max = reductions.iter().cloned().fold(f64::MIN, f64::max);
    println!("carbon reduction across architectures: {:.1}%..{:.1}%", min * 100.0, max * 100.0);
    Ok(())
}
