//! §Perf helper: time raw monolithic PJRT execution for an artifact
//! directory (used for the L1 tile-size A/B in EXPERIMENTS.md §Perf).
//!
//! ```sh
//! cargo run --release --example perf_exec -- --artifacts artifacts_t256
//! ```

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::util::bench::{black_box, Bencher};
use carbonedge::util::cli::Args;
use carbonedge::workload::synthetic_image;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let mut cfg = Config::default();
    cfg.artifacts_dir = args.str_or("artifacts", "artifacts");
    let model_name = args.str_or("model", "mobilenet_v2");
    let coord = Coordinator::new(cfg)?;
    let model = coord.load_model(&model_name)?;
    let exec = coord.exec();
    exec.register("perf", &model.monolithic_path(), model.all_weights(), true)?;
    let input = synthetic_image(coord.manifest.image_size, 0);
    exec.execute("perf", input.clone())?; // warmup
    let b = Bencher::default();
    let r = b.run(&format!("exec/{}/{}", coord.cfg.artifacts_dir, model_name), || {
        black_box(exec.execute("perf", input.clone()).unwrap());
    });
    println!("{}", r.report());
    Ok(())
}
