//! Green Partitioning Strategy (paper Sec. I / III-E): when distributing a
//! model across nodes, weigh each node's share by both compute capacity and
//! carbon intensity, tunable by the mode's carbon weight.

use std::sync::Arc;

use crate::node::EdgeNode;

use super::{partition_by_shares, Partition};

/// Compute per-node shares mixing speed and greenness.
///
/// `carbon_weight` ∈ [0,1]: 0 -> shares proportional to CPU quota (pure
/// performance balancing, the AMP4EC behaviour); 1 -> shares proportional
/// to inverse carbon intensity (pure green).
pub fn green_shares(nodes: &[Arc<EdgeNode>], carbon_weight: f64) -> Vec<f64> {
    assert!(!nodes.is_empty());
    assert!((0.0..=1.0).contains(&carbon_weight));
    let quota_sum: f64 = nodes.iter().map(|n| n.spec.cpu_quota).sum();
    let inv_int: Vec<f64> = nodes.iter().map(|n| 1.0 / n.spec.intensity.max(1.0)).collect();
    let inv_sum: f64 = inv_int.iter().sum();
    nodes
        .iter()
        .zip(&inv_int)
        .map(|(n, inv)| {
            (1.0 - carbon_weight) * (n.spec.cpu_quota / quota_sum) + carbon_weight * (inv / inv_sum)
        })
        .collect()
}

/// The green partitioner: stage costs + node fleet -> contiguous partition.
pub struct GreenPartitioner {
    pub carbon_weight: f64,
}

impl GreenPartitioner {
    pub fn new(carbon_weight: f64) -> GreenPartitioner {
        GreenPartitioner { carbon_weight }
    }

    pub fn partition(&self, stage_costs: &[u64], nodes: &[Arc<EdgeNode>]) -> Partition {
        let shares = green_shares(nodes, self.carbon_weight);
        partition_by_shares(stage_costs, &shares)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeRegistry;
    use crate::util::proptest::check;

    #[test]
    fn shares_sum_to_one() {
        let r = NodeRegistry::paper_setup();
        for w in [0.0, 0.3, 0.5, 1.0] {
            let s = green_shares(r.nodes(), w);
            assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-9, "w={w}");
        }
    }

    #[test]
    fn performance_shares_follow_quota() {
        let r = NodeRegistry::paper_setup(); // quotas 1.0/0.6/0.4
        let s = green_shares(r.nodes(), 0.0);
        assert!((s[0] - 0.5).abs() < 1e-9);
        assert!((s[1] - 0.3).abs() < 1e-9);
        assert!((s[2] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn green_weight_shifts_share_to_low_carbon() {
        let r = NodeRegistry::paper_setup();
        let perf = green_shares(r.nodes(), 0.0);
        let green = green_shares(r.nodes(), 1.0);
        // node-green (index 2, 380 g/kWh) must gain share as w rises.
        assert!(green[2] > perf[2]);
        // node-high (620 g/kWh) must lose share.
        assert!(green[0] < perf[0]);
        // monotone in between
        let mid = green_shares(r.nodes(), 0.5);
        assert!(mid[2] > perf[2] && mid[2] < green[2]);
    }

    #[test]
    fn partitioner_produces_valid_groups() {
        let r = NodeRegistry::paper_setup();
        let costs = [100, 300, 250, 400];
        for w in [0.0, 0.5, 1.0] {
            let p = GreenPartitioner::new(w).partition(&costs, r.nodes());
            assert!(p.is_valid());
            assert_eq!(p.n_groups(), 3);
        }
    }

    #[test]
    fn prop_share_monotonicity_in_carbon_weight() {
        // The greenest node's share is non-decreasing in carbon_weight.
        check(
            "greenest share monotone",
            100,
            |rng| (rng.range(0.0, 1.0), rng.range(0.0, 1.0)),
            |&(w1, w2)| {
                let (lo, hi) = if w1 <= w2 { (w1, w2) } else { (w2, w1) };
                let r = NodeRegistry::paper_setup();
                let greenest = 2; // lowest intensity in paper setup
                let a = green_shares(r.nodes(), lo)[greenest];
                let b = green_shares(r.nodes(), hi)[greenest];
                if b + 1e-12 >= a {
                    Ok(())
                } else {
                    Err(format!("share decreased: {a} -> {b} (w {lo} -> {hi})"))
                }
            },
        );
    }
}
