//! Model registry: parses `artifacts/manifest.json` (written by aot.py)
//! into typed structures, loads weight sidecars, and exposes the per-layer
//! cost tables the partitioner consumes.

mod manifest;

pub use manifest::{
    GoldenRecord, LayerEntry, Manifest, ModelEntry, StageEntry, WeightEntry,
};

use anyhow::{Context, Result};

use crate::runtime::Tensor;

/// A model plus its artifact directory, ready to register with the executor.
pub struct LoadedModel {
    pub entry: ModelEntry,
    pub dir: String,
    /// Per-stage weight tensors, in HLO argument order.
    pub stage_weights: Vec<Vec<Tensor>>,
}

impl LoadedModel {
    /// Load the packed weights and slice them per stage.
    pub fn load(dir: &str, entry: &ModelEntry) -> Result<LoadedModel> {
        let wpath = format!("{dir}/{}", entry.weights_file);
        let bytes = std::fs::read(&wpath).with_context(|| format!("reading {wpath}"))?;
        let flat = crate::runtime::f32_from_le_bytes(&bytes)?;
        anyhow::ensure!(
            flat.len() == entry.weights_total,
            "weights file {} has {} f32s, manifest says {}",
            wpath,
            flat.len(),
            entry.weights_total
        );
        let mut stage_weights: Vec<Vec<Tensor>> = vec![Vec::new(); entry.stages.len()];
        for w in &entry.weights {
            let t = Tensor::from_flat(&flat, w.offset, w.shape.clone())?;
            stage_weights[w.stage].push(t);
        }
        for (si, s) in entry.stages.iter().enumerate() {
            anyhow::ensure!(
                stage_weights[si].len() == s.num_weights,
                "stage {si} expects {} weights, packed {}",
                s.num_weights,
                stage_weights[si].len()
            );
        }
        Ok(LoadedModel { entry: entry.clone(), dir: dir.to_string(), stage_weights })
    }

    /// All weights in monolithic-program argument order.
    pub fn all_weights(&self) -> Vec<Tensor> {
        self.stage_weights.iter().flatten().cloned().collect()
    }

    pub fn monolithic_path(&self) -> String {
        format!("{}/{}", self.dir, self.entry.monolithic)
    }

    pub fn stage_path(&self, i: usize) -> String {
        format!("{}/{}", self.dir, self.entry.stages[i].artifact)
    }

    /// Golden input image exported by aot.py.
    pub fn golden_input(&self) -> Result<Tensor> {
        Tensor::from_bin_file(
            &format!("{}/{}", self.dir, self.entry.input_file),
            self.entry.input_shape.clone(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn tiny_manifest() -> &'static str {
        r#"{
          "image_size": 8, "num_classes": 4, "version": 1, "width": 0.25,
          "models": {
            "m": {
              "params": 10, "flops": 100, "num_classes": 4,
              "input_shape": [8, 8, 3],
              "monolithic": "m.hlo.txt",
              "weights_file": "m.weights.bin",
              "weights_total": 6,
              "input_file": "m.input.bin",
              "golden": {"seed": 0, "logits8": [1.0, 2.0], "argmax": 1, "logit_sum": 3.0},
              "stages": [
                {"name": "s0", "artifact": "m.stage0.hlo.txt", "in_shape": [8,8,3],
                 "out_shape": [4,4,2], "params": 6, "flops": 60, "cost": 50, "num_weights": 2}
              ],
              "weights": [
                {"stage": 0, "shape": [2, 2], "offset": 0},
                {"stage": 0, "shape": [2], "offset": 4}
              ],
              "layers": [
                {"name": "c1", "kind": "conv2d", "stage": 0, "params": 6, "cost": 50,
                 "flops": 60, "in_shape": [8,8,3], "out_shape": [4,4,2]}
              ]
            }
          }
        }"#
    }

    #[test]
    fn parse_manifest() {
        let m = Manifest::from_json(&Json::parse(tiny_manifest()).unwrap()).unwrap();
        assert_eq!(m.image_size, 8);
        let e = m.models.get("m").unwrap();
        assert_eq!(e.params, 10);
        assert_eq!(e.stages.len(), 1);
        assert_eq!(e.stages[0].out_shape, vec![4, 4, 2]);
        assert_eq!(e.weights[1].offset, 4);
        assert_eq!(e.layers[0].kind, "conv2d");
        assert_eq!(e.golden.argmax, 1);
    }

    #[test]
    fn loaded_model_slices_weights() {
        let dir = std::env::temp_dir().join("ce_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let flat: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let bytes: Vec<u8> = flat.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("m.weights.bin"), &bytes).unwrap();
        let m = Manifest::from_json(&Json::parse(tiny_manifest()).unwrap()).unwrap();
        let lm = LoadedModel::load(dir.to_str().unwrap(), m.models.get("m").unwrap()).unwrap();
        assert_eq!(lm.stage_weights.len(), 1);
        assert_eq!(lm.stage_weights[0][0].data, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(lm.stage_weights[0][1].data, vec![4.0, 5.0]);
        assert_eq!(lm.all_weights().len(), 2);
    }

    #[test]
    fn wrong_total_rejected() {
        let dir = std::env::temp_dir().join("ce_model_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.weights.bin"), [0u8; 8]).unwrap();
        let m = Manifest::from_json(&Json::parse(tiny_manifest()).unwrap()).unwrap();
        assert!(LoadedModel::load(dir.to_str().unwrap(), m.models.get("m").unwrap()).is_err());
    }
}
