//! Bench: regenerate paper Table V (node usage distribution per mode).

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let iters: usize =
        std::env::var("CE_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(15);
    let coord = Coordinator::new(cfg)?;
    let t5 = exp::table5(&coord, "mobilenet_v2", iters)?;
    println!("{}", exp::table5_render(&t5));
    println!("paper Table V shape: Performance/Balanced 100% node-high; Green 100% node-green");
    Ok(())
}
