//! CLI argument-parsing substrate (clap is not in the offline crate set).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! repeated flags, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, flags, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("missing value for --{0}")]
    MissingValue(String),
    #[error("invalid value for --{flag}: {value:?} ({why})")]
    Invalid { flag: String, value: String, why: String },
}

impl Args {
    /// Parse raw args (not including argv[0]). `switches` lists boolean
    /// flags that take no value.
    pub fn parse(raw: &[String], switches: &[&str]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let value = if let Some(v) = inline {
                    v
                } else if switches.contains(&name.as_str()) {
                    "true".to_string()
                } else {
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                        _ => return Err(CliError::MissingValue(name)),
                    }
                };
                out.flags.entry(name).or_default().push(value);
            } else if out.command.is_none() && out.positional.is_empty() && out.flags.is_empty() {
                out.command = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(switches: &[&str]) -> Result<Args, CliError> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, switches)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags.get(name).map(|v| v.iter().map(String::as_str).collect()).unwrap_or_default()
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::Invalid {
                flag: name.into(),
                value: v.into(),
                why: format!("expected {}", std::any::type_name::<T>()),
            }),
        }
    }

    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true" | "1" | "yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_flags() {
        let a =
            Args::parse(&sv(&["serve", "--model", "mobilenet_v2", "--mode=green"]), &[]).unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("mobilenet_v2"));
        assert_eq!(a.get("mode"), Some("green"));
    }

    #[test]
    fn switches_take_no_value() {
        let a = Args::parse(&sv(&["bench", "--verbose", "--n", "5"]), &["verbose"]).unwrap();
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.parse_or::<usize>("n", 0).unwrap(), 5);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["x", "--n"]), &[]).is_err());
        assert!(Args::parse(&sv(&["x", "--n", "--m", "1"]), &[]).is_err());
    }

    #[test]
    fn typed_parse_errors() {
        let a = Args::parse(&sv(&["x", "--n", "abc"]), &[]).unwrap();
        assert!(a.parse_or::<usize>("n", 0).is_err());
        assert_eq!(a.parse_or::<f64>("missing", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn repeated_flags() {
        let a = Args::parse(&sv(&["x", "--m", "a", "--m", "b"]), &[]).unwrap();
        assert_eq!(a.get_all("m"), vec!["a", "b"]);
        assert_eq!(a.get("m"), Some("b")); // last wins
    }

    #[test]
    fn positionals() {
        let a = Args::parse(&sv(&["run", "--x", "1", "p1", "p2"]), &[]).unwrap();
        assert_eq!(a.positional, vec!["p1", "p2"]);
    }

    #[test]
    fn no_command() {
        let a = Args::parse(&sv(&["--x", "1"]), &[]).unwrap();
        assert_eq!(a.command, None);
        assert_eq!(a.get("x"), Some("1"));
    }
}
