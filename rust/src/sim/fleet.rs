//! Fleet synthesis: generate N-node heterogeneous fleets from the
//! [`crate::carbon::REGIONS`] table with seeded parameter spreads, so
//! scheduler sweeps can run against hundreds of nodes that still live in
//! the paper's calibrated parameter regime.

use crate::carbon::REGIONS;
use crate::node::NodeSpec;
use crate::util::rng::Rng;

/// CPU-quota tiers mirroring the paper's high/medium/green containers plus
/// a beefier edge-server class.
const QUOTA_TIERS: [f64; 4] = [1.0, 0.8, 0.6, 0.4];

/// Synthesize `n` node specs. Regions cycle through [`REGIONS`] (so any
/// fleet ≥ 8 nodes spans coal-heavy to nordic-hydro grids); quota, power,
/// prior latency and intensity get seeded spreads around paper-calibrated
/// centers. Deterministic in `(n, seed)`.
pub fn synth_fleet(n: usize, seed: u64) -> Vec<NodeSpec> {
    // lint: allow(P2 one-shot fleet-builder guard)
    assert!(n > 0, "fleet needs at least one node");
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let region = REGIONS[i % REGIONS.len()];
            let quota = QUOTA_TIERS[rng.below(QUOTA_TIERS.len())];
            // Rated power scales with compute class, ±15% part-to-part.
            let rated_power_w = (40.0 + 130.0 * quota) * rng.range(0.85, 1.15);
            // Idle floor at 30% of full load — the edge-box regime GreenScale
            // reports (base power is a large, fixed fraction of peak).
            // Derived, not drawn, so the seeded parameter stream is stable.
            let idle_w = 0.3 * rated_power_w;
            // Capability prior: the paper's node-high does 250 ms at quota
            // 1.0; slower classes scale roughly inversely, ±10%.
            let prior_ms = 250.0 / quota * rng.range(0.9, 1.1);
            NodeSpec {
                name: format!("{}-{i:03}", region.name),
                cpu_quota: quota,
                mem_mb: if quota >= 0.8 { 1024 } else { 512 },
                intensity: region.intensity * rng.range(0.9, 1.1),
                rated_power_w,
                idle_w,
                prior_ms,
                alpha: 0.005,
                overhead_ms: 8.0,
                time_scale: 20.6,
                adaptive: false,
                batch_gamma: 0.8,
                batch_beta: 0.2,
            }
        })
        .collect()
}

/// Per-node service concurrency for a synthesized fleet: full-quota nodes
/// run two requests at once, the rest one.
pub fn capacities(specs: &[NodeSpec]) -> Vec<usize> {
    specs.iter().map(|s| if s.cpu_quota >= 1.0 { 2 } else { 1 }).collect()
}

/// Aggregate service capacity (requests/s) of a fleet under the latency
/// model at `base_exec_ms` — the scale arrival rates are set against.
pub fn service_capacity_hz(specs: &[NodeSpec], capacity: &[usize], base_exec_ms: f64) -> f64 {
    specs
        .iter()
        .zip(capacity)
        .map(|(s, &c)| c as f64 / (s.simulate_latency_ms(base_exec_ms) / 1e3))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_n_and_seed() {
        let a = synth_fleet(20, 3);
        let b = synth_fleet(20, 3);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.intensity, y.intensity);
            assert_eq!(x.rated_power_w, y.rated_power_w);
            assert_eq!(x.prior_ms, y.prior_ms);
        }
        let c = synth_fleet(20, 4);
        assert!(a.iter().zip(&c).any(|(x, y)| x.intensity != y.intensity));
    }

    #[test]
    fn parameters_stay_in_calibrated_regime() {
        for s in synth_fleet(100, 1) {
            assert!((0.4..=1.0).contains(&s.cpu_quota));
            assert!(s.rated_power_w > 30.0 && s.rated_power_w < 220.0, "{}", s.rated_power_w);
            assert!((s.idle_w - 0.3 * s.rated_power_w).abs() < 1e-12);
            assert!(s.dynamic_power_w() > 0.0);
            assert!((200.0..=700.0).contains(&s.prior_ms), "{}", s.prior_ms);
            assert!(s.intensity > 30.0 && s.intensity < 1000.0);
            assert!(s.mem_mb == 512 || s.mem_mb == 1024);
        }
    }

    #[test]
    fn regions_cycle_for_grid_diversity() {
        let fleet = synth_fleet(16, 2);
        let mut prefixes: Vec<&str> =
            fleet.iter().map(|s| s.name.rsplit_once('-').unwrap().0).collect();
        prefixes.sort();
        prefixes.dedup();
        assert_eq!(prefixes.len(), crate::carbon::REGIONS.len());
    }

    #[test]
    fn capacity_and_fleet_rate() {
        let specs = synth_fleet(10, 5);
        let caps = capacities(&specs);
        assert_eq!(caps.len(), 10);
        assert!(caps.iter().all(|&c| c == 1 || c == 2));
        let hz = service_capacity_hz(&specs, &caps, 9.6);
        // 10 nodes at ~200-560 ms per request: single-digit to tens of Hz.
        assert!(hz > 5.0 && hz < 120.0, "{hz}");
    }
}
