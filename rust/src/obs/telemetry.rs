//! In-process telemetry: monotonic event counters and fixed-bucket log2
//! histograms, cheap enough to keep hot during million-request runs.
//!
//! The histograms are power-of-two bucketed ([`Log2Histogram`]): recording
//! is a branch, an `exponent` extraction and one array increment — no
//! allocation, no sorting, O(64) memory per series. Quantiles come back as
//! bucket upper bounds (a ≤2× overestimate worst-case), which is the right
//! trade for an always-on tail monitor; the report's exact `Summary`
//! percentiles remain the precision path.
//!
//! [`Telemetry`] is collected *beside* the [`crate::sim::SimReport`], never
//! inside it: the per-decision overhead series is wall-clock and would
//! break the determinism-by-equality invariant (identical seeds ⇒ identical
//! reports) if it lived in the report struct.

use std::io;

use crate::util::json::JsonWriter;

use super::{EventKind, MonitorSummary};

/// The paper's scheduling-overhead envelope: 0.03 ms per decision, in ns.
/// [`Telemetry::render`] and the bench/test guards compare against it.
pub const OVERHEAD_ENVELOPE_NS: f64 = 30_000.0;

/// Bucket offset: bucket `i` holds values in `[2^(i-32), 2^(i-31))`, so the
/// 64 buckets cover ~4.7e-10 .. 4.3e9 — nanoseconds up to seconds, and
/// milliseconds from sub-microsecond to weeks.
const LOG2_OFFSET: i32 = 32;

/// Fixed 64-bucket power-of-two histogram.
#[derive(Debug, Clone)]
pub struct Log2Histogram {
    buckets: [u64; 64],
    pub count: u64,
    pub sum: f64,
    min: f64,
    max: f64,
}

impl Default for Log2Histogram {
    fn default() -> Log2Histogram {
        Log2Histogram::new()
    }
}

impl Log2Histogram {
    pub fn new() -> Log2Histogram {
        Log2Histogram {
            buckets: [0; 64],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(v: f64) -> usize {
        if v <= 0.0 || !v.is_finite() {
            return 0;
        }
        (v.log2().floor() as i32 + LOG2_OFFSET).clamp(0, 63) as usize
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.buckets[Self::bucket_of(v)] += 1;
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact sample minimum / maximum (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate quantile: the upper bound of the bucket holding the
    /// q-th sample (clamped to the exact max, so `quantile(1.0) == max`).
    pub fn quantile(&self, q: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&q));
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let upper = 2f64.powi(i as i32 - LOG2_OFFSET + 1);
                return upper.min(self.max);
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &Log2Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// `{count, mean, p50, p99, max}` as a JSON object on the stream.
    pub fn write_json<W: io::Write>(&self, j: &mut JsonWriter<W>) -> io::Result<()> {
        j.begin_obj()?;
        j.field_num("count", self.count as f64)?;
        j.field_fnum("mean", self.mean())?;
        j.field_fnum("p50", self.quantile(0.50))?;
        j.field_fnum("p99", self.quantile(0.99))?;
        j.field_fnum("max", self.max())?;
        j.end_obj()
    }
}

/// Per-run telemetry registry: event counters (deterministic — they mirror
/// the virtual-event stream) plus queue-delay / end-to-end latency / per-
/// decision scheduling-overhead histograms. Returned beside the report by
/// [`crate::sim::Simulation::try_run_observed`].
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    /// Events observed per [`EventKind`] (indexed by discriminant),
    /// counted *before* any sink filter — the conservation checks read
    /// these even when the firehose drops kinds.
    pub events: [u64; EventKind::COUNT],
    /// Queue-delay estimate at every dispatch (ms).
    pub queue_delay_ms: Log2Histogram,
    /// End-to-end latency at every completion (ms).
    pub latency_ms: Log2Histogram,
    /// Wall-clock cost of every `Scheduler::decide` call (ns) — the
    /// paper's 0.03 ms overhead envelope, measured in-process.
    pub decide_ns: Log2Histogram,
    /// Per-rule monitor summaries when a [`crate::obs::MonitorSet`] was
    /// attached ([`crate::sim::Simulation::try_run_monitored`]); empty
    /// otherwise. Deterministic — virtual-time only.
    pub monitors: Vec<MonitorSummary>,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    pub fn count(&mut self, kind: EventKind) {
        self.events[kind as usize] += 1;
    }

    pub fn events_of(&self, kind: EventKind) -> u64 {
        self.events[kind as usize]
    }

    pub fn total_events(&self) -> u64 {
        self.events.iter().sum()
    }

    /// Human-readable block appended under the report render.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = write!(out, "telemetry: {} events (", self.total_events());
        for (i, kind) in EventKind::ALL.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "{} {}", kind.label(), self.events_of(*kind));
        }
        out.push_str(")\n");
        for (name, h) in
            [("queue delay (ms)", &self.queue_delay_ms), ("latency (ms)", &self.latency_ms)]
        {
            let _ = writeln!(
                out,
                "  {name:<18} mean {:.3}  p50 <= {:.3}  p99 <= {:.3}  max {:.3}  (n={})",
                h.mean(),
                h.quantile(0.50),
                h.quantile(0.99),
                h.max(),
                h.count
            );
        }
        let d = &self.decide_ns;
        let _ = writeln!(
            out,
            "  decide overhead    mean {:.0} ns  p99 <= {:.0} ns  max {:.0} ns  \
             (envelope {OVERHEAD_ENVELOPE_NS:.0} ns = 0.03 ms, n={})",
            d.mean(),
            d.quantile(0.99),
            d.max(),
            d.count
        );
        for m in &self.monitors {
            let first = m
                .first_alert_s
                .map(|t| format!("first at t={t:.1}s"))
                .unwrap_or_else(|| "never fired".into());
            let _ = writeln!(
                out,
                "  monitor {:<12} {} alerts ({first})  peak {:.4} vs threshold {:.4}  \
                 window {:.0}s",
                m.rule, m.alerts, m.peak, m.threshold, m.window_s
            );
        }
        out
    }

    /// The whole registry as one JSON object on the stream.
    pub fn write_json<W: io::Write>(&self, j: &mut JsonWriter<W>) -> io::Result<()> {
        j.begin_obj()?;
        j.key("events")?;
        j.begin_obj()?;
        for kind in EventKind::ALL {
            j.field_num(kind.label(), self.events_of(kind) as f64)?;
        }
        j.end_obj()?;
        j.key("queue_delay_ms")?;
        self.queue_delay_ms.write_json(j)?;
        j.key("latency_ms")?;
        self.latency_ms.write_json(j)?;
        j.key("decide_ns")?;
        self.decide_ns.write_json(j)?;
        j.field_num("overhead_envelope_ns", OVERHEAD_ENVELOPE_NS)?;
        if !self.monitors.is_empty() {
            j.key("monitors")?;
            j.begin_arr()?;
            for m in &self.monitors {
                j.begin_obj()?;
                j.field_str("rule", &m.rule)?;
                j.field_fnum("threshold", m.threshold)?;
                j.field_num("window_s", m.window_s)?;
                j.field_num("alerts", m.alerts as f64)?;
                match m.first_alert_s {
                    Some(t) => j.field_num("first_alert_s", t)?,
                    None => {
                        j.key("first_alert_s")?;
                        j.null()?;
                    }
                }
                j.field_fnum("peak", m.peak)?;
                j.end_obj()?;
            }
            j.end_arr()?;
        }
        j.end_obj()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_buckets_cover_orders_of_magnitude() {
        let mut h = Log2Histogram::new();
        for v in [0.001, 1.0, 5.0, 1000.0, 2.5e6] {
            h.record(v);
        }
        assert_eq!(h.count, 5);
        assert!((h.mean() - (0.001 + 1.0 + 5.0 + 1000.0 + 2.5e6) / 5.0).abs() < 1e-9);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 2.5e6);
        // Quantiles are bucket upper bounds: within 2× of the true value.
        let p50 = h.quantile(0.5);
        assert!((5.0..=10.0).contains(&p50), "p50 {p50}");
        assert_eq!(h.quantile(1.0), 2.5e6); // clamped to the exact max
    }

    #[test]
    fn log2_empty_and_degenerate_values() {
        let h = Log2Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        // Zero / negative / non-finite values land in bucket 0, no panic.
        let mut h = Log2Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        assert_eq!(h.count, 2);
        assert!(h.quantile(0.5) <= 0.0 + 2f64.powi(1 - LOG2_OFFSET));
    }

    #[test]
    fn log2_merge_accumulates() {
        let mut a = Log2Histogram::new();
        let mut b = Log2Histogram::new();
        a.record(2.0);
        b.record(64.0);
        a.merge(&b);
        assert_eq!(a.count, 2);
        assert_eq!(a.max(), 64.0);
        assert!((a.mean() - 33.0).abs() < 1e-12);
    }

    #[test]
    fn telemetry_counts_and_renders() {
        let mut t = Telemetry::new();
        t.count(EventKind::Arrival);
        t.count(EventKind::Arrival);
        t.count(EventKind::Completion);
        t.decide_ns.record(1500.0);
        assert_eq!(t.events_of(EventKind::Arrival), 2);
        assert_eq!(t.events_of(EventKind::Completion), 1);
        assert_eq!(t.total_events(), 3);
        let r = t.render();
        assert!(r.contains("arrival 2"), "{r}");
        assert!(r.contains("decide overhead"), "{r}");
        assert!(r.contains("0.03 ms"), "{r}");
    }

    #[test]
    fn telemetry_json_parses_back() {
        let mut t = Telemetry::new();
        t.count(EventKind::Dispatch);
        t.latency_ms.record(250.0);
        let mut buf = Vec::new();
        let mut j = JsonWriter::new(&mut buf);
        t.write_json(&mut j).unwrap();
        let v = crate::util::json::Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
        assert_eq!(v.path(&["events", "dispatch"]).unwrap().as_i64(), Some(1));
        assert_eq!(v.path(&["latency_ms", "count"]).unwrap().as_i64(), Some(1));
        assert_eq!(v.get("overhead_envelope_ns").unwrap().as_f64(), Some(30_000.0));
        assert!(v.get("monitors").is_none(), "no monitors attached, no key");
    }

    #[test]
    fn telemetry_json_carries_monitor_summaries() {
        let mut t = Telemetry::new();
        t.monitors.push(MonitorSummary {
            rule: "carbon-budget".into(),
            threshold: 0.5,
            window_s: 600.0,
            alerts: 3,
            first_alert_s: Some(42.5),
            peak: 0.9,
        });
        t.monitors.push(MonitorSummary {
            rule: "slo-burn".into(),
            threshold: 10.0,
            window_s: 600.0,
            alerts: 0,
            first_alert_s: None,
            peak: 2.0,
        });
        let mut buf = Vec::new();
        let mut j = JsonWriter::new(&mut buf);
        t.write_json(&mut j).unwrap();
        let v = crate::util::json::Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
        let ms = v.get("monitors").unwrap().as_arr().unwrap();
        assert_eq!(ms.len(), 2);
        assert_eq!(ms[0].get("rule").unwrap().as_str(), Some("carbon-budget"));
        assert_eq!(ms[0].get("alerts").unwrap().as_i64(), Some(3));
        assert_eq!(ms[0].get("first_alert_s").unwrap().as_f64(), Some(42.5));
        assert_eq!(ms[1].get("first_alert_s"), Some(&crate::util::json::Json::Null));
        let render = t.render();
        assert!(render.contains("monitor carbon-budget"), "{render}");
        assert!(render.contains("never fired"), "{render}");
    }
}
