//! Source sanitizer + region tracker for the lint pass.
//!
//! The analyzer does not parse Rust; it runs line-oriented rules over a
//! *sanitized* view of each file in which comments, string literals and
//! char literals are blanked out (replaced by spaces, preserving line
//! structure) so that rule matching never fires inside prose or data.
//! Alongside the blanked text the lexer records the two pieces of
//! context the rules need:
//!
//! * **waivers** — `// lint: allow(RULE reason)` comments, collected per
//!   line while comments are being stripped;
//! * **regions** — which lines sit inside `#[cfg(test)]` / `#[test]`
//!   items (findings are never reported from test code) and the stack of
//!   enclosing `fn` names (the P2 rule exempts `validate*` one-shots).
//!
//! Everything here is hand-rolled on `char` scanning in the same
//! no-external-deps style as [`crate::util::json`].

/// A sanitized source file: blanked lines plus the side tables the
/// rules consume. Line numbers are 1-based everywhere in the public API;
/// the vectors here are 0-based (`lines[0]` is line 1).
pub struct SourceModel {
    /// Source lines with comments/strings/chars blanked to spaces.
    pub lines: Vec<String>,
    /// Rule ids waived per line via `// lint: allow(RULE reason)`.
    pub waivers: Vec<Vec<String>>,
    /// True for lines inside `#[cfg(test)]` / `#[test]` items.
    pub in_test: Vec<bool>,
    /// Names of the enclosing functions, outermost first.
    pub fns: Vec<Vec<String>>,
}

impl SourceModel {
    pub fn new(src: &str) -> SourceModel {
        let (lines, waivers) = sanitize(src);
        let (in_test, fns) = regions(&lines);
        SourceModel {
            lines,
            waivers,
            in_test,
            fns,
        }
    }

    /// Is `rule` waived on `line` (1-based)? A waiver comment applies to
    /// its own line and to the immediately following line, so both
    /// trailing (`stmt; // lint: allow(..)`) and preceding-line comments
    /// work.
    pub fn waived(&self, line: usize, rule: &str) -> bool {
        let hit = |ln: usize| {
            ln >= 1 && ln <= self.waivers.len() && self.waivers[ln - 1].iter().any(|r| r == rule)
        };
        hit(line) || hit(line.wrapping_sub(1))
    }
}

enum Mode {
    Code,
    LineComment,
    BlockComment,
    Str,
    RawStr,
    CharLit,
}

/// Blank comments, string literals and char literals out of `src`,
/// returning the sanitized lines and the per-line waiver rule ids parsed
/// from line comments. Handles nested block comments, escape sequences,
/// raw strings (`r"…"`, `r#"…"#`), byte strings and the char-literal vs
/// lifetime ambiguity (`'a'` is blanked, `'a` in `Vec<&'a str>` is not).
fn sanitize(src: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut lines: Vec<String> = Vec::new();
    let mut waivers: Vec<Vec<String>> = Vec::new();
    let mut cur = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    let mut depth = 0usize; // block-comment nesting
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == '\n' {
            let mut w = Vec::new();
            if matches!(mode, Mode::LineComment) {
                w = parse_waivers(&comment);
                comment.clear();
                mode = Mode::Code;
            }
            lines.push(std::mem::take(&mut cur));
            waivers.push(w);
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                if c == '/' && b.get(i + 1) == Some(&'/') {
                    mode = Mode::LineComment;
                    comment.clear();
                    cur.push_str("  ");
                    i += 2;
                } else if c == '/' && b.get(i + 1) == Some(&'*') {
                    mode = Mode::BlockComment;
                    depth = 1;
                    cur.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Str;
                    cur.push(' ');
                    i += 1;
                } else if c == 'r' && matches!(b.get(i + 1), Some(&'#') | Some(&'"')) {
                    let mut k = i + 1;
                    let mut h = 0usize;
                    while b.get(k) == Some(&'#') {
                        h += 1;
                        k += 1;
                    }
                    if b.get(k) == Some(&'"') {
                        mode = Mode::RawStr;
                        raw_hashes = h;
                        for _ in i..=k {
                            cur.push(' ');
                        }
                        i = k + 1;
                    } else {
                        cur.push(c);
                        i += 1;
                    }
                } else if c == 'b' && b.get(i + 1) == Some(&'"') {
                    // byte string: blank the prefix, let '"' open Str mode
                    cur.push(' ');
                    i += 1;
                } else if c == '\'' {
                    if b.get(i + 1) == Some(&'\\') {
                        mode = Mode::CharLit;
                        cur.push(' ');
                        i += 1;
                    } else if b.get(i + 2) == Some(&'\'') {
                        cur.push_str("   ");
                        i += 3;
                    } else {
                        // lifetime tick — leave it
                        cur.push(c);
                        i += 1;
                    }
                } else {
                    cur.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(c);
                cur.push(' ');
                i += 1;
            }
            Mode::BlockComment => {
                if c == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    cur.push_str("  ");
                    i += 2;
                } else if c == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    cur.push_str("  ");
                    i += 2;
                    if depth == 0 {
                        mode = Mode::Code;
                    }
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    if b.get(i + 1) == Some(&'\n') {
                        // line-continuation escape: keep the newline
                        cur.push(' ');
                        i += 1;
                    } else {
                        cur.push_str("  ");
                        i += 2;
                    }
                } else if c == '"' {
                    mode = Mode::Code;
                    cur.push(' ');
                    i += 1;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr => {
                let closes = c == '"'
                    && (i + 1..i + 1 + raw_hashes).all(|k| b.get(k) == Some(&'#'));
                if closes {
                    for _ in 0..=raw_hashes {
                        cur.push(' ');
                    }
                    i += 1 + raw_hashes;
                    mode = Mode::Code;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            Mode::CharLit => {
                if c == '\\' && i + 1 < n {
                    cur.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    mode = Mode::Code;
                    cur.push(' ');
                    i += 1;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !cur.is_empty() || matches!(mode, Mode::LineComment) {
        let w = if matches!(mode, Mode::LineComment) {
            parse_waivers(&comment)
        } else {
            Vec::new()
        };
        lines.push(cur);
        waivers.push(w);
    }
    (lines, waivers)
}

/// Parse `lint: allow(RULE reason)` out of a comment body. The rule id is
/// an uppercase letter followed by digits (`D1`, `P2`, …); everything
/// else inside the parens is the human reason and is not interpreted.
fn parse_waivers(comment: &str) -> Vec<String> {
    let Some(pos) = comment.find("lint:") else {
        return Vec::new();
    };
    let rest = comment[pos + 5..].trim_start();
    let Some(rest) = rest.strip_prefix("allow(") else {
        return Vec::new();
    };
    let mut chars = rest.chars();
    let mut rule = String::new();
    match chars.next() {
        Some(c) if c.is_ascii_uppercase() => rule.push(c),
        _ => return Vec::new(),
    }
    for c in chars {
        if c.is_ascii_digit() {
            rule.push(c);
        } else {
            break;
        }
    }
    if rule.len() < 2 {
        return Vec::new();
    }
    vec![rule]
}

/// Walk brace depth over the sanitized lines, tracking (a) regions opened
/// by a `#[cfg(test)]` / `#[test]` attribute and (b) the stack of
/// enclosing `fn` names. Attribute and `fn` sightings are *pending* until
/// their `{` opens; a `;` at depth 0 cancels a pending attribute (it
/// annotated a braceless item).
fn regions(lines: &[String]) -> (Vec<bool>, Vec<Vec<String>>) {
    let mut in_test = vec![false; lines.len()];
    let mut fns: Vec<Vec<String>> = vec![Vec::new(); lines.len()];
    let mut depth: i64 = 0;
    let mut pending_skip = false;
    let mut pending_fn: Option<String> = None;
    let mut skip_stack: Vec<i64> = Vec::new();
    let mut fn_stack: Vec<(String, i64)> = Vec::new();
    for (ix, text) in lines.iter().enumerate() {
        if text.contains("#[cfg(test)]") || text.contains("#[test]") {
            pending_skip = true;
        }
        if let Some(name) = fn_name(text) {
            pending_fn = Some(name);
        }
        in_test[ix] = !skip_stack.is_empty();
        fns[ix] = fn_stack.iter().map(|(n, _)| n.clone()).collect();
        for ch in text.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending_skip {
                        skip_stack.push(depth);
                        pending_skip = false;
                        in_test[ix] = true;
                    }
                    if let Some(n) = pending_fn.take() {
                        fn_stack.push((n, depth));
                    }
                }
                '}' => {
                    if skip_stack.last() == Some(&depth) {
                        skip_stack.pop();
                    }
                    if fn_stack.last().map(|(_, d)| *d) == Some(depth) {
                        fn_stack.pop();
                    }
                    depth -= 1;
                }
                ';' if depth == 0 => pending_skip = false,
                _ => {}
            }
        }
    }
    (in_test, fns)
}

/// The name declared by a `fn` token on this line, if any.
fn fn_name(line: &str) -> Option<String> {
    let b = line.as_bytes();
    for (start, end) in idents(line) {
        if &line[start..end] == "fn" {
            let mut k = end;
            while k < b.len() && b[k].is_ascii_whitespace() {
                k += 1;
            }
            let name_start = k;
            while k < b.len() && is_ident_byte(b[k]) {
                k += 1;
            }
            if k > name_start && !b[name_start].is_ascii_digit() {
                return Some(line[name_start..k].to_string());
            }
        }
    }
    None
}

pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte spans of the identifier tokens in a sanitized line (maximal runs
/// of ident bytes not starting with a digit).
pub fn idents(line: &str) -> Vec<(usize, usize)> {
    let b = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        if is_ident_byte(b[i]) {
            let start = i;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            if !b[start].is_ascii_digit() {
                out.push((start, i));
            }
        } else {
            i += 1;
        }
    }
    out
}

/// Does `line` contain `tok` as a whole word (ident-boundary on both
/// sides)? `tok` may contain `::`.
pub fn contains_token(line: &str, tok: &str) -> bool {
    find_token(line, tok, 0).is_some()
}

/// First occurrence of `tok` at or after `from`, with ident-boundary
/// checks on both ends.
pub fn find_token(line: &str, tok: &str, from: usize) -> Option<usize> {
    let b = line.as_bytes();
    let mut at = from;
    while let Some(rel) = line.get(at..).and_then(|s| s.find(tok)) {
        let pos = at + rel;
        let pre_ok = pos == 0 || !is_ident_byte(b[pos - 1]);
        let end = pos + tok.len();
        let post_ok = end >= b.len() || !is_ident_byte(b[end]);
        if pre_ok && post_ok {
            return Some(pos);
        }
        at = pos + 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "let s = \"HashMap in a string\"; // HashMap in a comment\nlet c = 'x';\n";
        let (lines, _) = sanitize(src);
        assert!(!lines[0].contains("HashMap"), "{:?}", lines[0]);
        assert!(lines[0].contains("let s ="));
        assert!(!lines[1].contains('x'));
    }

    #[test]
    fn raw_strings_and_nesting() {
        let src = "let r = r#\"assert!(x)\"#; /* outer /* assert!(y) */ */ let z = 1;\n";
        let (lines, _) = sanitize(src);
        assert!(!lines[0].contains("assert"), "{:?}", lines[0]);
        assert!(lines[0].contains("let z = 1;"));
    }

    #[test]
    fn lifetimes_survive_char_blanking() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\n";
        let (lines, _) = sanitize(src);
        assert!(lines[0].contains("&'a str"));
    }

    #[test]
    fn waiver_parsing() {
        let src = "x.unwrap(); // lint: allow(P1 guarded by is_some above)\ny.unwrap();\n";
        let model = SourceModel::new(src);
        assert!(model.waived(1, "P1"));
        assert!(model.waived(2, "P1"), "waiver covers the following line");
        assert!(!model.waived(2, "D1"));
        assert!(!model.waived(3, "P1"));
    }

    #[test]
    fn test_regions_and_fn_stack() {
        let src = "fn validate_cfg(x: f64) {\n    assert!(x > 0.0);\n}\n#[cfg(test)]\nmod tests {\n    fn helper() { assert!(true); }\n}\n";
        let model = SourceModel::new(src);
        assert!(!model.in_test[1]);
        assert_eq!(model.fns[1], vec!["validate_cfg".to_string()]);
        assert!(model.in_test[5], "lines under #[cfg(test)] are skipped");
    }
}
