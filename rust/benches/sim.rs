//! Simulator throughput bench: how many virtual requests per wall-clock
//! second the discrete-event engine sustains. Target (ISSUE 1 / ROADMAP
//! L3.5): ≥ 1M simulated requests/s on the paper-3-node scenario.
//!
//! Needs no artifacts — run with `cargo bench --bench sim`.

use std::time::Instant;

use carbonedge::scheduler::{CarbonAwareScheduler, Mode};
use carbonedge::sim::{scenarios, Simulation};

fn throughput(name: &str, nodes: usize, requests: usize, runs: usize) -> f64 {
    let sc = scenarios::build(name, nodes, requests, 42).expect("known scenario");
    let mut best = f64::MAX;
    for _ in 0..runs {
        let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
        let t0 = Instant::now();
        let r = Simulation::run(&sc, &mut sched);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(r.completed + r.rejected, requests as u64);
        best = best.min(dt);
    }
    requests as f64 / best
}

fn main() {
    println!("simulator throughput (best of 3, CE-Green)");
    let rps = throughput("paper-3-node", 0, 1_000_000, 3);
    let verdict = if rps >= 1e6 { "meets the 1M target" } else { "BELOW the 1M target" };
    println!("  paper-3-node     1M requests   {:>8.2}M sim-req/s  ({verdict})", rps / 1e6);

    let rps = throughput("fleet-100", 100, 200_000, 3);
    println!("  fleet-100      200k requests   {:>8.2}M sim-req/s", rps / 1e6);

    let rps = throughput("bursty", 0, 500_000, 3);
    println!("  bursty         500k requests   {:>8.2}M sim-req/s", rps / 1e6);

    let rps = throughput("churn", 0, 200_000, 3);
    println!("  churn          200k requests   {:>8.2}M sim-req/s", rps / 1e6);

    // Deferral + CSV-trace lookups on the hot path (every arrival consults
    // the forecast, every parked task re-enters the heap).
    let rps = throughput("real-trace", 0, 200_000, 3);
    println!("  real-trace     200k requests   {:>8.2}M sim-req/s  (deferral on)", rps / 1e6);

    // Idle-floor accrual + piecewise intensity integration at report time.
    let rps = throughput("consolidation", 0, 200_000, 3);
    println!("  consolidation  200k requests   {:>8.2}M sim-req/s  (idle floors)", rps / 1e6);

    // Microgrid settlement on the hot path: every draw change covers a
    // slice PV-first/battery/grid, every refresh re-blends the effective
    // intensity and samples the SoC timeline.
    let rps = throughput("solar-battery", 0, 200_000, 3);
    println!("  solar-battery  200k requests   {:>8.2}M sim-req/s  (pv+battery)", rps / 1e6);

    let rps = throughput("microgrid-fleet", 0, 200_000, 3);
    println!("  microgrid-flt  200k requests   {:>8.2}M sim-req/s  (mixed supply)", rps / 1e6);
}
