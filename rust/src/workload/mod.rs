//! Workload generation: synthetic inference inputs (the ImageNet-val
//! substitution, DESIGN.md §7), request arrival processes, and the
//! multi-tenant [`WorkloadMix`] class registry the L3.5 simulator samples
//! arrivals from.

use crate::runtime::Tensor;
use crate::scheduler::TaskDemand;
use crate::util::rng::Rng;

/// ImageNet normalization constants (paper Sec. IV-A2).
pub const IMAGENET_MEAN: [f32; 3] = [0.485, 0.456, 0.406];
pub const IMAGENET_STD: [f32; 3] = [0.229, 0.224, 0.225];

/// Deterministic synthetic "photo": smooth gradients + seeded noise, then
/// ImageNet normalization. **Must match aot.py's `golden_image`** — the
/// golden-logit integration tests depend on bit-identical inputs for seed 0.
pub fn synthetic_image(image_size: usize, seed: u64) -> Tensor {
    let n = image_size;
    let mut rng = GaussMt::new(seed);
    let mut data = vec![0f32; n * n * 3];
    for y in 0..n {
        for x in 0..n {
            let yy = y as f32 / n as f32;
            let xx = x as f32 / n as f32;
            let base = [yy, xx, 0.5 * (xx + yy)];
            for c in 0..3 {
                let v = base[c] + 0.1 * rng.next_for(y, x, c) as f32;
                let v = v.clamp(0.0, 1.0);
                data[(y * n + x) * 3 + c] = (v - IMAGENET_MEAN[c]) / IMAGENET_STD[c];
            }
        }
    }
    // lint: allow(P1 shape and data length are constructed together above)
    Tensor::new(vec![n, n, 3], data).expect("shape matches")
}

/// numpy `RandomState(seed).randn(...)` compatible generator is out of
/// scope for non-zero seeds; for seed 0 aot.py ships the image as a binary
/// sidecar, which the golden tests read directly. For workload *variety*
/// (the paper's "varied input complexity") any deterministic noise works —
/// this struct provides seeded Gaussian noise per pixel.
struct GaussMt {
    rng: Rng,
}

impl GaussMt {
    fn new(seed: u64) -> GaussMt {
        GaussMt { rng: Rng::new(seed) }
    }
    fn next_for(&mut self, _y: usize, _x: usize, _c: usize) -> f64 {
        self.rng.normal()
    }
}

/// Arrival process for the serving loop.
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Closed loop: next request issued when the previous completes
    /// (the paper's 50-iteration evaluation loop).
    ClosedLoop { count: usize },
    /// Open loop with Poisson arrivals at `rate_hz`.
    Poisson { count: usize, rate_hz: f64, seed: u64 },
}

impl Arrivals {
    pub fn count(&self) -> usize {
        match self {
            Arrivals::ClosedLoop { count } => *count,
            Arrivals::Poisson { count, .. } => *count,
        }
    }

    /// Inter-arrival gaps in seconds (empty for closed-loop).
    pub fn gaps(&self) -> Vec<f64> {
        match self {
            Arrivals::ClosedLoop { .. } => Vec::new(),
            Arrivals::Poisson { count, rate_hz, seed } => {
                let mut rng = Rng::new(*seed);
                (0..*count).map(|_| rng.exp(*rate_hz)).collect()
            }
        }
    }
}

/// One tenant class in a multi-tenant serving mix: a model (size expressed
/// as a scale on the scenario's base executor time), its resource demand,
/// an SLO tier, and a priority. Tasks of the same class share a model, so
/// the simulator may serve them in one batch
/// ([`crate::node::NodeSpec::batch_latency_ms`]).
#[derive(Debug, Clone)]
pub struct WorkloadClass {
    pub name: String,
    /// Per-class resource demand handed to the scheduler. The engine
    /// stamps [`TaskDemand::class`] with this class's index at arrival
    /// time, so builders need not keep the two in sync by hand.
    pub demand: TaskDemand,
    /// SLO deadline: seconds of slack from arrival to required
    /// completion. Completions past it count in the per-class
    /// `deadline_missed`. Use `f64::INFINITY` for best-effort tiers.
    pub slo_s: f64,
    /// Model-size multiplier on the scenario's `base_exec_ms` (0.5 = a
    /// distilled half-size model, 3.0 = a hefty one).
    pub exec_scale: f64,
    /// Larger = more latency-critical. Batch formation drains the
    /// highest-priority eligible class first on ties.
    pub priority: u8,
    /// Relative arrival weight within the mix (need not sum to 1).
    pub weight: f64,
}

/// The arrival mix over workload classes. Sampling is by cumulative
/// weight from one uniform draw, so a mix woven into the simulator's
/// seeded Poisson/MMPP generators stays deterministic.
#[derive(Debug, Clone, Default)]
pub struct WorkloadMix {
    pub classes: Vec<WorkloadClass>,
}

impl WorkloadMix {
    pub fn validate(&self) -> Result<(), String> {
        if self.classes.is_empty() {
            return Err("workload mix needs at least one class".into());
        }
        for c in &self.classes {
            if !c.weight.is_finite() || c.weight <= 0.0 {
                return Err(format!("class {}: weight must be > 0, got {}", c.name, c.weight));
            }
            if !c.exec_scale.is_finite() || c.exec_scale <= 0.0 {
                return Err(format!(
                    "class {}: exec_scale must be > 0, got {}",
                    c.name, c.exec_scale
                ));
            }
            if c.slo_s.is_nan() || c.slo_s <= 0.0 {
                return Err(format!("class {}: slo_s must be > 0, got {}", c.name, c.slo_s));
            }
        }
        Ok(())
    }

    /// Map one uniform draw `u ∈ [0, 1)` to a class index by cumulative
    /// weight. Deterministic and total: any finite `u` lands somewhere.
    pub fn sample(&self, u: f64) -> usize {
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        let target = u * total;
        let mut acc = 0.0;
        for (i, c) in self.classes.iter().enumerate() {
            acc += c.weight;
            if target < acc {
                return i;
            }
        }
        self.classes.len() - 1
    }

    /// The scheduler-facing demand for class `i`, with
    /// [`TaskDemand::class`] stamped to the index.
    pub fn demand_of(&self, i: usize) -> TaskDemand {
        TaskDemand { class: i, ..self.classes[i].demand }
    }
}

/// A stream of inference requests with per-request input seeds
/// (the paper samples 50 images per experiment).
#[derive(Debug, Clone)]
pub struct RequestStream {
    pub image_size: usize,
    pub arrivals: Arrivals,
    pub seed: u64,
}

impl RequestStream {
    pub fn paper_default(image_size: usize) -> RequestStream {
        RequestStream { image_size, arrivals: Arrivals::ClosedLoop { count: 50 }, seed: 0 }
    }

    /// Generate the request inputs.
    pub fn inputs(&self) -> Vec<Tensor> {
        (0..self.arrivals.count())
            .map(|i| synthetic_image(self.image_size, self.seed.wrapping_add(i as u64)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_shape_and_determinism() {
        let a = synthetic_image(16, 3);
        let b = synthetic_image(16, 3);
        assert_eq!(a.shape, vec![16, 16, 3]);
        assert_eq!(a, b);
        let c = synthetic_image(16, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn image_is_normalized() {
        let t = synthetic_image(32, 0);
        // After mean/std normalization values must straddle zero.
        let min = t.data.iter().cloned().fold(f32::MAX, f32::min);
        let max = t.data.iter().cloned().fold(f32::MIN, f32::max);
        assert!(min < 0.0 && max > 0.0);
        // and stay in a plausible normalized range
        assert!(min > -3.0 && max < 4.0);
    }

    #[test]
    fn closed_loop_counts() {
        let s = RequestStream::paper_default(8);
        assert_eq!(s.arrivals.count(), 50);
        assert_eq!(s.inputs().len(), 50);
        assert!(s.arrivals.gaps().is_empty());
    }

    #[test]
    fn poisson_gaps_have_right_mean() {
        let a = Arrivals::Poisson { count: 20_000, rate_hz: 4.0, seed: 7 };
        let gaps = a.gaps();
        assert_eq!(gaps.len(), 20_000);
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
        assert!(gaps.iter().all(|&g| g >= 0.0));
    }

    #[test]
    fn poisson_gaps_deterministic_and_seed_stable() {
        // Same seed ⇒ identical gaps on every call — the property the
        // simulator's reproducibility rests on.
        let a = Arrivals::Poisson { count: 4, rate_hz: 2.0, seed: 9 };
        assert_eq!(a.gaps(), a.gaps());
        // Pinned against the reference RNG implementation (seed 9, λ = 2).
        let want = [
            0.0012933912623040553,
            0.1448349383570217,
            0.07104812619394953,
            0.6596814003634573,
        ];
        for (g, w) in a.gaps().iter().zip(want) {
            assert!((g - w).abs() < 1e-12, "gap {g} vs pinned {w}");
        }
        // Different seed ⇒ different process.
        let b = Arrivals::Poisson { count: 4, rate_hz: 2.0, seed: 10 };
        assert_ne!(a.gaps(), b.gaps());
    }

    fn mix3() -> WorkloadMix {
        let class = |name: &str, w: f64| WorkloadClass {
            name: name.into(),
            demand: TaskDemand::default(),
            slo_s: 10.0,
            exec_scale: 1.0,
            priority: 0,
            weight: w,
        };
        WorkloadMix { classes: vec![class("a", 1.0), class("b", 2.0), class("c", 1.0)] }
    }

    #[test]
    fn mix_samples_by_cumulative_weight() {
        let m = mix3(); // cumulative shares: 0.25 | 0.75 | 1.0
        assert_eq!(m.sample(0.0), 0);
        assert_eq!(m.sample(0.24), 0);
        assert_eq!(m.sample(0.25), 1);
        assert_eq!(m.sample(0.74), 1);
        assert_eq!(m.sample(0.75), 2);
        assert_eq!(m.sample(0.999), 2);
        // Weight-proportional in the long run against the engine's RNG.
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[m.sample(rng.f64())] += 1;
        }
        assert!((counts[1] as f64 / 40_000.0 - 0.5).abs() < 0.02, "{counts:?}");
        assert!((counts[0] as f64 / 40_000.0 - 0.25).abs() < 0.02, "{counts:?}");
    }

    #[test]
    fn mix_demand_of_stamps_class_index() {
        let m = mix3();
        assert_eq!(m.demand_of(2).class, 2);
        assert_eq!(m.demand_of(0).mem_mb, TaskDemand::default().mem_mb);
    }

    #[test]
    fn mix_validate_catches_bad_classes() {
        assert!(mix3().validate().is_ok());
        assert!(WorkloadMix::default().validate().is_err());
        let mut m = mix3();
        m.classes[1].weight = 0.0;
        assert!(m.validate().is_err());
        let mut m = mix3();
        m.classes[0].exec_scale = -1.0;
        assert!(m.validate().is_err());
        let mut m = mix3();
        m.classes[2].slo_s = 0.0;
        assert!(m.validate().is_err());
        // Best-effort infinity SLO is legal.
        let mut m = mix3();
        m.classes[2].slo_s = f64::INFINITY;
        assert!(m.validate().is_ok());
    }

    #[test]
    fn distinct_request_inputs() {
        let s =
            RequestStream { image_size: 8, arrivals: Arrivals::ClosedLoop { count: 3 }, seed: 1 };
        let ins = s.inputs();
        assert_ne!(ins[0], ins[1]);
        assert_ne!(ins[1], ins[2]);
    }
}
