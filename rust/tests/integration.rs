//! Integration tests over the real artifacts: PJRT execution, golden
//! numerics, scheduling behaviour, and the paper's headline shapes.
//!
//! Requires `make artifacts` (skipped gracefully if artifacts are absent).

use std::sync::{Mutex, OnceLock};

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;
use carbonedge::metrics::RunReport;
use carbonedge::scheduler::{CarbonAwareScheduler, Mode, Weights};
use carbonedge::workload::RequestStream;

fn coord() -> Option<&'static Mutex<Coordinator>> {
    static COORD: OnceLock<Option<Mutex<Coordinator>>> = OnceLock::new();
    COORD
        .get_or_init(|| {
            if !std::path::Path::new("artifacts/manifest.json").exists() {
                eprintln!("skipping integration tests: run `make artifacts` first");
                return None;
            }
            Some(Mutex::new(Coordinator::new(Config::default()).expect("coordinator")))
        })
        .as_ref()
}

macro_rules! coord_or_skip {
    () => {
        match coord() {
            // Recover from poisoning: a failed test must not cascade into
            // every other test sharing the coordinator.
            Some(c) => c.lock().unwrap_or_else(|e| e.into_inner()),
            None => return,
        }
    };
}

#[test]
fn golden_logits_all_models() {
    let c = coord_or_skip!();
    for name in c.manifest.models.keys().cloned().collect::<Vec<_>>() {
        let model = c.load_model(&name).unwrap();
        let err = c.golden_check(&model).expect(&name);
        assert!(err < 1e-3, "{name}: max logit err {err}");
    }
}

#[test]
fn stage_chain_matches_monolithic_numerics() {
    let c = coord_or_skip!();
    let model = c.load_model("mobilenet_v2").unwrap();
    let cfg = c.cfg.clone();
    let exec = c.exec();
    let mono_key = carbonedge::deployer::register_monolithic(&exec, &model, &cfg).unwrap();
    let stage_keys = carbonedge::deployer::register_stages(&exec, &model, &cfg).unwrap();
    let input = model.golden_input().unwrap();
    let (want, _) = exec.execute(&mono_key, input.clone()).unwrap();
    let mut x = input;
    for k in &stage_keys {
        x = exec.execute(k, x).unwrap().0;
    }
    assert_eq!(x.shape, want.shape);
    let max_err = x
        .data
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "stage chain deviates by {max_err}");
}

#[test]
fn table2_shape_holds() {
    let c = coord_or_skip!();
    let t2 = exp::table2(&c, "mobilenet_v2", 8, 1).unwrap();
    let mono = &t2.reports[0];
    let perf = &t2.reports[2];
    let green = &t2.reports[4];
    // Green reduces carbon substantially; Performance increases it.
    let green_red = green.reduction_vs(mono);
    let perf_red = perf.reduction_vs(mono);
    assert!(green_red > 0.10, "green reduction {green_red}");
    assert!(perf_red < 0.0, "performance should increase carbon, got {perf_red}");
    // Latency overhead of CE modes stays bounded (paper: < 15%).
    assert!(green.latency_ms.mean < mono.latency_ms.mean * 1.25);
    // Carbon efficiency ordering (Fig. 2): green > mono > performance.
    assert!(green.carbon_efficiency > mono.carbon_efficiency);
    assert!(mono.carbon_efficiency > perf.carbon_efficiency);
}

#[test]
fn table5_full_concentration() {
    let c = coord_or_skip!();
    let t5 = exp::table5(&c, "mobilenet_v2", 10).unwrap();
    let row = |name: &str| -> &Vec<f64> {
        &t5.rows.iter().find(|(m, _)| m == name).unwrap().1
    };
    // registry order: node-high, node-medium, node-green
    assert_eq!(row("performance")[0], 100.0);
    assert_eq!(row("balanced")[0], 100.0);
    assert_eq!(row("green")[2], 100.0);
    assert_eq!(row("green")[0], 0.0);
}

#[test]
fn sweep_transition_behaviour() {
    let c = coord_or_skip!();
    let model = c.load_model("mobilenet_v2").unwrap();
    let run = |w_c: f64| -> RunReport {
        let mut s = CarbonAwareScheduler::new("sweep", Weights::sweep(w_c));
        let stream = RequestStream {
            image_size: c.manifest.image_size,
            arrivals: carbonedge::workload::Arrivals::ClosedLoop { count: 6 },
            seed: 0,
        };
        let r = c.run_scheduled(&model, &mut s, &stream.inputs()).unwrap();
        RunReport::from_records("sweep", &r.records).unwrap()
    };
    let low = run(0.05);
    let high = run(0.9);
    assert_eq!(low.node_usage[0].0, "node-high");
    assert_eq!(low.node_usage.len(), 1);
    assert_eq!(high.node_usage[0].0, "node-green");
    // Fig. 3: at w_C = 0.5 routing has flipped to the green node.
    let mid = run(0.5);
    assert_eq!(mid.node_usage[0].0, "node-green", "transition at w_C >= 0.5");
}

#[test]
fn pipeline_covers_fleet_and_is_correct() {
    let c = coord_or_skip!();
    let model = c.load_model("mobilenet_v2").unwrap();
    let input = model.golden_input().unwrap();
    let recs = c.run_pipeline(&model, 0.5, &[input], 2.0).unwrap();
    assert_eq!(recs.len(), 1);
    let rec = &recs[0];
    // crosses more than one node
    assert!(rec.node.contains('+'), "pipeline ran on {}", rec.node);
    // output is the golden logits
    let g = &model.entry.golden;
    for (i, want) in g.logits8.iter().enumerate() {
        assert!((rec.output.data[i] as f64 - want).abs() < 1e-3);
    }
    assert!(rec.carbon_g > 0.0 && rec.energy_j > 0.0);
}

#[test]
fn scheduling_overhead_sub_millisecond() {
    let c = coord_or_skip!();
    let s = exp::scheduling_overhead(&c, "mobilenet_v2", 30).unwrap();
    // The paper claims 0.03 ms/task; require well under 1 ms here.
    assert!(s.mean < 1.0, "scheduling overhead {} ms", s.mean);
}

#[test]
fn multi_model_green_reduces_carbon() {
    let c = coord_or_skip!();
    let models: Vec<String> = c.manifest.models.keys().cloned().collect();
    let refs: Vec<&str> = models.iter().map(String::as_str).collect();
    let rows = exp::table4(&c, &refs, 5, 1).unwrap();
    assert_eq!(rows.len(), 3);
    for r in &rows {
        let red = r.green.reduction_vs(&r.mono);
        // Paper Table IV: 14.8%-32.2% across architectures.
        assert!(red > 0.05, "{}: reduction {red}", r.model);
        assert!(red < 0.5, "{}: reduction {red}", r.model);
    }
}

#[test]
fn serving_loop_poisson_end_to_end() {
    let c = coord_or_skip!();
    let model = c.load_model("mobilenet_v4").unwrap();
    let registry = c.fresh_registry();
    let containers =
        carbonedge::deployer::deploy_task_level(&c.exec(), &model, registry.nodes(), &c.cfg)
            .unwrap();
    let stream = RequestStream {
        image_size: c.manifest.image_size,
        arrivals: carbonedge::workload::Arrivals::Poisson { count: 8, rate_hz: 50.0, seed: 3 },
        seed: 0,
    };
    let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
    let loop_ = carbonedge::coordinator::ServingLoop::new(&registry, &containers);
    let out = loop_.serve(&stream, &mut sched, "poisson").unwrap();
    assert_eq!(out.report.inferences, 8);
    assert!(out.report.carbon_per_inf_g > 0.0);
    assert_eq!(out.report.node_usage[0].0, "node-green");
}
