//! Statistics substrate: summary stats, percentiles, confidence intervals,
//! and a fixed-bucket latency histogram. Backs the metrics module and the
//! bench harness (criterion is not in the offline crate set).

/// Summary of a sample (latencies, energies, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }

    /// Half-width of the 95% confidence interval on the mean
    /// (normal approximation; the paper reports 95% CIs the same way).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        1.96 * self.std / (self.n as f64).sqrt()
    }

    /// CI as a fraction of the mean (the paper reports "<15% of mean").
    pub fn ci95_rel(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.ci95() / self.mean.abs()
        }
    }
}

/// Mean of a sample, 0.0 for an empty slice — the reporting convention for
/// optional measurements (queue waits, scheduling overhead) where "no
/// samples" means "nothing to report", not a panic.
pub fn mean_or_zero(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Log-scaled latency histogram (microseconds to seconds).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    lo: f64,
    ratio: f64,
    pub count: u64,
    pub sum: f64,
}

impl Histogram {
    /// `lo`..`hi` in whatever unit the caller uses, `n` log-spaced buckets.
    pub fn new(lo: f64, hi: f64, n: usize) -> Histogram {
        assert!(lo > 0.0 && hi > lo && n > 0);
        Histogram {
            buckets: vec![0; n + 2], // +underflow +overflow
            lo,
            ratio: (hi / lo).powf(1.0 / n as f64),
            count: 0,
            sum: 0.0,
        }
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        let idx = if v < self.lo {
            0
        } else {
            let i = ((v / self.lo).ln() / self.ratio.ln()).floor() as usize + 1;
            i.min(self.buckets.len() - 1)
        };
        self.buckets[idx] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                if i == 0 {
                    return self.lo;
                }
                return self.lo * self.ratio.powi(i as i32); // upper edge
            }
        }
        self.lo * self.ratio.powi(self.buckets.len() as i32)
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.ci95(), 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn mean_or_zero_handles_empty() {
        assert_eq!(mean_or_zero(&[]), 0.0);
        assert_eq!(mean_or_zero(&[3.0]), 3.0);
        assert!((mean_or_zero(&[1.0, 2.0, 6.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolation() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn ci95_shrinks_with_n() {
        let a = Summary::of(&vec![1.0, 2.0, 3.0, 2.0, 1.0, 3.0, 2.0, 2.0]);
        let bigger: Vec<f64> =
            std::iter::repeat([1.0, 2.0, 3.0, 2.0]).take(100).flatten().collect();
        let b = Summary::of(&bigger);
        assert!(b.ci95() < a.ci95());
    }

    #[test]
    fn histogram_mean_and_quantile() {
        let mut h = Histogram::new(0.1, 1000.0, 50);
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count, 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        let q50 = h.quantile(0.5);
        assert!(q50 > 30.0 && q50 < 80.0, "q50 {q50}");
        let q99 = h.quantile(0.99);
        assert!(q99 >= 90.0, "q99 {q99}");
    }

    #[test]
    fn histogram_under_overflow() {
        let mut h = Histogram::new(1.0, 10.0, 4);
        h.record(0.01);
        h.record(1e9);
        assert_eq!(h.count, 2);
        assert!(h.quantile(0.0) >= 1.0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new(1.0, 100.0, 10);
        let mut b = Histogram::new(1.0, 100.0, 10);
        a.record(5.0);
        b.record(50.0);
        a.merge(&b);
        assert_eq!(a.count, 2);
        assert!((a.mean() - 27.5).abs() < 1e-9);
    }
}
