//! Integration tests for the observability subsystem: the NDJSON event
//! firehose, the in-process telemetry registry, and the guarantee that
//! tracing never perturbs the simulation. Everything runs on the virtual
//! clock — no artifacts needed.

use std::collections::BTreeMap;

use carbonedge::obs::{
    replay, CarbonBudget, EventKind, FirehoseSink, MonitorSet, NullSink, Telemetry, TraceFilter,
    OVERHEAD_ENVELOPE_NS,
};
use carbonedge::scheduler::{CarbonAwareScheduler, DeferAwareGreenScheduler, Mode, Scheduler};
use carbonedge::sim::{scenarios, SimReport, Simulation};
use carbonedge::util::json::Json;

fn green() -> CarbonAwareScheduler {
    CarbonAwareScheduler::new("green", Mode::Green.weights())
}

/// Run a scenario with a full firehose attached — `defer-green` when the
/// scenario configures deferral (its intended scheduler), plain green
/// otherwise; return the report, telemetry, and the NDJSON the sink wrote.
fn observed(name: &str, requests: usize, seed: u64) -> (SimReport, Telemetry, String) {
    let sc = scenarios::build(name, 0, requests, seed).unwrap();
    let mut sched: Box<dyn Scheduler> = match &sc.config.deferral {
        Some(d) => Box::new(DeferAwareGreenScheduler::new(d.policy.min_gain)),
        None => Box::new(green()),
    };
    let mut sink = FirehoseSink::new(Vec::new());
    let (report, telem) =
        Simulation::try_run_observed(&sc, sched.as_mut(), &mut sink).unwrap();
    let text = String::from_utf8(sink.finish().unwrap()).unwrap();
    (report, telem, text)
}

/// Every firehose line parses back through `util::json`, event counts are
/// conserved against both the report and the telemetry counters, and
/// replaying completion + microgrid-slice carbon reproduces the report's
/// carbon total. `paper-3-node` covers the plain grid path, `arbitrage`
/// the deferral + microgrid settlement path (both fleets are zero-idle,
/// so the event stream carries *all* the carbon).
#[test]
fn firehose_round_trip_conserves_events_and_replays_carbon() {
    for name in ["paper-3-node", "arbitrage"] {
        let (report, telem, text) = observed(name, 4_000, 7);
        let mut counts: BTreeMap<String, u64> = BTreeMap::new();
        let mut completion_carbon = 0.0;
        let mut slice_carbon = 0.0;
        let mut missed = 0u64;
        let mut lines = 0u64;
        for line in text.lines() {
            lines += 1;
            let v = Json::parse(line)
                .unwrap_or_else(|e| panic!("{name}: invalid NDJSON line ({e}): {line}"));
            let kind = v.req_str("kind").unwrap().to_string();
            *counts.entry(kind.clone()).or_insert(0) += 1;
            match kind.as_str() {
                "completion" => {
                    completion_carbon += v.req_f64("carbon_g").unwrap();
                    if v.get("missed").unwrap().as_bool() == Some(true) {
                        missed += 1;
                    }
                }
                "mg_slice" => slice_carbon += v.req_f64("carbon_g").unwrap(),
                "decision" => {
                    // Decision traces carry the per-candidate rationale.
                    assert!(
                        !v.req_arr("candidates").unwrap().is_empty(),
                        "{name}: decision line without candidates: {line}"
                    );
                }
                _ => {}
            }
        }
        // One line per event, and the post-filter stream (filter = all)
        // matches the pre-filter telemetry counters kind by kind.
        assert_eq!(lines, telem.total_events(), "{name}: line count vs telemetry");
        for k in EventKind::ALL {
            assert_eq!(
                counts.get(k.label()).copied().unwrap_or(0),
                telem.events_of(k),
                "{name}: {} count mismatch",
                k.label()
            );
        }
        // Event-count conservation against the report.
        assert_eq!(counts["arrival"], report.requests, "{name}: arrivals");
        assert_eq!(counts["completion"], report.completed, "{name}: completions");
        assert_eq!(report.completed + report.rejected, report.requests, "{name}: leaked");
        assert_eq!(missed, report.deadline_missed, "{name}: missed-deadline replay");
        // Carbon replay: completions carry grid-attributed carbon,
        // microgrid slices carry settled carbon; together they reproduce
        // the run total.
        let replayed = completion_carbon + slice_carbon;
        assert!(
            (replayed - report.carbon_g_total).abs() <= 1e-6 * report.carbon_g_total.max(1e-12),
            "{name}: replayed carbon {replayed} != total {}",
            report.carbon_g_total
        );
        if name == "arbitrage" {
            // The interesting paths actually fired.
            assert!(counts.get("mg_slice").copied().unwrap_or(0) > 0, "no settlement slices");
            assert!(counts.get("defer_release").copied().unwrap_or(0) > 0, "no defer releases");
        }
    }
}

/// Tracing must never perturb the run: with the full firehose attached —
/// and with the counters-only `NullSink` — the `SimReport` is bit-identical
/// (`PartialEq` over every field) to the untraced run, across the whole
/// scenario library.
#[test]
fn traced_run_report_is_bit_identical_to_untraced() {
    for name in scenarios::SCENARIO_NAMES {
        let sc = scenarios::build(name, 0, 1_500, 7).unwrap();
        let untraced = Simulation::try_run(&sc, &mut green()).unwrap();

        let mut sink = FirehoseSink::new(Vec::new());
        let (traced, telem) = Simulation::try_run_observed(&sc, &mut green(), &mut sink).unwrap();
        assert_eq!(untraced, traced, "{name}: firehose tracing perturbed the simulation");
        assert_eq!(telem.events_of(EventKind::Completion), traced.completed, "{name}");

        let mut null = NullSink;
        let (counted, _) = Simulation::try_run_observed(&sc, &mut green(), &mut null).unwrap();
        assert_eq!(untraced, counted, "{name}: NullSink observation perturbed the simulation");
    }
}

/// The paper's 0.03 ms scheduling-overhead envelope, measured in-process:
/// per-decision wall-clock cost through the counters-only observation path
/// stays within [`OVERHEAD_ENVELOPE_NS`] (relaxed 10x in debug builds,
/// which is what `cargo test` runs).
#[test]
fn decision_overhead_stays_within_the_paper_envelope() {
    let sc = scenarios::build("paper-3-node", 0, 5_000, 42).unwrap();
    let mut null = NullSink;
    let (report, telem) = Simulation::try_run_observed(&sc, &mut green(), &mut null).unwrap();
    assert!(telem.decide_ns.count >= report.requests, "every arrival was timed");
    let envelope = if cfg!(debug_assertions) {
        OVERHEAD_ENVELOPE_NS * 10.0
    } else {
        OVERHEAD_ENVELOPE_NS
    };
    let mean = telem.decide_ns.mean();
    assert!(
        mean <= envelope,
        "mean decide overhead {mean:.0} ns exceeds the envelope {envelope:.0} ns"
    );
}

/// `--trace-filter decision`: the firehose drops every other kind, but the
/// telemetry counters (pre-filter by design) still see the whole run.
#[test]
fn filtered_firehose_drops_lines_but_telemetry_counts_everything() {
    let sc = scenarios::build("paper-3-node", 0, 2_000, 7).unwrap();
    let filter = TraceFilter::parse("decision").unwrap();
    let mut sink = FirehoseSink::with_filter(Vec::new(), filter);
    let (report, telem) = Simulation::try_run_observed(&sc, &mut green(), &mut sink).unwrap();
    let written = sink.events_written();
    let text = String::from_utf8(sink.finish().unwrap()).unwrap();
    assert_eq!(text.lines().count() as u64, written);
    assert!(written > 0, "no decision lines written");
    for line in text.lines() {
        let v = Json::parse(line).unwrap();
        assert_eq!(v.req_str("kind").unwrap(), "decision");
    }
    assert_eq!(telem.events_of(EventKind::Arrival), report.requests);
    assert_eq!(telem.events_of(EventKind::Dispatch), report.completed);
    assert_eq!(telem.events_of(EventKind::Decision), written);
}

/// The batched service path through the firehose: one `batch_formed`
/// line per sealed batch whose fills sum to exactly the completions, a
/// per-class seal count that matches the report's `ClassUsage` rows, and
/// (a grid-only fleet) a completion-carbon replay of the dynamic total.
#[test]
fn batch_serving_firehose_conserves_fills_and_replays_dynamic_carbon() {
    let (report, telem, text) = observed("batch-serving", 3_000, 7);
    let mut fills = 0u64;
    let mut seals_per_class: BTreeMap<i64, u64> = BTreeMap::new();
    let mut batch_lines = 0u64;
    let mut completion_carbon = 0.0;
    for line in text.lines() {
        let v = Json::parse(line).unwrap();
        match v.req_str("kind").unwrap() {
            "batch_formed" => {
                batch_lines += 1;
                let fill = v.get("fill").unwrap().as_i64().unwrap();
                assert!(fill >= 1, "empty batch sealed: {line}");
                fills += fill as u64;
                *seals_per_class
                    .entry(v.get("class").unwrap().as_i64().unwrap())
                    .or_insert(0) += 1;
                assert!(v.req_f64("head_wait_ms").unwrap() >= 0.0, "{line}");
            }
            "completion" => completion_carbon += v.req_f64("carbon_g").unwrap(),
            _ => {}
        }
    }
    assert_eq!(batch_lines, telem.events_of(EventKind::BatchFormed));
    assert_eq!(fills, report.completed, "batch fills must sum to completions");
    assert_eq!(report.classes.len(), 3);
    for (c, class) in report.classes.iter().enumerate() {
        assert_eq!(
            seals_per_class.get(&(c as i64)).copied().unwrap_or(0),
            class.batches,
            "{}: sealed-batch count mismatch",
            class.name
        );
    }
    assert!(
        (completion_carbon - report.carbon_dynamic_g_total).abs()
            <= 1e-6 * report.carbon_dynamic_g_total.max(1e-12),
        "completion carbon {completion_carbon} != dynamic total {}",
        report.carbon_dynamic_g_total
    );
}

/// The tentpole guarantee: an `all`-filter firehose is a complete ledger.
/// For every scenario in the library, folding the trace back through
/// [`replay::replay_report`] reconstructs the live [`SimReport`] — integer
/// counters exactly, energy/carbon totals and per-node splits within the
/// replay tolerance — with zero mismatches from [`replay::verify`].
#[test]
fn replay_reconstructs_every_library_scenario_report() {
    for name in scenarios::SCENARIO_NAMES {
        let (live, telem, text) = observed(name, 1_500, 7);
        let (replayed, events) = replay::replay_report(text.as_bytes())
            .unwrap_or_else(|e| panic!("{name}: replay failed: {e}"));
        assert_eq!(events, telem.total_events(), "{name}: replayed event count");
        let mismatches = replay::verify(&replayed, &live);
        assert!(
            mismatches.is_empty(),
            "{name}: replay drifted from the live report:\n  {}",
            mismatches.join("\n  ")
        );
        // Headline counters must be exact, not merely within tolerance.
        assert_eq!(replayed.requests, live.requests, "{name}: requests");
        assert_eq!(replayed.completed, live.completed, "{name}: completed");
        assert_eq!(replayed.rejected, live.rejected, "{name}: rejected");
        assert_eq!(replayed.deferred, live.deferred, "{name}: deferred");
        assert_eq!(replayed.deadline_missed, live.deadline_missed, "{name}: missed");
        assert_eq!(replayed.scenario, live.scenario, "{name}: header");
    }
}

/// The WAN ledger balances end to end: on the geographic scenarios every
/// shipped request leaves one `wan_hop` line carrying its transfer
/// latency, energy, and origin-priced carbon; the lines sum to exactly
/// the report's WAN totals; and folding the trace back through
/// [`replay::replay_report`] reconstructs the per-site rows and router
/// header with zero [`replay::verify`] mismatches.
#[test]
fn wan_hops_balance_the_site_ledger_and_replay_to_the_live_report() {
    for name in ["multi-site", "follow-the-sun"] {
        let (live, telem, text) = observed(name, 4_000, 7);
        assert!(live.wan_shipped > 0, "{name}: no cross-site traffic");
        let mut hops = 0u64;
        let mut energy_j = 0.0;
        let mut carbon_g = 0.0;
        for line in text.lines() {
            let v = Json::parse(line).unwrap();
            if v.req_str("kind").unwrap() == "wan_hop" {
                hops += 1;
                assert!(v.req_f64("latency_ms").unwrap() > 0.0, "{name}: free hop: {line}");
                energy_j += v.req_f64("energy_j").unwrap();
                carbon_g += v.req_f64("carbon_g").unwrap();
                assert_ne!(
                    v.req_str("from").unwrap(),
                    v.req_str("to").unwrap(),
                    "{name}: self-hop shipped: {line}"
                );
            }
        }
        assert_eq!(hops, telem.events_of(EventKind::WanHop), "{name}: hop count");
        assert_eq!(hops, live.wan_shipped, "{name}: one line per shipped request");
        let want_j = live.energy_wan_kwh_total * 3.6e6;
        assert!(
            (energy_j - want_j).abs() <= 1e-6 * want_j.max(1e-12),
            "{name}: wan energy {energy_j} J != report {want_j} J"
        );
        assert!(
            (carbon_g - live.carbon_wan_g_total).abs()
                <= 1e-6 * live.carbon_wan_g_total.max(1e-12),
            "{name}: wan carbon {carbon_g} != report {}",
            live.carbon_wan_g_total
        );
        // Site rows partition the shipped counts (no request leaks).
        let out: u64 = live.sites.iter().map(|s| s.shipped_out).sum();
        let inn: u64 = live.sites.iter().map(|s| s.shipped_in).sum();
        assert_eq!(out, hops, "{name}: shipped_out rows");
        assert_eq!(inn, hops, "{name}: shipped_in rows");
        // And the trace replays into the same site ledger.
        let (replayed, _) = replay::replay_report(text.as_bytes()).unwrap();
        let mismatches = replay::verify(&replayed, &live);
        assert!(
            mismatches.is_empty(),
            "{name}: WAN replay drift:\n  {}",
            mismatches.join("\n  ")
        );
        assert_eq!(replayed.wan_shipped, live.wan_shipped, "{name}: replayed shipped");
        assert_eq!(replayed.router, live.router, "{name}: replayed router");
    }
}

/// Monitors ride the same never-perturb contract as tracing: a monitored
/// NullSink run produces a bit-identical report (monitor summaries live in
/// their own field) across the whole scenario library, the telemetry
/// carries the same summary rows, and a zero budget fires on any run that
/// emits carbon at all.
#[test]
fn monitored_run_report_stays_bit_identical_to_unmonitored() {
    for name in scenarios::SCENARIO_NAMES {
        let sc = scenarios::build(name, 0, 1_500, 7).unwrap();
        let baseline = Simulation::try_run(&sc, &mut green()).unwrap();
        let monitors = MonitorSet::new(600.0)
            .carbon_budget(CarbonBudget { g_per_s: 0.0 })
            .slo_burn_pct(0.0)
            .reject_defer_pct(0.0);
        let mut null = NullSink;
        let (mut monitored, telem) =
            Simulation::try_run_monitored(&sc, &mut green(), &mut null, monitors).unwrap();
        assert_eq!(monitored.monitors.len(), 3, "{name}: one summary per rule");
        assert_eq!(telem.monitors, monitored.monitors, "{name}: telemetry copy");
        if baseline.carbon_g_total > 0.0 {
            assert!(
                monitored.monitors[0].alerts >= 1,
                "{name}: a zero carbon budget must fire on a carbon-emitting run"
            );
        }
        monitored.monitors = Vec::new();
        assert_eq!(baseline, monitored, "{name}: monitors perturbed the simulation");
    }
}

/// `replay --diff` semantics: a trace diffed against itself is clean, an
/// injected single-field perturbation is pinpointed (kind, virtual time,
/// field) order-stably, and a seed-perturbed twin diverges immediately.
#[test]
fn diff_is_order_stable_and_detects_an_injected_divergence() {
    let (_, _, trace) = observed("paper-3-node", 2_000, 7);
    assert_eq!(replay::diff(trace.as_bytes(), trace.as_bytes()).unwrap(), None);
    // Flip one boolean field on one completion mid-stream.
    let needle = "\"slo_missed\":false";
    let pos = trace.rfind(needle).expect("a completion line to perturb");
    let mut twin = String::with_capacity(trace.len());
    twin.push_str(&trace[..pos]);
    twin.push_str("\"slo_missed\":true");
    twin.push_str(&trace[pos + needle.len()..]);
    let d = replay::diff(trace.as_bytes(), twin.as_bytes()).unwrap().expect("must diverge");
    assert_eq!(d.kind, "completion");
    assert_eq!(d.field, "slo_missed");
    assert!(d.t_s >= 0.0, "divergence carries the virtual time");
    let rendered = d.render();
    assert!(rendered.contains("completion") && rendered.contains("slo_missed"), "{rendered}");
    // Order-stable: the same pair names the same first divergence.
    let again = replay::diff(trace.as_bytes(), twin.as_bytes()).unwrap().unwrap();
    assert_eq!(d, again);
    // Determinism debugging: a seed-perturbed twin diverges at the header.
    let (_, _, other) = observed("paper-3-node", 2_000, 8);
    let header = replay::diff(trace.as_bytes(), other.as_bytes()).unwrap().expect("seeds differ");
    assert_eq!(header.kind, "run_meta");
}

/// A breached monitor streams `alert` events into the firehose, counts
/// them in telemetry, and leaves matching summary rows in the report — and
/// the monitored trace still replays to the live report.
#[test]
fn tight_carbon_budget_fires_alerts_into_the_firehose_and_report() {
    let sc = scenarios::build("paper-3-node", 0, 2_000, 7).unwrap();
    let monitors = MonitorSet::parse("carbon-budget=0,window=600").unwrap();
    let mut sink = FirehoseSink::new(Vec::new());
    let (report, telem) =
        Simulation::try_run_monitored(&sc, &mut green(), &mut sink, monitors).unwrap();
    let text = String::from_utf8(sink.finish().unwrap()).unwrap();
    let mut alert_lines = 0u64;
    for line in text.lines() {
        let v = Json::parse(line).unwrap();
        if v.req_str("kind").unwrap() == "alert" {
            alert_lines += 1;
            assert_eq!(v.req_str("rule").unwrap(), "carbon-budget");
            assert!(v.req_f64("value").unwrap() > 0.0, "alert below threshold: {line}");
            assert_eq!(v.req_f64("threshold").unwrap(), 0.0);
            assert_eq!(v.req_f64("window_s").unwrap(), 600.0);
        }
    }
    assert!(alert_lines >= 1, "a zero budget must fire at least once");
    assert_eq!(telem.events_of(EventKind::Alert), alert_lines);
    assert_eq!(report.monitors.len(), 1);
    let m = &report.monitors[0];
    assert_eq!(m.rule, "carbon-budget");
    assert_eq!(m.alerts, alert_lines);
    assert!(m.first_alert_s.is_some());
    assert!(m.peak > 0.0);
    assert_eq!(telem.monitors, report.monitors);
    // An all-filter monitored trace replays like any other.
    let (replayed, _) = replay::replay_report(text.as_bytes()).unwrap();
    let mismatches = replay::verify(&replayed, &report);
    assert!(
        mismatches.is_empty(),
        "monitored trace replay drift:\n  {}",
        mismatches.join("\n  ")
    );
}
