//! Waived fixture: the same P1 hazard as `p1.rs`, suppressed by an
//! inline waiver documenting the invariant that makes it safe.

/// Pick the first candidate; the caller guarantees a non-empty slate.
pub fn first_choice(candidates: &[usize]) -> usize {
    // lint: allow(P1 caller guarantees a non-empty candidate slate)
    *candidates.first().unwrap()
}
