//! Baseline schedulers the paper compares against (Sec. IV-A4) plus the
//! standard strawmen used in the ablation benches. All answer `decide`
//! with `Assign`/`Reject` only — none of them defers, so under a deferral
//! scenario the simulator wraps them in the legacy
//! [`super::RouteThenDefer`] gate.

use crate::util::rng::Rng;

use super::{
    CarbonAwareScheduler, FleetView, Scheduler, SchedulingDecision, TaskDemand, Weights,
};

/// AMP4EC (the authors' prior framework): the same NSA **without** carbon
/// awareness — Eq. 3 with `w_C = 0` and the remaining weights in
/// Performance-mode proportions, renormalized.
pub struct Amp4ecScheduler {
    inner: CarbonAwareScheduler,
}

impl Amp4ecScheduler {
    pub fn new() -> Amp4ecScheduler {
        // Performance row of Table I with the carbon column removed.
        let w = Weights { r: 0.25, l: 0.25, p: 0.30, b: 0.15, c: 0.0 }.normalized();
        Amp4ecScheduler { inner: CarbonAwareScheduler::new("amp4ec", w) }
    }
}

impl Default for Amp4ecScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Amp4ecScheduler {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        self.inner.decide(task, fleet)
    }
    fn name(&self) -> &str {
        "amp4ec"
    }
}

/// Round-robin over feasible nodes.
pub struct RoundRobinScheduler {
    next: usize,
}

impl RoundRobinScheduler {
    pub fn new() -> RoundRobinScheduler {
        RoundRobinScheduler { next: 0 }
    }
}

impl Default for RoundRobinScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for RoundRobinScheduler {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        for k in 0..fleet.nodes.len() {
            let i = (self.next + k) % fleet.nodes.len();
            if fleet.nodes[i].fits(task) {
                self.next = (i + 1) % fleet.nodes.len();
                return SchedulingDecision::Assign(i);
            }
        }
        SchedulingDecision::reject()
    }
    fn name(&self) -> &str {
        "round-robin"
    }
}

/// Uniform random over feasible nodes (seeded).
pub struct RandomScheduler {
    rng: Rng,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> RandomScheduler {
        RandomScheduler { rng: Rng::new(seed) }
    }
}

impl Scheduler for RandomScheduler {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        let feasible: Vec<usize> =
            (0..fleet.nodes.len()).filter(|&i| fleet.nodes[i].fits(task)).collect();
        if feasible.is_empty() {
            SchedulingDecision::reject()
        } else {
            SchedulingDecision::Assign(feasible[self.rng.below(feasible.len())])
        }
    }
    fn name(&self) -> &str {
        "random"
    }
}

/// Fewest in-flight tasks wins (ties: lowest index).
pub struct LeastLoadedScheduler;

impl Scheduler for LeastLoadedScheduler {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        SchedulingDecision::from_choice(
            fleet
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, v)| v.fits(task))
                .min_by_key(|(_, v)| v.state.inflight)
                .map(|(i, _)| i),
        )
    }
    fn name(&self) -> &str {
        "least-loaded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeRegistry;

    fn pick(s: &mut dyn Scheduler, task: &TaskDemand, r: &NodeRegistry) -> Option<usize> {
        s.decide(task, &FleetView::observe(r.nodes())).assigned()
    }

    #[test]
    fn amp4ec_ignores_carbon() {
        // AMP4EC must pick the fast node regardless of its intensity —
        // exactly why Table II shows it *increasing* carbon vs monolithic.
        let r = NodeRegistry::paper_setup();
        let mut s = Amp4ecScheduler::new();
        let i = pick(&mut s, &TaskDemand::default(), &r).unwrap();
        assert_eq!(r.get(i).spec.name, "node-high");
        assert_eq!(s.name(), "amp4ec");
        assert!(!s.defers());
    }

    #[test]
    fn round_robin_cycles_feasible() {
        let r = NodeRegistry::paper_setup();
        let mut s = RoundRobinScheduler::new();
        let picks: Vec<usize> =
            (0..6).map(|_| pick(&mut s, &TaskDemand::default(), &r).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_infeasible() {
        let r = NodeRegistry::paper_setup();
        // 800MB only fits node-high
        let task = TaskDemand { mem_mb: 800, ..TaskDemand::default() };
        let mut s = RoundRobinScheduler::new();
        for _ in 0..4 {
            assert_eq!(pick(&mut s, &task, &r), Some(0));
        }
    }

    #[test]
    fn random_is_seeded_and_feasible() {
        let r = NodeRegistry::paper_setup();
        let mut a = RandomScheduler::new(9);
        let mut b = RandomScheduler::new(9);
        for _ in 0..20 {
            let x = pick(&mut a, &TaskDemand::default(), &r);
            let y = pick(&mut b, &TaskDemand::default(), &r);
            assert_eq!(x, y);
            assert!(x.unwrap() < 3);
        }
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let r = NodeRegistry::paper_setup();
        r.get(0).begin_task();
        let mut s = LeastLoadedScheduler;
        let i = pick(&mut s, &TaskDemand::default(), &r).unwrap();
        assert_ne!(i, 0);
    }

    #[test]
    fn all_reject_when_infeasible() {
        let r = NodeRegistry::paper_setup();
        let task = TaskDemand { mem_mb: 1 << 20, ..TaskDemand::default() };
        let fleet = FleetView::observe(r.nodes());
        assert_eq!(Amp4ecScheduler::new().decide(&task, &fleet), SchedulingDecision::reject());
        assert_eq!(
            RoundRobinScheduler::new().decide(&task, &fleet),
            SchedulingDecision::reject()
        );
        assert_eq!(RandomScheduler::new(1).decide(&task, &fleet), SchedulingDecision::reject());
        assert_eq!(LeastLoadedScheduler.decide(&task, &fleet), SchedulingDecision::reject());
    }
}
