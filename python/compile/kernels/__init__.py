"""CarbonEdge L1 Pallas kernels (build-time only; lowered into model HLO)."""

from .matmul import matmul_bias_act, apply_act
from .depthwise import depthwise3x3, same_pad
from .pool import avgpool_global

__all__ = [
    "matmul_bias_act",
    "apply_act",
    "depthwise3x3",
    "same_pad",
    "avgpool_global",
]
