//! Temporal carbon-aware deferral — the paper's Sec. II-E observation
//! ("deferring non-urgent tasks to low-carbon time periods") and its
//! "real-time carbon intensity integration" future-work item, implemented
//! against [`IntensityTrace`].

use super::IntensityTrace;

/// Decision for a deferrable task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeferDecision {
    /// Run now at the current intensity.
    RunNow { intensity: f64 },
    /// Wait until `at_s` (experiment clock) where intensity is lower.
    Defer { at_s: f64, intensity: f64 },
}

/// Policy: run a task now, or defer it (within a deadline) to the
/// lowest-intensity slot the trace forecasts.
#[derive(Debug, Clone)]
pub struct DeferralPolicy {
    /// Forecast sampling resolution (seconds).
    pub resolution_s: f64,
    /// Minimum relative improvement required to defer (e.g. 0.05 = 5%).
    pub min_gain: f64,
}

impl Default for DeferralPolicy {
    fn default() -> Self {
        DeferralPolicy { resolution_s: 300.0, min_gain: 0.05 }
    }
}

impl DeferralPolicy {
    /// Invariant check, run once at `SimConfig`/scenario build time (and
    /// by the CLI) so the per-arrival hot path can keep plain
    /// `debug_assert!`s instead of panicking mid-simulation.
    pub fn validate(&self) -> Result<(), String> {
        if !self.resolution_s.is_finite() || self.resolution_s <= 0.0 {
            return Err(format!(
                "deferral resolution must be finite and > 0, got {}",
                self.resolution_s
            ));
        }
        if !self.min_gain.is_finite() || !(0.0..=1.0).contains(&self.min_gain) {
            return Err(format!("deferral min_gain must be in [0, 1], got {}", self.min_gain));
        }
        Ok(())
    }

    /// Sample an intensity function from `now_s` to `horizon_s` at the
    /// policy resolution, clamping the final sample to the horizon itself:
    /// when the window is not a multiple of the resolution, a naive
    /// `t += resolution` walk overshoots and never prices a trough sitting
    /// on the horizon boundary. This is the single source of the sampling
    /// walk — [`DeferralPolicy::decide`] and the simulator's `FleetView`
    /// forecasts (grid-only *and* microgrid-projected) both build on it,
    /// so their slot grids always agree.
    ///
    /// Invariants (`resolution_s > 0`, window not reversed) are validated
    /// once at build time ([`DeferralPolicy::validate`]); here they are
    /// only debug-asserted, and a degenerate input degrades to a single
    /// "now" sample instead of panicking (or hanging) mid-simulation.
    pub fn forecast(
        &self,
        intensity_at: impl Fn(f64) -> f64,
        now_s: f64,
        horizon_s: f64,
    ) -> Vec<(f64, f64)> {
        debug_assert!(horizon_s >= now_s, "forecast window reversed");
        debug_assert!(self.resolution_s > 0.0, "forecast resolution must be positive");
        let span = horizon_s - now_s;
        if self.resolution_s <= 0.0 || !self.resolution_s.is_finite() || span <= 0.0 || !span.is_finite()
        {
            return vec![(now_s, intensity_at(now_s))];
        }
        let mut out =
            Vec::with_capacity(((horizon_s - now_s) / self.resolution_s) as usize + 2);
        let mut t = now_s;
        loop {
            out.push((t, intensity_at(t)));
            if t >= horizon_s {
                break;
            }
            t = (t + self.resolution_s).min(horizon_s);
        }
        out
    }

    /// Decide over a pre-sampled forecast whose first entry is "now". An
    /// empty forecast (a task with no usable slack) always runs now.
    pub fn decide_samples(&self, forecast: &[(f64, f64)]) -> DeferDecision {
        let Some(&(t0, now_i)) = forecast.first() else {
            return DeferDecision::RunNow { intensity: 0.0 };
        };
        let mut best_t = t0;
        let mut best_i = now_i;
        for &(t, i) in forecast {
            if i < best_i {
                best_i = i;
                best_t = t;
            }
        }
        if best_t > t0 && best_i < now_i * (1.0 - self.min_gain) {
            DeferDecision::Defer { at_s: best_t, intensity: best_i }
        } else {
            DeferDecision::RunNow { intensity: now_i }
        }
    }

    /// Decide for a task arriving at `now_s` with slack until
    /// `deadline_s` (absolute, experiment clock).
    pub fn decide(&self, trace: &IntensityTrace, now_s: f64, deadline_s: f64) -> DeferDecision {
        // Demoted: per-arrival hot path; deadlines are checked at admission.
        debug_assert!(deadline_s >= now_s);
        self.decide_samples(&self.forecast(|t| trace.at(t), now_s, deadline_s))
    }

    /// Expected carbon saving (grams) of the decision for a task of
    /// `energy_kwh`.
    pub fn saving_g(
        &self,
        trace: &IntensityTrace,
        now_s: f64,
        deadline_s: f64,
        energy_kwh: f64,
    ) -> f64 {
        match self.decide(trace, now_s, deadline_s) {
            DeferDecision::RunNow { .. } => 0.0,
            DeferDecision::Defer { intensity, .. } => {
                (trace.at(now_s) - intensity) * energy_kwh
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diurnal() -> IntensityTrace {
        // Peak ~710 at 06:00, trough ~350 at 18:00 (mean 530 ± 180).
        IntensityTrace::Diurnal { mean: 530.0, amplitude: 180.0, period_s: 86_400.0, phase_s: 0.0 }
    }

    #[test]
    fn static_trace_never_defers() {
        let p = DeferralPolicy::default();
        let d = p.decide(&IntensityTrace::Static(530.0), 0.0, 86_400.0);
        assert_eq!(d, DeferDecision::RunNow { intensity: 530.0 });
    }

    #[test]
    fn defers_from_peak_to_trough() {
        let p = DeferralPolicy::default();
        // At 06:00 (peak), with 24h slack, defer towards the trough.
        let d = p.decide(&diurnal(), 21_600.0, 21_600.0 + 86_400.0);
        match d {
            DeferDecision::Defer { intensity, at_s } => {
                assert!(intensity < 380.0, "deferred intensity {intensity}");
                assert!(at_s > 21_600.0);
            }
            other => panic!("expected defer, got {other:?}"),
        }
    }

    #[test]
    fn tight_deadline_runs_now() {
        let p = DeferralPolicy::default();
        // 10 minutes of slack at the peak: intensity barely moves.
        let d = p.decide(&diurnal(), 21_600.0, 21_600.0 + 600.0);
        assert!(matches!(d, DeferDecision::RunNow { .. }));
    }

    #[test]
    fn saving_positive_when_deferring() {
        let p = DeferralPolicy::default();
        let kwh = 1e-5; // one paper-scale inference
        let s = p.saving_g(&diurnal(), 21_600.0, 21_600.0 + 86_400.0, kwh);
        assert!(s > 0.0);
        // trough -> no saving available
        let s2 = p.saving_g(&diurnal(), 64_800.0, 64_800.0 + 3_600.0, kwh);
        assert_eq!(s2, 0.0);
    }

    #[test]
    fn trough_on_deadline_boundary_is_sampled() {
        // Regression: slack (999 s) is not a multiple of the resolution
        // (300 s), and the only trough sits exactly at the deadline. The
        // old `t += resolution` walk sampled 0/300/600/900 and then
        // overshot past 999, returning RunNow.
        let p = DeferralPolicy { resolution_s: 300.0, min_gain: 0.05 };
        let trace = IntensityTrace::Trace(vec![(0.0, 500.0), (999.0, 100.0)]);
        match p.decide(&trace, 0.0, 999.0) {
            DeferDecision::Defer { at_s, intensity } => {
                assert_eq!(at_s, 999.0);
                assert_eq!(intensity, 100.0);
            }
            other => panic!("deadline-boundary trough missed: {other:?}"),
        }
        // Zero slack degenerates to a single sample at now.
        let d = p.decide(&trace, 0.0, 0.0);
        assert_eq!(d, DeferDecision::RunNow { intensity: 500.0 });
    }

    #[test]
    fn validate_catches_bad_knobs() {
        assert!(DeferralPolicy::default().validate().is_ok());
        assert!(DeferralPolicy { resolution_s: 0.0, min_gain: 0.05 }.validate().is_err());
        assert!(DeferralPolicy { resolution_s: -1.0, min_gain: 0.05 }.validate().is_err());
        assert!(DeferralPolicy { resolution_s: f64::NAN, min_gain: 0.05 }.validate().is_err());
        assert!(DeferralPolicy { resolution_s: 300.0, min_gain: 1.5 }.validate().is_err());
        assert!(DeferralPolicy { resolution_s: 300.0, min_gain: -0.1 }.validate().is_err());
    }

    #[test]
    fn forecast_walk_clamps_to_horizon() {
        let p = DeferralPolicy { resolution_s: 300.0, min_gain: 0.05 };
        let fc = p.forecast(|t| t, 0.0, 999.0);
        let times: Vec<f64> = fc.iter().map(|&(t, _)| t).collect();
        assert_eq!(times, vec![0.0, 300.0, 600.0, 900.0, 999.0]);
        // Zero-width window: a single "now" sample.
        assert_eq!(p.forecast(|_| 5.0, 10.0, 10.0), vec![(10.0, 5.0)]);
    }

    #[test]
    fn decide_samples_matches_trace_decide_and_handles_empty() {
        // The trace-walking decide and the pre-sampled decide are the same
        // decision — the simulator's FleetView forecasts rely on it.
        let p = DeferralPolicy { resolution_s: 300.0, min_gain: 0.05 };
        let trace = IntensityTrace::Trace(vec![(0.0, 500.0), (999.0, 100.0)]);
        let fc = p.forecast(|t| trace.at(t), 0.0, 999.0);
        assert_eq!(p.decide_samples(&fc), p.decide(&trace, 0.0, 999.0));
        let diurnal = diurnal();
        let fc = p.forecast(|t| diurnal.at(t), 21_600.0, 21_600.0 + 86_400.0);
        assert_eq!(
            p.decide_samples(&fc),
            p.decide(&diurnal, 21_600.0, 21_600.0 + 86_400.0)
        );
        // No forecast context -> run now, never a defer.
        assert_eq!(p.decide_samples(&[]), DeferDecision::RunNow { intensity: 0.0 });
    }

    #[test]
    fn min_gain_threshold_respected() {
        let strict = DeferralPolicy { resolution_s: 300.0, min_gain: 0.99 };
        let d = strict.decide(&diurnal(), 21_600.0, 21_600.0 + 86_400.0);
        assert!(matches!(d, DeferDecision::RunNow { .. }));
    }
}
