//! # L3.5 — the discrete-event fleet simulator
//!
//! The real `ServingLoop` executes one request at a time against PJRT and
//! sleeps on the wall clock — high fidelity, but physically incapable of the
//! regimes where carbon-aware policies actually differentiate: load
//! contention, temporal intensity variation, and fleet heterogeneity
//! (GreenScale, Ecomap). This module trades the real executor for the
//! calibrated per-node models the repo already has and runs everything on a
//! **virtual clock**:
//!
//! * a deterministic binary-heap event queue over virtual seconds;
//! * per-node FIFO queues with bounded concurrency;
//! * service times from the `NodeSpec` latency model
//!   (`t_exec·(1 + α·(1/quota − 1)) + overhead`) with seeded lognormal
//!   jitter via [`crate::util::rng`];
//! * energy from `rated_power_w`, emissions via
//!   [`crate::carbon::emissions_g`] evaluated against the **time-varying**
//!   [`crate::carbon::IntensityTrace`] at each task's virtual completion
//!   time — `Diurnal`/`Trace` finally sit on the scheduling path;
//! * scheduling through the existing [`crate::scheduler::Scheduler`] trait:
//!   schedulers see queue depth + in-flight as `inflight`, and the current
//!   virtual-time grid intensity via `EdgeNode::intensity()`.
//!
//! Identical seeds produce identical [`SimReport`]s; millions of simulated
//! requests run in seconds (`benches/sim.rs`). The scenario library lives
//! in [`scenarios`]; fleet synthesis in [`fleet`].

mod engine;
pub mod fleet;
mod report;
pub mod scenarios;

pub use engine::{ArrivalProcess, ChurnEvent, SimConfig, Simulation};
pub use report::{NodeUsage, SimReport};
pub use scenarios::{Scenario, SCENARIO_NAMES};
