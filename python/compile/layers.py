"""L2 layer library: JAX building blocks that call the L1 Pallas kernels.

Every convolution in the model zoo routes through the Pallas kernels:
  * k x k conv  -> im2col + ``matmul_bias_act``      (stem, head, 1x1)
  * depthwise   -> ``depthwise3x3``
  * global pool -> ``avgpool_global``
BatchNorm is folded into the conv weights at build time (inference-time BN
folding), so each layer is a single fused conv+bias+act kernel call.

Layers also carry the paper's Eq. 5 cost metadata::

    Cost(l) = kh*kw*Cin*Cout   (Conv2D)
            | Nin*Nout         (Linear)
            | params_count     (others)

which `aot.py` exports in the manifest for the Rust partitioner.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .kernels import matmul_bias_act, depthwise3x3, avgpool_global, same_pad


@dataclasses.dataclass
class LayerMeta:
    """Per-layer record exported to the manifest (drives Eq. 5 in Rust)."""

    name: str
    kind: str  # conv2d | linear | depthwise | pool | add | scale
    params: int
    cost: int  # paper Eq. 5
    flops: int
    in_shape: tuple
    out_shape: tuple

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "params": self.params,
            "cost": self.cost,
            "flops": self.flops,
            "in_shape": list(self.in_shape),
            "out_shape": list(self.out_shape),
        }


class Initializer:
    """Deterministic He-normal initializer (numpy PRNG, seeded)."""

    def __init__(self, seed: int):
        self.rng = np.random.RandomState(seed)

    def conv(self, kh, kw, cin, cout):
        fan_in = kh * kw * cin
        w = self.rng.randn(kh, kw, cin, cout).astype(np.float32) * np.sqrt(2.0 / fan_in)
        # Folded-BN bias: small random offset (a trained model would carry
        # the folded running stats here).
        b = (self.rng.randn(cout) * 0.01).astype(np.float32)
        return jnp.asarray(w), jnp.asarray(b)

    def dw(self, c):
        w = self.rng.randn(3, 3, c).astype(np.float32) * np.sqrt(2.0 / 9.0)
        b = (self.rng.randn(c) * 0.01).astype(np.float32)
        return jnp.asarray(w), jnp.asarray(b)

    def dense(self, nin, nout):
        w = self.rng.randn(nin, nout).astype(np.float32) * np.sqrt(2.0 / nin)
        b = np.zeros(nout, np.float32)
        return jnp.asarray(w), jnp.asarray(b)


def im2col(x, k: int, stride: int):
    """Extract k x k patches (SAME padding) -> ``(Ho*Wo, k*k*Cin)``."""
    h, w, c = x.shape
    ho, plo_h, phi_h = same_pad(h, k, stride)
    wo, plo_w, phi_w = same_pad(w, k, stride)
    xp = jnp.pad(x, ((plo_h, phi_h), (plo_w, phi_w), (0, 0)))
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(
                xp[
                    di : di + (ho - 1) * stride + 1 : stride,
                    dj : dj + (wo - 1) * stride + 1 : stride,
                    :,
                ]
            )
    patches = jnp.concatenate(cols, axis=-1)  # (Ho, Wo, k*k*C)
    return patches.reshape(ho * wo, k * k * c), (ho, wo)


def conv2d(x, w, b, stride: int = 1, act: str = "none"):
    """k x k conv (SAME) via im2col + the Pallas matmul kernel.

    ``x (H,W,Cin)``, ``w (kh,kw,Cin,Cout)`` -> ``(Ho,Wo,Cout)``.
    """
    kh, kw, cin, cout = w.shape
    assert kh == kw, "square kernels only"
    if kh == 1 and stride == 1:
        h, wdt, _ = x.shape
        out = matmul_bias_act(x.reshape(h * wdt, cin), w.reshape(cin, cout), b, act)
        return out.reshape(h, wdt, cout)
    cols, (ho, wo) = im2col(x, kh, stride)
    out = matmul_bias_act(cols, w.reshape(kh * kw * cin, cout), b, act)
    return out.reshape(ho, wo, cout)


def dense(x, w, b, act: str = "none"):
    """``x (Nin,) @ w (Nin,Nout) + b`` via the Pallas matmul kernel."""
    return matmul_bias_act(x[None, :], w, b, act)[0]


def squeeze_excite(x, w1, b1, w2, b2):
    """SE block: GAP -> reduce(silu) -> expand(sigmoid) -> channel scale."""
    s = avgpool_global(x)
    s = dense(s, w1, b1, act="silu")
    s = dense(s, w2, b2, act="sigmoid")
    return x * s[None, None, :]


# ---------------------------------------------------------------------------
# Metadata helpers (Eq. 5 + FLOPs)
# ---------------------------------------------------------------------------


def conv_meta(name, kh, cin, cout, in_shape, out_shape) -> LayerMeta:
    ho, wo = out_shape[0], out_shape[1]
    params = kh * kh * cin * cout + cout
    return LayerMeta(
        name=name,
        kind="conv2d",
        params=params,
        cost=kh * kh * cin * cout,  # Eq. 5 Conv2D branch
        flops=2 * kh * kh * cin * cout * ho * wo,
        in_shape=in_shape,
        out_shape=out_shape,
    )


def dw_meta(name, c, in_shape, out_shape) -> LayerMeta:
    ho, wo = out_shape[0], out_shape[1]
    params = 9 * c + c
    return LayerMeta(
        name=name,
        kind="depthwise",
        params=params,
        cost=params,  # Eq. 5 "others" branch: params_count
        flops=2 * 9 * c * ho * wo,
        in_shape=in_shape,
        out_shape=out_shape,
    )


def linear_meta(name, nin, nout) -> LayerMeta:
    return LayerMeta(
        name=name,
        kind="linear",
        params=nin * nout + nout,
        cost=nin * nout,  # Eq. 5 Linear branch
        flops=2 * nin * nout,
        in_shape=(nin,),
        out_shape=(nout,),
    )


def misc_meta(name, kind, params, in_shape, out_shape, flops=0) -> LayerMeta:
    return LayerMeta(
        name=name,
        kind=kind,
        params=params,
        cost=params,  # Eq. 5 "others"
        flops=flops,
        in_shape=in_shape,
        out_shape=out_shape,
    )
