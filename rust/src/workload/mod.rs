//! Workload generation: synthetic inference inputs (the ImageNet-val
//! substitution, DESIGN.md §7) and request arrival processes.

use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// ImageNet normalization constants (paper Sec. IV-A2).
pub const IMAGENET_MEAN: [f32; 3] = [0.485, 0.456, 0.406];
pub const IMAGENET_STD: [f32; 3] = [0.229, 0.224, 0.225];

/// Deterministic synthetic "photo": smooth gradients + seeded noise, then
/// ImageNet normalization. **Must match aot.py's `golden_image`** — the
/// golden-logit integration tests depend on bit-identical inputs for seed 0.
pub fn synthetic_image(image_size: usize, seed: u64) -> Tensor {
    let n = image_size;
    let mut rng = GaussMt::new(seed);
    let mut data = vec![0f32; n * n * 3];
    for y in 0..n {
        for x in 0..n {
            let yy = y as f32 / n as f32;
            let xx = x as f32 / n as f32;
            let base = [yy, xx, 0.5 * (xx + yy)];
            for c in 0..3 {
                let v = base[c] + 0.1 * rng.next_for(y, x, c) as f32;
                let v = v.clamp(0.0, 1.0);
                data[(y * n + x) * 3 + c] = (v - IMAGENET_MEAN[c]) / IMAGENET_STD[c];
            }
        }
    }
    Tensor::new(vec![n, n, 3], data).expect("shape matches")
}

/// numpy `RandomState(seed).randn(...)` compatible generator is out of
/// scope for non-zero seeds; for seed 0 aot.py ships the image as a binary
/// sidecar, which the golden tests read directly. For workload *variety*
/// (the paper's "varied input complexity") any deterministic noise works —
/// this struct provides seeded Gaussian noise per pixel.
struct GaussMt {
    rng: Rng,
}

impl GaussMt {
    fn new(seed: u64) -> GaussMt {
        GaussMt { rng: Rng::new(seed) }
    }
    fn next_for(&mut self, _y: usize, _x: usize, _c: usize) -> f64 {
        self.rng.normal()
    }
}

/// Arrival process for the serving loop.
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Closed loop: next request issued when the previous completes
    /// (the paper's 50-iteration evaluation loop).
    ClosedLoop { count: usize },
    /// Open loop with Poisson arrivals at `rate_hz`.
    Poisson { count: usize, rate_hz: f64, seed: u64 },
}

impl Arrivals {
    pub fn count(&self) -> usize {
        match self {
            Arrivals::ClosedLoop { count } => *count,
            Arrivals::Poisson { count, .. } => *count,
        }
    }

    /// Inter-arrival gaps in seconds (empty for closed-loop).
    pub fn gaps(&self) -> Vec<f64> {
        match self {
            Arrivals::ClosedLoop { .. } => Vec::new(),
            Arrivals::Poisson { count, rate_hz, seed } => {
                let mut rng = Rng::new(*seed);
                (0..*count).map(|_| rng.exp(*rate_hz)).collect()
            }
        }
    }
}

/// A stream of inference requests with per-request input seeds
/// (the paper samples 50 images per experiment).
#[derive(Debug, Clone)]
pub struct RequestStream {
    pub image_size: usize,
    pub arrivals: Arrivals,
    pub seed: u64,
}

impl RequestStream {
    pub fn paper_default(image_size: usize) -> RequestStream {
        RequestStream { image_size, arrivals: Arrivals::ClosedLoop { count: 50 }, seed: 0 }
    }

    /// Generate the request inputs.
    pub fn inputs(&self) -> Vec<Tensor> {
        (0..self.arrivals.count())
            .map(|i| synthetic_image(self.image_size, self.seed.wrapping_add(i as u64)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_shape_and_determinism() {
        let a = synthetic_image(16, 3);
        let b = synthetic_image(16, 3);
        assert_eq!(a.shape, vec![16, 16, 3]);
        assert_eq!(a, b);
        let c = synthetic_image(16, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn image_is_normalized() {
        let t = synthetic_image(32, 0);
        // After mean/std normalization values must straddle zero.
        let min = t.data.iter().cloned().fold(f32::MAX, f32::min);
        let max = t.data.iter().cloned().fold(f32::MIN, f32::max);
        assert!(min < 0.0 && max > 0.0);
        // and stay in a plausible normalized range
        assert!(min > -3.0 && max < 4.0);
    }

    #[test]
    fn closed_loop_counts() {
        let s = RequestStream::paper_default(8);
        assert_eq!(s.arrivals.count(), 50);
        assert_eq!(s.inputs().len(), 50);
        assert!(s.arrivals.gaps().is_empty());
    }

    #[test]
    fn poisson_gaps_have_right_mean() {
        let a = Arrivals::Poisson { count: 20_000, rate_hz: 4.0, seed: 7 };
        let gaps = a.gaps();
        assert_eq!(gaps.len(), 20_000);
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
        assert!(gaps.iter().all(|&g| g >= 0.0));
    }

    #[test]
    fn poisson_gaps_deterministic_and_seed_stable() {
        // Same seed ⇒ identical gaps on every call — the property the
        // simulator's reproducibility rests on.
        let a = Arrivals::Poisson { count: 4, rate_hz: 2.0, seed: 9 };
        assert_eq!(a.gaps(), a.gaps());
        // Pinned against the reference RNG implementation (seed 9, λ = 2).
        let want = [
            0.0012933912623040553,
            0.1448349383570217,
            0.07104812619394953,
            0.6596814003634573,
        ];
        for (g, w) in a.gaps().iter().zip(want) {
            assert!((g - w).abs() < 1e-12, "gap {g} vs pinned {w}");
        }
        // Different seed ⇒ different process.
        let b = Arrivals::Poisson { count: 4, rate_hz: 2.0, seed: 10 };
        assert_ne!(a.gaps(), b.gaps());
    }

    #[test]
    fn distinct_request_inputs() {
        let s =
            RequestStream { image_size: 8, arrivals: Arrivals::ClosedLoop { count: 3 }, seed: 1 };
        let ins = s.inputs();
        assert_ne!(ins[0], ins[1]);
        assert_ne!(ins[1], ins[2]);
    }
}
