//! Micro-benchmark harness substrate (criterion is not in the offline crate
//! set). Used by `benches/*.rs` (with `harness = false`) and the §Perf pass.
//!
//! Method: warmup, then timed batches until both a minimum wall-clock budget
//! and a minimum iteration count are met; reports mean/p50/p95 per-iteration
//! time with a 95% CI.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub per_iter: Summary, // seconds per iteration
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.per_iter;
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  ±{:>5.1}%",
            self.name,
            self.iters,
            fmt_time(s.mean),
            fmt_time(s.p50),
            fmt_time(s.p95),
            s.ci95_rel() * 100.0
        )
    }
}

/// Human-friendly seconds formatting.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Benchmark runner with configurable budget.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            min_iters: 5,
            max_iters: 100_000,
        }
    }

    /// Time `f` repeatedly; one sample per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed samples.
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            per_iter: Summary::of(&samples),
        }
    }

    /// Like `run` but each call of `f` performs `batch` iterations
    /// (for sub-microsecond operations where per-call timing is too noisy).
    pub fn run_batched<F: FnMut()>(&self, name: &str, batch: usize, mut f: F) -> BenchResult {
        assert!(batch > 0);
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(s.elapsed().as_secs_f64() / batch as f64);
        }
        BenchResult {
            name: name.to_string(),
            iters: samples.len() * batch,
            per_iter: Summary::of(&samples),
        }
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box wrapper,
/// kept behind our own name so benches read uniformly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(100),
            min_iters: 3,
            max_iters: 1000,
        };
        let r = b.run("sleep1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.per_iter.mean >= 0.001, "mean {}", r.per_iter.mean);
        assert!(r.per_iter.mean < 0.05);
        assert!(r.iters >= 3);
    }

    #[test]
    fn batched_counts_iters() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let r = b.run_batched("add", 1000, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 5000);
        assert!(r.per_iter.mean < 1e-3);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }

    #[test]
    fn report_contains_name() {
        let b = Bencher::quick();
        let r = b.run("myname", || {});
        assert!(r.report().contains("myname"));
    }
}
