//! The lint rules: determinism (D1–D3), panic-safety (P1–P2) and
//! unit-hygiene (U1), evaluated line-by-line over a
//! [`SourceModel`](crate::analysis::lexer::SourceModel).
//!
//! Rules are deliberately *high-precision*: each one matches a narrow
//! syntactic shape that is almost always a real hazard in this codebase,
//! and anything legitimate gets an inline
//! `// lint: allow(RULE reason)` waiver rather than a looser rule. See
//! [`crate::analysis`] for the rule catalogue and scoping.

use super::lexer::{contains_token, find_token, idents, is_ident_byte, SourceModel};
use super::{Finding, Rule};

/// Wall-clock / ambient-randomness entry points (D2). Anything that
/// reads the host environment breaks replay: virtual time comes from the
/// event queue, randomness from the seeded [`crate::util::rng`] streams.
const D2_TOKENS: [&str; 6] = [
    "Instant::now",
    "SystemTime::now",
    "thread_rng",
    "rand::random",
    "Utc::now",
    "Local::now",
];

/// Methods that iterate a `HashMap`/`HashSet` (D1/D3).
const ITER_METHODS: [&str; 9] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
];

/// Fold adapters that turn an iteration into an f64 accumulation (D3).
const FOLD_METHODS: [&str; 3] = ["sum", "fold", "product"];

/// Run every rule over one sanitized file. `path` is only used for
/// module scoping (see [`crate::analysis::module_of`]); pushes raw,
/// unwaived findings into `out`.
pub fn run(path: &str, model: &SourceModel, out: &mut Vec<Finding>) {
    let module = super::module_of(path);
    let det = super::DET_MODULES.contains(&module.as_str());
    let panic_scope = det || super::PANIC_MODULES.contains(&module.as_str());
    let bench = path.ends_with("util/bench.rs");
    let tracked = tracked_unordered(&model.lines);
    for (ix, text) in model.lines.iter().enumerate() {
        if model.in_test[ix] {
            continue;
        }
        let line = ix + 1;
        let mut push = |rule: Rule| {
            out.push(Finding {
                path: path.to_string(),
                line,
                rule,
                excerpt: excerpt(text),
            });
        };
        if det {
            if let Some(hit) = unordered_iteration(text, &tracked) {
                // D1 and D3 are disjoint: a fold over the unordered
                // iteration is the sharper finding.
                push(if hit.folded { Rule::D3 } else { Rule::D1 });
            }
        }
        if !bench {
            for tok in D2_TOKENS {
                if contains_token(text, tok) {
                    push(Rule::D2);
                    break;
                }
            }
        }
        if panic_scope && (text.contains(".unwrap()") || text.contains(".expect(")) {
            push(Rule::P1);
        }
        if panic_scope
            && has_release_assert(text)
            && !model.fns[ix].iter().any(|f| f.starts_with("validate"))
        {
            push(Rule::P2);
        }
        for _ in 0..unit_mismatches(text) {
            push(Rule::U1);
        }
    }
}

fn excerpt(text: &str) -> String {
    let t = text.trim();
    let mut s: String = t.chars().take(90).collect();
    if s.len() < t.len() {
        s.push('…');
    }
    s
}

/// Names bound or typed as `HashMap`/`HashSet` anywhere in the file:
/// `name: [&][mut] [std::collections::] HashMap<…>` (bindings, fields,
/// params) and `let [mut] name = HashMap::…`.
fn tracked_unordered(lines: &[String]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for text in lines {
        for ty in ["HashMap", "HashSet"] {
            let mut from = 0usize;
            while let Some(pos) = find_token(text, ty, from) {
                from = pos + 1;
                let after = text[pos + ty.len()..].trim_start();
                if after.starts_with('<') {
                    if let Some(name) = annotated_name(text, pos) {
                        if !names.contains(&name) {
                            names.push(name);
                        }
                    }
                } else if after.starts_with("::") {
                    if let Some(name) = let_bound_name(text) {
                        if !names.contains(&name) {
                            names.push(name);
                        }
                    }
                }
            }
        }
    }
    names
}

/// Walk backwards from a `HashMap`/`HashSet` token at `pos` through
/// `mut`, `&`, and `path::` segments to the `:` of a type annotation,
/// returning the annotated identifier.
fn annotated_name(text: &str, pos: usize) -> Option<String> {
    let b = text.as_bytes();
    let mut i = pos;
    loop {
        while i > 0 && b[i - 1].is_ascii_whitespace() {
            i -= 1;
        }
        if i == 0 {
            return None;
        }
        if b[i - 1] == b'&' {
            i -= 1;
            continue;
        }
        if b[i - 1] == b':' {
            if i >= 2 && b[i - 2] == b':' {
                // `::` path separator — skip it and the segment before
                i -= 2;
                while i > 0 && is_ident_byte(b[i - 1]) {
                    i -= 1;
                }
                continue;
            }
            // the annotation colon: the name sits just before it
            i -= 1;
            while i > 0 && b[i - 1].is_ascii_whitespace() {
                i -= 1;
            }
            let end = i;
            while i > 0 && is_ident_byte(b[i - 1]) {
                i -= 1;
            }
            if end > i && !b[i].is_ascii_digit() {
                return Some(text[i..end].to_string());
            }
            return None;
        }
        // a trailing `mut` keyword?
        if is_ident_byte(b[i - 1]) {
            let end = i;
            while i > 0 && is_ident_byte(b[i - 1]) {
                i -= 1;
            }
            if &text[i..end] == "mut" {
                continue;
            }
            return None;
        }
        return None;
    }
}

/// `let [mut] name … = [std::collections::] Hash{Map,Set}::…` on one line.
fn let_bound_name(text: &str) -> Option<String> {
    let let_pos = find_token(text, "let", 0)?;
    let toks = idents(text);
    let mut it = toks.iter().skip_while(|&&(s, _)| s <= let_pos);
    let &(mut s, mut e) = it.next()?;
    if &text[s..e] == "mut" {
        let &(s2, e2) = it.next()?;
        s = s2;
        e = e2;
    }
    let name = &text[s..e];
    let eq = text[e..].find('=').map(|p| e + p)?;
    let rhs = text[eq + 1..].trim_start();
    let rhs = rhs.strip_prefix("std::collections::").unwrap_or(rhs);
    if rhs.starts_with("HashMap::") || rhs.starts_with("HashSet::") {
        Some(name.to_string())
    } else {
        None
    }
}

struct IterHit {
    folded: bool,
}

/// Does this line iterate one of the tracked unordered containers —
/// either `name.iter()`-style or `for … in … name …`? `folded` reports
/// whether the same line chains into `.sum()`/`.fold()`/`.product()`.
fn unordered_iteration(text: &str, tracked: &[String]) -> Option<IterHit> {
    let mut hit = false;
    for name in tracked {
        let mut from = 0usize;
        while let Some(pos) = find_token(text, name, from) {
            from = pos + 1;
            let after = text[pos + name.len()..].trim_start();
            let Some(meth) = after.strip_prefix('.') else {
                continue;
            };
            let meth = meth.trim_start();
            for m in ITER_METHODS {
                if let Some(rest) = meth.strip_prefix(m) {
                    let rest = rest.trim_start();
                    let next = meth.as_bytes().get(m.len()).copied();
                    let boundary = !next.is_some_and(is_ident_byte);
                    if boundary && rest.starts_with('(') {
                        hit = true;
                    }
                }
            }
        }
    }
    if !hit {
        if let Some(for_pos) = find_token(text, "for", 0) {
            if let Some(in_pos) = find_token(text, "in", for_pos + 3) {
                let rest = &text[in_pos + 2..];
                if tracked.iter().any(|n| contains_token(rest, n)) {
                    hit = true;
                }
            }
        }
    }
    if !hit {
        return None;
    }
    let folded = FOLD_METHODS.iter().any(|m| {
        let mut from = 0usize;
        while let Some(pos) = find_token(text, m, from) {
            from = pos + 1;
            if pos > 0 && text.as_bytes()[pos - 1] == b'.' {
                return true;
            }
        }
        false
    });
    Some(IterHit { folded })
}

/// `assert!` / `assert_eq!` / `assert_ne!` as a standalone token (the
/// ident-boundary check excludes `debug_assert*!`).
fn has_release_assert(text: &str) -> bool {
    for tok in ["assert", "assert_eq", "assert_ne"] {
        let mut from = 0usize;
        while let Some(pos) = find_token(text, tok, from) {
            from = pos + 1;
            let rest = text[pos + tok.len()..].trim_start();
            if let Some(rest) = rest.strip_prefix('!') {
                if rest.trim_start().starts_with('(') {
                    return true;
                }
            }
        }
    }
    false
}

/// Unit-suffix families for U1. Two identifiers in a *direct* flow
/// (`a = b`, `a += b`, comparisons, `a.max(b)`) whose suffixes differ
/// within one family are a unit bug (`_ms` vs `_s`, `_wh` vs `_kwh`, …).
/// Cross-family flows (`power_w * dt_s`) are physics, not bugs, and
/// conversions spelled as arithmetic carry literals that break the
/// "bare identifier on both sides" shape — so they pass.
fn suffix_family(suffix: &str) -> Option<u8> {
    match suffix {
        "s" | "ms" | "ns" => Some(0),  // time
        "w" | "kw" => Some(1),         // power
        "j" | "wh" | "kwh" => Some(2), // energy
        "g" | "kg" => Some(3),         // carbon mass
        _ => None,
    }
}

/// The unit suffix of a dotted path expression: the `_xyz` tail of its
/// last segment, if it names a known unit.
fn path_suffix(path: &str) -> Option<(&str, u8)> {
    let last = path.rsplit('.').next().unwrap_or(path);
    let (_, suffix) = last.rsplit_once('_')?;
    suffix_family(suffix).map(|fam| (suffix, fam))
}

/// Byte spans of dotted path expressions (`self.total_wh`, `flow.pv_j`)
/// in a sanitized line.
fn path_tokens(text: &str) -> Vec<(usize, usize)> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    let is_path_byte = |c: u8| is_ident_byte(c) || c == b'.';
    while i < b.len() {
        if is_path_byte(b[i]) {
            let start = i;
            while i < b.len() && is_path_byte(b[i]) {
                i += 1;
            }
            // must start like an identifier, not a number or bare dot
            if b[start] == b'_' || b[start].is_ascii_alphabetic() {
                out.push((start, i));
            }
        } else {
            i += 1;
        }
    }
    out
}

/// Count U1 hits on a line: `lhs OP rhs` at end-of-statement with both
/// sides suffixed in the same family but with different units, plus
/// `lhs.max(rhs)` / `lhs.min(rhs)` with the same mismatch.
fn unit_mismatches(text: &str) -> usize {
    let toks = path_tokens(text);
    let mut count = 0usize;
    const FLOW_OPS: [&str; 9] = ["=", "+=", "-=", "==", "!=", "<=", ">=", "<", ">"];
    for pair in toks.windows(2) {
        let (a_s, a_e) = pair[0];
        let (b_s, b_e) = pair[1];
        let between = text[a_e..b_s].trim();
        if !FLOW_OPS.contains(&between) {
            continue;
        }
        // end-of-statement anchor: nothing after the rhs but `;`/`,`/`)`
        let tail = text[b_e..].trim();
        if !(tail.is_empty() || (tail.len() == 1 && ";,)".contains(tail))) {
            continue;
        }
        if let (Some((ua, fa)), Some((ub, fb))) =
            (path_suffix(&text[a_s..a_e]), path_suffix(&text[b_s..b_e]))
        {
            if fa == fb && ua != ub {
                count += 1;
            }
        }
    }
    // lhs.max(rhs) / lhs.min(rhs)
    for &(a_s, a_e) in &toks {
        let lhs = &text[a_s..a_e];
        let Some(base) = lhs.strip_suffix(".max").or_else(|| lhs.strip_suffix(".min")) else {
            continue;
        };
        let Some(arg) = text[a_e..].trim_start().strip_prefix('(') else {
            continue;
        };
        let arg = arg.trim_start();
        let end = arg
            .as_bytes()
            .iter()
            .position(|&c| !(is_ident_byte(c) || c == b'.'))
            .unwrap_or(arg.len());
        if !arg[end..].trim_start().starts_with(')') || end == 0 {
            continue;
        }
        if let (Some((ua, fa)), Some((ub, fb))) = (path_suffix(base), path_suffix(&arg[..end])) {
            if fa == fb && ua != ub {
                count += 1;
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lint_source;

    fn rules_of(path: &str, src: &str) -> Vec<String> {
        lint_source(path, src).findings.iter().map(|f| f.rule.id().to_string()).collect()
    }

    #[test]
    fn d1_requires_iteration_not_lookup() {
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<String, f64>) -> f64 {\n    *m.get(\"x\").unwrap_or(&0.0)\n}\n";
        assert!(rules_of("rust/src/sim/x.rs", src).is_empty(), "lookups are fine");
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<String, f64>) {\n    for k in m.keys() {\n        drop(k);\n    }\n}\n";
        assert_eq!(rules_of("rust/src/sim/x.rs", src), ["D1"]);
        assert!(rules_of("rust/src/util/x.rs", src).is_empty(), "scoped to det modules");
    }

    #[test]
    fn d3_captures_folds_and_stays_disjoint_from_d1() {
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<String, f64>) -> f64 {\n    m.values().sum()\n}\n";
        assert_eq!(rules_of("rust/src/sim/x.rs", src), ["D3"]);
    }

    #[test]
    fn d2_everywhere_except_bench() {
        let src = "fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
        assert_eq!(rules_of("rust/src/util/table.rs", src), ["D2"]);
        assert!(rules_of("rust/src/util/bench.rs", src).is_empty());
    }

    #[test]
    fn p2_exempts_validate_fns_and_debug_asserts() {
        let src = "pub fn validate_spec(x: f64) {\n    assert!(x > 0.0);\n}\nfn hot(x: f64) {\n    debug_assert!(x > 0.0);\n}\n";
        assert!(rules_of("rust/src/sim/x.rs", src).is_empty());
    }

    #[test]
    fn u1_mismatched_family_only() {
        let src = "fn f(a_ms: f64, b_s: f64, c_w: f64) {\n    let mut x_ms = a_ms;\n    x_ms = b_s;\n    x_ms = c_w;\n}\n";
        assert_eq!(rules_of("rust/src/energy/x.rs", src), ["U1"], "time≠time fires, time≠power not");
    }

    #[test]
    fn u1_max_min_flows() {
        let src = "fn f(a_wh: f64, b_kwh: f64) -> f64 {\n    a_wh.max(b_kwh)\n}\n";
        assert_eq!(rules_of("rust/src/energy/x.rs", src), ["U1"]);
    }
}
