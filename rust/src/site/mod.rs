//! # Hierarchical multi-site fleets: the geographic routing layer
//!
//! The L3.5 simulator models one flat fleet; the real "millions of users
//! at the edge" problem is geographic — heterogeneous edge data centers
//! with local grids/microgrids linked by WAN hops that cost latency *and*
//! energy, where staggered-timezone grids and rotating PV peaks make
//! cross-region shifting the dominant carbon lever (GreenScale, the
//! Vertical edge-DC line of work). This module is that layer:
//!
//! * a [`SiteSpec`] groups a slice of the node fleet under one name and
//!   timezone offset — each site keeps its own grid trace / microgrid
//!   profile on its nodes, and the *existing* [`crate::scheduler::Scheduler`]
//!   runs unchanged within the site;
//! * a [`SiteTopology`] prices every ordered site pair with a [`WanLink`]
//!   (one-way latency in ms + transfer energy in joules per shipped
//!   request, derived from bytes-on-the-wire × J/byte): shipped requests
//!   pay the hop in end-to-end latency and the transfer joules enter the
//!   Eq. 2 carbon accounting at the origin site's effective intensity;
//! * a cross-site [`Router`] decides which region's grid/PV eats each
//!   request *before* the local scheduler places it within the site,
//!   deciding over O(sites) [`SiteView`] summaries — never O(total-nodes)
//!   snapshots. Three policies: [`NearestSiteRouter`] (keep everything at
//!   the arrival's home region — the latency-first baseline),
//!   [`CarbonGreedyRouter`] (always the cleanest region, transfer and
//!   deadline be damned), and [`DeadlineFeasibleCarbonRouter`] (ship only
//!   when the WAN hop + remote queue still clears the deadline *and* the
//!   grid delta clears the transfer energy).
//!
//! The simulator threads the layer through [`crate::sim::Scenario::sites`]:
//! arrivals draw a home site from a dedicated seeded stream, the router
//! picks the target, remote targets pay the WAN hop (a `wan_hop` firehose
//! event carries the priced joules/grams so replayed ledgers still
//! balance), and reports break completions, WAN-shipped share, transfer
//! energy and gCO₂/req out per site ([`crate::sim::SiteUsage`]). The
//! `multi-site` and `follow-the-sun` scenarios exercise it; with
//! `Scenario::sites = None` nothing here is ever constructed.

/// Default feasibility margin the deadline-aware router keeps between a
/// shipped request's ETA and its deadline (seconds): absorbs queue-estimate
/// error at the remote site so "feasible" survives a mildly stale view.
pub const DEFAULT_ROUTE_MARGIN_S: f64 = 60.0;

/// Default payload of one shipped inference request (bytes on the wire):
/// a 224×224×3 uint8 tensor plus framing.
pub const DEFAULT_REQUEST_BYTES: f64 = 160_000.0;

/// Default WAN transfer energy per byte (J/B): core-network transport at
/// the tens-of-nJ/B regime reported for wide-area transmission.
pub const DEFAULT_WAN_J_PER_BYTE: f64 = 4e-8;

/// One edge site: a named region grouping a slice of the node fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteSpec {
    /// Region name (prefixes per-site report rows).
    pub name: String,
    /// Timezone offset from the simulation clock (seconds). Scenario
    /// builders phase-shift grid traces and PV sunrises by it; the layer
    /// itself only carries it for reporting.
    pub tz_offset_s: f64,
}

impl SiteSpec {
    pub fn new(name: &str, tz_offset_s: f64) -> SiteSpec {
        SiteSpec { name: name.into(), tz_offset_s }
    }
}

/// One directed WAN link between two sites.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WanLink {
    /// One-way transfer latency (ms) a shipped request pays before it can
    /// enter the remote site's queues.
    pub latency_ms: f64,
    /// Transfer energy (joules) per shipped request, priced into Eq. 2
    /// carbon at the origin site's effective intensity.
    pub energy_j: f64,
}

impl WanLink {
    /// The zero link (a site to itself).
    pub fn zero() -> WanLink {
        WanLink { latency_ms: 0.0, energy_j: 0.0 }
    }

    /// Price a link from bytes on the wire: `latency_ms` one-way delay,
    /// `bytes × j_per_byte` joules per shipped request.
    pub fn of_bytes(latency_ms: f64, bytes: f64, j_per_byte: f64) -> WanLink {
        WanLink { latency_ms, energy_j: bytes * j_per_byte }
    }
}

/// Dense ordered-pair WAN link matrix over `n` sites (diagonal zero).
#[derive(Debug, Clone, PartialEq)]
pub struct SiteTopology {
    n: usize,
    links: Vec<WanLink>,
}

impl SiteTopology {
    /// `n` sites, every inter-site link zero (patch with [`Self::set`]).
    pub fn new(n: usize) -> SiteTopology {
        SiteTopology { n, links: vec![WanLink::zero(); n * n] }
    }

    /// `n` sites with the same `link` on every ordered off-diagonal pair.
    pub fn uniform(n: usize, link: WanLink) -> SiteTopology {
        let mut t = SiteTopology::new(n);
        for a in 0..n {
            for b in 0..n {
                if a != b {
                    t.links[a * n + b] = link;
                }
            }
        }
        t
    }

    pub fn n_sites(&self) -> usize {
        self.n
    }

    /// Install the directed link `from → to` (panics on the diagonal).
    pub fn set(&mut self, from: usize, to: usize, link: WanLink) {
        // lint: allow(P2 topology construction is a one-shot; the panic is the documented API)
        assert!(from != to, "the diagonal stays zero");
        self.links[from * self.n + to] = link;
    }

    /// The directed link `from → to` (zero on the diagonal).
    pub fn link(&self, from: usize, to: usize) -> &WanLink {
        &self.links[from * self.n + to]
    }
}

/// O(1)-sized routing summary of one site at decision time, maintained by
/// the engine from running per-site aggregates — a router over `S` sites
/// sees `S` of these, never per-node snapshots.
#[derive(Debug, Clone, Copy)]
pub struct SiteView {
    /// Site index (into the scenario's [`SiteLayer::sites`]).
    pub index: usize,
    /// Mean effective carbon intensity over the site's *active* nodes
    /// (gCO₂/kWh, microgrid-blended); `f64::INFINITY` when the whole site
    /// is churned out.
    pub intensity: f64,
    /// Queue-pressure estimate (seconds): outstanding tasks × mean
    /// service ÷ service slots.
    pub queue_delay_s: f64,
    /// Nodes currently powered on.
    pub active_nodes: usize,
    /// Aggregate service slots across active nodes.
    pub slots: usize,
    /// Mean single-task service estimate across active nodes (seconds).
    pub est_service_s: f64,
    /// Estimated dynamic energy of one task here (joules): mean dynamic
    /// power × `est_service_s`. What the router prices grid deltas over.
    pub task_energy_j: f64,
}

impl SiteView {
    /// Estimated task carbon if this site eats the request (grams,
    /// pre-PUE): `task_energy_j → kWh × intensity`.
    pub fn task_carbon_g(&self) -> f64 {
        self.task_energy_j / 3.6e6 * self.intensity
    }
}

/// Cross-site routing policy: which region's grid/PV eats each request.
/// Runs *before* the target site's local [`crate::scheduler::Scheduler`];
/// must be deterministic for identical inputs.
pub trait Router {
    /// Pick the target site for a request homed at `home`. `deadline_s`
    /// is absolute virtual time when the task carries slack. Must return
    /// a valid site index; returning `home` keeps the request local.
    fn route(
        &mut self,
        home: usize,
        now_s: f64,
        deadline_s: Option<f64>,
        sites: &[SiteView],
        topo: &SiteTopology,
    ) -> usize;

    fn name(&self) -> &str;
}

/// Latency-first baseline: every request stays at its home region
/// (falling over to the cheapest active site only when home is fully
/// churned out — a dead region cannot serve).
pub struct NearestSiteRouter;

impl Router for NearestSiteRouter {
    fn route(
        &mut self,
        home: usize,
        _now_s: f64,
        _deadline_s: Option<f64>,
        sites: &[SiteView],
        _topo: &SiteTopology,
    ) -> usize {
        if sites[home].active_nodes > 0 {
            return home;
        }
        cleanest_active(sites).unwrap_or(home)
    }

    fn name(&self) -> &str {
        "nearest"
    }
}

/// Carbon-only baseline: always the cleanest active region, ignoring both
/// the deadline and the transfer energy — the upper bound on shifting
/// aggression (and on WAN waste). Ties keep home, then the lowest index.
pub struct CarbonGreedyRouter;

impl Router for CarbonGreedyRouter {
    fn route(
        &mut self,
        home: usize,
        _now_s: f64,
        _deadline_s: Option<f64>,
        sites: &[SiteView],
        _topo: &SiteTopology,
    ) -> usize {
        let mut best = home;
        let mut best_i =
            if sites[home].active_nodes > 0 { sites[home].intensity } else { f64::INFINITY };
        for s in sites {
            if s.active_nodes > 0 && s.intensity < best_i {
                best = s.index;
                best_i = s.intensity;
            }
        }
        best
    }

    fn name(&self) -> &str {
        "carbon"
    }
}

/// The deadline-feasible carbon router: ship a request to another region
/// only when (a) the WAN hop + remote queue + remote service still clears
/// the deadline with `margin_s` to spare, and (b) the grid delta clears
/// the transfer energy by at least `min_gain_g` grams — i.e. remote task
/// carbon + transfer carbon (priced at the *origin's* intensity: the
/// sending edge powers the uplink) beats running at home.
pub struct DeadlineFeasibleCarbonRouter {
    /// Feasibility slack (seconds) kept between the shipped ETA and the
    /// deadline.
    pub margin_s: f64,
    /// Minimum per-request carbon saving (grams, pre-PUE) required to pay
    /// the WAN hop at all — a hysteresis floor against churn-shipping on
    /// noise-level grid deltas.
    pub min_gain_g: f64,
}

impl Router for DeadlineFeasibleCarbonRouter {
    fn route(
        &mut self,
        home: usize,
        now_s: f64,
        deadline_s: Option<f64>,
        sites: &[SiteView],
        topo: &SiteTopology,
    ) -> usize {
        let mut best = home;
        let mut best_g =
            if sites[home].active_nodes > 0 { sites[home].task_carbon_g() } else { f64::INFINITY };
        let origin_i = sites[home].intensity;
        for s in sites {
            if s.index == home || s.active_nodes == 0 {
                continue;
            }
            let link = topo.link(home, s.index);
            if let Some(d) = deadline_s {
                let hop_s = link.latency_ms / 1e3;
                let eta = now_s + hop_s + s.queue_delay_s + s.est_service_s + self.margin_s;
                if eta > d {
                    continue;
                }
            }
            let wan_g = if origin_i.is_finite() { link.energy_j / 3.6e6 * origin_i } else { 0.0 };
            let g = s.task_carbon_g() + wan_g;
            if g < best_g - self.min_gain_g {
                best = s.index;
                best_g = g;
            }
        }
        best
    }

    fn name(&self) -> &str {
        "deadline"
    }
}

/// Lowest-intensity site with at least one active node.
fn cleanest_active(sites: &[SiteView]) -> Option<usize> {
    let mut best: Option<usize> = None;
    let mut best_i = f64::INFINITY;
    for s in sites {
        if s.active_nodes > 0 && s.intensity < best_i {
            best = Some(s.index);
            best_i = s.intensity;
        }
    }
    best
}

/// Cloneable router configuration a [`SiteLayer`] carries; the engine
/// builds the boxed policy per run with [`RouterSpec::build`].
#[derive(Debug, Clone, PartialEq)]
pub enum RouterSpec {
    /// [`NearestSiteRouter`].
    Nearest,
    /// [`CarbonGreedyRouter`].
    Carbon,
    /// [`DeadlineFeasibleCarbonRouter`] with its two knobs.
    Deadline { margin_s: f64, min_gain_g: f64 },
}

impl Default for RouterSpec {
    fn default() -> RouterSpec {
        RouterSpec::Deadline { margin_s: DEFAULT_ROUTE_MARGIN_S, min_gain_g: 0.0 }
    }
}

impl RouterSpec {
    /// Parse a CLI/registry name: `nearest`, `carbon` or `deadline`.
    pub fn parse(s: &str) -> Option<RouterSpec> {
        match s {
            "nearest" => Some(RouterSpec::Nearest),
            "carbon" => Some(RouterSpec::Carbon),
            "deadline" => Some(RouterSpec::default()),
            _ => None,
        }
    }

    /// The stable routing-policy name (report/meta field).
    pub fn name(&self) -> &'static str {
        match self {
            RouterSpec::Nearest => "nearest",
            RouterSpec::Carbon => "carbon",
            RouterSpec::Deadline { .. } => "deadline",
        }
    }

    /// Build the boxed policy this spec describes.
    pub fn build(&self) -> Box<dyn Router> {
        match *self {
            RouterSpec::Nearest => Box::new(NearestSiteRouter),
            RouterSpec::Carbon => Box::new(CarbonGreedyRouter),
            RouterSpec::Deadline { margin_s, min_gain_g } => {
                Box::new(DeadlineFeasibleCarbonRouter { margin_s, min_gain_g })
            }
        }
    }
}

/// The full geographic layer a [`crate::sim::Scenario`] may carry: the
/// site roster, the node→site partition, the WAN topology and the router.
#[derive(Debug, Clone)]
pub struct SiteLayer {
    /// The site roster; `site_of` indexes into it.
    pub sites: Vec<SiteSpec>,
    /// Node index → site index, one entry per scenario node.
    pub site_of: Vec<usize>,
    /// WAN links over `sites`.
    pub topology: SiteTopology,
    /// Cross-site routing policy.
    pub router: RouterSpec,
}

impl SiteLayer {
    /// Structural validation against the owning scenario's node count.
    pub fn validate(&self, n_nodes: usize) -> Result<(), String> {
        if self.sites.len() < 2 {
            return Err(format!("site layer needs >= 2 sites, got {}", self.sites.len()));
        }
        if self.site_of.len() != n_nodes {
            return Err(format!(
                "site_of covers {} nodes, scenario has {n_nodes}",
                self.site_of.len()
            ));
        }
        if let Some(&bad) = self.site_of.iter().find(|&&s| s >= self.sites.len()) {
            return Err(format!("site_of points at site {bad}, only {} exist", self.sites.len()));
        }
        if self.topology.n_sites() != self.sites.len() {
            return Err(format!(
                "topology spans {} sites, roster has {}",
                self.topology.n_sites(),
                self.sites.len()
            ));
        }
        for a in 0..self.sites.len() {
            for b in 0..self.sites.len() {
                let l = self.topology.link(a, b);
                if !l.latency_ms.is_finite()
                    || l.latency_ms < 0.0
                    || !l.energy_j.is_finite()
                    || l.energy_j < 0.0
                {
                    return Err(format!("link {a}->{b} must be finite and >= 0, got {l:?}"));
                }
            }
        }
        for (i, s) in self.sites.iter().enumerate() {
            if !self.site_of.contains(&i) {
                return Err(format!("site {} ({}) has no nodes", i, s.name));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(intensities: &[f64]) -> Vec<SiteView> {
        intensities
            .iter()
            .enumerate()
            .map(|(i, &g)| SiteView {
                index: i,
                intensity: g,
                queue_delay_s: 0.0,
                active_nodes: 2,
                slots: 2,
                est_service_s: 0.5,
                task_energy_j: 100.0,
            })
            .collect()
    }

    #[test]
    fn topology_uniform_keeps_diagonal_zero() {
        let t = SiteTopology::uniform(3, WanLink { latency_ms: 40.0, energy_j: 8.0 });
        assert_eq!(t.n_sites(), 3);
        for a in 0..3 {
            assert_eq!(*t.link(a, a), WanLink::zero());
            for b in 0..3 {
                if a != b {
                    assert_eq!(t.link(a, b).latency_ms, 40.0);
                    assert_eq!(t.link(a, b).energy_j, 8.0);
                }
            }
        }
        let l = WanLink::of_bytes(60.0, DEFAULT_REQUEST_BYTES, DEFAULT_WAN_J_PER_BYTE);
        assert!((l.energy_j - 160_000.0 * 4e-8).abs() < 1e-12);
    }

    #[test]
    fn router_spec_parses_and_builds() {
        assert_eq!(RouterSpec::parse("nearest"), Some(RouterSpec::Nearest));
        assert_eq!(RouterSpec::parse("carbon"), Some(RouterSpec::Carbon));
        assert_eq!(RouterSpec::parse("deadline"), Some(RouterSpec::default()));
        assert_eq!(RouterSpec::parse("bogus"), None);
        for (spec, name) in [
            (RouterSpec::Nearest, "nearest"),
            (RouterSpec::Carbon, "carbon"),
            (RouterSpec::default(), "deadline"),
        ] {
            assert_eq!(spec.name(), name);
            assert_eq!(spec.build().name(), name);
        }
    }

    #[test]
    fn nearest_keeps_home_unless_dead() {
        let topo = SiteTopology::uniform(3, WanLink::zero());
        let mut r = NearestSiteRouter;
        let v = views(&[500.0, 100.0, 300.0]);
        assert_eq!(r.route(0, 0.0, None, &v, &topo), 0);
        // Home churned out: fail over to the cleanest active site.
        let mut dead = v.clone();
        dead[0].active_nodes = 0;
        dead[0].intensity = f64::INFINITY;
        assert_eq!(r.route(0, 0.0, None, &dead, &topo), 1);
    }

    #[test]
    fn carbon_greedy_chases_cleanest_and_ties_keep_home() {
        let topo = SiteTopology::uniform(3, WanLink::zero());
        let mut r = CarbonGreedyRouter;
        assert_eq!(r.route(0, 0.0, None, &views(&[500.0, 100.0, 300.0]), &topo), 1);
        // Exact tie with a remote site: home wins (strict <).
        assert_eq!(r.route(2, 0.0, None, &views(&[300.0, 300.0, 300.0]), &topo), 2);
        // Dead sites are never targets, however clean.
        let mut v = views(&[500.0, 100.0, 300.0]);
        v[1].active_nodes = 0;
        assert_eq!(r.route(0, 0.0, None, &v, &topo), 2);
    }

    #[test]
    fn deadline_router_ships_only_on_cleared_deadline_and_gain() {
        let topo = SiteTopology::uniform(2, WanLink { latency_ms: 100.0, energy_j: 10.0 });
        let mut r = DeadlineFeasibleCarbonRouter { margin_s: 1.0, min_gain_g: 0.0 };
        // Remote is 5× cleaner and the deadline is loose: ship.
        let v = views(&[500.0, 100.0]);
        assert_eq!(r.route(0, 0.0, Some(1_000.0), &v, &topo), 1);
        // No deadline at all: carbon gate alone decides.
        assert_eq!(r.route(0, 0.0, None, &v, &topo), 1);
        // Deadline tighter than hop + queue + service + margin: stay home.
        // eta = 0.1 hop + 0 queue + 0.5 service + 1 margin = 1.6 s.
        assert_eq!(r.route(0, 0.0, Some(1.5), &v, &topo), 0);
        // Remote queue pressure pushes the ETA past the deadline too.
        let mut busy = v.clone();
        busy[1].queue_delay_s = 500.0;
        assert_eq!(r.route(0, 0.0, Some(400.0), &busy, &topo), 0);
        // Transfer energy can eat the whole grid delta: near-equal
        // intensities with an expensive link stay home.
        let heavy = SiteTopology::uniform(2, WanLink { latency_ms: 100.0, energy_j: 5_000.0 });
        assert_eq!(r.route(0, 0.0, Some(1_000.0), &views(&[210.0, 200.0]), &heavy), 0);
        // min_gain_g hysteresis: a real but sub-floor saving stays home.
        let mut strict = DeadlineFeasibleCarbonRouter { margin_s: 1.0, min_gain_g: 10.0 };
        assert_eq!(strict.route(0, 0.0, None, &views(&[500.0, 100.0]), &topo), 0);
    }

    #[test]
    fn deadline_router_fails_over_from_a_dead_home() {
        let topo = SiteTopology::uniform(2, WanLink { latency_ms: 40.0, energy_j: 8.0 });
        let mut r = DeadlineFeasibleCarbonRouter { margin_s: 1.0, min_gain_g: 0.0 };
        let mut v = views(&[500.0, 480.0]);
        v[0].active_nodes = 0;
        v[0].intensity = f64::INFINITY;
        assert_eq!(r.route(0, 0.0, Some(1_000.0), &v, &topo), 1);
    }

    #[test]
    fn layer_validates_structure() {
        let layer = || SiteLayer {
            sites: vec![SiteSpec::new("eu", 0.0), SiteSpec::new("us", -21_600.0)],
            site_of: vec![0, 0, 1, 1],
            topology: SiteTopology::uniform(2, WanLink { latency_ms: 40.0, energy_j: 8.0 }),
            router: RouterSpec::default(),
        };
        assert!(layer().validate(4).is_ok());
        assert!(layer().validate(3).is_err(), "site_of length mismatch");
        let mut l = layer();
        l.site_of[0] = 9;
        assert!(l.validate(4).is_err(), "out-of-range site index");
        let mut l = layer();
        l.sites.pop();
        assert!(l.validate(4).is_err(), "topology/roster mismatch");
        let mut l = layer();
        l.topology.set(0, 1, WanLink { latency_ms: -1.0, energy_j: 0.0 });
        assert!(l.validate(4).is_err(), "negative latency");
        let mut l = layer();
        l.site_of = vec![0, 0, 0, 0];
        assert!(l.validate(4).is_err(), "empty site");
        let mut l = layer();
        l.sites.truncate(1);
        l.site_of = vec![0, 0, 0, 0];
        l.topology = SiteTopology::new(1);
        assert!(l.validate(4).is_err(), "single site is not a multi-site layer");
    }
}
